"""Minimal optax-style optimizers (optax is unavailable offline).

An optimizer is a pair of pure functions:
  init(params)                        -> opt_state
  update(grads, opt_state, params)    -> (updates, opt_state)
Updates are applied with ``apply_updates`` (params + updates).  All state is
a pytree of arrays, so the whole thing shards/checkpoints like any pytree.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["GradientTransformation", "adamw", "sgd", "apply_updates",
           "global_norm", "clip_by_global_norm",
           "accumulated_value_and_grad"]


def accumulated_value_and_grad(loss_fn, params, chunks):
    """Mean (loss, grads) over the leading microbatch axis of ``chunks``
    via an on-device ``lax.scan`` (f32 accumulator) — the one
    gradient-accumulation implementation both the mapper trainer
    (``core/train.py``) and the LM launcher (``launch/train.py``) use."""
    def acc(carry, chunk):
        loss_s, g_s = carry
        l, g = jax.value_and_grad(loss_fn)(params, chunk)
        return (loss_s + l,
                jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_s, g)), None

    n = jax.tree_util.tree_leaves(chunks)[0].shape[0]
    zero = (jnp.zeros(()),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss, grads), _ = jax.lax.scan(acc, zero, chunks)
    inv = 1.0 / n
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


class GradientTransformation(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          max_grad_norm: float | None = None) -> GradientTransformation:
    """AdamW with optional global-norm clipping.

    ``lr`` may be a float or a ``step -> lr`` schedule.  Moments are kept in
    f32 regardless of param dtype (mixed-precision-safe).
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state: AdamWState, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(m, v, p):
            u = -(lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step, mu, nu)

    return GradientTransformation(init, update)


class SGDState(NamedTuple):
    step: jax.Array
    mom: object


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.9,
        max_grad_norm: float | None = None) -> GradientTransformation:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                     params))

    def update(grads, state: SGDState, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                           state.mom, grads)
        updates = jax.tree.map(lambda m: -lr_fn(step) * m, mom)
        return updates, SGDState(step, mom)

    return GradientTransformation(init, update)
