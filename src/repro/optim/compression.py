"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients for the cross-pod all-reduce: at 2x16x16
the pod axis rides DCI-class links, so shrinking gradient payload 4x
(bf16->int8 + per-block scales) directly cuts the collective roofline term.
Error feedback (Seide et al. / EF-SGD) accumulates quantization residuals
so convergence is preserved — verified on a quadratic + the DT trainer in
tests/test_substrates.py.

Usage: ``tx = compressed(optim.adamw(...))`` — grads are (de)quantized
before the inner update; the residual buffer lives in the optimizer state
pytree and checkpoints/shards like everything else.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .adamw import GradientTransformation

__all__ = ["quantize_int8", "dequantize_int8", "compressed"]


def quantize_int8(x: jax.Array, block: int = 256):
    """Per-block symmetric int8 quantization along the flattened axis."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(fp / jnp.maximum(scale, 1e-12)), -127, 127
                 ).astype(jnp.int8)
    return q, scale, x.shape, n


def dequantize_int8(q, scale, shape, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def _roundtrip(x):
    return dequantize_int8(*quantize_int8(x))


class CompressedState(NamedTuple):
    inner: object
    err: object         # error-feedback residuals


def compressed(tx: GradientTransformation) -> GradientTransformation:
    def init(params):
        return CompressedState(
            tx.init(params),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state: CompressedState, params):
        acc = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                           grads, state.err)
        sent = jax.tree.map(_roundtrip, acc)       # what crosses the wire
        err = jax.tree.map(lambda a, s: a - s, acc, sent)
        updates, inner = tx.update(sent, state.inner, params)
        return updates, CompressedState(inner, err)

    return GradientTransformation(init, update)
