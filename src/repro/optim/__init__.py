from .adamw import adamw, sgd, apply_updates, global_norm, clip_by_global_norm
from .schedule import cosine_with_warmup, constant, linear_warmup

__all__ = ["adamw", "sgd", "apply_updates", "global_norm",
           "clip_by_global_norm", "cosine_with_warmup", "constant",
           "linear_warmup"]
