from .adamw import (adamw, sgd, apply_updates, global_norm,
                    clip_by_global_norm, accumulated_value_and_grad)
from .schedule import cosine_with_warmup, constant, linear_warmup

__all__ = ["adamw", "sgd", "apply_updates", "global_norm",
           "clip_by_global_norm", "accumulated_value_and_grad",
           "cosine_with_warmup", "constant", "linear_warmup"]
