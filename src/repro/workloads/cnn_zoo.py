"""The paper's CNN workload zoo: VGG16, ResNet18/50, MobileNet-V2, MnasNet-B1.

Each network is lowered to the chain-of-layers IR used by the fusion mapper.
Pooling is folded into the producing conv (MACs use pre-pool output dims,
the *staged* activation uses post-pool dims — that is what occupies the
on-chip buffer). Residual edges are chain annotations (``skip_src``).
Downsample/projection shortcuts in ResNets are folded into the merge layer's
weight/MAC counts so the chain stays a pure sequence (the paper's ResNet18
strategy in Fig. 4 has exactly 18 decisions).
"""
from __future__ import annotations

from .layer import Layer, Workload

__all__ = ["vgg16", "resnet18", "resnet50", "mobilenet_v2", "mnasnet_b1",
           "tiny_cnn", "CNN_ZOO", "get_workload"]


class _ChainBuilder:
    def __init__(self, name: str, c: int, y: int, x: int, batch: int = 64):
        self.name, self.c, self.y, self.x = name, c, y, x
        self.batch = batch
        self.input_elems = float(c * y * x)
        self.input_shape6 = (c, c, y, x, 1, 1)
        self.layers: list[Layer] = []

    @property
    def pos(self) -> int:
        """Chain position of the most recently added layer (0 = input)."""
        return len(self.layers)

    def conv(self, k: int, r: int = 3, stride: int = 1, groups: int = 1,
             pool: int = 1, skip_src: int = -1, extra_w: float = 0.0,
             extra_macs: float = 0.0, name: str = "conv") -> int:
        """Add a conv; returns its chain position."""
        y_out, x_out = self.y // stride, self.x // stride
        macs = float(k) * self.c * y_out * x_out * r * r / groups + extra_macs
        w = float(k) * self.c * r * r / groups + extra_w
        y_st, x_st = y_out // pool, x_out // pool  # staged (post-pool) dims
        self.layers.append(Layer(
            name=f"{name}{self.pos + 1}", K=k, C=self.c, Y=y_st, X=x_st,
            R=r, S=r, stride=stride, groups=groups, skip_src=skip_src,
            macs_override=macs, w_elems_override=w,
            out_elems_override=float(k * y_st * x_st)))
        self.c, self.y, self.x = k, y_st, x_st
        return self.pos

    def gap(self) -> None:
        """Global average pool (free op; collapses spatial dims)."""
        self.y = self.x = 1

    def fc(self, n: int, name: str = "fc") -> int:
        in_f = int(self.c * self.y * self.x)
        self.layers.append(Layer.matmul(f"{name}{self.pos + 1}", m=1, k=in_f, n=n))
        self.c, self.y, self.x = n, 1, 1
        return self.pos

    def build(self) -> Workload:
        return Workload(self.name, self.layers, self.input_elems,
                        self.input_shape6, default_batch=self.batch)


def vgg16(batch: int = 64) -> Workload:
    b = _ChainBuilder("vgg16", 3, 224, 224, batch)
    for stage, (k, reps) in enumerate([(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]):
        for i in range(reps):
            b.conv(k, r=3, pool=2 if i == reps - 1 else 1)
    b.fc(4096); b.fc(4096); b.fc(1000)
    return b.build()


def resnet18(batch: int = 64) -> Workload:
    b = _ChainBuilder("resnet18", 3, 224, 224, batch)
    b.conv(64, r=7, stride=2, pool=2, name="stem")  # 7x7/2 + maxpool -> 56x56
    cfg = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]
    for k, reps, first_stride in cfg:
        for i in range(reps):
            s = first_stride if i == 0 else 1
            src = b.pos  # block input
            downsample = s != 1 or b.c != k
            # 1x1/s projection shortcut folded into the merge conv below.
            proj_w = float(k) * b.c if downsample else 0.0
            proj_macs = proj_w * (b.y // s) * (b.x // s)
            b.conv(k, r=3, stride=s)
            b.conv(k, r=3, skip_src=src, extra_w=proj_w, extra_macs=proj_macs)
    b.gap()
    b.fc(1000)
    return b.build()


def resnet50(batch: int = 64) -> Workload:
    b = _ChainBuilder("resnet50", 3, 224, 224, batch)
    b.conv(64, r=7, stride=2, pool=2, name="stem")
    cfg = [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 6, 2), (512, 2048, 3, 2)]
    for mid, out, reps, first_stride in cfg:
        for i in range(reps):
            s = first_stride if i == 0 else 1
            src = b.pos
            downsample = s != 1 or b.c != out
            proj_w = float(out) * b.c if downsample else 0.0
            proj_macs = proj_w * (b.y // s) * (b.x // s)
            b.conv(mid, r=1)
            b.conv(mid, r=3, stride=s)
            b.conv(out, r=1, skip_src=src, extra_w=proj_w, extra_macs=proj_macs)
    b.gap()
    b.fc(1000)
    return b.build()


def mobilenet_v2(batch: int = 64) -> Workload:
    b = _ChainBuilder("mobilenet_v2", 3, 224, 224, batch)
    b.conv(32, r=3, stride=2, name="stem")
    # t=1 bottleneck: dw + pw
    b.conv(32, r=3, groups=32, name="dw")
    b.conv(16, r=1, name="pw")
    cfg = [(6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, reps, first_stride in cfg:
        for i in range(reps):
            s = first_stride if i == 0 else 1
            src = b.pos
            residual = (s == 1 and b.c == c)
            b.conv(b.c * t, r=1, name="expand")
            b.conv(b.c, r=3, stride=s, groups=b.c, name="dw")
            b.conv(c, r=1, skip_src=src if residual else -1, name="project")
    b.conv(1280, r=1, name="head")
    b.gap()
    b.fc(1000)
    return b.build()


def mnasnet_b1(batch: int = 64) -> Workload:
    b = _ChainBuilder("mnasnet_b1", 3, 224, 224, batch)
    b.conv(32, r=3, stride=2, name="stem")
    b.conv(32, r=3, groups=32, name="dw")
    b.conv(16, r=1, name="pw")
    cfg = [(3, 24, 3, 2, 3), (3, 40, 3, 2, 5), (6, 80, 3, 2, 5),
           (6, 96, 2, 1, 3), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3)]
    for t, c, reps, first_stride, r in cfg:
        for i in range(reps):
            s = first_stride if i == 0 else 1
            src = b.pos
            residual = (s == 1 and b.c == c)
            b.conv(b.c * t, r=1, name="expand")
            b.conv(b.c, r=r, stride=s, groups=b.c, name="dw")
            b.conv(c, r=1, skip_src=src if residual else -1, name="project")
    b.conv(1280, r=1, name="head")
    b.gap()
    b.fc(1000)
    return b.build()


def tiny_cnn(batch: int = 64) -> Workload:
    """A 6-layer VGG-style chain on 32x32 inputs — small enough that the
    whole teacher -> corpus -> train -> infer pipeline smoke-tests in
    seconds (CI training smoke job), with the same layer mix (convs with
    pooling + an FC head) the real zoo exercises."""
    b = _ChainBuilder("tiny_cnn", 3, 32, 32, batch)
    for k, reps in [(16, 2), (32, 2), (64, 1)]:
        for i in range(reps):
            b.conv(k, r=3, pool=2 if i == reps - 1 else 1)
    b.fc(64)
    return b.build()


CNN_ZOO = {
    "vgg16": vgg16,
    "resnet18": resnet18,
    "tiny_cnn": tiny_cnn,
    "resnet50": resnet50,
    "mobilenet_v2": mobilenet_v2,
    "mnasnet": mnasnet_b1,
}


def get_workload(name: str, batch: int = 64) -> Workload:
    if name not in CNN_ZOO:
        raise KeyError(f"unknown workload {name!r}; have {sorted(CNN_ZOO)}")
    return CNN_ZOO[name](batch)
