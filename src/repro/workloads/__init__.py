from .layer import Layer, Workload
from .cnn_zoo import (CNN_ZOO, get_workload, vgg16, resnet18, resnet50,
                      mobilenet_v2, mnasnet_b1, tiny_cnn)

__all__ = ["Layer", "Workload", "CNN_ZOO", "get_workload", "vgg16",
           "resnet18", "resnet50", "mobilenet_v2", "mnasnet_b1", "tiny_cnn"]
