"""Lower an assigned ArchConfig to a fusion-mapper Workload (beyond-paper).

The paper maps CNN chains; here every assigned LM architecture becomes a
chain at transformer-block granularity — the granularity at which
inter-layer fusion (FLAT-style activation staging across blocks) operates.
Per block: MACs = the block's matmul work per *sample* (one sequence for
train/prefill, one token for decode — where the fusible axis is the
sequence-chunk/batch of requests, DESIGN §5), staged activation = the
block-boundary hidden state, weights = the block's parameters (ALL experts
for MoE — residency is what fusion must budget, which is why the mapper
learns to sync around expert blocks).
"""
from __future__ import annotations

from ..configs import ArchConfig
from .layer import Layer, Workload

__all__ = ["lm_workload"]


def _block_stats(cfg: ArchConfig, seq: int, per_token: bool):
    """(macs, w_elems) per sample for one decoder block."""
    d, hd = cfg.d_model, cfg.hd
    toks = 1 if per_token else seq
    attn_w = d * (cfg.n_heads * hd) + 2 * d * (cfg.kv_heads * hd) \
        + (cfg.n_heads * hd) * d
    attn_macs = toks * attn_w
    # attention itself: per token attends to `seq` keys (cache len)
    kv_span = seq
    attn_macs += 2.0 * toks * kv_span * cfg.n_heads * hd
    if cfg.n_experts:
        w_ffn = cfg.n_experts * 3 * d * cfg.d_ff
        macs_ffn = toks * cfg.moe_top_k * 3 * d * cfg.d_ff
    elif cfg.family == "ssm":
        w_ffn = d * cfg.d_ff + cfg.d_ff * d + d * d     # channel mix + gate
        macs_ffn = toks * w_ffn
        attn_w = 4 * d * d                               # r,k,v,o time-mix
        attn_macs = toks * attn_w + toks * d * hd        # wkv update
    else:
        mult = 3 if cfg.mlp_kind == "swiglu" else 2
        w_ffn = mult * d * cfg.d_ff
        macs_ffn = toks * w_ffn
    if cfg.family == "hybrid":
        w_ffn += 2 * d * d + d * 2 * cfg.ssm_state
        macs_ffn += toks * (2 * d * d)
    return float(attn_macs + macs_ffn), float(attn_w + w_ffn)


def lm_workload(cfg: ArchConfig, *, seq_len: int, batch: int,
                mode: str = "train") -> Workload:
    """One Workload layer per transformer block (+ embed & head)."""
    per_token = (mode == "decode")
    toks = 1 if per_token else seq_len
    d = cfg.d_model
    layers: list[Layer] = []
    # embed: per sample act = toks x d
    layers.append(Layer.op(
        "embed", macs=float(toks * d), out_elems=float(toks * d),
        w_elems=float(cfg.vocab_padded * d),
        shape6=(d, cfg.vocab_padded, toks, 1, 1, 1)))
    macs, w = _block_stats(cfg, seq_len, per_token)
    n_blocks = cfg.n_layers + (cfg.encoder_layers if cfg.family == "encdec"
                               else 0)
    for i in range(n_blocks):
        layers.append(Layer.op(
            f"block{i}", macs=macs, out_elems=float(toks * d), w_elems=w,
            shape6=(d, d, toks, 1, cfg.d_ff // max(d, 1) + 1, 1)))
    layers.append(Layer.op(
        "head", macs=float(toks * d * cfg.vocab_padded),
        out_elems=float(toks * cfg.vocab_padded),
        w_elems=float(d * cfg.vocab_padded),
        shape6=(cfg.vocab_padded, d, toks, 1, 1, 1)))
    return Workload(f"{cfg.name}_{mode}", layers,
                    input_elems=float(toks),
                    input_shape6=(1, 1, toks, 1, 1, 1),
                    default_batch=batch)
