"""6-loop layer IR for the fusion map-space.

The paper (Eq. 2) describes every layer with the 6-loop CONV notation
``[K, C, Y, X, R, S]`` (output channels, input channels, output height,
output width, kernel height, kernel width).  Matmuls / FC layers / attention
blocks are expressed in the same notation via factory helpers, so the mapper
state features stay uniform across CNN and LM workloads.

A :class:`Workload` is a *chain* of layers (the paper's strategy vector is a
chain decision); residual/skip edges are annotated per-layer via
``skip_src`` and handled by the cost model as held-buffer / crossing-traffic
terms.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

__all__ = ["Layer", "Workload"]


@dataclass(frozen=True)
class Layer:
    """One fusible layer in 6-loop notation.

    ``macs``/``out_elems``/``w_elems`` are *per input sample* and default to
    the conv formulas; the factories override them for non-conv ops.
    ``skip_src`` is the 1-based position (in the chain, 0 = network input) of
    a residual source whose activation must be live until this layer
    consumes it; ``-1`` means no skip edge.
    """

    name: str
    K: int
    C: int
    Y: int
    X: int
    R: int = 1
    S: int = 1
    stride: int = 1
    groups: int = 1
    skip_src: int = -1
    # Explicit overrides (per-sample); ``None`` -> derived from the 6 loops.
    macs_override: float | None = None
    out_elems_override: float | None = None
    w_elems_override: float | None = None

    # ---- derived quantities (per sample) ---------------------------------
    @property
    def macs(self) -> float:
        if self.macs_override is not None:
            return float(self.macs_override)
        return float(self.K) * self.C * self.Y * self.X * self.R * self.S / self.groups

    @property
    def out_elems(self) -> float:
        if self.out_elems_override is not None:
            return float(self.out_elems_override)
        return float(self.K) * self.Y * self.X

    @property
    def w_elems(self) -> float:
        if self.w_elems_override is not None:
            return float(self.w_elems_override)
        return float(self.K) * self.C * self.R * self.S / self.groups

    @property
    def util_cap(self) -> float:
        """Max PE-array utilization. Depthwise convs lack channel-reduction
        parallelism and run rigid spatial arrays at ~8% (MAESTRO-consistent)."""
        if self.groups > 1 and self.groups == self.C:
            return 0.08
        return 1.0

    @property
    def shape6(self) -> tuple[int, int, int, int, int, int]:
        return (self.K, self.C, self.Y, self.X, self.R, self.S)

    # ---- factories --------------------------------------------------------
    @staticmethod
    def conv(name: str, k: int, c: int, y: int, x: int, r: int, s: int,
             stride: int = 1, groups: int = 1, skip_src: int = -1) -> "Layer":
        return Layer(name, k, c, y, x, r, s, stride, groups, skip_src)

    @staticmethod
    def depthwise(name: str, c: int, y: int, x: int, r: int, s: int,
                  stride: int = 1, skip_src: int = -1) -> "Layer":
        return Layer(name, c, c, y, x, r, s, stride, groups=c, skip_src=skip_src)

    @staticmethod
    def matmul(name: str, m: int, k: int, n: int, skip_src: int = -1,
               w_elems: float | None = None, macs: float | None = None) -> "Layer":
        """A per-sample matmul ``[m, k] @ [k, n]`` as a 1x1 'conv'.

        6-loop view: K=n (out features), C=k (in features), Y=m (rows /
        tokens), X=1, R=S=1 -> macs = m*k*n, out = m*n, w = k*n.
        """
        return Layer(name, K=n, C=k, Y=m, X=1, R=1, S=1, skip_src=skip_src,
                     macs_override=macs, w_elems_override=w_elems)

    @staticmethod
    def op(name: str, macs: float, out_elems: float, w_elems: float,
           shape6: tuple[int, int, int, int, int, int], skip_src: int = -1) -> "Layer":
        """Fully explicit op (e.g. a whole transformer block)."""
        K, C, Y, X, R, S = shape6
        return Layer(name, K, C, Y, X, R, S, skip_src=skip_src,
                     macs_override=macs, out_elems_override=out_elems,
                     w_elems_override=w_elems)


@dataclass
class Workload:
    """A chain of layers plus the network-input pseudo tensor.

    Position 0 is the network input (``input_elems`` per sample, with a
    pseudo 6-loop shape for the mapper state); positions ``1..N`` are layers.
    """

    name: str
    layers: list[Layer]
    input_elems: float
    input_shape6: tuple[int, int, int, int, int, int]
    default_batch: int = 64

    @property
    def n(self) -> int:
        return len(self.layers)

    def act_elems(self) -> np.ndarray:
        """Per-sample activation elems at positions 0..N (0 = input)."""
        return np.array([self.input_elems] + [l.out_elems for l in self.layers],
                        dtype=np.float64)

    def arrays(self, nmax: int, bytes_per_elem: float = 4.0) -> dict[str, np.ndarray]:
        """Pad to ``nmax`` positions (incl. input) for the jitted cost model.

        Returns float64/int32 numpy arrays; the cost model casts to f32.
        Keys: A (act bytes/sample), W (weight bytes), F (macs/sample),
        OE (out elems), SKIP (skip src position or -1), SHAPE6 (state feats),
        mask (valid layer positions, position 0 excluded), n (num layers).
        """
        n = self.n
        if n + 1 > nmax:
            raise ValueError(f"{self.name}: n+1={n + 1} > nmax={nmax}")
        A = np.zeros(nmax); W = np.zeros(nmax); F = np.zeros(nmax)
        OE = np.ones(nmax); UC = np.ones(nmax)
        SKIP = np.full(nmax, -1, dtype=np.int32)
        SHAPE6 = np.ones((nmax, 6))
        mask = np.zeros(nmax, dtype=bool)
        A[: n + 1] = self.act_elems() * bytes_per_elem
        SHAPE6[0] = np.array(self.input_shape6, dtype=np.float64)
        for i, l in enumerate(self.layers, start=1):
            W[i] = l.w_elems * bytes_per_elem
            F[i] = l.macs
            OE[i] = max(l.out_elems, 1.0)
            UC[i] = l.util_cap
            SKIP[i] = l.skip_src
            SHAPE6[i] = np.array(l.shape6, dtype=np.float64)
            mask[i] = True
        return dict(A=A, W=W, F=F, OE=OE, UC=UC, SKIP=SKIP, SHAPE6=SHAPE6,
                    mask=mask, n=np.int32(n))

    def total_macs(self, batch: int | None = None) -> float:
        b = batch if batch is not None else self.default_batch
        return b * sum(l.macs for l in self.layers)

    def total_weight_bytes(self, bytes_per_elem: float = 4.0) -> float:
        return bytes_per_elem * sum(l.w_elems for l in self.layers)

    def summary(self) -> str:
        rows = [f"{self.name}: {self.n} layers, "
                f"{sum(l.macs for l in self.layers) / 1e9:.2f} GMACs/sample, "
                f"{self.total_weight_bytes() / 1e6:.1f} MB weights (fp32)"]
        return "\n".join(rows)
