"""repro: DNNFuser (one-shot transformer layer-fusion mapper) as a
production multi-pod JAX framework.

Pillar A (the paper): repro.core + repro.workloads — analytical fusion
cost model, G-Sampler teacher, baselines, decision-transformer mapper,
one-shot conditional inference, transfer learning.

Pillar B (the substrate): repro.{nn,models,configs} — 10 assigned
architectures; repro.{distributed,launch} — (pod, data, model) mesh,
DP/FSDP/TP/EP/SP sharding, multi-pod dry-run + roofline;
repro.kernels — Pallas TPU kernels; repro.{data,optim,checkpoint,
runtime} — pipeline, optimizers, elastic checkpoints, fault-tolerant
training loop.  See DESIGN.md / EXPERIMENTS.md.
"""
__version__ = "0.1.0"
