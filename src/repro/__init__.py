"""repro: DNNFuser (one-shot transformer layer-fusion mapper) as a
production multi-pod JAX framework.

Pillar A (the paper): repro.core + repro.workloads — analytical fusion
cost model, G-Sampler teacher, baselines, decision-transformer mapper,
one-shot conditional inference, transfer learning.

Pillar B (the substrate): repro.{nn,models,configs} — 10 assigned
architectures; repro.{distributed,launch} — (pod, data, model) mesh,
DP/FSDP/TP/EP/SP sharding, multi-pod dry-run + roofline;
repro.kernels — Pallas TPU kernels; repro.{data,optim,checkpoint,
runtime} — pipeline, optimizers, elastic checkpoints, fault-tolerant
training loop.  See DESIGN.md / EXPERIMENTS.md.

The SUPPORTED public surface is ``__all__`` below (DESIGN §15): model
configs and one-shot inference, the teacher/training pipeline, the
accelerator zoo, and the serving stack behind one frozen
:class:`ServingConfig` — user code imports from ``repro``, never from
deep submodule paths.  :func:`serve` is the one-call production front
door.  Re-exports resolve lazily (PEP 562), so ``import repro`` stays
cheap and the core/serving import cycle never forms.
"""
__version__ = "0.1.0"

# name -> home submodule of every supported public symbol.  README's
# quickstarts and tests/test_docs.py import against THIS table.
_PUBLIC = {
    # the paper core: model + one-shot inference
    "DTConfig": "core", "dt_init": "core", "dt_loss": "core",
    "S2SConfig": "core", "s2s_init": "core", "s2s_loss": "core",
    "dnnfuser_infer": "core", "dnnfuser_infer_batch": "core",
    "InferResult": "core",
    # teacher + training
    "GSamplerConfig": "core", "gsampler_search": "core",
    "generate_teacher_corpus": "core", "TrajectoryDataset": "core",
    "TrainConfig": "core", "train_model": "core", "fine_tune": "core",
    "restore_params": "core",
    # the hardware-condition space (DESIGN §11)
    "AccelConfig": "core", "ACCEL_ZOO": "core", "PAPER_ACCEL": "core",
    "HW_FEATURE_DIM": "core", "accel_features": "core",
    # the serving stack (DESIGN §12, §14, §15)
    "ServingConfig": "serving", "DriftConfig": "serving",
    "MapperEngine": "serving", "MapRequest": "serving",
    "MapResponse": "serving", "StrategyCache": "serving",
    "AsyncMapperScheduler": "serving", "MapFuture": "serving",
    "AdmissionError": "serving", "ReplicaGroup": "serving",
    "DriftMonitor": "serving", "DriftReport": "serving",
    "RefreshWorker": "serving",
    # workloads
    "Workload": "workloads", "CNN_ZOO": "workloads",
    "get_workload": "workloads", "vgg16": "workloads",
    "resnet18": "workloads", "resnet50": "workloads",
    "mobilenet_v2": "workloads", "mnasnet_b1": "workloads",
    "tiny_cnn": "workloads",
}

__all__ = ["__version__", "serve"] + sorted(_PUBLIC)


def __getattr__(name):
    if name in _PUBLIC:
        import importlib
        mod = importlib.import_module(f".{_PUBLIC[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))


def serve(params, cfg, config=None, *, warm=None, accel=None):
    """One-call production front door (DESIGN §15): build the full
    serving stack — engine + async scheduler — from one frozen
    :class:`ServingConfig`.

    ``params``/``cfg`` are the checkpointed mapper; ``config`` defaults
    to ``ServingConfig()``.  With ``warm`` (a list of workloads,
    optionally ``accel``) the engine is warmed up first, so steady-state
    traffic over those shapes never recompiles and the drift monitor
    knows the in-distribution conditions.  Returns the
    :class:`AsyncMapperScheduler`; its ``.engine`` is the
    :class:`MapperEngine`.

    >>> sched = repro.serve(params, cfg, warm=[vgg16(), tiny_cnn()])
    >>> fut = sched.submit(repro.MapRequest(vgg16(), 64, 20 * 2**20,
    ...                                     repro.ACCEL_ZOO["edge"]))
    >>> sched.drain(); fut.result().strategy
    """
    from . import serving
    engine = serving.MapperEngine.from_config(params, cfg, config)
    if warm:
        engine.warmup(list(warm), accel)
    return serving.AsyncMapperScheduler(engine, config=engine.serving_config)
