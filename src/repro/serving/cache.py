"""Solved-strategy cache: in-memory LRU + a persistent, cross-process
file layer (DESIGN.md §12, §14).

A mapper front door sees heavy-tailed condition traffic: the same
(network, batch, budget, accelerator) query recurs across users.  A
solved strategy is a few dozen int32s — caching it turns a repeat query
into a dictionary hit instead of a device rollout.  Keys are the
condition identity (``MapperEngine._strategy_key``: workload id, batch,
budget id, rounded ``accel_features``), values whatever the engine
stores (strategy + metrics).

Since §14 the cache is **persistent and shared**:

 - :meth:`StrategyCache.save` serializes the entries to a versioned JSON
   payload together with a ``context`` dict (cache format, checkpoint
   fingerprint, budget-sharing mode) — a cache solved by one model
   checkpoint must never answer for another, so loads are rejected
   (counted, not raised by default) on any context mismatch;
 - :meth:`StrategyCache.load` populates a read-through **shared layer**:
   file entries don't consume LRU capacity until traffic actually touches
   them — a get() that misses memory but hits the shared layer promotes
   the entry (counted in ``shared_hits``) — so warm caches survive
   restarts and one file can back many engine replicas;
 - :meth:`save` is a read-modify-write *merge*: concurrent engines
   flushing to one file union their entries instead of clobbering.

Keys/values round-trip exactly: floats survive JSON (shortest-repr
binary64), strategies are small int lists.  Counters feed
``MapperEngine.stats()`` and the serving benchmark.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from collections import OrderedDict
from typing import Hashable

import numpy as np

__all__ = ["StrategyCache", "CACHE_FORMAT"]

# bump when the serialized key/entry layout changes incompatibly
CACHE_FORMAT = 1


def _key_to_json(key: tuple) -> list:
    """(name, batch, budget_id, accel feature tuple) -> JSON-safe list."""
    name, batch, budget_id, accel = key
    return [name, int(batch), budget_id, list(accel)]


def _key_from_json(k: list) -> tuple:
    name, batch, budget_id, accel = k
    return (str(name), int(batch),
            int(budget_id) if isinstance(budget_id, int) else float(budget_id),
            tuple(float(a) for a in accel))


def _entry_to_json(entry: tuple) -> list:
    strat, latency, peak, speedup = entry
    return [np.asarray(strat).astype(int).tolist(),
            float(latency), float(peak), float(speedup)]


def _entry_from_json(e: list) -> tuple:
    strat, latency, peak, speedup = e
    return (np.asarray(strat, np.int32), float(latency), float(peak),
            float(speedup))


class StrategyCache:
    """Bounded LRU + read-through shared file layer, with hit/miss and
    persistence accounting (not thread-safe; the engine serializes
    access).

    ``context`` identifies what the entries are valid FOR — the engine
    passes its checkpoint fingerprint and budget-sharing mode — and is
    embedded in every saved payload; :meth:`load` silently skips (and
    counts) files whose context differs."""

    def __init__(self, capacity: int = 4096, *, context: dict | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.context = dict(context or {})
        self._d: OrderedDict = OrderedDict()
        self._shared: dict = {}                  # read-through file layer
        self.hits = 0
        self.misses = 0
        self.shared_hits = 0                     # hits served from the file layer
        self.loads = 0                           # entries read from files
        self.saves = 0                           # entries written to files
        self.stale_skipped = 0                   # files rejected on context

    def get(self, key: Hashable):
        """Value for ``key`` (refreshing recency) or None; counts the
        lookup as a hit/miss.  Misses consult the shared file layer and
        promote on hit."""
        try:
            v = self._d[key]
        except KeyError:
            v = self._shared.get(key)
            if v is not None:                    # promote into the LRU
                self.put(key, v)
                self.shared_hits += 1
                self.hits += 1
                return v
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key: Hashable, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)          # evict least-recent

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:         # no counter side effects
        return key in self._d or key in self._shared

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def invalidate(self, predicate) -> int:
        """Drop every entry (LRU and shared layer) whose key satisfies
        ``predicate`` — the §15 scoped-invalidation hook: a hot checkpoint
        swap invalidates only the drifted region's strategies, so
        non-drifted keys keep answering bit-identically from cache.
        Returns the number of DISTINCT keys removed."""
        doomed = {k for k in self._d if predicate(k)}
        doomed |= {k for k in self._shared if predicate(k)}
        for k in doomed:
            self._d.pop(k, None)
            self._shared.pop(k, None)
        return len(doomed)

    def clear(self) -> None:
        self._d.clear()
        self._shared.clear()
        self.hits = 0
        self.misses = 0
        self.shared_hits = 0

    # -- persistence (DESIGN §14) --------------------------------------------

    def snapshot(self) -> dict:
        """All known entries (LRU over shared) — the determinism tests
        compare these across arrival orders / replica counts."""
        out = dict(self._shared)
        out.update(self._d)
        return out

    def save(self, path) -> int:
        """Merge-write every known entry to ``path`` (atomic rename).

        Entries already in a compatible file at ``path`` are preserved
        (read-modify-write union, memory winning ties), so N engines
        flushing to one shared file accumulate instead of clobbering.
        Returns the number of entries written."""
        path = pathlib.Path(path)
        merged: dict = {}
        if path.exists():
            try:
                payload = json.loads(path.read_text())
                if self._compatible(payload):
                    for k, e in payload["entries"]:
                        merged[_key_from_json(k)] = _entry_from_json(e)
            except (json.JSONDecodeError, KeyError, ValueError, TypeError):
                pass                             # corrupt file: overwrite
        merged.update(self.snapshot())
        payload = {
            "format": CACHE_FORMAT,
            "context": self.context,
            "entries": [[_key_to_json(k), _entry_to_json(e)]
                        for k, e in sorted(merged.items())],
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)                # atomic on POSIX
        except BaseException:
            pathlib.Path(tmp).unlink(missing_ok=True)
            raise
        self.saves += len(merged)
        return len(merged)

    def load(self, path, *, strict: bool = False) -> int:
        """Populate the read-through shared layer from ``path``.

        Entries stay out of the LRU until traffic touches them.  A
        missing file, or one whose format/context doesn't match, loads
        nothing (``stale_skipped`` counts it) unless ``strict``, which
        raises instead.  Returns the number of entries loaded."""
        path = pathlib.Path(path)
        if not path.exists():
            if strict:
                raise FileNotFoundError(f"no strategy cache at {path}")
            return 0
        try:
            payload = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            if strict:
                raise ValueError(f"corrupt strategy cache {path}: {e}") from e
            self.stale_skipped += 1
            return 0
        if not self._compatible(payload):
            if strict:
                raise ValueError(
                    f"incompatible strategy cache {path}: saved for "
                    f"format/context {payload.get('format')}/"
                    f"{payload.get('context')} but "
                    f"this engine expects {CACHE_FORMAT}/{self.context}")
            self.stale_skipped += 1
            return 0
        n = 0
        for k, e in payload["entries"]:
            self._shared[_key_from_json(k)] = _entry_from_json(e)
            n += 1
        self.loads += n
        return n

    def _compatible(self, payload: dict) -> bool:
        return (payload.get("format") == CACHE_FORMAT
                and payload.get("context") == self.context
                and isinstance(payload.get("entries"), list))
