"""Solved-strategy LRU cache (DESIGN.md §12).

A mapper front door sees heavy-tailed condition traffic: the same
(network, batch, budget-ish, accelerator) query recurs across users.  A
solved strategy is a few dozen int32s — caching it turns a repeat query
into a dictionary hit instead of a device rollout.  Keys are the QUANTIZED
condition (``MapperEngine._strategy_key``: workload id, batch,
``bucketing.budget_bucket``, rounded ``accel_features``), values whatever
the engine stores (strategy + metrics).  Plain LRU with hit/miss counters;
the counters feed ``MapperEngine.stats`` and the serving benchmark's
reported hit rates.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable

__all__ = ["StrategyCache"]


class StrategyCache:
    """Bounded LRU with hit/miss accounting (not thread-safe; the engine
    serializes access)."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable):
        """Value for ``key`` (refreshing recency) or None; counts the
        lookup as a hit/miss."""
        try:
            v = self._d[key]
        except KeyError:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key: Hashable, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)          # evict least-recent

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:         # no counter side effects
        return key in self._d

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def clear(self) -> None:
        self._d.clear()
        self.hits = 0
        self.misses = 0
