"""The layered serving stack (DESIGN.md §12, §14).

``engine.MapperEngine`` is the production core over the traced serving
episode (``repro.core.infer``): it buckets request shapes so steady-state
traffic never recompiles (``bucketing``), caches solved strategies with a
persistent cross-process file layer (``cache.StrategyCache``), coalesces
a mixed stream of (network, batch, budget, accelerator) queries into
fused device calls, and optionally shards those calls across data-parallel
device replicas (``replicas.ReplicaGroup``).
``scheduler.AsyncMapperScheduler`` is the async front door: continuous
batching over a live request stream with admission control and
deadline-bounded flushes.

Since §15 the stack is CLOSED-LOOP: one frozen ``config.ServingConfig``
is the deployment record (engine + cache + replicas + scheduler + drift
knobs), ``drift.DriftMonitor`` watches the served condition stream
through a bounded replay buffer, and ``refresh.RefreshWorker`` turns
drift reports into a G-Sampled teacher corpus, an off-path fine-tune,
and a quality-gated zero-recompile hot checkpoint swap
(``MapperEngine.swap_params``).
"""
from .bucketing import (batch_bucket, budget_bucket, coalesce,
                        default_nmax_buckets, nmax_bucket, pow2_buckets,
                        pow2_chunks)
from .cache import CACHE_FORMAT, StrategyCache
from .config import DriftConfig, ServingConfig
from .drift import (DriftMonitor, DriftReport, ReplayBuffer, ReplayRecord,
                    region_key_predicate)
from .engine import MapperEngine, MapRequest, MapResponse
from .refresh import RefreshWorker, probe_score
from .replicas import ReplicaGroup
from .scheduler import AdmissionError, AsyncMapperScheduler, MapFuture

__all__ = ["MapperEngine", "MapRequest", "MapResponse", "StrategyCache",
           "CACHE_FORMAT", "AsyncMapperScheduler", "MapFuture",
           "AdmissionError", "ReplicaGroup",
           "ServingConfig", "DriftConfig",
           "DriftMonitor", "DriftReport", "ReplayBuffer", "ReplayRecord",
           "region_key_predicate", "RefreshWorker", "probe_score",
           "batch_bucket", "budget_bucket", "coalesce",
           "default_nmax_buckets", "nmax_bucket", "pow2_buckets",
           "pow2_chunks"]
