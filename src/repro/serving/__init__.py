"""The layered serving stack (DESIGN.md §12).

``engine.MapperEngine`` is the production front door over the traced
serving core (``repro.core.infer.dnnfuser_infer_batch``): it buckets
request shapes so steady-state traffic never recompiles (``bucketing``),
caches solved strategies (``cache.StrategyCache``), and coalesces a mixed
stream of (network, batch, budget, accelerator) queries into one fused
device call per ``nmax`` bucket.
"""
from .bucketing import (batch_bucket, budget_bucket, coalesce,
                        default_nmax_buckets, nmax_bucket, pow2_buckets)
from .cache import StrategyCache
from .engine import MapperEngine, MapRequest, MapResponse

__all__ = ["MapperEngine", "MapRequest", "MapResponse", "StrategyCache",
           "batch_bucket", "budget_bucket", "coalesce",
           "default_nmax_buckets", "nmax_bucket", "pow2_buckets"]
