"""The layered serving stack (DESIGN.md §12, §14).

``engine.MapperEngine`` is the production core over the traced serving
episode (``repro.core.infer``): it buckets request shapes so steady-state
traffic never recompiles (``bucketing``), caches solved strategies with a
persistent cross-process file layer (``cache.StrategyCache``), coalesces
a mixed stream of (network, batch, budget, accelerator) queries into
fused device calls, and optionally shards those calls across data-parallel
device replicas (``replicas.ReplicaGroup``).
``scheduler.AsyncMapperScheduler`` is the async front door: continuous
batching over a live request stream with admission control and
deadline-bounded flushes.
"""
from .bucketing import (batch_bucket, budget_bucket, coalesce,
                        default_nmax_buckets, nmax_bucket, pow2_buckets,
                        pow2_chunks)
from .cache import CACHE_FORMAT, StrategyCache
from .engine import MapperEngine, MapRequest, MapResponse
from .replicas import ReplicaGroup
from .scheduler import AdmissionError, AsyncMapperScheduler, MapFuture

__all__ = ["MapperEngine", "MapRequest", "MapResponse", "StrategyCache",
           "CACHE_FORMAT", "AsyncMapperScheduler", "MapFuture",
           "AdmissionError", "ReplicaGroup",
           "batch_bucket", "budget_bucket", "coalesce",
           "default_nmax_buckets", "nmax_bucket", "pow2_buckets",
           "pow2_chunks"]
