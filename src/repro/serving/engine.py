"""MapperEngine: the layered serving front door (DESIGN.md §12).

Layer map — each layer only talks to the one below:

 - **core** (``repro.core.infer``): the traced episode.  Everything that
   varies per request — workload, batch, budget, accelerator — is per-row
   DATA of one jitted program (``dnnfuser_infer_batch`` over
   ``cost_model.stack_workloads``), so a mixed batch of networks serves in
   one device call;
 - **engine** (this module): checkpointed params + everything a device
   program must not recompute per request — a packed-workload cache, shape
   bucketing (``bucketing``: pow2 request batches x ``nmax`` buckets, so
   steady-state traffic hits a warmed, countable set of compiled
   programs), and a solved-strategy LRU (``cache.StrategyCache``);
 - **front door** (``examples/serve_mapper.py``,
   ``benchmarks/bench_serving.py``): accepts a request stream, calls
   :meth:`MapperEngine.serve` per arrival tick.

Compile accounting: the engine routes every device call through the one
module-level jitted entry point with a closed set of shape signatures
``(nmax bucket, batch bucket)``; ``compile_count`` increments exactly when
a signature is first materialized.  After :meth:`warmup` covers the set,
steady-state serving MUST NOT grow it — the recompile-churn guard
(``tests/test_serving.py``) and the serving benchmark both assert on it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

from ..core.accel import AccelConfig, accel_features
from ..core.backend import backend_for
from ..core.infer import dnnfuser_infer_batch
from ..core import cost_model as cm
from .bucketing import (MB, batch_bucket, budget_bucket, coalesce,
                        default_nmax_buckets, nmax_bucket, pow2_buckets)
from .cache import StrategyCache

__all__ = ["MapRequest", "MapResponse", "MapperEngine"]


@dataclass(frozen=True)
class MapRequest:
    """One mapping query: "map ``workload`` at ``batch`` under
    ``budget_bytes`` of on-chip buffer on ``accel``".

    ``workload`` is a ``repro.workloads.Workload``; its ``name`` is the
    cache identity, so distinct networks must carry distinct names."""
    workload: object
    batch: int
    budget_bytes: float
    accel: AccelConfig


@dataclass
class MapResponse:
    """The solved mapping for one request.

    ``strategy`` is trimmed to the workload's true ``n + 1`` positions
    (positions the padded device rollout masked to SYNC are dropped).
    ``valid`` is re-derived against THIS request's exact budget even when
    the strategy came from the cache.  ``cached`` marks a strategy-cache
    hit (no device work)."""
    workload: str
    strategy: np.ndarray
    latency: float
    peak_mem: float
    speedup: float
    valid: bool
    cached: bool


@functools.lru_cache(maxsize=1024)
def _accel_key(accel: AccelConfig) -> tuple:
    """Quantized accelerator identity for strategy-cache keys: the same
    normalized ``accel_features`` the model conditions on, rounded so f32
    noise cannot split one physical device into many keys."""
    feats = np.asarray(accel_features(accel), np.float64)
    return tuple(np.round(feats, 6).tolist())


class MapperEngine:
    """One checkpointed mapper serving heterogeneous traffic, recompile-free
    in steady state.

    Parameters: ``params``/``cfg`` — the checkpointed model (any registered
    ``MapperBackend`` config; ``cfg.max_steps`` caps the largest usable
    ``nmax`` bucket); ``nmax_buckets`` — the workload-length buckets
    (default ``bucketing.default_nmax_buckets``); ``budget_quantum`` —
    strategy-cache budget quantization (bytes); ``strategy_capacity`` —
    LRU size; ``repair`` — the inference-time budget guard.
    """

    def __init__(self, params, cfg, *, repair: bool = True,
                 nmax_buckets: tuple[int, ...] | None = None,
                 strategy_capacity: int = 4096,
                 budget_quantum: float = MB):
        if nmax_buckets is None:
            nmax_buckets = default_nmax_buckets(cfg.max_steps)
        if max(nmax_buckets) > cfg.max_steps:
            raise ValueError(
                f"nmax bucket {max(nmax_buckets)} exceeds the model's "
                f"max_steps={cfg.max_steps} trajectory capacity")
        self.params = params
        self.cfg = cfg
        self.backend = backend_for(cfg)          # fail early on bad cfg
        self.repair = repair
        self.nmax_buckets = tuple(sorted(nmax_buckets))
        self.budget_quantum = float(budget_quantum)
        self.strategies = StrategyCache(strategy_capacity)
        self._packed: dict = {}                  # (name, bpe, nmax) -> wl
        self._compiled: set = set()              # (nmax bucket, C bucket)
        self.compile_count = 0
        self.requests_served = 0
        self.device_calls = 0
        self.rows_padded = 0
        self.tick_dedup = 0

    # -- request planning ----------------------------------------------------

    def _pack(self, workload, accel: AccelConfig, nmax: int) -> dict:
        """Packed-workload cache: packing depends on the accelerator only
        through ``bytes_per_elem`` (the evaluators rescale in-graph,
        DESIGN §11), so the key is (name, bpe, nmax)."""
        key = (workload.name, float(accel.bytes_per_elem), nmax)
        wl = self._packed.get(key)
        if wl is None:
            wl = self._packed[key] = cm.pack_workload(workload, accel, nmax)
        return wl

    def _strategy_key(self, req: MapRequest) -> tuple:
        return (req.workload.name, int(req.batch),
                budget_bucket(req.budget_bytes, self.budget_quantum),
                _accel_key(req.accel))

    # -- serving -------------------------------------------------------------

    def serve(self, requests: list[MapRequest]) -> list[MapResponse]:
        """Solve one arrival tick of requests.

        Strategy-cache hits are answered without device work; misses are
        deduplicated within the tick (identical condition keys share one
        lane), coalesced by ``nmax`` bucket, padded to a pow2 request
        batch, and served in one fused device call per bucket.  Responses
        keep the request order."""
        out: list = [None] * len(requests)
        pending: dict = {}                       # key -> miss record
        for i, req in enumerate(requests):
            key = self._strategy_key(req)
            if key in pending:                   # in-tick duplicate: one lane
                pending[key][2].append((i, req))
                self.tick_dedup += 1
                continue
            hit = self.strategies.get(key)
            if hit is not None:
                strat, lat, peak, speed = hit
                out[i] = MapResponse(req.workload.name, strat, lat, peak,
                                     speed, valid=peak <= req.budget_bytes,
                                     cached=True)
            else:
                pending[key] = (key, req, [(i, req)])
        groups = coalesce(
            pending.values(),
            lambda m: nmax_bucket(m[1].workload.n + 1, self.nmax_buckets))
        for nb, group in groups.items():
            self._serve_bucket(nb, group, out)
        self.requests_served += len(requests)
        return out

    def serve_one(self, request: MapRequest) -> MapResponse:
        return self.serve([request])[0]

    def _serve_bucket(self, nb: int, group: list, out: list) -> None:
        """Solve one group of miss records ``(key, req, [out indices])``
        sharing an ``nmax`` bucket in one fused device call."""
        C = len(group)
        Cb = batch_bucket(C)
        rows = [self._pack(r.workload, r.accel, nb) for _, r, _ in group]
        accels = [r.accel for _, r, _ in group]
        batches = [float(r.batch) for _, r, _ in group]
        budgets = [float(r.budget_bytes) for _, r, _ in group]
        pad = Cb - C
        if pad:                                  # clone a real row: vmap
            rows += rows[:1] * pad               # lanes are independent
            accels += accels[:1] * pad
            batches += batches[:1] * pad
            budgets += budgets[:1] * pad
            self.rows_padded += pad
        sig = (nb, Cb)
        if sig not in self._compiled:
            self._compiled.add(sig)
            self.compile_count += 1
        res = dnnfuser_infer_batch(
            self.params, self.cfg, cm.stack_workloads(rows),
            np.asarray(batches, np.float32), np.asarray(budgets, np.float32),
            accels, repair=self.repair)
        self.device_calls += 1
        for lane, (key, req, idxs) in enumerate(group):
            strat = np.asarray(res["strategy"][lane][: req.workload.n + 1])
            peak = float(res["peak_mem"][lane])
            entry = (strat, float(res["latency"][lane]), peak,
                     float(res["speedup"][lane]))
            self.strategies.put(key, entry)
            # duplicates shared the lane, but each keeps its own validity:
            # the lane solved under the FIRST request's exact budget, and a
            # reused strategy must never be called valid for a (same-bucket
            # but tighter) budget it overflows
            for k, (i, req_i) in enumerate(idxs):
                valid = (bool(res["valid"][lane]) if k == 0
                         else peak <= req_i.budget_bytes)
                out[i] = MapResponse(req_i.workload.name, *entry,
                                     valid=valid, cached=k > 0)

    # -- warmup & stats ------------------------------------------------------

    def warmup(self, workloads: list, accel: AccelConfig | None = None,
               *, max_tick: int = 16) -> int:
        """Materialize every (nmax bucket, batch bucket) program traffic
        over ``workloads`` can hit, for arrival ticks up to ``max_tick``
        requests.  Returns the number of programs compiled.  After warmup,
        serving any mix of these workloads in ticks of <= ``max_tick``
        requests triggers ZERO new compilations (the churn guard).

        The warmed set is independent of ``cost_model``'s evaluator
        backend: serving rides the §9 prefix-carry episode, not the §13
        grid evaluator, so flipping ``set_default_evaluator`` never
        invalidates a warmed engine (``stats`` reports the active backend
        for operational visibility)."""
        if accel is None:
            accel = AccelConfig()
        before = self.compile_count
        reps: dict[int, object] = {}
        for w in workloads:
            reps.setdefault(nmax_bucket(w.n + 1, self.nmax_buckets), w)
        for nb, w in sorted(reps.items()):
            for cb in pow2_buckets(max_tick):
                if (nb, cb) in self._compiled:
                    continue
                reqs = [MapRequest(w, 1 + i % 4, (8 + i) * MB, accel)
                        for i in range(cb)]
                sink: list = [None] * cb
                self._serve_bucket(nb, [(self._strategy_key(r), r, [(j, r)])
                                        for j, r in enumerate(reqs)], sink)
        return self.compile_count - before

    @property
    def stats(self) -> dict:
        """Serving counters (the benchmark's reported schema)."""
        return {
            "requests_served": self.requests_served,
            "device_calls": self.device_calls,
            "compile_count": self.compile_count,
            "cost_evaluator": cm.default_evaluator(),
            "compiled_shapes": sorted(self._compiled),
            "rows_padded": self.rows_padded,
            "tick_dedup": self.tick_dedup,
            "packed_workloads": len(self._packed),
            "strategy_hits": self.strategies.hits,
            "strategy_misses": self.strategies.misses,
            "strategy_hit_rate": self.strategies.hit_rate,
        }
