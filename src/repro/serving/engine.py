"""MapperEngine: the layered serving front door (DESIGN.md §12, §14).

Layer map — each layer only talks to the one below:

 - **core** (``repro.core.infer``): the traced episode.  Everything that
   varies per request — workload, batch, budget, accelerator — is per-row
   DATA of one jitted program (``infer._fused_batch`` over
   ``cost_model.stack_workloads``), so a mixed batch of networks serves in
   one device call;
 - **engine** (this module): checkpointed params + everything a device
   program must not recompute per request — a packed-workload cache, shape
   bucketing (``bucketing``: pow2 request batches x ``nmax`` buckets, so
   steady-state traffic hits a warmed, countable set of compiled
   programs), oversized-tick chunking (``bucketing.pow2_chunks``), a
   solved-strategy cache with a persistent cross-process file layer
   (``cache.StrategyCache``), and optional data-parallel device replicas
   (``replicas.ReplicaGroup``);
 - **front door** (``scheduler.AsyncMapperScheduler``,
   ``examples/serve_mapper.py``, ``benchmarks/bench_serving.py``): accepts
   a request stream, forms ticks, calls :meth:`MapperEngine.serve`.

Determinism contract (DESIGN §14): by default the solving identity of a
request is its EXACT condition ``(workload, batch, f32 budget, accel)``
— dedup and cache hits only ever reuse a strategy solved under the very
same condition — so batched/coalesced/replicated serving is bit-identical
to serving each request alone, independent of arrival order and tick
formation.  ``approx_budget_sharing=True`` restores the pre-§14 quantized
budget keys (higher hit rates, per-request validity still re-derived) at
the cost of that per-request bit-identity.

Compile accounting: the engine routes every device call through the one
module-level jitted entry point with a closed set of shape signatures
``(nmax bucket, padded lane count)``; ``compile_count`` increments exactly
when a signature is first materialized.  After :meth:`warmup` covers the
set, steady-state serving MUST NOT grow it — oversized ticks are split
into warmed pow2 chunks instead of padding up to an unwarmed program —
which the recompile-churn guards (``tests/test_serving.py``,
``tests/test_scheduler.py``) and the serving benchmark assert on.
"""
from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass

import numpy as np

from ..core.accel import AccelConfig, HwVec, accel_features, hw_array
from ..core.backend import backend_for
from ..core import infer as _infer
from ..core import cost_model as cm
from ..core import polish as _polish
from ..core import portfolio as _portfolio
from ..core.gsampler import _fitness
from .bucketing import (MB, batch_bucket, budget_bucket, coalesce,
                        default_nmax_buckets, nmax_bucket, pow2_buckets,
                        pow2_chunks)
from .cache import StrategyCache
from .config import ServingConfig, _ENGINE_FIELDS, config_from_kwargs
from .drift import DriftMonitor, ReplayRecord
from .replicas import ReplicaGroup

__all__ = ["MapRequest", "MapResponse", "MapperEngine"]


@dataclass(frozen=True)
class MapRequest:
    """One mapping query: "map ``workload`` at ``batch`` under
    ``budget_bytes`` of on-chip buffer on ``accel``".

    ``workload`` is a ``repro.workloads.Workload``; its ``name`` is the
    cache identity, so distinct networks must carry distinct names."""
    workload: object
    batch: int
    budget_bytes: float
    accel: AccelConfig


@dataclass
class MapResponse:
    """The solved mapping for one request.

    ``strategy`` is trimmed to the workload's true ``n + 1`` positions
    (positions the padded device rollout masked to SYNC are dropped).
    ``valid`` is re-derived against THIS request's budget at serving
    precision (f32, matching the device comparison) even when the
    strategy came from the cache.  ``cached`` marks a strategy-cache hit
    or an in-tick duplicate (no extra device work)."""
    workload: str
    strategy: np.ndarray
    latency: float
    peak_mem: float
    speedup: float
    valid: bool
    cached: bool


@functools.lru_cache(maxsize=1024)
def _accel_key(accel: AccelConfig) -> tuple:
    """Quantized accelerator identity for strategy-cache keys: the same
    normalized ``accel_features`` the model conditions on, rounded so f32
    noise cannot split one physical device into many keys."""
    feats = np.asarray(accel_features(accel), np.float64)
    return tuple(np.round(feats, 6).tolist())


def _fits(peak: float, budget: float) -> bool:
    """Budget validity at serving precision: the device compares f32 peak
    to the f32 budget it was handed, so every host-side re-derivation
    compares in f32 too — a cache hit can never flip validity vs the
    device answer for the same condition."""
    return bool(np.float32(peak) <= np.float32(budget))


def _fingerprint(params, cfg) -> str:
    """Checkpoint identity for persisted caches: a digest over the config
    repr and every param leaf's bytes.  Two engines share cache files iff
    they would produce bit-identical strategies."""
    import jax
    h = hashlib.sha256(repr(cfg).encode())
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        h.update(str(path).encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


class MapperEngine:
    """One checkpointed mapper serving heterogeneous traffic, recompile-free
    in steady state.

    Canonical construction (DESIGN §15) takes a frozen
    ``config.ServingConfig`` — the one record of a deployment —
    via :meth:`from_config` / the ``config=`` keyword / the top-level
    ``repro.serve`` factory.  The pre-§15 scattered kwargs
    (``cache_path``, ``checkpoint_id``, ``approx_budget_sharing``, ...)
    keep working bit-identically through a deprecation shim that warns
    once per kwarg per process.

    Config fields: ``nmax_buckets`` — the workload-length buckets
    (default ``bucketing.default_nmax_buckets``; ``cfg.max_steps`` caps
    the largest usable bucket); ``max_coalesce`` — the widest device call
    the engine will form (wider ticks chunk); ``strategy_capacity`` — LRU
    size; ``budget_quantum`` + ``approx_budget_sharing`` — the
    strategy-cache budget identity (exact f32 by default; quantized
    sharing opt-in); ``cache_path`` — persistent strategy-cache file,
    read-through loaded at init; ``checkpoint_id`` — cache identity
    override (defaults to a params fingerprint); ``replicas`` — a
    ``ReplicaGroup`` or replica count for data-parallel multi-device
    serving; ``repair`` — the inference-time budget guard; ``drift`` /
    ``known_accels`` / ``known_workloads`` — the §15 closed-loop monitor.
    """

    def __init__(self, params, cfg, *, config: ServingConfig | None = None,
                 **legacy):
        if config is None:
            config = config_from_kwargs("MapperEngine", _ENGINE_FIELDS,
                                        legacy)
        elif legacy:
            raise TypeError(
                "pass either config= or the legacy engine kwargs, not "
                "both: got config= plus " + ", ".join(sorted(legacy)))
        nmax_buckets = config.nmax_buckets
        if nmax_buckets is None:
            nmax_buckets = default_nmax_buckets(cfg.max_steps)
        if max(nmax_buckets) > cfg.max_steps:
            raise ValueError(
                f"nmax bucket {max(nmax_buckets)} exceeds the model's "
                f"max_steps={cfg.max_steps} trajectory capacity")
        self.serving_config = config
        self.params = params
        self.cfg = cfg
        self.backend = backend_for(cfg)          # fail early on bad cfg
        self.repair = config.repair
        self.polish = bool(config.polish)
        self.escalate = bool(config.escalate)
        self._polish_cfg = _polish.PolishConfig()
        self._portfolio_cfg = _portfolio.PortfolioConfig(
            population=16, generations=12)
        self.nmax_buckets = tuple(sorted(nmax_buckets))
        self.max_coalesce = batch_bucket(config.max_coalesce)
        self.budget_quantum = float(config.budget_quantum)
        self.approx_budget_sharing = bool(config.approx_budget_sharing)
        replicas = config.replicas
        if isinstance(replicas, int):
            replicas = ReplicaGroup(replicas)
        self.replicas = replicas
        if replicas is not None and replicas.n > self.max_coalesce:
            raise ValueError(f"{replicas.n} replicas need max_coalesce >= "
                             f"{replicas.n}, got {self.max_coalesce}")
        self._params_dev = (replicas.replicate_params(params)
                            if replicas is not None else params)
        self.checkpoint_id = config.checkpoint_id or _fingerprint(params, cfg)
        self.strategies = StrategyCache(config.strategy_capacity, context={
            "checkpoint": self.checkpoint_id,
            "budget_sharing": ("approx" if self.approx_budget_sharing
                               else "exact"),
            "budget_quantum": self.budget_quantum,
        })
        self.cache_path = config.cache_path
        if self.cache_path is not None:
            self.strategies.load(self.cache_path)
        self.monitor = DriftMonitor(config.drift,
                                    known_accels=config.known_accels,
                                    known_workloads=config.known_workloads)
        self.scheduler = None                    # backref set by the scheduler
        self._packed: dict = {}                  # (name, bpe, nmax) -> np dict
        self._hw_rows: dict = {}                 # accel -> (np [10], np [F])
        self._compiled: set = set()              # (nmax bucket, padded lanes)
        self._warmed_cap: int | None = None      # widest warmed lane count
        self.compile_count = 0
        self.requests_served = 0
        self.device_calls = 0
        self.rows_padded = 0
        self.tick_dedup = 0
        self.swaps_accepted = 0
        self.swaps_rejected = 0
        self.cache_invalidated = 0
        self.coalesce_hist: dict[int, int] = {}  # true chunk width -> count
        # -- §17 propose-then-polish accounting --
        self.escalations = 0                     # lanes sent to the portfolio
        self.polish_invocations = 0              # lanes gradient-polished
        self.polish_improved = 0                 # lanes polish strictly won
        self.wins: list[dict] = []               # flywheel: improved lanes
        self._wins_cap = 512

    @classmethod
    def from_config(cls, params, cfg, config: ServingConfig | None = None):
        """Canonical §15 construction: one frozen :class:`ServingConfig`
        describes the whole deployment (engine + cache + replicas +
        drift; the scheduler fields are consumed by
        ``AsyncMapperScheduler`` / ``repro.serve``)."""
        return cls(params, cfg, config=config or ServingConfig())

    # -- request planning ----------------------------------------------------

    @property
    def chunk_cap(self) -> int:
        """Widest device call the engine currently forms: the warmed pow2
        cap once :meth:`warmup` has run, else ``max_coalesce``."""
        return self._warmed_cap or self.max_coalesce

    def _pack(self, workload, accel: AccelConfig, nmax: int) -> dict:
        """Packed-workload cache (host numpy form — stacking per tick is
        pure ``np.stack``, no per-call device traffic): packing depends on
        the accelerator only through ``bytes_per_elem`` (the evaluators
        rescale in-graph, DESIGN §11), so the key is (name, bpe, nmax)."""
        key = (workload.name, float(accel.bytes_per_elem), nmax)
        wl = self._packed.get(key)
        if wl is None:
            packed = cm.pack_workload(workload, accel, nmax)
            wl = self._packed[key] = {k: np.asarray(v)
                                      for k, v in packed.items()}
        return wl

    def _hw_row(self, accel: AccelConfig) -> tuple:
        """Cached (raw hw vector, normalized feature row) for one accel."""
        ent = self._hw_rows.get(accel)
        if ent is None:
            raw = np.asarray(hw_array(accel), np.float32)
            feat = (np.asarray(accel_features(accel), np.float32)
                    if getattr(self.cfg, "hw_dim", 0) else None)
            ent = self._hw_rows[accel] = (raw, feat)
        return ent

    def _strategy_key(self, req: MapRequest) -> tuple:
        if self.approx_budget_sharing:
            bid = budget_bucket(req.budget_bytes, self.budget_quantum)
        else:
            bid = float(np.float32(req.budget_bytes))  # serving precision
        return (req.workload.name, int(req.batch), bid, _accel_key(req.accel))

    # -- serving -------------------------------------------------------------

    def serve(self, requests: list[MapRequest]) -> list[MapResponse]:
        """Solve one arrival tick of requests.

        Strategy-cache hits are answered without device work; misses are
        deduplicated within the tick (identical condition keys share one
        lane), coalesced by ``nmax`` bucket, chunked to at most
        :attr:`chunk_cap` lanes, padded to a pow2 request batch, and
        served in fused device calls.  Responses keep the request
        order."""
        out: list = [None] * len(requests)
        pending: dict = {}                       # key -> miss record
        for i, req in enumerate(requests):
            key = self._strategy_key(req)
            if key in pending:                   # in-tick duplicate: one lane
                pending[key][2].append((i, req))
                self.tick_dedup += 1
                continue
            hit = self.strategies.get(key)
            if hit is not None:
                out[i] = self._hit_response(req, hit)
            else:
                pending[key] = (key, req, [(i, req)])
        groups = coalesce(
            pending.values(),
            lambda m: nmax_bucket(m[1].workload.n + 1, self.nmax_buckets))
        for nb, group in groups.items():
            self._serve_bucket(nb, group, out)
        self.requests_served += len(requests)
        for req, resp in zip(requests, out):
            self._observe(req, resp)
        return out

    def serve_one(self, request: MapRequest) -> MapResponse:
        return self.serve([request])[0]

    def serve_cached(self, request: MapRequest) -> MapResponse | None:
        """Answer from the strategy cache alone, or None on a miss.

        The scheduler's admission fast path: a hit resolves immediately
        instead of queueing for a tick (no device work, no flush
        latency).  A hit counts exactly like one inside :meth:`serve`; a
        miss does NOT count — the request will queue and re-probe in its
        tick, and that probe is the one real miss."""
        key = self._strategy_key(request)
        if key not in self.strategies:           # peek: miss counted in serve
            return None
        hit = self.strategies.get(key)
        if hit is None:                          # racy eviction between checks
            return None
        self.requests_served += 1
        resp = self._hit_response(request, hit)
        self._observe(request, resp)
        return resp

    def _observe(self, req: MapRequest, resp: MapResponse) -> None:
        """Feed the §15 replay/telemetry stream: one record per served
        request — the condition plus its realized outcome.  O(1) host
        bookkeeping; ``warmup`` traffic bypasses (it goes straight to
        ``_serve_bucket`` and is synthetic, not served demand)."""
        self.monitor.observe(ReplayRecord(
            req.workload, int(req.batch), float(req.budget_bytes),
            req.accel, bool(resp.valid), bool(resp.cached),
            float(resp.speedup)))

    def _hit_response(self, req: MapRequest, entry: tuple) -> MapResponse:
        strat, lat, peak, speed = entry
        return MapResponse(req.workload.name, strat, lat, peak, speed,
                           valid=_fits(peak, req.budget_bytes), cached=True)

    def _serve_bucket(self, nb: int, group: list, out: list) -> None:
        """Solve one group of miss records ``(key, req, [out indices])``
        sharing an ``nmax`` bucket, in fused device calls of at most
        :attr:`chunk_cap` lanes each (the oversized-tick escape hatch:
        a group wider than the warmed set is cut into warmed pow2 chunks
        rather than padded up to an unwarmed program)."""
        start = 0
        for width in pow2_chunks(len(group), self.chunk_cap):
            self._serve_chunk(nb, group[start:start + width], out)
            start += width

    def _serve_chunk(self, nb: int, group: list, out: list) -> None:
        C = len(group)
        Cb = batch_bucket(C)
        if self.replicas is not None:
            Cb = self.replicas.pad_width(Cb)     # >= one lane per replica
        rows = [self._pack(r.workload, r.accel, nb) for _, r, _ in group]
        hw_raw, hw_feat = zip(*(self._hw_row(r.accel) for _, r, _ in group))
        batches = [np.float32(r.batch) for _, r, _ in group]
        budgets = [np.float32(r.budget_bytes) for _, r, _ in group]
        pad = Cb - C
        if pad:                                  # clone a real row: vmap
            rows += rows[:1] * pad               # lanes are independent
            hw_raw += hw_raw[:1] * pad
            hw_feat += hw_feat[:1] * pad
            batches += batches[:1] * pad
            budgets += budgets[:1] * pad
            self.rows_padded += pad
        sig = (nb, Cb)
        if sig not in self._compiled:
            self._compiled.add(sig)
            self.compile_count += 1
        wl = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        hwv = HwVec(*np.moveaxis(np.stack(hw_raw), -1, 0))
        hwf = None if hw_feat[0] is None else np.stack(hw_feat)
        args = (wl, np.asarray(batches, np.float32),
                np.asarray(budgets, np.float32), hwv, hwf)
        if self.replicas is not None:
            args = self.replicas.shard_tick(args)
            self.replicas.account_rows(Cb)
        res = _infer._fused_batch(self._params_dev, self.cfg, *args,
                                  self.repair, self.backend, True)
        res = {k: np.asarray(v) for k, v in res.items()}
        self.device_calls += 1
        self.coalesce_hist[C] = self.coalesce_hist.get(C, 0) + 1
        if self.polish or self.escalate:
            self._refine_chunk(res, group, wl,
                               np.asarray(batches, np.float32),
                               np.asarray(budgets, np.float32), hwv)
        for lane, (key, req, idxs) in enumerate(group):
            strat = np.asarray(res["strategy"][lane][: req.workload.n + 1])
            peak = float(res["peak_mem"][lane])
            entry = (strat, float(res["latency"][lane]), peak,
                     float(res["speedup"][lane]))
            self.strategies.put(key, entry)
            # in-tick duplicates share the lane.  Under the default exact
            # budget identity every duplicate carries the SAME budget, so
            # the device's own validity applies to all of them; under
            # approx sharing a duplicate may carry a different (same-
            # bucket) budget and validity is re-derived, f32-faithfully,
            # against its own budget.
            for k, (i, req_i) in enumerate(idxs):
                valid = (bool(res["valid"][lane])
                         if req_i.budget_bytes == req.budget_bytes
                         else _fits(peak, req_i.budget_bytes))
                out[i] = MapResponse(req_i.workload.name, *entry,
                                     valid=valid, cached=k > 0)

    # -- propose-then-polish escalation (DESIGN §17) -------------------------

    def _refine_chunk(self, res: dict, group: list, wl: dict,
                      batches: np.ndarray, budgets: np.ndarray,
                      hwv: HwVec) -> None:
        """Refine one fused chunk's one-shot proposals in place.

        Stage 1 (``polish=True``): gradient-polish EVERY lane of the
        chunk in one :func:`repro.core.polish.polish_grid` call — the
        polisher is RNG-free and per-lane independent, so refined
        responses keep the §14 tick-composition invariance of the
        one-shot path.  Stage 2 (``escalate=True``): lanes STILL
        budget-violating are routed through a short warm-started DE
        portfolio run seeded from the (polished) proposal; constant
        salts keep the escalation stream independent of which lanes of
        which tick escalate.  Both stages only ever replace a lane when
        the replacement scores strictly better under the teacher's
        fitness (valid beats invalid; then latency; then budget
        overshoot), so refinement never worsens a response."""
        C = len(group)
        base = res["speedup"] * np.maximum(res["latency"], 1e-30)
        improved = np.zeros(len(res["strategy"]), bool)
        if self.polish:
            p = _polish.polish_grid(wl, res["strategy"], batches, budgets,
                                    hwv, cfg=self._polish_cfg)
            self.polish_invocations += C
            self.polish_improved += int(np.count_nonzero(p["improved"][:C]))
            improved |= p["improved"]
            res["strategy"] = np.asarray(p["strategy"])
            res["latency"] = np.asarray(p["latency"], res["latency"].dtype)
            res["peak_mem"] = np.asarray(p["peak_mem"],
                                         res["peak_mem"].dtype)
            res["valid"] = np.asarray(p["valid"])
            res["speedup"] = base / np.maximum(res["latency"], 1e-30)
        if self.escalate:
            idx = np.nonzero(~np.asarray(res["valid"][:C], bool))[0]
            if idx.size:
                self.escalations += int(idx.size)
                kb = batch_bucket(int(idx.size))
                take = np.concatenate(
                    [idx, np.full(kb - idx.size, idx[0], idx.dtype)])
                sub_wl = {k: v[take] for k, v in wl.items()}
                sub_hw = HwVec(*(np.asarray(f)[take] for f in hwv))
                r = _portfolio.de_search_grid(
                    None, sub_hw, batches[take], budgets[take],
                    cfg=self._portfolio_cfg,
                    init_strategies=res["strategy"][take],
                    salts=np.zeros(kb, np.uint32), packed=sub_wl)
                for j, lane in enumerate(idx):
                    cur = float(_fitness(float(res["latency"][lane]),
                                         float(res["peak_mem"][lane]),
                                         float(budgets[lane])))
                    esc = float(_fitness(float(r.latency[j]),
                                         float(r.peak_mem[j]),
                                         float(budgets[lane])))
                    if esc > cur:
                        res["strategy"][lane] = r.strategies[j]
                        res["latency"][lane] = r.latency[j]
                        res["peak_mem"][lane] = r.peak_mem[j]
                        res["valid"][lane] = bool(r.valid[j])
                        res["speedup"][lane] = base[lane] / max(
                            float(r.latency[j]), 1e-30)
                        improved[lane] = True
        # flywheel: refined wins become teacher elites at the next refresh
        for lane in range(C):
            if not (improved[lane] and bool(res["valid"][lane])):
                continue
            _, req, _ = group[lane]
            self.wins.append({
                "workload": req.workload,
                "accel": req.accel,
                "batch": int(req.batch),
                "budget_bytes": float(req.budget_bytes),
                "strategy": np.asarray(
                    res["strategy"][lane][: req.workload.n + 1],
                    np.int32).copy(),
                "latency": float(res["latency"][lane]),
                "speedup": float(res["speedup"][lane]),
            })
        if len(self.wins) > self._wins_cap:
            del self.wins[: len(self.wins) - self._wins_cap]

    def harvest_wins(self, *, workloads=None, accels=None,
                     drain: bool = True) -> list[dict]:
        """Collect (and by default drain) logged refinement wins.

        ``workloads``/``accels`` filter by name (objects or strings);
        ``None`` matches everything.  :meth:`RefreshWorker.refresh`
        harvests the drifted region's wins and feeds them to
        ``generate_teacher_corpus(extra_elites=...)`` so the next
        fine-tune distills what polish/search found (DESIGN §17)."""
        wset = (None if workloads is None
                else {getattr(w, "name", w) for w in workloads})
        aset = (None if accels is None
                else {getattr(a, "name", a) for a in accels})
        kept, got = [], []
        for w in self.wins:
            match = ((wset is None or w["workload"].name in wset)
                     and (aset is None or w["accel"].name in aset))
            (got if match else kept).append(w)
        if drain:
            self.wins = kept
        return got

    # -- persistence (DESIGN §14) --------------------------------------------

    def save_cache(self, path=None) -> int:
        """Persist the strategy cache (merge-write; see
        ``StrategyCache.save``).  Returns the number of entries written."""
        path = path if path is not None else self.cache_path
        if path is None:
            raise ValueError("no cache path: pass one here or construct the "
                             "engine with cache_path=")
        return self.strategies.save(path)

    def load_cache(self, path=None, *, strict: bool = False) -> int:
        """Read-through load of a persisted strategy cache.  Returns the
        number of entries loaded (0 on missing/stale files unless
        ``strict``)."""
        path = path if path is not None else self.cache_path
        if path is None:
            raise ValueError("no cache path: pass one here or construct the "
                             "engine with cache_path=")
        return self.strategies.load(path, strict=strict)

    # -- hot swap (DESIGN §15) -----------------------------------------------

    def swap_params(self, new_params, *, invalidate=None) -> int:
        """Atomically swap the serving checkpoint with ZERO recompiles.

        ``new_params`` must match the live tree leaf-for-leaf in
        structure, shape and dtype — then every warmed jitted program's
        signature is unchanged and the jit cache is reused as-is (the
        §15 swap tests cross-check the jax-level cache size).  The swap
        is a host-side pointer flip between ticks: in-flight device calls
        already hold the old tree; the next ``serve`` uses the new one.

        ``invalidate`` is an optional key predicate (see
        ``drift.region_key_predicate``) scoping which strategy-cache
        entries the new checkpoint obsoletes.  Keys OUTSIDE the scope are
        deliberately KEPT: their cached strategies were solved by the old
        params and keep answering bit-identically — the §15 non-drifted
        bit-exactness contract.  The cache's checkpoint context is
        re-fingerprinted, so persisted files carry the new identity.
        Returns the number of cache entries invalidated."""
        import jax
        old_flat, old_def = jax.tree_util.tree_flatten(self.params)
        new_flat, new_def = jax.tree_util.tree_flatten(new_params)
        if old_def != new_def:
            raise ValueError("swap_params needs the live tree structure; "
                             f"got {new_def} vs live {old_def}")
        for o, n in zip(old_flat, new_flat):
            so, sn = np.shape(o), np.shape(n)
            do = getattr(o, "dtype", np.asarray(o).dtype)
            dn = getattr(n, "dtype", np.asarray(n).dtype)
            if so != sn or str(do) != str(dn):
                raise ValueError(
                    f"swap_params leaf mismatch: {sn}/{dn} vs live "
                    f"{so}/{do} — a swap must not change any jit "
                    f"signature (use checkpoint.upgrade_pytree + a new "
                    f"engine for architecture changes)")
        self.params = new_params
        self._params_dev = (self.replicas.replicate_params(new_params)
                            if self.replicas is not None else new_params)
        self.checkpoint_id = _fingerprint(new_params, self.cfg)
        self.strategies.context["checkpoint"] = self.checkpoint_id
        n = self.strategies.invalidate(invalidate) if invalidate else 0
        self.swaps_accepted += 1
        self.cache_invalidated += n
        return n

    def mark_known(self, *, accels=(), workloads=()) -> None:
        """Declare conditions in-distribution for the drift monitor —
        called with the drifted region after an accepted swap, so the
        monitor stops re-firing on traffic the refreshed checkpoint now
        covers."""
        self.monitor.mark_known(accels=accels, workloads=workloads)

    # -- warmup & stats ------------------------------------------------------

    def warmup(self, workloads: list, accel: AccelConfig | None = None,
               *, max_tick: int | None = None) -> int:
        """Materialize every (nmax bucket, padded lane count) program
        traffic over ``workloads`` can hit.  Returns the number of
        programs compiled.  After warmup, serving any mix of these
        workloads triggers ZERO new compilations for ticks of ANY size:
        ticks wider than the warmed cap are chunked into warmed pow2
        programs (``bucketing.pow2_chunks``), never padded up to an
        unwarmed one.

        ``max_tick`` (default ``max_coalesce``) bounds the warmed lane
        counts; it is clamped to ``max_coalesce`` since the engine never
        forms a wider call.  The warmed set is independent of
        ``cost_model``'s evaluator backend: serving rides the §9
        prefix-carry episode, not the §13 grid evaluator, so flipping
        ``set_default_evaluator`` never invalidates a warmed engine
        (``stats`` reports the active backend for operational
        visibility)."""
        if accel is None:
            accel = AccelConfig()
        if max_tick is None:
            max_tick = self.max_coalesce
        cap = batch_bucket(min(max_tick, self.max_coalesce))
        before = self.compile_count
        reps: dict[int, object] = {}
        for w in workloads:
            reps.setdefault(nmax_bucket(w.n + 1, self.nmax_buckets), w)
        for nb, w in sorted(reps.items()):
            for cb in pow2_buckets(cap):
                eff = cb if self.replicas is None \
                    else self.replicas.pad_width(cb)
                if (nb, eff) in self._compiled:
                    continue
                reqs = [MapRequest(w, 1 + i % 4, (8 + i) * MB, accel)
                        for i in range(cb)]
                sink: list = [None] * cb
                self._serve_bucket(nb, [(self._strategy_key(r), r, [(j, r)])
                                        for j, r in enumerate(reqs)], sink)
        self._warmed_cap = max(self._warmed_cap or 0, cap)
        # warmed conditions are declared in-distribution: the operator
        # warms what the deployment was built for (DESIGN §15)
        self.mark_known(accels=[accel], workloads=workloads)
        return self.compile_count - before

    def stats(self) -> dict:
        """One observability dict across every serving layer (DESIGN §14):
        the engine's batching/compile counters, the strategy cache with
        its persistence counters, per-replica accounting when replicated,
        and the attached scheduler's queue counters when one is driving
        this engine."""
        s = {
            "requests_served": self.requests_served,
            "device_calls": self.device_calls,
            "compile_count": self.compile_count,
            "cost_evaluator": cm.default_evaluator(),
            "compiled_shapes": sorted(self._compiled),
            "chunk_cap": self.chunk_cap,
            "rows_padded": self.rows_padded,
            "tick_dedup": self.tick_dedup,
            "escalations": self.escalations,
            "polish_invocations": self.polish_invocations,
            "polish_improved": self.polish_improved,
            "coalesce_width_hist": dict(sorted(self.coalesce_hist.items())),
            "packed_workloads": len(self._packed),
            "strategy_hits": self.strategies.hits,
            "strategy_misses": self.strategies.misses,
            "strategy_hit_rate": self.strategies.hit_rate,
            "strategy_cache": {
                "entries": len(self.strategies),
                "capacity": self.strategies.capacity,
                "shared_hits": self.strategies.shared_hits,
                "loads": self.strategies.loads,
                "saves": self.strategies.saves,
                "stale_skipped": self.strategies.stale_skipped,
            },
            "replicas": (None if self.replicas is None
                         else self.replicas.stats()),
            "drift": {
                **self.monitor.stats(),
                "swaps_accepted": self.swaps_accepted,
                "swaps_rejected": self.swaps_rejected,
                "cache_invalidated": self.cache_invalidated,
            },
        }
        if self.scheduler is not None:
            s["scheduler"] = self.scheduler.stats()
        return s
