"""Data-parallel engine replicas over the ``distributed`` mesh (DESIGN §14).

One ``MapperEngine`` drives N devices: the checkpointed params are
replicated once (``distributed.sharding.replicate_tree``), and every
formed tick — whose lanes are an independent ``vmap`` over requests — is
sharded along its request axis (``shard_leading_axis``) so GSPMD splits
the fused episode across replicas with zero cross-device communication.
Per-row results are therefore bit-identical to the single-device program
(pinned by ``tests/test_replicas.py``), and the engine's shape-bucketed
compile accounting still holds: the sharded layout is a deterministic
function of the padded tick width, so the warmed program set stays
closed.

CI exercises this on CPU via ``--xla_force_host_platform_device_count``
(virtual devices sharing one host); on real multi-device hardware the
same code scales the device-bound miss path.  ``ReplicaGroup.stats()``
merges per-replica accounting (rows routed to each replica, sharded
calls) into ``MapperEngine.stats()``.
"""
from __future__ import annotations

import numpy as np

from ..distributed.sharding import (data_parallel_mesh, replicate_tree,
                                    shard_leading_axis)

__all__ = ["ReplicaGroup"]


class ReplicaGroup:
    """N data-parallel serving replicas on a 1-D ("data",) mesh.

    ``n`` defaults to every visible device.  The group owns placement
    (params replication, tick sharding) and per-replica accounting; the
    engine owns batching, caching and compile counting."""

    def __init__(self, n: int | None = None):
        import jax
        avail = len(jax.devices())
        if n is None:
            n = avail
        if n < 1 or n > avail:
            raise ValueError(f"need 1 <= replicas <= {avail} visible "
                             f"devices, got {n}")
        if n & (n - 1):
            raise ValueError(f"replica count must be a power of two to "
                             f"align with pow2 tick buckets, got {n}")
        self.n = int(n)
        self.mesh = data_parallel_mesh(self.n)
        self.rows_per_replica = [0] * self.n
        self.sharded_calls = 0

    def replicate_params(self, params):
        """One copy of the model per replica (done once at engine init)."""
        return replicate_tree(params, self.mesh)

    def pad_width(self, width: int) -> int:
        """Padded tick width: at least one lane per replica so every
        device call shards (one program layout per shape — keeps the
        warmed set closed)."""
        return max(int(width), self.n)

    def shard_tick(self, tree):
        """Shard a formed tick's per-row arrays across the replicas."""
        tree = shard_leading_axis(tree, self.mesh)
        self.sharded_calls += 1
        return tree

    def account_rows(self, width: int) -> None:
        """Attribute a ``width``-lane call's rows to their replicas
        (leading-axis sharding deals rows in contiguous blocks)."""
        per = width // self.n
        for i in range(self.n):
            self.rows_per_replica[i] += per

    def stats(self) -> dict:
        import jax
        return {
            "n_replicas": self.n,
            "devices": [str(d) for d in self.mesh.devices.flat],
            "platform": jax.devices()[0].platform,
            "sharded_calls": self.sharded_calls,
            "rows_per_replica": list(self.rows_per_replica),
        }
