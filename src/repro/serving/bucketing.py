"""Shape bucketing: the zero-recompile serving contract (DESIGN.md §12).

A jitted program is specialized to its input SHAPES; production traffic
arrives with arbitrary request-batch sizes, network lengths and budgets.
Left unbucketed, every new combination recompiles — worse than the search
the mapper replaces.  Bucketing quantizes the two shape axes to a small
closed set so steady-state traffic reuses a warmable set of programs:

 - request batches round UP to powers of two (:func:`batch_bucket`); the
   spare lanes are padded with copies of a real row.  vmap lanes are
   independent, so padding cannot perturb the real rows — the engine's
   padded results are bit-exact with unpadded calls (tested);
 - workload length (``n + 1`` positions incl. the input pseudo-tensor)
   rounds UP to an ``nmax`` bucket (:func:`nmax_bucket`); positions past a
   row's true ``n`` are masked to SYNC inside the fused scan (the per-row
   valid-length contract of ``cost_model``/``env``), so a short network
   padded into a long bucket rolls out bit-exactly;
 - budgets (and batch sizes) are VALUES, not shapes — they never force a
   recompile — but the strategy cache quantizes budgets
   (:func:`budget_bucket`) so near-identical conditions share one solved
   strategy.

The closed set is ``{nmax buckets} x {pow2 request batches}``; the engine
warms it once and counts compilations, which is what the recompile-churn
CI guard asserts on.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Iterable, Sequence

__all__ = ["batch_bucket", "nmax_bucket", "budget_bucket",
           "default_nmax_buckets", "pow2_buckets", "pow2_chunks", "coalesce"]

MB = float(2 ** 20)


def batch_bucket(c: int) -> int:
    """Smallest power of two >= ``c`` (the padded request-batch size)."""
    if c < 1:
        raise ValueError(f"need at least one request, got {c}")
    return 1 << (c - 1).bit_length()


def pow2_buckets(max_bucket: int) -> tuple[int, ...]:
    """All request-batch buckets up to ``batch_bucket(max_bucket)``."""
    top = batch_bucket(max_bucket)
    return tuple(1 << i for i in range(top.bit_length()))


def pow2_chunks(c: int, cap: int) -> tuple[int, ...]:
    """Split a group of ``c`` requests into device-call chunk sizes, each
    no wider than ``cap`` (the widest warmed pow2 bucket).

    The oversized-tick escape hatch (DESIGN §14): a tick wider than the
    warmed set must NOT pad up to an unwarmed pow2 program — it is cut
    into full ``cap``-wide chunks plus one remainder chunk that pads to
    its own (warmed, <= cap) pow2 bucket.  E.g. ``pow2_chunks(23, 8) ==
    (8, 8, 7)`` — the trailing 7 pads to the warmed 8-lane program."""
    if c < 1:
        raise ValueError(f"need at least one request, got {c}")
    cap = batch_bucket(cap)
    full, rem = divmod(c, cap)
    return (cap,) * full + ((rem,) if rem else ())


def nmax_bucket(n_pos: int, buckets: Sequence[int]) -> int:
    """Smallest configured ``nmax`` bucket holding ``n_pos`` positions.

    ``n_pos`` is ``workload.n + 1`` (layers + the input pseudo-tensor).
    Raises when the network is longer than every bucket — the caller must
    configure a bucket (<= the model's ``max_steps``) that fits."""
    for b in sorted(buckets):
        if n_pos <= b:
            return b
    raise ValueError(f"workload needs {n_pos} positions but the largest "
                     f"nmax bucket is {max(buckets)}")


def default_nmax_buckets(max_steps: int) -> tuple[int, ...]:
    """Powers of two from 8 up to (and always including) ``max_steps``.

    ``max_steps`` is the model's trajectory capacity — the hard ceiling on
    any bucket, since timestep embeddings only exist below it."""
    out = [b for b in (8, 16, 32, 64, 128) if b < max_steps]
    return tuple(out + [max_steps])


def budget_bucket(budget_bytes: float, quantum_bytes: float = MB) -> int:
    """Quantized budget id for strategy-cache keys (NOT a shape bucket).

    Requests whose budgets fall in the same quantum share a cached
    strategy; the cache re-derives validity against each request's exact
    budget from the stored peak memory, so a reused strategy can never be
    reported valid for a budget it overflows."""
    if budget_bytes <= 0:
        raise ValueError(f"budget must be positive, got {budget_bytes}")
    return int(budget_bytes // float(quantum_bytes))


def coalesce(items: Iterable, key: Callable) -> "OrderedDict":
    """Group ``items`` by ``key`` preserving first-seen group order.

    The engine's request planner: one group per ``nmax`` bucket -> one
    fused device call per group."""
    groups: OrderedDict = OrderedDict()
    for it in items:
        groups.setdefault(key(it), []).append(it)
    return groups
