"""Async serving front door: continuous batching over MapperEngine.

Production traffic does not arrive in neat ticks — requests trickle in,
burst, and carry latency expectations.  ``AsyncMapperScheduler`` turns
that stream into the engine's tick-shaped world:

 - :meth:`submit` admits a request (bounded queue — over-capacity
   submits raise :class:`AdmissionError` instead of growing latency
   unboundedly), answers strategy-cache hits IMMEDIATELY via
   ``engine.serve_cached`` (a hit never waits for a flush), and enqueues
   misses into per-``nmax``-bucket FIFO lanes with a flush deadline;
 - :meth:`pump` forms ticks continuously: a bucket flushes when it has
   coalesced a full device call's worth of unique conditions
   (``max_wave``, default the engine's warmed chunk cap) or when its
   oldest request's deadline (``flush_ms``) comes due — width when the
   load allows, latency when it does not.  ``flush_ms`` is therefore the
   knob bounding p99 under bursty arrivals;
 - :meth:`drain` force-flushes everything (end of stream / shutdown).

Determinism (DESIGN §14): the scheduler only ever REARRANGES requests
into ticks; the engine's exact-condition strategy identity guarantees
each unique condition is solved once in whichever tick it first lands,
and every other occurrence reuses that bit-identical entry.  Responses
are therefore bit-identical to per-request serving, independent of
arrival order, flush deadlines, coalescing, and replica count
(``tests/test_scheduler.py`` permutes all four).

Results come back as :class:`MapFuture`\\ s stamped with submit/resolve
times, so end-to-end (enqueue -> response) latency is measurable
directly — ``benchmarks/bench_serving.py`` reports p50/p99 over a Zipf
burst stream from these stamps.
"""
from __future__ import annotations

import time
from collections import OrderedDict

from .engine import MapperEngine, MapRequest, MapResponse
from .bucketing import nmax_bucket
from .config import ServingConfig, _SCHEDULER_FIELDS, config_from_kwargs

__all__ = ["AdmissionError", "MapFuture", "AsyncMapperScheduler"]


class AdmissionError(RuntimeError):
    """Raised by :meth:`AsyncMapperScheduler.submit` when the queue is at
    ``max_queue`` — backpressure instead of unbounded latency."""


class MapFuture:
    """A pending (or resolved) mapping request.

    ``t_submit``/``t_done`` are scheduler-clock stamps; ``latency_s`` is
    the end-to-end enqueue->response time once resolved."""

    __slots__ = ("request", "response", "done", "t_submit", "t_done")

    def __init__(self, request: MapRequest, t_submit: float):
        self.request = request
        self.response: MapResponse | None = None
        self.done = False
        self.t_submit = float(t_submit)
        self.t_done: float | None = None

    def _resolve(self, response: MapResponse, now: float) -> None:
        self.response = response
        self.done = True
        self.t_done = float(now)

    @property
    def latency_s(self) -> float:
        if not self.done:
            raise RuntimeError("future not resolved yet — pump or drain "
                               "the scheduler")
        return self.t_done - self.t_submit

    def result(self) -> MapResponse:
        if not self.done:
            raise RuntimeError("future not resolved yet — pump or drain "
                               "the scheduler")
        return self.response


class AsyncMapperScheduler:
    """Continuous-batching request scheduler over one :class:`MapperEngine`.

    Canonical construction (DESIGN §15) reads ``max_queue`` (bounds
    admitted-but-unsolved requests), ``flush_ms`` (how long a lone
    request waits for tick-mates — the p99 knob) and ``max_wave`` (caps
    unique conditions per formed tick; default: the engine's warmed
    chunk cap, so a full wave is exactly one warmed device call) from a
    frozen ``config.ServingConfig`` — by default the engine's own, so
    ``AsyncMapperScheduler(engine)`` honors the deployment record the
    engine was built from.  The pre-§15 scattered kwargs keep working
    bit-identically through a once-per-process deprecation shim.
    ``clock`` is injectable for simulated-time tests and benchmarks."""

    def __init__(self, engine: MapperEngine, *,
                 config: ServingConfig | None = None,
                 clock=time.perf_counter, **legacy):
        if config is None and legacy:
            config = config_from_kwargs("AsyncMapperScheduler",
                                        _SCHEDULER_FIELDS, legacy)
        elif legacy:
            raise TypeError(
                "pass either config= or the legacy scheduler kwargs, not "
                "both: got config= plus " + ", ".join(sorted(legacy)))
        if config is None:       # inherit the engine's deployment record
            config = getattr(engine, "serving_config", None) or ServingConfig()
        if config.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got "
                             f"{config.max_queue}")
        if config.flush_ms < 0:
            raise ValueError(f"flush_ms must be >= 0, got {config.flush_ms}")
        self.engine = engine
        self.max_queue = int(config.max_queue)
        self.flush_s = float(config.flush_ms) / 1e3
        self.max_wave = config.max_wave
        self.clock = clock
        self._lanes: OrderedDict = OrderedDict()   # nmax bucket -> [MapFuture]
        self._server_free = 0.0                    # simulated-time server clock
        self.queue_depth = 0
        self.max_queue_depth = 0
        self.submitted = 0
        self.rejected = 0
        self.resolved_at_submit = 0
        self.flushes = {"width": 0, "deadline": 0, "force": 0}
        engine.scheduler = self                    # stats() backref

    # -- intake --------------------------------------------------------------

    def submit(self, request: MapRequest, now: float | None = None) -> MapFuture:
        """Admit one request; returns its :class:`MapFuture`.

        Strategy-cache hits resolve before this returns (no queueing, no
        device work).  Misses enqueue for the next tick; raises
        :class:`AdmissionError` when the queue is full."""
        now = self.clock() if now is None else now
        self.submitted += 1
        fut = MapFuture(request, now)
        hit = self.engine.serve_cached(request)
        if hit is not None:
            self.resolved_at_submit += 1
            fut._resolve(hit, now)
            return fut
        if self.queue_depth >= self.max_queue:
            self.submitted -= 1
            self.rejected += 1
            raise AdmissionError(
                f"queue at capacity ({self.max_queue}); retry after a pump")
        nb = nmax_bucket(request.workload.n + 1, self.engine.nmax_buckets)
        self._lanes.setdefault(nb, []).append(fut)
        self.queue_depth += 1
        self.max_queue_depth = max(self.max_queue_depth, self.queue_depth)
        return fut

    # -- tick formation ------------------------------------------------------

    def _wave(self) -> int:
        return self.max_wave or self.engine.chunk_cap

    def _unique_pending(self, lane: list) -> int:
        return len({self.engine._strategy_key(f.request) for f in lane})

    def pump(self, now: float | None = None, *, force: bool = False) -> int:
        """Flush every bucket lane that is ready: a full wave of unique
        conditions, an expired oldest deadline, or ``force``.  Returns
        the number of requests resolved.

        With an explicit ``now`` the scheduler runs in SIMULATED time
        (open-loop arrivals, the standard dodge around coordinated
        omission): a flushed tick starts at ``max(now, server free)``,
        its service time is the MEASURED wall duration of the device
        call, and resolve stamps land on the simulated axis — so
        p50/p99 from :attr:`MapFuture.latency_s` include both queueing
        delay and real compute.  With ``now=None`` the real clock
        drives everything."""
        simulated = now is not None
        now = self.clock() if now is None else now
        resolved = 0
        wave = self._wave()
        for nb in list(self._lanes):
            lane = self._lanes[nb]
            if not lane:
                continue
            if force:
                reason = "force"
            elif self._unique_pending(lane) >= wave:
                reason = "width"
            elif now - lane[0].t_submit >= self.flush_s:
                reason = "deadline"
            else:
                continue
            self._lanes[nb] = []
            self.queue_depth -= len(lane)
            self.flushes[reason] += 1
            wall0 = time.perf_counter()
            responses = self.engine.serve([f.request for f in lane])
            elapsed = time.perf_counter() - wall0
            if simulated:
                t_done = max(now, self._server_free) + elapsed
                self._server_free = t_done
            else:
                t_done = self.clock()
            for fut, resp in zip(lane, responses):
                fut._resolve(resp, t_done)
            resolved += len(lane)
        return resolved

    def drain(self, now: float | None = None) -> int:
        """Force-flush all queued requests; returns how many resolved."""
        return self.pump(now, force=True)

    # -- conveniences --------------------------------------------------------

    def serve_stream(self, requests: list, arrivals: list | None = None
                     ) -> list[MapResponse]:
        """Run a whole request stream through submit/pump/drain and return
        responses in request order.

        With ``arrivals`` (monotone timestamps on the scheduler's clock,
        e.g. a simulated burst process), submit/pump run in simulated
        time; otherwise the real clock drives deadlines."""
        futs = []
        for i, req in enumerate(requests):
            now = arrivals[i] if arrivals is not None else None
            futs.append(self.submit(req, now))
            self.pump(now)
        self.drain(arrivals[-1] if arrivals else None)
        return [f.result() for f in futs]

    def stats(self) -> dict:
        return {
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "max_queue": self.max_queue,
            "flush_ms": self.flush_s * 1e3,
            "max_wave": self._wave(),
            "submitted": self.submitted,
            "rejected": self.rejected,
            "resolved_at_submit": self.resolved_at_submit,
            "flushes": dict(self.flushes),
        }
