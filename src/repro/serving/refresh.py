"""Background refresh: drift report -> teacher corpus -> fine-tune ->
gated hot swap (DESIGN.md §15).

The ACT half of the closed loop.  When ``drift.DriftMonitor`` fires a
:class:`DriftReport`, the :class:`RefreshWorker`:

 1. **G-Samples a fresh teacher corpus for the drifted region** —
    ``dataset.generate_teacher_corpus`` over the report's (workloads x
    accels x budgets) grid, the strongest teacher available for those
    conditions (paper §4.4);
 2. **fine-tunes off the serving path** — ``core.train.fine_tune`` warm-
    starts a COPY of the live params (the serving tree is never donated
    or mutated) and checkpoints to ``ckpt_dir``;
 3. **restores the candidate through the checkpoint upgrade path** —
    ``checkpoint.upgrade_pytree(prefix="params")`` on the written
    checkpoint, asserting zero missing leaves (same architecture ->
    function-preserving restore);
 4. **quality-gates** the candidate on a held-out probe grid — drifted
    conditions at budgets the fine-tune corpus did NOT train on, plus
    retained (non-drifted) conditions sampled from the replay buffer.
    The candidate must MATCH OR BEAT the live params' probe score
    (mean of valid x speedup, one fused ``dnnfuser_infer_batch`` call
    per params — off the engine's compile/serving counters);
 5. on accept, **hot-swaps** via ``MapperEngine.swap_params`` with a
    region-scoped cache invalidation predicate and marks the region's
    conditions known (so the monitor stops re-firing on them).

``poll()`` is the serving loop's hook: it drains pending reports, merges
their regions, and runs ONE refresh — cheap no-op when nothing fired.
Everything here is synchronous host code; "background" means off the
request path (between ticks), not a thread — JAX tracing is not
thread-safe to interleave with serving.
"""
from __future__ import annotations

import tempfile

import numpy as np

from ..checkpoint import Checkpointer, upgrade_pytree
from ..core import cost_model as cm
from ..core.dataset import generate_teacher_corpus
from ..core.infer import dnnfuser_infer_batch
from ..core.gsampler import GSamplerConfig
from ..core.model import DTConfig, dt_loss
from ..core.seq2seq import S2SConfig, s2s_loss
from ..core.train import TrainConfig, fine_tune
from .drift import region_key_predicate
from .engine import _accel_key

__all__ = ["RefreshWorker", "probe_score"]

MB = float(2 ** 20)


def _loss_for(cfg):
    """Imitation loss for a backend config (mirrors ``backend_for``)."""
    if isinstance(cfg, DTConfig):
        return lambda p, b: dt_loss(p, cfg, b)
    if isinstance(cfg, S2SConfig):
        return lambda p, b: s2s_loss(p, cfg, b)
    raise TypeError(f"no imitation loss registered for {type(cfg).__name__}")


def probe_score(params, cfg, conds, *, repair: bool = True) -> float:
    """Mean quality of ``params`` over probe conditions ``(workload,
    batch, budget_bytes, accel)``: ``mean(valid * speedup)`` from one
    fused inference call.  Uses the same serving episode the engine
    rides, but through the public batch API — probe traffic never touches
    the engine's compile/cache accounting."""
    if not conds:
        return 0.0
    nmax = max(w.n + 1 for w, _, _, _ in conds)
    rows = [cm.pack_workload(w, a, nmax) for w, _, _, a in conds]
    out = dnnfuser_infer_batch(
        params, cfg, cm.stack_workloads(rows),
        np.asarray([b for _, b, _, _ in conds], np.float32),
        np.asarray([bb for _, _, bb, _ in conds], np.float32),
        hw=[a for _, _, _, a in conds], repair=repair)
    return float(np.mean(out["valid"] * out["speedup"]))


class RefreshWorker:
    """Owns the corpus -> fine-tune -> gate -> swap pipeline for one
    engine.

    Knobs: ``train`` / ``ga`` — fine-tune and teacher budgets (defaults
    are refresh-sized: ~10% of a pre-train, small GA); ``batch`` /
    ``top_k`` — teacher corpus shape.  ``top_k`` defaults LOW (2): the
    conditioning return is the memory fraction, not achieved speedup, so
    trajectories of mixed quality over the same condition are
    indistinguishable to the student and deep elite lists DILUTE the
    refresh policy (measured in ``benchmarks/bench_drift.py``: top-6
    imitation recovers ~0.79 of teacher quality on the drifted region,
    top-2 recovers ~1.0); ``gate_tol`` — how much probe
    quality the candidate may give up and still swap (0 = must match or
    beat); ``probe_shift`` — relative budget shift for held-out probe
    conditions; ``max_probe`` — probe-grid cap per side (drifted /
    retained); ``ckpt_dir`` — where fine-tune checkpoints land (a temp
    dir per refresh when None)."""

    def __init__(self, engine, *, train: TrainConfig | None = None,
                 ga: GSamplerConfig | None = None, batch: int = 64,
                 top_k: int = 2, loss_fn=None, ckpt_dir=None,
                 seed: int = 0, gate_tol: float = 0.0,
                 probe_shift: float = 0.15, max_probe: int = 8):
        self.engine = engine
        self.train = train or TrainConfig(steps=200, batch_size=16,
                                          lr=1e-4, warmup=20)
        self.ga = ga or GSamplerConfig(population=24, generations=16)
        self.batch = int(batch)
        self.top_k = int(top_k)
        self.loss_fn = loss_fn
        self.ckpt_dir = ckpt_dir
        self.seed = int(seed)
        self.gate_tol = float(gate_tol)
        self.probe_shift = float(probe_shift)
        self.max_probe = int(max_probe)
        self.refreshes = 0
        self.last_result: dict | None = None

    # -- serving-loop hook ---------------------------------------------------

    def poll(self) -> dict | None:
        """Drain pending drift reports; if any fired, merge their regions
        and run one refresh.  Returns the refresh summary, or None when
        nothing fired."""
        reports = self.engine.monitor.pop_reports()
        if not reports:
            return None
        accels, wls, budgets = {}, {}, set()
        for r in reports:
            accels.update({a.name: a for a in r.accels})
            wls.update({w.name: w for w in r.workloads})
            budgets.update(r.budgets_mb)
        return self.refresh(list(wls.values()), list(accels.values()),
                            sorted(budgets))

    # -- the pipeline --------------------------------------------------------

    def refresh(self, workloads: list, accels: list,
                budgets_mb: list) -> dict:
        """Run corpus -> fine-tune -> gate -> (maybe) swap for one drifted
        region.  Returns a summary dict (``accepted``, scores, corpus
        size, missing-leaf count)."""
        engine = self.engine
        if not (workloads and accels and budgets_mb):
            raise ValueError("refresh needs a non-empty region: got "
                             f"{len(workloads)} workloads, {len(accels)} "
                             f"accels, {len(budgets_mb)} budgets")
        # canonical region order: the fused grid teacher's per-condition
        # RNG draws depend on grid POSITION, so the corpus (and therefore
        # the candidate) must not depend on which condition happened to
        # arrive more often in the drifted window
        workloads = sorted(workloads, key=lambda w: w.name)
        accels = sorted(accels, key=lambda a: a.name)
        budgets_mb = sorted(budgets_mb)
        self.refreshes += 1
        extra = self._harvest_extra(workloads, accels, budgets_mb)
        corpus = generate_teacher_corpus(
            workloads, accels, batch=self.batch, budgets_mb=list(budgets_mb),
            max_steps=engine.cfg.max_steps, top_k=self.top_k,
            ga_cfg=self.ga, seed=self.seed + self.refreshes,
            extra_elites=extra or None)
        ckpt_dir = self.ckpt_dir or tempfile.mkdtemp(prefix="repro_refresh_")
        loss = self.loss_fn or _loss_for(engine.cfg)
        _, log = fine_tune(loss, engine.params, corpus, self.train,
                           ckpt_dir=ckpt_dir)
        # the candidate that swaps is the one read back through the
        # documented checkpoint upgrade path — what a restarted process
        # would serve — not the in-memory tree the trainer returned
        candidate, missing = upgrade_pytree(
            Checkpointer(ckpt_dir).path(), engine.params, prefix="params")
        if missing:
            raise RuntimeError(
                f"refresh checkpoint is missing {len(missing)} leaves "
                f"({missing[:3]}...): fine-tune must preserve the live "
                f"architecture")

        conds = self._probe_conds(workloads, accels, budgets_mb)
        live = probe_score(engine.params, engine.cfg, conds,
                           repair=engine.repair)
        cand = probe_score(candidate, engine.cfg, conds,
                           repair=engine.repair)
        accepted = cand >= live - self.gate_tol
        if accepted:
            # invalidation scope: strictly-UNSEEN conditions (a known
            # workload appearing in drifted records only because it rode
            # an unseen accel keeps its known-accel cache entries — the
            # §15 non-drifted bit-exactness contract).  A report with no
            # unseen conditions at all (pure hit-decay / violation
            # drift) invalidates the whole region: those entries are the
            # stale ones that fired it.
            unseen_w = [w for w in workloads
                        if w.name not in engine.monitor.known_workloads]
            unseen_a = [a for a in accels
                        if a.name not in engine.monitor.known_accels]
            if not (unseen_w or unseen_a):
                unseen_w, unseen_a = workloads, accels
            pred = region_key_predicate(unseen_w, unseen_a, _accel_key)
            invalidated = engine.swap_params(candidate, invalidate=pred)
            engine.mark_known(accels=accels, workloads=workloads)
        else:
            invalidated = 0
            engine.swaps_rejected += 1
        self.last_result = {
            "accepted": bool(accepted),
            "live_score": live, "candidate_score": cand,
            "probe_conds": len(conds), "corpus_size": len(corpus),
            "fine_tune_loss": log["final_loss"],
            "cache_invalidated": invalidated,
            "extra_elites": sum(len(v) for v in extra.values()),
            "region": {"workloads": [w.name for w in workloads],
                       "accels": [a.name for a in accels],
                       "budgets_mb": list(budgets_mb)},
        }
        return self.last_result

    def _harvest_extra(self, workloads, accels, budgets_mb) -> dict:
        """Drain the engine's region-matched refinement wins into the
        ``generate_teacher_corpus(extra_elites=...)`` shape (DESIGN §17):
        ``(workload_name, accel_name, budget_mb)`` -> list of strategies.
        Wins at budgets outside the refresh grid stay in the engine's log
        (a later refresh over their region can still use them)."""
        grid = {round(float(b), 6) for b in budgets_mb}
        wins = self.engine.harvest_wins(workloads=workloads, accels=accels,
                                        drain=False)
        extra: dict = {}
        taken = []
        for w in wins:
            bmb = round(w["budget_bytes"] / MB, 6)
            if bmb not in grid:
                continue
            key = (w["workload"].name, w["accel"].name, bmb)
            extra.setdefault(key, []).append(w["strategy"])
            taken.append(w)
        for w in taken:                      # drain only what we consumed
            self.engine.wins.remove(w)
        return extra

    def _probe_conds(self, workloads, accels, budgets_mb) -> list:
        """Held-out probe grid: drifted (workload x accel) pairs at
        budgets shifted AWAY from the fine-tune corpus (x(1 +/- shift) —
        never trained on), plus retained conditions sampled from the
        replay buffer OUTSIDE the drifted region (the gate must see that
        the candidate didn't rot the old regime)."""
        shift = self.probe_shift
        held = [b * (1.0 + s) for b in budgets_mb for s in (-shift, shift)]
        drifted = [(w, self.batch, b * MB, a)
                   for w in workloads for a in accels for b in held]
        rng = np.random.default_rng(self.seed + self.refreshes)
        if len(drifted) > self.max_probe:
            idx = rng.choice(len(drifted), self.max_probe, replace=False)
            drifted = [drifted[i] for i in sorted(idx)]
        wl_names = {w.name for w in workloads}
        accel_names = {a.name for a in accels}
        retained, seen = [], set()
        for rec in self.engine.monitor.replay:
            if (rec.workload.name in wl_names
                    or rec.accel.name in accel_names):
                continue
            key = (rec.workload.name, rec.batch,
                   float(rec.budget_bytes), rec.accel.name)
            if key in seen:
                continue
            seen.add(key)
            retained.append((rec.workload, rec.batch,
                             float(rec.budget_bytes), rec.accel))
        if len(retained) > self.max_probe:
            idx = rng.choice(len(retained), self.max_probe, replace=False)
            retained = [retained[i] for i in sorted(idx)]
        return drifted + retained

    def stats(self) -> dict:
        return {"refreshes": self.refreshes, "last_result": self.last_result}
