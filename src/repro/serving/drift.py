"""Drift detection over the served condition stream (DESIGN.md §15).

The mapper is imitation-trained once, offline, on a fixed (workload x
accel x budget) mix — but production traffic drifts: new accelerator
SKUs roll out, new networks ship, budget regimes shift.  §15 closes the
loop.  This module is the SENSE half:

 - :class:`ReplayBuffer` — a bounded telemetry buffer the engine feeds
   with every served ``(request, response)`` pair: the condition plus the
   realized cost-model outcome (valid? cached? speedup).  It doubles as
   the sampling pool the refresh worker draws probe/teacher conditions
   from;
 - :class:`DriftMonitor` — evaluates each completed window of
   observations against :class:`DriftConfig` thresholds: unseen-accel
   rate, unseen-network rate, strategy-cache hit-rate decay vs a running
   baseline, and budget-violation rate.  Any trigger fires a typed
   :class:`DriftReport` naming the drifted REGION (the unseen accels /
   workloads and the budget range observed), which the ACT half
   (``refresh.RefreshWorker``) turns into a teacher corpus, a fine-tune,
   and a gated hot swap.

The monitor is pure host bookkeeping — O(1) per observation, no device
work, nothing on the serving fast path but a deque append and a few
set lookups.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from .config import DriftConfig

__all__ = ["ReplayRecord", "ReplayBuffer", "DriftReport", "DriftMonitor"]

MB = float(2 ** 20)


@dataclass(frozen=True)
class ReplayRecord:
    """One served condition + its realized outcome.  Holds the live
    workload/accel OBJECTS (not just names) so the refresh worker can
    G-Sample a teacher corpus for exactly the drifted conditions."""
    workload: object            # repro.workloads.Workload
    batch: int
    budget_bytes: float
    accel: object               # core.accel.AccelConfig
    valid: bool                 # realized: strategy fit the budget
    cached: bool                # strategy-cache hit (or in-tick dup)
    speedup: float


class ReplayBuffer:
    """Bounded FIFO of :class:`ReplayRecord`; oldest records drop first.
    ``total`` counts every observation ever, ``depth`` the retained
    window."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._d: deque = deque(maxlen=capacity)
        self.total = 0

    def append(self, rec: ReplayRecord) -> None:
        self._d.append(rec)
        self.total += 1

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(self._d)

    def recent(self, n: int) -> list:
        """The most recent ``n`` records, oldest first."""
        if n >= len(self._d):
            return list(self._d)
        return list(self._d)[-n:]


@dataclass(frozen=True)
class DriftReport:
    """One window's verdict: which thresholds fired and over WHAT region.

    ``triggers`` is a tuple of trigger names (``"unseen_accel"``,
    ``"unseen_workload"``, ``"hit_rate_decay"``, ``"budget_violations"``).
    The region fields carry live objects for the refresh worker, capped
    at ``DriftConfig.max_region`` each (``region_capped`` notes when
    traffic was broader than the cap)."""
    window_index: int
    window_size: int
    unseen_accel_rate: float
    unseen_workload_rate: float
    hit_rate: float
    baseline_hit_rate: float
    violation_rate: float
    triggers: tuple = ()
    accels: tuple = ()          # drifted AccelConfig objects (deduped)
    workloads: tuple = ()       # drifted Workload objects (deduped)
    budgets_mb: tuple = ()      # budgets observed in the drifted slice
    region_capped: bool = False

    @property
    def drifted(self) -> bool:
        return bool(self.triggers)


class DriftMonitor:
    """Window-based drift detector over the replay stream.

    ``known_accels`` / ``known_workloads`` (names) define the
    in-distribution sets; the engine seeds them from ``ServingConfig``
    and extends them on ``warmup`` and accepted swaps (``mark_known``).
    When BOTH seeds are empty, the first completed window self-calibrates:
    its conditions become the known sets and that window never fires.

    The hit-rate baseline is the first non-drifted window's rate,
    exponentially updated (0.8/0.2) on every later non-drifted window —
    so a gradual regime change still registers as decay against the
    remembered good regime.  Fired reports queue in :attr:`pending`
    until the refresh worker consumes them (:meth:`pop_reports`)."""

    def __init__(self, cfg: DriftConfig | None = None, *,
                 known_accels=(), known_workloads=()):
        self.cfg = cfg or DriftConfig()
        self.known_accels = set(known_accels)
        self.known_workloads = set(known_workloads)
        self._calibrate = not (self.known_accels or self.known_workloads)
        self.replay = ReplayBuffer(self.cfg.replay_capacity)
        self._window: list = []          # records of the in-flight window
        self.windows_evaluated = 0
        self.reports_fired = 0
        self.baseline_hit_rate: float | None = None
        self.pending: list = []          # fired, unconsumed DriftReports
        self.last_report: DriftReport | None = None

    # -- stream side (engine calls this per served request) ------------------

    def observe(self, rec: ReplayRecord) -> DriftReport | None:
        """Record one served condition; returns a report when this
        observation completes a window AND the window drifted."""
        self.replay.append(rec)
        self._window.append(rec)
        if len(self._window) < self.cfg.window:
            return None
        window, self._window = self._window, []
        return self._evaluate(window)

    def mark_known(self, *, accels=(), workloads=()) -> None:
        """Extend the in-distribution sets (accepted swap / warmup)."""
        self.known_accels.update(a.name if hasattr(a, "name") else str(a)
                                 for a in accels)
        self.known_workloads.update(w.name if hasattr(w, "name") else str(w)
                                    for w in workloads)
        if self.known_accels or self.known_workloads:
            self._calibrate = False

    def pop_reports(self) -> list:
        """Drain pending reports (refresh worker's consume side)."""
        out, self.pending = self.pending, []
        return out

    # -- window evaluation ---------------------------------------------------

    def _evaluate(self, window: list) -> DriftReport | None:
        self.windows_evaluated += 1
        n = len(window)
        if self._calibrate:
            # first window with no declared training mix: adopt it
            self.mark_known(accels=[r.accel for r in window],
                            workloads=[r.workload for r in window])
            self.baseline_hit_rate = sum(r.cached for r in window) / n
            return None
        unseen_a = [r for r in window
                    if r.accel.name not in self.known_accels]
        unseen_w = [r for r in window
                    if r.workload.name not in self.known_workloads]
        a_rate = len(unseen_a) / n
        w_rate = len(unseen_w) / n
        hit_rate = sum(r.cached for r in window) / n
        viol_rate = sum(not r.valid for r in window) / n
        base = self.baseline_hit_rate
        triggers = []
        if a_rate > self.cfg.unseen_accel_rate:
            triggers.append("unseen_accel")
        if w_rate > self.cfg.unseen_workload_rate:
            triggers.append("unseen_workload")
        if base is not None and (base - hit_rate) > self.cfg.hit_rate_drop:
            triggers.append("hit_rate_decay")
        if viol_rate > self.cfg.violation_rate:
            triggers.append("budget_violations")
        if not triggers:
            # non-drifted window: update the remembered good regime
            self.baseline_hit_rate = (hit_rate if base is None
                                      else 0.8 * base + 0.2 * hit_rate)
            return None
        drifted = unseen_a + unseen_w or list(window)
        accels, wls, capped = self._region(drifted)
        budgets = sorted({round(r.budget_bytes / MB, 3) for r in drifted})
        if len(budgets) > 2 * self.cfg.max_region:
            budgets = budgets[:: max(1, len(budgets)
                                     // (2 * self.cfg.max_region))]
            capped = True
        report = DriftReport(
            window_index=self.windows_evaluated - 1, window_size=n,
            unseen_accel_rate=a_rate, unseen_workload_rate=w_rate,
            hit_rate=hit_rate,
            baseline_hit_rate=base if base is not None else hit_rate,
            violation_rate=viol_rate, triggers=tuple(triggers),
            accels=accels, workloads=wls,
            budgets_mb=tuple(budgets), region_capped=capped)
        self.reports_fired += 1
        self.pending.append(report)
        self.last_report = report
        return report

    def _region(self, records: list) -> tuple:
        """Dedup (by name) the accels/workloads of the drifted slice,
        most-frequent first, capped at ``max_region`` each."""
        def top(items, name_of):
            counts: dict = {}
            first: dict = {}
            for it in items:
                k = name_of(it)
                counts[k] = counts.get(k, 0) + 1
                first.setdefault(k, it)
            ranked = sorted(counts, key=lambda k: -counts[k])
            return ([first[k] for k in ranked[: self.cfg.max_region]],
                    len(ranked) > self.cfg.max_region)
        accels, a_cap = top([r.accel for r in records], lambda a: a.name)
        wls, w_cap = top([r.workload for r in records], lambda w: w.name)
        return tuple(accels), tuple(wls), a_cap or w_cap

    def stats(self) -> dict:
        return {
            "replay_depth": len(self.replay),
            "replay_capacity": self.replay.capacity,
            "replay_total": self.replay.total,
            "windows_evaluated": self.windows_evaluated,
            "reports_fired": self.reports_fired,
            "pending_reports": len(self.pending),
            "baseline_hit_rate": self.baseline_hit_rate,
            "known_accels": sorted(self.known_accels),
            "known_workloads": sorted(self.known_workloads),
            "last_report": (None if self.last_report is None else {
                "window_index": self.last_report.window_index,
                "triggers": list(self.last_report.triggers),
                "accels": [a.name for a in self.last_report.accels],
                "workloads": [w.name for w in self.last_report.workloads],
                "budgets_mb": list(self.last_report.budgets_mb),
            }),
        }


def region_key_predicate(workloads, accels, accel_key_fn) -> callable:
    """Build a strategy-cache invalidation predicate scoped to a drift
    region: an entry is invalidated iff its key names a drifted workload
    OR a drifted accelerator (DESIGN §15 — non-drifted keys keep their
    entries, preserving bit-exact responses across a swap).

    ``accel_key_fn`` is the engine's ``_accel_key`` so the predicate
    compares in exactly the cache's accel identity."""
    wl_names = {w.name for w in workloads}
    accel_keys = {accel_key_fn(a) for a in accels}

    def pred(key: tuple) -> bool:
        name, _batch, _bid, akey = key
        return name in wl_names or akey in accel_keys
    return pred
