"""ServingConfig: the one construction surface for the serving stack
(DESIGN.md §15).

Before §15 the serving stack was configured by kwargs scattered across
two constructors — ``MapperEngine(cache_path=..., checkpoint_id=...,
approx_budget_sharing=..., replicas=...)`` and
``AsyncMapperScheduler(flush_ms=..., max_queue=...)`` — which made a
deployment's configuration impossible to name, persist, or diff.
:class:`ServingConfig` is the frozen record of EVERYTHING a serving
deployment is: engine batching/bucketing, strategy-cache identity and
persistence, replica topology, scheduler admission/flush policy, and the
closed-loop drift knobs (:class:`DriftConfig`).  Canonical construction
is ``MapperEngine.from_config(params, cfg, config)`` or the top-level
``repro.serve(params, cfg, config)`` factory.

The scattered kwargs keep working — each constructor shims them into a
``ServingConfig`` field-for-field, so old-kwarg construction is
BIT-IDENTICAL to config construction (tested) — but emits a
:class:`DeprecationWarning` ONCE per kwarg per process
(``tests/test_drift.py::test_deprecated_kwargs_warn_once_and_match_config``).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, fields

__all__ = ["DriftConfig", "ServingConfig"]

MB = float(2 ** 20)


@dataclass(frozen=True)
class DriftConfig:
    """Closed-loop drift knobs (DESIGN §15).

    The engine always keeps the bounded replay buffer and evaluates the
    monitor every ``window`` observed requests; a :class:`DriftReport`
    fires when any trigger threshold is crossed.  ``known_accels`` /
    ``known_workloads`` seed the monitor's in-distribution sets (names);
    ``warmup()`` and accepted swaps extend them.  With BOTH sets empty the
    monitor self-calibrates: the first full window's conditions become
    the known sets (a deployment that never declares its training mix
    still gets drift detection against its own early traffic)."""
    replay_capacity: int = 4096    # bounded telemetry/replay buffer depth
    window: int = 256              # requests per drift-evaluation window
    unseen_accel_rate: float = 0.2     # trigger: unseen-accel fraction
    unseen_workload_rate: float = 0.2  # trigger: unseen-network fraction
    hit_rate_drop: float = 0.3     # trigger: absolute hit-rate decay vs baseline
    violation_rate: float = 0.5    # trigger: budget-violation fraction
    max_region: int = 4            # accels/workloads reported per region


@dataclass(frozen=True)
class ServingConfig:
    """One frozen record of a serving deployment (DESIGN §15).

    Engine fields mirror the pre-§15 ``MapperEngine`` kwargs; scheduler
    fields the ``AsyncMapperScheduler`` ones; ``drift`` the closed-loop
    monitor.  ``replicas`` is a replica count or a prebuilt
    ``ReplicaGroup``; ``None`` serves single-device."""
    # -- engine (DESIGN §12) --
    repair: bool = True
    nmax_buckets: tuple | None = None
    max_coalesce: int = 16
    # -- propose-then-polish escalation (DESIGN §17) --
    # polish: gradient-refine every strategy-cache MISS before it is
    # cached/answered (opt-in; never worsens a response).  escalate: route
    # responses that are STILL budget-violating after the one-shot (and
    # polish, when enabled) rollout through the warm-started search
    # portfolio.  Both default off: the default serving path stays
    # bit-identical to pre-§17 serving.
    polish: bool = False
    escalate: bool = False
    # -- strategy cache (DESIGN §12, §14) --
    strategy_capacity: int = 4096
    budget_quantum: float = MB
    approx_budget_sharing: bool = False
    cache_path: object = None
    checkpoint_id: str | None = None
    # -- replicas (DESIGN §14) --
    replicas: object = None
    # -- scheduler (DESIGN §14) --
    max_queue: int = 1024
    flush_ms: float = 8.0
    max_wave: int | None = None
    # -- closed loop (DESIGN §15) --
    drift: DriftConfig = field(default_factory=DriftConfig)
    known_accels: tuple[str, ...] = ()
    known_workloads: tuple[str, ...] = ()


_ENGINE_FIELDS = ("repair", "nmax_buckets", "max_coalesce",
                  "strategy_capacity", "budget_quantum",
                  "approx_budget_sharing", "cache_path", "checkpoint_id",
                  "replicas", "drift", "known_accels", "known_workloads",
                  "polish", "escalate")
_SCHEDULER_FIELDS = ("max_queue", "flush_ms", "max_wave")

# Post-§15 fields accepted as direct kwargs WITHOUT a deprecation warning:
# they were born after ServingConfig, so the kwarg form is a supported
# convenience (``MapperEngine(params, cfg, polish=True)``), not a legacy
# construction surface being phased out.
_CURRENT_KWARGS = frozenset({"polish", "escalate"})

# DeprecationWarning fires once per kwarg per process — a serving loop
# constructing engines in a loop must not drown the log.
_WARNED: set[str] = set()


def _reset_deprecation_warnings() -> None:
    """Test hook: make the once-per-process warnings fire again."""
    _WARNED.clear()


def _warn_deprecated(owner: str, name: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{owner}(..., {name}=...) is deprecated; pass "
        f"ServingConfig({name}=...) via {owner}.from_config / the config= "
        f"keyword (or repro.serve) instead — the kwarg keeps working and "
        f"is bit-identical, but will eventually be removed",
        DeprecationWarning, stacklevel=4)


def config_from_kwargs(owner: str, allowed: tuple[str, ...],
                       kwargs: dict) -> ServingConfig:
    """Shim pre-§15 scattered kwargs into a :class:`ServingConfig`.

    Field-for-field: the resulting config is exactly the one the caller
    would have written by hand, so both construction paths are
    bit-identical.  Unknown kwargs raise ``TypeError`` (same contract as
    a real signature); each deprecated kwarg warns once per process."""
    valid = {f.name for f in fields(ServingConfig)}
    for name in kwargs:
        if name not in valid or name not in allowed:
            raise TypeError(f"{owner}() got an unexpected keyword argument "
                            f"{name!r}")
        if name not in _CURRENT_KWARGS:
            _warn_deprecated(owner, name)
    return ServingConfig(**kwargs)
