"""GPipe-style pipeline parallelism via shard_map + lax.ppermute.

Optional PP feature (DESIGN §6): stage-stacked params live on a 'stage'
mesh axis; microbatches stream through the classic (n_micro + n_stages - 1)
-tick schedule, activations hopping stage->stage+1 with collective-permute
each tick.  The 40-cell dry-run uses DPxTP (the right default at 256 chips
for these model sizes); this module demonstrates — and tests, on host
devices — that the framework's PP building block is coherent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_forward", "make_stage_mesh"]


def make_stage_mesh(n_stages: int):
    devs = jax.devices()[:n_stages]
    import numpy as np
    return Mesh(np.asarray(devs), ("stage",))


def pipeline_forward(stage_params, inputs, stage_fn, mesh, *,
                     n_microbatches: int):
    """Run ``stage_fn(params_s, x) -> x`` over S pipeline stages.

    ``stage_params``: pytree stacked [S, ...]; ``inputs``: [n_micro, mb, ...]
    microbatched inputs (consumed by stage 0).  Returns [n_micro, mb, ...]
    outputs (produced by stage S-1).  Bubble fraction is the GPipe
    (S-1)/(T+S-1); the schedule is the standard loop:

        tick t: every stage computes on its held activation, then
                ppermute(shift +1); stage 0 injects microbatch t.
    """
    S = mesh.shape["stage"]
    T = n_microbatches + S - 1

    def spmd(params, xs):
        stage = jax.lax.axis_index("stage")
        params = jax.tree.map(lambda a: a[0], params)   # this stage's slice
        mb_shape = xs.shape[1:]
        hold = jnp.zeros(mb_shape, xs.dtype)
        outs = jnp.zeros((n_microbatches,) + mb_shape, xs.dtype)

        def tick(t, carry):
            hold, outs = carry
            inject = jnp.where(t < n_microbatches,
                               xs[jnp.minimum(t, n_microbatches - 1)],
                               jnp.zeros(mb_shape, xs.dtype))
            cur = jnp.where(stage == 0, inject, hold)
            y = stage_fn(params, cur)
            # last stage emits microbatch (t - (S-1)) at tick t
            out_idx = t - (S - 1)
            emit = (stage == S - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(y, "stage",
                                   [(i, (i + 1) % S) for i in range(S)])
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (hold, outs))
        # only the last stage holds real outputs; psum replicates them
        # (every other stage contributes zeros)
        return jax.lax.psum(outs, "stage")

    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(P("stage"), P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(stage_params, inputs)
