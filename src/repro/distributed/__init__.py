from .sharding import (param_specs, batch_specs, decode_state_specs_sharded,
                       shard_spec_for_path, data_parallel_mesh,
                       replicate_tree, shard_leading_axis)

__all__ = ["param_specs", "batch_specs", "decode_state_specs_sharded",
           "shard_spec_for_path", "data_parallel_mesh", "replicate_tree",
           "shard_leading_axis"]
