"""Partitioning rules: DP/FSDP x TP x EP x SP on the (pod, data, model) mesh.

Strategy (DESIGN §6):
 - TP ("model" axis): attention head projections, MLP hidden dim, the vocab
   dim of embeddings/heads, and the expert axis of MoE stacks (EP == TP
   axis: experts live where their weights live).
 - FSDP (the "data"/"pod" axes): every parameter additionally shards its
   largest remaining dim over the data axes — ZeRO-3 semantics; GSPMD
   inserts the per-layer all-gathers inside the scan (and the roofline's
   collective term prices them).
 - Batch dims of inputs shard over (pod, data).  SP: decode caches with
   global_batch < data-parallel size shard the *sequence* axis instead
   (long_500k), giving flash-decode-style distributed attention.

Rules are path-keyed (regex on the flattened param path), robust to the
leading stacked-layer axis.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import dp_axes, batch_axes

__all__ = ["shard_spec_for_path", "param_specs", "batch_specs",
           "decode_state_specs_sharded", "logical_shard", "ambient_mesh",
           "data_parallel_mesh", "replicate_tree", "shard_leading_axis"]


def data_parallel_mesh(n_devices: int | None = None):
    """A 1-D ("data",) mesh over the local devices — the mapper trainer's
    mesh (DESIGN §10).  Unlike the (data, model) production mesh this always
    builds, even on a single-device CPU host, so the sharded train step is
    exercised by every smoke test."""
    import jax
    devs = jax.devices()[: n_devices or len(jax.devices())]
    return jax.sharding.Mesh(np.asarray(devs), ("data",))


def replicate_tree(tree, mesh):
    """Commit every leaf of ``tree`` fully replicated over ``mesh``.

    The serving replicas' parameter placement (DESIGN §14): one copy of
    the checkpointed params per device, so a tick sharded over the mesh
    finds its weights locally on every replica."""
    sh = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


def shard_leading_axis(tree, mesh, *, axis: str = "data"):
    """Commit every leaf of ``tree`` sharded over ``mesh`` along its
    leading axis (trailing dims replicated).

    This is how a formed serving tick fans out over engine replicas: the
    per-row condition arrays (stacked workloads, batches, budgets, hw
    rows) all carry the request-lane axis first, and the fused episode is
    an independent vmap over that axis, so GSPMD partitions it with zero
    cross-device communication — each replica rolls out its slice of the
    tick bit-identically to a single-device call.  Every leaf's leading
    dim must divide the mesh size (the engine pads ticks to guarantee
    it)."""
    n = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    sh = NamedSharding(mesh, P(axis))

    def put(x):
        x = np.asarray(x) if not isinstance(x, jax.Array) else x
        if x.ndim == 0 or x.shape[0] % n:
            raise ValueError(
                f"cannot shard leading axis of shape {getattr(x, 'shape', ())}"
                f" over {n} replicas; pad the tick to a multiple of {n}")
        return jax.device_put(x, sh)
    return jax.tree_util.tree_map(put, tree)


def ambient_mesh():
    """The mesh currently in context (abstract or physical), or None.

    ``get_abstract_mesh`` has moved between jax releases
    (``jax.sharding`` <-> ``jax._src.mesh``); older versions only expose the
    physical mesh entered via ``with mesh:`` through ``thread_resources``.
    Model code must stay mesh-agnostic either way, so every probe degrades
    to None instead of raising."""
    try:
        from jax._src import mesh as mesh_impl
    except ImportError:
        mesh_impl = None
    get_am = getattr(jax.sharding, "get_abstract_mesh",
                     getattr(mesh_impl, "get_abstract_mesh", None))
    if get_am is not None:
        try:
            am = get_am()
        except Exception:
            am = None
        if am is not None and getattr(am, "axis_names", ()) \
                and not getattr(am, "empty", False):
            return am
    tr = getattr(mesh_impl, "thread_resources", None)
    pm = getattr(getattr(tr, "env", None), "physical_mesh", None)
    if pm is not None and getattr(pm, "axis_names", ()) \
            and not getattr(pm, "empty", True):
        return pm
    return None


def logical_shard(x, *dims):
    """In-model sharding constraint with logical dim names.

    ``dims`` entries: "batch" (shard over the data-parallel axes), "model"
    (TP axis), "seq" (shard over 'data' — SP), or None.  A no-op when no
    mesh is in context (CPU smoke tests) or when the dim doesn't divide —
    so model code stays mesh-agnostic.  This is how we pin the layouts
    GSPMD otherwise gets wrong (e.g. vocab-dim of the logits: without the
    constraint it all-gathers a 262k-vocab f32 logits tensor per device).
    """
    am = ambient_mesh()
    if am is None or "model" not in am.axis_names:
        return x
    dp = tuple(a for a in am.axis_names if a != "model")
    dp_size = int(np.prod([am.shape[a] for a in dp]))
    spec = []
    for i, d in enumerate(dims):
        if d == "batch" and x.shape[i] % dp_size == 0:
            spec.append(dp if len(dp) > 1 else dp[0])
        elif d == "model" and x.shape[i] % am.shape["model"] == 0:
            spec.append("model")
        elif d == "seq" and x.shape[i] % am.shape["data"] == 0:
            spec.append("data")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _path_str(path) -> str:
    parts = []
    for k in path:
        name = getattr(k, "key", None)
        parts.append(str(name if name is not None else getattr(k, "idx", k)))
    return "/".join(parts)


# (regex, (tp_dim_from_end, fsdp_dim_from_end)) — dims counted from the END
# of the shape so the rules are indifferent to the stacked-layer axis.
# tp None => no TP; fsdp None => no FSDP shard.
_RULES: list[tuple[str, tuple[int | None, int | None]]] = [
    (r"(^|/)embed/emb$",              (-2, -1)),   # [V, d]: V->model, d->data
    (r"(^|/)(tok|pos)/emb$",          (-2, -1)),
    (r"(^|/)head/w$",                 (-1, -2)),   # [d, V]: V->model
    (r"(^|/)(attn|xattn)/(q|k|v)/w$", (-1, -2)),   # [d, Hh]: heads->model
    (r"(^|/)(attn|xattn)/(q|k|v)/b$", (-1, None)),
    (r"(^|/)(attn|xattn)/o/w$",       (-2, -1)),   # [Hh, d]
    (r"(^|/)mlp/(gate|up)/w$",        (-1, -2)),   # [d, f]
    (r"(^|/)mlp/(gate|up)/b$",        (-1, None)),
    (r"(^|/)mlp/down/w$",             (-2, -1)),   # [f, d]
    # [E, d, f]: EP (E->model) when E divides tp; else expert-TP (f->model)
    (r"(^|/)moe/(gate|up)$",          (-3, -1)),
    (r"(^|/)moe/down$",               (-3, -1)),
    (r"(^|/)moe_tp/(gate|up)$",       (-1, -2)),   # rewritten rule target
    (r"(^|/)moe_tp/down$",            (-2, -1)),
    (r"(^|/)moe/router/w$",           (None, None)),
    # rwkv time/channel mix
    (r"(^|/)(r|k|v|g|cr|ck)/w$",      (-1, -2)),
    (r"(^|/)(o|cv)/w$",               (-2, -1)),
    (r"(^|/)(w1|w2)/w$",              (None, -1)),
    # hymba ssm: small per-channel params, replicate
    (r"(^|/)ssm/",                    (None, None)),
]


# Paths whose TP shard is only legal when the HEAD COUNT (not the packed
# feature dim!) divides the TP size: sharding [d, H*hd] when H < tp would
# split head_dim and turn every attention contraction into an all-reduce
# (we measured 250 GB/device of score all-reduces on gemma3 before this
# gate).  When heads don't divide, the projection is replicated across
# 'model' (Megatron GQA practice) and FSDP still shards its storage.
_Q_PATHS = re.compile(r"(^|/)(attn|xattn)/(q/w|q/b|o/w)$")
_KV_PATHS = re.compile(r"(^|/)(attn|xattn)/(k|v)/(w|b)$")
_RWKV_HEAD_PATHS = re.compile(r"(^|/)(r|k|v|g|o)/w$")


def shard_spec_for_path(path_str: str, shape: tuple[int, ...], mesh,
                        cfg=None) -> P:
    """PartitionSpec for one param leaf (divisibility-checked)."""
    fsdp = dp_axes(mesh)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp]))
    tp_size = mesh.shape["model"]
    ndim = len(shape)
    spec = [None] * ndim

    tp_vetoed = False
    if cfg is not None:
        if _Q_PATHS.search(path_str) and cfg.n_heads % tp_size:
            tp_vetoed = True
        if _KV_PATHS.search(path_str) and "attn" in path_str \
                and cfg.kv_heads % tp_size:
            tp_vetoed = True
        if cfg.family == "ssm" and _RWKV_HEAD_PATHS.search(path_str) \
                and cfg.n_heads % tp_size:
            tp_vetoed = True
        # grok-style MoE (E=8 < tp=16): fall back to Megatron expert-TP —
        # shard each expert's hidden dim instead of the expert axis.
        if "/moe/" in path_str and cfg.n_experts % tp_size:
            path_str = path_str.replace("/moe/", "/moe_tp/")

    for pat, (tp_d, fs_d) in _RULES:
        if re.search(pat, path_str):
            if tp_d is not None and -tp_d <= ndim \
                    and shape[tp_d] % tp_size == 0 and not tp_vetoed:
                spec[ndim + tp_d] = "model"
            if fs_d is not None and -fs_d <= ndim \
                    and spec[ndim + fs_d] is None \
                    and shape[fs_d] % fsdp_size == 0:
                spec[ndim + fs_d] = fsdp if len(fsdp) > 1 else fsdp[0]
            return P(*spec)
    return P()      # norms, scalars, unmatched -> replicated


def param_specs(params, mesh, cfg=None):
    """Pytree of PartitionSpecs matching ``params`` (arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [shard_spec_for_path(_path_str(p), v.shape, mesh, cfg)
             for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch, mesh, *, shard_seq: bool = False):
    """Specs for a model-input batch: leading batch dim over (pod, data);
    if ``shard_seq`` (long-context, batch < dp size), shard dim 1 (seq)."""
    ba = batch_axes(mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(x):
        if x.ndim == 0:
            return P()
        if shard_seq and x.ndim >= 2 and x.shape[0] == 1 \
                and x.shape[1] % dp_size == 0:
            return P(None, ba, *([None] * (x.ndim - 2)))
        if x.shape[0] % dp_size:
            return P()                     # batch-1 decode: replicate
        return P(ba, *([None] * (x.ndim - 1)))
    return jax.tree_util.tree_map(spec, batch)


def decode_state_specs_sharded(state_specs, mesh, *, shard_seq: bool = False):
    """Specs for stacked decode caches [L, B, T, kvh, hd].

    Normal decode: batch over (pod, data) AND the cache sequence axis over
    'model' — distributed flash-decode (GSPMD inserts the tiny cross-shard
    softmax reductions; kv-head counts are < TP size for every GQA arch, so
    the head axis cannot carry the shard).  Without the seq shard a grok-1
    32k cache is 69 GB/device.  SP mode (``shard_seq``, long-context
    batch=1): the sequence axis shards over 'data' as well.
    """
    ba = batch_axes(mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tp = mesh.shape["model"]

    def spec(path, x):
        name = str(getattr(path[-1], "key", "")) if path else ""
        if x.ndim <= 1:
            return P()
        if name == "memory":                # whisper enc memory [B, T, d]
            return P(ba if x.shape[0] % dp_size == 0 else None, None, None)
        if x.ndim == 2:                     # [L, B]-style
            return (P(None, ba) if not shard_seq
                    and x.shape[1] % dp_size == 0 else P())
        if shard_seq:
            # [L, B=1, T, ...]: shard T over data+model; small states repl.
            if x.ndim >= 3 and x.shape[1] == 1 and x.shape[2] % \
                    (mesh.shape["data"] * tp) == 0:
                return P(None, None, ("data", "model"),
                         *([None] * (x.ndim - 3)))
            return P()
        b = ba if x.shape[1] % dp_size == 0 else None
        seq = "model" if x.ndim >= 5 and x.shape[2] % tp == 0 else None
        return P(None, b, seq, *([None] * (x.ndim - 3)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_specs)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, v) for p, v in flat])
