from .pipeline import SyntheticLM, Prefetcher, make_batch_iterator

__all__ = ["SyntheticLM", "Prefetcher", "make_batch_iterator"]
