"""Deterministic, resumable, sharded synthetic-LM data pipeline.

Design for restartability (DESIGN §6 fault tolerance): batches are a pure
function of (seed, step, shard) — a Philox-style counter stream — so a
resumed job at step N reproduces the exact global batch without persisted
iterator state, and elastic re-sharding just changes the shard grid.  The
token stream is Zipf-ish with short-range structure so losses actually
decrease (useful for the e2e example), not uniform noise.

``Prefetcher`` overlaps host batch synthesis with device compute (the
classic input-pipeline/compute overlap trick) with a bounded queue.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["SyntheticLM", "Prefetcher", "make_batch_iterator"]


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int | None = None    # for embed-input (stub frontend) archs
    dec_len: int | None = None      # for enc-dec archs

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The full global batch for ``step`` (pure function of inputs)."""
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # zipf-ish marginals + markov-ish structure: next token depends on
        # previous via a fixed random permutation half the time
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64) % V
        perm = np.random.default_rng(self.seed).permutation(V)
        shifted = perm[np.roll(base, 1, axis=1) % V]
        use_prev = rng.random((B, S)) < 0.5
        toks = np.where(use_prev, shifted, base).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        out = {"tokens": toks, "labels": labels}
        if self.embed_dim is not None:
            out["embeds"] = rng.standard_normal(
                (B, S, self.embed_dim)).astype(np.float32) * 0.02
        if self.dec_len is not None:
            dt = toks[:, : self.dec_len]
            out["tokens"] = dt
            out["labels"] = np.roll(dt, -1, axis=1)
        return out

    def shard_at(self, step: int, shard: int, n_shards: int) -> dict:
        """This host-shard's slice of the global batch (per-host loading)."""
        full = self.batch_at(step)
        B = self.global_batch
        lo, hi = B * shard // n_shards, B * (shard + 1) // n_shards
        return {k: v[lo:hi] for k, v in full.items()}


class Prefetcher:
    """Bounded-queue background prefetch of host batches."""

    def __init__(self, fn, start_step: int = 0, depth: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def make_batch_iterator(source: SyntheticLM, start_step: int = 0,
                        prefetch: int = 2):
    """Iterator of (step, global_batch) with background prefetch."""
    pf = Prefetcher(source.batch_at, start_step=start_step, depth=prefetch)
    try:
        while True:
            yield pf.next()
    finally:
        pf.close()
