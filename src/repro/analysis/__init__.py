"""Contract linter: AST static analysis for the repo's own invariants.

DESIGN §18.  The repo's reproduction claims rest on contracts that used to
live only in prose — §14 bit-exact determinism, the §11/§13 "hardware is
traced data" rule (the PR 5 silent-wrong-BPE bug class), seeded-RNG
discipline everywhere corpora are generated.  This package turns each one
into a machine-checked rule that fails CI at the diff, not at the
benchmark::

    python -m repro.analysis --check --baseline ANALYSIS_baseline.json

Public surface: :func:`run_analysis` (one call: rule registry + file walk
+ suppressions), the :data:`RULES` registry, and the finding/baseline
primitives.  Pure stdlib — importing it never pulls jax/numpy, so the CI
analysis job is dependency-free.
"""
from __future__ import annotations

import pathlib

from .findings import (Finding, Severity, apply_baseline, baseline_index,
                       load_baseline, parse_suppressions, write_baseline)
from .framework import (AnalysisResult, Analyzer, FileContext, Rule, RULES,
                        default_files, iter_jit_sites, register)
from . import rules as _rules  # registers every rule family  # noqa: F401

__all__ = ["Finding", "Severity", "Rule", "RULES", "Analyzer",
           "AnalysisResult", "FileContext", "run_analysis", "default_files",
           "iter_jit_sites", "register", "load_baseline", "baseline_index",
           "apply_baseline", "write_baseline", "parse_suppressions"]


def run_analysis(root: str | pathlib.Path, files=None,
                 rules: dict | None = None) -> AnalysisResult:
    """Run every registered rule (or ``rules``) over ``files`` under
    ``root`` (default: ``src/**``, ``benchmarks/*``, ``examples/*``)."""
    return Analyzer(rules).run(root, files)
