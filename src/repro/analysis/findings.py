"""Finding, suppression, and baseline primitives for the contract linter.

DESIGN §18: a finding is identified across revisions by its *fingerprint*
``(rule, path, stripped source line)`` rather than a line number, so the
committed ``ANALYSIS_baseline.json`` survives unrelated edits above the
flagged line.  Suppressions are in-source::

    expr_that_violates()  # repro: noqa[RNG001] -- one-line justification

The justification text is mandatory (a bare noqa does not suppress and is
itself reported as ANA002); a noqa that suppresses nothing is reported as
ANA001 so dead suppressions cannot accumulate.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re


class Severity:
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # e.g. "RNG001"
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based
    message: str
    severity: str = Severity.ERROR
    source: str = ""   # stripped text of the flagged line (fingerprint basis)

    @property
    def fingerprint(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.source)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} [{self.severity}] {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[([A-Za-z0-9_\s,]+)\]\s*(?:--\s*(\S.*))?")
RULE_ID_RE = re.compile(r"^[A-Z]{2,5}\d{3}$")


@dataclasses.dataclass
class Suppression:
    line: int                  # 1-based line the noqa comment sits on
    rules: frozenset           # rule ids it names
    justification: str         # mandatory; "" means invalid
    used: set = dataclasses.field(default_factory=set)


def parse_suppressions(source: str) -> dict[int, Suppression]:
    """Map 1-based line number -> Suppression for every noqa comment.

    Tokenizes so only real ``#`` comments count — a noqa *example* inside a
    docstring or string literal is not a suppression.  Falls back to a
    line scan when the file does not tokenize (the AST rules are skipped
    for such files anyway).
    """
    import io
    import tokenize

    comments: list[tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                comments.append((tok.start[0], tok.string))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        comments = list(enumerate(source.splitlines(), start=1))
    out: dict[int, Suppression] = {}
    for i, text in comments:
        m = NOQA_RE.search(text)
        if not m:
            continue
        rules = frozenset(r.strip() for r in m.group(1).split(",") if r.strip())
        out[i] = Suppression(i, rules, (m.group(2) or "").strip())
    return out


# ---------------------------------------------------------------- baseline

BASELINE_VERSION = 1


def load_baseline(path: str | pathlib.Path) -> list[dict]:
    """Read a baseline file; every entry must carry a justification."""
    payload = json.loads(pathlib.Path(path).read_text())
    if payload.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{payload.get('version')!r}")
    entries = payload.get("entries", [])
    for e in entries:
        for k in ("rule", "path", "fingerprint", "justification"):
            if not str(e.get(k, "")).strip():
                raise ValueError(
                    f"baseline entry {e!r} is missing a non-empty {k!r} "
                    "(justifications are mandatory, DESIGN §18)")
    return entries


def baseline_index(entries: list[dict]) -> set[tuple[str, str, str]]:
    return {(e["rule"], e["path"], e["fingerprint"]) for e in entries}


def apply_baseline(findings: list[Finding], entries: list[dict]
                   ) -> tuple[list[Finding], list[dict]]:
    """Split findings into (new, ...) and report stale baseline entries.

    Returns ``(new_findings, stale_entries)``: a finding is absorbed when
    its fingerprint matches a baseline entry; an entry matching no current
    finding is *stale* and must be pruned (keeps the baseline honest).
    """
    idx = baseline_index(entries)
    new = [f for f in findings if f.fingerprint not in idx]
    live = {f.fingerprint for f in findings}
    stale = [e for e in entries
             if (e["rule"], e["path"], e["fingerprint"]) not in live]
    return new, stale


def write_baseline(path: str | pathlib.Path, findings: list[Finding],
                   old_entries: list[dict] | None = None) -> list[dict]:
    """Write current findings as the new baseline, preserving existing
    justifications by fingerprint; new entries get a placeholder that a
    human must replace before review."""
    just = {(e["rule"], e["path"], e["fingerprint"]): e["justification"]
            for e in (old_entries or [])}
    entries = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if f.fingerprint in seen:
            continue
        seen.add(f.fingerprint)
        entries.append({
            "rule": f.rule, "path": f.path, "fingerprint": f.source,
            "justification": just.get(
                f.fingerprint, "GRANDFATHERED: justify before extending"),
        })
    payload = {"version": BASELINE_VERSION, "entries": entries}
    pathlib.Path(path).write_text(json.dumps(payload, indent=1) + "\n")
    return entries
