"""Traced-condition rules (DESIGN §18, JIT family).

Contract (DESIGN §11/§13): the serving conditions — hardware, workload —
are *traced data*, never static jit kwargs.  The pre-§13 Pallas kernel
took ``hw`` as a static kwarg: one recompile per accelerator AND a
silently-wrong result for any BPE-mismatched accelerator (the PR 5 bug).
JIT001 makes that bug class a diff-time failure; JIT002 sweeps the dead
``static_argnames=()``-style kwargs that camouflage real ones.
"""
from __future__ import annotations

import re

from ..framework import FileContext, Rule, iter_jit_sites, register

# a static argname is hw/accel/workload-like when any _-token matches
_CONDITION_TOKENS = {"hw", "hwvec", "accel", "accelerator", "workload",
                     "wl", "wls", "net", "network", "arch"}
_SPLIT = re.compile(r"[_\d]+")


def _is_condition_name(name: str) -> bool:
    return any(tok in _CONDITION_TOKENS
               for tok in _SPLIT.split(name.lower()) if tok)


@register
class StaticCondition(Rule):
    id = "JIT001"
    severity = "error"
    description = ("hardware/workload-like parameter marked static at a "
                   "jax.jit/pjit site — conditions must be traced data")
    contract = "DESIGN §11/§13 traced-condition rule (the PR 5 bug class)"

    def check_file(self, ctx: FileContext):
        for site in iter_jit_sites(ctx.tree):
            names = set(site.static_names)
            params = site.param_names()
            for i in site.static_nums:
                if 0 <= i < len(params):
                    names.add(params[i])
            for name in sorted(names):
                if _is_condition_name(name):
                    yield self.finding(ctx,
                        site.call, f"static argument {name!r} looks like a "
                        "hardware/workload condition; marking it static "
                        "recompiles per condition and (as in the pre-§13 "
                        "kernel) can skip traced rescales — pass it as "
                        "traced data")


@register
class DeadJitKwarg(Rule):
    id = "JIT002"
    severity = "warning"
    description = ("empty static/donate kwarg at a jit site "
                   "(e.g. static_argnames=()) — dead code, delete it")
    contract = "jit sites state exactly the static set they mean"

    def check_file(self, ctx: FileContext):
        for site in iter_jit_sites(ctx.tree):
            for kwarg in site.empty_kwargs:
                yield self.finding(ctx,
                    site.call, f"{kwarg}=() is a no-op at this jit site; "
                    "delete it (an empty static set is the default)")
