"""RNG-discipline rules (DESIGN §18, RNG family).

Contract (DESIGN §10/§14): everything that feeds a teacher corpus, a
training run, or a serving decision is seeded — ``np.random.default_rng``
with an explicit seed expression, or ``jax.random`` keys derived from one.
Ambient module-level NumPy RNG (``np.random.rand`` & co.) and wall-clock
seeds break the bit-exact corpus/replay contracts silently.
"""
from __future__ import annotations

import ast

from ..framework import FileContext, Rule, dotted_name, register

# attribute access on np.random that does NOT touch the ambient global RNG
_AMBIENT_OK = {"default_rng", "Generator", "BitGenerator", "SeedSequence",
               "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937"}

_TIME_CALLS = {"time.time", "time.time_ns", "time.perf_counter",
               "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
               "time.process_time", "datetime.now", "datetime.utcnow",
               "datetime.datetime.now", "datetime.datetime.utcnow",
               "os.urandom", "uuid.uuid1", "uuid.uuid4", "random.random",
               "random.randint"}

_SEEDING_CALLEES = {"default_rng", "PRNGKey", "key", "SeedSequence"}


def _np_random_attr(func: ast.AST) -> str | None:
    """Return ``fn`` when ``func`` is ``np.random.fn``/``numpy.random.fn``."""
    if isinstance(func, ast.Attribute) \
            and isinstance(func.value, ast.Attribute) \
            and func.value.attr == "random" \
            and isinstance(func.value.value, ast.Name) \
            and func.value.value.id in ("np", "numpy"):
        return func.attr
    return None


def _contains_time_call(node: ast.AST) -> ast.Call | None:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and dotted_name(sub.func) in _TIME_CALLS:
            return sub
    return None


@register
class AmbientNumpyRng(Rule):
    id = "RNG001"
    severity = "error"
    description = ("module-level numpy RNG call (np.random.<fn>) — use an "
                   "explicitly seeded np.random.default_rng(seed) Generator")
    contract = "seeded-RNG discipline for corpora, training and serving"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                fn = _np_random_attr(node.func)
                if fn is not None and fn not in _AMBIENT_OK:
                    yield self.finding(ctx,
                        node, f"ambient np.random.{fn}() draws from the "
                        "process-global RNG; thread a seeded "
                        "np.random.default_rng(seed) Generator instead")


@register
class UnseededDefaultRng(Rule):
    id = "RNG002"
    severity = "error"
    description = ("np.random.default_rng() with no seed argument draws OS "
                   "entropy — corpus/serving runs become unreproducible")
    contract = "seeded-RNG discipline for corpora, training and serving"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func).endswith("default_rng") \
                    and not node.args and not node.keywords:
                yield self.finding(ctx,
                    node, "default_rng() without an explicit seed expression "
                    "is nondeterministic; pass a seed")


@register
class TimeDerivedSeed(Rule):
    id = "RNG003"
    severity = "error"
    description = ("seed expression derived from wall clock / OS entropy "
                   "(time.*, datetime.now, os.urandom, uuid)")
    contract = "seeded-RNG discipline for corpora, training and serving"

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func).rsplit(".", 1)[-1]
            seed_exprs = []
            if callee in _SEEDING_CALLEES:
                seed_exprs += node.args
            seed_exprs += [kw.value for kw in node.keywords
                           if kw.arg == "seed"]
            for expr in seed_exprs:
                bad = _contains_time_call(expr)
                if bad is not None:
                    yield self.finding(ctx,
                        node, f"seed derived from {dotted_name(bad.func)}() "
                        "is nondeterministic; seeds must be explicit "
                        "constants or derived from config")
                    break
