"""Determinism-hazard rules (DESIGN §18, DET family).

Contract (DESIGN §10/§14): corpus generation and the strategy cache are
bit-reproducible — iteration order feeding either must be an explicit
total order, and the production evaluators are f32 end-to-end (float64 is
reserved for the certified oracles, which must say so with a noqa).
"""
from __future__ import annotations

import ast

from ..framework import FileContext, Rule, dotted_name, register

# modules whose dict-iteration order feeds corpus rows / serialized cache
_ORDER_SENSITIVE = {"src/repro/core/dataset.py", "src/repro/serving/cache.py"}

_DICT_VIEWS = {"items", "keys", "values"}


def _iter_nodes(tree):
    """(iter_expr, owner) for every for-loop and comprehension generator."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter, node
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter, node


@register
class SetIteration(Rule):
    id = "DET001"
    severity = "error"
    description = ("iteration over a set/frozenset — unordered; sort (or "
                   "use a dict) before iterating")
    contract = "DESIGN §10/§14 bit-reproducible corpus and cache"

    def check_file(self, ctx: FileContext):
        for it, _ in _iter_nodes(ctx.tree):
            is_set = isinstance(it, (ast.Set, ast.SetComp)) or (
                isinstance(it, ast.Call)
                and dotted_name(it.func) in ("set", "frozenset"))
            if is_set:
                yield self.finding(ctx,
                    it, "iterating a set has no guaranteed order; wrap in "
                    "sorted() so downstream bytes are reproducible")


@register
class UnsortedDictIteration(Rule):
    id = "DET002"
    severity = "warning"
    description = ("unsorted dict-view iteration in an order-sensitive "
                   "module (corpus / serialized-cache construction)")
    contract = "DESIGN §10/§14 bit-reproducible corpus and cache"

    def applies_to(self, rel: str) -> bool:
        return rel in _ORDER_SENSITIVE

    def check_file(self, ctx: FileContext):
        for it, _ in _iter_nodes(ctx.tree):
            if isinstance(it, ast.Call) \
                    and isinstance(it.func, ast.Attribute) \
                    and it.func.attr in _DICT_VIEWS and not it.args:
                yield self.finding(ctx,
                    it, f".{it.func.attr}() iteration order here is "
                    "insertion order, which can depend on arrival history; "
                    "wrap in sorted() or suppress with the reason order "
                    "never reaches persisted bytes")


@register
class Float64InCore(Rule):
    id = "DET003"
    severity = "warning"
    description = ("explicit float64 in src/repro/core — the production "
                   "evaluators are f32; f64 is reserved for the oracles")
    contract = "DESIGN §13/§16 f32 evaluator vs f64 oracle split"

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("src/repro/core/")

    def check_file(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            is_f64 = (isinstance(node, ast.Attribute)
                      and node.attr == "float64") or (
                isinstance(node, ast.Constant) and node.value == "float64")
            if is_f64:
                yield self.finding(ctx,
                    node, "float64 in core diverges from the f32 serving "
                    "evaluators (and silently downcasts under JAX x32); "
                    "only the certified oracles may use it, with a noqa "
                    "stating so")
