"""Doc-contract rules (DESIGN §18, DOC family).

Contract: DESIGN.md's ``## §N`` anchors are append-only and contiguous
(docstrings across the repo cite them), and README.md only names files,
benchmark scripts, and committed BENCH baselines that exist.  These rules
are the single implementation behind ``tests/test_docs.py``, which now
just asserts the analyzer reports zero DOC findings.

The rules no-op when DESIGN.md/README.md are absent (fixture trees);
their presence in THIS repo is pinned by tests/test_docs.py.
"""
from __future__ import annotations

import re

from ..findings import Finding
from ..framework import FileContext, Rule, register

SECTION_RE = re.compile(r"^##\s*§(\d+)\b")
CITE_RE = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")
LINK_RE = re.compile(r"\]\(([^)#\s]+)\)")
BENCH_SCRIPT_RE = re.compile(r"benchmarks/([\w.]+\.py)")
BASELINE_RE = re.compile(r"\bBENCH_\w+\.json\b")

# README completeness floor: the paper-claims scripts it must keep naming
REQUIRED_CLAIM_SCRIPTS = ("table1_methods.py", "table2_generalization.py",
                          "table3_transfer.py", "fig4_solutions.py",
                          "speed_oneshot.py", "table_hw_generalization.py")


def _design_sections(root) -> set[int] | None:
    p = root / "DESIGN.md"
    if not p.is_file():
        return None
    return {int(m.group(1)) for line in p.read_text().splitlines()
            if (m := SECTION_RE.match(line))}


def _md_finding(rule: Rule, rel: str, line_no: int, text: str,
                message: str) -> Finding:
    # repo-level findings may have no source line; fingerprint off the
    # message then, so they stay baselinable (fingerprints must be non-empty)
    return Finding(rule.id, rel, line_no, 0, message, rule.severity,
                   text.strip() or message)


@register
class DesignNumbering(Rule):
    id = "DOC001"
    severity = "error"
    description = ("DESIGN.md ## §N headings must be contiguous from §1 "
                   "(the numbering is append-only and load-bearing)")
    contract = "DESIGN §-anchors are append-only"

    def check_repo(self, root, ctxs):
        secs = _design_sections(root)
        if secs is None:
            return
        if not secs:
            yield _md_finding(self, "DESIGN.md", 1, "",
                              "DESIGN.md has no '## §N' headings")
            return
        expected = set(range(1, max(secs) + 1))
        if secs != expected:
            yield _md_finding(
                self, "DESIGN.md", 1, "",
                f"§-numbering must be contiguous from 1, got {sorted(secs)} "
                f"(missing {sorted(expected - secs)})")


@register
class DesignCiteResolves(Rule):
    id = "DOC002"
    severity = "error"
    description = "every `DESIGN §N` citation resolves to a real heading"
    contract = "DESIGN §-anchors are append-only"

    def check_file(self, ctx: FileContext):
        secs = _design_sections(ctx.root)
        if secs is None:
            return
        for i, line in enumerate(ctx.lines, start=1):
            for m in CITE_RE.finditer(line):
                n = int(m.group(1))
                if n not in secs:
                    yield self.finding(
                        ctx, i, f"cites DESIGN §{n} but DESIGN.md only has "
                        f"§1..§{max(secs)}")


@register
class ReadmeIntegrity(Rule):
    id = "DOC003"
    severity = "error"
    description = ("every local file, benchmarks/*.py script and "
                   "BENCH_*.json baseline README.md names must exist")
    contract = "README names only committed artifacts"

    def check_repo(self, root, ctxs):
        p = root / "README.md"
        if not p.is_file():
            return
        for i, line in enumerate(p.read_text().splitlines(), start=1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://")):
                    continue
                if not (root / target).exists():
                    yield _md_finding(self, "README.md", i, line,
                                      f"links missing file {target}")
            for m in BENCH_SCRIPT_RE.finditer(line):
                if not (root / "benchmarks" / m.group(1)).exists():
                    yield _md_finding(
                        self, "README.md", i, line,
                        f"names benchmarks/{m.group(1)} which does not exist")
            for m in BASELINE_RE.finditer(line):
                if not (root / m.group(0)).exists():
                    yield _md_finding(
                        self, "README.md", i, line,
                        f"cites {m.group(0)} which is not committed")


@register
class ReadmeCompleteness(Rule):
    id = "DOC004"
    severity = "error"
    description = ("README keeps the paper-claims scripts, the tier-1 "
                   "pytest command and the benchmarks.run driver visible")
    contract = "README is the reproduction's front door"

    def check_repo(self, root, ctxs):
        p = root / "README.md"
        if not p.is_file():
            return
        text = p.read_text()
        named = set(BENCH_SCRIPT_RE.findall(text))
        for required in REQUIRED_CLAIM_SCRIPTS:
            if required not in named:
                yield _md_finding(self, "README.md", 1, "",
                                  f"must reference benchmarks/{required}")
        if "python -m pytest" not in text:
            yield _md_finding(self, "README.md", 1, "",
                              "must give the tier-1 pytest command")
        if "benchmarks.run" not in text:
            yield _md_finding(self, "README.md", 1, "",
                              "must name the benchmarks.run driver")
