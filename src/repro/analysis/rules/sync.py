"""Host-device sync and tracer-leak rules (DESIGN §18, SYNC family).

Contract (DESIGN §9/§12/§14): jitted bodies stay on device — no host
materialization (``np.asarray``/``np.array``/``jax.device_get``), no
scalarization (``.item()``, ``float()/int()/bool()`` of jnp expressions),
and no Python truthiness on traced values; the serving hot path
(``src/repro/serving/``) additionally treats ``.item()`` as a hidden
per-request device sync even outside jit.
"""
from __future__ import annotations

import ast

from ..framework import (FileContext, Rule, dotted_name, iter_jit_sites,
                         register)

_HOST_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get", "np.frombuffer"}


def _jitted_scopes(tree):
    """(scope_node, traced_param_names) for every visible jitted body."""
    for site in iter_jit_sites(tree):
        if site.target is not None:
            yield site.target, site.traced_params()


def _is_serving(rel: str) -> bool:
    return rel.startswith("src/repro/serving/")


def _jnp_rooted(node: ast.AST) -> bool:
    """True when the expression is a call/attr rooted at jnp/jax.numpy."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id == "jnp":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "numpy" \
                and isinstance(sub.value, ast.Name) and sub.value.id == "jax":
            return True
    return False


def _names_outside_is_none(node: ast.AST) -> set:
    """Name ids referenced by ``node``, excluding operands of ``is None`` /
    ``is not None`` comparisons (structural checks are trace-safe)."""
    skip: set = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot)) for op in sub.ops) \
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in sub.comparators):
            for operand in [sub.left] + sub.comparators:
                for n in ast.walk(operand):
                    skip.add(id(n))
    return {sub.id for sub in ast.walk(node)
            if isinstance(sub, ast.Name) and id(sub) not in skip}


@register
class ItemSync(Rule):
    id = "SYNC001"
    severity = "error"
    description = (".item() in a jitted body (trace error) or in the "
                   "serving hot path (hidden per-request device sync)")
    contract = "DESIGN §9/§14 device-resident hot path"

    def check_file(self, ctx: FileContext):
        scopes = [s for s, _ in _jitted_scopes(ctx.tree)]
        seen: set = set()

        def _scan(root, where):
            for node in ast.walk(root):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args \
                        and id(node) not in seen:
                    seen.add(id(node))
                    yield self.finding(ctx,
                        node, f".item() {where}; keep scalars on device "
                        "(or sync once at the episode boundary)")

        for scope in scopes:
            yield from _scan(scope, "inside a jitted body")
        if _is_serving(ctx.rel):
            yield from _scan(ctx.tree, "in the serving hot path")


@register
class HostMaterialize(Rule):
    id = "SYNC002"
    severity = "error"
    description = ("np.asarray/np.array/jax.device_get inside a jitted "
                   "body — host materialization breaks tracing")
    contract = "DESIGN §9/§14 device-resident hot path"

    def check_file(self, ctx: FileContext):
        for scope, _ in _jitted_scopes(ctx.tree):
            for node in ast.walk(scope):
                if isinstance(node, ast.Call) \
                        and dotted_name(node.func) in _HOST_CALLS:
                    yield self.finding(ctx,
                        node, f"{dotted_name(node.func)}() inside a jitted "
                        "body materializes on host; use jnp.asarray / keep "
                        "the value traced")


@register
class TracerTruthiness(Rule):
    id = "SYNC003"
    severity = "error"
    description = ("if/while/assert condition on a traced (non-static) "
                   "parameter inside a jitted body")
    contract = "DESIGN §9 traced control flow goes through lax.cond/where"

    def check_file(self, ctx: FileContext):
        for scope, traced in _jitted_scopes(ctx.tree):
            if not traced:
                continue
            tests = []
            for node in ast.walk(scope):
                if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    tests.append(node.test)
                elif isinstance(node, ast.Assert):
                    tests.append(node.test)
            for test in tests:
                leaked = _names_outside_is_none(test) & traced
                if leaked:
                    yield self.finding(ctx,
                        test, "Python truthiness on traced parameter(s) "
                        f"{sorted(leaked)} — a tracer in `if` fails at "
                        "trace time (or silently freezes the condition); "
                        "use lax.cond/jnp.where or mark the arg static")


@register
class ScalarizeJnp(Rule):
    id = "SYNC004"
    severity = "warning"
    description = ("float()/int()/bool() wrapping a jnp expression in a "
                   "jitted body or serving hot path — device sync")
    contract = "DESIGN §9/§14 device-resident hot path"

    def check_file(self, ctx: FileContext):
        seen: set = set()

        def _scan(root, where):
            for node in ast.walk(root):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in ("float", "int", "bool") \
                        and len(node.args) == 1 \
                        and _jnp_rooted(node.args[0]) \
                        and id(node) not in seen:
                    seen.add(id(node))
                    yield self.finding(ctx,
                        node, f"{node.func.id}() of a jnp expression "
                        f"{where} forces a host round trip; keep it as a "
                        "device array")

        for scope, _ in _jitted_scopes(ctx.tree):
            yield from _scan(scope, "inside a jitted body")
        if _is_serving(ctx.rel):
            yield from _scan(ctx.tree, "in the serving hot path")
