"""Export-consistency rules (DESIGN §18, EXP family).

Contract (DESIGN §15): the public surface of every ``repro`` package is
its ``__all__``, and lazy (PEP 562) re-exports must stay in lockstep with
it — every ``__all__`` name either binds at module top level or appears in
the ``__getattr__`` lazy table, and every lazy-table name is advertised in
``__all__``.  The PR 4/PR 7 import-cycle fixes rely on this staying true.
"""
from __future__ import annotations

import ast

from ..framework import FileContext, Rule, register


def _top_level_names(tree: ast.Module) -> set:
    names: set = set()
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
                elif isinstance(t, (ast.Tuple, ast.List)):
                    names |= {e.id for e in t.elts
                              if isinstance(e, ast.Name)}
        elif isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name):
            names.add(stmt.target.id)
        elif isinstance(stmt, ast.ImportFrom):
            names |= {a.asname or a.name for a in stmt.names}
        elif isinstance(stmt, ast.Import):
            names |= {(a.asname or a.name).split(".")[0]
                      for a in stmt.names}
    return names


def _const_env(tree: ast.Module) -> dict:
    """Module-level literal assignments (for evaluating computed __all__)."""
    env: dict = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            try:
                env[stmt.targets[0].id] = ast.literal_eval(stmt.value)
            except (ValueError, TypeError, SyntaxError, MemoryError):
                pass
    return env


def _eval_all(node: ast.AST, env: dict):
    """Evaluate an ``__all__`` expression: literals, Name lookups,
    ``sorted(X)`` and ``+`` concatenation.  Returns None if out of reach."""
    try:
        return list(ast.literal_eval(node))
    except (ValueError, TypeError, SyntaxError, MemoryError):
        pass
    if isinstance(node, ast.Name):
        v = env.get(node.id)
        return list(v) if isinstance(v, (list, tuple, dict, set)) else None
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _eval_all(node.left, env)
        right = _eval_all(node.right, env)
        return None if left is None or right is None else left + right
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "sorted" and len(node.args) == 1:
        inner = _eval_all(node.args[0], env)
        return None if inner is None else sorted(inner)
    return None


def _lazy_names(tree: ast.Module, env: dict) -> set | None:
    """Names served by a PEP 562 ``__getattr__``; None when there is no
    ``__getattr__`` (then __all__ must bind eagerly)."""
    getattr_def = next(
        (s for s in tree.body
         if isinstance(s, ast.FunctionDef) and s.name == "__getattr__"),
        None)
    if getattr_def is None:
        return None
    lazy: set = set()
    for node in ast.walk(getattr_def):
        if isinstance(node, ast.Compare) and len(node.ops) == 1 \
                and isinstance(node.ops[0], ast.In):
            table = node.comparators[0]
            if isinstance(table, ast.Name):
                v = env.get(table.id)
                if isinstance(v, dict):
                    lazy |= set(v.keys())
                elif isinstance(v, (list, tuple, set)):
                    lazy |= set(v)
            else:
                try:
                    v = ast.literal_eval(table)
                    lazy |= set(v.keys() if isinstance(v, dict) else v)
                except (ValueError, TypeError, SyntaxError, MemoryError):
                    pass
    return lazy


def _module_facts(ctx: FileContext):
    tree = ctx.tree
    all_assign = next(
        (s for s in tree.body if isinstance(s, ast.Assign)
         and any(isinstance(t, ast.Name) and t.id == "__all__"
                 for t in s.targets)), None)
    if all_assign is None:
        return None
    env = _const_env(tree)
    all_list = _eval_all(all_assign.value, env)
    lazy = _lazy_names(tree, env)
    return all_assign, all_list, _top_level_names(tree), (lazy or set())


class _ExportRule(Rule):
    def applies_to(self, rel: str) -> bool:
        return rel.startswith("src/repro/") and rel.endswith("__init__.py")


@register
class AllResolves(_ExportRule):
    id = "EXP001"
    severity = "error"
    description = ("__all__ name with no top-level binding and no entry in "
                   "the PEP 562 lazy-export table")
    contract = "DESIGN §15 supported public surface"

    def check_file(self, ctx: FileContext):
        facts = _module_facts(ctx)
        if facts is None:
            return
        all_assign, all_list, defined, lazy = facts
        if all_list is None:
            yield self.finding(ctx,
                all_assign, "__all__ is too dynamic for the linter; keep it "
                "a literal (optionally + sorted(<literal table>))")
            return
        for name in all_list:
            if name not in defined and name not in lazy:
                yield self.finding(ctx,
                    all_assign, f"__all__ exports {name!r} but the module "
                    "neither binds it at top level nor lazy-serves it via "
                    "__getattr__")


@register
class LazyAdvertised(_ExportRule):
    id = "EXP002"
    severity = "error"
    description = ("PEP 562 lazy-export table name missing from __all__ "
                   "(hidden public surface)")
    contract = "DESIGN §15 supported public surface"

    def check_file(self, ctx: FileContext):
        facts = _module_facts(ctx)
        if facts is None:
            return
        all_assign, all_list, _, lazy = facts
        if all_list is None:
            return
        for name in sorted(lazy - set(all_list)):
            yield self.finding(ctx,
                all_assign, f"__getattr__ lazily serves {name!r} which is "
                "not advertised in __all__; add it or drop it from the "
                "lazy table")
