"""The contract-linter ruleset (DESIGN §18).

Importing this package registers every rule family into
``repro.analysis.framework.RULES``:

=======  ==================================================================
family   contract it mechanizes
=======  ==================================================================
RNG      seeded-RNG discipline for corpora/training/serving (§10, §14)
JIT      hardware/workload are traced data, never static kwargs (§11, §13)
SYNC     jitted bodies and the serving hot path stay on device (§9, §14)
DET      bit-reproducible corpus/cache; f32-evaluator vs f64-oracle (§16)
DOC      DESIGN §-anchors append-only; README names real artifacts
EXP      __all__ <-> PEP 562 lazy-export lockstep (§15)
ANA      the noqa/baseline mechanism itself stays honest
=======  ==================================================================
"""
from . import det, docs, exports, jit, meta, rng, sync  # noqa: F401 (registration side effect)

from ..framework import RULES

__all__ = ["RULES"]
