"""ANA meta-rules (DESIGN §18): the suppression mechanism polices itself.

These rules are *emitted by the Analyzer* (which is the only place that
knows which suppressions matched); they are registered here so they show
up in ``--list-rules``, are recognized inside ``noqa[...]`` brackets, and
carry their severities in one place.
"""
from __future__ import annotations

from ..findings import Severity
from ..framework import Rule, register


@register
class UnusedSuppression(Rule):
    id = "ANA001"
    severity = Severity.WARNING
    description = ("noqa that suppresses nothing on its line — dead "
                   "suppressions must be deleted, not accumulated")
    contract = "suppressions are scoped and justified (DESIGN §18)"


@register
class BareSuppression(Rule):
    id = "ANA002"
    severity = Severity.ERROR
    description = ("noqa without the mandatory '-- justification' text, or "
                   "naming an unknown rule id; it suppresses nothing")
    contract = "suppressions are scoped and justified (DESIGN §18)"
