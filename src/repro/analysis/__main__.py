"""CLI for the contract linter (DESIGN §18).

Usage::

    python -m repro.analysis                       # report all findings
    python -m repro.analysis --check \\
        --baseline ANALYSIS_baseline.json          # CI gate (exit 1 on new)
    python -m repro.analysis --write-baseline ANALYSIS_baseline.json
    python -m repro.analysis --json out.json path/to/file.py
    python -m repro.analysis --list-rules
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from . import (RULES, apply_baseline, load_baseline, run_analysis,
               write_baseline)


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST contract linter for the repo's determinism, "
                    "traced-condition and recompile contracts (DESIGN §18)")
    ap.add_argument("paths", nargs="*",
                    help="files to analyze, relative to --root (default: "
                         "src/**/*.py benchmarks/*.py examples/*.py)")
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any unbaselined finding (or stale "
                         "baseline entry) remains")
    ap.add_argument("--baseline", metavar="FILE",
                    help="subtract grandfathered findings recorded in FILE")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write current findings to FILE (preserving "
                         "existing justifications by fingerprint) and exit")
    ap.add_argument("--json", metavar="FILE", dest="json_out",
                    help="also dump findings as JSON to FILE")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--quiet", action="store_true",
                    help="only print the summary line")
    return ap


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for rid in sorted(RULES):
            r = RULES[rid]
            print(f"{rid}  [{r.severity:7s}] {r.description}")
        return 0

    root = pathlib.Path(args.root).resolve()
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} does not look like the repo root "
              "(no src/repro); pass --root", file=sys.stderr)
        return 2

    result = run_analysis(root, files=args.paths or None)
    findings = result.findings

    if args.write_baseline:
        p = pathlib.Path(args.write_baseline)
        old = load_baseline(p) if p.is_file() else []
        entries = write_baseline(p, findings, old)
        print(f"wrote {len(entries)} baseline entries to {p}")
        return 0

    stale = []
    if args.baseline:
        entries = load_baseline(args.baseline)
        findings, stale = apply_baseline(findings, entries)

    if args.json_out:
        payload = {
            "root": str(root),
            "files": result.files,
            "findings": [f.to_json() for f in findings],
            "suppressed": [f.to_json() for f in result.suppressed],
            "stale_baseline_entries": stale,
        }
        out = pathlib.Path(args.json_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(payload, indent=1) + "\n")

    if not args.quiet:
        for f in findings:
            print(f.format())
        for e in stale:
            print(f"{e['path']}: stale baseline entry for {e['rule']} "
                  f"({e['fingerprint'][:60]!r}); prune it")
    n_err = sum(f.severity == "error" for f in findings)
    n_warn = len(findings) - n_err
    print(f"{len(result.files)} files, {len(findings)} finding(s) "
          f"({n_err} error, {n_warn} warning), "
          f"{len(result.suppressed)} suppressed, "
          f"{len(stale)} stale baseline entr(y/ies)")
    if args.check and (findings or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
