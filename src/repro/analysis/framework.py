"""Rule framework for the contract linter (DESIGN §18).

A :class:`Rule` is a named, severity-tagged check over either one parsed
file (:meth:`Rule.check_file`) or the repository as a whole
(:meth:`Rule.check_repo`, for cross-file contracts like the DESIGN.md
§-numbering).  Rules self-register into :data:`RULES` via
:func:`register`; the :class:`Analyzer` walks the analyzed file set once,
parses each file once, dispatches every registered rule, applies in-source
``# repro: noqa[ID] -- why`` suppressions, and emits the ANA meta-findings
(bare or dead suppressions) itself so the suppression mechanism is
self-policing.

The module also hosts the shared ``jax.jit`` site model
(:func:`iter_jit_sites`) used by the JIT and SYNC rule families: a *jit
site* is any ``jax.jit``/``pjit`` call or ``functools.partial(jax.jit,
...)`` decorator, with its resolved ``static_argnames``/``static_argnums``
and, when syntactically visible, the function or lambda whose body is
traced.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Iterable, Iterator

from .findings import (Finding, RULE_ID_RE, Severity, Suppression,
                       parse_suppressions)

RULES: dict[str, "Rule"] = {}


def register(rule_cls):
    """Class decorator: instantiate the rule and add it to the registry."""
    rule = rule_cls()
    if not RULE_ID_RE.match(rule.id):
        raise ValueError(f"bad rule id {rule.id!r}")
    if rule.id in RULES:
        raise ValueError(f"duplicate rule id {rule.id!r}")
    RULES[rule.id] = rule
    return rule_cls


@dataclasses.dataclass
class FileContext:
    """One analyzed file: source, parsed tree, and suppression table."""
    root: pathlib.Path
    path: pathlib.Path             # absolute
    rel: str                       # repo-relative posix path
    source: str
    lines: list[str]
    tree: ast.AST | None           # None when the file failed to parse
    suppressions: dict[int, Suppression]

    @classmethod
    def load(cls, root: pathlib.Path, path: pathlib.Path) -> "FileContext":
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError:
            tree = None
        return cls(root, path, path.relative_to(root).as_posix(), source,
                   source.splitlines(), tree, parse_suppressions(source))

    def line_text(self, line: int) -> str:
        return self.lines[line - 1].strip() if 1 <= line <= len(self.lines) \
            else ""


class Rule:
    """Base rule.  Subclasses set ``id``/``severity``/``description`` and
    ``contract`` (the DESIGN contract the rule mechanizes), then override
    one of the two check hooks."""
    id = "XXX000"
    severity = Severity.ERROR
    description = ""
    contract = ""

    def applies_to(self, rel: str) -> bool:
        return rel.endswith(".py")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def check_repo(self, root: pathlib.Path,
                   ctxs: list[FileContext]) -> Iterable[Finding]:
        return ()

    # ------------------------------------------------------------ helpers
    def finding(self, ctx: FileContext, node_or_line, message: str,
                col: int | None = None) -> Finding:
        if isinstance(node_or_line, int):
            line, c = node_or_line, col or 0
        else:
            line, c = node_or_line.lineno, node_or_line.col_offset
        return Finding(self.id, ctx.rel, line, c, message, self.severity,
                       ctx.line_text(line))


# ------------------------------------------------------------ jit site model

_JIT_NAMES = {"jit", "pjit"}
_PARTIAL_NAMES = {"partial"}


def _callee_name(func: ast.AST) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def is_jit_callee(func: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` / ``pjit`` / ``jax.experimental.pjit.pjit``."""
    return _callee_name(func) in _JIT_NAMES


def is_partial_callee(func: ast.AST) -> bool:
    return _callee_name(func) in _PARTIAL_NAMES


def _const_str_items(node: ast.AST) -> list[str] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


def _const_int_items(node: ast.AST) -> list[int] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


@dataclasses.dataclass
class JitSite:
    """One jit invocation: the Call carrying the static/donate kwargs, the
    traced function body when visible, and the resolved static names."""
    call: ast.Call                      # the jit/partial call with kwargs
    static_names: set                   # resolved static_argnames
    static_nums: list                   # resolved static_argnums
    empty_kwargs: list                  # kwarg names bound to empty tuples
    target: ast.AST | None              # FunctionDef/Lambda traced, if known

    def param_names(self) -> list[str]:
        if self.target is None:
            return []
        args = self.target.args
        return [a.arg for a in args.posonlyargs + args.args]

    def traced_params(self) -> set:
        """Parameter names that arrive as tracers (non-static)."""
        names = set(self.param_names())
        static = set(self.static_names)
        for i in self.static_nums:
            params = self.param_names()
            if 0 <= i < len(params):
                static.add(params[i])
        return names - static


def _site_from_call(call: ast.Call, target: ast.AST | None) -> JitSite:
    static_names: set = set()
    static_nums: list = []
    empty: list = []
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums", "donate_argnums",
                      "donate_argnames"):
            if isinstance(kw.value, (ast.Tuple, ast.List)) \
                    and not kw.value.elts:
                empty.append(kw.arg)
                continue
            if kw.arg == "static_argnames":
                static_names |= set(_const_str_items(kw.value) or ())
            elif kw.arg == "static_argnums":
                static_nums += _const_int_items(kw.value) or []
    return JitSite(call, static_names, static_nums, empty, target)


def iter_jit_sites(tree: ast.AST) -> Iterator[JitSite]:
    """Yield every syntactically visible jit site in a module.

    Covers: ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators (target =
    the decorated FunctionDef), ``jax.jit(lambda ...: ..., ...)`` (target =
    the lambda), and bare ``jax.jit(f, ...)`` / ``partial(jax.jit, ...)``
    expression sites (target unknown -> None).
    """
    decorated_calls: set = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and is_jit_callee(dec.func):
                    decorated_calls.add(id(dec))
                    yield _site_from_call(dec, node)
                elif isinstance(dec, ast.Call) and is_partial_callee(dec.func) \
                        and dec.args and is_jit_callee(dec.args[0]):
                    decorated_calls.add(id(dec))
                    yield _site_from_call(dec, node)
                elif is_jit_callee(dec):      # plain @jax.jit, no kwargs
                    yield JitSite(ast.Call(func=dec, args=[], keywords=[]),
                                  set(), [], [], node)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or id(node) in decorated_calls:
            continue
        if is_jit_callee(node.func):
            target = node.args[0] if node.args \
                and isinstance(node.args[0], ast.Lambda) else None
            yield _site_from_call(node, target)
        elif is_partial_callee(node.func) and node.args \
                and is_jit_callee(node.args[0]):
            yield _site_from_call(node, None)


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted name of a Name/Attribute chain ('' otherwise)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ------------------------------------------------------------------ analyzer

DEFAULT_GLOBS = ("src/**/*.py", "benchmarks/*.py", "examples/*.py")


def default_files(root: pathlib.Path) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for g in DEFAULT_GLOBS:
        out += sorted(root.glob(g))
    return out


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]        # active (unsuppressed) findings
    suppressed: list[Finding]      # findings absorbed by a valid noqa
    files: list[str]               # repo-relative paths analyzed


class Analyzer:
    def __init__(self, rules: dict[str, Rule] | None = None):
        # rule modules register on import; the default registry is whatever
        # repro.analysis.rules populated
        self.rules = dict(rules if rules is not None else RULES)

    def run(self, root: str | pathlib.Path,
            files: Iterable[str | pathlib.Path] | None = None
            ) -> AnalysisResult:
        root = pathlib.Path(root).resolve()
        paths = [pathlib.Path(f) if pathlib.Path(f).is_absolute()
                 else root / f for f in files] if files is not None \
            else default_files(root)
        ctxs = [FileContext.load(root, p) for p in paths if p.is_file()]
        by_rel = {c.rel: c for c in ctxs}

        raw: list[Finding] = []
        for rule in self.rules.values():
            for ctx in ctxs:
                if ctx.tree is not None and rule.applies_to(ctx.rel):
                    raw += list(rule.check_file(ctx))
            raw += list(rule.check_repo(root, ctxs))

        # ---- apply suppressions (justification mandatory)
        active: list[Finding] = []
        suppressed: list[Finding] = []
        for f in raw:
            ctx = by_rel.get(f.path)
            sup = ctx.suppressions.get(f.line) if ctx else None
            if sup and f.rule in sup.rules and sup.justification:
                sup.used.add(f.rule)
                suppressed.append(f)
            else:
                active.append(f)

        # ---- ANA meta-findings: the suppression mechanism polices itself
        for ctx in ctxs:
            for sup in ctx.suppressions.values():
                src = ctx.line_text(sup.line)
                if not sup.justification:
                    active.append(Finding(
                        "ANA002", ctx.rel, sup.line, 0,
                        "noqa without justification text (write "
                        "'# repro: noqa[ID] -- why'); it suppresses nothing",
                        Severity.ERROR, src))
                    continue
                unknown = [r for r in sup.rules if r not in self.rules]
                if unknown:
                    active.append(Finding(
                        "ANA002", ctx.rel, sup.line, 0,
                        f"noqa names unknown rule id(s) {sorted(unknown)}",
                        Severity.ERROR, src))
                dead = sup.rules - sup.used - set(unknown)
                if dead:
                    active.append(Finding(
                        "ANA001", ctx.rel, sup.line, 0,
                        f"unused suppression for {sorted(dead)}: no such "
                        "finding on this line; delete the noqa",
                        Severity.WARNING, src))

        key = lambda f: (f.path, f.line, f.rule, f.message)
        return AnalysisResult(sorted(active, key=key),
                              sorted(suppressed, key=key),
                              [c.rel for c in ctxs])
