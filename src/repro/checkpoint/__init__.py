from .checkpointer import (Checkpointer, save_pytree, restore_pytree,
                           restore_subtree)

__all__ = ["Checkpointer", "save_pytree", "restore_pytree",
           "restore_subtree"]
