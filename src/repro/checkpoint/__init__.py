from .checkpointer import (Checkpointer, save_pytree, restore_pytree,
                           restore_subtree, upgrade_pytree)

__all__ = ["Checkpointer", "save_pytree", "restore_pytree",
           "restore_subtree", "upgrade_pytree"]
