"""Fault-tolerant, mesh-agnostic checkpointing.

Properties required at 1000-node scale (DESIGN §6):
 - ATOMIC: writes go to ``<dir>/.tmp_<step>`` and are renamed into place,
   so a crash mid-save never corrupts the restore point;
 - SELF-DESCRIBING: leaves are .npy files addressed by a flattened
   key-path manifest (meta.json) with a content digest — restore does not
   need live pytree templates and verifies integrity;
 - MESH-AGNOSTIC / ELASTIC: arrays are saved in logical (unsharded) form
   and re-sharded on restore with whatever mesh/sharding the new job uses —
   restarting 512-chip training on 256 chips is a restore, not a migration;
 - ASYNC: ``save_async`` snapshots to host memory synchronously (cheap) and
   writes in a background thread, overlapping I/O with the next steps;
 - BOUNDED: keeps the last ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading

import jax
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "restore_pytree",
           "restore_subtree", "upgrade_pytree"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        keyed[key] = leaf
    return keyed, treedef


def save_pytree(tree, path: pathlib.Path):
    """Atomic synchronous save of one pytree."""
    path = pathlib.Path(path)
    tmp = path.parent / f".tmp_{path.name}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    keyed, _ = _flatten(tree)
    manifest = {}
    digest = hashlib.sha256()
    for i, (key, leaf) in enumerate(sorted(keyed.items())):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":          # e.g. bfloat16 (ml_dtypes)
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        digest.update(key.encode())
        digest.update(arr.tobytes()[: 1 << 20])   # first MiB per leaf
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": logical_dtype}
    (tmp / "meta.json").write_text(json.dumps(
        {"leaves": manifest, "digest": digest.hexdigest()}, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)


def _rebuild(arrays: dict, template, *, shardings=None):
    """Fill ``template``'s structure from a flat {path: ndarray} dict in
    template flatten order, casting to template leaf dtypes (bf16 etc.) and
    optionally placing leaves sharded — the ONE template-rebuild path, used
    by both ``restore_pytree`` and ``restore_subtree``."""
    import jax.numpy as jnp
    keyed, _ = _flatten(template)
    _, treedef = jax.tree_util.tree_flatten(template)
    flat_sh = None
    if shardings is not None:
        flat_sh, _ = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
    restored = []
    for i, (k, tmpl_leaf) in enumerate(keyed.items()):
        arr = arrays[k]
        tmpl_dtype = getattr(tmpl_leaf, "dtype", np.asarray(tmpl_leaf).dtype)
        if str(arr.dtype) != str(tmpl_dtype):
            arr = jnp.asarray(arr).astype(tmpl_dtype)  # handles bf16 etc.
        if flat_sh is not None:
            arr = jax.device_put(arr, flat_sh[i])
        restored.append(arr)
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_pytree(path: pathlib.Path, template=None, *, shardings=None,
                   verify: bool = True):
    """Restore; with ``template`` the exact pytree structure/dtypes are
    rebuilt, otherwise a nested dict keyed by path is returned.  With
    ``shardings`` (a matching pytree of NamedSharding) leaves are placed
    sharded — the elastic-re-mesh path."""
    path = pathlib.Path(path)
    meta = json.loads((path / "meta.json").read_text())
    arrays = {}
    digest = hashlib.sha256()
    for key in sorted(meta["leaves"]):
        info = meta["leaves"][key]
        arr = np.load(path / info["file"])
        digest.update(key.encode())
        digest.update(arr.tobytes()[: 1 << 20])
        arrays[key] = arr
    if verify and digest.hexdigest() != meta["digest"]:
        raise IOError(f"checkpoint {path} failed digest verification")

    if template is None:
        return arrays
    keyed, _ = _flatten(template)
    assert set(keyed) == set(arrays), "checkpoint/template mismatch"
    return _rebuild(arrays, template, shardings=shardings)


def upgrade_pytree(path: pathlib.Path, template, *, prefix: str | None = None,
                   verify: bool = True):
    """Restore an OLDER checkpoint into a NEWER architecture ``template``.

    The documented §11 upgrade path for pre-hardware-condition mappers:
    leaves present in the checkpoint restore as usual; leaves the checkpoint
    lacks (e.g. the hw-condition embedding ``emb_h`` of a
    ``DTConfig(hw_dim>0)`` model) are ZERO-filled in the template's
    shape/dtype.  Because the hw embedding enters ADDITIVELY (see
    ``core.model``), a zero-filled upgrade is function-identical to the old
    mapper until fine-tuned on hw-labeled data.  ``prefix`` selects a
    subtree of the checkpoint (e.g. ``"params"`` of a {params, opt_state}
    training checkpoint).  Returns ``(tree, missing_keys)`` so callers can
    log / assert what was newly initialized; extra checkpoint leaves the
    template does not reference are ignored."""
    arrays = restore_pytree(path, None, verify=verify)
    if prefix is not None:
        pre = f"{prefix}/"
        arrays = {k[len(pre):]: v for k, v in arrays.items()
                  if k.startswith(pre)}
    keyed, _ = _flatten(template)
    missing, sub = [], {}
    for k, tmpl_leaf in keyed.items():
        if k in arrays:
            want = tuple(np.shape(tmpl_leaf))
            if tuple(arrays[k].shape) != want:
                # an upgrade only ADDS leaves; a reshaped existing leaf
                # (e.g. a grown `time` table) would restore misaligned and
                # fail silently at serving (gather clamps) — refuse loudly
                raise ValueError(
                    f"checkpoint leaf {k} has shape {arrays[k].shape} but "
                    f"the template expects {want}; upgrade_pytree only "
                    f"fills leaves the checkpoint lacks")
            sub[k] = arrays[k]
        else:
            missing.append(k)
            arr = np.asarray(tmpl_leaf)
            sub[k] = np.zeros(arr.shape, arr.dtype)
    return _rebuild(sub, template), missing


def restore_subtree(path: pathlib.Path, prefix: str, template, *,
                    verify: bool = True):
    """Restore only the leaves under ``<prefix>/`` into ``template``'s
    structure — e.g. warm-starting params from a {params, opt_state}
    training checkpoint without reconstructing the optimizer pytree."""
    arrays = restore_pytree(path, None, verify=verify)
    keyed, _ = _flatten(template)
    sub = {}
    for k in keyed:
        key = f"{prefix}/{k}"
        if key not in arrays:
            raise KeyError(f"checkpoint {path} has no leaf {key}")
        sub[k] = arrays[key]
    return _rebuild(sub, template)


class Checkpointer:
    """Step-indexed checkpoint manager with async save and keep-last-k."""

    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: threading.Thread | None = None

    def _dir(self, step: int) -> pathlib.Path:
        return self.root / f"ckpt_{step:08d}"

    def path(self, step: int | None = None) -> pathlib.Path:
        """Directory of checkpoint ``step`` (default: latest).  The public
        step->path mapping for partial restores (``restore_subtree``)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        return self._dir(step)

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.root.iterdir()
                      if p.name.startswith("ckpt_"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _gc(self):
        for step in self.steps()[: -self.keep]:
            shutil.rmtree(self._dir(step), ignore_errors=True)

    def save(self, step: int, tree):
        self.wait()
        save_pytree(tree, self._dir(step))
        self._gc()

    def save_async(self, step: int, tree):
        """Snapshot to host now; write in the background."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_pytree(host, self._dir(step))
            self._gc()
        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, template=None, step: int | None = None, *,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        tree = restore_pytree(self._dir(step), template,
                              shardings=shardings)
        return step, tree
