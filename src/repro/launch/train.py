"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Fault-tolerant loop (restore-from-latest, async checkpoints, straggler
monitor) over the synthetic pipeline.  ``--fusion-mapper`` turns on the
paper's technique as a framework feature: the arch is lowered to a fusion
workload, the mapper (trained DNNFuser artifact if present, else a quick
G-Sampler search) infers the input micro-batch under the activation-memory
budget, and the trainer uses it as the gradient-accumulation micro-batch —
the paper's §3 "micro-batching strategy" steering a real training loop.
"""
from __future__ import annotations

import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from ..configs import get_config, Shape
from ..core import PAPER_ACCEL, FusionEnv, GSamplerConfig, gsampler_search
from ..data import SyntheticLM
from ..models import registry
from ..runtime import TrainLoop
from ..workloads.lm_workloads import lm_workload

__all__ = ["mapper_microbatch", "make_local_train_step", "main"]

MB = float(2 ** 20)


def mapper_microbatch(cfg, *, seq_len: int, global_batch: int,
                      act_budget_mb: float, dt_params=None,
                      dt_cfg=None) -> dict:
    """Infer a micro-batching strategy for (arch, shape) under a budget.

    Returns {"micro_batch", "grad_accum", "strategy", "speedup"}.  With a
    trained DNNFuser (dt_params) inference is one-shot; otherwise G-Sampler
    searches (the teacher fallback).
    """
    wl = lm_workload(cfg, seq_len=seq_len, batch=global_batch, mode="train")
    # activations at LM-block granularity: scale the edge-accelerator cost
    # model to HBM-class numbers for this use
    hw = PAPER_ACCEL
    env = FusionEnv(wl, hw, batch=global_batch,
                    budget_bytes=act_budget_mb * MB, nmax=128)
    if dt_params is not None:
        from ..core.infer import dnnfuser_infer
        res = dnnfuser_infer(dt_params, dt_cfg, env)
        strategy, speedup = res.strategy, res.speedup
    else:
        res = gsampler_search(env, GSamplerConfig(generations=20, seed=0))
        strategy, speedup = res.strategy, res.speedup
    mb0 = int(max(1, strategy[0]))
    # round to a divisor of the global batch
    while global_batch % mb0:
        mb0 -= 1
    return {"micro_batch": mb0, "grad_accum": global_batch // mb0,
            "strategy": strategy[: wl.n + 1], "speedup": speedup}


def make_local_train_step(cfg, tx, *, grad_accum: int = 1, impl="xla",
                          remat="none"):
    """Single-host train step with optional gradient accumulation."""
    model = registry.get_model(cfg)

    def loss_fn(p, batch):
        return model.loss_fn(p, cfg, batch, impl=impl, remat=remat)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            B = jax.tree_util.tree_leaves(batch)[0].shape[0]
            mb = B // grad_accum
            chunks = jax.tree.map(
                lambda x: x[: mb * grad_accum].reshape(
                    (grad_accum, mb) + x.shape[1:]), batch)
            loss, grads = optim.accumulated_value_and_grad(
                loss_fn, params, chunks)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    return jax.jit(step, donate_argnums=(0, 1))


def train(arch: str, *, steps: int = 200, global_batch: int = 8,
          seq_len: int = 128, reduced: bool = True, lr: float = 3e-4,
          ckpt_dir: str = "artifacts/train", use_mapper: bool = False,
          act_budget_mb: float = 24.0, dt_params=None, dt_cfg=None,
          crash_at: int | None = None, seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    model = registry.get_model(cfg)
    grad_accum = 1
    mapper_info = None
    if use_mapper:
        mapper_info = mapper_microbatch(cfg, seq_len=seq_len,
                                        global_batch=global_batch,
                                        act_budget_mb=act_budget_mb,
                                        dt_params=dt_params, dt_cfg=dt_cfg)
        grad_accum = mapper_info["grad_accum"]
        print(f"[mapper] micro_batch={mapper_info['micro_batch']} "
              f"grad_accum={grad_accum} "
              f"(modeled fusion speedup {mapper_info['speedup']:.2f}x)")

    params = model.init(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    tx = optim.adamw(optim.cosine_with_warmup(lr, 20, steps),
                     weight_decay=0.01, max_grad_norm=1.0)
    opt_state = tx.init(params)
    step_fn = make_local_train_step(cfg, tx, grad_accum=grad_accum)

    src = SyntheticLM(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed,
        embed_dim=cfg.d_model if cfg.embed_inputs else None,
        dec_len=max(seq_len // 8, 8) if cfg.family == "encdec" else None)

    def batch_fn(step):
        b = src.batch_at(step)
        if cfg.embed_inputs and cfg.family != "encdec":
            b = {"embeds": b["embeds"], "labels": b["labels"]}
        elif cfg.family == "encdec":
            b = {"embeds": b["embeds"], "tokens": b["tokens"],
                 "labels": b["labels"]}
        else:
            b = {"tokens": b["tokens"], "labels": b["labels"]}
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop = TrainLoop(step_fn, params, opt_state, batch_fn,
                     ckpt_dir=ckpt_dir, ckpt_every=max(steps // 4, 10))
    params, opt_state = loop.run(steps, crash_at=crash_at)
    return loop, mapper_info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — needs real HW")
    ap.add_argument("--fusion-mapper", action="store_true")
    ap.add_argument("--act-budget-mb", type=float, default=24.0)
    ap.add_argument("--ckpt-dir", default="artifacts/train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    loop, _ = train(args.arch, steps=args.steps,
                    global_batch=args.global_batch, seq_len=args.seq_len,
                    reduced=not args.full, lr=args.lr,
                    ckpt_dir=args.ckpt_dir, use_mapper=args.fusion_mapper,
                    act_budget_mb=args.act_budget_mb)
    print("losses:", loop.losses)
    print("median step s:", round(loop.monitor.median, 4),
          "straggler events:", len(loop.monitor.events))


if __name__ == "__main__":
    main()
