"""Jitted step builders shared by train.py / serve.py / dryrun.py.

Each builder returns (jitted_fn, abstract_args) where abstract_args are
ShapeDtypeStructs — so the same code path serves real training (pass real
arrays) and the dry-run (``.lower(*abstract_args).compile()``, no
allocation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim
from ..configs import ArchConfig, Shape, SHAPES
from ..distributed.sharding import (param_specs, batch_specs,
                                    decode_state_specs_sharded)
from ..models import registry

__all__ = ["abstract_params", "build_train_step", "build_prefill",
           "build_decode_step", "default_tx"]


def default_tx(lr: float = 3e-4):
    return optim.adamw(lr, weight_decay=0.01, max_grad_norm=1.0)


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    model = registry.get_model(cfg)
    return jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), cfg, dtype=dtype))


def _ns(mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def build_train_step(cfg: ArchConfig, shape: Shape, mesh, *,
                     impl: str = "xla", remat: str = "full",
                     dtype=jnp.bfloat16, tx=None):
    """(jitted train_step, (params_sds, opt_sds, batch_sds))."""
    model = registry.get_model(cfg)
    tx = tx or default_tx()
    params_sds = abstract_params(cfg, dtype)
    opt_sds = jax.eval_shape(tx.init, params_sds)
    batch_sds = registry.input_specs(cfg, shape, act_dtype=dtype)

    p_ns = _ns(mesh, param_specs(params_sds, mesh, cfg))
    o_ns = _ns(mesh, param_specs(opt_sds, mesh, cfg))
    b_ns = _ns(mesh, batch_specs(batch_sds, mesh))
    scalar = NamedSharding(mesh, P())

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, impl=impl, remat=remat)
        )(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    jitted = jax.jit(train_step,
                     in_shardings=(p_ns, o_ns, b_ns),
                     out_shardings=(p_ns, o_ns, scalar),
                     donate_argnums=(0, 1))
    return jitted, (params_sds, opt_sds, batch_sds)


def build_prefill(cfg: ArchConfig, shape: Shape, mesh, *, impl: str = "xla",
                  dtype=jnp.bfloat16):
    model = registry.get_model(cfg)
    params_sds = abstract_params(cfg, dtype)
    batch_sds = registry.input_specs(cfg, shape, act_dtype=dtype)
    max_len = registry.decode_cache_len(cfg, shape)

    p_ns = _ns(mesh, param_specs(params_sds, mesh, cfg))
    b_ns = _ns(mesh, batch_specs(batch_sds, mesh))

    def prefill(params, batch):
        return model.prefill(params, cfg, batch, max_len, impl=impl)

    jitted = jax.jit(prefill, in_shardings=(p_ns, b_ns))
    return jitted, (params_sds, batch_sds)


def build_decode_step(cfg: ArchConfig, shape: Shape, mesh, *,
                      impl: str = "xla", dtype=jnp.bfloat16):
    """One-token serve step with donated caches. SP for batch-1 long ctx."""
    model = registry.get_model(cfg)
    params_sds = abstract_params(cfg, dtype)
    state_sds = registry.decode_state_specs(cfg, shape, cache_dtype=dtype)
    batch_sds = registry.input_specs(cfg, shape, act_dtype=dtype)
    shard_seq = shape.global_batch == 1

    p_ns = _ns(mesh, param_specs(params_sds, mesh, cfg))
    s_ns = _ns(mesh, decode_state_specs_sharded(state_sds, mesh,
                                                shard_seq=shard_seq))
    b_ns = _ns(mesh, batch_specs(batch_sds, mesh, shard_seq=False))

    def decode_step(params, state, batch):
        logits, state = model.decode_step(params, cfg, state, batch,
                                          impl=impl)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, state

    jitted = jax.jit(decode_step, in_shardings=(p_ns, s_ns, b_ns),
                     donate_argnums=(1,))
    return jitted, (params_sds, state_sds, batch_sds)
