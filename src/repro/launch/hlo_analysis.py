"""Roofline-term extraction from compiled/lowered HLO (DESIGN §8, §Roofline).

``collective_bytes`` is NOT in ``cost_analysis()`` — we parse the optimized
HLO text and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  Hardware constants are
the TPU v5e numbers given in the assignment.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineReport"]

# TPU v5e per-chip constants (assignment §Roofline)
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


# Per-device wire-traffic factor applied to the RESULT bytes of each
# collective (optimized HLO prints operand *names* only, so we read the
# result shape, which for these ops equals/bounds the per-device payload):
# all-reduce moves ~2x its buffer (reduce + broadcast phases); the others
# move ~1x their (already per-device) result.  This is a uniform ~2x-exact
# approximation, fine for roofline ranking; documented in EXPERIMENTS.md.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device collective wire bytes by kind, parsed from optimized HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find("=")
        if eq < 0:
            continue
        m = None
        for kind in _COLLECTIVES:
            # opcode position must be AFTER '=' (instruction names contain
            # the opcode too, e.g. %all-reduce.271); count async ops at
            # their "-start" half only.
            mm = re.search(rf"(?:^|\s)({kind})(-start|-done)?\(", s)
            if mm and mm.start() > eq and mm.group(2) != "-done":
                m = (kind, mm)
                break
        if not m:
            continue
        kind, mm = m
        result_part = s[eq + 1: mm.start()]
        byt = sum(_shape_bytes(d, dims)
                  for d, dims in _SHAPE_RE.findall(result_part))
        byt *= _WIRE_FACTOR[kind]
        out[kind] += byt
        out["total"] += byt
    return out


@dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float      # MODEL_FLOPS / (HLO flops x n_devices)

    def as_dict(self):
        return asdict(self)


def roofline_terms(*, flops: float, bytes_accessed: float,
                   coll_bytes: float, n_devices: int,
                   model_flops: float = 0.0) -> RooflineReport:
    """All inputs are per-device (XLA analyses run on the SPMD partition)."""
    t_c = flops / HW["peak_flops"]
    t_m = bytes_accessed / HW["hbm_bw"]
    t_x = coll_bytes / HW["ici_bw"]
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bn = max(terms, key=terms.get)
    useful = (model_flops / (flops * n_devices)) if flops else 0.0
    return RooflineReport(flops, bytes_accessed, coll_bytes, t_c, t_m, t_x,
                          bn, model_flops, useful)
