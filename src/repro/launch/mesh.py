"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  Single pod:
(16, 16) = 256 chips as ("data", "model"); multi-pod: (2, 16, 16) = 512
chips as ("pod", "data", "model").  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so both meshes can be built on the CPU host.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_production_mesh", "mesh_ctx", "dp_axes", "batch_axes"]


def mesh_ctx(mesh):
    """``jax.set_mesh`` context on jax versions that have it, else the mesh
    itself (``with mesh:`` — the pre-0.5 spelling of the same thing).  The
    single home of this version shim; dryrun and the sharding tests both
    use it."""
    import jax
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} "
            f"(dry-run must set xla_force_host_platform_device_count first)")
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel / FSDP axes of a mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_axes(mesh):
    """PartitionSpec entry for the global-batch dimension."""
    axes = dp_axes(mesh)
    return axes if len(axes) > 1 else axes[0]
