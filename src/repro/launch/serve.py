"""Serving launcher: batched prefill + decode with donated caches.

``python -m repro.launch.serve --arch <id> --prompt-len 64 --gen 32``
runs a reduced config on CPU end-to-end (the examples use this API); on a
real mesh the same ``steps.build_prefill/build_decode_step`` pair lowers
with the production shardings (that path is what the dry-run compiles).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import registry

__all__ = ["serve_greedy", "main"]


def serve_greedy(arch: str, *, batch: int = 4, prompt_len: int = 32,
                 gen_len: int = 16, reduced: bool = True, seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen_len + 8

    if cfg.family == "encdec":
        sd = max(prompt_len // 8, 8)
        pf_batch = {"embeds": jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, sd)),
                                  jnp.int32)}
        dec_max = sd + gen_len + 8
    elif cfg.embed_inputs:
        pf_batch = {"embeds": jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.float32)}
        dec_max = max_len
    else:
        pf_batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
        dec_max = max_len

    prefill = jax.jit(lambda p, b: model.prefill(p, cfg, b, dec_max,
                                                 cache_dtype=jnp.float32))
    decode = jax.jit(lambda p, s, b: model.decode_step(p, cfg, s, b),
                     donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, state = prefill(params, pf_batch)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(gen_len - 1):
        if cfg.embed_inputs and cfg.family != "encdec":
            step_b = {"embeds": jnp.zeros((batch, 1, cfg.d_model),
                                          jnp.float32)}
        else:
            step_b = {"tokens": tok}
        logits, state = decode(params, state, step_b)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks = np.concatenate(out_tokens, axis=1)
    return {"tokens": toks, "t_prefill_s": t_prefill,
            "t_decode_s": t_decode,
            "tok_per_s": batch * (gen_len - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out = serve_greedy(args.arch, batch=args.batch,
                       prompt_len=args.prompt_len, gen_len=args.gen,
                       reduced=not args.full)
    print(f"prefill {out['t_prefill_s']:.2f}s decode {out['t_decode_s']:.2f}s"
          f" -> {out['tok_per_s']:.1f} tok/s")
    print("first sequence:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
