import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init) — this file is the only place that forces 512 host
# devices; tests and benches see the real device count.

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and extract the roofline terms (assignment: MULTI-POD
# DRY-RUN + ROOFLINE ANALYSIS).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3_1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]
#
# Per cell this prints/saves: memory_analysis (proves it fits), cost_analysis
# FLOPs/bytes, parsed collective bytes, the three roofline terms and the
# dominant bottleneck.  Results are cached as JSON per cell.

import argparse
import json
import pathlib
import time
import traceback


def _compile_variant(cfg, shape, mesh, impl, remat):
    from repro.launch import steps
    from repro.launch.mesh import mesh_ctx
    with mesh_ctx(mesh):
        if shape.kind == "train":
            jitted, args = steps.build_train_step(cfg, shape, mesh,
                                                  impl=impl, remat=remat)
        elif shape.kind == "prefill":
            jitted, args = steps.build_prefill(cfg, shape, mesh, impl=impl)
        else:
            jitted, args = steps.build_decode_step(cfg, shape, mesh,
                                                   impl=impl)
        return jitted.lower(*args).compile()


def _costs(compiled, hlo_analysis):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            hlo_analysis.collective_bytes(compiled.as_text()))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               impl: str = "xla", remat: str = "full",
               donate: bool = True) -> dict:
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps, hlo_analysis

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()

    compiled = _compile_variant(cfg, shape, mesh, impl, remat)
    t_compile = time.perf_counter() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    mem_d = {k: float(getattr(mem, k, 0) or 0) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes")}

    # XLA's cost analysis counts a while-loop (the layer scan) body ONCE,
    # so per-step flops/bytes/collectives would be ~L x undercounted.  We
    # compile L=1 and L=2 variants of the cell (cheap) and extrapolate:
    # total = intercept + per_layer * L.
    def with_layers(n):
        kw = {"n_layers": n}
        if cfg.family == "encdec":
            kw["encoder_layers"] = n
        return dataclasses.replace(cfg, **kw)

    from repro.nn import flags
    with flags.force_unroll():
        f1, b1, x1 = _costs(_compile_variant(with_layers(1), shape, mesh,
                                             impl, remat), hlo_analysis)
        f2, b2, x2 = _costs(_compile_variant(with_layers(2), shape, mesh,
                                             impl, remat), hlo_analysis)
    L = cfg.n_layers
    flops = max(f1 + (f2 - f1) * (L - 1), 0.0)
    byts = max(b1 + (b2 - b1) * (L - 1), 0.0)
    coll = {k: max(x1[k] + (x2[k] - x1[k]) * (L - 1), 0.0) for k in x1}

    # analytic MODEL_FLOPS: 6*N_active*D for train, 2*N_active*D otherwise
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch          # one token per sequence
        model_flops = 2.0 * n_active * tokens

    rep = hlo_analysis.roofline_terms(flops=flops, bytes_accessed=byts,
                                      coll_bytes=coll["total"],
                                      n_devices=n_dev,
                                      model_flops=model_flops)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "kind": shape.kind, "impl": impl, "remat": remat,
        "ok": True,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": mem_d, "flops_per_device": flops,
        "bytes_per_device": byts, "collectives": coll,
        "roofline": rep.as_dict(),
    }


def run_cell(arch, shape_name, multi_pod, out_dir, force=False, **kw):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    path = out_dir / f"{tag}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if rec.get("ok"):
            print(f"[cached] {tag}: "
                  f"{rec.get('roofline', {}).get('bottleneck')}")
            return rec
        # cached failure: retry (the bug may be fixed)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    path.write_text(json.dumps(rec, indent=1))
    if rec["ok"]:
        r = rec["roofline"]
        print(f"[ok] {tag}: compile {rec['t_compile_s']}s "
              f"temp {rec['memory']['temp_size_in_bytes']/2**30:.2f} GiB/dev "
              f"terms c/m/x = {r['t_compute']*1e3:.2f}/{r['t_memory']*1e3:.2f}"
              f"/{r['t_collective']*1e3:.2f} ms -> {r['bottleneck']}")
    else:
        print(f"[FAIL] {tag}: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    from repro.configs import cells
    todo = []
    if args.all:
        for a, s, ok, why in cells(include_skipped=False):
            todo.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    n_fail = 0
    for a, s in todo:
        for mp in meshes:
            rec = run_cell(a, s, mp, out_dir, force=args.force,
                           impl=args.impl, remat=args.remat)
            n_fail += 0 if rec.get("ok") else 1
    print(f"done: {len(todo) * len(meshes)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
