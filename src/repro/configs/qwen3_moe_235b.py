"""qwen3-moe-235b-a22b [moe] (hf:Qwen/Qwen3-235B-A22B family).

94 layers, d_model=4096, 64 heads (GQA kv=4), head_dim=128, expert
d_ff=1536, vocab=151936, 128 experts top-8, qk-norm.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, n_experts=128, moe_top_k=8, qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B scaled (hf)")
