"""gemma3-1b [dense] (hf:google/gemma-3-1b-pt).

26 layers, d_model=1152, 4 heads (kv=1), head_dim=256, d_ff=6912,
vocab=262144, 5 local (1024-window) : 1 global interleave, 128k context.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, tie_embeddings=True,
    window_pattern=(1024, 1024, 1024, 1024, 1024, -1),
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt (unverified)")
