"""qwen3-8b [dense] (hf:Qwen/Qwen3-8B).

36 layers, d_model=4096, 32 heads (GQA kv=8), head_dim=128, d_ff=12288,
vocab=151936, qk-norm (RMS on per-head q/k).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B (hf)")
