"""Assigned architecture configs (+ shape grid).

Each ``<arch>.py`` defines ``CONFIG`` with the exact published parameters;
``get_config(name)`` returns it, ``get_config(name, reduced=True)`` returns
the same-family smoke-test reduction.  ``SHAPES`` is the assigned input-
shape grid; ``cells()`` enumerates the (arch x shape) dry-run cells with the
DESIGN §5 long_500k skip policy applied.
"""
from .base import ArchConfig, Shape, SHAPES, ARCH_NAMES, get_config, cells

__all__ = ["ArchConfig", "Shape", "SHAPES", "ARCH_NAMES", "get_config", "cells"]
