"""qwen1.5-4b [dense] (hf:Qwen/Qwen1.5 family).

40 layers, d_model=2560, 20 heads (kv=20), d_ff=6912, vocab=151936,
QKV bias on (Qwen1.5 signature).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen15_4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, kv_heads=20, d_ff=6912,
    vocab=151936, qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B scaled (hf)")
