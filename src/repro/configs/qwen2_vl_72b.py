"""qwen2-vl-72b [vlm] backbone (arXiv:2409.12191).

80 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064,
M-RoPE (temporal/height/width sections).  Vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings + 3d position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, kv_heads=8, d_ff=29568,
    vocab=152064, qkv_bias=True, mrope_sections=(16, 24, 24),
    embed_inputs=True, rope_theta=1_000_000.0,
    source="arXiv:2409.12191 (hf)")
