"""ArchConfig schema, the shape grid, and the (arch x shape) cell policy."""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "Shape", "SHAPES", "ARCH_NAMES", "get_config", "cells"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None    # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp_kind: str = "swiglu"
    norm: str = "rms"
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # gemma3-style local/global interleave: window per layer position in the
    # repeating pattern; <=0 means full attention.
    window_pattern: tuple[int, ...] = (-1,)
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    # encoder-decoder (whisper): n_layers = decoder layers
    encoder_layers: int = 0
    # VLM M-RoPE half-dim sections (t, h, w); None = standard RoPE
    mrope_sections: tuple[int, int, int] | None = None
    # modality frontend stub: model consumes precomputed embeddings
    embed_inputs: bool = False
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 for clean TP sharding (Megatron practice)."""
        return _round_up(self.vocab, 256)

    def window_for_layer(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    def windows(self) -> list[int]:
        return [self.window_for_layer(i) for i in range(self.n_layers)]

    @property
    def is_sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN §5): attention-free, hybrid, or
        sliding-window-dominant stacks."""
        if self.family in ("ssm", "hybrid"):
            return True
        wins = self.windows()
        local = sum(1 for w in wins if w > 0)
        return local >= 0.8 * len(wins)

    def reduced(self) -> "ArchConfig":
        """Same-family smoke-test reduction (runs a CPU train step)."""
        return replace(
            self, n_layers=min(self.n_layers, 2 if self.family != "encdec" else 2),
            d_model=64, n_heads=4, kv_heads=max(1, min(self.kv_heads, 2)),
            head_dim=16, d_ff=128, vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            encoder_layers=min(self.encoder_layers, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            window_pattern=tuple(min(w, 32) if w > 0 else w
                                 for w in self.window_pattern),
            mrope_sections=(4, 2, 2) if self.mrope_sections else None)

    def param_count(self) -> float:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "ssm":                     # rwkv6 block
            mix = 4 * d * d + d * self.d_ff + self.d_ff * d
            blocks = self.n_layers * mix
        else:
            if self.n_experts:
                ffn = self.n_experts * 3 * d * self.d_ff
            elif self.mlp_kind == "swiglu":
                ffn = 3 * d * self.d_ff
            else:
                ffn = 2 * d * self.d_ff
            blocks = self.n_layers * (attn + ffn)
            if self.family == "hybrid":
                blocks += self.n_layers * (2 * d * d + d * self.ssm_state * 2)
            if self.family == "encdec":
                blocks += self.encoder_layers * (attn + ffn) \
                    + self.n_layers * attn   # cross-attn
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return float(blocks + emb)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_exp = self.n_layers * self.n_experts * 3 * d * self.d_ff
        act_exp = self.n_layers * self.moe_top_k * 3 * d * self.d_ff
        return float(full - all_exp + act_exp)


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = [
    "whisper_base", "gemma3_1b", "qwen15_4b", "minitron_4b", "qwen3_8b",
    "grok1_314b", "qwen3_moe_235b", "rwkv6_3b", "qwen2_vl_72b", "hymba_15b",
]

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    key = _ALIASES.get(name, name)
    if key not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    cfg = importlib.import_module(f"repro.configs.{key}").CONFIG
    return cfg.reduced() if reduced else cfg


def cells(include_skipped: bool = False):
    """Yield (arch_name, shape_name, runnable, why) for all 40 cells."""
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = True, ""
            if s.name == "long_500k" and not cfg.is_sub_quadratic:
                ok, why = False, "pure full attention at 512k (DESIGN §5 skip)"
            if ok or include_skipped:
                yield (a, s.name, ok, why)
