"""minitron-4b [dense]: pruned Nemotron (arXiv:2407.14679).

32 layers, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron_4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, kv_heads=8, d_ff=9216,
    vocab=256000,
    source="arXiv:2407.14679 (hf)")
