"""rwkv6-3b "Finch" [ssm, attention-free] (arXiv:2404.05892).

32 layers, d_model=2560, d_ff=8960, vocab=65536; data-dependent decay WKV6
recurrence, head_size 64 -> 40 heads; O(1) decode state.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, kv_heads=40, head_dim=64,
    d_ff=8960, vocab=65536, mlp_kind="gelu",
    source="arXiv:2404.05892 (hf)")
