"""hymba-1.5b [hybrid] (arXiv:2411.13676).

32 layers, d_model=1600, 25 attn heads (GQA kv=5), d_ff=5504, vocab=32001,
parallel attention + Mamba-style SSM heads (state 16) fused per layer;
sliding-window attention on most layers (3 full-attention layers).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba_15b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, ssm_state=16,
    window_pattern=(1024,) * 15 + (-1,),
    source="arXiv:2411.13676 (hf)")
