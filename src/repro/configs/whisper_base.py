"""whisper-base [audio]: enc-dec transformer backbone (arXiv:2212.04356).

6 encoder + 6 decoder layers, d_model=512, 8 heads (kv=8), d_ff=2048,
vocab=51865.  The conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings of length seq_len; decoder length = seq_len//8.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_base", family="encdec",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, kv_heads=8,
    d_ff=2048, vocab=51865, mlp_kind="gelu", norm="layer",
    embed_inputs=True, tie_embeddings=True,
    source="arXiv:2212.04356 (unverified)")
