"""grok-1-314b [moe] (hf:xai-org/grok-1).

64 layers, d_model=6144, 48 heads (GQA kv=8), d_ff=32768, vocab=131072,
8 experts top-2.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok1_314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, kv_heads=8, d_ff=32768,
    vocab=131072, n_experts=8, moe_top_k=2,
    source="hf:xai-org/grok-1 (unverified)")
