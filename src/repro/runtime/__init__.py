from .fault_tolerance import TrainLoop, StragglerMonitor

__all__ = ["TrainLoop", "StragglerMonitor"]
