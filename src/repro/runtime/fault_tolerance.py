"""Fault-tolerant training runtime: resume, stragglers, elastic re-mesh.

``TrainLoop`` is the restartable driver used by ``launch/train.py`` and the
e2e example: every run begins with restore-from-latest (a no-op for fresh
jobs), checkpoints every ``ckpt_every`` steps (async), and because the data
pipeline is a pure function of the step index, a killed-and-restarted job
reproduces the exact remaining batch sequence — tested by literally killing
the process mid-run in tests/test_fault_tolerance.py.

``StragglerMonitor`` wraps the step with a watchdog: steps exceeding
``timeout_factor`` x the trailing-median latency are logged with their step
index (on a real cluster this feeds the controller that re-schedules the
slow host; on one host we record and expose the events).  Elastic scaling
is the checkpoint layer's mesh-agnostic restore (see checkpoint/) plus the
deterministic pipeline re-sharding.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint import Checkpointer

__all__ = ["StragglerMonitor", "TrainLoop"]


@dataclass
class StragglerMonitor:
    timeout_factor: float = 3.0
    window: int = 32
    history: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float):
        self.history.append(dt)
        tail = self.history[-self.window:]
        if len(tail) >= 8:
            med = statistics.median(tail)
            if dt > self.timeout_factor * med:
                self.events.append({"step": step, "dt": dt, "median": med})

    @property
    def median(self) -> float:
        return statistics.median(self.history) if self.history else 0.0


class TrainLoop:
    """Restartable (params, opt_state) training driver."""

    def __init__(self, step_fn, params, opt_state, batch_fn, *,
                 ckpt_dir: str, ckpt_every: int = 50, keep: int = 3,
                 shardings=None, log_every: int = 50):
        self.step_fn = step_fn            # (params, opt, batch)->(p,o,loss)
        self.batch_fn = batch_fn          # step -> device-ready batch
        self.ckpt = Checkpointer(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.monitor = StragglerMonitor()
        self.shardings = shardings

        # resume-from-latest: a fresh job restores nothing
        state_tmpl = {"params": params, "opt": opt_state,
                      "step": np.zeros((), np.int64)}
        step, restored = self.ckpt.restore(state_tmpl,
                                           shardings=self.shardings)
        if restored is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.start_step = int(restored["step"]) + 1
        else:
            self.params, self.opt_state = params, opt_state
            self.start_step = 0
        self.losses: list[tuple[int, float]] = []

    def run(self, n_steps: int, *, crash_at: int | None = None):
        """Run to global step ``n_steps``. ``crash_at`` (tests only) raises
        mid-run to exercise the restart path."""
        step = self.start_step
        while step < n_steps:
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            self.params, self.opt_state, loss = self.step_fn(
                self.params, self.opt_state, batch)
            if step % self.log_every == 0 or step == n_steps - 1:
                self.losses.append((step, float(loss)))
            self.monitor.observe(step, time.perf_counter() - t0)
            if step % self.ckpt_every == 0 or step == n_steps - 1:
                self.ckpt.save_async(step, {"params": self.params,
                                            "opt": self.opt_state,
                                            "step": np.int64(step)})
            if crash_at is not None and step == crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated node failure at step {step}")
            step += 1
        self.ckpt.wait()
        return self.params, self.opt_state
