"""G-Sampler: the paper's search-based teacher (§4.4.2).

GAMMA [ICCAD'20] extended to the layer-fusion map-space: a domain-specific
genetic algorithm with (i) heuristic seeding (all-sync + the naive uniform
micro-batching strategy of paper §3), (ii) fusion-aware mutation operators
(sync flip, micro-batch grow/shrink), and (iii) a constraint-repair operator
that targets the most over-budget fused group — the domain knowledge that
makes it "several orders of magnitude better" than generic optimizers in
the paper's Table 1.

Population fitness is evaluated by ONE vmapped+jitted cost-model call per
generation (see ``cost_model.evaluate_population``); with the default paper
budget (pop 40 x 50 gens = 2k samples) a search takes well under a second —
that is the vectorized-JAX counterpart of the paper's 0.66-1.3 min search.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import AccelConfig, HwVec, stack_hw

__all__ = ["GSamplerConfig", "GSamplerResult", "gsampler_search",
           "naive_uniform_mb", "GridTeacherResult", "gsampler_search_grid"]


@dataclass(frozen=True)
class GSamplerConfig:
    population: int = 40          # paper §5.1
    generations: int = 50         # paper §5.1 (=> 2k samples)
    elite: int = 4
    p_mut_gene: float = 3.0       # expected mutated genes per child
    p_sync_mut: float = 0.25
    repair_tries: int = 6
    seed: int = 0


@dataclass
class GSamplerResult:
    strategy: np.ndarray
    speedup: float
    latency: float
    peak_mem: float
    valid: bool
    n_evals: int
    wall_s: float
    history: list = field(default_factory=list)     # best speedup per gen
    elites: list = field(default_factory=list)      # top-k distinct strategies


def naive_uniform_mb(env, max_mb: int | None = None) -> np.ndarray:
    """Paper §3's naive strategy: one uniform micro-batch for the whole net,
    the largest that stages all intermediates on-chip (binary search)."""
    B = env.batch
    hi = max_mb or B
    best = None
    lo = 1
    while lo <= hi:
        mid = (lo + hi) // 2
        s = np.full(env.nmax, cm.SYNC, dtype=np.int32)
        s[: env.n + 1] = mid
        _, peak, valid = env.speedup(s)
        if valid:
            best, lo = s, mid + 1
        else:
            hi = mid - 1
    if best is None:
        best = np.full(env.nmax, cm.SYNC, dtype=np.int32)
        best[0] = 1
    return best


def _repair_population(env, pop: np.ndarray, cfg: GSamplerConfig,
                       rng: np.random.Generator) -> np.ndarray:
    """Constraint repair for a whole brood at once: while any child is over
    budget, split or shrink its worst fused group.

    One vmapped ``cost_model.evaluate_population_stats`` call per repair
    round replaces the pure-Python per-child ``ref_model`` probes (the old
    hot spot: population x repair_tries reference evaluations per
    generation); the returned per-group memory + group-id arrays supply the
    split/shrink targets."""
    s = pop.copy()
    mask = np.asarray(env.wl_np["mask"])
    for _ in range(cfg.repair_tries):
        out, gid, M_g = cm.evaluate_population_stats(
            env.wl, jnp.asarray(s), float(env.batch),
            float(env.budget_bytes), env.hw)
        invalid = ~np.asarray(out.valid)
        if not invalid.any():
            break
        gid = np.asarray(gid)
        M_g = np.asarray(M_g)
        for i in np.where(invalid)[0]:
            worst = int(np.argmax(M_g[i]))
            span = np.where((gid[i] == worst) & mask)[0]
            start, end = int(span[0]), int(span[-1])
            if end > start and rng.random() < 0.5:
                s[i, (start + end) // 2] = cm.SYNC     # split the group
            else:
                seg = s[i, start: end + 1]
                mbs = np.where(seg > 1, seg, 0)
                if mbs.max() > 1:
                    j = start + int(np.argmax(mbs))
                    s[i, j] = max(1, s[i, j] // 2)     # shrink largest stage
                elif end > start:
                    s[i, (start + end) // 2] = cm.SYNC
                # else: single layer already minimal — leave it
    return s


def _fitness(latency: np.ndarray, peak: np.ndarray, budget: float) -> np.ndarray:
    over = np.maximum(0.0, peak / budget - 1.0)
    return np.where(over > 0.0, -1e3 * (1.0 + over) - latency, -latency)


def gsampler_search(env, cfg: GSamplerConfig = GSamplerConfig(),
                    top_k: int = 8) -> GSamplerResult:
    rng = np.random.default_rng(cfg.seed)
    t0 = time.perf_counter()
    P, n, B = cfg.population, env.n, env.batch

    pop = np.stack([cm.random_strategy(rng, n, env.nmax, B, p_sync=0.4)
                    for _ in range(P)])
    pop[0] = np.full(env.nmax, cm.SYNC, dtype=np.int32); pop[0][0] = B
    pop[1] = naive_uniform_mb(env)
    n_evals = 0
    history = []
    seen_elites: dict[bytes, tuple[float, np.ndarray]] = {}

    for gen in range(cfg.generations):
        out = cm.evaluate_population(env.wl, jnp.asarray(pop), float(B),
                                     float(env.budget_bytes), env.hw)
        n_evals += P
        lat = np.asarray(out.latency); peak = np.asarray(out.peak_mem)
        fit = _fitness(lat, peak, env.budget_bytes)
        order = np.argsort(-fit)
        for idx in order[: cfg.elite]:
            if fit[idx] > -1e3:     # valid
                key = pop[idx, : n + 1].tobytes()
                seen_elites[key] = (float(fit[idx]), pop[idx].copy())
        best = order[0]
        history.append(env.baseline_latency / lat[best]
                       if fit[best] > -1e3 else 0.0)

        # --- next generation ---------------------------------------------
        nxt = [pop[i].copy() for i in order[: cfg.elite]]
        ranks = np.empty(P); ranks[order] = np.arange(P)
        p_sel = (P - ranks) / (P * (P + 1) / 2)
        while len(nxt) < P:
            pa, pb = rng.choice(P, size=2, p=p_sel)
            cut = rng.integers(1, n + 1)
            child = np.concatenate([pop[pa][:cut], pop[pb][cut:]])
            # mutation
            for j in range(n + 1):
                if rng.random() < cfg.p_mut_gene / (n + 1):
                    r = rng.random()
                    if j > 0 and r < cfg.p_sync_mut:
                        child[j] = cm.SYNC if child[j] != cm.SYNC \
                            else int(rng.integers(1, B + 1))
                    elif r < 0.6 and child[j] >= 1:
                        child[j] = int(np.clip(
                            child[j] * (2 if rng.random() < 0.5 else 0.5), 1, B))
                    else:
                        child[j] = int(rng.integers(1, B + 1))
            if child[0] < 1:
                child[0] = int(rng.integers(1, B + 1))
            nxt.append(child)
        brood = _repair_population(env, np.stack(nxt[cfg.elite:]), cfg, rng)
        pop = np.concatenate([np.stack(nxt[: cfg.elite]), brood])

    # final evaluation
    out = cm.evaluate_population(env.wl, jnp.asarray(pop), float(B),
                                 float(env.budget_bytes), env.hw)
    n_evals += P
    lat = np.asarray(out.latency); peak = np.asarray(out.peak_mem)
    fit = _fitness(lat, peak, env.budget_bytes)
    best = int(np.argmax(fit))
    for idx in np.argsort(-fit)[: cfg.elite]:
        if fit[idx] > -1e3:
            key = pop[idx, : n + 1].tobytes()
            seen_elites[key] = (float(fit[idx]), pop[idx].copy())

    elites = [s for _, s in sorted(seen_elites.values(),
                                   key=lambda kv: -kv[0])][:top_k]
    wall = time.perf_counter() - t0
    return GSamplerResult(
        strategy=pop[best].copy(),
        speedup=env.baseline_latency / float(lat[best]),
        latency=float(lat[best]), peak_mem=float(peak[best]),
        valid=bool(fit[best] > -1e3), n_evals=n_evals, wall_s=wall,
        history=history, elites=elites)


# ---------------------------------------------------------------------------
# Device-resident grid G-Sampler (DESIGN.md §10, §11).
#
# The host GA above searches ONE (workload, batch, budget) condition with one
# vmapped fitness call per generation; a teacher corpus needs a whole grid of
# conditions (paper §4.5.1: several memory budgets per workload, §4.6
# generalization: several workloads — and since §11 several ACCELERATORS).
# ``gsampler_search_grid`` runs every condition's population simultaneously:
# selection, crossover, mutation, the constraint-repair operator and the
# fitness evaluations are all jnp over a [C, POP, P] strategy tensor, so the
# ENTIRE evolutionary search — all conditions x populations x generations —
# is one jitted device program with zero host round trips.  Heterogeneity
# (different layer counts, batches, budgets, and per-condition hardware via
# ``accel.stack_hw``) rides the stacked condition axis; padding positions
# stay SYNC.
# ---------------------------------------------------------------------------


@dataclass
class GridTeacherResult:
    """Top-k elite strategies per condition plus their exact costs."""
    strategies: np.ndarray   # [C, K, P] int32
    latency: np.ndarray      # [C, K]
    peak_mem: np.ndarray     # [C, K]
    speedup: np.ndarray      # [C, K]
    valid: np.ndarray        # [C, K] bool
    history: np.ndarray      # [G, C] best valid speedup per generation
    baseline_latency: np.ndarray   # [C]
    n_evals: int
    wall_s: float


def _randint_1_to_B(key, shape, B) -> jax.Array:
    """Uniform int in [1, B] with per-condition (broadcast) B."""
    u = jax.random.uniform(key, shape)
    return (1.0 + jnp.floor(u * B)).astype(jnp.int32)


def _fitness_jnp(latency, peak, budget):
    over = jnp.maximum(0.0, peak / budget - 1.0)
    return jnp.where(over > 0.0, -1e3 * (1.0 + over) - latency, -latency)


def _naive_uniform_grid(wls, batches, budgets, hw, iters: int = 18,
                        evaluator: str = "xla"):
    """Device twin of :func:`naive_uniform_mb`: per-condition binary search
    for the largest uniform micro-batch that stages everything on-chip."""
    C, P = wls["A"].shape
    n = wls["n"]
    pos = jnp.arange(P)
    valid_pos = pos[None, :] <= n[:, None]

    def uniform(mb):
        return jnp.where(valid_pos, mb[:, None], cm.SYNC).astype(jnp.int32)

    fallback = jnp.where(pos[None, :] == 0, 1, cm.SYNC).astype(jnp.int32)
    fallback = jnp.broadcast_to(fallback, (C, P))
    lo = jnp.ones((C,), jnp.int32)
    hi = batches.astype(jnp.int32)

    def body(_, carry):
        lo, hi, best = carry
        done = lo > hi
        mid = jnp.maximum((lo + hi) // 2, 1)
        s = uniform(mid)
        out = cm.evaluate_grid(wls, s[:, None, :], batches, budgets, hw,
                               evaluator=evaluator)
        ok = out.valid[:, 0] & ~done
        best = jnp.where(ok[:, None], s, best)
        lo = jnp.where(done, lo, jnp.where(ok, mid + 1, lo))
        hi = jnp.where(done, hi, jnp.where(ok, hi, mid - 1))
        return lo, hi, best

    _, _, best = jax.lax.fori_loop(0, iters, body, (lo, hi, fallback))
    return best


def _mutate_grid(key, child, valid_pos, n, B, cfg: GSamplerConfig):
    """Fusion-aware mutation, vectorized over [C, K, P] children."""
    C, K, P = child.shape
    pos = jnp.arange(P)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p_gene = cfg.p_mut_gene / (n.astype(jnp.float32) + 1.0)       # [C]
    mut = (jax.random.uniform(k1, (C, K, P)) < p_gene[:, None, None]) \
        & valid_pos[:, None, :]
    r = jax.random.uniform(k2, (C, K, P))
    rand_val = _randint_1_to_B(k3, (C, K, P), B[:, None, None])
    sync_flip = (pos[None, None, :] > 0) & (r < cfg.p_sync_mut)
    flipped = jnp.where(child != cm.SYNC, cm.SYNC, rand_val)
    grow = jax.random.uniform(k4, (C, K, P)) < 0.5
    scaled = jnp.clip(jnp.where(grow, child * 2, child // 2),
                      1, B[:, None, None].astype(jnp.int32))
    scale_ok = (r < 0.6) & (child >= 1)
    new = jnp.where(sync_flip, flipped,
                    jnp.where(scale_ok, scaled, rand_val))
    child = jnp.where(mut, new, child)
    # the input micro-batch (position 0) can never sync
    c0 = child[..., 0]
    child = child.at[..., 0].set(
        jnp.where(c0 < 1, _randint_1_to_B(k5, (C, K), B[:, None]), c0))
    return child


def _repair_grid(key, wls, brood, batches, budgets, hw, cfg: GSamplerConfig,
                 evaluator: str = "xla"):
    """Constraint repair for every condition's brood at once: while a child
    is over budget, split its worst fused group or shrink that group's
    largest staged micro-batch — the same operator as
    :func:`_repair_population`, with the span/argmax logic in jnp."""
    C, K, P = brood.shape
    pos = jnp.arange(P)
    mask = wls["mask"]                                            # [C, P]

    def cond_fn(carry):
        # early exit once the whole brood is within budget (the host GA's
        # `break`): evaluate_grid_stats is the GA's hottest call and most
        # late-generation rounds need zero repair
        _, _, i, pending = carry
        return (i < cfg.repair_tries) & pending

    def round_fn(carry):
        s, key, i, _ = carry
        key, kc = jax.random.split(key)
        out, gid, M_g = cm.evaluate_grid_stats(wls, s, batches, budgets, hw,
                                               evaluator=evaluator)
        invalid = ~out.valid                                      # [C, K]
        worst = jnp.argmax(M_g, axis=-1)                          # [C, K]
        members = (gid == worst[..., None]) & mask[:, None, :]    # [C, K, P]
        start = jnp.argmax(members, axis=-1)
        end = P - 1 - jnp.argmax(members[..., ::-1], axis=-1)
        mid = (start + end) // 2
        multi = end > start
        seg_mb = jnp.where(members & (s > 1), s, 0)
        jmax = jnp.argmax(seg_mb, axis=-1)
        has_mb = jnp.max(seg_mb, axis=-1) > 1
        onehot_mid = pos[None, None, :] == mid[..., None]
        onehot_j = pos[None, None, :] == jmax[..., None]
        split_s = jnp.where(onehot_mid, cm.SYNC, s)               # split group
        shrink_s = jnp.where(onehot_j, jnp.maximum(1, s // 2), s)  # halve stage
        alt_s = jnp.where(multi[..., None] & onehot_mid, cm.SYNC, s)
        shr = jnp.where(has_mb[..., None], shrink_s, alt_s)
        do_split = multi & (jax.random.uniform(kc, (C, K)) < 0.5)
        new = jnp.where(do_split[..., None], split_s, shr)
        apply = invalid & members.any(-1)
        s = jnp.where(apply[..., None], new, s)
        return s, key, i + 1, invalid.any()

    s, _, _, _ = jax.lax.while_loop(
        cond_fn, round_fn, (brood, key, jnp.int32(0), jnp.bool_(True)))
    return s


@functools.partial(jax.jit, static_argnames=("cfg", "top_k", "evaluator"))
def _ga_grid(key, wls, batches, budgets, hw,
             cfg: GSamplerConfig, top_k: int, evaluator: str = "xla"):
    """The whole grid GA as one device program.  Returns stacked elites
    [C, top_k, P] with exact costs, plus the best-valid-speedup history.

    ``evaluator`` selects the fitness/repair backend (DESIGN §13); the
    backends are bit-identical, so the evolved populations — and therefore
    the emitted corpus — do not depend on the choice."""
    C, P = wls["A"].shape
    POP, E = cfg.population, cfg.elite
    n = wls["n"]
    pos = jnp.arange(P)
    valid_pos = pos[None, :] <= n[:, None]
    B = batches.astype(jnp.float32)
    base = cm.baseline_grid(wls, batches, hw).latency             # [C]

    key, k_init, k_sync = jax.random.split(key, 3)
    vals = _randint_1_to_B(k_init, (C, POP, P), B[:, None, None])
    syncs = jax.random.uniform(k_sync, (C, POP, P)) < 0.4
    syncs = syncs.at[:, :, 0].set(False)
    pop = jnp.where(syncs, cm.SYNC, vals)
    pop = jnp.where(valid_pos[:, None, :], pop, cm.SYNC)
    allsync = jnp.where(pos[None, :] == 0,
                        B[:, None].astype(jnp.int32), cm.SYNC)
    pop = pop.at[:, 0, :].set(allsync)
    pop = pop.at[:, 1, :].set(_naive_uniform_grid(wls, batches, budgets, hw,
                                                  evaluator=evaluator))

    def gen(pop, key):
        out = cm.evaluate_grid(wls, pop, batches, budgets, hw,
                               evaluator=evaluator)               # [C, POP]
        fit = _fitness_jnp(out.latency, out.peak_mem, budgets[:, None])
        order = jnp.argsort(-fit, axis=1)
        elites = jnp.take_along_axis(pop, order[:, :E, None], axis=1)
        ranks = jnp.argsort(order, axis=1)
        p_sel = (POP - ranks).astype(jnp.float32) / (POP * (POP + 1) / 2)
        kp, kc, km, kr = jax.random.split(key, 4)
        num = POP - E
        parents = jax.random.categorical(
            kp, jnp.log(p_sel)[:, None, None, :], shape=(C, num, 2))
        pa = jnp.take_along_axis(pop, parents[..., 0][..., None], axis=1)
        pb = jnp.take_along_axis(pop, parents[..., 1][..., None], axis=1)
        cut = 1 + jnp.floor(jax.random.uniform(kc, (C, num))
                            * n[:, None]).astype(jnp.int32)
        child = jnp.where(pos[None, None, :] < cut[..., None], pa, pb)
        child = _mutate_grid(km, child, valid_pos, n, B, cfg)
        brood = _repair_grid(kr, wls, child, batches, budgets, hw, cfg,
                             evaluator=evaluator)
        new_pop = jnp.concatenate([elites, brood], axis=1)
        sp = base[:, None] / jnp.maximum(out.latency, 1e-12)
        best = jnp.max(jnp.where(out.valid, sp, 0.0), axis=1)
        return new_pop, best

    key, k_scan = jax.random.split(key)
    pop, history = jax.lax.scan(gen, pop,
                                jax.random.split(k_scan, cfg.generations))

    out = cm.evaluate_grid(wls, pop, batches, budgets, hw,
                           evaluator=evaluator)
    fit = _fitness_jnp(out.latency, out.peak_mem, budgets[:, None])
    order = jnp.argsort(-fit, axis=1)[:, :top_k]
    take = lambda x: jnp.take_along_axis(x, order, axis=1)
    strategies = jnp.take_along_axis(pop, order[..., None], axis=1)
    lat, peak = take(out.latency), take(out.peak_mem)
    return dict(strategies=strategies, latency=lat, peak_mem=peak,
                valid=take(out.valid) & (take(fit) > -1e3),
                speedup=base[:, None] / jnp.maximum(lat, 1e-12),
                history=history, baseline_latency=base)


def gsampler_search_grid(workloads: list, hw, batches,
                         budgets_bytes, *, nmax: int = 64,
                         cfg: GSamplerConfig = GSamplerConfig(),
                         top_k: int = 8, packed=None,
                         evaluator: str | None = None) -> GridTeacherResult:
    """Search every (workload[c], accel[c], batches[c], budgets_bytes[c])
    condition in one fused device program (the teacher-corpus front door,
    DESIGN §10/§11).

    ``workloads`` entries may repeat (one per memory condition); all
    sequences must have equal length C.  ``hw`` is one ``AccelConfig`` or a
    length-C sequence of them (the §11 hardware axis); an
    already-vectorized form (stacked ``HwVec`` / raw ``[C, F]`` array) is
    accepted too but then ``packed`` is REQUIRED, since packing needs host
    configs.  ``packed`` optionally supplies the ``stack_workloads`` dict
    for the same grid (the corpus pipeline reuses one packing for search
    and decoration); when per-condition accelerators differ, each condition
    must be packed with its own accelerator.  Deterministic for a fixed
    ``cfg.seed`` — the corpus-generation determinism tests rely on it —
    and INDEPENDENT of ``evaluator`` ("xla" | "pallas" | None = the
    ``cost_model`` default): the two fitness backends are bit-identical
    (DESIGN §13), so the same seed yields the same result either way."""
    assert len(workloads) == len(batches) == len(budgets_bytes)
    t0 = time.perf_counter()
    C = len(workloads)
    if isinstance(hw, AccelConfig) or (
            isinstance(hw, (list, tuple)) and not isinstance(hw, HwVec)):
        hws = list(hw) if isinstance(hw, (list, tuple)) else [hw] * C
        assert len(hws) == C
        if packed is None:
            packed = cm.stack_workloads(
                [cm.pack_workload(w, h, nmax) for w, h in zip(workloads,
                                                              hws)])
        hwv = stack_hw(hws, C)
    else:
        # already-vectorized hardware (stacked HwVec / raw [C, F] array):
        # packing needs host AccelConfigs, so the caller must supply it
        if packed is None:
            raise ValueError("vectorized hw (HwVec / raw array) requires "
                             "`packed=` — pack_workload needs AccelConfigs")
        hwv = stack_hw(hw, C)
    wls = packed
    batches = jnp.asarray(np.asarray(batches, np.float32))
    budgets = jnp.asarray(np.asarray(budgets_bytes, np.float32))
    out = _ga_grid(jax.random.PRNGKey(cfg.seed), wls, batches, budgets,
                   hwv, cfg, top_k, cm._resolve_evaluator(evaluator))
    out = {k: np.asarray(v) for k, v in out.items()}
    # upper bound: the repair while_loop exits early once a brood is valid
    n_evals = C * cfg.population * (cfg.generations
                                    * (1 + cfg.repair_tries) + 1)
    return GridTeacherResult(
        strategies=out["strategies"], latency=out["latency"],
        peak_mem=out["peak_mem"], speedup=out["speedup"],
        valid=out["valid"], history=out["history"],
        baseline_latency=out["baseline_latency"], n_evals=n_evals,
        wall_s=time.perf_counter() - t0)
