"""G-Sampler: the paper's search-based teacher (§4.4.2).

GAMMA [ICCAD'20] extended to the layer-fusion map-space: a domain-specific
genetic algorithm with (i) heuristic seeding (all-sync + the naive uniform
micro-batching strategy of paper §3), (ii) fusion-aware mutation operators
(sync flip, micro-batch grow/shrink), and (iii) a constraint-repair operator
that targets the most over-budget fused group — the domain knowledge that
makes it "several orders of magnitude better" than generic optimizers in
the paper's Table 1.

Population fitness is evaluated by ONE vmapped+jitted cost-model call per
generation (see ``cost_model.evaluate_population``); with the default paper
budget (pop 40 x 50 gens = 2k samples) a search takes well under a second —
that is the vectorized-JAX counterpart of the paper's 0.66-1.3 min search.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import AccelConfig

__all__ = ["GSamplerConfig", "GSamplerResult", "gsampler_search", "naive_uniform_mb"]


@dataclass(frozen=True)
class GSamplerConfig:
    population: int = 40          # paper §5.1
    generations: int = 50         # paper §5.1 (=> 2k samples)
    elite: int = 4
    p_mut_gene: float = 3.0       # expected mutated genes per child
    p_sync_mut: float = 0.25
    repair_tries: int = 6
    seed: int = 0


@dataclass
class GSamplerResult:
    strategy: np.ndarray
    speedup: float
    latency: float
    peak_mem: float
    valid: bool
    n_evals: int
    wall_s: float
    history: list = field(default_factory=list)     # best speedup per gen
    elites: list = field(default_factory=list)      # top-k distinct strategies


def naive_uniform_mb(env, max_mb: int | None = None) -> np.ndarray:
    """Paper §3's naive strategy: one uniform micro-batch for the whole net,
    the largest that stages all intermediates on-chip (binary search)."""
    B = env.batch
    hi = max_mb or B
    best = None
    lo = 1
    while lo <= hi:
        mid = (lo + hi) // 2
        s = np.full(env.nmax, cm.SYNC, dtype=np.int32)
        s[: env.n + 1] = mid
        _, peak, valid = env.speedup(s)
        if valid:
            best, lo = s, mid + 1
        else:
            hi = mid - 1
    if best is None:
        best = np.full(env.nmax, cm.SYNC, dtype=np.int32)
        best[0] = 1
    return best


def _repair_population(env, pop: np.ndarray, cfg: GSamplerConfig,
                       rng: np.random.Generator) -> np.ndarray:
    """Constraint repair for a whole brood at once: while any child is over
    budget, split or shrink its worst fused group.

    One vmapped ``cost_model.evaluate_population_stats`` call per repair
    round replaces the pure-Python per-child ``ref_model`` probes (the old
    hot spot: population x repair_tries reference evaluations per
    generation); the returned per-group memory + group-id arrays supply the
    split/shrink targets."""
    s = pop.copy()
    mask = np.asarray(env.wl_np["mask"])
    for _ in range(cfg.repair_tries):
        out, gid, M_g = cm.evaluate_population_stats(
            env.wl, jnp.asarray(s), float(env.batch),
            float(env.budget_bytes), env.hw)
        invalid = ~np.asarray(out.valid)
        if not invalid.any():
            break
        gid = np.asarray(gid)
        M_g = np.asarray(M_g)
        for i in np.where(invalid)[0]:
            worst = int(np.argmax(M_g[i]))
            span = np.where((gid[i] == worst) & mask)[0]
            start, end = int(span[0]), int(span[-1])
            if end > start and rng.random() < 0.5:
                s[i, (start + end) // 2] = cm.SYNC     # split the group
            else:
                seg = s[i, start: end + 1]
                mbs = np.where(seg > 1, seg, 0)
                if mbs.max() > 1:
                    j = start + int(np.argmax(mbs))
                    s[i, j] = max(1, s[i, j] // 2)     # shrink largest stage
                elif end > start:
                    s[i, (start + end) // 2] = cm.SYNC
                # else: single layer already minimal — leave it
    return s


def _fitness(latency: np.ndarray, peak: np.ndarray, budget: float) -> np.ndarray:
    over = np.maximum(0.0, peak / budget - 1.0)
    return np.where(over > 0.0, -1e3 * (1.0 + over) - latency, -latency)


def gsampler_search(env, cfg: GSamplerConfig = GSamplerConfig(),
                    top_k: int = 8) -> GSamplerResult:
    rng = np.random.default_rng(cfg.seed)
    t0 = time.perf_counter()
    P, n, B = cfg.population, env.n, env.batch

    pop = np.stack([cm.random_strategy(rng, n, env.nmax, B, p_sync=0.4)
                    for _ in range(P)])
    pop[0] = np.full(env.nmax, cm.SYNC, dtype=np.int32); pop[0][0] = B
    pop[1] = naive_uniform_mb(env)
    n_evals = 0
    history = []
    seen_elites: dict[bytes, tuple[float, np.ndarray]] = {}

    for gen in range(cfg.generations):
        out = cm.evaluate_population(env.wl, jnp.asarray(pop), float(B),
                                     float(env.budget_bytes), env.hw)
        n_evals += P
        lat = np.asarray(out.latency); peak = np.asarray(out.peak_mem)
        fit = _fitness(lat, peak, env.budget_bytes)
        order = np.argsort(-fit)
        for idx in order[: cfg.elite]:
            if fit[idx] > -1e3:     # valid
                key = pop[idx, : n + 1].tobytes()
                seen_elites[key] = (float(fit[idx]), pop[idx].copy())
        best = order[0]
        history.append(env.baseline_latency / lat[best]
                       if fit[best] > -1e3 else 0.0)

        # --- next generation ---------------------------------------------
        nxt = [pop[i].copy() for i in order[: cfg.elite]]
        ranks = np.empty(P); ranks[order] = np.arange(P)
        p_sel = (P - ranks) / (P * (P + 1) / 2)
        while len(nxt) < P:
            pa, pb = rng.choice(P, size=2, p=p_sel)
            cut = rng.integers(1, n + 1)
            child = np.concatenate([pop[pa][:cut], pop[pb][cut:]])
            # mutation
            for j in range(n + 1):
                if rng.random() < cfg.p_mut_gene / (n + 1):
                    r = rng.random()
                    if j > 0 and r < cfg.p_sync_mut:
                        child[j] = cm.SYNC if child[j] != cm.SYNC \
                            else int(rng.integers(1, B + 1))
                    elif r < 0.6 and child[j] >= 1:
                        child[j] = int(np.clip(
                            child[j] * (2 if rng.random() < 0.5 else 0.5), 1, B))
                    else:
                        child[j] = int(rng.integers(1, B + 1))
            if child[0] < 1:
                child[0] = int(rng.integers(1, B + 1))
            nxt.append(child)
        brood = _repair_population(env, np.stack(nxt[cfg.elite:]), cfg, rng)
        pop = np.concatenate([np.stack(nxt[: cfg.elite]), brood])

    # final evaluation
    out = cm.evaluate_population(env.wl, jnp.asarray(pop), float(B),
                                 float(env.budget_bytes), env.hw)
    n_evals += P
    lat = np.asarray(out.latency); peak = np.asarray(out.peak_mem)
    fit = _fitness(lat, peak, env.budget_bytes)
    best = int(np.argmax(fit))
    for idx in np.argsort(-fit)[: cfg.elite]:
        if fit[idx] > -1e3:
            key = pop[idx, : n + 1].tobytes()
            seen_elites[key] = (float(fit[idx]), pop[idx].copy())

    elites = [s for _, s in sorted(seen_elites.values(),
                                   key=lambda kv: -kv[0])][:top_k]
    wall = time.perf_counter() - t0
    return GSamplerResult(
        strategy=pop[best].copy(),
        speedup=env.baseline_latency / float(lat[best]),
        latency=float(lat[best]), peak_mem=float(peak[best]),
        valid=bool(fit[best] > -1e3), n_evals=n_evals, wall_s=wall,
        history=history, elites=elites)
