"""Imitation-learning trainer for DNNFuser / Seq2Seq (paper §4.5.1 step 3;
DESIGN §10).

Pure-JAX training loop: AdamW + cosine schedule + global-norm clipping,
jitted step with donated (params, opt_state).  With a mesh the step is a
pjit data-parallel program: the (micro)batch axis shards over 'data',
params and optimizer state replicate, and ``grad_accum > 1`` accumulates
gradients over an on-device ``lax.scan`` with a donated carry — the same
pattern the big-model trainer in ``launch/train.py`` uses.

The loop is RESUMABLE and BIT-EXACT: batches are drawn from a per-step
counter-based RNG (a function of (seed, step), not of loop history), and
``ckpt_dir`` wires atomic {params, opt_state} checkpoints through
``checkpoint.Checkpointer`` — restarting from any saved step replays the
identical tail and lands on bit-identical parameters, which the training
smoke test asserts.  Fine-tuning (paper §4.6.2 transfer learning) is
``fine_tune``: the same loop warm-started from pre-trained params (a pytree
or a checkpoint directory) with ~10% of the steps.
"""
from __future__ import annotations

import pathlib
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from ..checkpoint import Checkpointer, restore_subtree

__all__ = ["TrainConfig", "train_model", "make_train_step", "fine_tune",
           "restore_params"]


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 3000
    batch_size: int = 64
    lr: float = 3e-4
    warmup: int = 100
    weight_decay: float = 1e-4
    max_grad_norm: float = 1.0
    seed: int = 0
    log_every: int = 200
    grad_accum: int = 1        # microbatches accumulated per optimizer step
    ckpt_every: int = 0        # save cadence (0 = only the final checkpoint)
    ckpt_keep: int = 3


def make_train_step(loss_fn, tx, mesh=None, grad_accum: int = 1):
    """Returns a jitted ``(params, opt_state, batch) -> (params, opt, loss)``.

    ``loss_fn(params, batch) -> scalar``.  With ``grad_accum > 1`` each
    batch leaf carries leading ``[grad_accum, microbatch]`` axes and the
    gradient accumulates over a ``lax.scan`` whose carry is donated with
    the rest of the step.  With a mesh, the (micro)batch axis is sharded
    over 'data' and params/opt state are replicated (pjit DP).
    """
    def grads_of(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        return optim.accumulated_value_and_grad(loss_fn, params, batch)

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    data = NamedSharding(mesh, P(None, "data") if grad_accum > 1
                         else P("data"))
    return jax.jit(step, donate_argnums=(0, 1),
                   in_shardings=(repl, repl, data), out_shardings=None)


def _step_batch(dataset, cfg: TrainConfig, it: int) -> dict:
    """Batch for step ``it`` from a counter-based RNG: a pure function of
    (seed, step), so a resumed run draws the identical stream."""
    rng = np.random.default_rng([cfg.seed, it])
    b = dataset.sample(rng, cfg.batch_size)
    if cfg.grad_accum > 1:
        if cfg.batch_size % cfg.grad_accum:
            raise ValueError(
                f"batch_size {cfg.batch_size} must divide into grad_accum "
                f"{cfg.grad_accum} microbatches")
        mb, acc = cfg.batch_size // cfg.grad_accum, cfg.grad_accum
        b = {k: np.asarray(v).reshape((acc, mb) + np.asarray(v).shape[1:])
             for k, v in b.items()}
    return {k: jnp.asarray(v) for k, v in b.items()}


def train_model(loss_fn, params, dataset, cfg: TrainConfig = TrainConfig(),
                mesh=None, eval_fn=None, ckpt_dir=None, resume: bool = True,
                crash_at: int | None = None) -> tuple[dict, dict]:
    """Train ``params`` on ``dataset`` (TrajectoryDataset-like .sample()).

    With ``ckpt_dir`` the loop checkpoints {params, opt_state} every
    ``cfg.ckpt_every`` steps (plus a final save) and, when ``resume``, picks
    up from the latest checkpoint on re-entry.  ``crash_at`` stops the loop
    after that step WITHOUT a final save — the fault-injection hook the
    resume tests use.  Returns (params, log); log carries losses,
    ``start_step`` and wall time.
    """
    tx = optim.adamw(optim.cosine_with_warmup(cfg.lr, cfg.warmup, cfg.steps),
                     weight_decay=cfg.weight_decay,
                     max_grad_norm=cfg.max_grad_norm)
    opt_state = tx.init(params)
    start = 0
    ckpt = None
    if ckpt_dir is not None:
        ckpt = Checkpointer(ckpt_dir, keep=cfg.ckpt_keep)
        if resume and ckpt.latest_step() is not None:
            step0, tree = ckpt.restore({"params": params,
                                        "opt_state": opt_state})
            start = min(int(step0), cfg.steps)
            params, opt_state = tree["params"], tree["opt_state"]

    step_fn = make_train_step(loss_fn, tx, mesh, cfg.grad_accum)
    losses, t0 = [], time.perf_counter()
    interrupted = False
    for it in range(start, cfg.steps):
        batch = _step_batch(dataset, cfg, it)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if it % cfg.log_every == 0 or it == cfg.steps - 1:
            losses.append((it, float(loss)))
        done = it + 1
        if ckpt is not None and cfg.ckpt_every \
                and done % cfg.ckpt_every == 0 and done < cfg.steps:
            # snapshot-to-host now, write in the background: the next steps
            # overlap the .npy I/O (the checkpointer's ASYNC property)
            ckpt.save_async(done, {"params": params, "opt_state": opt_state})
        if crash_at is not None and done >= crash_at:
            interrupted = True
            break
    if ckpt is not None:
        if not interrupted and cfg.steps > start:
            ckpt.save(cfg.steps, {"params": params, "opt_state": opt_state})
        ckpt.wait()   # never hand back with a half-written checkpoint
    log = {"losses": losses, "wall_s": time.perf_counter() - t0,
           "final_loss": losses[-1][1] if losses else None,
           "start_step": start}
    if eval_fn is not None:
        log["eval"] = eval_fn(params)
    return params, log


def restore_params(ckpt_dir, template, step: int | None = None):
    """Params-only restore from a {params, opt_state} training checkpoint —
    the warm-start half of a checkpoint, without rebuilding the optimizer."""
    return restore_subtree(Checkpointer(ckpt_dir).path(step), "params",
                           template)


def fine_tune(loss_fn, pretrained, dataset, cfg: TrainConfig, *,
              template=None, mesh=None, eval_fn=None, ckpt_dir=None
              ) -> tuple[dict, dict]:
    """Transfer fine-tuning (paper §4.6.2): warm-start from pre-trained
    params and run the same sharded loop on the new-condition corpus.

    ``pretrained`` is a params pytree or a checkpoint directory (then
    ``template`` supplies the pytree structure, e.g. a fresh ``dt_init``).
    The paper's recipe — ~10% of the pre-training steps, reduced lr — is
    encoded by the caller in ``cfg``.  A fresh optimizer state is built (the
    pre-training optimizer moments do not transfer across conditions)."""
    if isinstance(pretrained, (str, pathlib.Path)):
        if template is None:
            raise ValueError("template params are required to warm-start "
                             "from a checkpoint directory")
        pretrained = restore_params(pretrained, template)
    # real copies (jnp.asarray would alias jax arrays): the train step
    # donates its params, and the caller's pretrained tree must survive
    params = jax.tree.map(lambda x: jnp.array(x, copy=True), pretrained)
    return train_model(loss_fn, params, dataset, cfg, mesh=mesh,
                       eval_fn=eval_fn, ckpt_dir=ckpt_dir, resume=False)
