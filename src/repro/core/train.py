"""Imitation-learning trainer for DNNFuser / Seq2Seq (paper §4.5.1 step 3).

Pure-JAX training loop: AdamW + cosine schedule + global-norm clipping,
jitted step with donated (params, opt_state).  When a mesh is supplied the
batch is sharded over the ``data`` axis and parameters are replicated —
the same pjit pattern the big-model trainer in ``launch/train.py`` uses.
Fine-tuning (paper §4.6.2 transfer learning) is the same loop warm-started
from pre-trained params with ~10% of the steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim

__all__ = ["TrainConfig", "train_model", "make_train_step"]


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 3000
    batch_size: int = 64
    lr: float = 3e-4
    warmup: int = 100
    weight_decay: float = 1e-4
    max_grad_norm: float = 1.0
    seed: int = 0
    log_every: int = 200


def make_train_step(loss_fn, tx, mesh=None):
    """Returns a jitted ``(params, opt_state, batch) -> (params, opt, loss)``.

    ``loss_fn(params, batch) -> scalar``.  With a mesh, batch arrays are
    sharded on their leading axis over 'data' and params replicated.
    """
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    data = NamedSharding(mesh, P("data"))
    return jax.jit(step, donate_argnums=(0, 1),
                   in_shardings=(repl, repl, data), out_shardings=None)


def train_model(loss_fn, params, dataset, cfg: TrainConfig = TrainConfig(),
                mesh=None, eval_fn=None) -> tuple[dict, dict]:
    """Train ``params`` on ``dataset`` (TrajectoryDataset-like .sample()).

    Returns (params, log) where log has losses and wall time.
    """
    tx = optim.adamw(optim.cosine_with_warmup(cfg.lr, cfg.warmup, cfg.steps),
                     weight_decay=cfg.weight_decay,
                     max_grad_norm=cfg.max_grad_norm)
    opt_state = tx.init(params)
    step_fn = make_train_step(loss_fn, tx, mesh)
    rng = np.random.default_rng(cfg.seed)
    losses, t0 = [], time.perf_counter()
    for it in range(cfg.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in dataset.sample(rng, cfg.batch_size).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if it % cfg.log_every == 0 or it == cfg.steps - 1:
            losses.append((it, float(loss)))
    log = {"losses": losses, "wall_s": time.perf_counter() - t0,
           "final_loss": losses[-1][1]}
    if eval_fn is not None:
        log["eval"] = eval_fn(params)
    return params, log
