"""Layer-fusion RL environment (paper §4.2).

One episode = one pass over the N+1 positions of a workload chain.  At step
``t`` the agent picks the micro-batch of position ``t`` (``mb_0`` = input
micro-batch; ``SYNC`` = flush).  The analytical cost model *is* the
environment: states and rewards are computed from prefix evaluations, which
is exactly how DNNFuser rolls out at inference (paper Fig. 3).

State (paper Eq. 2):  ``s_t = [K,C,Y,X,R,S, M_hat, P_{a0..a_{t-1}}]``
 - 6-loop shape of the *current* layer (log-normalized),
 - M_hat: requested on-chip budget, normalized,
 - P: running speedup of the partial strategy over the no-fusion baseline.
Conditioning reward (paper §4.3.3): fraction of the requested buffer still
available given the strategy so far.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import AccelConfig, accel_features

__all__ = ["FusionEnv", "STATE_DIM", "encode_action", "decode_action",
           "encode_action_jnp", "decode_action_jnp", "returns_to_go",
           "EnvConsts", "env_make", "env_reset", "env_observe", "env_step",
           "env_final"]

STATE_DIM = 8
_LOG_CAP = np.log1p(2 ** 24)


def encode_action(a: int | np.ndarray, batch: int) -> np.ndarray:
    """Map {SYNC} u [1..B] -> [-1, 1] for the regression head (DESIGN §3)."""
    a = np.asarray(a, dtype=np.float32)
    return np.where(a < 0, -0.5, a / float(batch)).astype(np.float32)


def decode_action(y: float | np.ndarray, batch: int) -> np.ndarray:
    """Inverse of encode_action with thresholding at 0."""
    y = np.asarray(y, dtype=np.float32)
    mb = np.clip(np.rint(y * batch), 1, batch)
    return np.where(y < 0.0, cm.SYNC, mb).astype(np.int32)


def encode_action_jnp(a: jax.Array, batch: jax.Array) -> jax.Array:
    """Traced twin of :func:`encode_action` (``batch`` may be traced)."""
    a = jnp.asarray(a, jnp.float32)
    return jnp.where(a < 0.0, -0.5, a / batch).astype(jnp.float32)


def decode_action_jnp(y: jax.Array, batch: jax.Array) -> jax.Array:
    """Traced twin of :func:`decode_action` (round-half-even like np.rint)."""
    y = jnp.asarray(y, jnp.float32)
    mb = jnp.clip(jnp.round(y * batch), 1.0, batch)
    return jnp.where(y < 0.0, cm.SYNC, mb).astype(jnp.int32)


def returns_to_go(peak_mem, budget_bytes):
    """The §4.3.3 conditioning / relabel rule: fraction of the requested
    on-chip budget still free after the prefix commits.

    THE single definition — the host env (observation + decoration), the
    device-resident env (``env_observe``) and the grid corpus pipeline
    (``dataset._decorate_grid``) all call it, so a relabel change cannot
    diverge between pipelines.  Dispatches on input type: jax inputs
    (incl. tracers) stay on device; host floats/ndarrays stay NumPy so the
    per-step host-env observation pays no device sync."""
    if isinstance(peak_mem, jax.Array) or isinstance(budget_bytes, jax.Array):
        b = jnp.asarray(budget_bytes, jnp.float32)
        return jnp.maximum(0.0, (b - peak_mem) / b).astype(jnp.float32)
    b = np.float32(budget_bytes)
    return np.maximum(
        np.float32(0.0),
        (b - np.asarray(peak_mem, np.float32)) / b).astype(np.float32)


def _shape_feats(shape6) -> jax.Array:
    """Log-normalized 6-loop shape features (state dims 0..5).

    The model's input contract: both the NumPy reference env and the
    device-resident env_make featurize through this one function."""
    return (jnp.log1p(jnp.asarray(shape6, jnp.float32)) /
            _LOG_CAP).astype(jnp.float32)


def _budget_feat(budget_bytes) -> jax.Array:
    """Log-normalized requested budget (state dim 6); shared like
    :func:`_shape_feats`. ``budget_bytes`` may be traced."""
    b = jnp.asarray(budget_bytes, jnp.float32)
    return (jnp.log1p(b / 2 ** 20) / np.log1p(1024.0)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Pure-JAX environment (DESIGN.md §9).
#
# The device-resident counterpart of :class:`FusionEnv`: the episode state
# is a ``cost_model.PrefixCarry``, the transition an O(1) ``prefix_step``,
# and the observation an O(1) ``prefix_out`` — so a whole rollout fuses into
# one ``jax.lax.scan`` (see ``infer.dnnfuser_infer_fused``) and a stack of
# (batch, budget) serving conditions vmaps over ``env_make``.  FusionEnv
# below stays the NumPy reference path with identical semantics.
# ---------------------------------------------------------------------------


class EnvConsts(NamedTuple):
    pc: cm.PrefixConsts       # also carries B / budget / n (single source)
    base_lat: jax.Array       # no-fusion baseline latency
    shape_feats: jax.Array    # [P, 6] log-normalized 6-loop shapes
    budget_feat: jax.Array

    @property
    def B(self):
        return self.pc.B

    @property
    def budget(self):
        return self.pc.budget

    @property
    def n(self):
        return self.pc.n


def env_make(wl: dict, batch: jax.Array, budget_bytes: jax.Array,
             hw) -> EnvConsts:
    """Build per-condition constants. ``batch``/``budget_bytes`` may be
    traced (vmapped serving conditions), and so may ``hw`` — an
    ``AccelConfig`` or a traced ``accel.HwVec`` (DESIGN §11) — and the
    packed ``wl`` itself, whose arrays vmap per serving row (DESIGN §12).
    A row's true length rides ``wl["n"]``: the fused rollout masks every
    position past it to SYNC and freezes the carry there, so workloads of
    different depth padded to one ``nmax`` roll out bit-exactly."""
    B = jnp.asarray(batch, jnp.float32)
    budget = jnp.asarray(budget_bytes, jnp.float32)
    pc = cm.prefix_consts(wl, B, budget, hw)
    base = cm.baseline_no_fusion(wl, B, hw).latency
    return EnvConsts(pc=pc, base_lat=base,
                     shape_feats=_shape_feats(wl["SHAPE6"]),
                     budget_feat=_budget_feat(budget))


def env_reset(consts: EnvConsts) -> cm.PrefixCarry:
    return cm.prefix_init(consts.pc)


def env_observe(consts: EnvConsts, state: cm.PrefixCarry, hw):
    """(conditioning reward r_hat_t, state vector s_t) — paper Eq. 2."""
    out = cm.prefix_out(consts.pc, state, hw)
    mem_avail = returns_to_go(out.peak_mem, consts.budget)
    perf = consts.base_lat / jnp.maximum(out.latency, 1e-12)
    feats = consts.shape_feats[jnp.minimum(state.t, consts.n)]
    svec = jnp.concatenate([
        feats, consts.budget_feat[None],
        jnp.log1p(perf)[None]]).astype(jnp.float32)
    return mem_avail.astype(jnp.float32), svec


def env_step(consts: EnvConsts, state: cm.PrefixCarry, action,
             hw) -> cm.PrefixCarry:
    """Pure transition: commit ``action`` for position ``state.t``."""
    return cm.prefix_step(consts.pc, state, action, hw)


def env_final(consts: EnvConsts, state: cm.PrefixCarry,
              hw) -> cm.CostOut:
    """Full-strategy CostOut once all n+1 actions are committed."""
    return cm.prefix_out(consts.pc, state, hw)


@dataclass
class FusionEnv:
    """Scalar environment over one (workload, batch, budget) condition."""

    workload: object                 # workloads.Workload
    hw: AccelConfig
    batch: int
    budget_bytes: float
    nmax: int = 64

    def __post_init__(self):
        self.wl = cm.pack_workload(self.workload, self.hw, self.nmax)
        self.wl_np = {k: np.asarray(v) for k, v in self.wl.items()}
        self.n = int(self.workload.n)
        self.shape_feats = np.asarray(_shape_feats(
            np.asarray(self.workload.arrays(self.nmax)["SHAPE6"])))
        self._base = cm.baseline_no_fusion(self.wl, float(self.batch), self.hw)
        self.baseline_latency = float(self._base.latency)
        self._budget_feat = np.float32(_budget_feat(self.budget_bytes))
        # normalized hw condition vector (DESIGN §11) for hw-aware mappers
        self.hw_features = np.asarray(accel_features(self.hw), np.float32)
        self.reset()

    def jax_consts(self) -> EnvConsts:
        """EnvConsts for the device-resident scan rollout over the same
        (workload, batch, budget) condition this reference env models."""
        return env_make(self.wl, float(self.batch), float(self.budget_bytes),
                        self.hw)

    # -- episode API ---------------------------------------------------------
    def reset(self) -> np.ndarray:
        self.t = 0
        self.actions = np.full(self.nmax, cm.SYNC, dtype=np.int32)
        self._last = None  # CostOut of current prefix
        return self._state()

    def _prefix_eval(self) -> cm.CostOut:
        s = jnp.asarray(self.actions)
        pos = jnp.arange(self.nmax)
        s = jnp.where(pos < self.t, s, cm.SYNC)
        return cm.evaluate(self.wl, s, float(self.batch),
                           float(self.budget_bytes), self.hw)

    def _state(self) -> np.ndarray:
        out = self._prefix_eval()
        self._last = out
        peak = float(out.peak_mem)
        lat = float(out.latency)
        mem_avail = float(returns_to_go(peak, self.budget_bytes))
        perf = self.baseline_latency / max(lat, 1e-12)
        st = np.empty(STATE_DIM, dtype=np.float32)
        st[:6] = self.shape_feats[min(self.t, self.n)]
        st[6] = self._budget_feat
        st[7] = np.float32(np.log1p(perf))
        self._mem_avail = np.float32(mem_avail)   # conditioning reward r_hat
        return st

    @property
    def reward_to_go(self) -> float:
        """Conditioning reward r_hat_t: remaining fraction of the budget."""
        return float(self._mem_avail)

    def step(self, action: int) -> tuple[np.ndarray, float, bool]:
        """Apply action for position ``t``. Terminal reward = speedup if the
        full strategy is valid, else a constraint-violation penalty."""
        if self.t > self.n:
            raise RuntimeError("episode finished; call reset()")
        a = int(action)
        if self.t == 0 and a < 1:
            a = 1  # input micro-batch cannot sync
        self.actions[self.t] = a
        self.t += 1
        done = self.t > self.n
        state = self._state()
        reward = 0.0
        if done:
            out = self._last
            lat, peak = float(out.latency), float(out.peak_mem)
            speedup = self.baseline_latency / max(lat, 1e-12)
            if peak <= self.budget_bytes:
                reward = speedup
            else:
                reward = -1.0 * (peak / self.budget_bytes - 1.0)
        return state, reward, done

    # -- whole-strategy helpers ----------------------------------------------
    def evaluate_strategy(self, strategy: np.ndarray) -> cm.CostOut:
        return cm.evaluate(self.wl, jnp.asarray(strategy), float(self.batch),
                           float(self.budget_bytes), self.hw)

    def speedup(self, strategy: np.ndarray) -> tuple[float, float, bool]:
        out = self.evaluate_strategy(strategy)
        return (self.baseline_latency / max(float(out.latency), 1e-12),
                float(out.peak_mem), bool(out.valid))

    def decorate(self, strategy: np.ndarray) -> dict[str, np.ndarray]:
        """Turn a final strategy into a (reward, state, action) trajectory
        for imitation learning (paper §4.5.1 step 2) via one vmapped
        prefix_trace call."""
        tr = cm.prefix_trace(self.wl, jnp.asarray(strategy),
                             float(self.batch), float(self.budget_bytes),
                             self.hw)
        T = self.n + 1
        lat = np.asarray(tr.latency)[:T]
        peak = np.asarray(tr.peak_mem)[:T]
        states = np.zeros((T, STATE_DIM), dtype=np.float32)
        states[:, :6] = self.shape_feats[:T]
        states[:, 6] = self._budget_feat
        states[:, 7] = np.log1p(self.baseline_latency / np.maximum(lat, 1e-12))
        rtg = np.asarray(returns_to_go(peak, self.budget_bytes))
        acts = encode_action(strategy[:T], self.batch)
        return dict(states=states, rtg=rtg, actions=acts,
                    raw_actions=np.asarray(strategy[:T], dtype=np.int32),
                    length=np.int32(T))
