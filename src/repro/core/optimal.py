"""Exact optimal fusion mapper: the repo's absolute ground truth (DESIGN §16).

Every other mapper in the repo — G-Sampler, the DT one-shot mapper, the
Table-1 baselines — is a heuristic over the chain fusion map-space.  This
module solves that space *exactly*: a left-to-right cut-point DP over
fusion-group boundaries with a Pareto-front DP over per-member micro-batch
tilings inside each candidate segment, and dominated-state pruning that is
provably lossless (see DESIGN §16 for the exactness argument).

The DP mirrors ``ref_model.evaluate_ref``'s float64 arithmetic expression
by expression and accumulation order by accumulation order, so its optimum
is BIT-EXACT against brute-force enumeration of every strategy
(``brute_force_optimal``) — the property tests in ``tests/test_optimal.py``
pin that on random chains.  Certification against the *production* f32
evaluators is layered on top: every candidate final cut is evaluated in ONE
vmapped ``evaluate_population`` call (``optimal_grid`` uses one
``evaluate_grid`` call for a whole condition grid) and the DP winner must
also win under f32 — the two may disagree only by rounding, never by the
identity of the optimum.

Entry points
------------
``optimal_mapping(env)``            exact optimum for one FusionEnv
``optimal_search(wl_np, ...)``      same, from packed arrays (host-only)
``optimal_grid(...)``               per-condition optima + ONE
                                    ``evaluate_grid`` certification call
``brute_force_optimal(...)``        exhaustive oracle for small chains
``enumerate_strategies(...)``       the full strategy space as an array
                                    (feed to ``evaluate_population`` to pin
                                    the f32 evaluators against the space)
``scaled_wl_np(wl_np, hw)``         pack-time -> serve-time BPE rescale,
                                    bit-matching ``cost_model._scaled_AW``
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from itertools import product
from typing import Optional

import numpy as np

from . import cost_model as cm
from . import ref_model
from .accel import AccelConfig

SYNC = cm.SYNC
_UTIL_MIN = ref_model._UTIL_MIN
_INF = float("inf")


@dataclass(frozen=True)
class OptimalResult:
    """Certified exact optimum of one (workload, batch, budget, hw) cell.

    ``latency``/``peak_mem``/``traffic`` are the float64 reference-model
    numbers of the argmin strategy (``ref_model.evaluate_ref`` semantics —
    the same arithmetic the DP minimized).  ``valid`` is False only when NO
    strategy fits the budget, in which case ``strategy`` is the all-sync
    fallback (the same contract the search stack uses).  ``certified`` is
    the production f32 ``CostOut`` of the same strategy when certification
    ran, else None."""
    strategy: np.ndarray          # [nmax] int32, padded with SYNC
    latency: float
    peak_mem: float
    traffic: float
    valid: bool
    n_groups: int
    n_states: int                 # peak Pareto-front size (DP effort proxy)
    n_evals: int                  # state expansions + closes, effort proxy
    wall_s: float
    certified: Optional[cm.CostOut] = field(default=None, compare=False)


def scaled_wl_np(wl_np: dict, hw: AccelConfig) -> dict:
    """Host copy of a packed workload with A/W rescaled from the pack-time
    bytes/elem to ``hw``'s — the float32 multiply done exactly like
    ``cost_model._scaled_AW`` so oracle and production evaluators see
    bit-identical byte counts (identity when the BPEs match)."""
    out = {k: np.asarray(v) for k, v in wl_np.items()}
    bpe = out.get("BPE")
    if bpe is not None:
        s = np.float32(hw.bytes_per_elem) / np.asarray(bpe, np.float32)
        out["A"] = np.asarray(out["A"], np.float32) * s
        out["W"] = np.asarray(out["W"], np.float32) * s
        out["BPE"] = np.float32(hw.bytes_per_elem)
    return out


# ---------------------------------------------------------------------------
# member-term arithmetic (float64, expression-for-expression = evaluate_ref)
# ---------------------------------------------------------------------------

class _Chain:
    """Float64 views + hw scalars for one (workload, hw, batch) cell."""

    def __init__(self, wl_np: dict, batch: float, hw: AccelConfig):
        wl = scaled_wl_np(wl_np, hw)
        self.A, self.W, self.F, self.OE, self.UC = (
            np.asarray(wl[k], dtype=np.float64)  # repro: noqa[DET003] -- §16 oracle mirrors ref_model's f64 arithmetic
            for k in ("A", "W", "F", "OE", "UC"))
        self.skip = np.asarray(wl["SKIP"], dtype=np.int64)
        self.n = int(wl["n"])
        self.B = float(batch)
        self.hw = hw
        self.lanes = float(hw.npe * hw.pe_lanes)
        self.peak_macs = float(hw.peak_macs)

    def _same_group(self, i: int, l: int) -> bool:
        # evaluate_ref tests ``crossing iff any sync in [max(src,1), i)``;
        # inside a group positions l..i-1 are non-sync and l-1 is a sync
        # whenever l > 1, so the scan reduces to this closed form.
        src = int(self.skip[i])
        return src >= 0 and (src >= l or l == 1)

    def free_member(self, i: int, l: int, is_r: bool, c: np.ndarray):
        """(comp, t, o, m, w) of a free-micro-batch member ``i`` (interior,
        or the final member of a non-sync-terminated last group when
        ``is_r``) for every candidate micro-batch in ``c`` — same
        expressions, same add order as evaluate_ref's fused-member branch."""
        A, W, F, OE, UC, B = self.A, self.W, self.F, self.OE, self.UC, self.B
        w = np.ceil(B / c)
        m = c * A[i]
        if i == l:
            m = m + c * A[i - 1]
        t = W[i] * w
        if i == l:
            t = t + B * A[i - 1]
        if is_r:
            t = t + B * A[i]
        src = int(self.skip[i])
        if src >= 0:
            if self._same_group(i, l):
                m = m + c * A[src]
            else:
                t = t + 2.0 * B * A[src]
        util = np.minimum(np.maximum(c * OE[i] / self.lanes, _UTIL_MIN),
                          UC[i])
        comp = B * F[i] / self.peak_macs / util
        o = np.full_like(c, B * (A[i - 1] + A[i])) + W[i] * w
        return comp, t, o, m, w

    def sync_tail(self, r: int, l: int, p: np.ndarray):
        """Terms of the SYNC member closing fused group [l..r], riding its
        producer's micro-batch ``p`` (stage = 1)."""
        A, W, F, OE, UC, B = self.A, self.W, self.F, self.OE, self.UC, self.B
        w = np.ceil(B / p)
        m = np.full_like(p, 1.0 * A[r])          # stage * A[r], stage = 1
        t = W[r] * w
        t = t + B * A[r]                         # tail flush (i == r)
        src = int(self.skip[r])
        if src >= 0:
            if self._same_group(r, l):
                m = m + p * A[src]
            else:
                t = t + 2.0 * B * A[src]
        util = np.minimum(np.maximum(p * OE[r] / self.lanes, _UTIL_MIN),
                          UC[r])
        comp = B * F[r] / self.peak_macs / util
        o = np.full_like(p, B * (A[r - 1] + A[r])) + W[r] * w
        return comp, t, o, m, w

    def singleton(self, i: int) -> tuple[float, float]:
        """(latency, mem) of the isolated group {i} with stage = 1 (the
        SYNC variant — its non-sync twin, which only exists at i == n, has
        identical latency and >= mem, so it can never beat it)."""
        A, W, F, OE, UC, B = self.A, self.W, self.F, self.OE, self.UC, self.B
        hw = self.hw
        m = 1.0 * A[i]
        m = m + B * A[i - 1]                     # head term, mbe = B
        t = W[i] * 1
        t = t + B * A[i - 1]
        t = t + B * A[i]
        src = int(self.skip[i])
        if src >= 0:
            if self._same_group(i, i):           # l == i for a singleton
                m = m + B * A[src]
            else:
                t = t + 2.0 * B * A[src]
        m = min(m, float(hw.stream_buf_bytes))
        util = min(max(B * OE[i] / self.lanes, _UTIL_MIN), float(UC[i]))
        comp = B * F[i] / self.peak_macs / util
        o = B * (A[i - 1] + A[i]) + W[i] * 1
        lat = max(comp, t / hw.bw_offchip, o / hw.bw_onchip) \
            + 1 * hw.t_pass + hw.t_sync
        return float(lat), float(m)

    def group_latency(self, vec: np.ndarray) -> np.ndarray:
        """L_g from accumulated (comp, traffic, onchip, mem, waves) rows."""
        hw = self.hw
        lat = np.maximum(np.maximum(vec[:, 0], vec[:, 1] / hw.bw_offchip),
                         vec[:, 2] / hw.bw_onchip)
        return lat + vec[:, 4] * hw.t_pass + hw.t_sync


def _pareto_keep(aug: np.ndarray, cap: int) -> np.ndarray:
    """Indices of the Pareto-minimal rows of ``aug`` (componentwise <=).

    Lossless: a row is dropped only when a kept row is <= in EVERY
    component and differs somewhere — any completion of the dominating row
    is then <= the dominated one's, so the optimum survives.  Exact
    duplicates collapse to one representative.  ``cap`` is a safety valve:
    an over-``cap`` front RAISES rather than silently approximating.

    After deduplication, ``a dominates b`` implies ``sum(a) < sum(b)``
    (<= everywhere + < somewhere), so rows are processed in component-sum
    order and each chunk is only checked against the already-kept front
    plus itself — O(K * front) instead of O(K^2)."""
    uniq, first = np.unique(aug, axis=0, return_index=True)
    order = np.argsort(uniq.sum(axis=1), kind="stable")
    rows = uniq[order]
    kept_rows = np.empty((0, rows.shape[1]))
    kept_idx: list[np.ndarray] = []
    CH = 2048
    for s in range(0, len(rows), CH):
        blk = rows[s:s + CH]
        sel = order[s:s + CH]
        if len(kept_rows):
            dom = np.zeros(len(blk), dtype=bool)
            for fs in range(0, len(kept_rows), 4096):
                fr = kept_rows[fs:fs + 4096]
                dom |= (fr[None, :, :] <= blk[:, None, :]).all(-1).any(1)
            blk, sel = blk[~dom], sel[~dom]
        if not len(blk):
            continue
        le = (blk[None, :, :] <= blk[:, None, :]).all(-1)
        np.fill_diagonal(le, False)
        inner = le.any(1)
        kept_rows = np.concatenate([kept_rows, blk[~inner]])
        kept_idx.append(sel[~inner])
        if len(kept_rows) > cap:
            raise RuntimeError(
                f"optimal-DP Pareto front exploded (> cap={cap}); raise "
                "front_cap= for this workload instead of approximating")
    idx = first[np.concatenate(kept_idx)] if kept_idx else first[:0]
    return np.sort(idx)


# ---------------------------------------------------------------------------
# branch-and-bound machinery (lossless: prune only on strict LB > UB)
# ---------------------------------------------------------------------------

_LB_SLACK = 1.0 - 1e-12      # guards against LB summation-order rounding


def _bounds_for_l(ch: _Chain, l: int, budget: float) -> dict:
    """Per-``l`` B&B tables: componentwise LOWER bounds on what future
    members/tails must still add to a partial group, and incumbent UPPER
    bounds from uniform tilings evaluated with the exact DP arithmetic
    (so every UB is a true achievable segment cost, never below the
    optimum — the strict-inequality prune is therefore lossless)."""
    n, B = ch.n, ch.B
    cand = np.arange(1.0, B + 1.0, dtype=np.float64)  # repro: noqa[DET003] -- §16 oracle tile grid, exact in f64
    # cum[m] = sum of per-member componentwise minima over l+1..m
    cum = np.zeros((n + 1, 5))
    acc = np.zeros(5)
    for j in range(l + 1, n):
        acc = acc + np.array([t.min() for t in
                              ch.free_member(j, l, False, cand)])
        cum[j] = acc
    tailmin = np.zeros((n + 1, 5))
    for r in range(l + 1, n + 1):
        tailmin[r] = [t.min() for t in ch.sync_tail(r, l, cand)]
    finmin = (np.array([t.min() for t in ch.free_member(n, l, True, cand)])
              if n > l else None)

    # incumbents: exact cost of uniform tilings (all members at mb = u)
    U = np.array(sorted({float(u) for u in (1, 2, 4, 8, 16, 32, 64, B)
                         if 1 <= u <= B}))
    UB = np.full(n + 1, _INF)
    UBfin = _INF
    acc = np.zeros((len(U), 5))
    for i in range(l, n):
        acc = acc + np.stack(ch.free_member(i, l, False, U), axis=1)
        r = i + 1
        closed = acc + np.stack(ch.sync_tail(r, l, U), axis=1)
        ok = closed[:, 3] <= budget
        if ok.any():
            UB[r] = ch.group_latency(closed[ok]).min()
        if r == n:
            fin = acc + np.stack(ch.free_member(n, l, True, U), axis=1)
            ok = fin[:, 3] <= budget
            if ok.any():
                UBfin = ch.group_latency(fin[ok]).min()
    return dict(cum=cum, tailmin=tailmin, finmin=finmin, UB=UB, UBfin=UBfin)


def _bnb_keep(ch: _Chain, bounds: dict, m: int, vec: np.ndarray,
              budget: float) -> np.ndarray:
    """True for states (interior through member ``m``) that can still beat
    SOME remaining close's incumbent: exists r > m with
    LB(state -> close at r) <= UB[r] and the minimal future memory fits."""
    n = ch.n
    hw = ch.hw
    adds, ubs = [], []
    for r in range(m + 1, n + 1):
        adds.append(bounds["cum"][r - 1] - bounds["cum"][m]
                    + bounds["tailmin"][r])
        ubs.append(bounds["UB"][r])
    if bounds["finmin"] is not None and m < n:
        adds.append(bounds["cum"][n - 1] - bounds["cum"][m]
                    + bounds["finmin"])
        ubs.append(bounds["UBfin"])
    if not adds:
        return np.ones(len(vec), dtype=bool)
    adds = np.stack(adds)                       # [R, 5]
    ubs = np.asarray(ubs)                       # [R]
    keep = np.zeros(len(vec), dtype=bool)
    for s in range(0, len(vec), 65536):
        v = vec[s:s + 65536]
        C = v[:, 0, None] + adds[None, :, 0]
        T = v[:, 1, None] + adds[None, :, 1]
        O = v[:, 2, None] + adds[None, :, 2]
        M = v[:, 3, None] + adds[None, :, 3]
        Wv = v[:, 4, None] + adds[None, :, 4]
        lb = np.maximum(np.maximum(C, T / hw.bw_offchip),
                        O / hw.bw_onchip) + Wv * hw.t_pass + hw.t_sync
        ok = (lb * _LB_SLACK <= ubs[None, :]) & \
             (M <= budget * (1.0 + 1e-12))
        keep[s:s + 65536] = ok.any(1)
    return keep


# ---------------------------------------------------------------------------
# the exact DP
# ---------------------------------------------------------------------------

def _solve(ch: _Chain, budget: float, front_cap: int) -> dict:
    """All-pairs optimal segments + prefix cut-point DP.

    Returns the internals (dp table, backpointers, per-segment optimal
    latency and tiling, effort counters) so wrappers can reconstruct the
    argmin strategy for any final cut."""
    n, B = ch.n, ch.B
    cand_all = np.arange(1.0, B + 1.0, dtype=np.float64)  # repro: noqa[DET003] -- §16 oracle tile grid, exact in f64
    segL = np.full((n + 2, n + 2), _INF)
    seg_tiling: dict[tuple[int, int], np.ndarray] = {}
    max_front, n_evals = 0, 0

    for l in range(1, n + 1):
        lat_s, mem_s = ch.singleton(l)
        if mem_s <= budget:
            segL[l, l] = lat_s
            seg_tiling[(l, l)] = np.array([SYNC], dtype=np.int64)
        bounds = _bounds_for_l(ch, l, budget) if l < n else None

        # Pareto states over the interior members l..m-1 of a growing
        # fused group: ``vec`` columns = accumulated (comp, traffic,
        # onchip, mem, waves); ``mbs`` = the LAST member's micro-batch
        # (the sync tail rides it); ``hist`` records (parent, mb) per
        # extension for path reconstruction.
        vec = np.zeros((1, 5))
        mbs = np.zeros(1)
        hist: list[tuple[np.ndarray, np.ndarray]] = []

        for m in range(l, n + 1):
            if m > l and len(vec):
                # close [l..m] with a SYNC tail riding each state's last mb
                tc, tt, to, tm, tw = ch.sync_tail(m, l, mbs)
                closed = vec + np.stack([tc, tt, to, tm, tw], axis=1)
                n_evals += len(vec)
                ok = closed[:, 3] <= budget
                if ok.any():
                    lat = ch.group_latency(closed[ok])
                    j = int(np.argmin(lat))
                    if lat[j] < segL[l, m]:
                        segL[l, m] = lat[j]
                        win = int(np.flatnonzero(ok)[j])
                        seg_tiling[(l, m)] = _backtrack(hist, win, True)
                if m == n:
                    # non-SYNC-terminated final group [l..n]: member n is
                    # a free-mb member that also flushes its output
                    fc = _prune_cand(ch.free_member(n, l, True, cand_all),
                                     cand_all, None, front_cap)
                    (c_, t_, o_, m_, w_), cmb = fc
                    ext = vec[:, None, :] + np.stack(
                        [c_, t_, o_, m_, w_], axis=1)[None, :, :]
                    ext = ext.reshape(-1, 5)
                    n_evals += len(ext)
                    ok = ext[:, 3] <= budget
                    if ok.any():
                        lat = ch.group_latency(ext[ok])
                        j = int(np.argmin(lat))
                        if lat[j] < segL[l, n]:
                            flat = int(np.flatnonzero(ok)[j])
                            st, ci = divmod(flat, len(cmb))
                            tl = _backtrack(hist, st, False)
                            seg_tiling[(l, n)] = np.concatenate(
                                [tl, [np.int64(cmb[ci])]])
                            segL[l, n] = lat[j]
            if m == n:
                break

            # extend the interior with member m; candidate micro-batches
            # pre-pruned under the same augmented dominance as states
            fc = _prune_cand(ch.free_member(m, l, False, cand_all),
                             cand_all, ch.sync_tail(m + 1, l, cand_all),
                             front_cap)
            (c_, t_, o_, m_, w_), cmb = fc
            new = vec[:, None, :] + np.stack(
                [c_, t_, o_, m_, w_], axis=1)[None, :, :]
            new = new.reshape(-1, 5)
            par = np.repeat(np.arange(len(vec)), len(cmb))
            chosen = np.tile(cmb, len(vec))
            n_evals += len(new)
            feas = new[:, 3] <= budget
            new, par, chosen = new[feas], par[feas], chosen[feas]
            if len(new):
                bk = _bnb_keep(ch, bounds, m, new, budget)
                new, par, chosen = new[bk], par[bk], chosen[bk]
            if len(new):
                # augmented dominance: base accumulators + what the NEXT
                # sync tail (position m+1, the only close that still reads
                # this member's mb) would add as a function of it —
                # lossless, see DESIGN §16.
                tc, _, _, tm, tw = ch.sync_tail(m + 1, l, chosen)
                aug = np.concatenate(
                    [new, np.stack([tw, tc, tm], axis=1)], axis=1)
                idx = _pareto_keep(aug, front_cap)
                vec, mbs = new[idx], chosen[idx]
                hist.append((par[idx], mbs.copy()))
                max_front = max(max_front, len(idx))
            else:
                vec = np.zeros((0, 5))
                mbs = np.zeros(0)
                hist.append((np.zeros(0, dtype=np.int64), np.zeros(0)))

    # prefix DP over segment ends: dp[r] = min_l dp[l-1] + segL[l, r]
    dp = np.full(n + 1, _INF)
    back = np.zeros(n + 1, dtype=np.int64)
    dp[0] = 0.0
    for r in range(1, n + 1):
        for l in range(1, r + 1):
            if dp[l - 1] < _INF and segL[l, r] < _INF:
                lat = dp[l - 1] + segL[l, r]
                if lat < dp[r]:
                    dp[r] = lat
                    back[r] = l
    return dict(dp=dp, back=back, segL=segL, seg_tiling=seg_tiling,
                max_front=max_front, n_evals=n_evals)


def _prune_cand(terms, cand: np.ndarray, tail, cap: int):
    """Pareto-prune per-member micro-batch candidates.  ``tail`` carries
    the would-be sync-tail terms at the next position as a function of the
    candidate (None for the final member, whose mb has no future)."""
    c_, t_, o_, m_, w_ = terms
    base = np.stack([c_, t_, o_, m_, w_], axis=1)
    if tail is None:
        aug = base
    else:
        tc, _, _, tm, tw = tail
        aug = np.concatenate([base, np.stack([tw, tc, tm], axis=1)], axis=1)
    idx = _pareto_keep(aug, cap)
    return tuple(x[idx] for x in (c_, t_, o_, m_, w_)), cand[idx]


def _backtrack(hist, last_idx: int, tail_sync: bool) -> np.ndarray:
    """Interior member micro-batches ending at state ``last_idx`` of the
    latest front, walking the (parent, mb) records backwards."""
    out = []
    idx = int(last_idx)
    for par, mb in reversed(hist):
        out.append(np.int64(mb[idx]))
        idx = int(par[idx])
    out.reverse()
    if tail_sync:
        out.append(np.int64(SYNC))
    return np.asarray(out, dtype=np.int64)


def _assemble(sol: dict, nmax: int, batch: float, upto: int) -> np.ndarray:
    """Strategy vector of the DP-optimal segmentation of layers 1..upto."""
    s = np.full(nmax, SYNC, dtype=np.int32)
    s[0] = int(batch)
    r = upto
    while r >= 1:
        l = int(sol["back"][r])
        s[l:r + 1] = sol["seg_tiling"][(l, r)].astype(np.int32)
        r = l - 1
    return s


def _result_from_sol(wl_np: dict, ch: _Chain, budget: float, nmax: int,
                     sol: dict, t0: float) -> OptimalResult:
    n = ch.n
    feasible = sol["dp"][n] < _INF
    if feasible:
        strategy = _assemble(sol, nmax, ch.B, n)
    else:
        strategy = np.full(nmax, SYNC, dtype=np.int32)
        strategy[0] = int(ch.B)
    ref = ref_model.evaluate_ref(
        scaled_wl_np(wl_np, ch.hw), strategy, ch.B, budget, ch.hw)
    if feasible:
        if ref["latency"] != sol["dp"][n] or not ref["valid"]:
            raise AssertionError(
                "optimal-DP self-check failed: reconstructed strategy "
                f"re-evaluates to {ref['latency']!r} (valid={ref['valid']})"
                f" but the DP claims {sol['dp'][n]!r} — the DP arithmetic "
                "has drifted from ref_model.evaluate_ref")
    elif ref["valid"]:
        raise AssertionError(
            "optimal-DP claims the budget is infeasible but the all-sync "
            "fallback fits — the per-segment feasibility test has drifted")
    return OptimalResult(
        strategy=strategy, latency=float(ref["latency"]),
        peak_mem=float(ref["peak_mem"]), traffic=float(ref["traffic"]),
        valid=bool(ref["valid"]), n_groups=int(ref["n_groups"]),
        n_states=int(sol["max_front"]), n_evals=int(sol["n_evals"]),
        wall_s=time.perf_counter() - t0)


def optimal_search(wl_np: dict, batch: float, budget_bytes: float,
                   hw: AccelConfig, nmax: int | None = None, *,
                   front_cap: int = 4096) -> OptimalResult:
    """Exact optimum from packed host arrays — float64, no JAX.

    If no strategy fits the budget the all-sync fallback is returned with
    ``valid=False`` (same contract as the search stack)."""
    t0 = time.perf_counter()
    ch = _Chain(wl_np, batch, hw)
    nmax = nmax or len(ch.A)
    sol = _solve(ch, float(budget_bytes), front_cap)
    return _result_from_sol(wl_np, ch, float(budget_bytes), nmax, sol, t0)


def optimal_mapping(env, *, certify: bool = True,
                    front_cap: int = 4096) -> OptimalResult:
    """Exact optimum for one ``FusionEnv`` condition, optionally certified
    against the production f32 evaluator.

    Certification composes every candidate final cut — the DP-optimal
    prefix through l-1 glued to the optimal last segment [l..n], for every
    feasible l — and evaluates ALL of them in ONE vmapped
    ``evaluate_population`` call: the DP's winner must also win under f32
    (within rounding).  This is the 'vmapped segment evaluation over
    candidate cuts' leg of DESIGN §16."""
    t0 = time.perf_counter()
    ch = _Chain(env.wl_np, env.batch, env.hw)
    budget = float(env.budget_bytes)
    sol = _solve(ch, budget, front_cap)
    base = _result_from_sol(env.wl_np, ch, budget, env.nmax, sol, t0)
    if not (certify and base.valid):
        return base
    n = ch.n
    cuts = [l for l in range(1, n + 1)
            if sol["dp"][l - 1] < _INF and sol["segL"][l, n] < _INF]
    win = cuts.index(int(sol["back"][n]))
    pop = np.stack([_compose_cut(sol, l, n, env.nmax, ch.B) for l in cuts])
    # pad to a fixed population size so repeated per-condition calls hit
    # one compiled program (pad rows duplicate the winner: min unchanged)
    if len(pop) < env.nmax:
        pad = np.repeat(pop[win][None], env.nmax - len(pop), axis=0)
        pop = np.concatenate([pop, pad], axis=0)
    out = cm.evaluate_population(env.wl, np.asarray(pop), float(ch.B),
                                 budget, env.hw)
    lats = np.asarray(out.latency, dtype=np.float64)  # repro: noqa[DET003] -- f32-certification readback widened host-side (§16)
    # f32 may reorder near-ties among cuts, but never beyond rounding
    if lats[win] > lats.min() * (1.0 + 1e-5):
        raise AssertionError(
            f"certification failed: DP winner (cut l={cuts[win]}) has f32 "
            f"latency {lats[win]:.6e} but another cut achieves "
            f"{lats.min():.6e} — beyond f32 rounding of a true tie")
    certified = cm.CostOut(*(np.asarray(x)[win] for x in out))
    return OptimalResult(
        strategy=base.strategy, latency=base.latency,
        peak_mem=base.peak_mem, traffic=base.traffic, valid=base.valid,
        n_groups=base.n_groups, n_states=base.n_states,
        n_evals=base.n_evals + len(cuts),
        wall_s=time.perf_counter() - t0, certified=certified)


def _compose_cut(sol: dict, l: int, n: int, nmax: int,
                 batch: float) -> np.ndarray:
    """DP-optimal prefix through l-1 + optimal final segment [l..n]."""
    s = _assemble(sol, nmax, batch, upto=l - 1)
    s[l:n + 1] = sol["seg_tiling"][(l, n)].astype(np.int32)
    return s


def optimal_grid(workloads, hws, batches, budgets_bytes, *,
                 nmax: int = 64, front_cap: int = 4096,
                 certify: bool = True) -> list[OptimalResult]:
    """Exact optima for an aligned condition list, certified in ONE
    ``evaluate_grid`` device call (the grid counterpart of
    ``optimal_mapping``'s population certification).

    ``workloads``/``hws``/``batches``/``budgets_bytes`` are equal-length
    lists; entry c is one (workload, accelerator, batch, budget) cell."""
    C = len(workloads)
    assert len(hws) == len(batches) == len(budgets_bytes) == C
    packs = [cm.pack_workload(w, a, nmax) for w, a in zip(workloads, hws)]
    results = [optimal_search({k: np.asarray(v) for k, v in p.items()},
                              b, g, a, nmax, front_cap=front_cap)
               for p, a, b, g in zip(packs, hws, batches, budgets_bytes)]
    if not certify:
        return results
    stacked = cm.stack_workloads(packs)
    strategies = np.stack([r.strategy for r in results])[:, None, :]
    out = cm.evaluate_grid(stacked, np.asarray(strategies),
                           np.asarray(batches, np.float32),
                           np.asarray(budgets_bytes, np.float32), hws)
    certified = []
    for c, r in enumerate(results):
        cell = cm.CostOut(*(np.asarray(x)[c, 0] for x in out))
        if r.valid:
            rel = abs(float(cell.latency) - r.latency) / max(r.latency,
                                                             1e-30)
            if rel > 1e-4:
                raise AssertionError(
                    f"grid certification: condition {c} f32/f64 latency "
                    f"drift {rel:.2e} exceeds rounding tolerance")
            if float(cell.peak_mem) > budgets_bytes[c] * (1.0 + 1e-5):
                raise AssertionError(
                    f"grid certification: condition {c} optimal strategy "
                    "is budget-valid in f64 but violates the budget by "
                    "more than f32 rounding under the production evaluator")
        certified.append(OptimalResult(
            strategy=r.strategy, latency=r.latency, peak_mem=r.peak_mem,
            traffic=r.traffic, valid=r.valid, n_groups=r.n_groups,
            n_states=r.n_states, n_evals=r.n_evals, wall_s=r.wall_s,
            certified=cell))
    return certified


# ---------------------------------------------------------------------------
# brute force (the DP's own oracle)
# ---------------------------------------------------------------------------

def enumerate_strategies(n: int, batch: int, nmax: int, *,
                         mb_values=None, limit: int = 2_000_000
                         ) -> np.ndarray:
    """Every strategy of an n-layer chain as an int32 array [S, nmax]:
    positions 1..n range over {SYNC} U mb_values (default 1..batch),
    position 0 is pinned to ``batch`` (its value is cost-irrelevant — the
    property tests verify that too).  Raises if the space exceeds
    ``limit`` rows: this is an oracle for SMALL chains by construction."""
    vals = ([SYNC] + list(range(1, int(batch) + 1)) if mb_values is None
            else [SYNC] + [int(v) for v in mb_values])
    S = len(vals) ** n
    if S > limit:
        raise ValueError(f"strategy space {S} exceeds limit={limit}; "
                         "shrink n/batch or pass mb_values")
    out = np.full((S, nmax), SYNC, dtype=np.int32)
    out[:, 0] = int(batch)
    for row, combo in enumerate(product(vals, repeat=n)):
        out[row, 1:n + 1] = combo
    return out


def brute_force_optimal(wl_np: dict, batch: float, budget_bytes: float,
                        hw: AccelConfig, nmax: int | None = None, *,
                        mb_values=None, limit: int = 300_000
                        ) -> OptimalResult:
    """Exhaustive float64 optimum via ``ref_model.evaluate_ref`` — the
    independent ground truth the DP is pinned against, with the identical
    infeasible-budget fallback contract."""
    t0 = time.perf_counter()
    wl = scaled_wl_np(wl_np, hw)      # ref takes byte arrays as-is
    n = int(wl["n"])
    nmax = nmax or len(np.asarray(wl["A"]))
    pop = enumerate_strategies(n, int(batch), nmax, mb_values=mb_values,
                               limit=limit)
    best = None
    for s in pop:
        r = ref_model.evaluate_ref(wl, s, float(batch),
                                   float(budget_bytes), hw)
        if r["valid"] and (best is None or r["latency"] < best[0]):
            best = (r["latency"], s, r)
    if best is None:
        s = np.full(nmax, SYNC, dtype=np.int32)
        s[0] = int(batch)
        r = ref_model.evaluate_ref(wl, s, float(batch),
                                   float(budget_bytes), hw)
        best = (r["latency"], s, r)
    lat, s, r = best
    return OptimalResult(
        strategy=np.asarray(s, dtype=np.int32), latency=float(lat),
        peak_mem=float(r["peak_mem"]), traffic=float(r["traffic"]),
        valid=bool(r["valid"]), n_groups=int(r["n_groups"]),
        n_states=len(pop), n_evals=len(pop),
        wall_s=time.perf_counter() - t0)
