"""Accelerator model: config, zoo presets, and the traced hardware vector.

The paper's configuration (§5.1): 1024 PEs, 64 MB on-chip buffer, 900 GB/s
off-chip BW, 9000 GB/s on-chip BW, 1 GHz.

Hardware-adaptation note (see DESIGN.md §4): taken literally (1 MAC/PE/cycle
= 2 GOPS against 900 GB/s) every CNN in the paper sits ~200x inside the
compute-bound roofline region, where layer fusion cannot produce the
reported 1.2x-3.1x speedups; and the paper's own Fig. 4 strategies
(micro-batch 36 staged under a 20 MB budget on ResNet18) are only
memory-consistent with 1-byte activations and an activation-only buffer
constraint.  We therefore model the paper's *observed regime*: an edge-class
int8 accelerator (1024 PEs x 4-lane vector MAC = 8.2 TOPS, LPDDR-class
8 GB/s off-chip, 40 GB/s on-chip), activations quantized to 1 byte, the on-chip buffer constraint
applying to staged activations (a separate streaming path feeds weights,
re-fetched once per micro-batch wave).  All constants are config fields.

Hardware as a CONDITION (DESIGN.md §11): the mapper generalizes over
accelerators, so the hardware descriptor must be *data*, not a baked-in
constant.  Three representations, all interconvertible:

 - :class:`AccelConfig` — the frozen host-side dataclass (Python floats);
 - :class:`HwVec` — the same fields as a NamedTuple of ``jnp`` scalars (a
   pytree), so the cost model traces through it and ``vmap`` runs over a
   *batch of accelerators*; ``stack_hw`` builds the per-condition form;
 - ``accel_features`` — a normalized (log-range, each field mapped to
   [0, 1]) feature vector that conditions the learned mapper; it is
   invertible (``accel_from_features``) so checkpoints carry no hidden
   normalization state.

``ACCEL_ZOO`` holds named design points spanning embedded to
datacenter-class devices — the train/hold-out axis of the
hardware-generalization benchmark (``benchmarks/table_hw_generalization``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AccelConfig", "PAPER_ACCEL", "ACCEL_ZOO", "HwVec", "HW_FIELDS",
           "HW_FEATURE_DIM", "as_hw", "stack_hw", "hw_array",
           "hw_from_array", "accel_features", "accel_from_features"]

MB = float(2 ** 20)


@dataclass(frozen=True)
class AccelConfig:
    npe: int = 1024                  # PEs (paper §5.1)
    pe_lanes: int = 4                # vector MACs per PE (adaptation, DESIGN §4)
    freq_hz: float = 1e9             # 1 GHz
    bw_offchip: float = 8e9          # bytes/s (LPDDR-class edge device)
    bw_onchip: float = 40e9          # bytes/s (5:1 on:off, see DESIGN §4)
    buf_bytes: float = 64 * MB       # on-chip activation buffer
    bytes_per_elem: float = 1.0      # int8 tensors (edge inference)
    t_pass: float = 5e-6             # per-wave pipeline restart overhead (s)
    t_sync: float = 20e-6            # per-group off-chip sync/drain cost (s)
    stream_buf_bytes: float = 2 * MB  # act working set of an unfused layer
    name: str = "edge"               # zoo identity (not part of the hw vector)

    @property
    def peak_macs(self) -> float:
        return self.npe * self.pe_lanes * self.freq_hz

    def with_buffer_mb(self, mb: float) -> "AccelConfig":
        return replace(self, buf_bytes=mb * MB)


PAPER_ACCEL = AccelConfig()

# Named design points for hardware generalization (DESIGN.md §11).  "edge"
# is the paper-observed regime above; the others sweep compute, bandwidth,
# buffering and datatype across realistic device classes so the learned
# mapper sees genuinely different roofline/buffer trade-offs.
ACCEL_ZOO: dict[str, AccelConfig] = {
    "edge": PAPER_ACCEL,
    "nano": AccelConfig(
        name="nano", npe=256, pe_lanes=2, freq_hz=8e8, bw_offchip=4e9,
        bw_onchip=16e9, buf_bytes=8 * MB, bytes_per_elem=1.0, t_pass=5e-6,
        t_sync=30e-6, stream_buf_bytes=1 * MB),
    "mobile": AccelConfig(
        name="mobile", npe=2048, pe_lanes=4, freq_hz=1e9, bw_offchip=25.6e9,
        bw_onchip=128e9, buf_bytes=32 * MB, bytes_per_elem=1.0, t_pass=4e-6,
        t_sync=15e-6, stream_buf_bytes=2 * MB),
    "laptop": AccelConfig(
        name="laptop", npe=4096, pe_lanes=4, freq_hz=1.2e9, bw_offchip=68e9,
        bw_onchip=400e9, buf_bytes=96 * MB, bytes_per_elem=1.0, t_pass=3e-6,
        t_sync=12e-6, stream_buf_bytes=4 * MB),
    "datacenter": AccelConfig(
        name="datacenter", npe=16384, pe_lanes=8, freq_hz=1.5e9,
        bw_offchip=300e9, bw_onchip=2400e9, buf_bytes=192 * MB,
        bytes_per_elem=2.0, t_pass=2e-6, t_sync=10e-6,
        stream_buf_bytes=8 * MB),
}


# ---------------------------------------------------------------------------
# Traced hardware vector (DESIGN.md §11).
# ---------------------------------------------------------------------------

# Canonical field order of the raw hardware vector; slot i of a packed
# [..., HW_FEATURE_DIM] array is HW_FIELDS[i].
HW_FIELDS = ("npe", "pe_lanes", "freq_hz", "bw_offchip", "bw_onchip",
             "buf_bytes", "bytes_per_elem", "t_pass", "t_sync",
             "stream_buf_bytes")
HW_FEATURE_DIM = len(HW_FIELDS)

# Per-field log-range bounds for feature normalization: feature =
# log(x / lo) / log(hi / lo), so every realistic design point lands in
# [0, 1] and the map inverts exactly (accel_from_features).
_FEAT_LO = np.array([32, 1, 1e8, 1e8, 1e9, 0.25 * MB, 0.25, 1e-7, 1e-7,
                     0.0625 * MB], np.float64)
_FEAT_HI = np.array([2 ** 20, 64, 1e10, 1e13, 1e14, 16384 * MB, 8.0, 1e-3,
                     1e-2, 1024 * MB], np.float64)


class HwVec(NamedTuple):
    """``AccelConfig`` as a pytree of ``jnp`` scalars (or [C] vectors).

    Field names mirror :class:`AccelConfig`, so the cost model's arithmetic
    is agnostic to which it was handed; because it is a pytree, ``jit``
    traces through it and ``vmap``/``lax.scan`` run over stacked
    accelerators — the property the whole §11 condition-space rests on."""
    npe: jax.Array
    pe_lanes: jax.Array
    freq_hz: jax.Array
    bw_offchip: jax.Array
    bw_onchip: jax.Array
    buf_bytes: jax.Array
    bytes_per_elem: jax.Array
    t_pass: jax.Array
    t_sync: jax.Array
    stream_buf_bytes: jax.Array

    @property
    def peak_macs(self) -> jax.Array:
        return self.npe * self.pe_lanes * self.freq_hz


@functools.lru_cache(maxsize=256)
def _hw_of_cfg(cfg: AccelConfig) -> HwVec:
    """Cached AccelConfig -> HwVec (host constants -> f32 scalars)."""
    return HwVec(*(jnp.float32(getattr(cfg, f)) for f in HW_FIELDS))


def as_hw(hw) -> HwVec:
    """Normalize an accelerator descriptor to a traced :class:`HwVec`.

    Accepts an :class:`AccelConfig` (cached conversion), an ``HwVec``
    (passthrough, possibly mid-trace) or a raw ``[..., HW_FEATURE_DIM]``
    array in ``HW_FIELDS`` order."""
    if isinstance(hw, HwVec):
        return hw
    if isinstance(hw, AccelConfig):
        return _hw_of_cfg(hw)
    return hw_from_array(hw)


def hw_array(hw) -> jax.Array:
    """Raw ``[..., HW_FEATURE_DIM]`` f32 vector in ``HW_FIELDS`` order."""
    if isinstance(hw, AccelConfig):
        return jnp.asarray([float(getattr(hw, f)) for f in HW_FIELDS],
                           jnp.float32)
    if isinstance(hw, HwVec):
        return jnp.stack(list(hw), axis=-1).astype(jnp.float32)
    return jnp.asarray(hw, jnp.float32)


def hw_from_array(arr) -> HwVec:
    """Inverse of :func:`hw_array`; a leading batch axis becomes stacked
    per-condition leaves (the ``vmap``-over-hardware form)."""
    arr = jnp.asarray(arr, jnp.float32)
    return HwVec(*(arr[..., i] for i in range(HW_FEATURE_DIM)))


def stack_hw(hw, C: int) -> HwVec:
    """Per-condition ``HwVec`` with ``[C]`` leaves.

    ``hw`` may be one descriptor (broadcast to all C conditions), a
    sequence of C descriptors, an already-stacked ``HwVec``, or a raw
    ``[C, HW_FEATURE_DIM]`` array — the grid entry points
    (``cost_model.evaluate_grid``, ``gsampler_search_grid``,
    ``infer.dnnfuser_infer_batch``) all funnel through here."""
    if isinstance(hw, (list, tuple)) and not isinstance(hw, HwVec):
        if len(hw) != C:
            raise ValueError(f"got {len(hw)} accelerators for {C} conditions")
        return hw_from_array(jnp.stack([hw_array(h) for h in hw]))
    v = as_hw(hw)
    if jnp.ndim(v.npe) == 0:
        v = HwVec(*(jnp.broadcast_to(x, (C,)) for x in v))
    elif v.npe.shape[0] != C:
        raise ValueError(f"stacked HwVec has {v.npe.shape[0]} rows, "
                         f"expected {C}")
    return v


def accel_features(hw) -> jax.Array:
    """Normalized hardware condition features, ``[..., HW_FEATURE_DIM]``.

    Each raw field maps log-linearly onto [0, 1] over its ``_FEAT_LO`` /
    ``_FEAT_HI`` design range — the learned mapper's hw-condition input
    (DESIGN.md §11).  Works on an AccelConfig, HwVec (incl. stacked) or raw
    vector; invertible via :func:`accel_from_features`."""
    x = hw_array(hw)
    lo = jnp.asarray(_FEAT_LO, jnp.float32)
    span = jnp.asarray(np.log(_FEAT_HI / _FEAT_LO), jnp.float32)
    return (jnp.log(x / lo) / span).astype(jnp.float32)


def accel_from_features(feats, name: str = "decoded") -> AccelConfig:
    """Invert :func:`accel_features` back to an :class:`AccelConfig`.

    Integer fields (``npe``, ``pe_lanes``) are rounded; everything else
    round-trips to f32 precision."""
    f = np.asarray(jax.device_get(feats), np.float64)
    if f.shape != (HW_FEATURE_DIM,):
        raise ValueError(f"expected [{HW_FEATURE_DIM}] features, "
                         f"got shape {f.shape}")
    raw = _FEAT_LO * np.exp(f * np.log(_FEAT_HI / _FEAT_LO))
    kw = dict(zip(HW_FIELDS, raw))
    kw["npe"] = int(round(kw["npe"]))
    kw["pe_lanes"] = int(round(kw["pe_lanes"]))
    return AccelConfig(name=name, **kw)
