"""Accelerator model parameters for the fusion cost model.

The paper's configuration (§5.1): 1024 PEs, 64 MB on-chip buffer, 900 GB/s
off-chip BW, 9000 GB/s on-chip BW, 1 GHz.

Hardware-adaptation note (see DESIGN.md §4): taken literally (1 MAC/PE/cycle
= 2 GOPS against 900 GB/s) every CNN in the paper sits ~200x inside the
compute-bound roofline region, where layer fusion cannot produce the
reported 1.2x-3.1x speedups; and the paper's own Fig. 4 strategies
(micro-batch 36 staged under a 20 MB budget on ResNet18) are only
memory-consistent with 1-byte activations and an activation-only buffer
constraint.  We therefore model the paper's *observed regime*: an edge-class
int8 accelerator (1024 PEs x 4-lane vector MAC = 8.2 TOPS, LPDDR-class
8 GB/s off-chip, 40 GB/s on-chip), activations quantized to 1 byte, the on-chip buffer constraint
applying to staged activations (a separate streaming path feeds weights,
re-fetched once per micro-batch wave).  All constants are config fields.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["AccelConfig", "PAPER_ACCEL"]

MB = float(2 ** 20)


@dataclass(frozen=True)
class AccelConfig:
    npe: int = 1024                  # PEs (paper §5.1)
    pe_lanes: int = 4                # vector MACs per PE (adaptation, DESIGN §4)
    freq_hz: float = 1e9             # 1 GHz
    bw_offchip: float = 8e9          # bytes/s (LPDDR-class edge device)
    bw_onchip: float = 40e9          # bytes/s (5:1 on:off, see DESIGN §4)
    buf_bytes: float = 64 * MB       # on-chip activation buffer
    bytes_per_elem: float = 1.0      # int8 tensors (edge inference)
    t_pass: float = 5e-6             # per-wave pipeline restart overhead (s)
    t_sync: float = 20e-6            # per-group off-chip sync/drain cost (s)
    stream_buf_bytes: float = 2 * MB  # act working set of an unfused layer

    @property
    def peak_macs(self) -> float:
        return self.npe * self.pe_lanes * self.freq_hz

    def with_buffer_mb(self, mb: float) -> "AccelConfig":
        return replace(self, buf_bytes=mb * MB)


PAPER_ACCEL = AccelConfig()
