"""Naive pure-Python reference of the fusion cost model.

Single-strategy, loop-based, written independently from the vectorized
``cost_model.evaluate`` — used as the oracle in property tests and for the
Pallas ``fusion_eval`` kernel, and by search heuristics that want per-group
introspection (e.g. G-Sampler's repair operator).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .accel import AccelConfig

SYNC = -1
_UTIL_MIN = 1.0 / 4096.0


@dataclass
class GroupInfo:
    start: int            # first layer position (1-based chain position)
    end: int              # last layer position (inclusive)
    mem: float            # peak on-chip bytes
    traffic: float        # off-chip bytes
    compute: float        # seconds
    latency: float        # seconds (incl. overheads)


def evaluate_ref(wl_np: dict, strategy: np.ndarray, batch: float,
                 budget_bytes: float, hw: AccelConfig) -> dict:
    """Reference evaluation. ``wl_np``: numpy arrays from Workload.arrays
    scaled to bytes (same content as cost_model.pack_workload)."""
    A, W, F, OE, UC = (np.asarray(wl_np[k], dtype=np.float64)  # repro: noqa[DET003] -- deliberate f64 oracle arithmetic (DESIGN §16)
                       for k in ("A", "W", "F", "OE", "UC"))
    skip = np.asarray(wl_np["SKIP"], dtype=np.int64)
    mask = np.asarray(wl_np["mask"])
    n = int(wl_np["n"])
    B = float(batch)
    s = np.asarray(strategy, dtype=np.int64)

    # effective / staged micro-batches
    is_sync = [(i >= 1 and i <= n and s[i] < 0) for i in range(len(s))]
    def mb_of(i):
        return float(min(max(int(s[i]), 1), int(B)))

    # split into groups
    groups: list[list[int]] = [[]]
    for i in range(1, n + 1):
        groups[-1].append(i)
        if is_sync[i] and i != n:
            groups.append([])
    groups = [g for g in groups if g]

    infos: list[GroupInfo] = []
    for g in groups:
        l, r = g[0], g[-1]
        fused = len(g) > 1
        mem = 0.0; traffic = 0.0; comp = 0.0; onchip = 0.0; waves = 0.0
        for i in g:
            if not fused:
                mbe = B            # isolated layer: one full-batch pass
                stage = mb_of(i) if not is_sync[i] else 1.0
            elif is_sync[i]:
                prev = i - 1
                if prev >= 1 and not is_sync[prev]:
                    mbe = mb_of(prev)
                elif prev == 0:
                    mbe = mb_of(0)
                else:
                    mbe = 1.0
                stage = 1.0
            else:
                mbe = mb_of(i)
                stage = mbe
            w_i = math.ceil(B / mbe)           # weight re-fetches (per wave)
            m_i = stage * A[i]                 # activation buffer only
            if i == l:
                m_i += mbe * A[i - 1]
            t_i = W[i] * w_i
            if i == l:
                t_i += B * A[i - 1]
            if i == r or is_sync[i]:
                t_i += B * A[i]
            # skip edges: crossing iff any sync strictly between src and i
            # (inclusive of src itself — a sync at src flushes the tensor),
            # which is exactly gid[src] != gid[i] in the vectorized model.
            src = int(skip[i])
            if src >= 0:
                crossing = any(is_sync[j] for j in range(max(src, 1), i))
                if crossing:
                    t_i += 2.0 * B * A[src]
                else:
                    m_i += mbe * A[src]
            if not fused:
                m_i = min(m_i, hw.stream_buf_bytes)
            mem += m_i
            traffic += t_i
            util = min(max(mbe * OE[i] / (hw.npe * hw.pe_lanes), _UTIL_MIN), UC[i])
            comp += B * F[i] / hw.peak_macs / util
            onchip += B * (A[i - 1] + A[i]) + W[i] * w_i
            waves += w_i
        lat = max(comp, traffic / hw.bw_offchip, onchip / hw.bw_onchip) \
            + waves * hw.t_pass + hw.t_sync
        infos.append(GroupInfo(l, r, mem, traffic, comp, lat))

    latency = sum(gi.latency for gi in infos)
    peak = max(gi.mem for gi in infos) if infos else 0.0
    traffic = sum(gi.traffic for gi in infos)
    return dict(latency=latency, peak_mem=peak, traffic=traffic,
                valid=peak <= budget_bytes, n_groups=len(infos),
                groups=infos)


def baseline_ref(wl_np: dict, batch: float, hw: AccelConfig) -> float:
    A, W, F, OE, UC = (np.asarray(wl_np[k], dtype=np.float64)  # repro: noqa[DET003] -- deliberate f64 oracle arithmetic (DESIGN §16)
                       for k in ("A", "W", "F", "OE", "UC"))
    n = int(wl_np["n"]); B = float(batch)
    lat = 0.0
    for i in range(1, n + 1):
        util = min(max(B * OE[i] / (hw.npe * hw.pe_lanes), _UTIL_MIN), UC[i])
        comp = B * F[i] / hw.peak_macs / util
        t = B * (A[i - 1] + A[i]) + W[i]
        lat += max(comp, t / hw.bw_offchip, t / hw.bw_onchip) + hw.t_sync
    return lat
