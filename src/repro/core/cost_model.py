"""Analytical layer-fusion cost model (paper §5.1 "Cost Model").

Maps (workload, batch, HW, fusion strategy) -> (latency, peak on-chip
memory, off-chip traffic).  Semantics are specified in DESIGN.md §3; in
short, a strategy ``[mb_0, mb_1, ..., mb_N]`` (``-1`` = sync) segments the
chain into fused groups; within a group weights are resident and
intermediate activations are staged on-chip at per-layer micro-batch
granularity, so only group inputs/outputs (and group weights, once) touch
off-chip memory.  Group latency is the roofline max of compute / off-chip /
on-chip time plus per-wave pipeline and per-group sync overheads.

Everything is fixed-shape ``jnp`` so a whole GA population (and a batch of
memory conditions) evaluates in a single jitted/vmapped call — this is the
search hot loop the Pallas kernel ``kernels/fusion_eval`` also implements.

Array convention (see ``Workload.arrays``): position 0 is the network input
pseudo-tensor, positions ``1..n`` are layers, padded to ``nmax``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .accel import AccelConfig

__all__ = ["SYNC", "CostOut", "evaluate", "evaluate_population",
           "baseline_no_fusion", "prefix_trace", "pack_workload"]

SYNC = -1  # strategy sentinel: flush activation off-chip after this layer
_UTIL_MIN = 1.0 / 4096.0


class CostOut(NamedTuple):
    latency: jax.Array      # seconds, end-to-end
    peak_mem: jax.Array     # bytes, max over fused groups
    traffic: jax.Array      # bytes, total off-chip
    valid: jax.Array        # peak_mem <= budget
    n_groups: jax.Array     # number of fused groups


def pack_workload(workload, hw: AccelConfig, nmax: int = 64) -> dict[str, jnp.ndarray]:
    """Device-ready workload arrays (bytes scaled by hw.bytes_per_elem)."""
    arrs = workload.arrays(nmax, bytes_per_elem=hw.bytes_per_elem)
    out = {k: jnp.asarray(v, dtype=jnp.float32) for k, v in arrs.items()
           if k in ("A", "W", "F", "OE", "UC", "SHAPE6")}
    out["SKIP"] = jnp.asarray(arrs["SKIP"], dtype=jnp.int32)
    out["mask"] = jnp.asarray(arrs["mask"])
    out["n"] = jnp.asarray(arrs["n"], dtype=jnp.int32)
    return out


def _prep_strategy(strategy: jax.Array, mask: jax.Array, batch: float) -> tuple:
    """Clip/normalize a raw strategy vector.

    Returns (sync, stage_mb, mbe) where ``sync`` marks flush positions,
    ``stage_mb`` is the staged-output micro-batch (1-sample FIFO at syncs)
    and ``mbe`` is the effective compute micro-batch (syncs inherit their
    producer's granularity).
    """
    s = strategy.astype(jnp.float32)
    sync = (s < 0.0) & mask                       # position 0 can never sync
    mb = jnp.clip(s, 1.0, batch)
    prev_mb = jnp.roll(mb, 1).at[0].set(1.0)
    prev_sync = jnp.roll(sync, 1).at[0].set(False)
    mbe = jnp.where(sync, jnp.where(prev_sync, 1.0, prev_mb), mb)
    stage_mb = jnp.where(sync, 1.0, mb)
    return sync, stage_mb, mbe


@functools.partial(jax.jit, static_argnames=("hw", "nseg"))
def evaluate(wl: dict, strategy: jax.Array, batch: jax.Array,
             budget_bytes: jax.Array, hw: AccelConfig, *,
             nseg: int | None = None) -> CostOut:
    """Cost of one strategy. All inputs may be traced except ``hw``/``nseg``."""
    A, W, F, OE, UC = wl["A"], wl["W"], wl["F"], wl["OE"], wl["UC"]
    mask, skip, n = wl["mask"], wl["SKIP"], wl["n"]
    P = A.shape[0]
    nseg = nseg or P
    pos = jnp.arange(P)
    B = jnp.asarray(batch, jnp.float32)

    sync, stage_mb, mbe = _prep_strategy(strategy, mask, B)
    fmask = mask.astype(jnp.float32)

    # --- group segmentation -------------------------------------------------
    gid = (jnp.cumsum(sync.astype(jnp.int32)) - sync.astype(jnp.int32))
    head = mask & (jnp.roll(sync, 1).at[0].set(False) | (pos == 1))
    tail = mask & (sync | (pos == n))
    glen = jax.ops.segment_sum(fmask, gid, num_segments=nseg,
                               indices_are_sorted=True)
    fused = (glen[gid] > 1.0) & mask
    # an isolated (unfused) layer runs baseline-style: one full-batch pass
    mbe = jnp.where(fused, mbe, B)

    A_prev = jnp.roll(A, 1).at[0].set(0.0)

    # --- skip (residual) edges ----------------------------------------------
    has_skip = (skip >= 0) & mask
    src = jnp.clip(skip, 0, P - 1)
    same_group = has_skip & (gid[src] == gid)
    skip_hold = jnp.where(same_group, mbe * A[src], 0.0)
    skip_traffic = jnp.where(has_skip & ~same_group, 2.0 * B * A[src], 0.0)

    # --- per-group peak (activation) memory ----------------------------------
    # Weights use a separate streaming path (DESIGN §3): the buffer
    # constraint — the paper's reported "Act. Usage" — is on staged acts.
    m_fused = (stage_mb * A + head.astype(jnp.float32) * mbe * A_prev
               + skip_hold)
    mem_i = jnp.where(fused, m_fused, jnp.minimum(m_fused, hw.stream_buf_bytes))
    M_g = jax.ops.segment_sum(mem_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)
    nonempty = glen > 0.0
    peak_mem = jnp.max(jnp.where(nonempty, M_g, 0.0))

    # --- off-chip traffic ---------------------------------------------------
    # Weights are re-fetched once per micro-batch wave (they are not held in
    # the activation buffer); a full-batch pass fetches them exactly once.
    waves = jnp.ceil(B / mbe)
    t_i = (head.astype(jnp.float32) * B * A_prev
           + tail.astype(jnp.float32) * B * A + W * waves + skip_traffic)
    T_g = jax.ops.segment_sum(t_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)

    # --- compute / on-chip / overheads ---------------------------------------
    util = jnp.clip(mbe * OE / (hw.npe * hw.pe_lanes), _UTIL_MIN, UC)
    comp = B * F / hw.peak_macs / util
    C_g = jax.ops.segment_sum(comp * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)
    o_i = B * (A_prev + A) + W * waves
    O_g = jax.ops.segment_sum(o_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)
    fill_g = (jax.ops.segment_sum(waves * fmask, gid, num_segments=nseg,
                                  indices_are_sorted=True) * hw.t_pass
              + nonempty.astype(jnp.float32) * hw.t_sync)

    L_g = jnp.maximum(jnp.maximum(C_g, T_g / hw.bw_offchip),
                      O_g / hw.bw_onchip) + fill_g
    latency = jnp.sum(L_g)
    traffic = jnp.sum(T_g)
    n_groups = jnp.sum(nonempty.astype(jnp.int32))
    valid = peak_mem <= jnp.asarray(budget_bytes, jnp.float32)
    return CostOut(latency, peak_mem, traffic, valid, n_groups)


@functools.partial(jax.jit, static_argnames=("hw",))
def baseline_no_fusion(wl: dict, batch: jax.Array, hw: AccelConfig) -> CostOut:
    """The paper's baseline: best layer-by-layer mapping, full batch per
    layer, minimal buffer, every activation round-trips off-chip."""
    A, W, F, OE, UC = wl["A"], wl["W"], wl["F"], wl["OE"], wl["UC"]
    mask = wl["mask"]
    B = jnp.asarray(batch, jnp.float32)
    fmask = mask.astype(jnp.float32)
    A_prev = jnp.roll(A, 1).at[0].set(0.0)
    util = jnp.clip(B * OE / (hw.npe * hw.pe_lanes), _UTIL_MIN, UC)
    comp = B * F / hw.peak_macs / util
    t_i = B * (A_prev + A) + W
    o_i = t_i
    L_i = jnp.maximum(jnp.maximum(comp, t_i / hw.bw_offchip),
                      o_i / hw.bw_onchip) + hw.t_sync
    latency = jnp.sum(L_i * fmask)
    traffic = jnp.sum(t_i * fmask)
    peak = jnp.asarray(hw.stream_buf_bytes, jnp.float32)
    n = jnp.sum(mask.astype(jnp.int32))
    return CostOut(latency, peak, traffic, jnp.asarray(True), n)


@functools.partial(jax.jit, static_argnames=("hw",))
def evaluate_population(wl: dict, strategies: jax.Array, batch: jax.Array,
                        budget_bytes: jax.Array, hw: AccelConfig) -> CostOut:
    """Vectorized cost of a population ``[pop, P]`` of strategies."""
    return jax.vmap(lambda s: evaluate(wl, s, batch, budget_bytes, hw))(strategies)


@functools.partial(jax.jit, static_argnames=("hw",))
def prefix_trace(wl: dict, strategy: jax.Array, batch: jax.Array,
                 budget_bytes: jax.Array, hw: AccelConfig) -> CostOut:
    """Partial-strategy trace for RL state decoration (paper Eq. 2).

    Entry ``t`` evaluates the strategy with only positions ``< t`` applied
    (the rest forced to sync) — i.e. the environment state *before* action
    ``t``: ``P_{a_0..a_{t-1}}`` and the memory committed so far.
    Returns CostOut with a leading axis of length ``P``.
    """
    P = strategy.shape[0]
    pos = jnp.arange(P)

    def at_t(t):
        s = jnp.where(pos < t, strategy, SYNC)
        return evaluate(wl, s, batch, budget_bytes, hw)

    return jax.vmap(at_t)(jnp.arange(P))


def random_strategy(rng: np.random.Generator, n: int, nmax: int, batch: int,
                    p_sync: float = 0.3) -> np.ndarray:
    """A random valid-format strategy (numpy; for tests and search seeds)."""
    s = np.full(nmax, SYNC, dtype=np.int32)
    vals = rng.integers(1, batch + 1, size=n + 1)
    syncs = rng.random(n + 1) < p_sync
    syncs[0] = False
    s[: n + 1] = np.where(syncs, SYNC, vals)
    return s
