"""Analytical layer-fusion cost model (paper §5.1 "Cost Model").

Maps (workload, batch, HW, fusion strategy) -> (latency, peak on-chip
memory, off-chip traffic).  Semantics are specified in DESIGN.md §3; in
short, a strategy ``[mb_0, mb_1, ..., mb_N]`` (``-1`` = sync) segments the
chain into fused groups; within a group weights are resident and
intermediate activations are staged on-chip at per-layer micro-batch
granularity, so only group inputs/outputs (and group weights, once) touch
off-chip memory.  Group latency is the roofline max of compute / off-chip /
on-chip time plus per-wave pipeline and per-group sync overheads.

Everything is fixed-shape ``jnp`` so a whole GA population (and a batch of
memory conditions) evaluates in a single jitted/vmapped call — this is the
search hot loop the Pallas kernel ``kernels/fusion_eval`` also implements.
The population/grid entry points dispatch between the two backends via
their ``evaluator`` kwarg ("xla" | "pallas", DESIGN.md §13); both funnel
their per-group decompositions through :func:`finalize_groups`, so on the
CPU container (interpret mode) the backends are bit-identical and the
G-Sampler teacher pipeline emits the same corpus on either.

The accelerator is a CONDITION, not a compile-time constant (DESIGN.md
§11): every entry point takes ``hw`` as either a host ``AccelConfig`` or a
traced ``accel.HwVec`` pytree, so one jitted program evaluates strategies
across a *batch of accelerators* (the grid entry points vmap the hardware
axis alongside batch/budget).  Packed workloads carry their pack-time
bytes/elem (``BPE``); evaluation rescales activation/weight bytes to the
serving accelerator's datatype in-graph, which is an exact identity when
the two match.

Array convention (see ``Workload.arrays``): position 0 is the network input
pseudo-tensor, positions ``1..n`` are layers, padded to ``nmax``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .accel import AccelConfig, HwVec, as_hw, stack_hw

__all__ = ["SYNC", "CostOut", "evaluate", "evaluate_population",
           "evaluate_population_stats", "baseline_no_fusion", "prefix_trace",
           "pack_workload", "stack_workloads", "PrefixConsts", "PrefixCarry",
           "prefix_consts", "prefix_init", "prefix_step", "prefix_out",
           "prefix_probe_peak", "prefix_scan", "evaluate_grid",
           "evaluate_grid_stats", "baseline_grid", "finalize_groups",
           "default_evaluator", "set_default_evaluator"]

SYNC = -1  # strategy sentinel: flush activation off-chip after this layer
_UTIL_MIN = 1.0 / 4096.0

# ---------------------------------------------------------------------------
# Evaluator-backend dispatch (DESIGN.md §13).
#
# The population/grid evaluators have two interchangeable backends: "xla"
# (the vmapped jnp path below) and "pallas" (``kernels.fusion_eval``, the
# block kernel; interpret mode on CPU).  Both share ``finalize_groups`` and
# are bit-identical on the CPU container, so search/teacher pipelines may
# flip backends without changing a single emitted corpus byte.  ``evaluator``
# kwargs accept "xla" | "pallas" | None (None = the module default).
# ---------------------------------------------------------------------------

_EVALUATOR_BACKENDS = ("xla", "pallas")
_DEFAULT_EVALUATOR = "xla"


def default_evaluator() -> str:
    """The backend used when an entry point's ``evaluator=None``."""
    return _DEFAULT_EVALUATOR


def set_default_evaluator(name: str) -> str:
    """Set the process-wide default backend; returns the previous one."""
    global _DEFAULT_EVALUATOR
    prev = _DEFAULT_EVALUATOR
    _DEFAULT_EVALUATOR = _resolve_evaluator(name)
    return prev


def _resolve_evaluator(evaluator: str | None) -> str:
    ev = _DEFAULT_EVALUATOR if evaluator is None else evaluator
    if ev not in _EVALUATOR_BACKENDS:
        raise ValueError(f"evaluator must be one of {_EVALUATOR_BACKENDS}, "
                         f"got {ev!r}")
    return ev


class CostOut(NamedTuple):
    latency: jax.Array      # seconds, end-to-end
    peak_mem: jax.Array     # bytes, max over fused groups
    traffic: jax.Array      # bytes, total off-chip
    valid: jax.Array        # peak_mem <= budget
    n_groups: jax.Array     # number of fused groups


def pack_workload(workload, hw: AccelConfig, nmax: int = 64) -> dict[str, jnp.ndarray]:
    """Device-ready workload arrays (bytes scaled by hw.bytes_per_elem).

    ``BPE`` records the pack-time bytes/elem so the evaluators can rescale
    A/W when serving the same packing on an accelerator with a different
    datatype (DESIGN §11) — identity when they match."""
    arrs = workload.arrays(nmax, bytes_per_elem=hw.bytes_per_elem)
    out = {k: jnp.asarray(v, dtype=jnp.float32) for k, v in arrs.items()
           if k in ("A", "W", "F", "OE", "UC", "SHAPE6")}
    out["SKIP"] = jnp.asarray(arrs["SKIP"], dtype=jnp.int32)
    out["mask"] = jnp.asarray(arrs["mask"])
    out["n"] = jnp.asarray(arrs["n"], dtype=jnp.int32)
    out["BPE"] = jnp.asarray(hw.bytes_per_elem, jnp.float32)
    return out


def _scaled_AW(wl: dict, hw: HwVec) -> tuple[jax.Array, jax.Array]:
    """A/W rescaled from pack-time bytes to ``hw``'s bytes/elem.

    The multiplier is exactly 1.0 when the serving accelerator matches the
    packing (IEEE identity), so the static-hw path stays bit-exact."""
    A, W = wl["A"], wl["W"]
    bpe = wl.get("BPE")
    if bpe is None:
        return A, W
    s = hw.bytes_per_elem / bpe
    return A * s, W * s


def stack_workloads(wls: list[dict]) -> dict[str, jnp.ndarray]:
    """Stack packed workloads (same ``nmax``) along a leading condition axis.

    The stacked dict vmaps through every cost-model entry point — this is
    what lets a heterogeneous (workload, budget) condition grid evaluate in
    one device program (``evaluate_grid``, DESIGN §10) and a mixed-network
    request batch serve in one fused call (``infer.dnnfuser_infer_batch``,
    DESIGN §12).  Entry ``c`` may repeat a workload; rows with different
    true layer counts ride their per-row ``n`` — positions past it are
    masked (padding stays SYNC/zero), so padding to a shared ``nmax``
    never changes a row's cost."""
    sizes = {int(np.shape(w["A"])[-1]) for w in wls}
    if len(sizes) > 1:
        raise ValueError(f"cannot stack workloads packed to different nmax "
                         f"{sorted(sizes)}; repack to a shared bucket")
    keys = wls[0].keys()
    return {k: jnp.stack([w[k] for w in wls]) for k in keys}


def _prep_strategy(strategy: jax.Array, mask: jax.Array, batch: float) -> tuple:
    """Clip/normalize a raw strategy vector.

    Returns (sync, stage_mb, mbe) where ``sync`` marks flush positions,
    ``stage_mb`` is the staged-output micro-batch (1-sample FIFO at syncs)
    and ``mbe`` is the effective compute micro-batch (syncs inherit their
    producer's granularity).
    """
    s = strategy.astype(jnp.float32)
    sync = (s < 0.0) & mask                       # position 0 can never sync
    mb = jnp.clip(s, 1.0, batch)
    prev_mb = jnp.roll(mb, 1).at[0].set(1.0)
    prev_sync = jnp.roll(sync, 1).at[0].set(False)
    mbe = jnp.where(sync, jnp.where(prev_sync, 1.0, prev_mb), mb)
    stage_mb = jnp.where(sync, 1.0, mb)
    return sync, stage_mb, mbe


def _evaluate_full(wl: dict, strategy: jax.Array, batch: jax.Array,
                   budget_bytes: jax.Array, hw,
                   nseg: int | None = None):
    """``evaluate`` body, additionally returning the group decomposition
    (``gid`` [P] and per-group activation memory ``M_g`` [nseg]) that search
    heuristics (G-Sampler repair) use to pick split/shrink targets."""
    hw = as_hw(hw)
    A, W = _scaled_AW(wl, hw)
    F, OE, UC = wl["F"], wl["OE"], wl["UC"]
    mask, skip, n = wl["mask"], wl["SKIP"], wl["n"]
    P = A.shape[0]
    nseg = nseg or P
    pos = jnp.arange(P)
    B = jnp.asarray(batch, jnp.float32)

    sync, stage_mb, mbe = _prep_strategy(strategy, mask, B)
    fmask = mask.astype(jnp.float32)

    # --- group segmentation -------------------------------------------------
    gid = (jnp.cumsum(sync.astype(jnp.int32)) - sync.astype(jnp.int32))
    head = mask & (jnp.roll(sync, 1).at[0].set(False) | (pos == 1))
    tail = mask & (sync | (pos == n))
    glen = jax.ops.segment_sum(fmask, gid, num_segments=nseg,
                               indices_are_sorted=True)
    fused = (glen[gid] > 1.0) & mask
    # an isolated (unfused) layer runs baseline-style: one full-batch pass
    mbe = jnp.where(fused, mbe, B)

    A_prev = jnp.roll(A, 1).at[0].set(0.0)

    # --- skip (residual) edges ----------------------------------------------
    has_skip = (skip >= 0) & mask
    src = jnp.clip(skip, 0, P - 1)
    same_group = has_skip & (gid[src] == gid)
    skip_hold = jnp.where(same_group, mbe * A[src], 0.0)
    skip_traffic = jnp.where(has_skip & ~same_group, 2.0 * B * A[src], 0.0)

    # --- per-group peak (activation) memory ----------------------------------
    # Weights use a separate streaming path (DESIGN §3): the buffer
    # constraint — the paper's reported "Act. Usage" — is on staged acts.
    m_fused = (stage_mb * A + head.astype(jnp.float32) * mbe * A_prev
               + skip_hold)
    mem_i = jnp.where(fused, m_fused, jnp.minimum(m_fused, hw.stream_buf_bytes))
    M_g = jax.ops.segment_sum(mem_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)

    # --- off-chip traffic ---------------------------------------------------
    # Weights are re-fetched once per micro-batch wave (they are not held in
    # the activation buffer); a full-batch pass fetches them exactly once.
    waves = jnp.ceil(B / mbe)
    t_i = (head.astype(jnp.float32) * B * A_prev
           + tail.astype(jnp.float32) * B * A + W * waves + skip_traffic)
    T_g = jax.ops.segment_sum(t_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)

    # --- compute / on-chip / overheads ---------------------------------------
    util = jnp.clip(mbe * OE / (hw.npe * hw.pe_lanes), _UTIL_MIN, UC)
    comp = B * F / hw.peak_macs / util
    C_g = jax.ops.segment_sum(comp * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)
    o_i = B * (A_prev + A) + W * waves
    O_g = jax.ops.segment_sum(o_i * fmask, gid, num_segments=nseg,
                              indices_are_sorted=True)
    wave_g = jax.ops.segment_sum(waves * fmask, gid, num_segments=nseg,
                                 indices_are_sorted=True)

    out = finalize_groups(C_g, T_g, O_g, M_g, wave_g, glen,
                          budget_bytes, hw)
    return out, gid, M_g


def finalize_groups(C_g, T_g, O_g, M_g, wave_g, glen, budget_bytes,
                    hw) -> CostOut:
    """Per-group decomposition -> CostOut (the shared reduction, DESIGN §13).

    Inputs are the per-group component sums over the trailing group axis —
    compute seconds, off-chip bytes, on-chip bytes, staged-act bytes,
    micro-batch waves and member counts — exactly what the sorted
    segment-sums above and the Pallas ``kernels.fusion_eval`` block kernel
    both accumulate (in the same position order).  BOTH evaluator backends
    funnel through this function, so the roofline max and the latency /
    traffic / peak reductions lower identically — the keystone of the
    backends' bit-exact equivalence.  ``hw`` leaves may carry broadcast
    batch axes ([C, 1, 1] for grid blocks)."""
    hw = as_hw(hw)
    nonempty = glen > 0.0
    peak_mem = jnp.max(jnp.where(nonempty, M_g, 0.0), axis=-1)
    fill_g = wave_g * hw.t_pass + nonempty.astype(jnp.float32) * hw.t_sync
    L_g = jnp.maximum(jnp.maximum(C_g, T_g / hw.bw_offchip),
                      O_g / hw.bw_onchip) + fill_g
    latency = jnp.sum(L_g, axis=-1)
    traffic = jnp.sum(T_g, axis=-1)
    n_groups = jnp.sum(nonempty.astype(jnp.int32), axis=-1)
    valid = peak_mem <= jnp.asarray(budget_bytes, jnp.float32)
    return CostOut(latency, peak_mem, traffic, valid, n_groups)


@functools.partial(jax.jit, static_argnames=("nseg",))
def _evaluate_jit(wl, strategy, batch, budget_bytes, hw, nseg=None):
    out, _, _ = _evaluate_full(wl, strategy, batch, budget_bytes, hw, nseg)
    return out


def evaluate(wl: dict, strategy: jax.Array, batch: jax.Array,
             budget_bytes: jax.Array, hw, *,
             nseg: int | None = None) -> CostOut:
    """Cost of one strategy. All inputs may be traced except ``nseg`` —
    including ``hw`` (AccelConfig or ``accel.HwVec``, DESIGN §11)."""
    return _evaluate_jit(wl, strategy, batch, budget_bytes, as_hw(hw),
                         nseg=nseg)


@jax.jit
def _baseline_jit(wl, batch, hw):
    hw = as_hw(hw)
    A, W = _scaled_AW(wl, hw)
    F, OE, UC = wl["F"], wl["OE"], wl["UC"]
    mask = wl["mask"]
    B = jnp.asarray(batch, jnp.float32)
    fmask = mask.astype(jnp.float32)
    A_prev = jnp.roll(A, 1).at[0].set(0.0)
    util = jnp.clip(B * OE / (hw.npe * hw.pe_lanes), _UTIL_MIN, UC)
    comp = B * F / hw.peak_macs / util
    t_i = B * (A_prev + A) + W
    o_i = t_i
    L_i = jnp.maximum(jnp.maximum(comp, t_i / hw.bw_offchip),
                      o_i / hw.bw_onchip) + hw.t_sync
    latency = jnp.sum(L_i * fmask)
    traffic = jnp.sum(t_i * fmask)
    peak = jnp.asarray(hw.stream_buf_bytes, jnp.float32)
    n = jnp.sum(mask.astype(jnp.int32))
    return CostOut(latency, peak, traffic, jnp.asarray(True), n)


def baseline_no_fusion(wl: dict, batch: jax.Array, hw) -> CostOut:
    """The paper's baseline: best layer-by-layer mapping, full batch per
    layer, minimal buffer, every activation round-trips off-chip."""
    return _baseline_jit(wl, batch, as_hw(hw))


@jax.jit
def _population_jit(wl, strategies, batch, budget_bytes, hw):
    return jax.vmap(
        lambda s: _evaluate_jit(wl, s, batch, budget_bytes, hw))(strategies)


def evaluate_population(wl: dict, strategies: jax.Array, batch: jax.Array,
                        budget_bytes: jax.Array, hw, *,
                        evaluator: str | None = None) -> CostOut:
    """Vectorized cost of a population ``[pop, P]`` of strategies.

    ``evaluator`` selects the backend ("xla" | "pallas" | None = the
    module default, DESIGN §13); both are bit-identical on CPU."""
    if _resolve_evaluator(evaluator) == "pallas":
        from ..kernels.fusion_eval import fusion_eval_population
        return fusion_eval_population(strategies, wl, batch=batch,
                                      budget_bytes=budget_bytes, hw=hw)
    return _population_jit(wl, strategies, batch, budget_bytes, as_hw(hw))


@jax.jit
def _population_stats_jit(wl, strategies, batch, budget_bytes, hw):
    return jax.vmap(
        lambda s: _evaluate_full(wl, s, batch, budget_bytes, hw))(strategies)


def evaluate_population_stats(wl: dict, strategies: jax.Array,
                              batch: jax.Array, budget_bytes: jax.Array,
                              hw, *, evaluator: str | None = None):
    """Like :func:`evaluate_population` but also returns the per-strategy
    group decomposition: ``(CostOut [pop], gid [pop, P], M_g [pop, P])``.

    ``gid[p, i]`` is the fused-group id of position ``i`` in strategy ``p``
    and ``M_g[p, g]`` that group's staged-activation peak — everything a
    constraint-repair operator needs to find the worst group and its span
    in one device call (DESIGN.md §3)."""
    if _resolve_evaluator(evaluator) == "pallas":
        from ..kernels.fusion_eval import fusion_eval_population_stats
        return fusion_eval_population_stats(strategies, wl, batch=batch,
                                            budget_bytes=budget_bytes, hw=hw)
    return _population_stats_jit(wl, strategies, batch, budget_bytes,
                                 as_hw(hw))


# ---------------------------------------------------------------------------
# Condition-grid evaluation (DESIGN.md §10, §11).
#
# A teacher run sweeps a grid of C = |workloads| x |accels| x |budgets|
# conditions, each with its own GA population.  The three entry points below
# vmap the per-condition evaluators over a ``stack_workloads`` dict plus
# per-condition batch/budget vectors AND a per-condition ``accel.stack_hw``
# hardware vector, so a whole grid generation — C x POP strategies across
# heterogeneous accelerators — costs one device call (and, inside the fused
# GA, zero host round trips).
# ---------------------------------------------------------------------------


@jax.jit
def _grid_jit(wls, strategies, batches, budgets, hw):
    return jax.vmap(
        lambda wl, s, b, m, h: _population_jit(wl, s, b, m, h)
    )(wls, strategies, batches, budgets, hw)


def evaluate_grid(wls: dict, strategies: jax.Array, batches: jax.Array,
                  budgets: jax.Array, hw, *,
                  evaluator: str | None = None) -> CostOut:
    """CostOut [C, POP] of per-condition populations ``strategies``
    [C, POP, P] over stacked workloads [C, ...], per-condition ``batches``
    / ``budgets`` [C] and per-condition hardware (anything
    ``accel.stack_hw`` accepts: one config, a list, or stacked vectors).

    ``evaluator`` selects the backend (DESIGN §13): "xla" vmaps the jnp
    evaluator, "pallas" runs the ``kernels.fusion_eval`` block kernel
    (interpret mode on CPU) — bit-identical outputs either way."""
    if _resolve_evaluator(evaluator) == "pallas":
        from ..kernels.fusion_eval import fusion_eval_grid
        return fusion_eval_grid(wls, strategies, batches, budgets, hw)
    return _grid_jit(wls, strategies, batches, budgets,
                     stack_hw(hw, strategies.shape[0]))


@jax.jit
def _grid_stats_jit(wls, strategies, batches, budgets, hw):
    return jax.vmap(
        lambda wl, s, b, m, h: jax.vmap(
            lambda one: _evaluate_full(wl, one, b, m, h))(s)
    )(wls, strategies, batches, budgets, hw)


def evaluate_grid_stats(wls: dict, strategies: jax.Array, batches: jax.Array,
                        budgets: jax.Array, hw, *,
                        evaluator: str | None = None):
    """Grid counterpart of :func:`evaluate_population_stats`:
    ``(CostOut [C, POP], gid [C, POP, P], M_g [C, POP, P])`` — the
    constraint-repair operator's split/shrink targets for every child of
    every condition in one call.  ``evaluator`` as in
    :func:`evaluate_grid` (DESIGN §13)."""
    if _resolve_evaluator(evaluator) == "pallas":
        from ..kernels.fusion_eval import fusion_eval_grid_stats
        return fusion_eval_grid_stats(wls, strategies, batches, budgets, hw)
    return _grid_stats_jit(wls, strategies, batches, budgets,
                           stack_hw(hw, strategies.shape[0]))


@jax.jit
def _baseline_grid_jit(wls, batches, hw):
    return jax.vmap(lambda wl, b, h: _baseline_jit(wl, b, h)
                    )(wls, batches, hw)


def baseline_grid(wls: dict, batches: jax.Array, hw) -> CostOut:
    """Per-condition no-fusion baselines, CostOut [C]."""
    return _baseline_grid_jit(wls, batches,
                              stack_hw(hw, np.shape(batches)[0]))


@jax.jit
def _prefix_trace_jit(wl, strategy, batch, budget_bytes, hw):
    P = strategy.shape[0]
    pos = jnp.arange(P)

    def at_t(t):
        s = jnp.where(pos < t, strategy, SYNC)
        return _evaluate_jit(wl, s, batch, budget_bytes, hw)

    return jax.vmap(at_t)(jnp.arange(P))


def prefix_trace(wl: dict, strategy: jax.Array, batch: jax.Array,
                 budget_bytes: jax.Array, hw) -> CostOut:
    """Partial-strategy trace for RL state decoration (paper Eq. 2).

    Entry ``t`` evaluates the strategy with only positions ``< t`` applied
    (the rest forced to sync) — i.e. the environment state *before* action
    ``t``: ``P_{a_0..a_{t-1}}`` and the memory committed so far.
    Returns CostOut with a leading axis of length ``P``.
    """
    return _prefix_trace_jit(wl, strategy, batch, budget_bytes, as_hw(hw))


# ---------------------------------------------------------------------------
# Incremental prefix evaluation (scan-carry form, DESIGN.md §9).
#
# ``prefix_trace`` above re-evaluates the whole chain once per position —
# O(P^2) work for a rollout that queries the environment at every step.  The
# carry form below maintains the exact same quantity — the cost of the
# strategy with positions ``< t`` applied and the rest forced to SYNC —
# as O(1)-per-step running state, so a full autoregressive episode is O(P)
# and lives inside one ``jax.lax.scan`` with zero host syncs.
#
# Invariant: positions ``>= t`` forced to SYNC are each a singleton
# (unfused) group whose cost is independent of the prefix, so their
# latency/traffic suffix-sums and memory suffix-max are precomputed once
# (``PrefixConsts``).  The carry tracks the closed-group aggregates plus the
# component sums of the one open (not-yet-synced) group.
# ---------------------------------------------------------------------------


class PrefixConsts(NamedTuple):
    """Per-(workload, batch, budget, hw) constants for the prefix carry.

    All fields are jnp arrays (``batch``/``budget`` — and since §11 the
    accelerator itself — may be traced, e.g. under a vmap over serving
    conditions); ``A``/``W`` are already rescaled to the accelerator's
    bytes/elem."""
    A: jax.Array          # [P] act bytes/sample (position 0 = network input)
    A_prev: jax.Array     # [P] producer act bytes
    W: jax.Array          # [P] weight bytes
    F: jax.Array          # [P] MACs/sample
    OE: jax.Array         # [P] output elems (utilization model)
    UC: jax.Array         # [P] utilization cap
    skip: jax.Array       # [P] residual source position or -1
    has_skip: jax.Array   # [P] bool
    mask: jax.Array       # [P] valid layer positions
    n: jax.Array          # num layers
    B: jax.Array          # batch (f32)
    budget: jax.Array     # bytes (f32)
    sm: jax.Array         # [P] singleton(all-SYNC) group peak mem
    st: jax.Array         # [P] singleton group off-chip traffic
    slat: jax.Array       # [P] singleton group latency
    hold0: jax.Array      # [P] same-group skip-hold term of a singleton
    SLAT: jax.Array       # [P+2] suffix sum of slat (SLAT[i] = sum_{j>=i})
    SPEAK: jax.Array      # [P+2] suffix max of sm
    STRAF: jax.Array      # [P+2] suffix sum of st
    SGRP: jax.Array       # [P+2] suffix count of layers (i32)


class PrefixCarry(NamedTuple):
    """Running state after committing actions for positions ``< t``."""
    t: jax.Array          # next position to act on (i32)
    g_start: jax.Array    # first position of the open group (i32)
    open_len: jax.Array   # committed members of the open group (i32)
    last_mb: jax.Array    # micro-batch of the last committed member (f32)
    c_sum: jax.Array      # open-group compute seconds
    t_sum: jax.Array      # open-group off-chip bytes
    o_sum: jax.Array      # open-group on-chip bytes
    m_sum: jax.Array      # open-group staged-act bytes
    w_sum: jax.Array      # open-group micro-batch waves
    lat: jax.Array        # closed groups: total latency
    peak: jax.Array       # closed groups: max group memory
    traf: jax.Array       # closed groups: total traffic
    groups: jax.Array     # closed groups: count (i32)


def _suffix_sum(x: jax.Array, pad: int = 2) -> jax.Array:
    s = jnp.cumsum(x[::-1])[::-1]
    return jnp.concatenate([s, jnp.zeros((pad,), x.dtype)])


def _suffix_max(x: jax.Array, pad: int = 2) -> jax.Array:
    s = jax.lax.cummax(x[::-1])[::-1]
    return jnp.concatenate([s, jnp.zeros((pad,), x.dtype)])


def prefix_consts(wl: dict, batch: jax.Array, budget_bytes: jax.Array,
                  hw) -> PrefixConsts:
    """Precompute the per-position constants of the forced-SYNC suffix.

    A forced-SYNC position is a singleton group: unfused, so its effective
    micro-batch is the full batch, its staged output one sample, and its
    working set clamped to the streaming buffer — none of which depends on
    the actions taken for the prefix (see ``evaluate``)."""
    hw = as_hw(hw)
    A, W = _scaled_AW(wl, hw)
    F = wl["F"]
    OE, UC = wl["OE"], wl["UC"]
    mask, skip, n = wl["mask"], wl["SKIP"], wl["n"]
    P = A.shape[0]
    pos = jnp.arange(P)
    B = jnp.asarray(batch, jnp.float32)
    fmask = mask.astype(jnp.float32)
    A_prev = jnp.roll(A, 1).at[0].set(0.0)
    src = jnp.clip(skip, 0, P - 1)
    has = (skip >= 0) & mask
    Asrc = A[src]
    # position 0 shares gid 0 with the first group, so a residual edge from
    # the network input into position 1 is same-group even for a singleton
    same0 = has & (skip == 0) & (pos == 1)
    hold0 = jnp.where(same0, B * Asrc, 0.0)
    cross = jnp.where(has & ~same0, 2.0 * B * Asrc, 0.0)
    util_B = jnp.clip(B * OE / (hw.npe * hw.pe_lanes), _UTIL_MIN, UC)
    comp_B = B * F / hw.peak_macs / util_B
    sm = jnp.minimum(A + B * A_prev + hold0, hw.stream_buf_bytes) * fmask
    st = (B * A_prev + B * A + W + cross) * fmask
    so = B * (A_prev + A) + W
    slat = (jnp.maximum(jnp.maximum(comp_B, st / hw.bw_offchip),
                        so / hw.bw_onchip) + hw.t_pass + hw.t_sync) * fmask
    return PrefixConsts(
        A=A, A_prev=A_prev, W=W, F=F, OE=OE, UC=UC,
        skip=skip, has_skip=has, mask=mask, n=n, B=B,
        budget=jnp.asarray(budget_bytes, jnp.float32),
        sm=sm, st=st, slat=slat, hold0=hold0,
        SLAT=_suffix_sum(slat), SPEAK=_suffix_max(sm),
        STRAF=_suffix_sum(st),
        SGRP=_suffix_sum(fmask).astype(jnp.int32))


def prefix_init(consts: PrefixConsts) -> PrefixCarry:
    f0 = jnp.float32(0.0)
    i0 = jnp.int32(0)
    return PrefixCarry(t=i0, g_start=jnp.int32(1), open_len=i0,
                       last_mb=jnp.float32(1.0), c_sum=f0, t_sum=f0,
                       o_sum=f0, m_sum=f0, w_sum=f0, lat=f0, peak=f0,
                       traf=f0, groups=i0)


def _tree_select(pred, a, b):
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def _gather(consts: PrefixConsts, i: jax.Array):
    """Per-position terms at (clipped) position ``i``."""
    P = consts.A.shape[0]
    j = jnp.clip(i, 0, P - 1)
    return (consts.A[j], consts.A_prev[j], consts.W[j], consts.F[j],
            consts.OE[j], consts.UC[j], consts.skip[j], consts.has_skip[j])


def _same_group(consts: PrefixConsts, src, has, g_start):
    """gid[src] == gid[i] for ``i`` in the open group starting at g_start
    (position 0 always carries gid 0, the id of the first group)."""
    return has & ((src >= g_start) | ((src == 0) & (g_start == 1)))


def prefix_step(consts: PrefixConsts, carry: PrefixCarry, action,
                hw) -> PrefixCarry:
    """Commit ``action`` for position ``carry.t`` (O(1) work).

    Matches ``evaluate`` semantics exactly: a non-SYNC action extends the
    open group (fused-style terms); a SYNC action closes it — as a
    precomputed singleton when the group would hold one sync'd position, or
    by reducing the carried component sums.  Position 0 is the network-input
    pseudo tensor and contributes nothing."""
    hw = as_hw(hw)
    c = consts
    i = carry.t
    B = c.B
    lanes = hw.npe * hw.pe_lanes
    a = jnp.asarray(action, jnp.float32)
    Ai, Api, Wi, Fi, OEi, UCi, srci, hasi = _gather(c, i)
    Asrc = c.A[jnp.clip(srci, 0, c.A.shape[0] - 1)]
    same = _same_group(c, srci, hasi, carry.g_start)
    is_tail_n = i == c.n

    # --- non-SYNC: extend the open group (fused-style contributions) -------
    mb = jnp.clip(a, 1.0, B)
    head = carry.open_len == 0
    waves = jnp.ceil(B / mb)
    util = jnp.clip(mb * OEi / lanes, _UTIL_MIN, UCi)
    comp = B * Fi / hw.peak_macs / util
    mem = (mb * Ai + jnp.where(head, mb * Api, 0.0)
           + jnp.where(same, mb * Asrc, 0.0))
    tr = (jnp.where(head, B * Api, 0.0) + jnp.where(is_tail_n, B * Ai, 0.0)
          + Wi * waves + jnp.where(hasi & ~same, 2.0 * B * Asrc, 0.0))
    o = B * (Api + Ai) + Wi * waves
    carry_ns = carry._replace(
        t=i + 1, open_len=carry.open_len + 1, last_mb=mb,
        c_sum=carry.c_sum + comp, t_sum=carry.t_sum + tr,
        o_sum=carry.o_sum + o, m_sum=carry.m_sum + mem,
        w_sum=carry.w_sum + waves)

    # --- SYNC: close the open group ----------------------------------------
    # fused close: the sync position rides the producer's micro-batch with a
    # 1-sample staged FIFO; singleton close: the precomputed all-SYNC terms.
    mbe = carry.last_mb
    waves_s = jnp.ceil(B / mbe)
    util_s = jnp.clip(mbe * OEi / lanes, _UTIL_MIN, UCi)
    comp_s = B * Fi / hw.peak_macs / util_s
    mem_s = Ai + jnp.where(same, mbe * Asrc, 0.0)
    tr_s = (B * Ai + Wi * waves_s
            + jnp.where(hasi & ~same, 2.0 * B * Asrc, 0.0))
    o_s = B * (Api + Ai) + Wi * waves_s
    Mg = carry.m_sum + mem_s
    Cg = carry.c_sum + comp_s
    Tg = carry.t_sum + tr_s
    Og = carry.o_sum + o_s
    Wg = carry.w_sum + waves_s
    Lg = (jnp.maximum(jnp.maximum(Cg, Tg / hw.bw_offchip),
                      Og / hw.bw_onchip) + Wg * hw.t_pass + hw.t_sync)
    single = carry.open_len == 0
    j = jnp.clip(i, 0, c.A.shape[0] - 1)
    Lc = jnp.where(single, c.slat[j], Lg)
    Mc = jnp.where(single, c.sm[j], Mg)
    Tc = jnp.where(single, c.st[j], Tg)
    f0 = jnp.float32(0.0)
    carry_sy = PrefixCarry(
        t=i + 1, g_start=i + 1, open_len=jnp.int32(0),
        last_mb=jnp.float32(1.0), c_sum=f0, t_sum=f0, o_sum=f0, m_sum=f0,
        w_sum=f0, lat=carry.lat + Lc, peak=jnp.maximum(carry.peak, Mc),
        traf=carry.traf + Tc, groups=carry.groups + 1)

    out = _tree_select(a < 0.0, carry_sy, carry_ns)
    return _tree_select(i == 0, carry._replace(t=jnp.int32(1)), out)


def prefix_out(consts: PrefixConsts, carry: PrefixCarry,
               hw) -> CostOut:
    """CostOut of the carried prefix: actions ``< t`` applied, rest SYNC.

    Identical quantity to ``prefix_trace`` entry ``t`` (and to a full
    ``evaluate`` once ``t == n + 1``), assembled in O(1) from the carry,
    one forced-SYNC close of the open group, and the precomputed suffix
    aggregates."""
    hw = as_hw(hw)
    c = consts
    t = carry.t
    B = c.B
    lanes = hw.npe * hw.pe_lanes
    n1 = c.n + 1
    tc = jnp.clip(t, 0, c.SLAT.shape[0] - 2)

    # case A — no open group: closed + all-SYNC suffix from t
    latA = carry.lat + c.SLAT[tc]
    peakA = jnp.maximum(carry.peak, c.SPEAK[tc])
    trafA = carry.traf + c.STRAF[tc]
    grpA = carry.groups + c.SGRP[tc]

    # case B — open group force-closed by the SYNC at t, suffix from t+1
    Ai, Api, Wi, Fi, OEi, UCi, srci, hasi = _gather(c, t)
    Asrc = c.A[jnp.clip(srci, 0, c.A.shape[0] - 1)]
    same = _same_group(c, srci, hasi, carry.g_start)
    mbe = carry.last_mb
    waves_t = jnp.ceil(B / mbe)
    util_t = jnp.clip(mbe * OEi / lanes, _UTIL_MIN, UCi)
    comp_t = B * Fi / hw.peak_macs / util_t
    mem_t = Ai + jnp.where(same, mbe * Asrc, 0.0)
    tr_t = (B * Ai + Wi * waves_t
            + jnp.where(hasi & ~same, 2.0 * B * Asrc, 0.0))
    o_t = B * (Api + Ai) + Wi * waves_t
    Mg = carry.m_sum + mem_t
    Cg = carry.c_sum + comp_t
    Tg = carry.t_sum + tr_t
    Og = carry.o_sum + o_t
    Wg = carry.w_sum + waves_t
    Lg = (jnp.maximum(jnp.maximum(Cg, Tg / hw.bw_offchip),
                      Og / hw.bw_onchip) + Wg * hw.t_pass + hw.t_sync)
    latB = carry.lat + Lg + c.SLAT[tc + 1]
    peakB = jnp.maximum(jnp.maximum(carry.peak, Mg), c.SPEAK[tc + 1])
    trafB = carry.traf + Tg + c.STRAF[tc + 1]
    grpB = carry.groups + 1 + c.SGRP[tc + 1]

    # case C — t == n+1, the episode is complete: close the open group
    # as-is.  A 1-member group is unfused and re-derived from the singleton
    # constants (full-batch pass, staged output at its own micro-batch,
    # streaming-buffer clamp); >= 2 members close from the carried sums.
    jn = jnp.clip(c.n, 0, c.A.shape[0] - 1)
    memC1 = jnp.minimum(
        carry.last_mb * c.A[jn] + B * c.A_prev[jn] + c.hold0[jn],
        hw.stream_buf_bytes)
    latC1 = carry.lat + c.slat[jn]
    peakC1 = jnp.maximum(carry.peak, memC1)
    trafC1 = carry.traf + c.st[jn]
    LgC = (jnp.maximum(jnp.maximum(carry.c_sum,
                                   carry.t_sum / hw.bw_offchip),
                       carry.o_sum / hw.bw_onchip)
           + carry.w_sum * hw.t_pass + hw.t_sync)
    latC2 = carry.lat + LgC
    peakC2 = jnp.maximum(carry.peak, carry.m_sum)
    trafC2 = carry.traf + carry.t_sum

    open0 = carry.open_len == 0
    open1 = carry.open_len == 1
    latC = jnp.where(open0, carry.lat, jnp.where(open1, latC1, latC2))
    peakC = jnp.where(open0, carry.peak, jnp.where(open1, peakC1, peakC2))
    trafC = jnp.where(open0, carry.traf, jnp.where(open1, trafC1, trafC2))
    grpC = carry.groups + jnp.where(open0, 0, 1)

    done = t >= n1
    lat = jnp.where(done, latC, jnp.where(open0, latA, latB))
    peak = jnp.where(done, peakC, jnp.where(open0, peakA, peakB))
    traf = jnp.where(done, trafC, jnp.where(open0, trafA, trafB))
    grp = jnp.where(done, grpC, jnp.where(open0, grpA, grpB))
    return CostOut(lat, peak, traf, peak <= c.budget, grp)


def prefix_probe_peak(consts: PrefixConsts, carry: PrefixCarry, action,
                      hw) -> jax.Array:
    """Peak memory of the probe strategy (``action`` at position ``t``,
    everything after forced SYNC) — the quantity the inference-time budget
    guard tests, without the latency/roofline math of a full
    ``prefix_step`` + ``prefix_out`` round trip.

    Equals ``prefix_out(prefix_step(carry, action)).peak_mem`` for a
    non-SYNC ``action`` (the guard never probes SYNC)."""
    hw = as_hw(hw)
    c = consts
    i = carry.t
    B = c.B
    mb = jnp.clip(jnp.asarray(action, jnp.float32), 1.0, B)
    Ai, Api, _, _, _, _, srci, hasi = _gather(c, i)
    Asrc = c.A[jnp.clip(srci, 0, c.A.shape[0] - 1)]
    same = _same_group(c, srci, hasi, carry.g_start)
    head = carry.open_len == 0
    mem_t = (mb * Ai + jnp.where(head, mb * Api, 0.0)
             + jnp.where(same, mb * Asrc, 0.0))
    tc = jnp.clip(i + 1, 0, c.A.shape[0] - 1)
    A1, src1, has1 = c.A[tc], c.skip[tc], c.has_skip[tc]
    same1 = _same_group(c, src1, has1, carry.g_start)
    mem_s = A1 + jnp.where(same1, mb * c.A[jnp.clip(src1, 0,
                                                    c.A.shape[0] - 1)], 0.0)
    # t < n: fused group [g_start..t+1] + all-SYNC suffix from t+2
    sfx = jnp.clip(i + 2, 0, c.SLAT.shape[0] - 1)
    peak_mid = jnp.maximum(carry.m_sum + mem_t + mem_s, c.SPEAK[sfx])
    # t == n: the strategy is complete after this action
    jn = jnp.clip(c.n, 0, c.A.shape[0] - 1)
    mem_single = jnp.minimum(mb * c.A[jn] + B * c.A_prev[jn] + c.hold0[jn],
                             hw.stream_buf_bytes)
    peak_end = jnp.where(head, mem_single, carry.m_sum + mem_t)
    grp = jnp.where(i >= c.n, peak_end, peak_mid)
    # t > n: inactive lane — nothing left to commit
    grp = jnp.where(i > c.n, jnp.float32(0.0), grp)
    # t == 0: the input pseudo-tensor carries no cost; all-SYNC chain
    grp = jnp.where(i == 0, c.SPEAK[1], grp)
    return jnp.maximum(carry.peak, grp)


@jax.jit
def _prefix_scan_jit(wl, strategy, batch, budget_bytes, hw):
    consts = prefix_consts(wl, batch, budget_bytes, hw)
    carry = prefix_init(consts)

    def step(carry, a):
        out = prefix_out(consts, carry, hw)
        new = prefix_step(consts, carry, a, hw)
        carry = _tree_select(carry.t <= consts.n, new, carry)
        return carry, out

    carry, trace = jax.lax.scan(step, carry, strategy)
    return trace, prefix_out(consts, carry, hw)


def prefix_scan(wl: dict, strategy: jax.Array, batch: jax.Array,
                budget_bytes: jax.Array, hw):
    """Carry-based equivalent of :func:`prefix_trace`.

    Returns ``(trace, final)``: ``trace`` is a CostOut with leading axis
    ``P`` whose entry ``t`` matches ``prefix_trace`` entry ``t``, and
    ``final`` the full-strategy CostOut — all from one O(P) scan instead of
    P full evaluations."""
    return _prefix_scan_jit(wl, strategy, batch, budget_bytes, as_hw(hw))


def random_strategy(rng: np.random.Generator, n: int, nmax: int, batch: int,
                    p_sync: float = 0.3) -> np.ndarray:
    """A random valid-format strategy (numpy; for tests and search seeds)."""
    s = np.full(nmax, SYNC, dtype=np.int32)
    vals = rng.integers(1, batch + 1, size=n + 1)
    syncs = rng.random(n + 1) < p_sync
    syncs[0] = False
    s[: n + 1] = np.where(syncs, SYNC, vals)
    return s
