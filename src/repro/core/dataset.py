"""Teacher-data collection + replay buffer (paper §4.4, §4.5.1).

Pipeline: G-Sampler searches a few memory conditions per workload; its
elite strategies are decorated into (reward, state, action) trajectories by
the environment (one vmapped prefix-trace each) and stored in a replay
buffer of padded arrays the imitation trainer samples from.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .accel import AccelConfig
from .env import FusionEnv, STATE_DIM
from .gsampler import GSamplerConfig, gsampler_search

__all__ = ["TrajectoryDataset", "collect_teacher_data", "merge_datasets"]

MB = float(2 ** 20)


@dataclass
class TrajectoryDataset:
    rtg: np.ndarray        # [N, T] f32
    states: np.ndarray     # [N, T, STATE_DIM] f32
    actions: np.ndarray    # [N, T] f32 (encoded)
    mask: np.ndarray       # [N, T] f32
    meta: list = field(default_factory=list)   # (workload, budget_mb, speedup)

    def __len__(self):
        return self.rtg.shape[0]

    @property
    def max_steps(self) -> int:
        return self.rtg.shape[1]

    def sample(self, rng: np.random.Generator, batch_size: int) -> dict:
        idx = rng.integers(0, len(self), size=batch_size)
        return {"rtg": self.rtg[idx], "states": self.states[idx],
                "actions": self.actions[idx], "mask": self.mask[idx]}

    def split(self, frac: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(self))
        k = max(1, int(len(self) * frac))
        tr, va = perm[k:], perm[:k]
        pick = lambda ix: TrajectoryDataset(
            self.rtg[ix], self.states[ix], self.actions[ix], self.mask[ix],
            [self.meta[i] for i in ix])
        return pick(tr), pick(va)


def _pad(traj: dict, T: int) -> tuple[np.ndarray, ...]:
    L = int(traj["length"])
    rtg = np.zeros(T, np.float32); rtg[:L] = traj["rtg"]
    st = np.zeros((T, STATE_DIM), np.float32); st[:L] = traj["states"]
    ac = np.zeros(T, np.float32); ac[:L] = traj["actions"]
    mk = np.zeros(T, np.float32); mk[:L] = 1.0
    return rtg, st, ac, mk


def collect_teacher_data(workloads: list, hw: AccelConfig, batch: int,
                         budgets_mb: list[float], *, max_steps: int = 64,
                         top_k: int = 8, ga_cfg: GSamplerConfig | None = None,
                         seed: int = 0, augment_jitter: int = 2) -> TrajectoryDataset:
    """Run the teacher over ``workloads x budgets_mb`` and decorate elites.

    ``augment_jitter`` additionally decorates small random perturbations of
    elite strategies (still evaluated by the true cost model) — the replay-
    buffer-diversity trick the Decision-Transformer line relies on.
    """
    rng = np.random.default_rng(seed)
    rows, meta = [], []
    for wi, wl in enumerate(workloads):
        for budget in budgets_mb:
            env = FusionEnv(wl, hw, batch=batch, budget_bytes=budget * MB,
                            nmax=max_steps)
            cfg = ga_cfg or GSamplerConfig(seed=seed + 31 * wi + int(budget))
            res = gsampler_search(env, cfg, top_k=top_k)
            cands = list(res.elites) or [res.strategy]
            extra = []
            for s in cands[:max(1, top_k // 2)]:
                for _ in range(augment_jitter):
                    j = s.copy()
                    pos = rng.integers(1, env.n + 1)
                    if j[pos] >= 1:
                        j[pos] = int(np.clip(j[pos] + rng.integers(-4, 5),
                                             1, batch))
                    extra.append(j)
            for s in cands + extra:
                traj = env.decorate(s)
                sp, _, valid = env.speedup(s)
                if not valid:
                    continue
                rows.append(_pad(traj, max_steps))
                meta.append((wl.name, budget, sp))
    if not rows:
        raise RuntimeError("teacher produced no valid trajectories")
    rtg, st, ac, mk = (np.stack(x) for x in zip(*rows))
    return TrajectoryDataset(rtg, st, ac, mk, meta)


def merge_datasets(ds: list[TrajectoryDataset]) -> TrajectoryDataset:
    return TrajectoryDataset(
        np.concatenate([d.rtg for d in ds]),
        np.concatenate([d.states for d in ds]),
        np.concatenate([d.actions for d in ds]),
        np.concatenate([d.mask for d in ds]),
        sum([d.meta for d in ds], []))
