"""Teacher-data collection + replay buffer (paper §4.4, §4.5.1; DESIGN §10).

Two pipelines produce the same :class:`TrajectoryDataset`:

 - ``collect_teacher_data``: the original host loop — one G-Sampler search
   per (workload, budget) condition, one ``env.decorate`` per elite.  Kept
   as the readable reference.
 - ``generate_teacher_corpus``: the device-grid pipeline.  ONE fused GA
   program searches every condition of the (workload x budget) grid
   simultaneously (``gsampler.gsampler_search_grid``) and ONE fused
   decoration program (``_decorate_grid``: a vmapped ``prefix_scan`` per
   elite) relabels every elite into (returns-to-go, state, action)
   trajectories.  Deterministic for a fixed seed — same seed, bit-identical
   corpus — which the corpus-determinism tests and resumable training rely
   on.

``window_dataset`` cuts long trajectories into fixed-length windows with
absolute-time offsets (``t0``) so large chains train on a small-context
model; ``returns_to_go`` is the §4.3.3 conditioning-relabel rule both
pipelines share.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import (AccelConfig, HW_FEATURE_DIM, accel_features, stack_hw)
from .env import (FusionEnv, STATE_DIM, _budget_feat, _shape_feats,
                  encode_action_jnp, returns_to_go)
from .gsampler import GSamplerConfig, gsampler_search, gsampler_search_grid

__all__ = ["TrajectoryDataset", "collect_teacher_data", "merge_datasets",
           "generate_teacher_corpus", "window_dataset", "returns_to_go"]

MB = float(2 ** 20)


@dataclass
class TrajectoryDataset:
    rtg: np.ndarray        # [N, T] f32
    states: np.ndarray     # [N, T, STATE_DIM] f32
    actions: np.ndarray    # [N, T] f32 (encoded)
    mask: np.ndarray       # [N, T] f32
    meta: list = field(default_factory=list)   # (workload, budget_mb, speedup, accel)
    t0: np.ndarray | None = None   # [N] i32 absolute window offsets
    hw: np.ndarray | None = None   # [N, HW_FEATURE_DIM] f32 accel condition

    def __post_init__(self):
        if self.t0 is None:
            self.t0 = np.zeros(self.rtg.shape[0], np.int32)
        if self.hw is None:
            self.hw = np.zeros((self.rtg.shape[0], HW_FEATURE_DIM),
                               np.float32)

    def __len__(self):
        return self.rtg.shape[0]

    @property
    def max_steps(self) -> int:
        return self.rtg.shape[1]

    def hw_feats(self) -> np.ndarray:
        """Per-trajectory hw condition rows; zeros for corpora pickled
        before DESIGN §11 (which restore without ``hw``)."""
        h = getattr(self, "hw", None)
        if h is None:
            h = np.zeros((len(self), HW_FEATURE_DIM), np.float32)
        return h

    def sample(self, rng: np.random.Generator, batch_size: int) -> dict:
        idx = rng.integers(0, len(self), size=batch_size)
        return {"rtg": self.rtg[idx], "states": self.states[idx],
                "actions": self.actions[idx], "mask": self.mask[idx],
                "t0": self.t0[idx], "hw": self.hw_feats()[idx]}

    def split(self, frac: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(self))
        k = max(1, int(len(self) * frac))
        tr, va = perm[k:], perm[:k]
        pick = lambda ix: TrajectoryDataset(
            self.rtg[ix], self.states[ix], self.actions[ix], self.mask[ix],
            [self.meta[i] for i in ix], self.t0[ix], self.hw_feats()[ix])
        return pick(tr), pick(va)


def _pad(traj: dict, T: int) -> tuple[np.ndarray, ...]:
    L = int(traj["length"])
    rtg = np.zeros(T, np.float32); rtg[:L] = traj["rtg"]
    st = np.zeros((T, STATE_DIM), np.float32); st[:L] = traj["states"]
    ac = np.zeros(T, np.float32); ac[:L] = traj["actions"]
    mk = np.zeros(T, np.float32); mk[:L] = 1.0
    return rtg, st, ac, mk


def collect_teacher_data(workloads: list, hw: AccelConfig, batch: int,
                         budgets_mb: list[float], *, max_steps: int = 64,
                         top_k: int = 8, ga_cfg: GSamplerConfig | None = None,
                         seed: int = 0, augment_jitter: int = 2) -> TrajectoryDataset:
    """Run the teacher over ``workloads x budgets_mb`` and decorate elites.

    ``augment_jitter`` additionally decorates small random perturbations of
    elite strategies (still evaluated by the true cost model) — the replay-
    buffer-diversity trick the Decision-Transformer line relies on.
    """
    rng = np.random.default_rng(seed)
    feats = np.asarray(accel_features(hw), np.float32)
    rows, meta = [], []
    for wi, wl in enumerate(workloads):
        for budget in budgets_mb:
            env = FusionEnv(wl, hw, batch=batch, budget_bytes=budget * MB,
                            nmax=max_steps)
            cfg = ga_cfg or GSamplerConfig(seed=seed + 31 * wi + int(budget))
            res = gsampler_search(env, cfg, top_k=top_k)
            cands = list(res.elites) or [res.strategy]
            extra = []
            for s in cands[:max(1, top_k // 2)]:
                for _ in range(augment_jitter):
                    j = s.copy()
                    pos = rng.integers(1, env.n + 1)
                    if j[pos] >= 1:
                        j[pos] = int(np.clip(j[pos] + rng.integers(-4, 5),
                                             1, batch))
                    extra.append(j)
            for s in cands + extra:
                traj = env.decorate(s)
                sp, _, valid = env.speedup(s)
                if not valid:
                    continue
                rows.append(_pad(traj, max_steps))
                meta.append((wl.name, budget, sp, hw.name))
    if not rows:
        raise RuntimeError("teacher produced no valid trajectories")
    rtg, st, ac, mk = (np.stack(x) for x in zip(*rows))
    return TrajectoryDataset(rtg, st, ac, mk, meta,
                             hw=np.tile(feats, (len(rows), 1)))


def merge_datasets(ds: list[TrajectoryDataset]) -> TrajectoryDataset:
    return TrajectoryDataset(
        np.concatenate([d.rtg for d in ds]),
        np.concatenate([d.states for d in ds]),
        np.concatenate([d.actions for d in ds]),
        np.concatenate([d.mask for d in ds]),
        sum([d.meta for d in ds], []),
        np.concatenate([d.t0 for d in ds]),
        np.concatenate([d.hw_feats() for d in ds]))


# ---------------------------------------------------------------------------
# Device-grid corpus generation (DESIGN.md §10).
# ---------------------------------------------------------------------------


@jax.jit
def _decorate_grid_jit(wls: dict, strategies: jax.Array, batches: jax.Array,
                       budgets: jax.Array, hw):
    P = wls["A"].shape[-1]
    pos = jnp.arange(P)

    def per_cond(wl, S, b, m, h):
        base = cm.baseline_no_fusion(wl, b, h).latency
        feats = _shape_feats(wl["SHAPE6"])                  # [P, 6]
        bfeat = _budget_feat(m)
        idx = jnp.minimum(pos, wl["n"])
        valid = (pos <= wl["n"]).astype(jnp.float32)

        def per_strat(s):
            trace, final = cm.prefix_scan(wl, s, b, m, h)
            perf = jnp.log1p(base / jnp.maximum(trace.latency, 1e-12))
            states = jnp.concatenate(
                [feats[idx], jnp.full((P, 1), bfeat), perf[:, None]],
                axis=1) * valid[:, None]
            rtg = returns_to_go(trace.peak_mem, m) * valid
            acts = encode_action_jnp(s, b) * valid
            return states, rtg, acts, final

        st, rtg, ac, fin = jax.vmap(per_strat)(S)
        mk = jnp.broadcast_to(valid, (S.shape[0], P))
        return st, rtg, ac, mk, fin

    return jax.vmap(per_cond)(wls, strategies, batches, budgets, hw)


def _decorate_grid(wls: dict, strategies: jax.Array, batches: jax.Array,
                   budgets: jax.Array, hw):
    """Decorate [C, K] strategies into padded trajectories in one program.

    Per strategy this is exactly ``env.decorate``: one O(P) ``prefix_scan``
    supplies the per-step prefix latency/peak, from which the state vector
    (paper Eq. 2) and the relabeled returns-to-go are assembled.  ``hw``
    is anything ``accel.stack_hw`` accepts — per-condition accelerators
    ride the same vmap as batches/budgets (DESIGN §11).  Returns
    (states [C,K,P,STATE_DIM], rtg [C,K,P], actions [C,K,P], mask [C,K,P],
    final CostOut [C,K])."""
    return _decorate_grid_jit(wls, strategies, batches, budgets,
                              stack_hw(hw, strategies.shape[0]))


def _augment_candidates(rng: np.random.Generator, elites: np.ndarray,
                        ns: np.ndarray, batch: int, top_k: int,
                        augment_jitter: int) -> np.ndarray:
    """Jittered copies of the top elites (vectorized twin of the host
    pipeline's replay-diversity trick): perturb one micro-batch position per
    copy; the cost model re-scores them during decoration."""
    C, K, P = elites.shape
    K2 = max(1, top_k // 2)
    extra = []
    for _ in range(augment_jitter):
        j = elites[:, :K2].copy()
        sel = rng.integers(1, ns[:, None] + 1, size=(C, K2))
        delta = rng.integers(-4, 5, size=(C, K2))
        cur = np.take_along_axis(j, sel[..., None], axis=2)[..., 0]
        new = np.where(cur >= 1, np.clip(cur + delta, 1, batch), cur)
        np.put_along_axis(j, sel[..., None], new[..., None].astype(np.int32),
                          axis=2)
        extra.append(j)
    return np.concatenate([elites] + extra, axis=1) if extra else elites


def generate_teacher_corpus(workloads: list, hw, *,
                            batch: int = 64, budgets_mb: list[float],
                            max_steps: int = 64, top_k: int = 8,
                            ga_cfg: GSamplerConfig | None = None,
                            seed: int = 0, augment_jitter: int = 2,
                            evaluator: str | None = None,
                            teacher: str = "gsampler",
                            front_cap: int = 4096,
                            extra_elites: dict | None = None,
                            ) -> TrajectoryDataset:
    """Device-grid teacher pipeline: the scalable twin of
    :func:`collect_teacher_data`.

    One fused GA program searches the whole ``workloads x accels x
    budgets_mb`` grid (``hw`` may be a single :class:`AccelConfig` or a
    sequence of them — the §11 accelerator axis), one fused decoration
    program relabels every elite (+ jittered variants) into returns-to-go
    trajectories; the host only filters invalid rows and dedups exact
    duplicates.  Each trajectory stores its accelerator's normalized
    feature vector (``TrajectoryDataset.hw``), the condition the hw-aware
    mapper trains on.  Deterministic: a fixed ``seed`` reproduces the
    corpus bit-for-bit — on EITHER fitness backend (``evaluator`` = "xla"
    | "pallas" | None, forwarded to the grid GA): the backends are
    bit-identical (DESIGN §13), so the corpus does not depend on it.

    ``teacher`` selects the label source (DESIGN §16): "gsampler" (default)
    runs the fused grid GA; "optimal" replaces the stochastic elites with
    the single provably optimal strategy per condition from the exact DP
    oracle (:func:`repro.core.optimal.optimal_search`; ``front_cap`` is
    forwarded — the oracle raises rather than approximate when a condition
    exceeds it, so keep "optimal" to small-to-mid chains).  Everything
    downstream — jitter augmentation, decoration, filtering, the
    :class:`TrajectoryDataset` schema — is byte-identical between the two
    teachers; only the elite strategies differ.

    ``extra_elites`` injects serving-time refinement wins into the elite
    pool (the §17 flywheel): a dict keyed ``(workload_name, accel_name,
    budget_mb)`` — budget in MB, matched after ``round(..., 6)`` — whose
    values are lists of strategy arrays (any length ≤ ``max_steps``;
    trailing steps pad to SYNC).  Extras ride the same augmentation /
    decoration / validity-filter path as teacher elites; conditions
    without extras are padded with copies of their own first elite,
    which the exact-duplicate dedup drops again."""
    if teacher not in ("gsampler", "optimal"):
        raise ValueError(f"unknown teacher {teacher!r}; "
                         "expected 'gsampler' or 'optimal'")
    accels = list(hw) if isinstance(hw, (list, tuple)) else [hw]
    if any(not isinstance(a, AccelConfig) for a in accels):
        raise TypeError("generate_teacher_corpus needs AccelConfig presets "
                        "(packing + naming); got " + repr(accels))
    conds = [(w, a, float(b)) for w in workloads for a in accels
             for b in budgets_mb]
    wl_list = [w for w, _, _ in conds]
    hw_list = [a for _, a, _ in conds]
    budgets = np.asarray([b * MB for _, _, b in conds], np.float32)
    batches = np.full(len(conds), float(batch), np.float32)
    ns = np.asarray([w.n for w in wl_list], np.int64)
    cfg = ga_cfg or GSamplerConfig(seed=seed)

    # pack the grid ONCE: the teacher search and the decoration share it
    packed = [cm.pack_workload(w, a, max_steps) for w, a, _ in conds]
    wls = cm.stack_workloads(packed)
    if teacher == "optimal":
        from .optimal import optimal_search
        elites = np.stack([
            optimal_search({k: np.asarray(v) for k, v in p.items()},  # repro: noqa[DET002] -- key-addressed rebuild; order never reaches corpus bytes
                           batch, float(bud), a,
                           front_cap=front_cap).strategy
            for p, (_, a, _), bud in zip(packed, conds, budgets)
        ])[:, None, :]                                    # [C, 1, P]
        base_lat = np.asarray(
            cm.baseline_grid(wls, jnp.asarray(batches), hw_list).latency)
    else:
        res = gsampler_search_grid(wl_list, hw_list, batches, budgets,
                                   nmax=max_steps, cfg=cfg, top_k=top_k,
                                   packed=wls, evaluator=evaluator)
        elites, base_lat = res.strategies, res.baseline_latency
    if extra_elites:
        per_cond = [extra_elites.get(
            (w.name, a.name, round(float(b), 6)), ())
            for w, a, b in conds]
        kx = max((len(lst) for lst in per_cond), default=0)
        if kx:
            extra = np.repeat(elites[:, :1], kx, axis=1).copy()  # [C,kx,P]
            for c, lst in enumerate(per_cond):
                for k, s in enumerate(lst[:kx]):
                    s = np.asarray(s, np.int32).ravel()
                    if s.shape[0] > max_steps:
                        continue            # oversized win: skip, keep filler
                    row = np.full(max_steps, cm.SYNC, np.int32)
                    row[: s.shape[0]] = s
                    extra[c, k] = row
            elites = np.concatenate([elites, extra], axis=1)
    rng = np.random.default_rng(seed)
    cand = _augment_candidates(rng, elites, ns, batch, top_k,
                               augment_jitter)

    st, rtg, ac, mk, fin = _decorate_grid(
        wls, jnp.asarray(cand), jnp.asarray(batches), jnp.asarray(budgets),
        hw_list)
    st, rtg, ac, mk = (np.asarray(x) for x in (st, rtg, ac, mk))
    valid = np.asarray(fin.valid)
    speedup = base_lat[:, None] / np.maximum(
        np.asarray(fin.latency), 1e-12)
    feats = np.stack([np.asarray(accel_features(a), np.float32)
                      for a in hw_list])                       # [C, F]

    rows, meta, hw_rows = [], [], []
    for c, (wl, acc, budget) in enumerate(conds):
        seen = set()
        for k in range(cand.shape[1]):
            key = cand[c, k, : wl.n + 1].tobytes()
            if not valid[c, k] or key in seen:
                continue
            seen.add(key)
            rows.append((rtg[c, k], st[c, k], ac[c, k], mk[c, k]))
            meta.append((wl.name, budget, float(speedup[c, k]), acc.name))
            hw_rows.append(feats[c])
    if not rows:
        raise RuntimeError("teacher produced no valid trajectories")
    r, s, a, m = (np.stack(x) for x in zip(*rows))
    return TrajectoryDataset(r, s, a, m, meta, hw=np.stack(hw_rows))


def window_dataset(ds: TrajectoryDataset, T: int,
                   stride: int | None = None) -> TrajectoryDataset:
    """Cut trajectories into length-``T`` windows with absolute offsets.

    Windows step by ``stride`` (default ``T``); a final window is appended
    flush with the trajectory end so no suffix is dropped.  Each window
    carries ``t0`` — its absolute start step — so the model embeds the same
    timestep positions it would see in the full trajectory (``dt_apply``'s
    ``t0`` argument).  Returns-to-go, states and the mask are per-step
    quantities and slice through unchanged (the relabel rule is windowing-
    invariant); the hw condition row is per-trajectory and copies to every
    window."""
    if T >= ds.max_steps:
        return ds
    stride = stride or T
    hw_full = ds.hw_feats()
    rows, meta, offs, hw_rows = [], [], [], []
    for i in range(len(ds)):
        L = int(ds.mask[i].sum())
        starts = list(range(0, max(L - T, 0) + 1, stride))
        if not starts:
            starts = [0]
        if starts[-1] + T < L:
            starts.append(L - T)
        for s0 in starts:
            rows.append((ds.rtg[i, s0:s0 + T], ds.states[i, s0:s0 + T],
                         ds.actions[i, s0:s0 + T], ds.mask[i, s0:s0 + T]))
            meta.append(ds.meta[i] if i < len(ds.meta) else None)
            offs.append(int(ds.t0[i]) + s0)
            hw_rows.append(hw_full[i])
    r, s, a, m = (np.stack(x) for x in zip(*rows))
    return TrajectoryDataset(r, s, a, m, meta, np.asarray(offs, np.int32),
                             np.stack(hw_rows))
