"""Warm-started searcher portfolio: pure-JAX DE + diagonal CMA-ES
(DESIGN.md §17).

Two population searchers alongside the G-Sampler GA, built for one job:
ESCALATION.  When the one-shot mapper (or its gradient polish) leaves a
request budget-violating or low-quality, the engine re-searches the
condition — but warm-started from the proposal, so the search spends its
evaluations refining a good incumbent instead of rediscovering it.
Measured in ``benchmarks/bench_polish.py``: the warm-started portfolio
reaches cold-G-Sampler-final cost in a small fraction of the cost
evaluations.

Search space — the ENCODED ACTION space of ``env.encode_action``: a
genome is ``y`` in ``[-1, 1]^P`` where ``y < 0`` decodes to SYNC and
``y >= 0`` to the tile ``clip(round(y * B), 1, B)`` (position 0 and
padding follow the serving rules: the input position cannot sync,
positions past ``n`` always do).  Warm start is therefore exact:
``encode_action(proposal)`` decodes back to the proposal bit-for-bit,
and sync-structure flips stay reachable as sign changes.

Both searchers follow the grid idiom of ``gsampler_search_grid``: every
condition's population evolves simultaneously inside ONE jitted program,
fitness is one ``cost_model.evaluate_grid`` call per generation
(``evaluator`` = "xla" | "pallas", bit-identical backends), and
selection is elitist — the returned strategy can never be worse (by
fitness) than the best warm seed, which includes the proposal itself.

Randomness protocol: every random draw uses a PER-CONDITION key stream,
``fold_in(PRNGKey(cfg.seed), salts[c])`` — so a single-condition run
with ``salts=[c]`` bit-reproduces row ``c`` of a grid run (tested), and
an engine escalating with constant salts stays tick-composition
invariant (§14 determinism).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import AccelConfig, HwVec, stack_hw
from .env import encode_action
from .gsampler import _fitness_jnp

__all__ = ["PortfolioConfig", "PortfolioResult", "de_search_grid",
           "cmaes_search_grid"]


@dataclass(frozen=True)
class PortfolioConfig:
    """Shared searcher knobs (hashable: static jit argument)."""
    population: int = 24
    generations: int = 30
    seed: int = 0
    warm_sigma: float = 0.12   # genome jitter around a warm proposal
    # -- differential evolution --
    de_f: float = 0.6          # differential weight
    de_cr: float = 0.7         # crossover rate
    # -- diagonal CMA-ES --
    cma_mu: int = 0            # parents (0 -> population // 2)
    cma_lr_sigma: float = 0.2  # per-dim variance adaptation rate
    sigma0: float = 0.4        # cold-start step size
    sigma_min: float = 1e-3
    sigma_max: float = 0.8


@dataclass
class PortfolioResult:
    """Best-ever strategy per condition plus the convergence history."""
    strategies: np.ndarray        # [C, P] int32
    latency: np.ndarray           # [C]
    peak_mem: np.ndarray          # [C]
    speedup: np.ndarray           # [C]
    valid: np.ndarray             # [C] bool
    history: np.ndarray           # [G, C] best valid latency so far (inf)
    baseline_latency: np.ndarray  # [C]
    n_evals: int                  # exact cost evaluations performed
    wall_s: float


def _decode_grid(y: jax.Array, B: jax.Array,
                 valid_pos: jax.Array) -> jax.Array:
    """Genomes [C, POP, P] -> strategies: the serving decode rules.

    Matches ``env.decode_action_jnp`` for ``y >= 0``; position 0 decodes
    its magnitude (the input micro-batch can never sync) and padding
    positions stay SYNC."""
    Bc = B[:, None, None]
    mb = jnp.clip(jnp.round(jnp.abs(y) * Bc), 1.0, Bc)
    s = jnp.where(y < 0.0, float(cm.SYNC), mb)
    s = s.at[..., 0].set(mb[..., 0])
    s = jnp.where(valid_pos[:, None, :], s, float(cm.SYNC))
    return s.astype(jnp.int32)


def _allsync_genome(C: int, P: int) -> jax.Array:
    """The guaranteed-format fallback member: full-batch input, all SYNC
    (the same heuristic seed the GA plants)."""
    y = jnp.full((P,), -0.5, jnp.float32).at[0].set(1.0)
    return jnp.broadcast_to(y, (C, P))


def _vsplit(keys: jax.Array, num: int) -> tuple:
    """Per-condition key split: [C, 2] -> ``num`` arrays of [C, 2]."""
    ks = jax.vmap(lambda k: jax.random.split(k, num))(keys)
    return tuple(ks[:, i] for i in range(num))


@functools.partial(jax.jit,
                   static_argnames=("method", "warm", "cfg", "evaluator"))
def _portfolio_grid_jit(keys, wls, batches, budgets, hw, y0,
                        method: str, warm: bool, cfg: PortfolioConfig,
                        evaluator: str = "xla"):
    C, P = wls["A"].shape
    POP, G = cfg.population, cfg.generations
    n = wls["n"]
    pos = jnp.arange(P)
    valid_pos = pos[None, :] <= n[:, None]
    B = batches.astype(jnp.float32)
    base = cm.baseline_grid(wls, batches, hw).latency

    def fitness(y):
        s = _decode_grid(y, B, valid_pos)
        out = cm.evaluate_grid(wls, s, batches, budgets, hw,
                               evaluator=evaluator)
        fit = _fitness_jnp(out.latency, out.peak_mem, budgets[:, None])
        vlat = jnp.min(jnp.where(out.valid, out.latency, jnp.inf), axis=1)
        return fit, vlat

    def track(best, y, fit, vlat):
        best_fit, best_y, best_lat = best
        idx = jnp.argmax(fit, axis=1)
        top = jnp.take_along_axis(fit, idx[:, None], axis=1)[:, 0]
        upd = top > best_fit
        best_fit = jnp.where(upd, top, best_fit)
        cand = jnp.take_along_axis(y, idx[:, None, None], axis=1)[:, 0]
        best_y = jnp.where(upd[:, None], cand, best_y)
        return best_fit, best_y, jnp.minimum(best_lat, vlat)

    keys, k_init = _vsplit(keys, 2)
    if warm:
        noise = jax.vmap(
            lambda k: jax.random.normal(k, (POP, P)))(k_init)
        pop = jnp.clip(y0[:, None, :] + cfg.warm_sigma * noise, -1.0, 1.0)
        pop = pop.at[:, 0, :].set(y0)          # member 0: the exact proposal
    else:
        pop = jax.vmap(lambda k: jax.random.uniform(
            k, (POP, P), minval=-1.0, maxval=1.0))(k_init)
    pop = pop.at[:, 1, :].set(_allsync_genome(C, P))

    fit, vlat = fitness(pop)
    best = (jnp.full((C,), -jnp.inf), pop[:, 0], jnp.full((C,), jnp.inf))
    best = track(best, pop, fit, vlat)

    if method == "de":
        def gen(carry, _):
            pop, fit, keys, best = carry
            keys, k1, k2, k3 = _vsplit(keys, 4)
            r = jax.vmap(lambda k: jax.random.randint(
                k, (POP, 3), 0, POP))(k1)
            x1 = jnp.take_along_axis(pop, r[..., 0][..., None], axis=1)
            x2 = jnp.take_along_axis(pop, r[..., 1][..., None], axis=1)
            x3 = jnp.take_along_axis(pop, r[..., 2][..., None], axis=1)
            mutant = jnp.clip(x1 + cfg.de_f * (x2 - x3), -1.0, 1.0)
            jrand = jax.vmap(lambda k: jax.random.randint(
                k, (POP,), 0, P))(k2)
            cross = (jax.vmap(lambda k: jax.random.uniform(
                k, (POP, P)))(k3) < cfg.de_cr) \
                | (pos[None, None, :] == jrand[..., None])
            trial = jnp.where(cross, mutant, pop)
            tfit, tvlat = fitness(trial)
            best = track(best, trial, tfit, tvlat)
            sel = tfit >= fit
            pop = jnp.where(sel[..., None], trial, pop)
            fit = jnp.where(sel, tfit, fit)
            return (pop, fit, keys, best), best[2]

        (_, _, _, best), history = jax.lax.scan(
            gen, (pop, fit, keys, best), None, length=G)
    elif method == "cmaes":
        MU = cfg.cma_mu or POP // 2
        w = np.log(MU + 0.5) - np.log(np.arange(1, MU + 1))
        w = jnp.asarray(w / w.sum(), jnp.float32)
        mean = y0 if warm else jnp.zeros((C, P), jnp.float32)
        sigma = jnp.full((C, P),
                         cfg.warm_sigma if warm else cfg.sigma0,
                         jnp.float32)

        def gen(carry, _):
            mean, sigma, keys, best = carry
            keys, k1 = _vsplit(keys, 2)
            z = jax.vmap(lambda k: jax.random.normal(k, (POP, P)))(k1)
            z = z.at[:, 0, :].set(0.0)         # sample 0: the mean itself
            x = jnp.clip(mean[:, None, :] + sigma[:, None, :] * z,
                         -1.0, 1.0)
            xfit, xvlat = fitness(x)
            best = track(best, x, xfit, xvlat)
            order = jnp.argsort(-xfit, axis=1)[:, :MU]
            xsel = jnp.take_along_axis(x, order[..., None], axis=1)
            zsel = jnp.take_along_axis(z, order[..., None], axis=1)
            mean = jnp.sum(w[None, :, None] * xsel, axis=1)
            var_step = jnp.sum(w[None, :, None] * (zsel ** 2 - 1.0),
                               axis=1)
            sigma = jnp.clip(
                sigma * jnp.exp(0.5 * cfg.cma_lr_sigma * var_step),
                cfg.sigma_min, cfg.sigma_max)
            return (mean, sigma, keys, best), best[2]

        (_, _, _, best), history = jax.lax.scan(
            gen, (mean, sigma, keys, best), None, length=G)
    else:
        raise ValueError(f"unknown portfolio method {method!r}")

    _, best_y, _ = best
    best_s = _decode_grid(best_y[:, None, :], B, valid_pos)
    out = cm.evaluate_grid(wls, best_s, batches, budgets, hw,
                           evaluator=evaluator)
    lat = out.latency[:, 0]
    return dict(strategies=best_s[:, 0], latency=lat,
                peak_mem=out.peak_mem[:, 0], valid=out.valid[:, 0],
                speedup=base / jnp.maximum(lat, 1e-12),
                history=history,                 # scan-stacked: [G, C]
                baseline_latency=base)


def _prepare_grid(workloads, hw, batches, budgets_bytes, nmax, packed):
    """Pack/stack the condition grid — the ``gsampler_search_grid``
    front-door contract: host ``AccelConfig``s pack on demand; an
    already-vectorized ``hw`` requires ``packed=``."""
    C = len(batches)
    if isinstance(hw, AccelConfig) or (
            isinstance(hw, (list, tuple)) and not isinstance(hw, HwVec)):
        hws = list(hw) if isinstance(hw, (list, tuple)) else [hw] * C
        assert len(hws) == C
        if packed is None:
            if workloads is None:
                raise ValueError("pass workloads= or packed=")
            packed = cm.stack_workloads(
                [cm.pack_workload(w, h, nmax)
                 for w, h in zip(workloads, hws)])
        hwv = stack_hw(hws, C)
    else:
        if packed is None:
            raise ValueError("vectorized hw (HwVec / raw array) requires "
                             "`packed=` — pack_workload needs AccelConfigs")
        hwv = stack_hw(hw, C)
    return packed, hwv


def _search_grid(method: str, workloads, hw, batches, budgets_bytes, *,
                 nmax, cfg, init_strategies, salts, packed,
                 evaluator) -> PortfolioResult:
    t0 = time.perf_counter()
    batches = np.asarray(batches, np.float32)
    budgets = np.asarray(budgets_bytes, np.float32)
    C = len(batches)
    wls, hwv = _prepare_grid(workloads, hw, batches, budgets_bytes, nmax,
                             packed)
    wls = {k: jnp.asarray(v) for k, v in wls.items()}
    P = wls["A"].shape[-1]
    if salts is None:
        salts = np.arange(C)
    salts = np.asarray(salts, np.uint32)
    assert salts.shape == (C,)
    key0 = jax.random.PRNGKey(cfg.seed)
    keys = jax.vmap(lambda s: jax.random.fold_in(key0, s))(
        jnp.asarray(salts))
    warm = init_strategies is not None
    if warm:
        init = np.asarray(init_strategies, np.int32)
        assert init.shape == (C, P), (init.shape, (C, P))
        y0 = jnp.asarray(np.stack([
            encode_action(init[c], int(batches[c])) for c in range(C)]))
    else:
        y0 = jnp.zeros((C, P), jnp.float32)
    out = _portfolio_grid_jit(keys, wls, jnp.asarray(batches),
                              jnp.asarray(budgets), hwv, y0, method, warm,
                              cfg, cm._resolve_evaluator(evaluator))
    out = {k: np.asarray(v) for k, v in out.items()}
    hist = out["history"].reshape(cfg.generations, C)
    n_evals = C * cfg.population * (cfg.generations + 1) + C
    return PortfolioResult(
        strategies=out["strategies"], latency=out["latency"],
        peak_mem=out["peak_mem"], speedup=out["speedup"],
        valid=out["valid"], history=hist,
        baseline_latency=out["baseline_latency"], n_evals=n_evals,
        wall_s=time.perf_counter() - t0)


def de_search_grid(workloads, hw, batches, budgets_bytes, *,
                   nmax: int = 64,
                   cfg: PortfolioConfig = PortfolioConfig(),
                   init_strategies=None, salts=None, packed=None,
                   evaluator: str | None = None) -> PortfolioResult:
    """Differential evolution over every condition of the grid in one
    jitted program (rand/1/bin, elitist replacement).

    ``init_strategies`` [C, P] int32 warm-starts the population from a
    proposal per condition (member 0 is the exact proposal; the rest are
    ``warm_sigma`` genome jitters of it) — the DT-propose -> search-refine
    protocol.  ``salts`` [C] picks each condition's RNG stream
    (default ``arange(C)``): a single-condition run with ``salts=[c]``
    bit-reproduces grid row ``c``.  ``history[g, c]`` is the best VALID
    exact latency seen up to generation ``g`` (inf until one exists);
    ``n_evals`` counts exact cost evaluations, the unit the
    warm-vs-cold benchmark gates on."""
    return _search_grid("de", workloads, hw, batches, budgets_bytes,
                        nmax=nmax, cfg=cfg,
                        init_strategies=init_strategies, salts=salts,
                        packed=packed, evaluator=evaluator)


def cmaes_search_grid(workloads, hw, batches, budgets_bytes, *,
                      nmax: int = 64,
                      cfg: PortfolioConfig = PortfolioConfig(),
                      init_strategies=None, salts=None, packed=None,
                      evaluator: str | None = None) -> PortfolioResult:
    """Diagonal (separable) CMA-ES over the same grid contract as
    :func:`de_search_grid`: rank-weighted recombination of the top
    ``cma_mu`` samples, per-dimension variance adaptation, the mean
    re-evaluated every generation (sample 0), best-ever elitism across
    all evaluations.  Warm start sets the initial mean to the proposal
    and the step size to ``warm_sigma``."""
    return _search_grid("cmaes", workloads, hw, batches, budgets_bytes,
                        nmax=nmax, cfg=cfg,
                        init_strategies=init_strategies, salts=salts,
                        packed=packed, evaluator=evaluator)
