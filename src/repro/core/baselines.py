"""Baseline black-box optimizers for Table 1 (paper §5.1).

The paper compares G-Sampler against nevergrad's PSO / CMA-ES / DE / TBPSA /
stdGA plus an A2C agent (see ``a2c.py``).  nevergrad is not available
offline, so the five optimizers are implemented here from their standard
formulations, operating on a continuous relaxation of the strategy vector
(decoded to {SYNC} u [1..B]); like in the paper, they receive NO domain
knowledge (no heuristic seeding, no repair operator) and a 2k sampling
budget — which is precisely why they fail the memory constraint in Table 1.

All candidate batches are evaluated through the same vmapped cost model as
G-Sampler, so wall-clock comparisons are apples-to-apples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from . import cost_model as cm

__all__ = ["SearchResult", "run_baseline", "BASELINE_METHODS"]

_PENALTY = 1e3


@dataclass
class SearchResult:
    method: str
    strategy: np.ndarray
    speedup: float
    latency: float
    peak_mem: float
    valid: bool
    n_evals: int
    wall_s: float


def _decode(z: np.ndarray, batch: int, nmax: int, n: int) -> np.ndarray:
    """Continuous genome -> strategy.

    The paper's map-space has "64 tiling choices per layer" (§2): choice 0 is
    SYNC, choices 1..B are micro-batch sizes.  Under an uninformed init the
    sync choice is hit w.p. ~1/(B+1), so random candidates fuse nearly
    everything and blow the memory budget — exactly the Table 1 behaviour of
    the domain-agnostic baselines (usages of 100-400 MB, marked N/A).
    """
    idx = np.floor(np.clip(z, 0.0, batch + 0.999)).astype(np.int32)
    s = np.full((z.shape[0], nmax), cm.SYNC, dtype=np.int32)
    s[:, : n + 1] = np.where(idx[:, : n + 1] == 0, cm.SYNC, idx[:, : n + 1])
    s[:, 0] = np.maximum(s[:, 0], 1)
    return s


def _score(env, z: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched objective (lower better).

    Faithful to the paper's Table 1 protocol: the domain-agnostic baselines
    minimize raw latency; the memory constraint is checked *post hoc* and
    over-budget solutions are reported N/A with their (100-400 MB) usages.
    Since fusing more monotonically reduces modeled latency, unconstrained
    optimizers drift deep into the invalid region — the paper's observation
    that they "cannot meet the constraint within the 2K sampling budget".
    """
    strat = _decode(z, env.batch, env.nmax, env.n)
    out = cm.evaluate_population(env.wl, jnp.asarray(strat), float(env.batch),
                                 float(env.budget_bytes), env.hw)
    lat = np.asarray(out.latency, dtype=np.float64)
    peak = np.asarray(out.peak_mem, dtype=np.float64)
    return lat.copy(), lat, peak


def _finish(env, method: str, zbest: np.ndarray, n_evals: int,
            t0: float) -> SearchResult:
    strat = _decode(zbest[None], env.batch, env.nmax, env.n)[0]
    out = env.evaluate_strategy(strat)
    lat, peak = float(out.latency), float(out.peak_mem)
    return SearchResult(method, strat, env.baseline_latency / lat, lat, peak,
                        bool(out.valid), n_evals, time.perf_counter() - t0)


def _init_pop(rng, pop: int, dim: int, batch: int) -> np.ndarray:
    """Uninformed init: uniform over the B+1 tiling choices."""
    return rng.uniform(0.0, batch + 1.0, size=(pop, dim))


def pso(env, budget: int = 2000, seed: int = 0, pop: int = 40) -> SearchResult:
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    x = _init_pop(rng, pop, dim, env.batch)
    v = rng.normal(0, 1, size=(pop, dim))
    obj, _, _ = _score(env, x); n_evals = pop
    pbest, pobj = x.copy(), obj.copy()
    g = int(np.argmin(obj)); gbest, gobj = x[g].copy(), obj[g]
    w, c1, c2 = 0.7, 1.5, 1.5
    while n_evals + pop <= budget:
        r1, r2 = rng.random((pop, dim)), rng.random((pop, dim))
        v = w * v + c1 * r1 * (pbest - x) + c2 * r2 * (gbest - x)
        x = x + v
        obj, _, _ = _score(env, x); n_evals += pop
        imp = obj < pobj
        pbest[imp], pobj[imp] = x[imp], obj[imp]
        g = int(np.argmin(pobj))
        if pobj[g] < gobj:
            gbest, gobj = pbest[g].copy(), pobj[g]
    return _finish(env, "PSO", gbest, n_evals, t0)


def de(env, budget: int = 2000, seed: int = 0, pop: int = 40) -> SearchResult:
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    x = _init_pop(rng, pop, dim, env.batch)
    obj, _, _ = _score(env, x); n_evals = pop
    F, CR = 0.8, 0.9
    while n_evals + pop <= budget:
        idx = np.array([rng.choice(pop, 3, replace=False) for _ in range(pop)])
        mutant = x[idx[:, 0]] + F * (x[idx[:, 1]] - x[idx[:, 2]])
        cross = rng.random((pop, dim)) < CR
        cross[np.arange(pop), rng.integers(0, dim, pop)] = True
        trial = np.where(cross, mutant, x)
        tobj, _, _ = _score(env, trial); n_evals += pop
        imp = tobj < obj
        x[imp], obj[imp] = trial[imp], tobj[imp]
    b = int(np.argmin(obj))
    return _finish(env, "DE", x[b], n_evals, t0)


def cma_es(env, budget: int = 2000, seed: int = 0, pop: int = 40) -> SearchResult:
    """(mu/mu_w, lambda)-CMA-ES (Hansen 2006), full covariance."""
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    mean = rng.uniform(0, env.batch / 2, size=dim)
    sigma = env.batch / 4.0
    lam = pop; mu = lam // 2
    wts = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
    wts /= wts.sum(); mueff = 1.0 / np.sum(wts ** 2)
    cc = (4 + mueff / dim) / (dim + 4 + 2 * mueff / dim)
    cs = (mueff + 2) / (dim + mueff + 5)
    c1 = 2 / ((dim + 1.3) ** 2 + mueff)
    cmu = min(1 - c1, 2 * (mueff - 2 + 1 / mueff) / ((dim + 2) ** 2 + mueff))
    damps = 1 + 2 * max(0, np.sqrt((mueff - 1) / (dim + 1)) - 1) + cs
    pc = np.zeros(dim); ps = np.zeros(dim); C = np.eye(dim)
    chiN = np.sqrt(dim) * (1 - 1 / (4 * dim) + 1 / (21 * dim ** 2))
    n_evals = 0; best, bobj = mean.copy(), np.inf
    while n_evals + lam <= budget:
        try:
            Bm = np.linalg.cholesky((C + C.T) / 2 + 1e-10 * np.eye(dim))
        except np.linalg.LinAlgError:
            C = np.eye(dim); Bm = C
        z = rng.normal(size=(lam, dim))
        x = mean + sigma * z @ Bm.T
        obj, _, _ = _score(env, x); n_evals += lam
        order = np.argsort(obj)
        if obj[order[0]] < bobj:
            best, bobj = x[order[0]].copy(), obj[order[0]]
        xsel = x[order[:mu]]
        old_mean = mean
        mean = wts @ xsel
        y = (mean - old_mean) / sigma
        Cinvsqrt = np.linalg.pinv(Bm)
        ps = (1 - cs) * ps + np.sqrt(cs * (2 - cs) * mueff) * (Cinvsqrt @ y)
        hsig = (np.linalg.norm(ps) / np.sqrt(1 - (1 - cs) ** (2 * n_evals / lam))
                / chiN) < (1.4 + 2 / (dim + 1))
        pc = (1 - cc) * pc + hsig * np.sqrt(cc * (2 - cc) * mueff) * y
        artmp = (xsel - old_mean) / sigma
        C = ((1 - c1 - cmu) * C + c1 * (np.outer(pc, pc)
             + (not hsig) * cc * (2 - cc) * C)
             + cmu * artmp.T @ np.diag(wts) @ artmp)
        sigma *= np.exp((cs / damps) * (np.linalg.norm(ps) / chiN - 1))
        sigma = float(np.clip(sigma, 1e-3, env.batch))
    return _finish(env, "CMA", best, n_evals, t0)


def tbpsa(env, budget: int = 2000, seed: int = 0, pop: int = 40) -> SearchResult:
    """Test-based population-size adaptation (simplified (mu, lambda)-ES
    with averaged elites, nevergrad's noisy-optimization default)."""
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    mean = rng.uniform(0, env.batch / 2, size=dim)
    sigma = np.full(dim, env.batch / 4.0)
    lam = pop; mu = max(2, lam // 4)
    n_evals = 0; best, bobj = mean.copy(), np.inf
    while n_evals + lam <= budget:
        x = mean + sigma * rng.normal(size=(lam, dim))
        obj, _, _ = _score(env, x); n_evals += lam
        order = np.argsort(obj)
        if obj[order[0]] < bobj:
            best, bobj = x[order[0]].copy(), obj[order[0]]
        elite = x[order[:mu]]
        mean = elite.mean(axis=0)
        sigma = 0.9 * sigma + 0.1 * elite.std(axis=0) * np.sqrt(mu / dim + 1.0)
        sigma = np.clip(sigma, 1e-2, env.batch)
    return _finish(env, "TBPSA", best, n_evals, t0)


def std_ga(env, budget: int = 2000, seed: int = 0, pop: int = 40) -> SearchResult:
    """Generic GA: uniform crossover + gene resample, NO domain operators."""
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    x = _init_pop(rng, pop, dim, env.batch)
    obj, _, _ = _score(env, x); n_evals = pop
    while n_evals + pop <= budget:
        order = np.argsort(obj)
        elite = x[order[:4]]
        children = [e.copy() for e in elite]
        while len(children) < pop:
            pa, pb = x[order[rng.integers(0, pop // 2)]], \
                x[order[rng.integers(0, pop // 2)]]
            child = np.where(rng.random(dim) < 0.5, pa, pb)
            mut = rng.random(dim) < 0.1
            child[mut] = rng.uniform(0.0, env.batch + 1.0, size=mut.sum())
            children.append(child)
        x = np.stack(children)
        obj, _, _ = _score(env, x); n_evals += pop
    b = int(np.argmin(obj))
    return _finish(env, "stdGA", x[b], n_evals, t0)


def random_search(env, budget: int = 2000, seed: int = 0,
                  pop: int = 40) -> SearchResult:
    rng = np.random.default_rng(seed); t0 = time.perf_counter()
    dim = env.n + 1
    best, bobj, n_evals = None, np.inf, 0
    while n_evals + pop <= budget:
        x = _init_pop(rng, pop, dim, env.batch)
        obj, _, _ = _score(env, x); n_evals += pop
        b = int(np.argmin(obj))
        if obj[b] < bobj:
            best, bobj = x[b].copy(), obj[b]
    return _finish(env, "Random", best, n_evals, t0)


BASELINE_METHODS = {
    "PSO": pso, "CMA": cma_es, "DE": de, "TBPSA": tbpsa,
    "stdGA": std_ga, "Random": random_search,
}


def run_baseline(env, method: str, budget: int = 2000,
                 seed: int = 0) -> SearchResult:
    return BASELINE_METHODS[method](env, budget=budget, seed=seed)
