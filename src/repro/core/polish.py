"""Gradient polish of integer fusion strategies (DESIGN.md §17).

The whole cost model is a traced JAX program, so a proposed strategy can
be REFINED by descent instead of re-searched: relax the integer
micro-batches to a continuous tile space (the sync structure — which
positions flush — stays FIXED), descend a smooth twin of the cost under a
ramped budget penalty, then re-round snapshots of the trajectory and keep
the best exactly-scored valid candidate.

Relaxation contract:

 - ``mb_i = 1 + (B - 1) * sigmoid(z_i)`` maps unconstrained ``z`` into the
   legal tile range ``[1, B]`` (no clipping kinks inside the descent);
 - the smooth evaluator mirrors ``cost_model._evaluate_full`` EXCEPT that
   micro-batch waves are continuous (``B / mbe`` instead of
   ``ceil(B / mbe)``) — the one integer cliff in the model, and a lower
   bound of the integer cost that is tight at divisors of ``B``;
 - the descent loss is ``latency / latency_0 + lam_t * relu(peak/budget
   - 1)^2`` with ``lam_t`` ramped geometrically from ``lam0`` to ``lam1``
   over the steps, so early steps chase latency across the budget surface
   and late steps are pushed back inside it.  Snapshots along the ramp
   capture both regimes.

Rounding contract (the never-worsens guarantee): every snapshot is
re-rounded three ways (round-to-nearest, floor — it can restore validity
that rounding up broke — and ceil — the smooth twin undercuts the real
``ceil(B/mbe)`` just below wave boundaries, where the integer winner is
the tile ABOVE the continuous optimum), each candidate is doubled with a
tail-flush variant (SYNC at position ``n`` — the one topology move the
exact scorer tries for free), the ORIGINAL is prepended, and
all candidates are exactly re-scored through
``cost_model.evaluate_grid`` (``evaluator`` = "xla" | "pallas", both
backends bit-identical) — so the returned strategy is never worse than
the input: the best valid candidate by exact latency wins, ties keep the
original.  If NO candidate is valid, a deterministic constraint repair
(shrink the worst group's largest stage, else split it — the G-Sampler
operator without its coin flip) runs on the lowest-peak candidate; if
even that fails the original comes back untouched.

Everything here is strictly OPT-IN: the bit-exact one-shot serving path
never calls it unless ``ServingConfig(polish=True)``.  The polisher is
deterministic — no RNG anywhere — and per-condition ops are vmapped with
no cross-lane coupling, so a request's polished answer cannot depend on
which tick it arrived in (the §14 determinism contract).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model as cm
from .accel import as_hw, stack_hw

__all__ = ["PolishConfig", "PolishResult", "polish_strategy", "polish_grid"]


@dataclass(frozen=True)
class PolishConfig:
    """Descent/rounding knobs (hashable: it is a static jit argument)."""
    steps: int = 48          # Adam steps along the penalty ramp
    snapshots: int = 6       # re-rounded trajectory points (3 cands each)
    lr: float = 0.16         # Adam step size in z (logit-tile) space;
    # sized so steps*lr covers the logit range mid-tile -> saturation
    # (a proposal at B/2 can reach B within one descent)
    lam0: float = 0.1        # penalty weight at step 0
    lam1: float = 300.0      # penalty weight at the last step
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    zclip: float = 12.0      # |z| bound (sigmoid saturation guard)
    repair_tries: int = 8    # deterministic-repair rounds for invalid cells


@dataclass
class PolishResult:
    """One polished condition: the accepted strategy with its exact cost
    and the pre-polish numbers it is guaranteed not to be worse than."""
    strategy: np.ndarray
    latency: float
    peak_mem: float
    valid: bool
    improved: bool
    pre_latency: float
    pre_peak: float
    pre_valid: bool
    wall_s: float


# ---------------------------------------------------------------------------
# The smooth relaxed evaluator.
# ---------------------------------------------------------------------------


def _relaxed_cost(wl: dict, sync: jax.Array, mb: jax.Array, batch,
                  budget_bytes, hw) -> cm.CostOut:
    """Smooth twin of ``cost_model._evaluate_full`` over a FIXED sync
    structure: ``sync`` [P] bool is given (not derived from a strategy
    vector) and ``mb`` [P] is continuous in ``[1, B]``.  Identical math
    except ``waves = B / mbe`` (no ceil), so the latency/peak surface is
    differentiable in ``mb`` everywhere off the roofline/clip kinks."""
    hw = as_hw(hw)
    A, W = cm._scaled_AW(wl, hw)
    F, OE, UC = wl["F"], wl["OE"], wl["UC"]
    mask, skip, n = wl["mask"], wl["SKIP"], wl["n"]
    P = A.shape[0]
    pos = jnp.arange(P)
    B = jnp.asarray(batch, jnp.float32)

    sync = sync & mask
    mb = jnp.clip(mb, 1.0, B)
    prev_mb = jnp.roll(mb, 1).at[0].set(1.0)
    prev_sync = jnp.roll(sync, 1).at[0].set(False)
    mbe = jnp.where(sync, jnp.where(prev_sync, 1.0, prev_mb), mb)
    stage_mb = jnp.where(sync, 1.0, mb)
    fmask = mask.astype(jnp.float32)

    gid = (jnp.cumsum(sync.astype(jnp.int32)) - sync.astype(jnp.int32))
    head = mask & (jnp.roll(sync, 1).at[0].set(False) | (pos == 1))
    tail = mask & (sync | (pos == n))
    glen = jax.ops.segment_sum(fmask, gid, num_segments=P,
                               indices_are_sorted=True)
    fused = (glen[gid] > 1.0) & mask
    mbe = jnp.where(fused, mbe, B)

    A_prev = jnp.roll(A, 1).at[0].set(0.0)
    has_skip = (skip >= 0) & mask
    src = jnp.clip(skip, 0, P - 1)
    same_group = has_skip & (gid[src] == gid)
    skip_hold = jnp.where(same_group, mbe * A[src], 0.0)
    skip_traffic = jnp.where(has_skip & ~same_group, 2.0 * B * A[src], 0.0)

    m_fused = (stage_mb * A + head.astype(jnp.float32) * mbe * A_prev
               + skip_hold)
    mem_i = jnp.where(fused, m_fused, jnp.minimum(m_fused,
                                                  hw.stream_buf_bytes))
    M_g = jax.ops.segment_sum(mem_i * fmask, gid, num_segments=P,
                              indices_are_sorted=True)

    waves = B / mbe                       # continuous: the relaxation
    t_i = (head.astype(jnp.float32) * B * A_prev
           + tail.astype(jnp.float32) * B * A + W * waves + skip_traffic)
    T_g = jax.ops.segment_sum(t_i * fmask, gid, num_segments=P,
                              indices_are_sorted=True)

    util = jnp.clip(mbe * OE / (hw.npe * hw.pe_lanes), cm._UTIL_MIN, UC)
    comp = B * F / hw.peak_macs / util
    C_g = jax.ops.segment_sum(comp * fmask, gid, num_segments=P,
                              indices_are_sorted=True)
    o_i = B * (A_prev + A) + W * waves
    O_g = jax.ops.segment_sum(o_i * fmask, gid, num_segments=P,
                              indices_are_sorted=True)
    wave_g = jax.ops.segment_sum(waves * fmask, gid, num_segments=P,
                                 indices_are_sorted=True)

    return cm.finalize_groups(C_g, T_g, O_g, M_g, wave_g, glen,
                              budget_bytes, hw)


def _mb_of(z: jax.Array, B) -> jax.Array:
    return 1.0 + (B - 1.0) * jax.nn.sigmoid(z)


def _z_of(strategy: jax.Array, B) -> jax.Array:
    """Logit-space init: ``mb_of(z_of(s)) ~= clip(s, 1, B)``.  SYNC
    positions land at the low saturation (their tile is unused — a sync
    rides its producer's micro-batch)."""
    mb0 = jnp.clip(strategy.astype(jnp.float32), 1.0, B)
    frac = jnp.clip((mb0 - 1.0) / jnp.maximum(B - 1.0, 1e-6),
                    1e-4, 1.0 - 1e-4)
    return jnp.log(frac) - jnp.log1p(-frac)


def _snap_indices(cfg: PolishConfig) -> tuple[int, ...]:
    """Static snapshot steps: ``snapshots`` points spread over the ramp,
    always including the final step (skipping step ~0: that is the
    original, which is prepended as its own candidate)."""
    k = max(1, min(cfg.snapshots, cfg.steps))
    return tuple(sorted({int(i) for i in
                         np.linspace(0, cfg.steps - 1, k + 1)[1:]}))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _descent_grid_jit(wls, strategies, batches, budgets, hw,
                      cfg: PolishConfig):
    """Vmapped Adam descent: [C] conditions -> tile snapshots [C, K, P].

    Deterministic (no RNG) and per-condition independent — a lane's
    snapshots do not depend on its neighbours or its index."""
    lams = jnp.exp(jnp.linspace(jnp.log(cfg.lam0), jnp.log(cfg.lam1),
                                cfg.steps))
    snap = jnp.asarray(_snap_indices(cfg))

    def one(wl, s, b, m, h):
        B = jnp.asarray(b, jnp.float32)
        sync = (s < 0) & wl["mask"]
        z0 = _z_of(s, B)
        lat0 = jnp.maximum(
            _relaxed_cost(wl, sync, _mb_of(z0, B), B, m, h).latency, 1e-30)

        def loss(z, lam):
            out = _relaxed_cost(wl, sync, _mb_of(z, B), B, m, h)
            over = jnp.maximum(out.peak_mem / m - 1.0, 0.0)
            return out.latency / lat0 + lam * over * over

        def step(carry, lam):
            z, mu, nu, t = carry
            g = jax.grad(loss)(z, lam)
            t = t + 1.0
            mu = cfg.beta1 * mu + (1.0 - cfg.beta1) * g
            nu = cfg.beta2 * nu + (1.0 - cfg.beta2) * g * g
            mh = mu / (1.0 - cfg.beta1 ** t)
            nh = nu / (1.0 - cfg.beta2 ** t)
            z = jnp.clip(z - cfg.lr * mh / (jnp.sqrt(nh) + cfg.eps),
                         -cfg.zclip, cfg.zclip)
            return (z, mu, nu, t), _mb_of(z, B)

        init = (z0, jnp.zeros_like(z0), jnp.zeros_like(z0),
                jnp.float32(0.0))
        _, mbs = jax.lax.scan(step, init, lams)
        return mbs[snap]                                     # [K, P]

    return jax.vmap(one)(wls, strategies, batches, budgets, hw)


# ---------------------------------------------------------------------------
# Re-rounding, exact scoring, deterministic repair.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("tries", "evaluator"))
def _repair_det_jit(wls, s, batches, budgets, hw, tries: int,
                    evaluator: str = "xla"):
    """Deterministic twin of ``gsampler._repair_grid``: while a strategy
    is over budget, SHRINK the worst group's largest staged micro-batch,
    or SPLIT the group when no stage can shrink — no coin flip, so the
    result is a pure function of the input (lane-order invariant)."""
    C, K, P = s.shape
    pos = jnp.arange(P)
    mask = wls["mask"]

    def cond_fn(carry):
        _, i, pending = carry
        return (i < tries) & pending

    def round_fn(carry):
        s, i, _ = carry
        out, gid, M_g = cm.evaluate_grid_stats(wls, s, batches, budgets,
                                               hw, evaluator=evaluator)
        invalid = ~out.valid                                  # [C, K]
        worst = jnp.argmax(M_g, axis=-1)
        members = (gid == worst[..., None]) & mask[:, None, :]
        start = jnp.argmax(members, axis=-1)
        end = P - 1 - jnp.argmax(members[..., ::-1], axis=-1)
        mid = (start + end) // 2
        multi = end > start
        seg_mb = jnp.where(members & (s > 1), s, 0)
        jmax = jnp.argmax(seg_mb, axis=-1)
        has_mb = jnp.max(seg_mb, axis=-1) > 1
        onehot_mid = pos[None, None, :] == mid[..., None]
        onehot_j = pos[None, None, :] == jmax[..., None]
        shrink_s = jnp.where(onehot_j, jnp.maximum(1, s // 2), s)
        split_s = jnp.where(multi[..., None] & onehot_mid, cm.SYNC, s)
        new = jnp.where(has_mb[..., None], shrink_s, split_s)
        apply = invalid & members.any(-1)
        s = jnp.where(apply[..., None], new, s)
        return s, i + 1, invalid.any()

    s, _, _ = jax.lax.while_loop(cond_fn, round_fn,
                                 (s, jnp.int32(0), jnp.bool_(True)))
    return s


def _round_candidates(strategies: np.ndarray, mbs: np.ndarray,
                      batches: np.ndarray, ns: np.ndarray,
                      mask: np.ndarray) -> np.ndarray:
    """[original | round | floor | ceil](snapshots)] x [as-is |
    tail-flush] -> [C, 2(1+3K), P].

    Sync positions and padding keep SYNC; tiles clip to [1, B].  All
    three integer neighbours matter: the smooth ``B/mbe`` twin undercuts
    the real ``ceil(B/mbe)`` just below wave boundaries, so the
    continuous optimum often sits at e.g. 63.4 where 64 (ceil) is the
    true winner, 63 (round/floor) pays a whole extra wave, and floor can
    restore validity that rounding up broke."""
    C, K, P = mbs.shape
    pos = np.arange(P)
    validp = pos[None, :] <= ns[:, None]
    sync = (strategies < 0) & mask
    B = batches.astype(np.float64)[:, None, None]
    tiles = np.concatenate([np.rint(mbs), np.floor(mbs), np.ceil(mbs)],
                           axis=1)
    tiles = np.clip(tiles, 1.0, B).astype(np.int32)
    cand = np.where(sync[:, None, :], cm.SYNC, tiles)
    cand = np.where(validp[:, None, :], cand, cm.SYNC)
    cand = np.concatenate([strategies[:, None, :], cand],
                          axis=1).astype(np.int32)
    # tail-flush variants: the one sync-topology move the exact scorer
    # gets to try for free — flushing the LAST layer (SYNC at position n)
    # shrinks the final group's working set, which at tight budgets lets
    # the interior tiles stay saturated instead of shrinking everywhere.
    # The descent's tiles are reused; position 0 can never sync (rows
    # with n == 0 just duplicate, and duplicates re-score harmlessly).
    tail = (pos[None, :] == ns[:, None]) & (ns > 0)[:, None]
    flush = np.where(tail[:, None, :], cm.SYNC, cand)
    return np.concatenate([cand, flush], axis=1)


def polish_grid(wls: dict, strategies, batches, budgets_bytes, hw, *,
                cfg: PolishConfig = PolishConfig(),
                evaluator: str | None = None) -> dict:
    """Polish [C] proposed strategies in one fused pipeline.

    ``wls`` is a ``stack_workloads`` dict [C, ...]; ``strategies``
    [C, P] int32 (SYNC = -1); ``hw`` anything ``accel.stack_hw`` accepts.
    Returns a dict of numpy arrays: the accepted ``strategy`` [C, P] plus
    its exact ``latency`` / ``peak_mem`` / ``valid`` and the pre-polish
    ``pre_latency`` / ``pre_peak`` / ``pre_valid``; ``improved`` [C] marks
    cells where polish strictly beat the proposal (lower exact latency,
    or validity restored).  Per cell the result is NEVER worse than the
    input (see the module docstring's rounding contract)."""
    strategies = np.asarray(strategies, np.int32)
    C, P = strategies.shape
    batches = np.asarray(batches, np.float32)
    budgets = np.asarray(budgets_bytes, np.float32)
    hwv = stack_hw(hw, C)
    wls_j = {k: jnp.asarray(v) for k, v in wls.items()}
    mask = np.asarray(wls["mask"]).astype(bool)
    ns = np.asarray(wls["n"], np.int64)
    ev = cm._resolve_evaluator(evaluator)

    mbs = np.asarray(_descent_grid_jit(
        wls_j, jnp.asarray(strategies), jnp.asarray(batches),
        jnp.asarray(budgets), hwv, cfg))                      # [C, K, P]
    cands = _round_candidates(strategies, mbs, batches, ns, mask)
    out = cm.evaluate_grid(wls_j, jnp.asarray(cands),
                           jnp.asarray(batches), jnp.asarray(budgets),
                           hwv, evaluator=ev)
    lat = np.asarray(out.latency)
    peak = np.asarray(out.peak_mem)
    val = np.asarray(out.valid)

    rows = np.arange(C)
    score = np.where(val, lat, np.inf)
    pick = np.argmin(score, axis=1)        # ties -> lowest index: original
    has_valid = val.any(axis=1)

    final = cands[rows, pick]
    f_lat, f_peak, f_val = lat[rows, pick], peak[rows, pick], val[rows, pick]

    if not has_valid.all():
        # no rounding was valid anywhere in these cells: deterministic
        # repair of the lowest-peak candidate, then exact re-score
        alt = np.argmin(peak, axis=1)
        seed = cands[rows, np.where(has_valid, pick, alt)][:, None, :]
        rep = np.asarray(_repair_det_jit(
            wls_j, jnp.asarray(seed), jnp.asarray(batches),
            jnp.asarray(budgets), hwv, cfg.repair_tries, ev))[:, 0]
        rout = cm.evaluate_grid(wls_j, jnp.asarray(rep[:, None, :]),
                                jnp.asarray(batches), jnp.asarray(budgets),
                                hwv, evaluator=ev)
        r_lat = np.asarray(rout.latency)[:, 0]
        r_peak = np.asarray(rout.peak_mem)[:, 0]
        r_val = np.asarray(rout.valid)[:, 0]
        use = ~has_valid & r_val
        final = np.where(use[:, None], rep, final)
        f_lat = np.where(use, r_lat, f_lat)
        f_peak = np.where(use, r_peak, f_peak)
        f_val = np.where(use, r_val, f_val)
        # still invalid: hand the original back untouched
        keep = ~has_valid & ~r_val
        final = np.where(keep[:, None], strategies, final)
        f_lat = np.where(keep, lat[:, 0], f_lat)
        f_peak = np.where(keep, peak[:, 0], f_peak)
        f_val = np.where(keep, val[:, 0], f_val)

    o_lat, o_peak, o_val = lat[:, 0], peak[:, 0], val[:, 0]
    improved = (f_val & ~o_val) | (f_val & o_val & (f_lat < o_lat))
    return dict(strategy=final.astype(np.int32), latency=f_lat,
                peak_mem=f_peak, valid=f_val, improved=improved,
                pre_latency=o_lat, pre_peak=o_peak, pre_valid=o_val)


def polish_strategy(env, strategy, *, cfg: PolishConfig = PolishConfig(),
                    evaluator: str | None = None) -> PolishResult:
    """Polish one strategy against a ``FusionEnv`` condition (the
    single-condition front door; :func:`polish_grid` is the fused form
    the engine and benchmarks use)."""
    t0 = time.perf_counter()
    wls = cm.stack_workloads([env.wl])
    res = polish_grid(wls, np.asarray(strategy, np.int32)[None, :],
                      [float(env.batch)], [float(env.budget_bytes)],
                      [env.hw], cfg=cfg, evaluator=evaluator)
    return PolishResult(
        strategy=res["strategy"][0], latency=float(res["latency"][0]),
        peak_mem=float(res["peak_mem"][0]), valid=bool(res["valid"][0]),
        improved=bool(res["improved"][0]),
        pre_latency=float(res["pre_latency"][0]),
        pre_peak=float(res["pre_peak"][0]),
        pre_valid=bool(res["pre_valid"][0]),
        wall_s=time.perf_counter() - t0)
