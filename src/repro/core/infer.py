"""One-shot inference: the paper's headline capability (§4.5.2).

The trained model is rolled out autoregressively against the cost-model
environment: at step t it reads the (reward, state, action) prefix — with
the conditioning reward supplied by the requested memory budget — and emits
micro-batch a_t; the environment updates s_{t+1}/r_{t+1}.  One rollout
(= N+1 tiny forward passes) replaces an entire 2k-sample search, which is
the 66x-127x speed claim benchmarked in ``benchmarks/speed_oneshot.py``.

Two implementations (DESIGN.md §9): the host reference ``_rollout`` (a
Python loop re-running a jitted full-sequence forward and a full cost-model
evaluation per step — the readable oracle) and the device-resident
``dnnfuser_infer_fused`` — one jitted ``jax.lax.scan`` fusing cached
single-token decode, the O(1) ``prefix_step`` env transition and a
``lax.while_loop`` halve-or-sync budget guard, zero host syncs inside the
episode.  Both roll any model implementing the ``backend.MapperBackend``
protocol (DESIGN §12): DT (KV cache) and seq2seq (streaming LSTM state)
ride the exact same episode code via ``backend_for``.

``dnnfuser_infer_batch`` vmaps the episode over a stacked batch of serving
conditions in one device call.  Since DESIGN §11 the accelerator is a
traced per-row condition (``accel.HwVec``); since §12 the WORKLOAD is too
(``cost_model.stack_workloads``: heterogeneous networks padded to a shared
``nmax``, positions past each row's true ``n`` masked to SYNC), so one
device call serves "resnet50 on mobile at 20 MB" next to "mnasnet on edge
at 8 MB".  This is the serving primitive ``repro.serving.MapperEngine``
and the benchmarks fan out over.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .env import (FusionEnv, STATE_DIM, decode_action, encode_action,
                  decode_action_jnp, encode_action_jnp, env_make,
                  env_observe, env_reset, env_step, env_final)
from .backend import backend_for
from .accel import accel_features, as_hw, stack_hw
from . import cost_model as cm

__all__ = ["InferResult", "dnnfuser_infer", "s2s_infer",
           "dnnfuser_infer_fused", "s2s_infer_fused", "dnnfuser_infer_batch"]


@dataclass
class InferResult:
    strategy: np.ndarray
    speedup: float
    latency: float
    peak_mem: float
    valid: bool
    wall_s: float
    n_model_calls: int


@partial(jax.jit, static_argnames=("cfg", "backend"))
def _forward(params, cfg, backend, rtg, states, actions, hw=None):
    return backend.forward(params, cfg, rtg, states, actions, hw)


def _hw_condition(cfg, env: FusionEnv):
    """The model's hw-condition row [1, F] (None for pre-§11 configs).

    Computed on the host from the SAME ``accel_features`` the batched
    front-end uses, so host and fused rollouts see bit-identical inputs."""
    if not getattr(cfg, "hw_dim", 0):
        return None
    return env.hw_features[None]


def _rollout(backend, params, cfg, env: FusionEnv, *,
             repair: bool) -> InferResult:
    T = cfg.max_steps
    rtg = np.zeros((1, T), np.float32)
    states = np.zeros((1, T, STATE_DIM), np.float32)
    actions = np.zeros((1, T), np.float32)
    hwf = _hw_condition(cfg, env)
    t0 = time.perf_counter()
    s = env.reset()
    calls = 0
    for t in range(env.n + 1):
        states[0, t] = s
        rtg[0, t] = env.reward_to_go
        pred = _forward(params, cfg, backend, jnp.asarray(rtg),
                        jnp.asarray(states), jnp.asarray(actions),
                        None if hwf is None else jnp.asarray(hwf))
        calls += 1
        a_enc = float(pred[0, t])
        a = int(decode_action(a_enc, env.batch))
        if t == 0 and a < 1:
            a = 1                      # input micro-batch cannot sync
        if repair and a >= 1 and t > 0:
            # inference-time constraint guard (the model conditions on the
            # budget, but a hard guard keeps generalization runs valid):
            # shrink/sync if the staged buffer would overflow.
            while a >= 1:
                probe = env.actions.copy(); probe[t] = a
                pos = np.arange(env.nmax)
                probe = np.where(pos <= t, probe, cm.SYNC)
                out = env.evaluate_strategy(probe)
                if float(out.peak_mem) <= env.budget_bytes:
                    break
                a = a // 2 if a > 1 else cm.SYNC
        actions[0, t] = encode_action(np.float32(a), env.batch)
        s, _, done = env.step(a)
    wall = time.perf_counter() - t0
    strat = env.actions.copy()
    out = env.evaluate_strategy(strat)
    return InferResult(strat, env.baseline_latency / float(out.latency),
                       float(out.latency), float(out.peak_mem),
                       bool(out.valid), wall, calls)


def dnnfuser_infer(params, cfg, env: FusionEnv, *,
                   repair: bool = True) -> InferResult:
    """Conditional autoregressive inference (host reference); works for any
    registered ``MapperBackend`` config (DT, seq2seq, ...)."""
    return _rollout(backend_for(cfg), params, cfg, env, repair=repair)


# ---------------------------------------------------------------------------
# Device-resident fused rollout (DESIGN.md §9, §12).
# ---------------------------------------------------------------------------


def _fused_episode(params, cfg, wl, batch, budget_bytes, hw,
                   hw_feats, repair: bool, backend) -> dict:
    """One (workload, batch, budget, accel) episode, fully traced.

    All control flow the host loop does in Python — the per-step env
    observation, the model call, the halve-or-sync budget guard and the env
    transition — runs inside one ``lax.scan`` (guard: ``lax.while_loop``),
    so the episode lowers to a single device program with no host syncs.
    Everything that varies per serving lane is traced data and vmaps:
    ``hw``/``hw_feats`` (DESIGN §11) and, since §12, the packed workload
    ``wl`` itself — positions past a lane's true ``n`` are masked to SYNC
    (``active``), which is what makes heterogeneous-length rows under one
    ``nmax`` bit-exact with their unpadded single-row rollouts.
    """
    consts = env_make(wl, batch, budget_bytes, hw)
    B, budget, n = consts.B, consts.budget, consts.n
    P = wl["A"].shape[0]
    hwb = None if hw_feats is None else hw_feats[None]

    def guard(carry, a):
        """The host probe loop: shrink / sync until the staged prefix plus
        an all-SYNC suffix fits the budget (paper's inference-time
        constraint guard).  Probes via the peak-only fast path."""
        def cond(av):
            return (av >= 1) & (cm.prefix_probe_peak(consts.pc, carry, av,
                                                     hw) > budget)
        def body(av):
            return jnp.where(av > 1, av // 2, jnp.int32(cm.SYNC))
        return jax.lax.while_loop(cond, body, a)

    # --- t = 0: prefill (r_0, s_0); the input micro-batch cannot sync ------
    carry0 = env_reset(consts)
    r0, s0 = env_observe(consts, carry0, hw)
    pred0, mstate = backend.prefill(params, cfg, backend.state_init(cfg),
                                    r0[None], s0[None], hwb)
    a0 = jnp.maximum(decode_action_jnp(pred0[0], B), 1)
    carry = env_step(consts, carry0, a0, hw)
    actions = jnp.full((P,), cm.SYNC, jnp.int32).at[0].set(a0)

    def step(sc, t):
        carry, mstate, a_prev, actions = sc
        active = t <= n
        r_t, s_t = env_observe(consts, carry, hw)
        pred, mstate = backend.step(params, cfg, mstate, r_t[None], s_t[None],
                                    encode_action_jnp(a_prev, B)[None], hwb)
        a = decode_action_jnp(pred[0], B)
        if repair:
            a = guard(carry, a)
        a = jnp.where(active, a, jnp.int32(cm.SYNC))
        new_carry = env_step(consts, carry, a, hw)
        carry = cm._tree_select(active, new_carry, carry)
        actions = actions.at[t].set(a)
        a_prev = jnp.where(active, a, a_prev)
        return (carry, mstate, a_prev, actions), None

    (carry, _, _, actions), _ = jax.lax.scan(
        step, (carry, mstate, a0, actions), jnp.arange(1, P))
    out = env_final(consts, carry, hw)
    return dict(strategy=actions, latency=out.latency,
                peak_mem=out.peak_mem, valid=out.valid,
                speedup=consts.base_lat / jnp.maximum(out.latency, 1e-12),
                baseline_latency=consts.base_lat)


@partial(jax.jit, static_argnames=("cfg", "repair", "backend"))
def _fused_one(params, cfg, wl, batch, budget_bytes, hw, hw_feats,
               repair, backend):
    return _fused_episode(params, cfg, wl, batch, budget_bytes, hw,
                          hw_feats, repair, backend)


@partial(jax.jit, static_argnames=("cfg", "repair", "backend", "stacked"))
def _fused_batch(params, cfg, wl, batches, budgets, hw, hw_feats,
                 repair, backend, stacked):
    # ``stacked`` workloads carry a leading per-row axis and vmap alongside
    # the other conditions; a shared workload broadcasts (in_axes None).
    return jax.vmap(
        lambda w, b, m, h, hf: _fused_episode(params, cfg, w, b, m, h, hf,
                                              repair, backend),
        in_axes=(0 if stacked else None, 0, 0, 0,
                 None if hw_feats is None else 0),
    )(wl, batches, budgets, hw, hw_feats)


def _fused_infer(backend, params, cfg, env: FusionEnv, repair) -> InferResult:
    hwf = _hw_condition(cfg, env)
    t0 = time.perf_counter()
    out = _fused_one(params, cfg, env.wl, float(env.batch),
                     float(env.budget_bytes), as_hw(env.hw),
                     None if hwf is None else jnp.asarray(hwf[0]),
                     repair, backend)
    strat = np.asarray(out["strategy"])          # device sync = episode end
    wall = time.perf_counter() - t0
    return InferResult(strat, float(out["speedup"]), float(out["latency"]),
                       float(out["peak_mem"]), bool(out["valid"]), wall,
                       env.n + 1)


def dnnfuser_infer_fused(params, cfg, env: FusionEnv, *,
                         repair: bool = True) -> InferResult:
    """Device-resident one-shot inference: emits the same strategy as
    :func:`dnnfuser_infer` from a single jitted scan."""
    return _fused_infer(backend_for(cfg), params, cfg, env, repair)


# Backend dispatch made the s2s entry points pure aliases (the config type
# selects seq2seq.S2SBackend); kept for API compatibility.
s2s_infer = dnnfuser_infer
s2s_infer_fused = dnnfuser_infer_fused


def dnnfuser_infer_batch(params, cfg, env_or_wl, batches,
                         budgets_bytes, hw=None, *,
                         repair: bool = True) -> dict:
    """Serve a stacked batch of (workload, batch, budget, accel) serving
    conditions in ONE device call.

    ``env_or_wl`` supplies the per-row workloads:
     - a FusionEnv (condition fields ignored) or a packed workload dict
       from ``cost_model.pack_workload`` — ONE network shared by all rows;
     - a sequence of FusionEnvs / packed dicts (same ``nmax``), or an
       already-stacked dict from ``cost_model.stack_workloads`` — a
       HETEROGENEOUS network per row, padded to the shared ``nmax`` with
       each row's positions past its true ``n`` masked to SYNC in the scan
       (DESIGN §12), bit-exact per row with the single-workload rollout.

    ``batches`` and ``budgets_bytes`` are same-length 1-D arrays.  ``hw``
    is optional with FusionEnvs (defaults to each env's accelerator) and
    accepts anything ``accel.stack_hw`` does — one ``AccelConfig``, a
    length-C sequence, a stacked ``HwVec``, or a raw ``[C, 10]`` array —
    heterogeneous per-row accelerators serve in the same fused call
    (DESIGN §11).  Any registered ``MapperBackend`` config works (DT and
    seq2seq).  Returns a dict of stacked arrays (strategy [C, P] int32,
    latency/peak_mem/speedup/valid [C])."""
    if isinstance(env_or_wl, FusionEnv):
        wl = env_or_wl.wl
        if hw is None:
            hw = env_or_wl.hw
    elif isinstance(env_or_wl, (list, tuple)):
        rows = [e.wl if isinstance(e, FusionEnv) else e for e in env_or_wl]
        wl = cm.stack_workloads(rows)
        if hw is None:
            if not all(isinstance(e, FusionEnv) for e in env_or_wl):
                raise ValueError("hw is required with packed workloads")
            hw = [e.hw for e in env_or_wl]
    else:
        wl = env_or_wl
        if hw is None:
            raise ValueError("hw is required with a packed workload")
    batches = jnp.asarray(batches, jnp.float32)
    budgets = jnp.asarray(budgets_bytes, jnp.float32)
    C = batches.shape[0]
    stacked = jnp.ndim(wl["n"]) == 1
    if stacked and wl["n"].shape[0] != C:
        raise ValueError(f"stacked workloads have {wl['n'].shape[0]} rows, "
                         f"expected {C}")
    hwv = stack_hw(hw, C)
    # the model's condition rows are computed OUTSIDE the jit by the same
    # accel_features the host reference uses -> bit-identical inputs
    hwf = (jnp.asarray(np.asarray(accel_features(hwv), np.float32))
           if getattr(cfg, "hw_dim", 0) else None)
    out = _fused_batch(params, cfg, wl, batches, budgets, hwv, hwf,
                       repair, backend_for(cfg), stacked)
    return {k: np.asarray(v) for k, v in out.items()}
