"""One-shot inference: the paper's headline capability (§4.5.2).

The trained model is rolled out autoregressively against the cost-model
environment: at step t it reads the (reward, state, action) prefix — with
the conditioning reward supplied by the requested memory budget — and emits
micro-batch a_t; the environment updates s_{t+1}/r_{t+1}.  One rollout
(= N+1 tiny forward passes) replaces an entire 2k-sample search, which is
the 66x-127x speed claim benchmarked in ``benchmarks/speed_oneshot.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .env import FusionEnv, STATE_DIM, decode_action, encode_action
from .model import DTConfig, dt_apply
from .seq2seq import S2SConfig, s2s_apply
from . import cost_model as cm

__all__ = ["InferResult", "dnnfuser_infer", "s2s_infer"]


@dataclass
class InferResult:
    strategy: np.ndarray
    speedup: float
    latency: float
    peak_mem: float
    valid: bool
    wall_s: float
    n_model_calls: int


@partial(jax.jit, static_argnames=("cfg",))
def _dt_forward(params, cfg: DTConfig, rtg, states, actions):
    return dt_apply(params, cfg, rtg, states, actions)


@partial(jax.jit, static_argnames=("cfg",))
def _s2s_forward(params, cfg: S2SConfig, rtg, states, actions):
    return s2s_apply(params, cfg, rtg, states, actions)


def _rollout(forward, params, cfg, env: FusionEnv, *, repair: bool) -> InferResult:
    T = cfg.max_steps
    rtg = np.zeros((1, T), np.float32)
    states = np.zeros((1, T, STATE_DIM), np.float32)
    actions = np.zeros((1, T), np.float32)
    t0 = time.perf_counter()
    s = env.reset()
    calls = 0
    for t in range(env.n + 1):
        states[0, t] = s
        rtg[0, t] = env.reward_to_go
        pred = forward(params, cfg, jnp.asarray(rtg), jnp.asarray(states),
                       jnp.asarray(actions))
        calls += 1
        a_enc = float(pred[0, t])
        a = int(decode_action(a_enc, env.batch))
        if t == 0 and a < 1:
            a = 1                      # input micro-batch cannot sync
        if repair and a >= 1 and t > 0:
            # inference-time constraint guard (the model conditions on the
            # budget, but a hard guard keeps generalization runs valid):
            # shrink/sync if the staged buffer would overflow.
            while a >= 1:
                probe = env.actions.copy(); probe[t] = a
                pos = np.arange(env.nmax)
                probe = np.where(pos <= t, probe, cm.SYNC)
                out = env.evaluate_strategy(probe)
                if float(out.peak_mem) <= env.budget_bytes:
                    break
                a = a // 2 if a > 1 else cm.SYNC
        actions[0, t] = encode_action(np.float32(a), env.batch)
        s, _, done = env.step(a)
    wall = time.perf_counter() - t0
    strat = env.actions.copy()
    out = env.evaluate_strategy(strat)
    return InferResult(strat, env.baseline_latency / float(out.latency),
                       float(out.latency), float(out.peak_mem),
                       bool(out.valid), wall, calls)


def dnnfuser_infer(params, cfg: DTConfig, env: FusionEnv, *,
                   repair: bool = True) -> InferResult:
    """Conditional autoregressive inference of DNNFuser."""
    return _rollout(_dt_forward, params, cfg, env, repair=repair)


def s2s_infer(params, cfg: S2SConfig, env: FusionEnv, *,
              repair: bool = True) -> InferResult:
    return _rollout(_s2s_forward, params, cfg, env, repair=repair)
