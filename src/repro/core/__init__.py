"""DNNFuser core: the paper's contribution as a composable JAX module.

Layers: analytical fusion cost model (cost_model/ref_model), RL environment
(env), search-based teacher G-Sampler (gsampler) + Table-1 baselines
(baselines, a2c), the decision-transformer mapper (model) and RNN baseline
(seq2seq), teacher-data pipeline (dataset), imitation trainer (train) and
one-shot conditional inference (infer).
"""
from .accel import AccelConfig, PAPER_ACCEL
from .cost_model import (SYNC, CostOut, evaluate, evaluate_population,
                         baseline_no_fusion, prefix_trace, pack_workload)
from .env import FusionEnv, STATE_DIM, encode_action, decode_action
from .gsampler import GSamplerConfig, GSamplerResult, gsampler_search
from .baselines import BASELINE_METHODS, run_baseline, SearchResult
from .a2c import a2c_search
from .model import DTConfig, dt_init, dt_apply, dt_loss
from .seq2seq import S2SConfig, s2s_init, s2s_apply, s2s_loss
from .dataset import TrajectoryDataset, collect_teacher_data, merge_datasets
from .train import TrainConfig, train_model, make_train_step
from .infer import InferResult, dnnfuser_infer, s2s_infer

__all__ = [
    "AccelConfig", "PAPER_ACCEL", "SYNC", "CostOut", "evaluate",
    "evaluate_population", "baseline_no_fusion", "prefix_trace",
    "pack_workload", "FusionEnv", "STATE_DIM", "encode_action",
    "decode_action", "GSamplerConfig", "GSamplerResult", "gsampler_search",
    "BASELINE_METHODS", "run_baseline", "SearchResult", "a2c_search",
    "DTConfig", "dt_init", "dt_apply", "dt_loss", "S2SConfig", "s2s_init",
    "s2s_apply", "s2s_loss", "TrajectoryDataset", "collect_teacher_data",
    "merge_datasets", "TrainConfig", "train_model", "make_train_step",
    "InferResult", "dnnfuser_infer", "s2s_infer",
]
