"""DNNFuser core: the paper's contribution as a composable JAX module.

Layers: analytical fusion cost model (cost_model/ref_model), RL environment
(env), search-based teacher G-Sampler (gsampler) + Table-1 baselines
(baselines, a2c), the decision-transformer mapper (model) and RNN baseline
(seq2seq), teacher-data pipeline (dataset), imitation trainer (train) and
one-shot conditional inference (infer).
"""
from .accel import (AccelConfig, PAPER_ACCEL, ACCEL_ZOO, HwVec, HW_FIELDS,
                    HW_FEATURE_DIM, as_hw, stack_hw, hw_array, hw_from_array,
                    accel_features, accel_from_features)
from .cost_model import (SYNC, CostOut, evaluate, evaluate_population,
                         evaluate_population_stats, baseline_no_fusion,
                         prefix_trace, pack_workload, stack_workloads,
                         PrefixConsts, PrefixCarry, prefix_consts,
                         prefix_init, prefix_step, prefix_out,
                         prefix_probe_peak, prefix_scan, evaluate_grid,
                         evaluate_grid_stats, baseline_grid,
                         finalize_groups, default_evaluator,
                         set_default_evaluator)
from .env import (FusionEnv, STATE_DIM, encode_action, decode_action,
                  encode_action_jnp, decode_action_jnp, EnvConsts, env_make,
                  env_reset, env_observe, env_step, env_final)
from .gsampler import (GSamplerConfig, GSamplerResult, gsampler_search,
                       GridTeacherResult, gsampler_search_grid)
from .baselines import BASELINE_METHODS, run_baseline, SearchResult
from .a2c import a2c_search
from .model import (DTConfig, dt_init, dt_apply, dt_loss, dt_cache_init,
                    dt_prefill, dt_decode_step, DTBackend)
from .seq2seq import (S2SConfig, s2s_init, s2s_apply, s2s_loss, s2s_encode,
                      s2s_decode_start, s2s_decode_step, s2s_stream_init,
                      s2s_stream_step, S2SBackend)
from .backend import MapperBackend, backend_for, register_backend
from .dataset import (TrajectoryDataset, collect_teacher_data,
                      merge_datasets, generate_teacher_corpus,
                      window_dataset, returns_to_go)
from .train import (TrainConfig, train_model, make_train_step, fine_tune,
                    restore_params)
from .infer import (InferResult, dnnfuser_infer, s2s_infer,
                    dnnfuser_infer_fused, s2s_infer_fused,
                    dnnfuser_infer_batch)
from .optimal import (OptimalResult, optimal_search, optimal_mapping,
                      optimal_grid, brute_force_optimal,
                      enumerate_strategies, scaled_wl_np)
from .polish import (PolishConfig, PolishResult, polish_strategy,
                     polish_grid)
from .portfolio import (PortfolioConfig, PortfolioResult, de_search_grid,
                        cmaes_search_grid)

# The serving engine (DESIGN §12) layers ON TOP of core; its API is
# re-exported here so front doors import one namespace.  The re-export is
# lazy (PEP 562): an eager import would cycle when ``repro.serving`` is
# imported first (serving pulls core submodules mid-initialization).
_SERVING_API = ("MapperEngine", "MapRequest", "MapResponse", "StrategyCache",
                "AsyncMapperScheduler", "MapFuture", "AdmissionError",
                "ReplicaGroup", "ServingConfig", "DriftConfig",
                "DriftMonitor", "DriftReport", "RefreshWorker")


def __getattr__(name):
    if name in _SERVING_API:
        from .. import serving
        return getattr(serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AccelConfig", "PAPER_ACCEL", "ACCEL_ZOO", "HwVec", "HW_FIELDS",
    "HW_FEATURE_DIM", "as_hw", "stack_hw", "hw_array", "hw_from_array",
    "accel_features", "accel_from_features",
    "SYNC", "CostOut", "evaluate",
    "evaluate_population", "evaluate_population_stats", "baseline_no_fusion",
    "prefix_trace", "pack_workload", "PrefixConsts", "PrefixCarry",
    "prefix_consts", "prefix_init", "prefix_step", "prefix_out",
    "prefix_probe_peak", "prefix_scan", "stack_workloads", "evaluate_grid",
    "evaluate_grid_stats", "baseline_grid", "finalize_groups",
    "default_evaluator", "set_default_evaluator",
    "FusionEnv", "STATE_DIM",
    "encode_action",
    "decode_action", "encode_action_jnp", "decode_action_jnp", "EnvConsts",
    "env_make", "env_reset", "env_observe", "env_step", "env_final",
    "GSamplerConfig", "GSamplerResult", "gsampler_search",
    "GridTeacherResult", "gsampler_search_grid",
    "BASELINE_METHODS", "run_baseline", "SearchResult", "a2c_search",
    "DTConfig", "dt_init", "dt_apply", "dt_loss", "dt_cache_init",
    "dt_prefill", "dt_decode_step", "DTBackend", "S2SConfig", "s2s_init",
    "s2s_apply", "s2s_loss", "s2s_encode", "s2s_decode_start",
    "s2s_decode_step", "s2s_stream_init", "s2s_stream_step", "S2SBackend",
    "MapperBackend", "backend_for", "register_backend",
    "MapperEngine", "MapRequest", "MapResponse", "StrategyCache",
    "AsyncMapperScheduler", "MapFuture", "AdmissionError", "ReplicaGroup",
    "ServingConfig", "DriftConfig", "DriftMonitor", "DriftReport",
    "RefreshWorker",
    "TrajectoryDataset",
    "collect_teacher_data", "merge_datasets", "generate_teacher_corpus",
    "window_dataset", "returns_to_go", "TrainConfig", "train_model",
    "make_train_step", "fine_tune", "restore_params", "InferResult",
    "dnnfuser_infer", "s2s_infer",
    "dnnfuser_infer_fused", "s2s_infer_fused", "dnnfuser_infer_batch",
    "OptimalResult", "optimal_search", "optimal_mapping", "optimal_grid",
    "brute_force_optimal", "enumerate_strategies", "scaled_wl_np",
    "PolishConfig", "PolishResult", "polish_strategy", "polish_grid",
    "PortfolioConfig", "PortfolioResult", "de_search_grid",
    "cmaes_search_grid",
]
