"""RNN Seq2Seq baseline sequence model (paper §5.1).

"The Seq2Seq is made of a LSTM with 2 layers of fully connected layers and
128 hidden dimension in each encoder and decoder."  The encoder LSTM reads
the (reward, state) sequence (the workload and condition are known up
front); the decoder LSTM, initialized from the encoder's final state,
consumes [state_t, rtg_t, a_{t-1}] and regresses a_t.  Trained with the
same masked-MSE imitation objective as DNNFuser.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import nn
from .env import STATE_DIM

__all__ = ["S2SConfig", "s2s_init", "s2s_apply", "s2s_loss"]


@dataclass(frozen=True)
class S2SConfig:
    hidden: int = 128          # paper §5.1
    max_steps: int = 64
    dtype: object = jnp.float32


def _lstm_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {"wx": nn.dense_init(k1, d_in, 4 * d_h, dtype=dtype),
            "wh": nn.dense_init(k2, d_h, 4 * d_h, bias=False, dtype=dtype)}


def _lstm_scan(p, xs, h0, c0):
    """xs [B,T,d_in] -> outputs [B,T,d_h], final (h, c)."""
    def cell(carry, x):
        h, c = carry
        z = nn.dense_apply(p["wx"], x) + nn.dense_apply(p["wh"], h)
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h
    (h, c), ys = jax.lax.scan(cell, (h0, c0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (h, c)


def s2s_init(key: jax.Array, cfg: S2SConfig) -> dict:
    ks = jax.random.split(key, 8)
    H = cfg.hidden
    return {
        "enc_in": nn.dense_init(ks[0], STATE_DIM + 1, H, dtype=cfg.dtype),
        "enc_fc": nn.dense_init(ks[1], H, H, dtype=cfg.dtype),
        "enc_lstm": _lstm_init(ks[2], H, H, cfg.dtype),
        "dec_in": nn.dense_init(ks[3], STATE_DIM + 2, H, dtype=cfg.dtype),
        "dec_fc": nn.dense_init(ks[4], H, H, dtype=cfg.dtype),
        "dec_lstm": _lstm_init(ks[5], H, H, cfg.dtype),
        "head1": nn.dense_init(ks[6], H, H, dtype=cfg.dtype),
        "head2": nn.dense_init(ks[7], H, 1, dtype=cfg.dtype),
    }


def s2s_apply(params: dict, cfg: S2SConfig, rtg: jax.Array,
              states: jax.Array, actions: jax.Array) -> jax.Array:
    """Teacher-forced predictions [B,T] (a_{t-1} fed, a_{-1}=0)."""
    B, T = rtg.shape
    zeros = jnp.zeros((B, 1), rtg.dtype)
    enc_x = jnp.concatenate([states, rtg[..., None]], -1)
    h = jax.nn.relu(nn.dense_apply(params["enc_fc"],
                                   jax.nn.relu(nn.dense_apply(params["enc_in"], enc_x))))
    h0 = jnp.zeros((B, cfg.hidden), rtg.dtype)
    _, (he, ce) = _lstm_scan(params["enc_lstm"], h, h0, h0)
    prev_a = jnp.concatenate([zeros, actions[:, :-1]], axis=1)
    dec_x = jnp.concatenate([states, rtg[..., None], prev_a[..., None]], -1)
    g = jax.nn.relu(nn.dense_apply(params["dec_fc"],
                                   jax.nn.relu(nn.dense_apply(params["dec_in"], dec_x))))
    ys, _ = _lstm_scan(params["dec_lstm"], g, he, ce)
    out = nn.dense_apply(params["head2"],
                         jax.nn.relu(nn.dense_apply(params["head1"], ys)))
    return out[..., 0]


def s2s_loss(params: dict, cfg: S2SConfig, batch: dict) -> jax.Array:
    pred = s2s_apply(params, cfg, batch["rtg"], batch["states"],
                     batch["actions"])
    err = jnp.square(pred - batch["actions"]) * batch["mask"]
    return err.sum() / jnp.maximum(batch["mask"].sum(), 1.0)
