"""RNN Seq2Seq baseline sequence model (paper §5.1).

"The Seq2Seq is made of a LSTM with 2 layers of fully connected layers and
128 hidden dimension in each encoder and decoder."  The encoder LSTM reads
the (reward, state) sequence (the workload and condition are known up
front); the decoder LSTM, initialized from the encoder's final state,
consumes [state_t, rtg_t, a_{t-1}] and regresses a_t.  Trained with the
same masked-MSE imitation objective as DNNFuser.

Hardware conditioning (DESIGN.md §11): with ``cfg.hw_dim > 0`` a learned
projection of the ``accel.accel_features`` vector is added to every
encoder and decoder input — additive like the DT's, so a zero-initialized
``emb_h`` is exactly the pre-§11 function (checkpoint upgrade path).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import nn
from .env import STATE_DIM

__all__ = ["S2SConfig", "s2s_init", "s2s_apply", "s2s_loss", "s2s_encode",
           "s2s_decode_start", "s2s_decode_step", "s2s_stream_init",
           "s2s_stream_step", "S2SBackend"]


@dataclass(frozen=True)
class S2SConfig:
    hidden: int = 128          # paper §5.1
    max_steps: int = 64
    dtype: object = jnp.float32
    hw_dim: int = 0            # hw-condition feature dim (0 = pre-§11 arch)


def _lstm_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {"wx": nn.dense_init(k1, d_in, 4 * d_h, dtype=dtype),
            "wh": nn.dense_init(k2, d_h, 4 * d_h, bias=False, dtype=dtype)}


def _lstm_cell(p, x, h, c):
    """One LSTM step: x [B,d_in], (h, c) [B,d_h] -> (h, c)."""
    z = nn.dense_apply(p["wx"], x) + nn.dense_apply(p["wh"], h)
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def _lstm_scan(p, xs, h0, c0):
    """xs [B,T,d_in] -> outputs [B,T,d_h], final (h, c)."""
    def cell(carry, x):
        h, c = _lstm_cell(p, x, *carry)
        return (h, c), h
    (h, c), ys = jax.lax.scan(cell, (h0, c0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (h, c)


def s2s_init(key: jax.Array, cfg: S2SConfig) -> dict:
    ks = jax.random.split(key, 9 if cfg.hw_dim else 8)
    H = cfg.hidden
    p = {
        "enc_in": nn.dense_init(ks[0], STATE_DIM + 1, H, dtype=cfg.dtype),
        "enc_fc": nn.dense_init(ks[1], H, H, dtype=cfg.dtype),
        "enc_lstm": _lstm_init(ks[2], H, H, cfg.dtype),
        "dec_in": nn.dense_init(ks[3], STATE_DIM + 2, H, dtype=cfg.dtype),
        "dec_fc": nn.dense_init(ks[4], H, H, dtype=cfg.dtype),
        "dec_lstm": _lstm_init(ks[5], H, H, cfg.dtype),
        "head1": nn.dense_init(ks[6], H, H, dtype=cfg.dtype),
        "head2": nn.dense_init(ks[7], H, 1, dtype=cfg.dtype),
    }
    if cfg.hw_dim:
        p["emb_h"] = nn.dense_init(ks[8], cfg.hw_dim, H, dtype=cfg.dtype)
    return p


def _hw_emb(params: dict, cfg: S2SConfig, hw, batch: int):
    """[B, H] additive hw embedding, or None (see model._hw_emb)."""
    if not cfg.hw_dim:
        return None
    if hw is None:
        hw = jnp.zeros((batch, cfg.hw_dim), cfg.dtype)
    return nn.dense_apply(params["emb_h"], hw)


def s2s_apply(params: dict, cfg: S2SConfig, rtg: jax.Array,
              states: jax.Array, actions: jax.Array,
              hw: jax.Array | None = None) -> jax.Array:
    """Teacher-forced predictions [B,T] (a_{t-1} fed, a_{-1}=0)."""
    B, T = rtg.shape
    zeros = jnp.zeros((B, 1), rtg.dtype)
    hemb = _hw_emb(params, cfg, hw, B)
    enc_x = jnp.concatenate([states, rtg[..., None]], -1)
    h = jax.nn.relu(nn.dense_apply(params["enc_fc"],
                                   jax.nn.relu(nn.dense_apply(params["enc_in"], enc_x))))
    if hemb is not None:
        h = h + hemb[:, None, :]
    h0 = jnp.zeros((B, cfg.hidden), rtg.dtype)
    _, (he, ce) = _lstm_scan(params["enc_lstm"], h, h0, h0)
    prev_a = jnp.concatenate([zeros, actions[:, :-1]], axis=1)
    dec_x = jnp.concatenate([states, rtg[..., None], prev_a[..., None]], -1)
    g = jax.nn.relu(nn.dense_apply(params["dec_fc"],
                                   jax.nn.relu(nn.dense_apply(params["dec_in"], dec_x))))
    if hemb is not None:
        g = g + hemb[:, None, :]
    ys, _ = _lstm_scan(params["dec_lstm"], g, he, ce)
    out = nn.dense_apply(params["head2"],
                         jax.nn.relu(nn.dense_apply(params["head1"], ys)))
    return out[..., 0]


# ---------------------------------------------------------------------------
# Incremental decode (DESIGN.md §9).
#
# The LSTM analogue of a KV cache is the recurrent (h, c) state.  Two entry
# points:
#  - exact: ``s2s_encode`` runs the full encoder once (known condition
#    sequence), then ``s2s_decode_step`` replays the teacher-forced decoder
#    cell-by-cell — bit-equal to ``s2s_apply``.
#  - streaming: ``s2s_stream_step`` for the device-resident rollout, where
#    future states do not exist yet.  The encoder LSTM advances alongside
#    the decoder and seeds it at t=0.  (The host rollout instead re-encodes
#    a zero-padded sequence every step; neither matches teacher forcing
#    exactly — the condition sequence is generated on the fly — so the
#    streaming form is the documented serving contract.)
# ---------------------------------------------------------------------------


def _enc_in(params, r_t, s_t):
    x = jnp.concatenate([s_t, r_t[..., None]], -1)
    return jax.nn.relu(nn.dense_apply(params["enc_fc"],
                                      jax.nn.relu(nn.dense_apply(params["enc_in"], x))))


def _dec_in(params, r_t, s_t, a_prev):
    x = jnp.concatenate([s_t, r_t[..., None], a_prev[..., None]], -1)
    return jax.nn.relu(nn.dense_apply(params["dec_fc"],
                                      jax.nn.relu(nn.dense_apply(params["dec_in"], x))))


def _head(params, h):
    return nn.dense_apply(params["head2"],
                          jax.nn.relu(nn.dense_apply(params["head1"], h)))[..., 0]


def s2s_encode(params: dict, cfg: S2SConfig, rtg: jax.Array,
               states: jax.Array, hw: jax.Array | None = None):
    """Full-sequence encoder, identical to the one inside ``s2s_apply``."""
    B = rtg.shape[0]
    h = _enc_in(params, rtg, states)
    hemb = _hw_emb(params, cfg, hw, B)
    if hemb is not None:
        h = h + hemb[:, None, :]
    h0 = jnp.zeros((B, cfg.hidden), rtg.dtype)
    _, (he, ce) = _lstm_scan(params["enc_lstm"], h, h0, h0)
    return he, ce


def s2s_decode_start(enc_state) -> dict:
    he, ce = enc_state
    return {"h": he, "c": ce}


def s2s_decode_step(params: dict, cfg: S2SConfig, cache: dict,
                    r_t: jax.Array, s_t: jax.Array, a_prev: jax.Array,
                    hw: jax.Array | None = None):
    """One decoder cell step; exact replay of teacher-forced ``s2s_apply``
    when seeded from ``s2s_encode``.  Returns (pred [B], cache)."""
    g = _dec_in(params, r_t, s_t, a_prev)
    hemb = _hw_emb(params, cfg, hw, r_t.shape[0])
    if hemb is not None:
        g = g + hemb
    h, c = _lstm_cell(params["dec_lstm"], g, cache["h"], cache["c"])
    return _head(params, h), {"h": h, "c": c}


def s2s_stream_init(cfg: S2SConfig, batch: int = 1,
                    dtype=jnp.float32) -> dict:
    z = jnp.zeros((batch, cfg.hidden), dtype)
    return {"eh": z, "ec": z, "h": z, "c": z, "t": jnp.zeros((), jnp.int32)}


def s2s_stream_step(params: dict, cfg: S2SConfig, cache: dict,
                    r_t: jax.Array, s_t: jax.Array, a_prev: jax.Array,
                    hw: jax.Array | None = None):
    """Streaming decode for on-the-fly rollouts: advance the encoder on
    (s_t, r_t), seed the decoder from it at t=0, step the decoder."""
    ex = _enc_in(params, r_t, s_t)
    hemb = _hw_emb(params, cfg, hw, r_t.shape[0])
    if hemb is not None:
        ex = ex + hemb
    eh, ec = _lstm_cell(params["enc_lstm"], ex, cache["eh"], cache["ec"])
    first = cache["t"] == 0
    h = jnp.where(first, eh, cache["h"])
    c = jnp.where(first, ec, cache["c"])
    pred, dc = s2s_decode_step(params, cfg, {"h": h, "c": c},
                               r_t, s_t, a_prev, hw)
    return pred, {"eh": eh, "ec": ec, "h": dc["h"], "c": dc["c"],
                  "t": cache["t"] + 1}


class S2SBackend:
    """The seq2seq baseline as a ``infer.MapperBackend`` (DESIGN §12).

    The decode state is the streaming (encoder, decoder) LSTM state; the
    prefill is the documented streaming-encoder contract — the first step
    feeds (r_0, s_0) with a zero previous action and seeds the decoder from
    the advancing encoder (see the incremental-decode note above)."""

    kind = "s2s"

    @staticmethod
    def forward(params, cfg: S2SConfig, rtg, states, actions, hw=None):
        """Full-sequence teacher-forced scores (host reference path)."""
        return s2s_apply(params, cfg, rtg, states, actions, hw)

    @staticmethod
    def state_init(cfg: S2SConfig, batch: int = 1):
        return s2s_stream_init(cfg, batch)

    @staticmethod
    def prefill(params, cfg: S2SConfig, state, r0, s0, hw=None):
        return s2s_stream_step(params, cfg, state, r0, s0,
                               jnp.zeros(r0.shape, jnp.float32), hw)

    @staticmethod
    def step(params, cfg: S2SConfig, state, r_t, s_t, a_prev, hw=None):
        return s2s_stream_step(params, cfg, state, r_t, s_t, a_prev, hw)


def s2s_loss(params: dict, cfg: S2SConfig, batch: dict) -> jax.Array:
    pred = s2s_apply(params, cfg, batch["rtg"], batch["states"],
                     batch["actions"], batch.get("hw"))
    err = jnp.square(pred - batch["actions"]) * batch["mask"]
    return err.sum() / jnp.maximum(batch["mask"].sum(), 1.0)
