"""DNNFuser: the decision-transformer mapper (paper §4.3, §5.1).

Architecture per §5.1: 3 transformer blocks, 2 heads, hidden dim 128.
A trajectory (r_0,s_0,a_0, ..., r_T,s_T,a_T) is embedded into interleaved
reward/state/action tokens; a causal transformer predicts the action for
step t from the *state* token of step t; the loss is masked MSE between
predicted and teacher actions (continuous encoding, see env.encode_action).

Conditioning (paper §4.3.3): the reward channel carries the requested
on-chip-buffer headroom, so at inference the generated mapping is steered by
feeding the desired memory condition.

Hardware conditioning (DESIGN.md §11): with ``cfg.hw_dim > 0`` the model
additionally conditions on the accelerator itself — a learned projection of
the normalized ``accel.accel_features`` vector is ADDED to every reward
token, so the conditioning channel carries (budget headroom, hardware)
jointly.  The additive form is deliberate: a zero-initialized ``emb_h``
leaves the function bit-identical to a pre-§11 mapper, which makes the
checkpoint upgrade path (``checkpoint.upgrade_pytree``) exactly
behavior-preserving, and the KV-cache geometry does not change.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .. import nn
from .env import STATE_DIM

__all__ = ["DTConfig", "dt_init", "dt_apply", "dt_loss", "dt_cache_init",
           "dt_prefill", "dt_decode_step", "DTBackend"]


@dataclass(frozen=True)
class DTConfig:
    n_blocks: int = 3          # paper §5.1
    n_heads: int = 2           # paper §5.1
    d_model: int = 128         # paper §5.1
    max_steps: int = 64        # trajectory positions (N+1 <= max_steps)
    d_ff: int = 512
    dtype: object = jnp.float32
    hw_dim: int = 0            # hw-condition feature dim (0 = pre-§11 arch)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def dt_init(key: jax.Array, cfg: DTConfig) -> dict:
    ks = jax.random.split(key, 8 + cfg.n_blocks)
    d = cfg.d_model
    p = {
        "emb_r": nn.dense_init(ks[0], 1, d, dtype=cfg.dtype),
        "emb_s": nn.dense_init(ks[1], STATE_DIM, d, dtype=cfg.dtype),
        "emb_a": nn.dense_init(ks[2], 1, d, dtype=cfg.dtype),
        "time": nn.embedding_init(ks[3], cfg.max_steps, d, dtype=cfg.dtype),
        "type": nn.embedding_init(ks[4], 3, d, dtype=cfg.dtype),
        "ln_f": nn.layernorm_init(d, cfg.dtype),
        "head": nn.dense_init(ks[5], d, 1, dtype=cfg.dtype),
        "blocks": [
            nn.block_init(ks[8 + i], d, n_heads=cfg.n_heads, d_ff=cfg.d_ff,
                          mlp_kind="gelu", norm="layer", dtype=cfg.dtype)
            for i in range(cfg.n_blocks)
        ],
    }
    if cfg.hw_dim:
        # ks[6] is unused by the pre-§11 keys, so the shared parameters of
        # an hw-conditioned init match a plain init with the same seed
        p["emb_h"] = nn.dense_init(ks[6], cfg.hw_dim, d, dtype=cfg.dtype)
    return p


def _hw_emb(params: dict, cfg: DTConfig, hw: jax.Array | None,
            batch: int) -> jax.Array | None:
    """[B, d] additive hw-condition embedding, or None when unconditioned.

    A missing ``hw`` input on an hw-aware model falls back to zeros — the
    "unspecified hardware" condition (also what legacy corpora decode to)."""
    if not cfg.hw_dim:
        return None
    if hw is None:
        hw = jnp.zeros((batch, cfg.hw_dim), cfg.dtype)
    return nn.dense_apply(params["emb_h"], hw)


def dt_apply(params: dict, cfg: DTConfig, rtg: jax.Array, states: jax.Array,
             actions: jax.Array, t0: jax.Array | None = None,
             hw: jax.Array | None = None) -> jax.Array:
    """rtg [B,T], states [B,T,8], actions [B,T] -> predicted actions [B,T].

    Prediction for step t reads the causal prefix up to (and incl.) s_t;
    a_t tokens only influence steps > t, so one forward pass scores every
    step (teacher forcing) and autoregressive generation is consistent.

    ``t0`` [B] (optional) are absolute-time offsets: a trajectory window
    starting at step ``t0`` embeds positions ``t0 .. t0+T-1``, so corpora
    windowed by ``dataset.window_dataset`` train with the same timestep
    embeddings full trajectories use.  ``t0 + T`` must stay within
    ``cfg.max_steps``.

    ``hw`` [B, cfg.hw_dim] (optional) are normalized accelerator features
    (``accel.accel_features``), added to every reward token when
    ``cfg.hw_dim > 0`` (ignored otherwise) — see DESIGN.md §11.
    """
    B, T = rtg.shape
    d = cfg.d_model
    tok_r = nn.dense_apply(params["emb_r"], rtg[..., None])
    hemb = _hw_emb(params, cfg, hw, B)
    if hemb is not None:
        tok_r = tok_r + hemb[:, None, :]
    tok_s = nn.dense_apply(params["emb_s"], states)
    tok_a = nn.dense_apply(params["emb_a"], actions[..., None])
    steps = jnp.arange(T)
    if t0 is None:
        time = nn.embedding_apply(params["time"], steps)[None]         # [1,T,d]
    else:
        idx = t0.astype(jnp.int32)[:, None] + steps[None, :]
        time = nn.embedding_apply(params["time"], idx)
        # a window past the embedding table must fail LOUDLY: jnp's gather
        # clamps out-of-range rows, which would silently alias positions —
        # poison them instead so a too-small max_steps NaNs the loss
        time = jnp.where((idx < cfg.max_steps)[..., None], time, jnp.nan)
    typ = params["type"]["emb"]                                        # [3,d]
    toks = jnp.stack([tok_r + typ[0], tok_s + typ[1], tok_a + typ[2]],
                     axis=2) + time[:, :, None, :]
    x = toks.reshape(B, 3 * T, d)
    for blk in params["blocks"]:
        x, _, _ = nn.block_apply(blk, x, n_heads=cfg.n_heads,
                                 kv_heads=cfg.n_heads,
                                 head_dim=cfg.head_dim, mlp_kind="gelu",
                                 norm="layer", causal=True)
    x = nn.layernorm_apply(params["ln_f"], x)
    s_tok = x.reshape(B, T, 3, d)[:, :, 1]       # state-token outputs
    return nn.dense_apply(params["head"], s_tok)[..., 0]


# ---------------------------------------------------------------------------
# KV-cached single-token decode (DESIGN.md §9).
#
# One autoregressive step of ``dt_apply`` re-run over the full
# ``3 * max_steps`` token sequence costs O(T^2); with a per-block KV cache a
# step appends at most 3 tokens — (a_{t-1}, r_t, s_t) — and attends over the
# cached prefix, so an episode is O(T) per step and the whole rollout fits
# in one ``jax.lax.scan`` (see ``infer``).  Matches ``dt_apply`` logits to
# float32 round-off because the math and causal mask are identical.
# ---------------------------------------------------------------------------


def dt_cache_init(cfg: DTConfig, batch: int = 1) -> list:
    """Per-block KV caches over the flat (r, s, a) token stream."""
    return [nn.attention.init_kv_cache(batch, 3 * cfg.max_steps,
                                       cfg.n_heads, cfg.head_dim,
                                       dtype=cfg.dtype)
            for _ in range(cfg.n_blocks)]


def _dt_blocks_cached(params: dict, cfg: DTConfig, x: jax.Array,
                      caches: list):
    new_caches = []
    for blk, cch in zip(params["blocks"], caches):
        x, cch, _ = nn.block_apply(blk, x, n_heads=cfg.n_heads,
                                   kv_heads=cfg.n_heads,
                                   head_dim=cfg.head_dim, mlp_kind="gelu",
                                   norm="layer", causal=True, cache=cch)
        new_caches.append(cch)
    x = nn.layernorm_apply(params["ln_f"], x)
    return nn.dense_apply(params["head"], x)[..., 0], new_caches


def dt_prefill(params: dict, cfg: DTConfig, cache: list, r0: jax.Array,
               s0: jax.Array, hw: jax.Array | None = None):
    """Start an episode: feed (r_0, s_0), predict a_0.

    r0 [B], s0 [B, STATE_DIM] -> (pred_a0 [B], cache).  ``hw`` as in
    :func:`dt_apply` (added to the reward token)."""
    typ = params["type"]["emb"]
    time0 = nn.embedding_apply(params["time"], jnp.asarray(0))
    tok_r = nn.dense_apply(params["emb_r"], r0[..., None]) + typ[0] + time0
    hemb = _hw_emb(params, cfg, hw, r0.shape[0])
    if hemb is not None:
        tok_r = tok_r + hemb
    tok_s = nn.dense_apply(params["emb_s"], s0) + typ[1] + time0
    preds, cache = _dt_blocks_cached(params, cfg,
                                     jnp.stack([tok_r, tok_s], axis=1), cache)
    return preds[:, 1], cache


def dt_decode_step(params: dict, cfg: DTConfig, cache: list, r_t: jax.Array,
                   s_t: jax.Array, a_prev: jax.Array,
                   hw: jax.Array | None = None):
    """One decode step t >= 1: append (a_{t-1}, r_t, s_t), predict a_t.

    ``a_prev`` is the *encoded* action chosen at step t-1 (see
    ``env.encode_action``); the step index is recovered from the cache write
    position (idx == 3t - 1), so the caller only threads the cache pytree.
    ``hw`` as in :func:`dt_apply`.  Returns (pred_a_t [B], cache)."""
    idx = cache[0]["idx"]
    t = (idx + 1) // 3
    typ = params["type"]["emb"]
    time_prev = nn.embedding_apply(params["time"], t - 1)
    time_t = nn.embedding_apply(params["time"], t)
    tok_a = (nn.dense_apply(params["emb_a"], a_prev[..., None])
             + typ[2] + time_prev)
    tok_r = nn.dense_apply(params["emb_r"], r_t[..., None]) + typ[0] + time_t
    hemb = _hw_emb(params, cfg, hw, r_t.shape[0])
    if hemb is not None:
        tok_r = tok_r + hemb
    tok_s = nn.dense_apply(params["emb_s"], s_t) + typ[1] + time_t
    preds, cache = _dt_blocks_cached(
        params, cfg, jnp.stack([tok_a, tok_r, tok_s], axis=1), cache)
    return preds[:, 2], cache


class DTBackend:
    """The decision transformer as a ``infer.MapperBackend`` (DESIGN §12).

    The rollout engines in ``infer`` are model-agnostic: they drive any
    backend exposing (``forward``, ``state_init``, ``prefill``, ``step``)
    with a pytree decode state.  For the DT the state is the per-block KV
    cache.  The class itself is the backend (stateless, hashable), so it
    rides ``jax.jit`` as a static argument."""

    kind = "dt"

    @staticmethod
    def forward(params, cfg: DTConfig, rtg, states, actions, hw=None):
        """Full-sequence teacher-forced scores (host reference path)."""
        return dt_apply(params, cfg, rtg, states, actions, hw=hw)

    @staticmethod
    def state_init(cfg: DTConfig, batch: int = 1):
        return dt_cache_init(cfg, batch)

    @staticmethod
    def prefill(params, cfg: DTConfig, state, r0, s0, hw=None):
        return dt_prefill(params, cfg, state, r0, s0, hw)

    @staticmethod
    def step(params, cfg: DTConfig, state, r_t, s_t, a_prev, hw=None):
        return dt_decode_step(params, cfg, state, r_t, s_t, a_prev, hw)


def dt_loss(params: dict, cfg: DTConfig, batch: dict) -> jax.Array:
    """Masked MSE (paper §4.3.1); honors window offsets (batch["t0"]) and
    the per-trajectory hw condition (batch["hw"], DESIGN §11)."""
    pred = dt_apply(params, cfg, batch["rtg"], batch["states"],
                    batch["actions"], batch.get("t0"), batch.get("hw"))
    err = jnp.square(pred - batch["actions"]) * batch["mask"]
    return err.sum() / jnp.maximum(batch["mask"].sum(), 1.0)
