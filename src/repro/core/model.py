"""DNNFuser: the decision-transformer mapper (paper §4.3, §5.1).

Architecture per §5.1: 3 transformer blocks, 2 heads, hidden dim 128.
A trajectory (r_0,s_0,a_0, ..., r_T,s_T,a_T) is embedded into interleaved
reward/state/action tokens; a causal transformer predicts the action for
step t from the *state* token of step t; the loss is masked MSE between
predicted and teacher actions (continuous encoding, see env.encode_action).

Conditioning (paper §4.3.3): the reward channel carries the requested
on-chip-buffer headroom, so at inference the generated mapping is steered by
feeding the desired memory condition.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .. import nn
from .env import STATE_DIM

__all__ = ["DTConfig", "dt_init", "dt_apply", "dt_loss"]


@dataclass(frozen=True)
class DTConfig:
    n_blocks: int = 3          # paper §5.1
    n_heads: int = 2           # paper §5.1
    d_model: int = 128         # paper §5.1
    max_steps: int = 64        # trajectory positions (N+1 <= max_steps)
    d_ff: int = 512
    dtype: object = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def dt_init(key: jax.Array, cfg: DTConfig) -> dict:
    ks = jax.random.split(key, 8 + cfg.n_blocks)
    d = cfg.d_model
    p = {
        "emb_r": nn.dense_init(ks[0], 1, d, dtype=cfg.dtype),
        "emb_s": nn.dense_init(ks[1], STATE_DIM, d, dtype=cfg.dtype),
        "emb_a": nn.dense_init(ks[2], 1, d, dtype=cfg.dtype),
        "time": nn.embedding_init(ks[3], cfg.max_steps, d, dtype=cfg.dtype),
        "type": nn.embedding_init(ks[4], 3, d, dtype=cfg.dtype),
        "ln_f": nn.layernorm_init(d, cfg.dtype),
        "head": nn.dense_init(ks[5], d, 1, dtype=cfg.dtype),
        "blocks": [
            nn.block_init(ks[8 + i], d, n_heads=cfg.n_heads, d_ff=cfg.d_ff,
                          mlp_kind="gelu", norm="layer", dtype=cfg.dtype)
            for i in range(cfg.n_blocks)
        ],
    }
    return p


def dt_apply(params: dict, cfg: DTConfig, rtg: jax.Array, states: jax.Array,
             actions: jax.Array) -> jax.Array:
    """rtg [B,T], states [B,T,8], actions [B,T] -> predicted actions [B,T].

    Prediction for step t reads the causal prefix up to (and incl.) s_t;
    a_t tokens only influence steps > t, so one forward pass scores every
    step (teacher forcing) and autoregressive generation is consistent.
    """
    B, T = rtg.shape
    d = cfg.d_model
    tok_r = nn.dense_apply(params["emb_r"], rtg[..., None])
    tok_s = nn.dense_apply(params["emb_s"], states)
    tok_a = nn.dense_apply(params["emb_a"], actions[..., None])
    time = nn.embedding_apply(params["time"], jnp.arange(T))          # [T,d]
    typ = params["type"]["emb"]                                        # [3,d]
    toks = jnp.stack([tok_r + typ[0], tok_s + typ[1], tok_a + typ[2]],
                     axis=2) + time[None, :, None, :]
    x = toks.reshape(B, 3 * T, d)
    for blk in params["blocks"]:
        x, _, _ = nn.block_apply(blk, x, n_heads=cfg.n_heads,
                                 kv_heads=cfg.n_heads,
                                 head_dim=cfg.head_dim, mlp_kind="gelu",
                                 norm="layer", causal=True)
    x = nn.layernorm_apply(params["ln_f"], x)
    s_tok = x.reshape(B, T, 3, d)[:, :, 1]       # state-token outputs
    return nn.dense_apply(params["head"], s_tok)[..., 0]


def dt_loss(params: dict, cfg: DTConfig, batch: dict) -> jax.Array:
    """Masked MSE (paper §4.3.1)."""
    pred = dt_apply(params, cfg, batch["rtg"], batch["states"],
                    batch["actions"])
    err = jnp.square(pred - batch["actions"]) * batch["mask"]
    return err.sum() / jnp.maximum(batch["mask"].sum(), 1.0)
