"""The MapperBackend protocol: one rollout engine, many sequence models.

Before DESIGN §12 the inference module special-cased the decision
transformer vs the seq2seq baseline at every call site (separate jitted
forwards, a string-keyed ``_model_iface`` switch).  The protocol below is
the single seam instead: a backend is a stateless, hashable namespace (a
class) exposing the four entry points the rollouts need, with the mutable
decode state as an opaque pytree — so the host loop, the fused scan episode
and the batched/bucketed serving engine are written ONCE and ride either
model (``model.DTBackend``: KV cache; ``seq2seq.S2SBackend``: streaming
LSTM state).  Backends pass through ``jax.jit`` as static arguments, which
is why they are classes rather than instances.
"""
from __future__ import annotations

from typing import Protocol

from .model import DTConfig, DTBackend
from .seq2seq import S2SConfig, S2SBackend

__all__ = ["MapperBackend", "backend_for", "register_backend"]


class MapperBackend(Protocol):
    """What a sequence model must expose to ride the shared rollouts.

    All array arguments carry a leading batch axis; ``hw`` is the optional
    normalized accelerator-condition row (DESIGN §11)."""

    kind: str

    @staticmethod
    def forward(params, cfg, rtg, states, actions, hw=None):
        """Teacher-forced scores [B, T] over a full trajectory."""

    @staticmethod
    def state_init(cfg, batch: int = 1):
        """Fresh decode-state pytree (KV cache / recurrent state)."""

    @staticmethod
    def prefill(params, cfg, state, r0, s0, hw=None):
        """Feed (r_0, s_0), predict a_0 -> (pred [B], state)."""

    @staticmethod
    def step(params, cfg, state, r_t, s_t, a_prev, hw=None):
        """Append (a_{t-1}, r_t, s_t), predict a_t -> (pred [B], state)."""


_BACKENDS: dict[type, type] = {DTConfig: DTBackend, S2SConfig: S2SBackend}


def register_backend(cfg_cls: type, backend: type) -> None:
    """Register a new config-type -> backend mapping (extension point)."""
    _BACKENDS[cfg_cls] = backend


def backend_for(cfg) -> type:
    """Resolve the :class:`MapperBackend` for a model config instance."""
    for cfg_cls, backend in _BACKENDS.items():
        if isinstance(cfg, cfg_cls):
            return backend
    raise TypeError(f"no MapperBackend registered for {type(cfg).__name__}")
