"""A2C baseline (paper §5.1, Table 1 "A2C").

A small actor-critic agent interacting with the fusion environment.  The
paper reports that A2C barely finds a valid solution after ~5 hours and
underperforms the baseline mapping — the state transitions of the fusion
environment are abrupt (layer shapes have no smooth relation step-to-step),
which starves temporal-difference methods.  We reproduce the method
faithfully (discrete action head over {SYNC} u [1..B], advantage
actor-critic with entropy bonus) and observe the same qualitative outcome.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from .baselines import SearchResult
from .env import STATE_DIM
from . import cost_model as cm

__all__ = ["a2c_search"]


def _init_params(rng: jax.Array, n_actions: int, hidden: int = 64) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sc = lambda k, i, o: jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i)
    return {
        "w1": sc(k1, STATE_DIM, hidden), "b1": jnp.zeros(hidden),
        "wp": sc(k2, hidden, n_actions), "bp": jnp.zeros(n_actions),
        "wv": sc(k3, hidden, 1), "bv": jnp.zeros(1),
        "w2": sc(k4, hidden, hidden), "b2": jnp.zeros(hidden),
    }


def _forward(params, s):
    h = jnp.tanh(s @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    logits = h @ params["wp"] + params["bp"]
    value = (h @ params["wv"] + params["bv"])[..., 0]
    return logits, value


@jax.jit
def _sample_action(params, s, key):
    logits, value = _forward(params, s)
    a = jax.random.categorical(key, logits)
    return a, value


def _loss(params, states, actions, returns, beta):
    logits, values = _forward(params, states)
    logp = jax.nn.log_softmax(logits)
    lp_a = jnp.take_along_axis(logp, actions[:, None], axis=1)[:, 0]
    adv = returns - jax.lax.stop_gradient(values)
    pg = -(lp_a * adv).mean()
    vloss = 0.5 * jnp.mean((values - returns) ** 2)
    ent = -jnp.mean(jnp.sum(jnp.exp(logp) * logp, axis=1))
    return pg + 0.5 * vloss - beta * ent


def a2c_search(env, budget: int = 2000, seed: int = 0,
               gamma: float = 0.99, lr: float = 3e-4,
               entropy_beta: float = 1e-2) -> SearchResult:
    """Train A2C for ``budget`` episodes; return the best strategy seen."""
    t0 = time.perf_counter()
    n_actions = env.batch + 1          # 0 => SYNC, k => micro-batch k
    key = jax.random.PRNGKey(seed)
    key, sub = jax.random.split(key)
    params = _init_params(sub, n_actions)
    tx = optim.adamw(lr, max_grad_norm=1.0)
    opt_state = tx.init(params)

    grad_fn = jax.jit(jax.grad(_loss))

    @jax.jit
    def apply(params, opt_state, grads):
        updates, opt_state = tx.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state

    best_strat, best_obj = None, -np.inf
    for ep in range(budget):
        s = env.reset()
        states, actions, rewards = [], [], []
        done = False
        while not done:
            key, sub = jax.random.split(key)
            a, _ = _sample_action(params, jnp.asarray(s), sub)
            a = int(a)
            states.append(s)
            actions.append(a)
            env_a = cm.SYNC if a == 0 else a
            s, r, done = env.step(env_a)
            rewards.append(r)
        # returns (terminal-heavy reward, discounted backwards)
        R, returns = 0.0, []
        for r in reversed(rewards):
            R = r + gamma * R
            returns.append(R)
        returns = returns[::-1]
        final = rewards[-1]
        if final > best_obj:
            best_obj = final
            best_strat = env.actions.copy()
        grads = grad_fn(params, jnp.asarray(np.stack(states)),
                        jnp.asarray(np.array(actions, dtype=np.int32)),
                        jnp.asarray(np.array(returns, dtype=np.float32)),
                        entropy_beta)
        params, opt_state = apply(params, opt_state, grads)

    out = env.evaluate_strategy(best_strat)
    lat, peak = float(out.latency), float(out.peak_mem)
    return SearchResult("A2C", best_strat, env.baseline_latency / lat, lat,
                        peak, bool(out.valid), budget, time.perf_counter() - t0)
