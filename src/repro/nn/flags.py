"""Global lowering-mode flags.

``force_unroll()``: context manager that makes every structured loop
(layer-stack scan, attention query-chunk map, CE chunk scan, WKV chunk
scan) lower as a python-unrolled chain instead of ``lax.scan``/``lax.map``.

Why: XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of
trip count, so flop/byte/collective numbers from the memory-optimal scanned
lowering are ~L x undercounted.  The dry-run compiles tiny L=1/L=2 unrolled
variants under this flag purely for cost measurement; the deployable
artifact keeps the scanned (memory-optimal) form.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def unroll_enabled() -> bool:
    return _UNROLL


@contextlib.contextmanager
def force_unroll(enabled: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = enabled
    try:
        yield
    finally:
        _UNROLL = prev
