"""Generic pre-norm transformer block + scanned stack.

``stack_apply`` runs ``jax.lax.scan`` over parameters stacked on a leading
layer axis (MaxText-style): HLO size and compile time stay O(1) in depth —
essential for 94-layer models compiled for 512 devices on a CPU host.
Per-layer heterogeneity (sliding-window sizes, local/global flags) rides
along as scanned arrays, keeping a single block body.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .attention import mha_apply, mha_init
from .linear import dense_apply, dense_init
from .norms import layernorm_apply, layernorm_init, rmsnorm_apply, rmsnorm_init

__all__ = ["mlp_init", "mlp_apply", "block_init", "block_apply",
           "stack_init", "stack_apply"]


def mlp_init(key: jax.Array, d: int, d_ff: int, *, kind: str = "swiglu",
             bias: bool = False, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"gate": dense_init(k1, d, d_ff, bias=bias, dtype=dtype),
                "up": dense_init(k2, d, d_ff, bias=bias, dtype=dtype),
                "down": dense_init(k3, d_ff, d, bias=bias, dtype=dtype)}
    if kind == "gelu":
        return {"up": dense_init(k1, d, d_ff, bias=True, dtype=dtype),
                "down": dense_init(k2, d_ff, d, bias=True, dtype=dtype)}
    raise ValueError(kind)


def mlp_apply(p: dict, x: jax.Array, *, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        return dense_apply(p["down"],
                           jax.nn.silu(dense_apply(p["gate"], x))
                           * dense_apply(p["up"], x))
    return dense_apply(p["down"], jax.nn.gelu(dense_apply(p["up"], x)))


def block_init(key: jax.Array, d_model: int, *, n_heads: int,
               kv_heads: int | None = None, head_dim: int | None = None,
               d_ff: int, mlp_kind: str = "swiglu", norm: str = "rms",
               qkv_bias: bool = False, qk_norm: bool = False,
               cross_attn: bool = False, dtype=jnp.float32) -> dict:
    ka, km, kc = jax.random.split(key, 3)
    norm_init = rmsnorm_init if norm == "rms" else layernorm_init
    p = {"ln1": norm_init(d_model, dtype),
         "attn": mha_init(ka, d_model, n_heads=n_heads, kv_heads=kv_heads,
                          head_dim=head_dim, qkv_bias=qkv_bias,
                          qk_norm=qk_norm, dtype=dtype),
         "ln2": norm_init(d_model, dtype),
         "mlp": mlp_init(km, d_model, d_ff, kind=mlp_kind, dtype=dtype)}
    if cross_attn:
        p["lnx"] = norm_init(d_model, dtype)
        p["xattn"] = mha_init(kc, d_model, n_heads=n_heads, kv_heads=kv_heads,
                              head_dim=head_dim, dtype=dtype)
    return p


def block_apply(p: dict, x: jax.Array, *, n_heads: int, kv_heads: int,
                head_dim: int, mlp_kind: str = "swiglu", norm: str = "rms",
                cos=None, sin=None, causal: bool = True, window=-1,
                memory: jax.Array | None = None, cache: dict | None = None,
                xcache: dict | None = None, impl: str = "xla"):
    """Pre-norm block. Returns (x, cache, xcache)."""
    norm_apply = rmsnorm_apply if norm == "rms" else layernorm_apply
    h, cache = mha_apply(p["attn"], norm_apply(p["ln1"], x), cos=cos, sin=sin,
                         causal=causal, window=window, cache=cache, impl=impl,
                         n_heads=n_heads, kv_heads=kv_heads, head_dim=head_dim)
    x = x + h
    if memory is not None:
        h, _ = mha_apply(p["xattn"], norm_apply(p["lnx"], x), xkv=memory,
                         causal=False, impl=impl, n_heads=n_heads,
                         kv_heads=kv_heads, head_dim=head_dim)
        x = x + h
    x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x), kind=mlp_kind)
    return x, cache, xcache


def stack_init(key: jax.Array, n_layers: int, init_fn) -> dict:
    """Stack per-layer params on a leading axis: ``init_fn(key) -> params``."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


def stack_apply(params: dict, x: jax.Array, body_fn, *, per_layer=None,
                caches=None, remat: str = "none"):
    """``lax.scan`` over stacked layer params.

    ``body_fn(layer_params, x, aux, cache) -> (x, new_cache)``; ``per_layer``
    is a pytree of [L, ...] arrays scanned alongside params; ``caches`` a
    stacked pytree of per-layer caches (or None).  ``remat``: "none" | "full"
    | "dots" (checkpoint matmul outputs only).
    """
    def scan_body(carry, scanned):
        lp, aux, cache = scanned
        y, new_cache = body_fn(lp, carry, aux, cache)
        return y, new_cache

    if remat == "full":
        scan_body = jax.checkpoint(scan_body)
    elif remat == "dots":
        scan_body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    elif remat != "none":
        raise ValueError(remat)

    n_layers = jax.tree_util.tree_leaves(params)[0].shape[0]
    if per_layer is None:
        per_layer = jnp.zeros((n_layers,), jnp.int32)
    x, new_caches = jax.lax.scan(scan_body, x, (params, per_layer, caches))
    return x, new_caches
