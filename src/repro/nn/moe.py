"""Top-k token-choice MoE with capacity (GShard/Switch semantics).

Dispatch is sort-based (argsort by expert id + per-expert position via
searchsorted) rather than one-hot-matmul: no [tokens, E, C] tensor is ever
materialized, so the layer scales to 128 experts at 1M tokens.  Tokens over
an expert's capacity are dropped (standard capacity semantics); the router
adds the Switch load-balancing auxiliary loss.

Expert weights are stacked [E, ...] so expert-parallelism is a single
PartitionSpec on the leading axis; under pjit the token->expert resharding
becomes the all-to-all GSPMD inserts at the dispatch/combine gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .linear import dense_init
from ..distributed.sharding import logical_shard

__all__ = ["moe_init", "moe_apply"]


def moe_init(key: jax.Array, d: int, d_ff: int, n_experts: int, *,
             dtype=jnp.float32) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    import numpy as np
    sc = 1.0 / np.sqrt(d)
    scf = 1.0 / np.sqrt(d_ff)
    return {
        "router": dense_init(kr, d, n_experts, bias=False, dtype=jnp.float32),
        "gate": (jax.random.normal(kg, (n_experts, d, d_ff), jnp.float32)
                 * sc).astype(dtype),
        "up": (jax.random.normal(ku, (n_experts, d, d_ff), jnp.float32)
               * sc).astype(dtype),
        "down": (jax.random.normal(kd, (n_experts, d_ff, d), jnp.float32)
                 * scf).astype(dtype),
    }


def moe_apply(p: dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (out [B,S,d], aux_loss scalar).

    GShard-style GROUPED dispatch (§Perf iteration 2): each batch element
    is an independent dispatch group with per-group capacity, so the sort/
    position computation is local to the group.  Under pjit with batch
    sharded over (pod, data), every sort is shard-local — the only
    cross-device traffic left is the token->expert exchange itself, which
    GSPMD lowers as the canonical MoE all-to-all.  (The previous global-
    argsort formulation made XLA emit a distributed sort over B*S*k
    elements per layer: the 1.8e6 ms collective term on qwen3-moe.)
    """
    B, S, d = x.shape
    E = p["gate"].shape[0]

    logits = (x.astype(jnp.float32) @ p["router"]["w"])         # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)                        # [B,S,k]
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (frac tokens to e) * (mean router prob e)
    me = probs.mean((0, 1))
    ce = jnp.zeros(E, jnp.float32).at[idx.reshape(-1)].add(
        jnp.ones(B * S * top_k, jnp.float32)) / (B * S * top_k)
    aux = E * jnp.sum(me * ce)

    SK = S * top_k
    C = max(1, int(SK / E * capacity_factor))   # per-group capacity

    def dispatch_one(xg, idx_g, w_g):
        """One group (= one sequence): xg [S,d], idx/w [S,k]."""
        flat_e = idx_g.reshape(SK)
        flat_w = w_g.reshape(SK).astype(xg.dtype)
        src = jnp.repeat(jnp.arange(S), top_k)
        order = jnp.argsort(flat_e, stable=True)
        se, ssrc, sw = flat_e[order], src[order], flat_w[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E))
        pos = jnp.arange(SK) - seg_start[se]
        keep = pos < C
        slot = se * C + jnp.minimum(pos, C - 1)
        vals = xg[ssrc] * keep[:, None].astype(xg.dtype)
        xe = jnp.zeros((E * C, d), xg.dtype).at[slot].add(vals)
        return xe.reshape(E, C, d), (slot, ssrc, sw, keep)

    xe, meta = jax.vmap(dispatch_one)(x, idx, w)    # xe [B,E,C,d]
    xe = logical_shard(xe, "batch", "model", None, None)   # DP x EP

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["gate"])) \
        * jnp.einsum("becd,edf->becf", xe, p["up"])
    ye = jnp.einsum("becf,efd->becd", h, p["down"])
    ye = logical_shard(ye, "batch", "model", None, None)

    def combine_one(ye_g, m):
        slot, ssrc, sw, keep = m
        contrib = ye_g.reshape(E * C, d)[slot] \
            * (sw * keep.astype(ye_g.dtype))[:, None]
        return jnp.zeros((S, d), ye_g.dtype).at[ssrc].add(contrib)

    out = jax.vmap(combine_one)(ye, meta)
    return out, aux
