"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head dim into three sections rotated
by (temporal, height, width) position ids; for the text backbone all three
ids coincide, which is what the stubbed-frontend configs use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rope_freqs", "apply_rope", "mrope_freqs"]


def rope_freqs(positions: jax.Array, head_dim: int,
               theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables of shape [..., seq, head_dim/2] (f32)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_freqs(pos_thw: jax.Array, head_dim: int, sections: tuple[int, int, int],
                theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """M-RoPE cos/sin. ``pos_thw``: [3, ...seq] (temporal/height/width ids);
    ``sections``: half-dim split (sums to head_dim//2)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos_thw.astype(jnp.float32)[..., None] * inv  # [3, ..., half]
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -2),                      # [..., 3, half]
        sec_id[(None,) * (ang.ndim - 2) + (None, slice(None))], axis=-2
    )[..., 0, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x`` [..., seq, heads, head_dim] by tables [..., seq, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s],
                           axis=-1).astype(x.dtype)
