"""Dense / embedding primitives."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["dense_init", "dense_apply", "embedding_init", "embedding_apply"]


def dense_init(key: jax.Array, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key: jax.Array, vocab: int, d: int, *,
                   dtype=jnp.float32, scale: float = 0.02) -> dict:
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32)
                    * scale).astype(dtype)}


def embedding_apply(p: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(p["emb"], ids, axis=0)
