"""RWKV6 "Finch" block (arXiv:2404.05892): data-dependent decay WKV.

Time-mix recurrence per head (head_dim n):
    y_t = r_t @ (diag(u) k_t^T v_t + S_t)
    S_{t+1} = diag(w_t) S_t + k_t^T v_t
with per-channel decay w_t = exp(-exp(w0 + lora_w(x_t)))  (data-dependent),
token-shift interpolation on every projection input, per-head GroupNorm and
SiLU(g) output gating.  Channel-mix is the squared-ReLU RWKV FFN.

The training path scans time in jnp (``wkv_scan``); the TPU hot path is the
chunked Pallas kernel ``kernels/rwkv6_scan`` validated against this oracle.
Decode carries O(1) state: (S [B,H,n,n], last token for the shifts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flags as _flags
from .linear import dense_apply, dense_init
from .norms import layernorm_init, layernorm_apply, rmsnorm_init

__all__ = ["rwkv_block_init", "rwkv_block_apply", "rwkv_decode_step",
           "rwkv_init_state", "wkv_scan"]

_MIX = ("r", "k", "v", "w", "g")


def rwkv_block_init(key: jax.Array, d: int, *, n_heads: int, head_dim: int,
                    d_ff: int, lora_rank: int = 32, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 16)
    H, n = n_heads, head_dim
    assert H * n == d, (H, n, d)
    p = {
        "ln1": layernorm_init(d, dtype), "ln2": layernorm_init(d, dtype),
        # token-shift mix coefficients per projection
        "mu": {m: jnp.full((d,), 0.5, dtype) for m in _MIX},
        "r": dense_init(ks[0], d, d, bias=False, dtype=dtype),
        "k": dense_init(ks[1], d, d, bias=False, dtype=dtype),
        "v": dense_init(ks[2], d, d, bias=False, dtype=dtype),
        "g": dense_init(ks[3], d, d, bias=False, dtype=dtype),
        "o": dense_init(ks[4], d, d, bias=False, dtype=dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x W1) W2))
        "w0": jnp.zeros((d,), jnp.float32) - 6.0,   # decays near 1 (RWKV init); also keeps the chunked scan numerically stable
        "w1": dense_init(ks[5], d, lora_rank, bias=False, dtype=dtype),
        "w2": dense_init(ks[6], lora_rank, d, bias=False, dtype=dtype),
        "u": jnp.zeros((H, n), jnp.float32),          # bonus for current token
        "gn": layernorm_init(n, dtype),               # per-head group norm
        # channel mix
        "mu_c": {m: jnp.full((d,), 0.5, dtype) for m in ("k", "r")},
        "ck": dense_init(ks[7], d, d_ff, bias=False, dtype=dtype),
        "cv": dense_init(ks[8], d_ff, d, bias=False, dtype=dtype),
        "cr": dense_init(ks[9], d, d, bias=False, dtype=dtype),
    }
    return p


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros or carried ``last`` at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def wkv_scan(r, k, v, w, u, s0):
    """WKV6 recurrence. r,k,v,w: [B,T,H,n]; u: [H,n]; s0: [B,H,n,n].
    Returns (y [B,T,H,n], sT)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                      # [B,H,n]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,n,n]
        y = jnp.einsum("bhi,bhij->bhj", rt, u[..., None] * kv + s)
        s = wt[..., None] * s + kv
        return s, y
    rs, ks_, vs, ws = (jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                       for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rs, ks_, vs, ws))
    return jnp.moveaxis(ys, 0, 1), sT


def wkv_chunked(r, k, v, w, u, s0, *, chunk: int = 32):
    """Chunk-parallel WKV6 (GLA-style, arXiv:2312.06635 §4).

    Within a chunk of length C, with per-channel decays w_t and cumulative
    products cum_t = prod_{i<=t} w_i:
        r~_t = r_t * cum_{t-1},   k~_s = k_s / cum_s
        y_t  = r~_t @ S_0 + sum_{s<t} (r~_t . k~_s) v_s + (r_t.u.k_t) v_t
        S_C  = cum_C * S_0 + sum_s (cum_C / cum_s) k_s^T v_s
    turning T sequential steps into T/C chunk matmuls — the math the Pallas
    kernel ``kernels/rwkv6_scan`` implements on TPU, exposed here in jnp so
    the model's training path is matmul-bound (and XLA-countable) too.
    Chunks run in a python loop (static count); f32 throughout.
    """
    B, T, H, n = r.shape
    if _flags.unroll_enabled():
        # cost-measurement lowering: a handful of large chunks keeps the
        # unrolled HLO small; intra-chunk wkv flops are <1% of block flops
        # so the chunk-size dependence of the count is negligible, and the
        # variant is never executed (numerics don't matter).
        chunk = max(chunk, -(-T // 8))
    nc = -(-T // chunk)
    pad = nc * chunk - T
    def pf(x, val=0.0):
        return jnp.pad(x.astype(jnp.float32),
                       ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=val)
    rp, kp, vp = pf(r), pf(k), pf(v)
    wp = pf(w, 1.0)            # pad decay with 1 (identity)
    s = s0.astype(jnp.float32)
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    uf = u.astype(jnp.float32)

    def one_chunk(s, blk):
        rc, kc, vc, wc = blk
        lw = jnp.log(jnp.maximum(wc, 1e-30))
        cum = jnp.exp(jnp.cumsum(lw, axis=1))           # [B,C,H,n]
        cum_prev = cum / wc                              # cum_{t-1}
        rt = rc * cum_prev
        kt = kc / jnp.maximum(cum, 1e-30)
        inter = jnp.einsum("bchn,bhnm->bchm", rt, s)
        scores = jnp.einsum("bchn,bdhn->bhcd", rt, kt) * causal[None, None]
        diag = jnp.einsum("bchn,hn,bchn->bch", rc, uf, kc)
        intra = jnp.einsum("bhcd,bdhm->bchm", scores, vc) \
            + diag[..., None] * vc
        cend = cum[:, -1]                                # [B,H,n]
        s = cend[..., None] * s \
            + jnp.einsum("bchn,bchm->bhnm",
                         (cend[:, None] / jnp.maximum(cum, 1e-30)) * kc, vc)
        return s, inter + intra

    blocks = tuple(t.reshape(t.shape[0], nc, chunk, *t.shape[2:]
                             ).transpose(1, 0, 2, 3, 4)
                   for t in (rp, kp, vp, wp))
    if _flags.unroll_enabled():
        ys = []
        for ci in range(nc):
            s, yi = one_chunk(s, tuple(b[ci] for b in blocks))
            ys.append(yi)
        y = jnp.concatenate(ys, axis=1)[:, :T]
        return y, s
    s, ys = jax.lax.scan(one_chunk, s, blocks)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(rp.shape[0], nc * chunk,
                                            rp.shape[2], rp.shape[3])[:, :T]
    return y, s


def rwkv_init_state(batch: int, n_heads: int, head_dim: int, d: int,
                    dtype=jnp.float32) -> dict:
    return {"s": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            "x_tm": jnp.zeros((batch, d), dtype),
            "xc_tm": jnp.zeros((batch, d), dtype)}


def _time_mix(p, xn, x_prev, *, n_heads, head_dim, state_s, impl="xla"):
    """Shared by train (seq) and decode (T=1). xn: [B,T,d] normed input;
    x_prev: [B,T,d] shifted sequence. Returns (out, new_state_s)."""
    B, T, d = xn.shape
    H, n = n_heads, head_dim
    proj = {m: _mix(xn, x_prev, p["mu"][m]) for m in _MIX}
    r = dense_apply(p["r"], proj["r"]).reshape(B, T, H, n)
    k = dense_apply(p["k"], proj["k"]).reshape(B, T, H, n)
    v = dense_apply(p["v"], proj["v"]).reshape(B, T, H, n)
    g = dense_apply(p["g"], proj["g"])
    lora = dense_apply(p["w2"], jnp.tanh(dense_apply(p["w1"], proj["w"])))
    w = jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))
    w = w.reshape(B, T, H, n)
    if impl == "pallas" and T > 1:
        from ..kernels import rwkv6_scan as rk
        y, sT = rk.wkv6(r, k, v, w, p["u"], state_s)
    elif T > 1:
        y, sT = wkv_chunked(r, k, v, w, p["u"], state_s)
    else:
        y, sT = wkv_scan(r, k, v, w, p["u"], state_s)
    yn = layernorm_apply(p["gn"], y.astype(xn.dtype))          # [B,T,H,n]
    out = dense_apply(p["o"], (yn.reshape(B, T, d)
                               * jax.nn.silu(g)))
    return out, sT


def rwkv_block_apply(p: dict, x: jax.Array, *, n_heads: int, head_dim: int,
                     state: dict | None = None, impl: str = "xla"):
    """Full block (time-mix + channel-mix). x [B,T,d].
    With ``state`` (decode, T==1) the shifts come from carried tokens."""
    B, T, d = x.shape
    s0 = state["s"] if state is not None else \
        jnp.zeros((B, n_heads, head_dim, head_dim), jnp.float32)

    xn = layernorm_apply(p["ln1"], x)
    xs = _shift(xn, state["x_tm"] if state is not None else None)
    att, sT = _time_mix(p, xn, xs, n_heads=n_heads, head_dim=head_dim,
                        state_s=s0, impl=impl)
    x = x + att

    xc = layernorm_apply(p["ln2"], x)
    xcs = _shift(xc, state["xc_tm"] if state is not None else None)
    kx = _mix(xc, xcs, p["mu_c"]["k"])
    rx = _mix(xc, xcs, p["mu_c"]["r"])
    kk = jnp.square(jax.nn.relu(dense_apply(p["ck"], kx)))
    x = x + jax.nn.sigmoid(dense_apply(p["cr"], rx)) * dense_apply(p["cv"], kk)

    new_state = None
    if state is not None:
        new_state = {"s": sT, "x_tm": xn[:, -1], "xc_tm": xc[:, -1]}
    return x, new_state


def rwkv_decode_step(p, x, state, *, n_heads, head_dim):
    return rwkv_block_apply(p, x, n_heads=n_heads, head_dim=head_dim,
                            state=state)
