"""Multi-head attention with GQA, qk-norm, sliding windows, KV cache.

One implementation serves every assigned architecture:
 - GQA via ``kv_heads < n_heads`` (grouped einsum, no materialized repeat);
 - per-layer sliding windows as a *traced* scalar (``window <= 0`` = full
   attention), so heterogeneous stacks (gemma3's 5:1 local:global) run as a
   single ``lax.scan`` body;
 - optional qk-norm (qwen3), QKV bias (qwen1.5), cross-attention (whisper);
 - decode path with a donated KV cache (``cache["idx"]`` write position);
 - ``impl`` selects the math backend: "xla" (dry-run / CPU default) or
   "pallas" (TPU flash kernels, validated in interpret mode — DESIGN §7).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import flags
from .linear import dense_init, dense_apply
from .norms import rmsnorm_init, rmsnorm_apply
from .rope import apply_rope

__all__ = ["mha_init", "mha_apply", "attend", "init_kv_cache"]

NEG_INF = -1e30


def mha_init(key: jax.Array, d_model: int, *, n_heads: int,
             kv_heads: int | None = None, head_dim: int | None = None,
             qkv_bias: bool = False, out_bias: bool = False,
             qk_norm: bool = False, dtype=jnp.float32) -> dict:
    kv_heads = kv_heads or n_heads
    head_dim = head_dim or d_model // n_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "q": dense_init(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "k": dense_init(kk, d_model, kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "v": dense_init(kv, d_model, kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "o": dense_init(ko, n_heads * head_dim, d_model, bias=out_bias, dtype=dtype),
    }
    if qk_norm:
        p["qn"] = rmsnorm_init(head_dim, dtype)
        p["kn"] = rmsnorm_init(head_dim, dtype)
    return p


def init_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {"k": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            "idx": jnp.zeros((), jnp.int32)}


def _grouped_scores(q, k):
    """q [B,S,Hq,hd], k [B,T,Hkv,hd] -> scores [B,Hkv,G,S,T] (f32)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)


def _grouped_out(probs, v):
    """probs [B,Hkv,G,S,T], v [B,T,Hkv,hd] -> [B,S,Hq*hd]."""
    B, Hkv, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hkv * G * v.shape[-1])


def _attend_dense(q, k, v, *, causal, window, q_offset, kv_len):
    S, T = q.shape[1], k.shape[1]
    i = jnp.arange(S)[:, None] + q_offset
    j = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= j <= i
    w = jnp.asarray(window)
    ok &= (w <= 0) | ((i - j) < w)
    if kv_len is not None:
        ok &= j < kv_len
    scores = _grouped_scores(q, k)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _grouped_out(probs, v).astype(q.dtype)


def _attend_chunked(q, k, v, *, causal, window, q_offset, kv_len,
                    q_chunk: int = 512):
    """Flash-style chunked attention in pure XLA: scan over query blocks
    with online-softmax accumulation, so the S x T score matrix is never
    materialized (peak temp ~ q_chunk x T per (kv-head, group)).  This is
    the memory-sane fallback the dry-run lowers when the Pallas kernel is
    not selected; each chunk is rematerialized in the backward pass."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nc = -(-S // q_chunk)
    pad = nc * q_chunk - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = qp.reshape(B, nc, q_chunk, Hq, hd).transpose(1, 0, 2, 3, 4)
    j = jnp.arange(T)[None, :]
    w = jnp.asarray(window)

    def chunk(ci, qi):
        i = ci * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
        ok = jnp.ones((q_chunk, T), bool)
        if causal:
            ok &= j <= i
        ok &= (w <= 0) | ((i - j) < w)
        if kv_len is not None:
            ok &= j < kv_len
        s = _grouped_scores(qi, k)                       # [B,Hkv,G,qc,T]
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(-1, keepdims=True)
        o = jnp.einsum("bkgst,btkh->bskgh", p / jnp.maximum(l, 1e-30),
                       v.astype(jnp.float32))
        return o.reshape(B, q_chunk, Hq * hd)

    chunk = jax.checkpoint(chunk)
    if flags.unroll_enabled():
        # cost-measurement lowering: python loop so XLA counts every chunk
        outs = [chunk(jnp.asarray(ci), qc[ci]) for ci in range(nc)]
        out = jnp.concatenate(outs, axis=1)
    else:
        # deployable lowering: sequential map keeps one chunk live at a time
        outs = jax.lax.map(lambda args: chunk(*args), (jnp.arange(nc), qc))
        out = outs.transpose(1, 0, 2, 3).reshape(B, nc * q_chunk, Hq * hd)
    return out[:, :S].astype(q.dtype)


def attend(q, k, v, *, causal: bool = True, window=-1,
           q_offset=0, kv_len=None, impl: str = "xla",
           q_chunk: int = 512) -> jax.Array:
    """Core attention math. ``window``/``q_offset``/``kv_len`` may be traced.

    q position i (global ``i + q_offset``) may see kv position j iff
      j <= i+q_offset              (if causal)
      i+q_offset - j < window      (if window > 0)
      j < kv_len                   (if kv_len given; masks unwritten cache)
    """
    if impl == "pallas":
        # The KV-cached paths carry q_offset/kv_len masking the pallas
        # prefill kernel does not implement — dropping them here would
        # attend over the UNWRITTEN cache tail (the staleness bug pinned by
        # tests/test_kernels.py::test_attend_pallas_*).  Dispatch:
        #  - uncached full sequence      -> flash_attention (as before);
        #  - single-token cached decode  -> flash_decode (kv_len-masked
        #    split-K, the deployable decode kernel);
        #  - multi-token cache append    -> the XLA masking math below,
        #    bit-exact with impl="xla" (this is what keeps the cached
        #    decode of model.dt_decode_step — 2-3 token appends — equal to
        #    dt_apply whichever impl is selected).  TODO: thread
        #    q_offset/kv_len masking into flash_attention so long cached
        #    prefills keep the flash kernel on TPU instead of this
        #    correct-but-dense fallback.
        cached = (kv_len is not None
                  or not (isinstance(q_offset, int) and q_offset == 0))
        if not cached:
            from ..kernels.flash_attention import flash_attention as _fa
            return _fa(q, k, v, causal=causal, window=window)
        if (q.shape[1] == 1 and causal and kv_len is not None
                and isinstance(window, int) and window == -1):
            from ..kernels.flash_decode import flash_decode as _fd
            # exact single-token causal mask: j <= q_offset AND j < kv_len
            # == j < min(kv_len, q_offset + 1) — so a mid-cache query
            # (q_offset < kv_len - 1) masks identically to impl="xla"
            return _fd(q, k, v, jnp.minimum(jnp.asarray(kv_len),
                                            jnp.asarray(q_offset) + 1))
    if q.shape[1] > q_chunk:
        return _attend_chunked(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, kv_len=kv_len,
                               q_chunk=q_chunk)
    return _attend_dense(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, kv_len=kv_len)


def mha_apply(p: dict, x: jax.Array, *, cos=None, sin=None,
              causal: bool = True, window=-1, xkv: jax.Array | None = None,
              cache: dict | None = None, impl: str = "xla",
              n_heads: int, kv_heads: int, head_dim: int):
    """Returns (out, new_cache). ``xkv`` switches to cross-attention (no
    rope/cache-append on q side; kv from encoder memory). With ``cache``,
    ``x`` is the current step's tokens (decode: S == 1)."""
    B, S, _ = x.shape
    q = dense_apply(p["q"], x).reshape(B, S, n_heads, head_dim)
    src = xkv if xkv is not None else x
    Tkv = src.shape[1]
    k = dense_apply(p["k"], src).reshape(B, Tkv, kv_heads, head_dim)
    v = dense_apply(p["v"], src).reshape(B, Tkv, kv_heads, head_dim)
    if "qn" in p:
        q = rmsnorm_apply(p["qn"], q)
        k = rmsnorm_apply(p["kn"], k)
    if cos is not None and xkv is None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = cache
    q_offset = 0
    kv_len = None
    if cache is not None and xkv is None:
        idx = cache["idx"]
        ck = jax.lax.dynamic_update_slice(cache["k"],
                                          k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"],
                                          v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "idx": idx + S}
        k, v = ck, cv
        q_offset = idx
        kv_len = idx + S

    out = attend(q, k, v, causal=causal and xkv is None, window=window,
                 q_offset=q_offset, kv_len=kv_len, impl=impl)
    return dense_apply(p["o"], out), new_cache
