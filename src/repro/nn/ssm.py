"""Mamba-style selective SSM head for the hymba hybrid block (arXiv:2411.13676).

Diagonal selective state-space:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t      h: [d_inner, N]
    y_t = (h_t @ C_t) + D * x_t
with input-dependent (dt, B, C), causal depthwise conv front, SiLU gates.
Decode carries (h, conv window) — O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .linear import dense_apply, dense_init

__all__ = ["ssm_init", "ssm_apply", "ssm_init_state"]


def ssm_init(key: jax.Array, d: int, *, state: int = 16, conv: int = 4,
             dt_rank: int | None = None, dtype=jnp.float32) -> dict:
    dt_rank = dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 6)
    import numpy as np
    return {
        "conv": (jax.random.normal(ks[0], (conv, d), jnp.float32)
                 / np.sqrt(conv)).astype(dtype),
        "wbc": dense_init(ks[1], d, 2 * state, bias=False, dtype=dtype),
        "wdt1": dense_init(ks[2], d, dt_rank, bias=False, dtype=dtype),
        "wdt2": dense_init(ks[3], dt_rank, d, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.arange(1, state + 1, dtype=jnp.float32)
                         )[None, :].repeat(d, 0),       # [d, N]
        "D": jnp.ones((d,), jnp.float32),
    }


def ssm_init_state(batch: int, d: int, state: int, conv: int,
                   dtype=jnp.float32) -> dict:
    return {"h": jnp.zeros((batch, d, state), jnp.float32),
            "cwin": jnp.zeros((batch, conv - 1, d), dtype)}


def _causal_conv(x: jax.Array, kernel: jax.Array,
                 cwin: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x [B,T,d], kernel [K,d]."""
    K = kernel.shape[0]
    pad = (jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
           if cwin is None else cwin)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(K))
    return y, xp[:, -(K - 1):] if K > 1 else pad


def ssm_apply(p: dict, x: jax.Array, *, state: dict | None = None):
    """x [B,T,d] -> (y [B,T,d], new_state)."""
    B, T, d = x.shape
    N = p["A_log"].shape[1]
    xc, cwin = _causal_conv(x, p["conv"],
                            state["cwin"] if state is not None else None)
    xc = jax.nn.silu(xc)
    bc = dense_apply(p["wbc"], xc).astype(jnp.float32)
    Bt, Ct = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dense_apply(
        p["wdt2"], dense_apply(p["wdt1"], xc)).astype(jnp.float32))  # [B,T,d]
    A = -jnp.exp(p["A_log"])                                          # [d,N]
    decay = jnp.exp(dt[..., None] * A)                                # [B,T,d,N]
    inp = (dt * xc.astype(jnp.float32))[..., None] * Bt[..., None, :]

    h0 = (state["h"] if state is not None
          else jnp.zeros((B, d, N), jnp.float32))

    def step(h, z):
        dec, u, c = z
        h = dec * h + u
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y
    hT, ys = jax.lax.scan(step, h0,
                          (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(inp, 1, 0),
                           jnp.moveaxis(Ct, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype) + xc * p["D"].astype(x.dtype)
    new_state = {"h": hT, "cwin": cwin} if state is not None else None
    return y, new_state
