"""Minimal pure-JAX neural substrate (flax/haiku are unavailable offline).

Convention: every module is an ``<name>_init(key, ...) -> params`` /
``<name>_apply(params, x, ...) -> y`` pair of pure functions; params are
plain dict pytrees so they shard, scan, checkpoint and donate like any
pytree.  Layer stacks for the big models are ``jax.lax.scan`` over params
stacked on a leading axis (MaxText-style), which keeps HLO size and compile
time independent of depth.
"""
from .linear import dense_init, dense_apply, embedding_init, embedding_apply
from .norms import layernorm_init, layernorm_apply, rmsnorm_init, rmsnorm_apply
from .rope import rope_freqs, apply_rope, mrope_freqs
from . import attention
from .attention import mha_init, mha_apply
from .transformer import (block_init, block_apply, stack_init, stack_apply,
                          mlp_init, mlp_apply)

__all__ = [
    "dense_init", "dense_apply", "embedding_init", "embedding_apply",
    "layernorm_init", "layernorm_apply", "rmsnorm_init", "rmsnorm_apply",
    "rope_freqs", "apply_rope", "mrope_freqs", "attention",
    "mha_init", "mha_apply", "block_init", "block_apply",
    "stack_init", "stack_apply", "mlp_init", "mlp_apply",
]
