"""LayerNorm / RMSNorm (always computed in f32, cast back)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["layernorm_init", "layernorm_apply", "rmsnorm_init", "rmsnorm_apply"]


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm_apply(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.square(xf).mean(-1, keepdims=True) + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)
