"""Vocab-parallel cross-entropy (Megatron-style).

With TP-sharded logits [batch, seq, vocab/'model'], a naive
``log_softmax + take_along_axis`` makes GSPMD re-replicate the full logits
(we measured a 64 GiB all-reduce + all-gather pair per step on gemma3).
This formulation keeps every elementwise op shard-local; the only cross-
shard traffic is two [batch, seq] f32 all-reduces (max and sum-exp) plus
one for the label term — O(tokens), not O(tokens x vocab).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_shard
from .flags import unroll_enabled

__all__ = ["vocab_parallel_ce"]


def vocab_parallel_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits [B,S,V] (V possibly TP-sharded),
    labels [B,S] int32."""
    lf = logical_shard(logits.astype(jnp.float32), "batch", None, "model")
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = logical_shard(
        jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32),
        "batch", None, "model")
    ll = jnp.sum(lf * onehot, axis=-1)
    return jnp.mean(lse - ll)


def fused_linear_ce(x: jax.Array, w: jax.Array, labels: jax.Array, *,
                    chunk: int = 512) -> jax.Array:
    """Chunked fused-projection CE: never materializes [B,S,V] logits.

    ``x`` [B,S,d] final hidden states, ``w`` [d,V] head weights (pass
    ``emb.T`` for tied embeddings), ``labels`` [B,S].  The sequence is
    scanned in ``chunk``-sized pieces; each piece projects, computes the
    vocab-parallel CE sum, and is rematerialized in the backward pass —
    peak temp drops from O(S*V) to O(chunk*V) per device.
    """
    B, S, d = x.shape
    if S <= chunk:
        return vocab_parallel_ce(
            (x @ w).astype(jnp.float32), labels)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def piece(xi, li):
        logits = logical_shard((xi @ w).astype(jnp.float32),
                               "batch", None, "model")
        m = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), -1)) + m[..., 0]
        onehot = logical_shard(
            jax.nn.one_hot(li, logits.shape[-1], dtype=jnp.float32),
            "batch", None, "model")
        ll = jnp.sum(logits * onehot, -1)
        valid = (li >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * valid)

    if unroll_enabled():
        tot = jnp.zeros((), jnp.float32)
        for ci in range(nc):
            tot = tot + piece(xc[ci], lc[ci])
    else:
        def scan_body(t, args):
            return t + piece(*args), None
        tot, _ = jax.lax.scan(scan_body, jnp.zeros((), jnp.float32),
                              (xc, lc))
    return tot / (B * S)
