"""Generic decoder-only LM covering the dense / MoE / VLM-backbone archs.

One scanned block body parameterized by ArchConfig handles: GQA (+qk-norm,
QKV bias), per-layer sliding windows (gemma3 5:1 local:global as a scanned
window array), SwiGLU or MoE FFN (stacked experts, EP-ready), standard RoPE
or M-RoPE (qwen2-vl), tied or untied embeddings, and stubbed modality
frontends (``embed_inputs``: the batch carries precomputed embeddings).

Entry points: ``forward`` (teacher-forced logits), ``loss_fn`` (next-token
CE + MoE aux), ``prefill`` (build KV caches), ``decode_step`` (one token,
donated caches).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import nn
from ..nn import flags as _flags
from ..nn.moe import moe_apply, moe_init
from ..distributed.sharding import logical_shard
from ..nn.losses import vocab_parallel_ce, fused_linear_ce
from ..configs import ArchConfig

__all__ = ["init", "forward", "loss_fn", "init_decode_state", "prefill",
           "decode_step"]


def _block_init(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    kb, km = jax.random.split(key)
    p = nn.block_init(kb, cfg.d_model, n_heads=cfg.n_heads,
                      kv_heads=cfg.kv_heads, head_dim=cfg.hd, d_ff=cfg.d_ff,
                      mlp_kind=cfg.mlp_kind, norm=cfg.norm,
                      qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype)
    if cfg.n_experts:
        del p["mlp"]
        p["moe"] = moe_init(km, cfg.d_model, cfg.d_ff, cfg.n_experts,
                            dtype=dtype)
    return p


def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    p = {
        "embed": nn.embedding_init(ke, cfg.vocab_padded, cfg.d_model,
                                   dtype=dtype),
        "blocks": nn.stack_init(kb, cfg.n_layers,
                                lambda k: _block_init(k, cfg, dtype)),
        "ln_f": (nn.rmsnorm_init if cfg.norm == "rms"
                 else nn.layernorm_init)(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = nn.dense_init(kh, cfg.d_model, cfg.vocab_padded,
                                  bias=False, dtype=dtype)
    return p


def _rope_tables(cfg: ArchConfig, batch: dict, positions: jax.Array):
    """cos/sin [B?, S, hd/2]; M-RoPE if the config says so."""
    if cfg.mrope_sections is not None:
        pos_thw = batch.get("pos_thw")
        if pos_thw is None:  # text-only: all three ids coincide
            pos_thw = jnp.broadcast_to(positions, (3,) + positions.shape)
        return nn.mrope_freqs(pos_thw, cfg.hd, cfg.mrope_sections,
                              cfg.rope_theta)
    return nn.rope_freqs(positions, cfg.hd, cfg.rope_theta)


def _body(cfg: ArchConfig, impl: str, static_window=None):
    """Scan body: (layer_params, x-or-(x,aux), per_layer, cache)."""
    norm_apply = nn.rmsnorm_apply if cfg.norm == "rms" else nn.layernorm_apply

    def body(lp, carry, aux, cache):
        x, aux_sum, cos, sin = carry
        x = logical_shard(x, "batch", None, None)
        # uniform window patterns pass statically (required by the pallas
        # kernel, which specializes per window value)
        window = static_window if static_window is not None else aux
        h, new_cache = nn.attention.mha_apply(
            lp["attn"], norm_apply(lp["ln1"], x), cos=cos, sin=sin,
            causal=True, window=window, cache=cache, impl=impl,
            n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=cfg.hd)
        x = x + h
        hin = norm_apply(lp["ln2"], x)
        if cfg.n_experts:
            h, aux_l = moe_apply(lp["moe"], hin, top_k=cfg.moe_top_k,
                                 capacity_factor=cfg.capacity_factor)
            aux_sum = aux_sum + aux_l
        else:
            h = nn.mlp_apply(lp["mlp"], hin, kind=cfg.mlp_kind)
        x = logical_shard(x + h, "batch", None, None)
        return (x, aux_sum, cos, sin), new_cache

    return body


def _run_stack(params, cfg: ArchConfig, x, cos, sin, *, caches=None,
               impl="xla", remat="none"):
    wins = cfg.windows()
    static_window = wins[0] if len(set(wins)) == 1 else None
    body = _body(cfg, impl, static_window)
    windows = jnp.asarray(wins, jnp.int32)

    def scan_body(carry, scanned):
        lp, win, cache = scanned
        (x, aux, c, s), new_cache = body(lp, carry, win, cache)
        return (x, aux, c, s), new_cache

    if remat == "full":
        scan_body = jax.checkpoint(scan_body)
    elif remat == "dots":
        scan_body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    carry0 = (x, jnp.zeros((), jnp.float32), cos, sin)
    if _flags.unroll_enabled():
        carry = carry0
        new_caches = caches
        sl = lambda t, i: jax.tree.map(lambda a: a[i], t)
        ncs = []
        L = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        for i in range(L):
            carry, nc_i = scan_body(carry, (sl(params["blocks"], i),
                                            windows[i],
                                            sl(caches, i) if caches is not None else None))
            ncs.append(nc_i)
        (x, aux, _, _) = carry
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                      if caches is not None else None)
        return x, aux, new_caches
    (x, aux, _, _), new_caches = jax.lax.scan(
        scan_body, carry0, (params["blocks"], windows, caches))
    return x, aux, new_caches


def _logits(params, cfg: ArchConfig, x):
    norm_apply = nn.rmsnorm_apply if cfg.norm == "rms" else nn.layernorm_apply
    x = norm_apply(params["ln_f"], x)
    if cfg.tie_embeddings:
        w = params["embed"]["emb"]
        logits = x @ w.T
    else:
        logits = nn.dense_apply(params["head"], x)
    # keep the vocab dim TP-sharded: without this GSPMD may materialize
    # full-vocab logits per device (DESIGN §6)
    return logical_shard(logits, "batch", None, "model")


def _hidden(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none"):
    """Final normed hidden states [B,S,d] (+ MoE aux)."""
    if cfg.embed_inputs:
        x = batch["embeds"]
        B, S = x.shape[:2]
    else:
        ids = batch["tokens"]
        B, S = ids.shape
        x = nn.embedding_apply(params["embed"], ids)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = _rope_tables(cfg, batch, positions)
    x, aux, _ = _run_stack(params, cfg, x, cos, sin, impl=impl, remat=remat)
    norm_apply = nn.rmsnorm_apply if cfg.norm == "rms" else nn.layernorm_apply
    return norm_apply(params["ln_f"], x), aux


def _head_w(params, cfg: ArchConfig):
    return (params["embed"]["emb"].T if cfg.tie_embeddings
            else params["head"]["w"])


def forward(params, cfg: ArchConfig, batch: dict, *, impl: str = "xla",
            remat: str = "none"):
    """Teacher-forced logits [B,S,Vp] (+ MoE aux loss)."""
    if cfg.embed_inputs:
        x = batch["embeds"]
        B, S = x.shape[:2]
    else:
        ids = batch["tokens"]
        B, S = ids.shape
        x = nn.embedding_apply(params["embed"], ids)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = _rope_tables(cfg, batch, positions)
    x, aux, _ = _run_stack(params, cfg, x, cos, sin, impl=impl, remat=remat)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ArchConfig, batch: dict, *, impl: str = "xla",
            remat: str = "none", aux_weight: float = 0.01):
    x, aux = _hidden(params, cfg, batch, impl=impl, remat=remat)
    ce = fused_linear_ce(x, _head_w(params, cfg), batch["labels"])
    return ce + aux_weight * aux / max(cfg.n_layers, 1)


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Stacked per-layer KV caches [L, B, T, kvh, hd]."""
    L = cfg.n_layers
    mk = lambda: jnp.zeros((L, batch, max_len, cfg.kv_heads, cfg.hd), dtype)
    return {"k": mk(), "v": mk(), "idx": jnp.zeros((L,), jnp.int32)}


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int, *,
            impl: str = "xla", cache_dtype=jnp.bfloat16):
    """Process the prompt, returning (last-token logits, filled caches)."""
    if cfg.embed_inputs:
        x = batch["embeds"]; B, S = x.shape[:2]
    else:
        ids = batch["tokens"]; B, S = ids.shape
        x = nn.embedding_apply(params["embed"], ids)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = _rope_tables(cfg, batch, positions)
    caches = init_decode_state(cfg, B, max_len, cache_dtype)
    x, _, caches = _run_stack(params, cfg, x, cos, sin, caches=caches,
                              impl=impl)
    return _logits(params, cfg, x[:, -1:]), caches


def decode_step(params, cfg: ArchConfig, state: dict, batch: dict, *,
                impl: str = "xla"):
    """One decode step. ``batch['tokens']`` [B,1] (or embeds [B,1,d]).
    ``state`` caches are donated by the serving loop."""
    if cfg.embed_inputs:
        x = batch["embeds"]; B = x.shape[0]
    else:
        ids = batch["tokens"]; B = ids.shape[0]
        x = nn.embedding_apply(params["embed"], ids)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    pos = jnp.broadcast_to(state["idx"][0], (B, 1))
    cos, sin = _rope_tables(cfg, batch, pos)
    x, _, state = _run_stack(params, cfg, x, cos, sin, caches=state,
                             impl=impl)
    return _logits(params, cfg, x), state
