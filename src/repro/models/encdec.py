"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings [B, S, d] (sinusoidal positions added);
the decoder (learned positions, causal self-attn + cross-attn to encoder
memory) trains on text tokens of length S//8.  Decode carries a self-attn
KV cache; cross-attn reads the static encoder memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from ..nn import flags as _flags
from ..distributed.sharding import logical_shard
from ..nn.losses import vocab_parallel_ce, fused_linear_ce
from ..configs import ArchConfig

__all__ = ["init", "forward", "loss_fn", "init_decode_state", "prefill",
           "decode_step", "DEC_FRAC"]

DEC_FRAC = 8  # decoder_len = encoder seq_len // DEC_FRAC (stub frontend)


def _sinusoid(S: int, d: int, dtype) -> jax.Array:
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ke, kd, kt, kp = jax.random.split(key, 4)
    blk = lambda cross: (lambda k: nn.block_init(
        k, cfg.d_model, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.hd, d_ff=cfg.d_ff, mlp_kind=cfg.mlp_kind, norm=cfg.norm,
        cross_attn=cross, dtype=dtype))
    return {
        "enc_blocks": nn.stack_init(ke, cfg.encoder_layers, blk(False)),
        "enc_ln": nn.layernorm_init(cfg.d_model, dtype),
        "tok": nn.embedding_init(kt, cfg.vocab_padded, cfg.d_model,
                                 dtype=dtype),
        "pos": nn.embedding_init(kp, 4096 + 8, cfg.d_model, dtype=dtype),
        "dec_blocks": nn.stack_init(kd, cfg.n_layers, blk(True)),
        "dec_ln": nn.layernorm_init(cfg.d_model, dtype),
    }


def _enc(params, cfg: ArchConfig, embeds, *, impl="xla", remat="none"):
    x = embeds + _sinusoid(embeds.shape[1], cfg.d_model, embeds.dtype)

    def body(x, lp):
        x = logical_shard(x, "batch", None, None)
        x, _, _ = nn.block_apply(lp, x, n_heads=cfg.n_heads,
                                 kv_heads=cfg.kv_heads, head_dim=cfg.hd,
                                 mlp_kind=cfg.mlp_kind, norm=cfg.norm,
                                 causal=False, impl=impl)
        return x, None
    if remat == "full":
        body = jax.checkpoint(body)
    if _flags.unroll_enabled():
        L = jax.tree_util.tree_leaves(params["enc_blocks"])[0].shape[0]
        for i in range(L):
            x, _ = body(x, jax.tree.map(lambda a: a[i],
                                        params["enc_blocks"]))
        return nn.layernorm_apply(params["enc_ln"], x)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return nn.layernorm_apply(params["enc_ln"], x)


def _dec(params, cfg: ArchConfig, tokens, memory, *, caches=None, pos0=0,
         impl="xla", remat="none"):
    B, S = tokens.shape
    x = nn.embedding_apply(params["tok"], tokens) \
        + nn.embedding_apply(params["pos"], pos0 + jnp.arange(S))

    def body(carry, scanned):
        x, memory = carry
        lp, cache = scanned
        x = logical_shard(x, "batch", None, None)
        x, cache, _ = nn.block_apply(lp, x, n_heads=cfg.n_heads,
                                     kv_heads=cfg.kv_heads, head_dim=cfg.hd,
                                     mlp_kind=cfg.mlp_kind, norm=cfg.norm,
                                     causal=True, memory=memory, cache=cache,
                                     impl=impl)
        return (x, memory), cache
    if remat == "full":
        body = jax.checkpoint(body)
    if _flags.unroll_enabled():
        sl = lambda t, i: jax.tree.map(lambda a: a[i], t)
        carry = (x, memory)
        outs = []
        L = jax.tree_util.tree_leaves(params["dec_blocks"])[0].shape[0]
        for i in range(L):
            carry, c_i = body(carry, (sl(params["dec_blocks"], i),
                                      sl(caches, i) if caches is not None else None))
            outs.append(c_i)
        (x, _) = carry
        caches = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                  if caches is not None else None)
        x = nn.layernorm_apply(params["dec_ln"], x)
        return x, caches
    (x, _), caches = jax.lax.scan(body, (x, memory),
                                  (params["dec_blocks"], caches))
    x = nn.layernorm_apply(params["dec_ln"], x)
    return x, caches


def _dec_hidden(params, cfg, tokens, memory, *, caches=None, pos0=0,
                impl="xla", remat="none"):
    return _dec(params, cfg, tokens, memory, caches=caches, pos0=pos0,
                impl=impl, remat=remat)


def forward(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none"):
    memory = _enc(params, cfg, batch["embeds"], impl=impl, remat=remat)
    x, _ = _dec(params, cfg, batch["tokens"], memory, impl=impl, remat=remat)
    logits = logical_shard(x @ params["tok"]["emb"].T, "batch", None, "model")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none", aux_weight: float = 0.0):
    memory = _enc(params, cfg, batch["embeds"], impl=impl, remat=remat)
    x, _ = _dec_hidden(params, cfg, batch["tokens"], memory, impl=impl,
                       remat=remat)
    return fused_linear_ce(x, params["tok"]["emb"].T, batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.kv_heads, cfg.hd), dtype),
        "idx": jnp.zeros((L,), jnp.int32),
        "memory": jnp.zeros((batch, max_len * DEC_FRAC, cfg.d_model), dtype),
    }


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int, *,
            impl="xla", cache_dtype=jnp.bfloat16):
    """Encode the audio stub + consume the decoder prompt."""
    memory = _enc(params, cfg, batch["embeds"], impl=impl)
    B, S = batch["tokens"].shape
    caches = {"k": jnp.zeros((cfg.n_layers, B, max_len, cfg.kv_heads, cfg.hd),
                             cache_dtype),
              "v": jnp.zeros((cfg.n_layers, B, max_len, cfg.kv_heads, cfg.hd),
                             cache_dtype),
              "idx": jnp.zeros((cfg.n_layers,), jnp.int32)}
    x, caches = _dec(params, cfg, batch["tokens"], memory,
                     caches=caches, impl=impl)
    logits = logical_shard(x @ params["tok"]["emb"].T, "batch", None, "model")
    caches["memory"] = memory.astype(cache_dtype)
    return logits[:, -1:], caches


def decode_step(params, cfg: ArchConfig, state, batch: dict, *, impl="xla"):
    memory = state["memory"]
    caches = {k: state[k] for k in ("k", "v", "idx")}
    pos0 = state["idx"][0]
    x, caches = _dec(params, cfg, batch["tokens"], memory,
                     caches=caches, pos0=pos0, impl=impl)
    logits = logical_shard(x @ params["tok"]["emb"].T, "batch", None, "model")
    caches["memory"] = memory
    return logits, caches
