"""Model registry: ArchConfig -> model module + ShapeDtypeStruct input specs.

``input_specs`` follows the shannon/kernels dry-run pattern: weak-type-
correct, shardable stand-ins for every model input, no device allocation.
Decode-state specs come from ``jax.eval_shape`` over the model's own
``init_decode_state`` so they always match the real pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ArchConfig, Shape
from . import lm, rwkv_lm, hymba, encdec

__all__ = ["get_model", "input_specs", "decode_state_specs", "decode_cache_len"]

_FAMILY = {"dense": lm, "moe": lm, "vlm": lm, "ssm": rwkv_lm,
           "hybrid": hymba, "encdec": encdec}


def get_model(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def decode_cache_len(cfg: ArchConfig, shape: Shape) -> int:
    """KV/cache length for decode shapes (whisper: decoder-side length)."""
    if cfg.family == "encdec":
        return max(shape.seq_len // encdec.DEC_FRAC, 8)
    return shape.seq_len


def input_specs(cfg: ArchConfig, shape: Shape, *, act_dtype=jnp.bfloat16
                ) -> dict:
    """Model-input ShapeDtypeStructs for (cfg, shape)."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if cfg.family == "encdec":
        sd = max(S // encdec.DEC_FRAC, 8)
        if kind == "train":
            return {"embeds": _sds((B, S, cfg.d_model), act_dtype),
                    "tokens": _sds((B, sd), jnp.int32),
                    "labels": _sds((B, sd), jnp.int32)}
        if kind == "prefill":
            return {"embeds": _sds((B, S, cfg.d_model), act_dtype),
                    "tokens": _sds((B, sd), jnp.int32)}
        return {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.embed_inputs:                      # vlm stub frontend
        if kind == "train":
            return {"embeds": _sds((B, S, cfg.d_model), act_dtype),
                    "labels": _sds((B, S), jnp.int32)}
        if kind == "prefill":
            return {"embeds": _sds((B, S, cfg.d_model), act_dtype)}
        return {"embeds": _sds((B, 1, cfg.d_model), act_dtype)}
    if kind in ("train",):
        return {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
    if kind == "prefill":
        return {"tokens": _sds((B, S), jnp.int32)}
    return {"tokens": _sds((B, 1), jnp.int32)}


def decode_state_specs(cfg: ArchConfig, shape: Shape,
                       cache_dtype=jnp.bfloat16):
    model = get_model(cfg)
    T = decode_cache_len(cfg, shape)
    return jax.eval_shape(
        lambda: model.init_decode_state(cfg, shape.global_batch, T,
                                        cache_dtype))
