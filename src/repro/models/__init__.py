from .registry import (get_model, input_specs, decode_state_specs,
                       decode_cache_len)

__all__ = ["get_model", "input_specs", "decode_state_specs",
           "decode_cache_len"]
