"""Hymba hybrid-head LM (arXiv:2411.13676).

Each layer runs GQA attention (mostly sliding-window; a few global layers)
and a Mamba-style selective-SSM head *in parallel* on the same normed input;
the two paths are averaged (the paper's mean fusion after per-path
normalization) before the residual add, followed by a SwiGLU MLP.
Decode state = KV cache (window-bounded for local layers) + SSM state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn
from ..nn import flags as _flags
from ..nn.ssm import ssm_init, ssm_apply, ssm_init_state
from ..distributed.sharding import logical_shard
from ..nn.losses import vocab_parallel_ce, fused_linear_ce
from ..configs import ArchConfig

__all__ = ["init", "forward", "loss_fn", "init_decode_state", "prefill",
           "decode_step"]


def _block_init(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    ka, ks, km = jax.random.split(key, 3)
    return {
        "ln1": nn.rmsnorm_init(cfg.d_model, dtype),
        "attn": nn.attention.mha_init(ka, cfg.d_model, n_heads=cfg.n_heads,
                                      kv_heads=cfg.kv_heads, head_dim=cfg.hd,
                                      dtype=dtype),
        "ssm": ssm_init(ks, cfg.d_model, state=cfg.ssm_state,
                        conv=cfg.ssm_conv, dtype=dtype),
        "na": nn.rmsnorm_init(cfg.d_model, dtype),   # per-path output norms
        "ns": nn.rmsnorm_init(cfg.d_model, dtype),
        "ln2": nn.rmsnorm_init(cfg.d_model, dtype),
        "mlp": nn.mlp_init(km, cfg.d_model, cfg.d_ff, kind="swiglu",
                           dtype=dtype),
    }


def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    return {
        "embed": nn.embedding_init(ke, cfg.vocab_padded, cfg.d_model,
                                   dtype=dtype),
        "blocks": nn.stack_init(kb, cfg.n_layers,
                                lambda k: _block_init(k, cfg, dtype)),
        "ln_f": nn.rmsnorm_init(cfg.d_model, dtype),
        "head": nn.dense_init(kh, cfg.d_model, cfg.vocab_padded, bias=False,
                              dtype=dtype),
    }


def _run(params, cfg: ArchConfig, x, cos, sin, *, caches=None, impl="xla",
         remat="none"):
    windows = jnp.asarray(cfg.windows(), jnp.int32)

    def scan_body(carry, scanned):
        x, cos, sin = carry
        lp, win, cache = scanned
        x = logical_shard(x, "batch", None, None)
        kv_cache = cache["kv"] if cache is not None else None
        ssm_state = cache["ssm"] if cache is not None else None
        xn = nn.rmsnorm_apply(lp["ln1"], x)
        ha, kv_cache = nn.attention.mha_apply(
            lp["attn"], xn, cos=cos, sin=sin, causal=True, window=win,
            cache=kv_cache, impl=impl, n_heads=cfg.n_heads,
            kv_heads=cfg.kv_heads, head_dim=cfg.hd)
        hs, ssm_state = ssm_apply(lp["ssm"], xn, state=ssm_state)
        h = 0.5 * (nn.rmsnorm_apply(lp["na"], ha)
                   + nn.rmsnorm_apply(lp["ns"], hs))
        x = x + h
        x = x + nn.mlp_apply(lp["mlp"], nn.rmsnorm_apply(lp["ln2"], x),
                             kind="swiglu")
        new_cache = (None if cache is None
                     else {"kv": kv_cache, "ssm": ssm_state})
        return (x, cos, sin), new_cache

    if remat == "full":
        scan_body = jax.checkpoint(scan_body)
    elif remat == "dots":
        scan_body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if _flags.unroll_enabled():
        sl = lambda t, i: jax.tree.map(lambda a: a[i], t)
        carry = (x, cos, sin)
        outs = []
        L = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        for i in range(L):
            carry, c_i = scan_body(carry, (sl(params["blocks"], i),
                                           windows[i],
                                           sl(caches, i) if caches is not None else None))
            outs.append(c_i)
        (x, _, _) = carry
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                      if caches is not None else None)
        return x, new_caches
    (x, _, _), new_caches = jax.lax.scan(scan_body, (x, cos, sin),
                                         (params["blocks"], windows, caches))
    return x, new_caches


def forward(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none"):
    ids = batch["tokens"]
    B, S = ids.shape
    x = nn.embedding_apply(params["embed"], ids)
    cos, sin = nn.rope_freqs(jnp.broadcast_to(jnp.arange(S), (B, S)), cfg.hd,
                             cfg.rope_theta)
    x, _ = _run(params, cfg, x, cos, sin, impl=impl, remat=remat)
    x = nn.rmsnorm_apply(params["ln_f"], x)
    logits = logical_shard(nn.dense_apply(params["head"], x),
                           "batch", None, "model")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none", aux_weight: float = 0.0):
    ids = batch["tokens"]
    B, S = ids.shape
    x = nn.embedding_apply(params["embed"], ids)
    cos, sin = nn.rope_freqs(jnp.broadcast_to(jnp.arange(S), (B, S)), cfg.hd,
                             cfg.rope_theta)
    x, _ = _run(params, cfg, x, cos, sin, impl=impl, remat=remat)
    x = nn.rmsnorm_apply(params["ln_f"], x)
    return fused_linear_ce(x, params["head"]["w"], batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    L = cfg.n_layers
    kv = {"k": jnp.zeros((L, batch, max_len, cfg.kv_heads, cfg.hd), dtype),
          "v": jnp.zeros((L, batch, max_len, cfg.kv_heads, cfg.hd), dtype),
          "idx": jnp.zeros((L,), jnp.int32)}
    ssm = ssm_init_state(batch, cfg.d_model, cfg.ssm_state, cfg.ssm_conv,
                         dtype)
    ssm = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), ssm)
    return {"kv": kv, "ssm": ssm}


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int, *,
            impl="xla", cache_dtype=jnp.bfloat16):
    ids = batch["tokens"]
    B, S = ids.shape
    caches = init_decode_state(cfg, B, max_len, cache_dtype)
    x = nn.embedding_apply(params["embed"], ids)
    cos, sin = nn.rope_freqs(jnp.broadcast_to(jnp.arange(S), (B, S)), cfg.hd,
                             cfg.rope_theta)
    x, caches = _run(params, cfg, x, cos, sin, caches=caches, impl=impl)
    x = nn.rmsnorm_apply(params["ln_f"], x[:, -1:])
    return nn.dense_apply(params["head"], x), caches


def decode_step(params, cfg: ArchConfig, state, batch: dict, *, impl="xla"):
    ids = batch["tokens"]
    B = ids.shape[0]
    x = nn.embedding_apply(params["embed"], ids)
    pos = jnp.broadcast_to(state["kv"]["idx"][0], (B, 1))
    cos, sin = nn.rope_freqs(pos, cfg.hd, cfg.rope_theta)
    x, state = _run(params, cfg, x, cos, sin, caches=state, impl=impl)
    x = nn.rmsnorm_apply(params["ln_f"], x)
    return nn.dense_apply(params["head"], x), state
