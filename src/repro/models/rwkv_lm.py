"""RWKV6 language model (attention-free; arXiv:2404.05892)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn
from ..nn import flags as _flags
from ..nn.rwkv import (rwkv_block_init, rwkv_block_apply, rwkv_init_state)
from ..distributed.sharding import logical_shard
from ..nn.losses import vocab_parallel_ce, fused_linear_ce
from ..configs import ArchConfig

__all__ = ["init", "forward", "loss_fn", "init_decode_state", "prefill",
           "decode_step"]


def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    return {
        "embed": nn.embedding_init(ke, cfg.vocab_padded, cfg.d_model,
                                   dtype=dtype),
        "blocks": nn.stack_init(
            kb, cfg.n_layers,
            lambda k: rwkv_block_init(k, cfg.d_model, n_heads=cfg.n_heads,
                                      head_dim=cfg.hd, d_ff=cfg.d_ff,
                                      dtype=dtype)),
        "ln_f": nn.layernorm_init(cfg.d_model, dtype),
        "head": nn.dense_init(kh, cfg.d_model, cfg.vocab_padded, bias=False,
                              dtype=dtype),
    }


def _run(params, cfg: ArchConfig, x, *, states=None, impl="xla",
         remat="none"):
    def scan_body(x, scanned):
        lp, st = scanned
        x = logical_shard(x, "batch", None, None)
        x, new_st = rwkv_block_apply(lp, x, n_heads=cfg.n_heads,
                                     head_dim=cfg.hd, state=st, impl=impl)
        return x, new_st
    if remat == "full":
        scan_body = jax.checkpoint(scan_body)
    if _flags.unroll_enabled():
        sl = lambda t, i: jax.tree.map(lambda a: a[i], t)
        outs = []
        L = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        for i in range(L):
            x, st_i = scan_body(x, (sl(params["blocks"], i),
                                    sl(states, i) if states is not None else None))
            outs.append(st_i)
        new_states = (jax.tree.map(lambda *a: jnp.stack(a), *outs)
                      if states is not None else None)
        return x, new_states
    x, new_states = jax.lax.scan(scan_body, x, (params["blocks"], states))
    return x, new_states


def forward(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none"):
    x = nn.embedding_apply(params["embed"], batch["tokens"])
    x, _ = _run(params, cfg, x, impl=impl, remat=remat)
    x = nn.layernorm_apply(params["ln_f"], x)
    logits = logical_shard(nn.dense_apply(params["head"], x),
                           "batch", None, "model")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, impl="xla",
            remat="none", aux_weight: float = 0.0):
    x = nn.embedding_apply(params["embed"], batch["tokens"])
    x, _ = _run(params, cfg, x, impl=impl, remat=remat)
    x = nn.layernorm_apply(params["ln_f"], x)
    return fused_linear_ce(x, params["head"]["w"], batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """O(1) recurrent state per layer (max_len unused — that's the point)."""
    L = cfg.n_layers
    st = rwkv_init_state(batch, cfg.n_heads, cfg.hd, cfg.d_model, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), st)


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int, *,
            impl="xla", cache_dtype=jnp.bfloat16):
    B = batch["tokens"].shape[0]
    states = init_decode_state(cfg, B, max_len, cache_dtype)
    x = nn.embedding_apply(params["embed"], batch["tokens"])
    x, states = _run(params, cfg, x, states=states, impl=impl)
    x = nn.layernorm_apply(params["ln_f"], x[:, -1:])
    return nn.dense_apply(params["head"], x), states


def decode_step(params, cfg: ArchConfig, state, batch: dict, *, impl="xla"):
    x = nn.embedding_apply(params["embed"], batch["tokens"])
    x, state = _run(params, cfg, x, states=state)
    x = nn.layernorm_apply(params["ln_f"], x)
    return nn.dense_apply(params["head"], x), state
