"""Population fusion-strategy evaluation (TPU Pallas) — the paper's search
hot loop as a kernel, in production grid form (DESIGN §7, §13).

G-Sampler evaluates thousands of strategies per generation across a whole
(workload x accelerator x budget) condition grid; this kernel evaluates a
``[bp, P]`` BLOCK of candidate strategies per grid step entirely in VMEM.
The per-condition layer table (A/W/F/OE/UC/SKIP, padded to P positions) is
resident in VMEM and shared by every candidate in the block; per-candidate
group accumulators live in registers/VPU lanes, so the sweep over the P
chain positions is a statically unrolled loop of [bp]-wide vector ops — no
HBM traffic beyond one read of the strategy block and one write of the
per-group result matrices.

THE ACCELERATOR IS TRACED DATA, not a compile-time constant: the hardware
descriptor enters as a per-condition ``[C, HW_FEATURE_DIM]`` row (any form
``accel.stack_hw`` accepts) and the pack-time ``wl["BPE"]`` -> serving
``bytes_per_elem`` A/W rescale happens IN-KERNEL — exactly the
``cost_model._scaled_AW`` contract of DESIGN §11, an IEEE identity when the
datatypes match.  Sweeping the whole ``ACCEL_ZOO`` therefore reuses ONE
compiled program per block shape (zero recompiles across accelerators).

BIT-EXACTNESS CONTRACT (DESIGN §13): the kernel emits the per-group
decomposition (compute / traffic / on-chip / memory / waves / length
vectors plus per-position group ids) accumulated in the same position order
as ``cost_model._evaluate_full``'s sorted segment-sums, and the CostOut
roofline/reduction step runs OUTSIDE the kernel through
``cost_model.finalize_groups`` — the same jnp expressions the XLA evaluator
lowers.  On the CPU container (interpret mode, the ``kernels/ops.py``
selection contract) the two backends are bit-identical, which is what lets
``gsampler_search_grid`` produce the same teacher corpus on either
``evaluator`` backend.  The oracles are ``kernels/ref.fusion_eval_ref`` /
``fusion_eval_grid_ref`` and the loop-based ``core.ref_model``.
"""
from __future__ import annotations

import functools
import time
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import cost_model as _cm
from ..core.accel import HW_FEATURE_DIM, hw_array, stack_hw

__all__ = ["fusion_eval_population", "fusion_eval_population_stats",
           "fusion_eval_grid", "fusion_eval_grid_stats",
           "compiled_backend_supported", "autotune_block", "backend_stats"]

_UTIL_MIN = 1.0 / 4096.0
# HW_FIELDS slots the kernel reads from its [C, HW_FEATURE_DIM] hw row
_NPE, _LANES, _FREQ = 0, 1, 2
_BPE_SLOT, _STREAM = 6, 9


def _fe_kernel(strat_ref, A_ref, W_ref, F_ref, OE_ref, UC_ref, SKIP_ref,
               n_ref, batch_ref, bpe_ref, hw_ref,
               Cg_ref, Tg_ref, Og_ref, Mg_ref, Wg_ref, glen_ref, gid_ref,
               *, P: int):
    """One [bp, P] strategy block of one condition row.

    Emits the group decomposition (per-group component sums indexed by
    group id, plus per-position group ids); latency/peak/validity are
    assembled outside by ``cost_model.finalize_groups`` so both evaluator
    backends share one reduction lowering (DESIGN §13)."""
    bp = strat_ref.shape[1]
    strat = strat_ref[...][0].astype(jnp.float32)         # [bp, P]
    n = n_ref[...][0]
    B = batch_ref[...][0]
    hw = hw_ref[...][0]                                   # [HW_FEATURE_DIM]
    lanes = hw[_NPE] * hw[_LANES]
    peak_macs = lanes * hw[_FREQ]
    stream_buf = hw[_STREAM]

    # pack-time -> serving-datatype rescale, in-kernel (DESIGN §11/§13);
    # the multiplier is exactly 1.0 when the datatypes match
    scale = hw[_BPE_SLOT] / bpe_ref[...][0]
    A = A_ref[...][0] * scale                             # [P]
    W = W_ref[...][0] * scale
    F = F_ref[...][0]
    OEv = OE_ref[...][0]
    UC = UC_ref[...][0]
    SKIP = SKIP_ref[...][0]

    def util(mbe, oe, uc):
        return jnp.clip(mbe * oe / lanes, _UTIL_MIN, uc)

    zeros = jnp.zeros((bp,), jnp.float32)
    zmat = jnp.zeros((bp, P), jnp.float32)
    pos = jnp.arange(P)

    # per-group output matrices (group id -> component sums)
    C_g, T_g, O_g, M_g, wave_g, glen = (zmat,) * 6
    gid_cols = [jnp.zeros((bp,), jnp.int32)]              # position 0: gid 0
    # open-group accumulators + strategy-prefix carry
    g_comp = g_traf = g_on = g_mem = g_wav = g_len = zeros
    scount = jnp.zeros((bp,), jnp.int32)                  # syncs before pos i
    prev_sync = jnp.zeros((bp,), bool)
    prev_mb = jnp.clip(strat[:, 0], 1.0, B)
    lastb = jnp.full((bp,), -1.0, jnp.float32)            # last sync position

    for i in range(1, P):
        a = strat[:, i]
        live = jnp.asarray(i <= n)                        # mask: 1 <= i <= n
        Ai, Ap, Wi, Fi = A[i], A[i - 1], W[i], F[i]
        OEi, UCi = OEv[i], UC[i]
        src = SKIP[i]
        gid_cols.append(scount)
        sync = (a < 0.0) & live
        mb = jnp.clip(a, 1.0, B)
        mbe = jnp.where(sync, jnp.where(prev_sync, 1.0, prev_mb), mb)
        stage = jnp.where(sync, 1.0, mb)
        head = g_len == 0.0

        # residual edge: same-group iff the source is after the last sync
        # (gid[src] == gid[i]; position 0 shares gid 0 with the first group)
        has_skip = src >= 0
        same = has_skip & (src.astype(jnp.float32) > lastb)
        Asrc = A[jnp.maximum(src, 0)]
        hold = jnp.where(same, mbe * Asrc, 0.0)
        cross_t = jnp.where(has_skip & ~same, 2.0 * B * Asrc, 0.0)

        is_tail = (sync | (i == n)) & live
        waves = jnp.ceil(B / mbe)
        head_f = jnp.where(head, 1.0, 0.0)
        tail_f = jnp.where(is_tail, 1.0, 0.0)
        # fused-style per-position terms — expression order mirrors
        # cost_model._evaluate_full term by term (bit-exactness contract)
        mem_i = stage * Ai + (head_f * mbe) * Ap + hold
        traf_i = (head_f * B) * Ap + (tail_f * B) * Ai + Wi * waves + cross_t
        comp_i = B * Fi / peak_macs / util(mbe, OEi, UCi)
        on_i = B * (Ap + Ai) + Wi * waves

        # streaming alternative: this layer alone in its group (unfused:
        # one full-batch pass, working set clamped to the streaming buffer)
        hold_a = jnp.where(same, B * Asrc, 0.0)
        mem_a = jnp.minimum(stage * Ai + (head_f * B) * Ap + hold_a,
                            stream_buf)
        comp_a = B * Fi / peak_macs / util(jnp.full((bp,), B), OEi, UCi)
        traf_a = (head_f * B) * Ap + (tail_f * B) * Ai + Wi * 1.0 + cross_t
        on_a = B * (Ap + Ai) + Wi * 1.0

        lv = jnp.where(live, 1.0, 0.0)
        g_comp = g_comp + comp_i * lv
        g_traf = g_traf + traf_i * lv
        g_on = g_on + on_i * lv
        g_mem = g_mem + mem_i * lv
        g_wav = g_wav + waves * lv
        g_len = g_len + lv

        single = g_len == 1.0
        Cc = jnp.where(single, comp_a, g_comp)
        Tc = jnp.where(single, traf_a, g_traf)
        Oc = jnp.where(single, on_a, g_on)
        Mc = jnp.where(single, mem_a, g_mem)
        Wc = jnp.where(single, 1.0, g_wav)

        onehot = (pos[None, :] == scount[:, None]) & is_tail[:, None]
        C_g = jnp.where(onehot, Cc[:, None], C_g)
        T_g = jnp.where(onehot, Tc[:, None], T_g)
        O_g = jnp.where(onehot, Oc[:, None], O_g)
        M_g = jnp.where(onehot, Mc[:, None], M_g)
        wave_g = jnp.where(onehot, Wc[:, None], wave_g)
        glen = jnp.where(onehot, g_len[:, None], glen)

        rz = lambda x: jnp.where(is_tail, zeros, x)
        g_comp, g_traf, g_on = rz(g_comp), rz(g_traf), rz(g_on)
        g_mem, g_wav, g_len = rz(g_mem), rz(g_wav), rz(g_len)
        scount = scount + jnp.where(sync, 1, 0)
        lastb = jnp.where(sync, jnp.float32(i), lastb)
        prev_sync = sync
        prev_mb = mb

    Cg_ref[...] = C_g[None]
    Tg_ref[...] = T_g[None]
    Og_ref[...] = O_g[None]
    Mg_ref[...] = M_g[None]
    Wg_ref[...] = wave_g[None]
    glen_ref[...] = glen[None]
    gid_ref[...] = jnp.stack(gid_cols, axis=-1)[None]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def _fusion_eval_grid_jit(strategies, wls: dict, batches, budgets, hwrows,
                          *, bp: int, interpret: bool):
    C, POP, P = strategies.shape
    NP = _ceil_to(POP, bp)
    if NP != POP:
        strategies = jnp.pad(strategies, ((0, 0), (0, NP - POP), (0, 0)),
                             constant_values=_cm.SYNC)
    row = lambda k, dt: wls[k].astype(dt).reshape(C, P)
    args = (strategies,
            row("A", jnp.float32), row("W", jnp.float32),
            row("F", jnp.float32), row("OE", jnp.float32),
            row("UC", jnp.float32), row("SKIP", jnp.int32),
            wls["n"].astype(jnp.int32).reshape(C),
            batches.astype(jnp.float32).reshape(C),
            wls["BPE"].astype(jnp.float32).reshape(C),
            hwrows.astype(jnp.float32).reshape(C, HW_FEATURE_DIM))

    cond_spec = [pl.BlockSpec((1, P), lambda c, g: (c, 0))] * 6
    scal_spec = [pl.BlockSpec((1,), lambda c, g: (c,))] * 3
    outs = pl.pallas_call(
        functools.partial(_fe_kernel, P=P),
        grid=(C, NP // bp),
        in_specs=[pl.BlockSpec((1, bp, P), lambda c, g: (c, g, 0))]
        + cond_spec + scal_spec
        + [pl.BlockSpec((1, HW_FEATURE_DIM), lambda c, g: (c, 0))],
        out_specs=[pl.BlockSpec((1, bp, P), lambda c, g: (c, g, 0))] * 7,
        out_shape=[jax.ShapeDtypeStruct((C, NP, P), jnp.float32)] * 6
        + [jax.ShapeDtypeStruct((C, NP, P), jnp.int32)],
        interpret=interpret,
    )(*args)
    C_g, T_g, O_g, M_g, wave_g, glen, gid = (o[:, :POP] for o in outs)
    hw = _cm.as_hw(hwrows)
    bc = lambda x: x[:, None, None]
    hwb = jax.tree_util.tree_map(bc, hw)
    out = _cm.finalize_groups(C_g, T_g, O_g, M_g, wave_g, glen,
                              budgets[:, None], hwb)
    return out, gid, M_g


def _block_size(pop: int, bp: int) -> int:
    """Block width: cover small populations with one block (padded to the
    next pow2 lane count), cap at ``bp``."""
    b = 8
    while b < pop and b < bp:
        b *= 2
    return b


# -- compiled (non-interpret) lowering: probe / fallback / autotune ----------
#
# ``interpret=False`` is the production path on accelerator backends: the
# kernel lowers to Mosaic/Triton instead of being emulated op-by-op.  Not
# every backend can lower Pallas (CPU cannot — jax raises "Only interpret
# mode is supported on CPU backend."), so support is PROBED once per
# process with a trivial kernel and memoized; callers that ask for the
# compiled path on an unsupported backend get a clearly-warned interpret
# fallback with bit-identical results (the kernel body is backend-neutral
# jnp — the DESIGN §13 parity contract) instead of a crash.  The fallback
# is also armed at call time: if a *specific* program fails to lower even
# though the probe passed, that call (and all later ones) falls back too.

_COMPILED_OK: bool | None = None      # memoized probe result (None = unprobed)
_FALLBACKS = 0                        # compiled->interpret retries served
_LEGACY_BP = 128                      # pre-autotune default block width


def compiled_backend_supported() -> bool:
    """Can this process's default backend lower a Pallas kernel with
    ``interpret=False``?  Probed once with a trivial copy kernel and
    memoized (compiling the probe is milliseconds; re-raising per call
    would be seconds)."""
    global _COMPILED_OK
    if _COMPILED_OK is None:
        def _probe(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0
        try:
            out = pl.pallas_call(
                _probe,
                out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
                interpret=False,
            )(jnp.ones((8, 128), jnp.float32))
            jax.block_until_ready(out)
            _COMPILED_OK = True
        except Exception:
            _COMPILED_OK = False
    return _COMPILED_OK


def _note_fallback(reason: str) -> None:
    global _COMPILED_OK, _FALLBACKS
    _COMPILED_OK = False
    _FALLBACKS += 1
    if _FALLBACKS == 1:                       # warn once, count every time
        warnings.warn(
            f"fusion_eval: compiled (interpret=False) Pallas lowering is "
            f"unavailable on backend '{jax.default_backend()}' — falling "
            f"back to interpret mode (bit-identical, slower). {reason}",
            RuntimeWarning, stacklevel=3)


def backend_stats() -> dict:
    """Operational visibility for the kernel lowering path: the probe
    verdict (None until first asked), how many compiled calls fell back
    to interpret, and the autotuned block widths chosen so far."""
    return {
        "backend": jax.default_backend(),
        "compiled_supported": _COMPILED_OK,
        "interpret_fallbacks": _FALLBACKS,
        "autotuned_bp": dict(_AUTOTUNED),
    }


_AUTOTUNED: dict = {}                 # (P, pop bucket) -> chosen bp


def autotune_block(P: int, pop: int,
                   candidates: tuple = (32, 64, 128, 256)) -> int:
    """Pick the fastest block width for a ``[pop, P]`` evaluation on the
    compiled backend by timing each candidate on synthetic data (one
    warm-up compile + best-of-2 timed calls per candidate); memoized per
    (P, pop-bucket).  On interpret backends the block width only sets
    emulation chunking, so the legacy default is returned untimed."""
    key = (int(P), _block_size(pop, max(candidates)))
    got = _AUTOTUNED.get(key)
    if got is not None:
        return got
    if not compiled_backend_supported():
        return _AUTOTUNED.setdefault(key, _block_size(pop, _LEGACY_BP))
    import numpy as np
    rng = np.random.default_rng(0)
    popb = key[1]
    strat = jnp.asarray(
        rng.integers(-1, 5, size=(1, popb, P)).astype(np.float32))
    wls = {"A": jnp.full((1, P), 1e4, jnp.float32),
           "W": jnp.full((1, P), 1e4, jnp.float32),
           "F": jnp.full((1, P), 1e6, jnp.float32),
           "OE": jnp.ones((1, P), jnp.float32),
           "UC": jnp.ones((1, P), jnp.float32),
           "SKIP": jnp.full((1, P), -1, jnp.int32),
           "n": jnp.full((1,), P - 1, jnp.int32),
           "BPE": jnp.ones((1,), jnp.float32)}
    batches = jnp.ones((1,), jnp.float32)
    budgets = jnp.full((1,), 2.0 ** 24, jnp.float32)
    hwrows = hw_array(stack_hw(None, 1))
    best, best_t = None, float("inf")
    for bp in candidates:
        bpc = _block_size(popb, bp)
        if best is not None and bpc == best:
            continue
        try:
            out = _call_grid(strat, wls, batches, budgets, hwrows,
                             bp=bpc, interpret=False)
            jax.block_until_ready(out)
            t = float("inf")
            for _ in range(2):
                t0 = time.perf_counter()
                jax.block_until_ready(
                    _call_grid(strat, wls, batches, budgets, hwrows,
                               bp=bpc, interpret=False))
                t = min(t, time.perf_counter() - t0)
        except Exception:
            continue
        if t < best_t:
            best, best_t = bpc, t
    if best is None:                  # every candidate failed to lower
        best = _block_size(pop, _LEGACY_BP)
    return _AUTOTUNED.setdefault(key, best)


def _call_grid(strategies, wls, batches, budgets, hwrows, *,
               bp: int, interpret: bool):
    """The one funnel to the jitted kernel: serves compiled requests on
    unsupported backends via the warned interpret fallback, including
    programs that fail to lower only at compile time."""
    if not interpret and not compiled_backend_supported():
        _note_fallback("(probe failed)")
        interpret = True
    try:
        return _fusion_eval_grid_jit(strategies, wls, batches, budgets,
                                     hwrows, bp=bp, interpret=interpret)
    except Exception as e:
        if interpret:
            raise
        _note_fallback(f"({type(e).__name__}: {e})")
        return _fusion_eval_grid_jit(strategies, wls, batches, budgets,
                                     hwrows, bp=bp, interpret=True)


def _resolve_interpret(interpret: bool | None) -> bool:
    """Default lowering: compiled wherever the backend supports it,
    interpret otherwise (the probe, not a platform allowlist)."""
    if interpret is None:
        return not compiled_backend_supported()
    return interpret


def _resolve_bp(pop: int, P: int, bp: int | None, interpret: bool) -> int:
    """Default block width: the autotuned choice on compiled backends,
    the legacy default under interpret (where it only chunks emulation).
    An explicit ``bp`` always wins (clamped to the population)."""
    if bp is not None:
        return _block_size(pop, bp)
    if interpret:
        return _block_size(pop, _LEGACY_BP)
    return _block_size(pop, autotune_block(P, pop))


def fusion_eval_grid(wls: dict, strategies, batches, budgets, hw, *,
                     bp: int | None = None, interpret: bool | None = None):
    """Pallas backend of ``cost_model.evaluate_grid`` (same contract):
    CostOut [C, POP] for strategies [C, POP, P] over stacked workloads,
    per-condition batches/budgets [C] and per-condition hardware (anything
    ``accel.stack_hw`` accepts).  Zero recompiles across accelerators for a
    fixed block shape — the hw row is traced kernel data.

    ``interpret=None`` compiles wherever the backend can lower Pallas and
    interprets elsewhere; ``bp=None`` autotunes the block width on
    compiled backends (``autotune_block``)."""
    strategies = jnp.asarray(strategies)
    C, POP, P = strategies.shape
    interp = _resolve_interpret(interpret)
    out, _, _ = _call_grid(
        strategies, _kernel_wls(wls), jnp.asarray(batches),
        jnp.asarray(budgets), hw_array(stack_hw(hw, C)),
        bp=_resolve_bp(POP, P, bp, interp), interpret=interp)
    return out


def fusion_eval_grid_stats(wls: dict, strategies, batches, budgets, hw, *,
                           bp: int | None = None,
                           interpret: bool | None = None):
    """Pallas backend of ``cost_model.evaluate_grid_stats``:
    ``(CostOut [C, POP], gid [C, POP, P], M_g [C, POP, P])`` — the group
    decomposition the G-Sampler repair operator consumes."""
    strategies = jnp.asarray(strategies)
    C, POP, P = strategies.shape
    interp = _resolve_interpret(interpret)
    return _call_grid(
        strategies, _kernel_wls(wls), jnp.asarray(batches),
        jnp.asarray(budgets), hw_array(stack_hw(hw, C)),
        bp=_resolve_bp(POP, P, bp, interp), interpret=interp)


_KERNEL_KEYS = ("A", "W", "F", "OE", "UC", "SKIP", "n", "BPE")


def _kernel_wls(wls: dict) -> dict:
    """The packed-workload subset the kernel reads (mask is derived from
    ``n`` in-kernel; SHAPE6 is a decoration-only feature)."""
    missing = [k for k in _KERNEL_KEYS if k not in wls]
    if missing:
        raise KeyError(f"packed workload missing {missing} — pack with "
                       f"cost_model.pack_workload (BPE is required for the "
                       f"in-kernel rescale, DESIGN §13)")
    return {k: wls[k] for k in _KERNEL_KEYS}


def _lift(wl: dict):
    return {k: jnp.asarray(v)[None] for k, v in _kernel_wls(wl).items()}


def fusion_eval_population(strategies, wl: dict, *, batch, budget_bytes,
                           hw, bp: int | None = None,
                           interpret: bool | None = None):
    """Single-condition form: CostOut [pop] for strategies [pop, P] against
    one packed workload — ``cost_model.evaluate_population``'s contract.
    ``hw`` may be an AccelConfig or a traced ``accel.HwVec``."""
    out = fusion_eval_grid(
        _lift(wl), jnp.asarray(strategies)[None],
        jnp.asarray(batch, jnp.float32).reshape(1),
        jnp.asarray(budget_bytes, jnp.float32).reshape(1),
        stack_hw(hw, 1), bp=bp, interpret=interpret)
    return jax.tree_util.tree_map(lambda x: x[0], out)


def fusion_eval_population_stats(strategies, wl: dict, *, batch,
                                 budget_bytes, hw, bp: int | None = None,
                                 interpret: bool | None = None):
    """Single-condition stats form: ``(CostOut [pop], gid [pop, P],
    M_g [pop, P])`` — ``cost_model.evaluate_population_stats``'s contract."""
    out, gid, M_g = fusion_eval_grid_stats(
        _lift(wl), jnp.asarray(strategies)[None],
        jnp.asarray(batch, jnp.float32).reshape(1),
        jnp.asarray(budget_bytes, jnp.float32).reshape(1),
        stack_hw(hw, 1), bp=bp, interpret=interpret)
    return (jax.tree_util.tree_map(lambda x: x[0], out), gid[0], M_g[0])
