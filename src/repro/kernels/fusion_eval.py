"""Population fusion-strategy evaluation (TPU Pallas) — the paper's search
hot loop as a kernel.

G-Sampler evaluates 2k strategies per search and a production mapper
serves many concurrent (workload, budget) queries; this kernel evaluates a
BLOCK of candidate strategies per grid step entirely in VMEM.  The layer
table (A/W/F/OE/UC/SKIP, padded to P positions) is resident in VMEM and
shared by every candidate; per-candidate group accumulators live in
registers/VPU lanes, so the sweep over the P chain positions is a
sequential fori with [bp]-wide vector ops — no HBM traffic beyond one read
of the strategy block and one write of the three result vectors.

Semantics are exactly ``core.cost_model.evaluate`` (same group/streaming/
weight-wave rules); the oracle used in tests is ``core.ref_model``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.accel import AccelConfig

__all__ = ["fusion_eval_population"]

_UTIL_MIN = 1.0 / 4096.0


def _fe_kernel(strat_ref, A_ref, W_ref, F_ref, OE_ref, UC_ref, SKIP_ref,
               lat_ref, peak_ref, traf_ref, *, P: int, n: int, batch: float,
               hw: AccelConfig):
    bp = strat_ref.shape[0]
    B = jnp.float32(batch)
    strat = strat_ref[...].astype(jnp.float32)           # [bp, P]

    A = A_ref[...][0]                                     # [P]
    W = W_ref[...][0]
    F = F_ref[...][0]
    OE = OE_ref[...][0]
    UC = UC_ref[...][0]
    SKIP = SKIP_ref[...][0]

    peak_macs = jnp.float32(hw.npe * hw.pe_lanes * hw.freq_hz)

    def util(mbe, oe, uc):
        return jnp.clip(mbe * oe / (hw.npe * hw.pe_lanes), _UTIL_MIN, uc)

    zeros = jnp.zeros((bp,), jnp.float32)

    def flush(st):
        (lat, peak, traf, g_comp, g_traf, g_on, g_mem, g_waves, g_len,
         alt) = st
        use_alt = g_len == 1.0
        comp = jnp.where(use_alt, alt["comp"], g_comp)
        trf = jnp.where(use_alt, alt["traf"], g_traf)
        onc = jnp.where(use_alt, alt["on"], g_on)
        mem = jnp.where(use_alt, alt["mem"], g_mem)
        wav = jnp.where(use_alt, 1.0, g_waves)
        lg = jnp.maximum(jnp.maximum(comp, trf / hw.bw_offchip),
                         onc / hw.bw_onchip) + wav * hw.t_pass + hw.t_sync
        nonempty = g_len > 0.0
        lat = lat + jnp.where(nonempty, lg, 0.0)
        peak = jnp.maximum(peak, jnp.where(nonempty, mem, 0.0))
        traf = traf + jnp.where(nonempty, trf, 0.0)
        return lat, peak, traf

    def body(i, carry):
        (lat, peak, traf, g_comp, g_traf, g_on, g_mem, g_waves, g_len,
         prev_sync, prev_mb, lastb) = carry
        a = strat[:, i]
        Ai = A[i]; Ap = A[i - 1]; Wi = W[i]; Fi = F[i]
        OEi = OE[i]; UCi = UC[i]
        src = SKIP[i]
        sync = a < 0.0
        mb = jnp.clip(a, 1.0, B)
        mbe = jnp.where(sync, jnp.where(prev_sync, 1.0, prev_mb), mb)
        stage = jnp.where(sync, 1.0, mb)
        head = (g_len == 0.0)

        has_skip = src >= 0
        same = has_skip & (src.astype(jnp.float32) > lastb)
        Asrc = A[jnp.maximum(src, 0)]
        hold = jnp.where(same, mbe * Asrc, 0.0)
        cross_t = jnp.where(has_skip & ~same, 2.0 * B * Asrc, 0.0)

        is_tail = sync | (i == n)
        waves = jnp.ceil(B / mbe)
        mem_i = stage * Ai + jnp.where(head, mbe * Ap, 0.0) + hold
        traf_i = (jnp.where(head, B * Ap, 0.0)
                  + jnp.where(is_tail, B * Ai, 0.0) + Wi * waves + cross_t)
        comp_i = B * Fi / peak_macs / util(mbe, OEi, UCi)
        on_i = B * (Ap + Ai) + Wi * waves

        # streaming alternative (used when this layer ends up alone)
        hold_a = jnp.where(same, B * Asrc, 0.0)
        mem_a = jnp.minimum(stage * Ai + B * Ap + hold_a,
                            jnp.float32(hw.stream_buf_bytes))
        alt = {"comp": B * Fi / peak_macs / util(jnp.float32(B), OEi, UCi),
               "traf": B * Ap + B * Ai + Wi + cross_t,
               "on": B * (Ap + Ai) + Wi,
               "mem": mem_a}

        g_comp += comp_i; g_traf += traf_i; g_on += on_i
        g_mem += mem_i; g_waves += waves; g_len += 1.0

        st = (lat, peak, traf, g_comp, g_traf, g_on, g_mem, g_waves, g_len,
              alt)
        latf, peakf, traff = flush(st)
        do_flush = is_tail
        lat = jnp.where(do_flush, latf, lat)
        peak = jnp.where(do_flush, peakf, peak)
        traf = jnp.where(do_flush, traff, traf)
        rz = lambda x: jnp.where(do_flush, zeros, x)
        g_comp, g_traf, g_on = rz(g_comp), rz(g_traf), rz(g_on)
        g_mem, g_waves, g_len = rz(g_mem), rz(g_waves), rz(g_len)
        lastb = jnp.where(sync, jnp.full((bp,), jnp.float32(i)), lastb)
        return (lat, peak, traf, g_comp, g_traf, g_on, g_mem, g_waves,
                g_len, sync, mb, lastb)

    init = (zeros, zeros, zeros, zeros, zeros, zeros, zeros, zeros, zeros,
            jnp.zeros((bp,), bool), jnp.clip(strat[:, 0], 1.0, B),
            jnp.full((bp,), -1.0, jnp.float32))
    out = jax.lax.fori_loop(1, n + 1, body, init)
    lat_ref[...] = out[0][:, None]
    peak_ref[...] = out[1][:, None]
    traf_ref[...] = out[2][:, None]


def fusion_eval_population(strategies, wl: dict, *, batch: float,
                           hw: AccelConfig, n: int | None = None,
                           bp: int = 128, interpret: bool | None = None):
    """strategies [pop, P] int32; wl = cost_model.pack_workload arrays.
    Returns (latency [pop], peak_mem [pop], traffic [pop])."""
    import numpy as _np
    if n is None:
        n = int(_np.asarray(wl["n"]))
    wl2 = {k: v for k, v in wl.items() if k != "n"}
    return _fusion_eval_jit(jnp.asarray(strategies), wl2, batch=float(batch),
                            hw=hw, n=n, bp=bp, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("batch", "hw", "bp", "n",
                                             "interpret"))
def _fusion_eval_jit(strategies: jax.Array, wl: dict, *, batch: float,
                     hw: AccelConfig, n: int, bp: int = 128,
                     interpret: bool | None = None):
    pop, P = strategies.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    pad = (-pop) % bp
    if pad:
        strategies = jnp.pad(strategies, ((0, pad), (0, 0)),
                             constant_values=-1)
    npop = strategies.shape[0]
    row = lambda k, dt: wl[k].astype(dt).reshape(1, P)
    args = (strategies, row("A", jnp.float32), row("W", jnp.float32),
            row("F", jnp.float32), row("OE", jnp.float32),
            row("UC", jnp.float32), row("SKIP", jnp.int32))

    lat, peak, traf = pl.pallas_call(
        functools.partial(_fe_kernel, P=P, n=n, batch=float(batch), hw=hw),
        grid=(npop // bp,),
        in_specs=[pl.BlockSpec((bp, P), lambda g: (g, 0))]
        + [pl.BlockSpec((1, P), lambda g: (0, 0))] * 6,
        out_specs=[pl.BlockSpec((bp, 1), lambda g: (g, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((npop, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(*args)
    return lat[:pop, 0], peak[:pop, 0], traf[:pop, 0]
