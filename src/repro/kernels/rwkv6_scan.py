"""Chunked WKV6 recurrence (TPU Pallas) — RWKV6's data-dependent-decay scan.

Grid = (B*H, T/chunk) with the CHUNK dimension iterated sequentially
(innermost TPU grid dim): the running state S [n, n] lives in VMEM scratch
and persists across chunk steps, so the whole sequence is processed with
one kernel launch and zero HBM state traffic — the TPU-native replacement
for the GPU per-timestep CUDA kernel RWKV ships.  Within a chunk the
recurrence is closed-form (GLA-style, see nn/rwkv.py::wkv_chunked):
    y = (r*cumw_prev) @ S + ((r~ k~^T) . causal) @ v + (r.u.k) v
    S' = cumw_C * S + (cumw_C/cumw)k ^T v
so the MXU does chunk x chunk and chunk x n matmuls instead of T sequential
rank-1 updates.  Oracle: ``nn.rwkv.wkv_scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, chunk: int, n: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)            # [chunk, n]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)            # [1, n]
    s = s_scr[...]                                # [n, n]

    lw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.exp(jnp.cumsum(lw, axis=0))         # [chunk, n]
    cum_prev = cum / w
    rt = r * cum_prev
    kt = k / jnp.maximum(cum, 1e-30)

    inter = rt @ s                                # [chunk, n]
    scores = rt @ kt.T                            # [chunk, chunk]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)      # strictly causal
    diag = jnp.sum(r * u * k, axis=-1)            # [chunk]
    y = inter + scores @ v + diag[:, None] * v

    cend = cum[-1]                                # [n]
    s_new = cend[:, None] * s + ((cend[None, :] / jnp.maximum(cum, 1e-30))
                                 * k).T @ v
    s_scr[...] = s_new
    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        sT_ref[...] = s_new.astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: jax.Array, *, chunk: int = 64,
         interpret: bool | None = None):
    """r,k,v,w [B,T,H,n]; u [H,n]; s0 [B,H,n,n] -> (y [B,T,H,n], sT)."""
    B, T, H, n = r.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nc = -(-T // chunk)
    pad = nc * chunk - T
    def prep(x, val=0.0):
        x = x.astype(jnp.float32)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=val)
        return x.transpose(0, 2, 1, 3).reshape(B * H, nc * chunk, n)
    rp, kp, vp = prep(r), prep(k), prep(v)
    wp = prep(w, 1.0)
    uu = jnp.broadcast_to(u.astype(jnp.float32)[None], (B, H, n)
                          ).reshape(B * H, 1, n)
    s0r = s0.astype(jnp.float32).reshape(B * H, n, n)

    y, sT = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n=n, nc=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1, n), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((None, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nc * chunk, n), jnp.float32),
            jax.ShapeDtypeStruct((B * H, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, wp, uu, s0r)

    y = y.reshape(B, H, nc * chunk, n)[:, :, :T].transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, n, n)
