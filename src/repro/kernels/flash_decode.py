"""Flash decoding (TPU Pallas): split-K attention over a long KV cache.

One query token per sequence attends to a cache of length T.  Phase 1
(kernel): grid = (B, Hq, T/bk splits); each program reduces its KV split
with a local softmax, emitting (o_partial, m, l) — the FlashDecoding++
split-K scheme, which keeps all splits parallel across the grid instead of
serializing a single long reduction.  Phase 2 (jnp): the per-split partials
are combined with the standard online-softmax merge.  ``kv_len`` masks the
unwritten tail of the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_decode"]

NEG_INF = -1e30


def _fd_kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, m_ref, l_ref, *,
               bk: int):
    s_idx = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)            # [1, hd]
    k = k_ref[...].astype(jnp.float32)            # [bk, hd]
    v = v_ref[...].astype(jnp.float32)
    kv_len = kvlen_ref[0]
    hd = q.shape[-1]
    s = (q @ k.T) / (hd ** 0.5)                   # [1, bk]
    ids = s_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(ids < kv_len, s, NEG_INF)
    m = s.max(-1)                                 # [1]
    p = jnp.exp(s - m[:, None])
    l = p.sum(-1)
    o_ref[...] = (p @ v).astype(o_ref.dtype)      # unnormalized partial
    m_ref[...] = m.astype(m_ref.dtype)
    l_ref[...] = l.astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array, *, bk: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """q [B,1,Hq,hd], k/v cache [B,T,Hkv,hd], kv_len scalar -> [B,1,Hq*hd]."""
    B, _, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    # clamp the split size to the cache and pad the cache to a whole number
    # of splits: a bk that does not divide T must never silently drop tail
    # keys (serving caches are 3*max_steps, rarely a multiple of 512).  The
    # padded tail is masked by the ids < kv_len test in the kernel.
    bk = max(1, min(bk, T))
    pad = (-T) % bk
    ns = (T + pad) // bk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    qt = q.reshape(B, Hq, 1, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if pad:
        zpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        kt = jnp.pad(kt, zpad)
        vt = jnp.pad(vt, zpad)
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(1), (1,))

    o, m, l = pl.pallas_call(
        functools.partial(_fd_kernel, bk=bk),
        grid=(B, Hq, ns),
        in_specs=[
            pl.BlockSpec((None, None, 1, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, s: (b, h // G, s, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, s: (b, h // G, s, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((None, None, None, 1, hd),
                         lambda b, h, s: (b, h, s, 0, 0)),
            pl.BlockSpec((None, None, None, 1),
                         lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((None, None, None, 1),
                         lambda b, h, s: (b, h, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, ns, 1, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, ns, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, ns, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, kvl)

    # phase 2: merge the split-K partials (online-softmax combine)
    m = m[..., 0]                                  # [B,Hq,ns]
    l = l[..., 0]
    mg = m.max(-1, keepdims=True)
    w = jnp.exp(m - mg) * l
    denom = w.sum(-1)
    o = (o[..., 0, :] * jnp.exp(m - mg)[..., None]).sum(2) \
        / jnp.maximum(denom, 1e-30)[..., None]
    return o.reshape(B, 1, Hq * hd).astype(q.dtype)
