"""Pure-jnp oracles for every Pallas kernel (the ``ref`` layer).

Each function is the semantic ground truth its kernel is tested against;
they intentionally use naive formulations (full score matrices, sequential
scans, vmapped cost model) so divergence localizes to the kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import cost_model as _cm
from ..nn.attention import attend as _attend
from ..nn.rwkv import wkv_scan as _wkv_scan

__all__ = ["attention_ref", "decode_ref", "wkv6_ref", "fusion_eval_ref",
           "fusion_eval_grid_ref"]


def attention_ref(q, k, v, *, causal=True, window=-1):
    """Dense attention oracle: [B,S,Hq,hd] x [B,T,Hkv,hd] -> [B,S,Hq*hd]."""
    return _attend(q, k, v, causal=causal, window=window, impl="xla",
                   q_chunk=1 << 30)


def decode_ref(q, k, v, kv_len):
    """One-token decode oracle over a cache prefix of ``kv_len``."""
    return _attend(q, k, v, causal=True, q_offset=kv_len - 1, kv_len=kv_len,
                   impl="xla", q_chunk=1 << 30)


def wkv6_ref(r, k, v, w, u, s0):
    """Sequential WKV6 recurrence oracle."""
    return _wkv_scan(r, k, v, w, u, s0)


def fusion_eval_ref(strategies, wl, *, batch, budget_bytes, hw):
    """Vmapped analytical cost model, CostOut [pop] (itself cross-checked
    against the loop-based ``core.ref_model`` in tests/test_cost_model.py).
    ``hw`` may be an AccelConfig or a traced ``accel.HwVec`` — the §11/§13
    contract the kernel shares: pack-time ``wl["BPE"]`` A/W bytes rescale
    to the serving accelerator happens in-graph."""
    return _cm.evaluate_population(wl, jnp.asarray(strategies),
                                   jnp.asarray(batch, jnp.float32),
                                   jnp.asarray(budget_bytes, jnp.float32),
                                   hw, evaluator="xla")


def fusion_eval_grid_ref(wls, strategies, batches, budgets, hw):
    """Grid oracle: ``cost_model.evaluate_grid_stats`` pinned to the XLA
    backend — ``(CostOut [C, POP], gid [C, POP, P], M_g [C, POP, P])``,
    the contract ``fusion_eval_grid_stats`` must reproduce bit-for-bit on
    CPU (DESIGN §13)."""
    return _cm.evaluate_grid_stats(wls, jnp.asarray(strategies),
                                   jnp.asarray(batches),
                                   jnp.asarray(budgets), hw,
                                   evaluator="xla")
