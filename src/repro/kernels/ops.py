"""Public jit'd wrappers for the Pallas kernels (the ``ops`` layer).

Selection contract: the models call these when ``attn_impl="pallas"`` and
the cost model dispatches to them when ``evaluator="pallas"`` (DESIGN §13);
on the CPU container they execute with ``interpret=True`` (pure-Python
kernel body) which is how the per-kernel shape/dtype sweeps in
``tests/test_kernels.py`` validate them against ``ref.py``.
"""
from __future__ import annotations

from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .rwkv6_scan import wkv6
from .fusion_eval import (fusion_eval_population,
                          fusion_eval_population_stats,
                          fusion_eval_grid, fusion_eval_grid_stats)

__all__ = ["flash_attention", "flash_decode", "wkv6",
           "fusion_eval_population", "fusion_eval_population_stats",
           "fusion_eval_grid", "fusion_eval_grid_stats"]
