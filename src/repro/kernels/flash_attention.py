"""Flash attention (TPU Pallas): blocked online-softmax, causal + sliding
window + GQA.

Tiling: grid = (batch, q_heads, S/bq); each program holds one (bq, hd) query
block in VMEM, loops over (bk, hd) KV blocks of its kv-head with the online
softmax recurrence (m, l, acc in VMEM scratch), and writes one output block.
GQA is expressed in the kv BlockSpec index_map (q-head h reads kv-head
h // group).  MXU alignment: bq/bk multiples of the 128 lane width; hd is
the natural minor dim.  Causality/window prune whole KV blocks via the
loop's upper bound.  Validated on CPU with interpret=True against
``ref.attention_ref`` (see tests/test_kernels.py); on TPU it is selected by
``attn_impl=pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq_k: int,
               causal: bool, window: int, sm_scale: float):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * sm_scale          # [bq, hd]
    nkv = seq_k // bk

    # block-level pruning bounds
    q_lo = qi * bq
    q_hi = q_lo + bq - 1
    if causal:
        hi = jnp.minimum(nkv, (q_hi // bk) + 1)
    else:
        hi = nkv
    if window > 0:
        lo = jnp.maximum(0, (q_lo - window + 1) // bk)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)                   # [bk, hd]
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = q @ k.T                                         # [bq, bk]
        ids_q = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ids_k = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= ids_k <= ids_q
        if window > 0:
            mask &= (ids_q - ids_k) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = -1, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None) -> jax.Array:
    """q [B,S,Hq,hd], k/v [B,T,Hkv,hd] -> [B,S,Hq*hd].

    S and T must be multiples of bq/bk (the launchers pad); ``window`` is a
    *static* int here (the XLA path accepts traced windows; kernels are
    specialized per window value).
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    sm_scale = 1.0 / (hd ** 0.5)

    qt = q.transpose(0, 2, 1, 3)        # [B, Hq, S, hd]
    kt = k.transpose(0, 2, 1, 3)        # [B, Hkv, T, hd]
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, Hq, S // bq)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, bq=bq, bk=bk, seq_k=T, causal=causal,
                          window=int(window), sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, T, hd),
                         lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((None, None, T, hd),
                         lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
