"""TPU Pallas kernels for the framework's compute hot-spots.

 - flash_attention: prefill/train attention (blocked online softmax,
   causal/window/GQA) — DESIGN §7
 - flash_decode:    split-K decode over long KV caches
 - rwkv6_scan:      chunked data-dependent-decay WKV6 recurrence
 - fusion_eval:     the paper's hot loop — fusion-strategy evaluation over
   a (workload x accel x budget) condition grid with the layer table
   VMEM-resident; the production ``evaluator="pallas"`` backend of
   ``cost_model.evaluate_grid`` — DESIGN §13

Structure per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd public wrappers), ``ref.py`` (pure-jnp oracles).  On this
CPU container kernels execute with ``interpret=True``; on TPU the models
select them via ``attn_impl=pallas`` / the rwkv impl switch and the cost
model via its ``evaluator`` kwarg.
"""
from . import ops, ref
from .ops import (flash_attention, flash_decode, wkv6,
                  fusion_eval_population, fusion_eval_population_stats,
                  fusion_eval_grid, fusion_eval_grid_stats)

__all__ = ["ops", "ref", "flash_attention", "flash_decode", "wkv6",
           "fusion_eval_population", "fusion_eval_population_stats",
           "fusion_eval_grid", "fusion_eval_grid_stats"]
