"""Perf-regression harness for the layered serving engine (DESIGN.md §12).

Open-loop synthetic load over MIXED (network, batch, budget, accelerator)
requests — the production shape the engine exists for: heterogeneous
networks in one device call, pow2/nmax shape bucketing, in-tick dedup and
a solved-strategy LRU.  Two servers answer the SAME deterministic stream:

 - ``engine``: ``serving.MapperEngine`` — warmup once, then serve arrival
   ticks; reports throughput, p50/p99 per-tick latency, compile and
   strategy-cache counters.  Steady state MUST be zero-recompile.
 - ``loop``:   the pre-§12 front door — one ``FusionEnv`` +
   ``dnnfuser_infer_fused`` call per request (post-jit; the loop reuses
   the same bucketed shapes so it never recompiles either).

The stream draws budgets from a quantized grid and repeats conditions the
way user traffic does, so the strategy cache sees realistic hit rates;
``--zipf 0`` makes every condition distinct (cold cache) if you want the
pure batching win.

``--check BASELINE.json`` turns the harness into the CI gate (like
``bench_infer``): fails on engine-latency regression beyond ``--tol`` x
baseline, on ANY steady-state recompile, and on the engine losing its
throughput edge over the per-request loop (``--min-speedup``).

    PYTHONPATH=src python benchmarks/bench_serving.py [--quick] [--out P]
        [--check BASELINE.json] [--tol 2.5] [--min-speedup 1.3]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, DTConfig, FusionEnv, HW_FEATURE_DIM,
                        MapperEngine, MapRequest, dnnfuser_infer_fused,
                        dt_init)
from repro.serving import nmax_bucket
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = float(2 ** 20)


def make_stream(n_requests: int, zipf: float, seed: int = 0):
    """Deterministic mixed request stream.

    Conditions are drawn from a finite grid (3 networks x 3 accels x 3
    batches x 12 budgets); ``zipf`` > 0 skews the draw so popular
    conditions repeat (heavy-tailed traffic), 0 draws uniformly."""
    rng = np.random.default_rng(seed)
    nets = [vgg16(), resnet18(), tiny_cnn()]
    accs = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"], ACCEL_ZOO["laptop"]]
    batches = [16, 32, 64]
    budgets = np.linspace(6.0, 48.0, 12) * MB
    grid = [(w, a, b, m) for w in nets for a in accs for b in batches
            for m in budgets]
    if zipf > 0:
        p = 1.0 / np.arange(1, len(grid) + 1) ** zipf
        p /= p.sum()
        order = rng.permutation(len(grid))      # popularity != grid order
        idx = order[rng.choice(len(grid), size=n_requests, p=p)]
    else:
        idx = rng.integers(0, len(grid), size=n_requests)
    return [MapRequest(grid[i][0], grid[i][2], float(grid[i][3]), grid[i][1])
            for i in idx]


def bench_engine(params, cfg, stream, tick: int) -> dict:
    engine = MapperEngine(params, cfg)
    t0 = time.perf_counter()
    nets = {r.workload.name: r.workload for r in stream}
    warmup_compiles = engine.warmup(list(nets.values()),
                                    ACCEL_ZOO["edge"], max_tick=tick)
    warmup_s = time.perf_counter() - t0
    compiles_before = engine.compile_count
    tick_ms = []
    t0 = time.perf_counter()
    for i in range(0, len(stream), tick):
        t1 = time.perf_counter()
        engine.serve(stream[i:i + tick])
        tick_ms.append((time.perf_counter() - t1) * 1e3)
    total = time.perf_counter() - t0
    stats = engine.stats
    return {
        "throughput_rps": len(stream) / total,
        "ms_per_request": total * 1e3 / len(stream),
        "p50_tick_ms": float(np.percentile(tick_ms, 50)),
        "p99_tick_ms": float(np.percentile(tick_ms, 99)),
        "warmup_s": warmup_s,
        "warmup_compiles": warmup_compiles,
        "steady_new_compiles": engine.compile_count - compiles_before,
        "device_calls": stats["device_calls"],
        "strategy_hit_rate": stats["strategy_hit_rate"],
        "tick_dedup": stats["tick_dedup"],
        "rows_padded": stats["rows_padded"],
    }


def bench_loop(params, cfg, stream, nmax_buckets) -> dict:
    """The pre-§12 front door: one env + one fused call per request."""
    seen = set()                                 # warm each nmax shape once
    for r in stream:
        nb = nmax_bucket(r.workload.n + 1, nmax_buckets)
        if nb not in seen:
            seen.add(nb)
            env = FusionEnv(r.workload, r.accel, batch=r.batch,
                            budget_bytes=r.budget_bytes, nmax=nb)
            dnnfuser_infer_fused(params, cfg, env)
    t0 = time.perf_counter()
    for r in stream:
        env = FusionEnv(r.workload, r.accel, batch=r.batch,
                        budget_bytes=r.budget_bytes,
                        nmax=nmax_bucket(r.workload.n + 1, nmax_buckets))
        dnnfuser_infer_fused(params, cfg, env)
    total = time.perf_counter() - t0
    return {"throughput_rps": len(stream) / total,
            "ms_per_request": total * 1e3 / len(stream)}


def run(quick: bool = False, out: str = "BENCH_serve.json",
        zipf: float = 1.1) -> dict:
    cfg = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    n_requests = 96 if quick else 512
    tick = 16
    stream = make_stream(n_requests, zipf)
    engine = bench_engine(params, cfg, stream, tick)
    loop = bench_loop(params, cfg, stream,
                      MapperEngine(params, cfg).nmax_buckets)
    speedup = engine["throughput_rps"] / loop["throughput_rps"]
    print(f"engine: {engine['throughput_rps']:7.1f} req/s "
          f"(p50 tick {engine['p50_tick_ms']:.1f} ms, p99 "
          f"{engine['p99_tick_ms']:.1f} ms, hit rate "
          f"{engine['strategy_hit_rate']:.2f}, "
          f"{engine['steady_new_compiles']} steady-state compiles)")
    print(f"loop:   {loop['throughput_rps']:7.1f} req/s  ->  engine is "
          f"{speedup:.1f}x the per-request loop")
    report = {
        "bench": "serving",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "n_requests": n_requests,
        "tick": tick,
        "zipf": zipf,
        "engine": engine,
        "loop": loop,
        "speedup_vs_loop": speedup,
    }
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")
    return report


def check_regression(report: dict, baseline_path: str, tol: float,
                     min_speedup: float) -> list:
    """Gate rules (empty list = pass): same quick mode as the baseline;
    zero steady-state recompiles; engine latency within ``tol`` x the
    committed baseline; engine still >= ``min_speedup`` x the per-request
    loop ON THIS machine (a machine-relative ratio, so CI hardware speed
    cancels out)."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    failures = []
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline"]
    if report["engine"]["steady_new_compiles"] != 0:
        failures.append(
            f"steady-state recompiles: "
            f"{report['engine']['steady_new_compiles']} (must be 0)")
    new = report["engine"]["ms_per_request"]
    old = base.get("engine", {}).get("ms_per_request")
    if old is None:
        failures.append(f"baseline {baseline_path} has no "
                        f"engine.ms_per_request — regenerate it")
    elif new > old * tol:
        failures.append(f"engine.ms_per_request: {new:.2f} > {tol:.1f}x "
                        f"baseline {old:.2f}")
    if report["speedup_vs_loop"] < min_speedup:
        failures.append(f"engine is only {report['speedup_vs_loop']:.2f}x "
                        f"the per-request loop (gate: {min_speedup:.1f}x)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized stream (same protocol)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="traffic skew (0 = uniform/cold-cache)")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) on regression vs this baseline")
    ap.add_argument("--tol", type=float, default=2.5,
                    help="allowed latency ratio vs the baseline")
    ap.add_argument("--min-speedup", type=float, default=1.3,
                    help="required engine-vs-loop throughput ratio")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_serve_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    report = run(quick=args.quick, out=args.out, zipf=args.zipf)
    if args.check:
        failures = check_regression(report, args.check, args.tol,
                                    args.min_speedup)
        if failures:
            print("SERVING REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"serving gate OK (tol {args.tol}x, min speedup "
              f"{args.min_speedup}x vs {args.check})")


if __name__ == "__main__":
    main()
