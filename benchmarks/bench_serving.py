"""Perf-regression harness for the async serving stack (DESIGN.md §12, §14).

Open-loop synthetic load over MIXED (network, batch, budget, accelerator)
requests with a seeded Zipf-burst ARRIVAL PROCESS — requests carry
timestamps, the ``AsyncMapperScheduler`` forms ticks continuously
(width- and deadline-triggered), and end-to-end (enqueue -> response)
p50/p99 latency is measured in simulated time with real measured device
service times (no coordinated omission).  Four measurements:

 - ``loop``:        the pre-§12 front door — one ``FusionEnv`` + one
   fused call per request (post-jit).  The machine-speed anchor: every
   throughput gate below is a RATIO against this number, so CI hardware
   cancels out.
 - ``engine_cold``: async scheduler + engine, empty strategy cache.
   Steady state MUST be zero-recompile.
 - ``engine_warm``: the production restart path — ``--priors`` earlier
   request streams (different seeds, SAME fixed condition-popularity
   head) are served by a builder engine and persisted
   (``StrategyCache.save``); a FRESH engine loads the file read-through
   and serves the benchmark stream.  The Zipf head resolves at submit
   from the shared cache; only the unseen tail does device work.  This
   is the cross-process round trip the §14 persistence contract gates.
 - ``replica_curve``: data-parallel replicas over
   ``--xla_force_host_platform_device_count`` virtual devices (pass
   ``--devices N`` BEFORE jax initializes, or export XLA_FLAGS).  On the
   one-core CI host virtual devices add no compute, so the gate is a
   lenient per-replica-count throughput RATIO vs replicas=1 (no
   regression from sharding machinery) — on real multi-device hardware
   the same curve shows the device-bound miss path scaling.

``--check BASELINE.json`` turns the harness into the CI gate: fails on
cold-latency regression beyond ``--tol`` x baseline, ANY steady-state
recompile (cold, warm, or any replica count), a broken persistence round
trip, the engine losing its edge over the loop (``--min-speedup``), the
warm path losing its edge (``--min-warm-vs-loop``, the machine-relative
encoding of the PR headline "warm >= 4x the old engine throughput"), or
replica overhead (``--min-replica-ratio``).

    PYTHONPATH=src python benchmarks/bench_serving.py [--quick] [--out P]
        [--devices N] [--priors W] [--check BASELINE.json] [--tol 2.5]
        [--min-speedup 1.3] [--min-warm-vs-loop 6.0]
        [--min-replica-ratio 0.4]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

# --devices must land in XLA_FLAGS before jax initializes its backend;
# honor a pre-set --xla_force_host_platform_device_count (the CI job
# exports one) and only inject when the flag is absent.
if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count={int(_n)}"
        ).strip()

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, DTConfig, FusionEnv, HW_FEATURE_DIM,
                        MapperEngine, MapRequest, dnnfuser_infer_fused,
                        dt_init)
from repro.serving import AsyncMapperScheduler, nmax_bucket
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = float(2 ** 20)

# PR4's committed engine throughput on the reference container — kept as
# an informational ratio in the report; the CI gate uses the
# machine-relative --min-warm-vs-loop instead.
PR4_ENGINE_RPS = 218.295


def make_stream(n_requests: int, zipf: float, seed: int = 0):
    """Deterministic mixed request stream.

    Conditions are drawn from a finite grid (3 networks x 3 accels x 3
    batches x 12 budgets); ``zipf`` > 0 skews the draw so popular
    conditions repeat (heavy-tailed traffic), 0 draws uniformly.  WHICH
    conditions are popular is a FIXED permutation independent of
    ``seed`` — different seeds are different days of traffic against the
    same user base, which is what makes warming a persistent cache from
    prior streams meaningful."""
    rng = np.random.default_rng(seed)
    nets = [vgg16(), resnet18(), tiny_cnn()]
    accs = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"], ACCEL_ZOO["laptop"]]
    batches = [16, 32, 64]
    budgets = np.linspace(6.0, 48.0, 12) * MB
    grid = [(w, a, b, m) for w in nets for a in accs for b in batches
            for m in budgets]
    if zipf > 0:
        p = 1.0 / np.arange(1, len(grid) + 1) ** zipf
        p /= p.sum()
        popularity = np.random.default_rng(4242).permutation(len(grid))
        idx = popularity[rng.choice(len(grid), size=n_requests, p=p)]
    else:
        idx = rng.integers(0, len(grid), size=n_requests)
    return [MapRequest(grid[i][0], grid[i][2], float(grid[i][3]), grid[i][1])
            for i in idx]


def make_arrivals(n: int, rate_rps: float, seed: int = 0) -> list:
    """Seeded bursty arrival timestamps: Zipf-sized bursts (heavy-tailed
    cluster sizes, capped) arriving at exponential gaps sized to an
    average of ``rate_rps`` — the arrival process the p50/p99 end-to-end
    numbers are quoted under."""
    rng = np.random.default_rng(seed + 7)
    t, out = 0.0, []
    while len(out) < n:
        burst = min(int(rng.zipf(2.0)), 8)
        out.extend([t] * min(burst, n - len(out)))
        t += float(rng.exponential(burst / rate_rps))
    return out


def bench_loop(params, cfg, stream, nmax_buckets) -> dict:
    """The pre-§12 front door: one env + one fused call per request."""
    seen = set()                                 # warm each nmax shape once
    for r in stream:
        nb = nmax_bucket(r.workload.n + 1, nmax_buckets)
        if nb not in seen:
            seen.add(nb)
            env = FusionEnv(r.workload, r.accel, batch=r.batch,
                            budget_bytes=r.budget_bytes, nmax=nb)
            dnnfuser_infer_fused(params, cfg, env)
    t0 = time.perf_counter()
    for r in stream:
        env = FusionEnv(r.workload, r.accel, batch=r.batch,
                        budget_bytes=r.budget_bytes,
                        nmax=nmax_bucket(r.workload.n + 1, nmax_buckets))
        dnnfuser_infer_fused(params, cfg, env)
    total = time.perf_counter() - t0
    return {"throughput_rps": len(stream) / total,
            "ms_per_request": total * 1e3 / len(stream)}


def bench_engine_async(params, cfg, stream, arrivals, *, tick: int,
                       flush_ms: float, cache_path=None,
                       replicas=None) -> tuple:
    """One async serving run: warmup, then submit/pump the timestamped
    stream through the scheduler.  Returns (report dict, engine)."""
    engine = MapperEngine(params, cfg, max_coalesce=tick,
                          cache_path=cache_path, replicas=replicas)
    nets = {r.workload.name: r.workload for r in stream}
    t0 = time.perf_counter()
    warmup_compiles = engine.warmup(list(nets.values()), ACCEL_ZOO["edge"],
                                    max_tick=tick)
    warmup_s = time.perf_counter() - t0
    compiles_before = engine.compile_count
    sched = AsyncMapperScheduler(engine, flush_ms=flush_ms, max_wave=tick)
    futs = []
    t0 = time.perf_counter()
    for req, t in zip(stream, arrivals):
        futs.append(sched.submit(req, now=t))
        sched.pump(now=t)
    sched.drain(now=arrivals[-1])
    wall = time.perf_counter() - t0
    lat_ms = np.asarray([f.latency_s for f in futs]) * 1e3
    stats = engine.stats()
    report = {
        "throughput_rps": len(stream) / wall,
        "ms_per_request": wall * 1e3 / len(stream),
        "e2e_p50_ms": float(np.percentile(lat_ms, 50)),
        "e2e_p99_ms": float(np.percentile(lat_ms, 99)),
        "warmup_s": warmup_s,
        "warmup_compiles": warmup_compiles,
        "steady_new_compiles": engine.compile_count - compiles_before,
        "device_calls": stats["device_calls"],
        "strategy_hit_rate": stats["strategy_hit_rate"],
        "shared_cache_hits": stats["strategy_cache"]["shared_hits"],
        "tick_dedup": stats["tick_dedup"],
        "rows_padded": stats["rows_padded"],
        "resolved_at_submit": stats["scheduler"]["resolved_at_submit"],
        "flushes": stats["scheduler"]["flushes"],
        "coalesce_width_hist": {str(k): v for k, v in
                                stats["coalesce_width_hist"].items()},
    }
    return report, engine


def build_warm_cache(params, cfg, priors: int, n_requests: int, zipf: float,
                     tick: int, cache_path) -> dict:
    """Serve ``priors`` earlier traffic streams (seeds 1..priors) through a
    builder engine and persist the merged strategy cache — the state a
    long-running deployment accumulates before a restart."""
    builder = MapperEngine(params, cfg, max_coalesce=tick)
    builder.warmup([vgg16(), resnet18(), tiny_cnn()], ACCEL_ZOO["edge"],
                   max_tick=tick)
    t0 = time.perf_counter()
    for seed in range(1, priors + 1):
        prior = make_stream(n_requests, zipf, seed=seed)
        for i in range(0, len(prior), tick):
            builder.serve(prior[i:i + tick])
    entries = builder.save_cache(cache_path)
    return {"priors": priors, "entries_saved": entries,
            "build_s": time.perf_counter() - t0}


def bench_replica_curve(params, cfg, counts, n_requests: int) -> list:
    """Cold device-bound scaling: an all-miss single-nmax stream (every
    condition unique — no cache, no dedup) served in full-width ticks at
    each replica count."""
    w = tiny_cnn()
    tick = 8
    reqs = [MapRequest(w, 1 + i % 4, (4.0 + 0.25 * i) * MB,
                       ACCEL_ZOO["edge"]) for i in range(n_requests)]
    curve = []
    for n in counts:
        engine = MapperEngine(params, cfg, max_coalesce=tick, replicas=n)
        engine.warmup([w], ACCEL_ZOO["edge"], max_tick=tick)
        before = engine.compile_count
        t0 = time.perf_counter()
        for i in range(0, len(reqs), tick):
            engine.serve(reqs[i:i + tick])
        wall = time.perf_counter() - t0
        entry = {"replicas": n,
                 "throughput_rps": len(reqs) / wall,
                 "steady_new_compiles": engine.compile_count - before}
        rs = engine.stats()["replicas"]
        entry["rows_per_replica"] = rs["rows_per_replica"]
        curve.append(entry)
    base = curve[0]["throughput_rps"]
    for entry in curve:
        entry["scaling_vs_1"] = entry["throughput_rps"] / base
    return curve


def run(quick: bool = False, out: str = "BENCH_serve.json",
        zipf: float = 1.1, rate_rps: float = 1000.0, flush_ms: float = 50.0,
        priors: int = 12) -> dict:
    cfg = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    n_requests = 96 if quick else 512
    tick = 16
    stream = make_stream(n_requests, zipf)
    arrivals = make_arrivals(n_requests, rate_rps)

    loop = bench_loop(params, cfg, stream,
                      MapperEngine(params, cfg).nmax_buckets)
    print(f"loop:        {loop['throughput_rps']:7.1f} req/s")

    cold, _ = bench_engine_async(params, cfg, stream, arrivals, tick=tick,
                                 flush_ms=flush_ms)
    print(f"engine cold: {cold['throughput_rps']:7.1f} req/s "
          f"(e2e p50 {cold['e2e_p50_ms']:.1f} ms, p99 "
          f"{cold['e2e_p99_ms']:.1f} ms, hit rate "
          f"{cold['strategy_hit_rate']:.2f}, "
          f"{cold['steady_new_compiles']} steady compiles)")

    cache_path = pathlib.Path("artifacts/bench/strategy_cache.json")
    cache_path.parent.mkdir(parents=True, exist_ok=True)
    if cache_path.exists():
        cache_path.unlink()                      # a real cold->warm cycle
    warm_cache = build_warm_cache(params, cfg, priors, n_requests, zipf,
                                  tick, cache_path)
    warm, warm_eng = bench_engine_async(params, cfg, stream, arrivals,
                                        tick=tick, flush_ms=flush_ms,
                                        cache_path=cache_path)
    warm_cache["entries_loaded"] = (
        len(json.loads(cache_path.read_text())["entries"])
        if warm_eng.strategies.loads else 0)
    warm_cache["save_load_roundtrip"] = bool(
        warm_cache["entries_saved"] > 0 and warm["shared_cache_hits"] > 0
        and warm["steady_new_compiles"] == 0)
    print(f"engine warm: {warm['throughput_rps']:7.1f} req/s "
          f"(e2e p50 {warm['e2e_p50_ms']:.1f} ms, p99 "
          f"{warm['e2e_p99_ms']:.1f} ms, hit rate "
          f"{warm['strategy_hit_rate']:.2f}, "
          f"{warm['resolved_at_submit']}/{n_requests} resolved at submit, "
          f"{warm['shared_cache_hits']} from the persisted cache)")

    avail = len(jax.devices())
    counts = [n for n in (1, 2, 4, 8) if n <= avail]
    curve = bench_replica_curve(params, cfg, counts,
                                32 if quick else 64)
    for entry in curve:
        print(f"replicas={entry['replicas']}: "
              f"{entry['throughput_rps']:7.1f} req/s "
              f"(x{entry['scaling_vs_1']:.2f} vs 1, "
              f"{entry['steady_new_compiles']} steady compiles)")

    report = {
        "bench": "serving",
        "device": jax.devices()[0].platform,
        "n_devices": avail,
        "quick": quick,
        "n_requests": n_requests,
        "tick": tick,
        "zipf": zipf,
        "rate_rps": rate_rps,
        "flush_ms": flush_ms,
        "loop": loop,
        "engine_cold": cold,
        "engine_warm": warm,
        "warm_cache": warm_cache,
        "replica_curve": curve,
        "speedup_vs_loop": cold["throughput_rps"] / loop["throughput_rps"],
        "warm_speedup_vs_loop": (warm["throughput_rps"] /
                                 loop["throughput_rps"]),
        "warm_speedup_vs_cold": (warm["throughput_rps"] /
                                 cold["throughput_rps"]),
        "pr4_engine_rps": PR4_ENGINE_RPS,
        "warm_speedup_vs_pr4": warm["throughput_rps"] / PR4_ENGINE_RPS,
    }
    print(f"cold is {report['speedup_vs_loop']:.1f}x the loop; warm is "
          f"{report['warm_speedup_vs_loop']:.1f}x the loop "
          f"({report['warm_speedup_vs_pr4']:.1f}x the PR4 reference rate)")
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")
    return report


def check_regression(report: dict, baseline_path: str, tol: float,
                     min_speedup: float, min_warm_vs_loop: float,
                     min_replica_ratio: float) -> list:
    """Gate rules (empty list = pass) — all throughput gates are ratios
    measured ON THIS machine, so CI hardware speed cancels out."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    failures = []
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline"]
    for phase in ("engine_cold", "engine_warm"):
        if report[phase]["steady_new_compiles"] != 0:
            failures.append(
                f"{phase} steady-state recompiles: "
                f"{report[phase]['steady_new_compiles']} (must be 0)")
    new = report["engine_cold"]["ms_per_request"]
    old = base.get("engine_cold", {}).get("ms_per_request")
    if old is None:
        failures.append(f"baseline {baseline_path} has no "
                        f"engine_cold.ms_per_request — regenerate it")
    elif new > old * tol:
        failures.append(f"engine_cold.ms_per_request: {new:.2f} > "
                        f"{tol:.1f}x baseline {old:.2f}")
    if report["speedup_vs_loop"] < min_speedup:
        failures.append(f"cold engine is only "
                        f"{report['speedup_vs_loop']:.2f}x the per-request "
                        f"loop (gate: {min_speedup:.1f}x)")
    if report["warm_speedup_vs_loop"] < min_warm_vs_loop:
        failures.append(f"warm engine is only "
                        f"{report['warm_speedup_vs_loop']:.2f}x the "
                        f"per-request loop (gate: {min_warm_vs_loop:.1f}x)")
    if not report["warm_cache"]["save_load_roundtrip"]:
        failures.append("strategy-cache save/load round trip failed: "
                        f"{report['warm_cache']} / shared hits "
                        f"{report['engine_warm']['shared_cache_hits']}")
    if report["engine_warm"]["strategy_hit_rate"] < 0.6:
        failures.append(f"warm hit rate "
                        f"{report['engine_warm']['strategy_hit_rate']:.2f} "
                        f"< 0.6 — the persisted cache is not covering the "
                        f"popularity head")
    for entry in report["replica_curve"]:
        if entry["steady_new_compiles"] != 0:
            failures.append(f"replicas={entry['replicas']}: "
                            f"{entry['steady_new_compiles']} steady-state "
                            f"recompiles (must be 0)")
        if entry["scaling_vs_1"] < min_replica_ratio:
            failures.append(f"replicas={entry['replicas']} throughput is "
                            f"only {entry['scaling_vs_1']:.2f}x replicas=1 "
                            f"(gate: {min_replica_ratio:.1f}x)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized stream (same protocol)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="traffic skew (0 = uniform/cold-cache)")
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="mean simulated arrival rate (req/s)")
    ap.add_argument("--flush-ms", type=float, default=50.0,
                    help="scheduler flush deadline")
    ap.add_argument("--priors", type=int, default=12,
                    help="prior traffic streams persisted before the warm "
                         "run")
    ap.add_argument("--devices", type=int,
                    help="force N virtual host devices (sets XLA_FLAGS "
                         "before jax init; ignored if already forced)")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) on regression vs this baseline")
    ap.add_argument("--tol", type=float, default=2.5,
                    help="allowed cold-latency ratio vs the baseline")
    ap.add_argument("--min-speedup", type=float, default=1.3,
                    help="required cold engine-vs-loop throughput ratio")
    ap.add_argument("--min-warm-vs-loop", type=float, default=6.0,
                    help="required warm engine-vs-loop throughput ratio")
    ap.add_argument("--min-replica-ratio", type=float, default=0.4,
                    help="required per-replica-count throughput ratio vs "
                         "replicas=1")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_serve_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    report = run(quick=args.quick, out=args.out, zipf=args.zipf,
                 rate_rps=args.rate, flush_ms=args.flush_ms,
                 priors=args.priors)
    if args.check:
        failures = check_regression(report, args.check, args.tol,
                                    args.min_speedup, args.min_warm_vs_loop,
                                    args.min_replica_ratio)
        if failures:
            print("SERVING REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"serving gate OK (tol {args.tol}x, cold >= "
              f"{args.min_speedup}x loop, warm >= {args.min_warm_vs_loop}x "
              f"loop, replicas >= {args.min_replica_ratio}x vs "
              f"{args.check})")


if __name__ == "__main__":
    main()
