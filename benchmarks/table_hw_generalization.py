"""Hardware-generalization benchmark (DESIGN.md §11; beyond-paper).

The paper's headline is a mapper that generalizes over *workload and
memory* conditions; this table extends the claim to the ACCELERATOR itself:
one checkpoint, trained with the hardware descriptor as a learned condition
(``DTConfig.hw_dim``), serves a whole device family — including a zoo
preset never seen in training — via ``dnnfuser_infer_batch`` with per-row
hw vectors, every accelerator of a workload in ONE device call.

Protocol
 - TRAIN accelerators: ``edge``, ``nano``, ``mobile`` (zoo presets);
   HELD-OUT: ``laptop`` — never in the teacher corpus.
 - teacher: ``generate_teacher_corpus`` over the full
   (workload x train-accel x budget) grid (one fused GA program);
 - student: one DNNFuser with an hw-condition embedding, trained once;
 - eval: for every (workload, budget) the mapper serves ALL accelerators
   (train + held-out) in one batched call; each row is
     * checked bit-exact against the host ``dnnfuser_infer`` reference on
       the same condition (the §9/§11 serving contract), and
     * compared to a fresh per-accelerator G-Sampler search — the
       per-device tool the hardware condition replaces.

Output: ``BENCH_hw.json`` with per-(accel, workload, budget) rows
{dt_speedup, dt_valid, teacher_speedup, ratio, parity, held_out} plus the
one-call serving latency.  ``ratio`` ~ 1.0 on the held-out accelerator is
the hardware-generalization claim.  ``--quick`` shrinks workloads, GA
budget and training steps to CI-smoke size (same protocol).

    PYTHONPATH=src python benchmarks/table_hw_generalization.py
        [--quick] [--out BENCH_hw.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, DTConfig, FusionEnv, GSamplerConfig,
                        HW_FEATURE_DIM, TrainConfig, dnnfuser_infer,
                        dnnfuser_infer_batch, dt_init, dt_loss,
                        generate_teacher_corpus, gsampler_search,
                        restore_params, train_model)
from repro.workloads import resnet18, tiny_cnn, vgg16

try:                                   # as a module (benchmarks.run) ...
    from .common import fmt_speedup, load_or
except ImportError:                    # ... or as a script
    from common import fmt_speedup, load_or

MB = float(2 ** 20)
TRAIN_ACCELS = ["edge", "nano", "mobile"]
HOLDOUT = "laptop"


def _setup(quick: bool) -> dict:
    if quick:
        return dict(workloads=[tiny_cnn()], budgets=[2.0, 6.0],
                    max_steps=16, steps=240,
                    ga=GSamplerConfig(population=16, generations=10, seed=0))
    return dict(workloads=[vgg16(), resnet18()], budgets=[16.0, 32.0, 48.0],
                max_steps=20, steps=600, ga=GSamplerConfig(seed=0))


def _train_mapper(su: dict, quick: bool):
    """Teacher corpus over the train-accel grid + ONE hw-conditioned
    student, checkpointed atomically and restored before serving (the
    served mapper is the on-disk checkpoint, not loop state); cached under
    artifacts/bench (delete to regenerate)."""
    cfg = DTConfig(max_steps=su["max_steps"], hw_dim=HW_FEATURE_DIM)
    accels = [ACCEL_ZOO[n] for n in TRAIN_ACCELS]
    mode = "quick" if quick else "full"
    ckpt_dir = pathlib.Path("artifacts/bench") / f"hwgen_ckpt_{mode}"

    def build():
        ds = generate_teacher_corpus(
            su["workloads"], accels, batch=64, budgets_mb=su["budgets"],
            max_steps=su["max_steps"], ga_cfg=su["ga"], top_k=6, seed=0)
        params = dt_init(jax.random.PRNGKey(0), cfg)
        params, log = train_model(
            lambda p, b: dt_loss(p, cfg, b), params, ds,
            TrainConfig(steps=su["steps"], batch_size=16,
                        warmup=min(50, su["steps"] // 5), seed=0),
            ckpt_dir=ckpt_dir, resume=False)
        params = restore_params(ckpt_dir, params)   # serve the checkpoint
        return {"params": jax.device_get(params),
                "final_loss": log["final_loss"], "n_traj": len(ds)}

    art = load_or(f"hwgen_{mode}", build)
    return art, cfg


def run(quick: bool = False, out: str = "BENCH_hw.json") -> list:
    su = _setup(quick)
    art, cfg = _train_mapper(su, quick)
    params = art["params"]
    accels = [ACCEL_ZOO[n] for n in TRAIN_ACCELS] + [ACCEL_ZOO[HOLDOUT]]
    print(f"mapper: {art['n_traj']} teacher trajectories "
          f"(accels {TRAIN_ACCELS}), imitation loss {art['final_loss']:.4f}; "
          f"held-out accelerator: {HOLDOUT}")

    rows, csv_rows = [], []
    for wl in su["workloads"]:
        conds = [(acc, b) for acc in accels for b in su["budgets"]]
        env0 = FusionEnv(wl, ACCEL_ZOO["edge"], batch=64,
                         budget_bytes=su["budgets"][0] * MB,
                         nmax=su["max_steps"])
        batches = np.full(len(conds), 64.0, np.float32)
        budgets = np.asarray([b * MB for _, b in conds], np.float32)
        hw_rows = [acc for acc, _ in conds]
        served = dnnfuser_infer_batch(params, cfg, env0, batches, budgets,
                                      hw_rows)                    # warm jit
        t0 = time.perf_counter()
        served = dnnfuser_infer_batch(params, cfg, env0, batches, budgets,
                                      hw_rows)
        wall = time.perf_counter() - t0

        for i, (acc, b) in enumerate(conds):
            env = FusionEnv(wl, acc, batch=64, budget_bytes=b * MB,
                            nmax=su["max_steps"])
            host = dnnfuser_infer(params, cfg, env)
            parity = bool((host.strategy == served["strategy"][i]).all())
            gs = gsampler_search(env, su["ga"], top_k=4)
            dt_sp = float(served["speedup"][i])
            dt_valid = bool(served["valid"][i])
            ratio = dt_sp / gs.speedup if (dt_valid and gs.valid) else 0.0
            rows.append(dict(
                workload=wl.name, accel=acc.name, budget_mb=b,
                held_out=acc.name == HOLDOUT, dt_speedup=dt_sp,
                dt_valid=dt_valid, teacher_speedup=gs.speedup,
                teacher_valid=gs.valid, ratio=ratio, parity=parity))
            tag = "HELD-OUT" if acc.name == HOLDOUT else "train   "
            print(f"  {wl.name:9s} {acc.name:10s} {tag} @{b:5.1f}MB: "
                  f"DT {fmt_speedup(dt_sp, dt_valid):>5s}x vs G-Sampler "
                  f"{fmt_speedup(gs.speedup, gs.valid):>5s}x "
                  f"(ratio {ratio:4.2f}) parity={parity}")

        us_per_cond = wall * 1e6 / len(conds)
        hold = [r for r in rows if r["workload"] == wl.name and r["held_out"]
                and r["ratio"] > 0]
        hold_ratio = (float(np.mean([r["ratio"] for r in hold]))
                      if hold else 0.0)
        csv_rows.append((f"hw_generalization_{wl.name}", us_per_cond,
                         f"holdout_ratio={hold_ratio:.2f}"))

    parity_all = all(r["parity"] for r in rows)
    hold_valid = [r for r in rows if r["held_out"]]
    report = {
        "bench": "hw_generalization",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "train_accels": TRAIN_ACCELS,
        "holdout_accel": HOLDOUT,
        "hw_feature_dim": HW_FEATURE_DIM,
        "teacher_trajectories": art["n_traj"],
        "imitation_loss": art["final_loss"],
        "fused_host_parity": parity_all,
        "holdout_valid_fraction": float(np.mean(
            [r["dt_valid"] for r in hold_valid])) if hold_valid else 0.0,
        "holdout_mean_ratio": float(np.mean(
            [r["ratio"] for r in hold_valid if r["ratio"] > 0]) if any(
            r["ratio"] > 0 for r in hold_valid) else 0.0),
        "results": rows,
    }
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}  (holdout mean DT/G-Sampler ratio "
          f"{report['holdout_mean_ratio']:.2f}, parity={parity_all})")
    if not parity_all:
        # RuntimeError, not SystemExit: benchmarks/run.py isolates suite
        # failures with `except Exception` and must keep running
        raise RuntimeError("fused/batched serving diverged from the host "
                           "reference — the §11 serving contract is broken")
    return csv_rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: tiny workload, small GA, short training")
    ap.add_argument("--out", default="BENCH_hw.json")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
