"""Table 3: transfer learning (paper §5.4).

Pre-train a general DNNFuser on VGG16 + ResNet18; fine-tune with 10% of the
epochs on ResNet50 / MobileNet-V2 / MnasNet (Transfer-DF) vs training from
scratch (Direct-DF) vs a full G-Sampler search, at 25/35/45/55 MB.
"""
from __future__ import annotations

from repro.core import dnnfuser_infer, gsampler_search
from repro.workloads import mnasnet_b1, mobilenet_v2, resnet18, resnet50, vgg16

from . import common as C

CONDS = [25.0, 35.0, 45.0, 55.0]
T = 56                      # trajectory positions (resnet50/mnv2 ~ 51-54)


def run(quick: bool = False):
    rows = []
    conds = CONDS[:2] if quick else CONDS
    steps_full = 80 if quick else C.DT_STEPS
    # general pre-trained model (paper: trained on VGG16 + ResNet18)
    ds_gen = C.teacher_dataset([vgg16(), resnet18()], 64, C.TRAIN_BUDGETS,
                               T, "general_vgg_r18")
    gen_params, gen_cfg, _ = C.train_dt(ds_gen, "general_T56", max_steps=T,
                                        steps=steps_full)
    print("\n=== Table 3: transfer vs direct vs G-Sampler (batch 64)")
    for wl_fn, name in [(resnet50, "resnet50"), (mobilenet_v2, "mnv2"),
                        (mnasnet_b1, "mnasnet")]:
        wl = wl_fn()
        ds_new = C.teacher_dataset([wl], 64, C.TRAIN_BUDGETS, T,
                                   f"{name}_T56")
        tr_params, tr_cfg, _ = C.train_dt(
            ds_new, f"transfer_{name}", max_steps=T,
            steps=max(steps_full // 10, 20),      # 10% of the epochs
            init_params=gen_params, lr=1e-4)
        di_params, di_cfg, _ = C.train_dt(ds_new, f"direct_{name}",
                                          max_steps=T, steps=steps_full)
        for cond in conds:
            env = C.env_for(wl, 64, cond, max_steps=T)
            tr = dnnfuser_infer(tr_params, tr_cfg, env)
            di = dnnfuser_infer(di_params, di_cfg, env)
            gs = gsampler_search(env)
            print(f"{name:9s} {cond:4.0f}MB: Transfer-DF="
                  f"{C.fmt_speedup(tr.speedup, tr.valid):>5s} Direct-DF="
                  f"{C.fmt_speedup(di.speedup, di.valid):>5s} "
                  f"GS={gs.speedup:5.2f}")
            rows.append((f"table3/{name}/{int(cond)}MB", tr.wall_s * 1e6,
                         f"transfer={C.fmt_speedup(tr.speedup, tr.valid)};"
                         f"direct={C.fmt_speedup(di.speedup, di.valid)};"
                         f"gs={gs.speedup:.2f}"))
    return rows


if __name__ == "__main__":
    run()
