"""Render the §Dry-run/§Roofline tables in EXPERIMENTS.md from the cell
JSONs produced by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import cells, get_config, SHAPES


def load(dir_: pathlib.Path):
    recs = {}
    for p in sorted(dir_.glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_table(recs, mesh: str) -> str:
    lines = [
        "| arch | shape | fits (temp GiB/dev) | t_comp ms | t_mem ms |"
        " t_coll ms | bottleneck | useful (6ND/HLO) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, ok, why in cells(include_skipped=True):
        if not ok:
            lines.append(f"| {arch} | {shape} | SKIP — {why} | | | | | |")
            continue
        r = recs.get((arch, shape, mesh))
        if r is None or not r.get("ok"):
            err = (r or {}).get("error", "missing")[:60]
            lines.append(f"| {arch} | {shape} | FAIL: {err} | | | | | |")
            continue
        rf = r["roofline"]
        temp = r["memory"]["temp_size_in_bytes"] / 2 ** 30
        fits = "yes" if temp <= 16.0 else "NO"
        lines.append(
            f"| {arch} | {shape} | {fits} ({temp:.1f}) |"
            f" {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} |"
            f" {rf['t_collective']*1e3:.1f} | {rf['bottleneck']} |"
            f" {rf['useful_ratio']:.3f} |")
    return "\n".join(lines)


def summarize(recs):
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    n_fail = sum(1 for r in recs.values() if not r.get("ok"))
    worst = sorted((r for r in recs.values()
                    if r.get("ok") and r["mesh"] == "16x16"),
                   key=lambda r: r["roofline"]["useful_ratio"])[:5]
    collb = sorted((r for r in recs.values()
                    if r.get("ok") and r["mesh"] == "16x16"),
                   key=lambda r: -r["roofline"]["t_collective"])[:5]
    out = [f"cells ok: {n_ok}, failed: {n_fail}",
           "worst useful-ratio (hillclimb candidates): "
           + ", ".join(f"{r['arch']}/{r['shape']}"
                       f"({r['roofline']['useful_ratio']:.3f})"
                       for r in worst),
           "most collective-bound: "
           + ", ".join(f"{r['arch']}/{r['shape']}"
                       f"({r['roofline']['t_collective']*1e3:.0f}ms)"
                       for r in collb)]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    recs = load(pathlib.Path(args.dir))
    print("## 16x16 (single pod, 256 chips)\n")
    print(fmt_table(recs, "16x16"))
    print("\n## 2x16x16 (multi-pod, 512 chips)\n")
    print(fmt_table(recs, "2x16x16"))
    print("\n## summary\n")
    print(summarize(recs))


if __name__ == "__main__":
    main()
