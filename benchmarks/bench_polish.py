"""Propose-then-polish benchmark (DESIGN §17; beyond-paper).

Measures the three §17 claims on a (network x accel x budget) grid and
gates them in CI:

 1. **Quality**: the one-shot DT proposal + gradient polish matches or
    beats a cold fused G-Sampler search — ``quality_ratio_mean`` =
    mean(gs_latency / polished_latency) over cells where both are valid
    must be >= 1.00;
 2. **Latency**: the fused polish call costs <= 25% of the cold
    G-Sampler grid search's wall clock (both post-compile — the compile
    is a once-per-shape cost the §14 serving path amortizes);
 3. **Warm starts**: the warm-started DE portfolio (seeded from the
    polished proposals) reaches the cold DE run's final cost in <= 1/3
    of the exact cost evaluations, per the searchers' own convergence
    histories (``eval_ratio_mean`` >= 3.0).

Protocol
 - student: the shared hw-conditioned mapper from
   ``table_hw_generalization`` (same ``artifacts/bench`` cache tag);
 - propose: all cells in ONE ``dnnfuser_infer_batch`` call;
 - polish: all cells in ONE ``polish_grid`` call (deterministic);
 - cold search: ONE fused ``gsampler_search_grid`` over the same cells;
 - portfolio: ``de_search_grid`` warm (init = polished proposals) vs
   cold, same population/generations/seed; per cell, evaluations-to-
   reach the target ``max(warm_final, cold_final)`` are read off the
   best-so-far histories (evals(g) = population * (g + 2): the init
   population plus g+1 evolved generations).

Output: ``BENCH_polish.json`` rows + summary; ``--check BASELINE``
enforces the three absolute gates above plus a ``--tol`` ratio gate on
``quality_ratio_mean`` vs the committed baseline (mode must match,
zero comparisons refuse) — the same contract as
``bench_infer.check_regression``.

    PYTHONPATH=src python benchmarks/bench_polish.py
        [--quick] [--out BENCH_polish.json] [--check BASELINE] [--tol R]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, GSamplerConfig, PolishConfig,
                        PortfolioConfig, dnnfuser_infer_batch,
                        de_search_grid, gsampler_search_grid, polish_grid)
from repro.core import cost_model as cm
from repro.workloads import resnet18, tiny_cnn, vgg16

try:                                   # as a module (benchmarks.run) ...
    from .table_hw_generalization import _train_mapper
    from .table_optimality_gap import _hw_args
except ImportError:                    # ... or as a script
    from table_hw_generalization import _train_mapper
    from table_optimality_gap import _hw_args

MB = float(2 ** 20)

# the three gates (absolute — the §17 acceptance numbers, not ratios
# against a baseline)
GATE_QUALITY = 1.00        # mean gs_latency / polished_latency
GATE_WALL_FRACTION = 0.25  # polish wall / cold G-Sampler wall
GATE_EVAL_RATIO = 3.0      # cold evals-to-target / warm evals-to-target


def _setup(quick: bool) -> dict:
    if quick:
        return dict(workloads=[tiny_cnn()],
                    accels=["edge", "nano", "mobile", "laptop"],
                    budgets=[2.0, 4.0, 6.0],
                    ga=GSamplerConfig(population=24, generations=20,
                                      seed=0),
                    de=PortfolioConfig(population=16, generations=24,
                                       seed=0))
    return dict(workloads=[vgg16(), resnet18()],
                accels=["edge", "nano", "mobile", "laptop", "datacenter"],
                budgets=[16.0, 32.0, 48.0],
                ga=GSamplerConfig(seed=0),
                de=PortfolioConfig(population=24, generations=40, seed=0))


def _evals_to(history: np.ndarray, c: int, target: float,
              pop: int) -> int:
    """Exact cost evaluations until cell ``c``'s best-so-far curve first
    reaches ``target``: the init population plus g+1 evolved generations
    of ``pop`` evaluations each."""
    hit = history[:, c] <= target
    g = int(np.argmax(hit)) if hit.any() else history.shape[0] - 1
    return pop * (g + 2)


def run(quick: bool = False, out: str = "BENCH_polish.json") -> list:
    su = _setup(quick)
    hw_su = _hw_args(quick)
    nmax = hw_su["max_steps"]
    su["workloads"] = [w for w in su["workloads"] if w.n + 1 <= nmax]
    art, cfg = _train_mapper(hw_su, quick)
    params = art["params"]

    conds = [(w, ACCEL_ZOO[a], b) for w in su["workloads"]
             for a in su["accels"] for b in su["budgets"]]
    wl_list = [w for w, _, _ in conds]
    hw_list = [a for _, a, _ in conds]
    batches = np.full(len(conds), 64.0, np.float32)
    budgets = np.asarray([b * MB for _, _, b in conds], np.float32)
    packed = cm.stack_workloads(
        [cm.pack_workload(w, a, nmax) for w, a, _ in conds])

    # -- propose (one fused call; warm the jit, then time) -------------------
    def propose():
        return dnnfuser_infer_batch(params, cfg, packed, batches, budgets,
                                    hw_list)
    propose()
    t0 = time.perf_counter()
    served = propose()
    propose_wall = time.perf_counter() - t0
    proposals = np.asarray(served["strategy"], np.int32)

    # -- polish (one fused call; warm, then time) ----------------------------
    pcfg = PolishConfig()
    polish_grid(packed, proposals, batches, budgets, hw_list, cfg=pcfg)
    t0 = time.perf_counter()
    pol = polish_grid(packed, proposals, batches, budgets, hw_list,
                      cfg=pcfg)
    polish_wall = time.perf_counter() - t0

    # -- cold G-Sampler (one fused grid; warm, then time) --------------------
    def cold_gs():
        return gsampler_search_grid(wl_list, hw_list, batches, budgets,
                                    nmax=nmax, cfg=su["ga"], top_k=1,
                                    packed=packed)
    cold_gs()
    t0 = time.perf_counter()
    gs = cold_gs()
    gs_wall = time.perf_counter() - t0
    gs_lat = gs.latency[:, 0]
    gs_valid = gs.valid[:, 0]

    # -- portfolio: warm (polished seeds) vs cold ----------------------------
    de = su["de"]
    warm = de_search_grid(None, hw_list, batches, budgets, nmax=nmax,
                          cfg=de, init_strategies=pol["strategy"],
                          packed=packed)
    cold = de_search_grid(None, hw_list, batches, budgets, nmax=nmax,
                          cfg=de, packed=packed)

    rows, ratios, eratios = [], [], []
    for c, (w, acc, b) in enumerate(conds):
        both = bool(pol["valid"][c]) and bool(gs_valid[c])
        q = float(gs_lat[c] / pol["latency"][c]) if both else 0.0
        if q:
            ratios.append(q)
        target = max(warm.latency[c], cold.latency[c]) * (1 + 1e-6)
        ew = _evals_to(warm.history, c, target, de.population)
        ec = _evals_to(cold.history, c, target, de.population)
        er = ec / ew
        eratios.append(er)
        rows.append(dict(
            workload=w.name, accel=acc.name, budget_mb=b,
            oneshot_latency=float(served["latency"][c]),
            oneshot_valid=bool(served["valid"][c]),
            polished_latency=float(pol["latency"][c]),
            polished_valid=bool(pol["valid"][c]),
            polish_improved=bool(pol["improved"][c]),
            gs_latency=float(gs_lat[c]), gs_valid=bool(gs_valid[c]),
            quality_ratio=q,
            warm_evals_to_target=ew, cold_evals_to_target=ec,
            eval_ratio=float(er)))
        print(f"  {w.name:9s} {acc.name:10s} @{b:5.1f}MB: "
              f"polished {pol['latency'][c]:.3e}s vs GS "
              f"{gs_lat[c]:.3e}s ({q:5.3f}x)  warm/cold evals "
              f"{ew}/{ec} ({er:.1f}x)")

    report = {
        "bench": "polish",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "cells": len(conds),
        "quality_ratio_mean": float(np.mean(ratios)) if ratios else 0.0,
        "quality_ratio_min": float(np.min(ratios)) if ratios else 0.0,
        "quality_cells": len(ratios),
        "polish_improved_fraction": float(np.mean(pol["improved"])),
        "polished_valid_fraction": float(np.mean(pol["valid"])),
        "propose_wall_s": propose_wall,
        "polish_wall_s": polish_wall,
        "gs_wall_s": gs_wall,
        "wall_fraction": polish_wall / gs_wall,
        "eval_ratio_mean": float(np.mean(eratios)),
        "eval_ratio_min": float(np.min(eratios)),
        "warm_final_latency_mean": float(np.mean(warm.latency)),
        "cold_final_latency_mean": float(np.mean(cold.latency)),
        "results": rows,
    }
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}  (quality {report['quality_ratio_mean']:.3f}x, "
          f"polish wall {report['wall_fraction']:.3f}x GS, "
          f"warm evals advantage {report['eval_ratio_mean']:.1f}x)")
    return [("polish_pipeline", (propose_wall + polish_wall) * 1e6
             / len(conds),
             f"quality={report['quality_ratio_mean']:.3f}x"),
            ("polish_vs_gsampler_wall", polish_wall * 1e6 / len(conds),
             f"fraction={report['wall_fraction']:.3f}"),
            ("portfolio_warm_advantage", 0.0,
             f"eval_ratio={report['eval_ratio_mean']:.1f}x")]


def check_regression(report: dict, baseline_path: str, tol: float) -> list:
    """Gate the §17 claims; returns human-readable failures.

    Hard absolute gates: ``quality_ratio_mean`` >= 1.00,
    ``wall_fraction`` <= 0.25, ``eval_ratio_mean`` >= 3.0.  Baseline
    gates: mode match, >=1 compared cell, and ``quality_ratio_mean``
    within ``tol`` of the committed baseline's."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline in "
                f"the same mode"]
    failures = []
    if report.get("quality_cells", 0) == 0:
        failures.append("no cells where both polish and G-Sampler were "
                        "valid — nothing compared; shrink the budgets")
    if report["quality_ratio_mean"] < GATE_QUALITY:
        failures.append(
            f"quality_ratio_mean {report['quality_ratio_mean']:.4f} < "
            f"{GATE_QUALITY:.2f}: one-shot+polish no longer matches the "
            "cold G-Sampler")
    if report["wall_fraction"] > GATE_WALL_FRACTION:
        failures.append(
            f"wall_fraction {report['wall_fraction']:.3f} > "
            f"{GATE_WALL_FRACTION:.2f}: polish costs more than 25% of the "
            "cold search")
    if report["eval_ratio_mean"] < GATE_EVAL_RATIO:
        failures.append(
            f"eval_ratio_mean {report['eval_ratio_mean']:.2f} < "
            f"{GATE_EVAL_RATIO:.1f}: warm starts lost their evaluation "
            "advantage")
    if base.get("quality_ratio_mean", 0) > 0 and \
            report["quality_ratio_mean"] < \
            base["quality_ratio_mean"] / tol - 1e-3:
        failures.append(
            f"quality_ratio_mean {report['quality_ratio_mean']:.3f} < "
            f"baseline {base['quality_ratio_mean']:.3f} / {tol:.2f}")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: tiny_cnn only, small GA/mapper")
    ap.add_argument("--out", default="BENCH_polish.json")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) if any §17 gate fails or quality "
                         "regresses more than --tol vs this baseline")
    ap.add_argument("--tol", type=float, default=1.10,
                    help="allowed quality ratio drop vs the baseline "
                         "(default 1.10)")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_polish_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    run(quick=args.quick, out=args.out)
    if args.check:
        report = json.loads(pathlib.Path(args.out).read_text())
        failures = check_regression(report, args.check, args.tol)
        if failures:
            print("POLISH REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"polish gate OK (tol {args.tol} vs {args.check})")


if __name__ == "__main__":
    main()
