"""The 66x-127x one-shot-vs-search speed claim (paper §5.2).

Measures wall time of a full G-Sampler search vs a single DNNFuser
autoregressive inference on the same (workload, condition).  Two framings
are reported honestly:
 - vs OUR vectorized-JAX G-Sampler (itself ~50x faster than the paper's,
   thanks to one vmapped cost-model call per generation);
 - vs the paper's reported G-Sampler time (0.66-1.27 min) — the
   apples-to-apples analogue of their Table 1 comparison.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dnnfuser_infer, gsampler_search
from repro.workloads import resnet18, vgg16

from . import common as C


def run(quick: bool = False):
    rows = []
    print("\n=== One-shot inference vs search speed")
    for wl_fn, name, paper_gs_min in [(vgg16, "vgg16", 0.66),
                                      (resnet18, "resnet18", 1.27)]:
        wl = wl_fn()
        env = C.env_for(wl, 64, 20.0, max_steps=20)
        ds = C.teacher_dataset([wl], 64, C.TRAIN_BUDGETS, 20, f"{name}_b64")
        dtp, dtc, _ = C.train_dt(ds, f"{name}_b64", max_steps=20)
        dnnfuser_infer(dtp, dtc, env)        # warm the jit cache
        t0 = time.perf_counter()
        gs = gsampler_search(env)
        t_gs = time.perf_counter() - t0
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            df = dnnfuser_infer(dtp, dtc, env)
        t_df = (time.perf_counter() - t0) / reps
        ratio = t_gs / t_df
        ratio_paper = paper_gs_min * 60.0 / t_df
        print(f"{name:9s}: GS search {t_gs:6.2f}s | DF one-shot "
              f"{t_df*1e3:6.0f}ms | {ratio:6.1f}x vs our GS | "
              f"{ratio_paper:7.0f}x vs paper GS "
              f"(speedups: GS {gs.speedup:.2f} DF {df.speedup:.2f})")
        rows.append((f"speed/{name}", t_df * 1e6,
                     f"gs_s={t_gs:.2f};ratio_ours={ratio:.1f};"
                     f"ratio_vs_paper_gs={ratio_paper:.0f}"))
    return rows


if __name__ == "__main__":
    run()
