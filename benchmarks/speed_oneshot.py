"""The 66x-127x one-shot-vs-search speed claim (paper §5.2).

Measures wall time of a full G-Sampler search vs a single DNNFuser
autoregressive inference on the same (workload, condition).  Framings
reported honestly:
 - vs OUR vectorized-JAX G-Sampler (itself ~50x faster than the paper's,
   thanks to one vmapped cost-model call per generation);
 - vs the paper's reported G-Sampler time (0.66-1.27 min) — the
   apples-to-apples analogue of their Table 1 comparison;
 - host vs FUSED rollout (``fused-vs-host``): the device-resident
   ``lax.scan`` one-shot against the Python-loop reference, plus batched
   serving throughput (conditions/sec for a stacked grid of (batch, budget)
   conditions in one device call) — DESIGN.md §9.

A machine-readable summary lands in ``artifacts/bench/speed_oneshot.json``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (dnnfuser_infer, dnnfuser_infer_batch,
                        dnnfuser_infer_fused, gsampler_search)
from repro.workloads import resnet18, vgg16

from . import common as C


def run(quick: bool = False):
    rows = []
    report = []
    n_cond = 32
    print("\n=== One-shot inference vs search speed")
    for wl_fn, name, paper_gs_min in [(vgg16, "vgg16", 0.66),
                                      (resnet18, "resnet18", 1.27)]:
        wl = wl_fn()
        env = C.env_for(wl, 64, 20.0, max_steps=20)
        ds = C.teacher_dataset([wl], 64, C.TRAIN_BUDGETS, 20, f"{name}_b64")
        dtp, dtc, _ = C.train_dt(ds, f"{name}_b64", max_steps=20)
        dnnfuser_infer(dtp, dtc, env)        # warm the jit caches
        dnnfuser_infer_fused(dtp, dtc, env)
        t0 = time.perf_counter()
        gs = gsampler_search(env)
        t_gs = time.perf_counter() - t0
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            df = dnnfuser_infer(dtp, dtc, env)
        t_df = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            ff = dnnfuser_infer_fused(dtp, dtc, env)
        t_ff = (time.perf_counter() - t0) / reps
        batches = np.full(n_cond, 64.0, np.float32)
        budgets = (np.linspace(8.0, 64.0, n_cond) * C.MB).astype(np.float32)
        dnnfuser_infer_batch(dtp, dtc, env, batches, budgets)   # warm
        t0 = time.perf_counter()
        bf = dnnfuser_infer_batch(dtp, dtc, env, batches, budgets)
        t_bf = time.perf_counter() - t0
        ratio = t_gs / t_ff
        ratio_paper = paper_gs_min * 60.0 / t_ff
        print(f"{name:9s}: GS search {t_gs:6.2f}s | DF host "
              f"{t_df*1e3:6.0f}ms | fused {t_ff*1e3:6.1f}ms "
              f"({t_df/t_ff:5.1f}x fused-vs-host) | {ratio:6.1f}x vs our GS "
              f"| {ratio_paper:7.0f}x vs paper GS "
              f"(speedups: GS {gs.speedup:.2f} DF {df.speedup:.2f} "
              f"fused {ff.speedup:.2f})")
        print(f"{'':9s}  batched serving: {n_cond} conditions in "
              f"{t_bf*1e3:.0f}ms = {n_cond/t_bf:.0f} cond/s "
              f"({int(bf['valid'].sum())}/{n_cond} valid)")
        rows.append((f"speed/{name}", t_ff * 1e6,
                     f"gs_s={t_gs:.2f};host_ms={t_df*1e3:.0f};"
                     f"fused_vs_host={t_df/t_ff:.1f};ratio_ours={ratio:.1f};"
                     f"ratio_vs_paper_gs={ratio_paper:.0f};"
                     f"batch_cond_per_s={n_cond/t_bf:.0f}"))
        report.append(dict(
            workload=name, gs_s=t_gs, host_ms=t_df * 1e3,
            fused_ms=t_ff * 1e3, fused_vs_host_x=t_df / t_ff,
            oneshot_vs_our_gs_x=ratio, oneshot_vs_paper_gs_x=ratio_paper,
            batch_conditions=n_cond, batch_ms=t_bf * 1e3,
            batch_conditions_per_s=n_cond / t_bf,
            gs_speedup=gs.speedup, df_speedup=df.speedup,
            fused_speedup=ff.speedup))
    out = C.ART / "speed_oneshot.json"
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return rows


if __name__ == "__main__":
    run()
