"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]

Suites (``--only`` takes a comma list of the keys below; default = all):

 - ``table1``  search-method comparison (paper Table 1)
 - ``table2``  workload/condition generalization (paper Table 2)
 - ``table3``  transfer fine-tuning (paper Table 3)
 - ``fig4``    qualitative strategies (paper Fig. 4)
 - ``speed``   one-shot vs search wall clock + batched serving throughput
 - ``hw``      hardware generalization across the accel zoo (DESIGN §11)
 - ``lm``      LM-workload mapping (beyond paper)
 - ``kernel``  Pallas fusion_eval kernel vs XLA cost model
 - ``drift``   closed-loop drift recovery: refresh + hot swap (DESIGN §15)
 - ``optgap``  gap-to-optimal vs the exact DP oracle (DESIGN §16)
 - ``polish``  propose-then-polish quality/latency/eval gates (DESIGN §17)

THE ``--quick`` CONTRACT: every suite's ``run(quick=True)`` must (i) keep
the full protocol shape — same pipeline stages, same metrics, same JSON/CSV
schema — while shrinking only sizes (workloads, GA budget, training steps,
condition counts), and (ii) finish CI-sized (minutes, CPU-only).  Numbers
from quick and full runs are therefore comparable in STRUCTURE but not in
magnitude; regression baselines (``BENCH_*.json``) record which mode wrote
them, and the CI gates compare like with like (see
``bench_infer.check_regression``).

CACHING: teacher corpora and trained mappers are pickled under
``artifacts/bench/`` keyed by suite + mode tag (``common.load_or``); reruns
reuse them, so deleting ``artifacts/bench`` is the way to force a retrain
after a semantic change.  Each suite prints a human-readable section and
contributes ``name,us_per_call,derived`` rows to the final CSV block
(scaffold format); a suite failure is reported at the end and exits
non-zero without blocking the other suites.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(
        description="DNNFuser benchmark driver (see module docstring: "
                    "python -m benchmarks.run)",
        epilog="--quick keeps every suite's protocol and schema but shrinks "
               "sizes to CI scale; artifacts/bench/ caches teacher corpora "
               "and trained mappers across reruns (delete to retrain).")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: same protocol/metrics, smaller "
                         "workloads/search/training budgets")
    ap.add_argument("--only", default="",
                    help="comma list: table1,table2,table3,fig4,speed,hw,"
                         "lm,kernel,drift,optgap,polish")
    args = ap.parse_args()

    from . import (bench_drift, bench_polish, fig4_solutions,
                   fusion_eval_kernel, lm_mapping, speed_oneshot,
                   table1_methods, table2_generalization, table3_transfer,
                   table_hw_generalization, table_optimality_gap)
    suites = {
        "table1": table1_methods, "table2": table2_generalization,
        "table3": table3_transfer, "fig4": fig4_solutions,
        "speed": speed_oneshot, "hw": table_hw_generalization,
        "lm": lm_mapping, "kernel": fusion_eval_kernel,
        "drift": bench_drift, "optgap": table_optimality_gap,
        "polish": bench_polish,
    }
    only = [s for s in args.only.split(",") if s]
    rows, failures = [], []
    for name, mod in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows += mod.run(quick=args.quick)
            print(f"[{name} done in {time.perf_counter()-t0:.1f}s]")
        except Exception as e:
            failures.append(name)
            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")

    print("\n=== CSV (name,us_per_call,derived)")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
