"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableN,...]``
Prints a human-readable section per table and a final
``name,us_per_call,derived`` CSV block (scaffold format).  Trained-mapper
artifacts are cached under artifacts/bench/ so reruns are cheap.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced budgets/conditions (CI-sized)")
    ap.add_argument("--only", default="",
                    help="comma list: table1,table2,table3,fig4,speed,"
                         "lm,kernel")
    args = ap.parse_args()

    from . import (fig4_solutions, fusion_eval_kernel, lm_mapping,
                   speed_oneshot, table1_methods, table2_generalization,
                   table3_transfer)
    suites = {
        "table1": table1_methods, "table2": table2_generalization,
        "table3": table3_transfer, "fig4": fig4_solutions,
        "speed": speed_oneshot, "lm": lm_mapping,
        "kernel": fusion_eval_kernel,
    }
    only = [s for s in args.only.split(",") if s]
    rows, failures = [], []
    for name, mod in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows += mod.run(quick=args.quick)
            print(f"[{name} done in {time.perf_counter()-t0:.1f}s]")
        except Exception as e:
            failures.append(name)
            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")

    print("\n=== CSV (name,us_per_call,derived)")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
