"""Table 1: optimization methods on VGG16, two memory/batch cases.

Reproduces the paper's comparison: domain-agnostic optimizers (2k samples,
unconstrained-latency protocol -> N/A on the memory constraint), A2C, the
G-Sampler teacher, and the two sequence models (Seq2Seq, DNNFuser) doing
one-shot inference after imitation training.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BASELINE_METHODS, a2c_search, gsampler_search,
                        dnnfuser_infer, s2s_infer)
from repro.workloads import vgg16

from . import common as C


def run(quick: bool = False):
    rows, table = [], []
    cases = [("case1_20MB_B64", 64, 20.0), ("case2_40MB_B128", 128, 40.0)]
    a2c_budget = 150 if quick else 1200
    for tag, batch, budget in cases:
        wl = vgg16(batch=batch)
        env = C.env_for(wl, batch, budget, max_steps=20)
        # baselines (2k sampling budget, as in the paper)
        for name, fn in C.BASELINE_ITEMS:
            r = fn(env, budget=2000, seed=0)
            table.append((tag, name, C.fmt_speedup(r.speedup, r.valid),
                          r.peak_mem / C.MB, r.wall_s))
        r = a2c_search(env, budget=a2c_budget, seed=0)
        table.append((tag, "A2C", C.fmt_speedup(r.speedup, r.valid),
                      r.peak_mem / C.MB, r.wall_s))
        g = gsampler_search(env)
        table.append((tag, "G-Sampler", C.fmt_speedup(g.speedup, g.valid),
                      g.peak_mem / C.MB, g.wall_s))
        # sequence models: imitation-train on {16,32,48,64} MB conditions
        ds = C.teacher_dataset([wl], batch, C.TRAIN_BUDGETS, 20,
                               f"vgg16_b{batch}")
        dtp, dtc, _ = C.train_dt(ds, f"vgg16_b{batch}", max_steps=20)
        s2p, s2c, _ = C.train_s2s(ds, f"vgg16_b{batch}", max_steps=20)
        ir = s2s_infer(s2p, s2c, env)
        table.append((tag, "Seq2Seq", C.fmt_speedup(ir.speedup, ir.valid),
                      ir.peak_mem / C.MB, ir.wall_s))
        ir = dnnfuser_infer(dtp, dtc, env)
        table.append((tag, "DNNFuser", C.fmt_speedup(ir.speedup, ir.valid),
                      ir.peak_mem / C.MB, ir.wall_s))

    print("\n=== Table 1: methods on VGG16 (speedup | usage MB | search s)")
    for tag, name, sp, mem, wall in table:
        print(f"{tag:18s} {name:10s} speedup={sp:>5s} usage={mem:7.1f}MB "
              f"time={wall:7.2f}s")
        rows.append((f"table1/{tag}/{name}", wall * 1e6,
                     f"speedup={sp};usage_mb={mem:.1f}"))
    return rows


C.BASELINE_ITEMS = list(BASELINE_METHODS.items())

if __name__ == "__main__":
    run()
