"""Fig. 4: the found layer-fusion strategies on ResNet18 @ 20 MB, batch 64.

Prints the DNNFuser and G-Sampler strategies side by side and verifies the
paper's two qualitative observations: (1) deeper layers fuse into longer
groups (smaller activations), (2) expansions/residual merges trigger syncs.
"""
from __future__ import annotations

import numpy as np

from repro.core import SYNC, dnnfuser_infer, gsampler_search
from repro.workloads import resnet18

from . import common as C


def _group_lengths(strategy, n):
    lens, cur = [], 0
    for i in range(1, n + 1):
        cur += 1
        if strategy[i] == SYNC:
            lens.append(cur)
            cur = 0
    if cur:
        lens.append(cur)
    return lens


def run(quick: bool = False):
    wl = resnet18()
    env = C.env_for(wl, 64, 20.0, max_steps=20)
    ds = C.teacher_dataset([wl], 64, C.TRAIN_BUDGETS, 20, "resnet18_b64")
    dtp, dtc, _ = C.train_dt(ds, "resnet18_b64", max_steps=20)
    df = dnnfuser_infer(dtp, dtc, env)
    gs = gsampler_search(env)
    n = wl.n
    print("\n=== Fig 4: strategies on ResNet18 @20MB batch 64")
    print("layer_id :", " ".join(f"{i:3d}" for i in range(n + 1)))
    print("DNNFuser :", " ".join(f"{int(v):3d}" for v in df.strategy[:n+1]),
          f"-> speedup {df.speedup:.2f} usage {df.peak_mem/C.MB:.1f}MB")
    print("G-Sampler:", " ".join(f"{int(v):3d}" for v in gs.strategy[:n+1]),
          f"-> speedup {gs.speedup:.2f} usage {gs.peak_mem/C.MB:.1f}MB")
    gl_df = _group_lengths(df.strategy, n)
    gl_gs = _group_lengths(gs.strategy, n)
    h_df, h_gs = len(gl_df) // 2 or 1, len(gl_gs) // 2 or 1
    print(f"group lengths DF={gl_df} GS={gl_gs}")
    deeper_longer = (np.mean(gl_gs[h_gs:]) >= np.mean(gl_gs[:h_gs]))
    print(f"observation 'deeper layers fuse more' (teacher): {deeper_longer}")
    return [("fig4/resnet18_20MB", df.wall_s * 1e6,
             f"df={df.speedup:.2f};gs={gs.speedup:.2f};"
             f"deeper_fuse_more={deeper_longer}")]


if __name__ == "__main__":
    run()
