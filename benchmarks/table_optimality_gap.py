"""Gap-to-optimal benchmark (DESIGN §16; beyond-paper).

Every other table reports quality RELATIVE to the stochastic G-Sampler;
this one anchors the whole stack to the exact DP oracle
(``core.optimal``): for each (network x accel x budget) cell it measures
the certified optimum latency, the G-Sampler latency, the one-shot DT
mapper latency, and the DT+polish latency (the §17 gradient refinement
of the same served proposals), and reports each as a gap-to-optimal
ratio (>= 1.0 by construction — a ratio below 1 - 1e-5 means an
evaluator disagreed with the oracle and is a hard RuntimeError, never a
data point).

Protocol
 - oracle: ``optimal_mapping`` per cell (exact f64 DP + one-call f32
   certification against ``evaluate_population``);
 - teacher: fresh per-cell ``gsampler_search`` (the same budgets the
   other tables give it);
 - student: the shared hw-conditioned mapper from
   ``table_hw_generalization`` (same ``artifacts/bench`` cache tag), all
   cells of a workload served in ONE ``dnnfuser_infer_batch`` call.

Output: ``BENCH_optgap.json`` rows {opt_latency, gs_gap, dt_gap,
dtp_gap, ...} plus summary {gs_never_below_opt, mean_dt_gap,
mean_dt_polish_gap, mean/max_gs_gap}.  ``--check BASELINE`` gates
regressions: per-cell G-Sampler gap, the mean DT gap, and the mean
DT+polish gap must stay within ``--tol`` x the committed baseline,
modes must match, zero comparisons refuse, ``gs_never_below_opt`` is
gated hard, and every polished cell must hold the §17 never-worsens
contract against its own one-shot cell (mirrors
``bench_infer.check_regression``).

The grid is the TRACTABLE slice of the zoo (DESIGN §16): quick =
tiny_cnn; full adds vgg16 (exact at front ~7e3, minutes/cell).  Deep
residual nets exceed practical front caps and are excluded by design.

    PYTHONPATH=src python benchmarks/table_optimality_gap.py
        [--quick] [--out BENCH_optgap.json] [--check BASELINE] [--tol R]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, FusionEnv, GSamplerConfig,
                        PolishConfig, dnnfuser_infer_batch,
                        gsampler_search, optimal_mapping, polish_grid)
from repro.core import cost_model as cm
from repro.workloads import tiny_cnn, vgg16

try:                                   # as a module (benchmarks.run) ...
    from .table_hw_generalization import _train_mapper
except ImportError:                    # ... or as a script
    from table_hw_generalization import _train_mapper

MB = float(2 ** 20)
ACCELS = ["edge", "nano", "datacenter"]
_SLACK = 1e-5       # f32 evaluator vs f64 oracle rounding allowance


def _setup(quick: bool) -> dict:
    if quick:
        return dict(workloads=[tiny_cnn()], budgets=[2.0, 6.0],
                    max_steps=16, front_cap=8192,
                    ga=GSamplerConfig(population=16, generations=10, seed=0))
    return dict(workloads=[tiny_cnn(), vgg16()], budgets=[16.0, 48.0],
                max_steps=20, front_cap=32768, ga=GSamplerConfig(seed=0))


def run(quick: bool = False, out: str = "BENCH_optgap.json") -> list:
    su = _setup(quick)
    # the student is table_hw_generalization's cached checkpoint: same
    # artifact tag, same training grid (DESIGN §11), zero extra training
    art, cfg = _train_mapper(_hw_args(quick), quick)
    params = art["params"]

    rows, csv_rows = [], []
    for wl in su["workloads"]:
        conds = [(ACCEL_ZOO[a], b) for a in ACCELS for b in su["budgets"]]
        envs = [FusionEnv(wl, acc, batch=64, budget_bytes=b * MB,
                          nmax=su["max_steps"]) for acc, b in conds]

        t0 = time.perf_counter()
        opts = [optimal_mapping(env, front_cap=su["front_cap"])
                for env in envs]
        opt_wall = time.perf_counter() - t0

        batches = np.full(len(conds), 64.0, np.float32)
        budgets = np.asarray([b * MB for _, b in conds], np.float32)
        hw_rows = [acc for acc, _ in conds]
        served = dnnfuser_infer_batch(params, cfg, envs[0], batches,
                                      budgets, hw_rows)        # warm jit
        served = dnnfuser_infer_batch(params, cfg, envs[0], batches,
                                      budgets, hw_rows)
        # §17: one fused polish of the same served proposals — the
        # propose-then-polish serving path's view of every cell
        pol = polish_grid(cm.stack_workloads([env.wl for env in envs]),
                          np.asarray(served["strategy"]), batches,
                          budgets, hw_rows, cfg=PolishConfig())

        for i, ((acc, b), env, res) in enumerate(zip(conds, envs, opts)):
            if not res.valid:
                raise RuntimeError(
                    f"oracle found no feasible mapping for {wl.name} on "
                    f"{acc.name} @{b}MB — shrink the grid, don't report "
                    "gaps against an infeasible cell")
            gs = gsampler_search(env, su["ga"], top_k=4)
            gs_gap = float(gs.latency) / res.latency if gs.valid else 0.0
            dt_valid = bool(served["valid"][i])
            dt_gap = (float(served["latency"][i]) / res.latency
                      if dt_valid else 0.0)
            dtp_valid = bool(pol["valid"][i])
            dtp_gap = (float(pol["latency"][i]) / res.latency
                       if dtp_valid else 0.0)
            for tag, gap in (("G-Sampler", gs_gap), ("DT", dt_gap),
                             ("DT+polish", dtp_gap)):
                if gap and gap < 1.0 - _SLACK:
                    raise RuntimeError(
                        f"{tag} reported {gap:.8f}x the certified optimum "
                        f"on {wl.name}/{acc.name}@{b}MB — an evaluator "
                        "disagrees with the oracle")
            rows.append(dict(
                workload=wl.name, accel=acc.name, budget_mb=b,
                opt_latency=res.latency, opt_front=res.n_states,
                opt_evals=res.n_evals, opt_wall_s=res.wall_s,
                gs_valid=bool(gs.valid), gs_gap=gs_gap,
                dt_valid=dt_valid, dt_gap=dt_gap,
                dtp_valid=dtp_valid, dtp_gap=dtp_gap))
            print(f"  {wl.name:9s} {acc.name:10s} @{b:5.1f}MB: "
                  f"opt {res.latency:.3e}s  GS gap "
                  f"{gs_gap:5.3f}x  DT gap {dt_gap:5.3f}x  polish "
                  f"{dtp_gap:5.3f}x (front {res.n_states}, "
                  f"{res.wall_s:.2f}s)")

        dtp_gaps = [r["dtp_gap"] for r in rows
                    if r["workload"] == wl.name and r["dtp_gap"] > 0]
        csv_rows.append((
            f"optimality_gap_{wl.name}", opt_wall * 1e6 / len(conds),
            f"mean_dt_polish_gap="
            f"{float(np.mean(dtp_gaps)) if dtp_gaps else 0:.3f}"))

    gs_gaps = [r["gs_gap"] for r in rows if r["gs_gap"] > 0]
    dt_gaps = [r["dt_gap"] for r in rows if r["dt_gap"] > 0]
    dtp_gaps = [r["dtp_gap"] for r in rows if r["dtp_gap"] > 0]
    report = {
        "bench": "optimality_gap",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "accels": ACCELS,
        "gs_never_below_opt": all(g >= 1.0 - _SLACK for g in gs_gaps),
        "gs_valid_fraction": float(np.mean([r["gs_valid"] for r in rows])),
        "dt_valid_fraction": float(np.mean([r["dt_valid"] for r in rows])),
        "dtp_valid_fraction": float(np.mean([r["dtp_valid"] for r in rows])),
        "mean_gs_gap": float(np.mean(gs_gaps)) if gs_gaps else 0.0,
        "max_gs_gap": float(np.max(gs_gaps)) if gs_gaps else 0.0,
        "mean_dt_gap": float(np.mean(dt_gaps)) if dt_gaps else 0.0,
        "mean_dt_polish_gap": float(np.mean(dtp_gaps)) if dtp_gaps else 0.0,
        "results": rows,
    }
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}  (mean gap-to-optimal: G-Sampler "
          f"{report['mean_gs_gap']:.3f}x, DT {report['mean_dt_gap']:.3f}x, "
          f"DT+polish {report['mean_dt_polish_gap']:.3f}x)")
    return csv_rows


def _hw_args(quick: bool) -> dict:
    """table_hw_generalization's _setup, imported lazily so the student's
    training grid stays defined in exactly one place."""
    try:
        from .table_hw_generalization import _setup as hw_setup
    except ImportError:
        from table_hw_generalization import _setup as hw_setup
    return hw_setup(quick)


def check_regression(report: dict, baseline_path: str, tol: float) -> list:
    """Gate vs the committed baseline; returns human-readable failures.

    Hard gates: mode match, >=1 compared cell, ``gs_never_below_opt``,
    and the §17 never-worsens contract per cell (a valid one-shot cell
    must stay valid after polish, with dtp_gap <= dt_gap).  Ratio gates
    (machine-independent, but jax-version drift happens): per-cell
    gs_gap, the mean dt_gap, and the mean dt_polish_gap within ``tol``
    x baseline."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline in "
                f"the same mode"]
    failures = []
    if not report.get("gs_never_below_opt", False):
        failures.append("gs_never_below_opt is False — the search stack "
                        "beat the 'exact' oracle; the oracle or an "
                        "evaluator is wrong")
    key = lambda r: (r["workload"], r["accel"], r["budget_mb"])
    by_cell = {key(r): r for r in base.get("results", [])}
    compared = 0
    for row in report["results"]:
        if row.get("dt_gap", 0) > 0 and not (
                row.get("dtp_gap", 0) > 0 and
                row["dtp_gap"] <= row["dt_gap"] * (1 + 1e-6)):
            failures.append(
                f"{key(row)}: polish worsened the one-shot cell "
                f"(dt_gap {row['dt_gap']:.3f} -> dtp_gap "
                f"{row.get('dtp_gap', 0):.3f})")
        ref = by_cell.get(key(row))
        if ref is None or ref.get("gs_gap", 0) <= 0:
            continue
        compared += 1
        if row["gs_gap"] > ref["gs_gap"] * tol + 1e-3:
            failures.append(
                f"{key(row)}: gs_gap {row['gs_gap']:.3f} > {tol:.2f}x "
                f"baseline {ref['gs_gap']:.3f}")
    for k in ("mean_dt_gap", "mean_dt_polish_gap"):
        if base.get(k, 0) > 0 and \
                report.get(k, 0) > base[k] * tol + 1e-3:
            failures.append(
                f"{k} {report[k]:.3f} > {tol:.2f}x baseline "
                f"{base[k]:.3f}")
    if compared == 0:
        failures.append(
            f"no comparable cells between this run and {baseline_path} — "
            "regenerate the baseline")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: tiny_cnn only, small GA/mapper")
    ap.add_argument("--out", default="BENCH_optgap.json")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) if gaps regress more than --tol x "
                         "this baseline JSON or the optimum is beaten")
    ap.add_argument("--tol", type=float, default=1.15,
                    help="allowed gap ratio vs the baseline (default 1.15; "
                         "tightened from 1.25 once the §17 polished path "
                         "pinned the serving gaps)")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_optgap_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    run(quick=args.quick, out=args.out)
    if args.check:
        report = json.loads(pathlib.Path(args.out).read_text())
        failures = check_regression(report, args.check, args.tol)
        if failures:
            print("OPTIMALITY-GAP REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"optimality gate OK (tol {args.tol}x vs {args.check})")


if __name__ == "__main__":
    main()
