"""Shared benchmark utilities: artifact cache, teacher-data collection,
model training wrappers, CSV emission.

CACHING CONTRACT (the reason benchmark reruns are cheap): ``load_or(tag,
builder)`` pickles the builder's result under ``artifacts/bench/<tag>.pkl``
and short-circuits every later call with the same tag.  Teacher corpora
(``teacher_<tag>``) and trained mappers (``dt_<tag>`` / ``s2s_<tag>`` /
``hwgen_<mode>``) are cached this way and SHARED across suites — e.g.
table2 and speed_oneshot reuse one trained mapper.  Tags do not encode the
builder's hyperparameters, so after changing teacher/training semantics
delete ``artifacts/bench/`` (or the specific tag) to force a rebuild; CI
always starts from an empty cache.

QUICK vs FULL: the cache tag must differ between modes whenever the built
artifact differs (the convention is a ``_q``/``_quick`` suffix in the tag),
so a quick CI run never poisons a full local run or vice versa.

CSV: ``emit_csv`` prints the scaffold's ``name,us_per_call,derived`` rows;
``fmt_speedup`` renders invalid (over-budget) results as ``N/A``."""
from __future__ import annotations

import json
import pathlib
import pickle
import time

import jax
import numpy as np

from repro.core import (PAPER_ACCEL, DTConfig, FusionEnv, S2SConfig,
                        TrainConfig, collect_teacher_data, dt_init, dt_loss,
                        s2s_init, s2s_loss, train_model, merge_datasets)

MB = float(2 ** 20)
ART = pathlib.Path("artifacts/bench")
ART.mkdir(parents=True, exist_ok=True)

TRAIN_BUDGETS = [16.0, 32.0, 48.0, 64.0]          # paper §5.3
DT_STEPS = 400                                     # "full training" unit
DT_BATCH = 16


def cache(name: str):
    return ART / f"{name}.pkl"


def load_or(name: str, builder):
    p = cache(name)
    if p.exists():
        with open(p, "rb") as f:
            return pickle.load(f)
    obj = builder()
    with open(p, "wb") as f:
        pickle.dump(obj, f)
    return obj


def teacher_dataset(workloads, batch, budgets, max_steps, tag, seed=0):
    def build():
        return collect_teacher_data(workloads, PAPER_ACCEL, batch=batch,
                                    budgets_mb=budgets, max_steps=max_steps,
                                    seed=seed)
    return load_or(f"teacher_{tag}", build)


def train_dt(dataset, tag, *, max_steps, steps=DT_STEPS, seed=0,
             init_params=None, lr=3e-4):
    """Train (or fine-tune, via init_params) a DNNFuser model; cached."""
    cfg = DTConfig(max_steps=max_steps)

    def build():
        params = (init_params if init_params is not None
                  else dt_init(jax.random.PRNGKey(seed), cfg))
        params, log = train_model(
            lambda p, b: dt_loss(p, cfg, b), params, dataset,
            TrainConfig(steps=steps, batch_size=DT_BATCH, lr=lr,
                        warmup=min(50, steps // 5), seed=seed))
        return {"params": jax.device_get(params), "log": log}
    out = load_or(f"dt_{tag}", build)
    return out["params"], cfg, out["log"]


def train_s2s(dataset, tag, *, max_steps, steps=DT_STEPS, seed=0):
    cfg = S2SConfig(max_steps=max_steps)

    def build():
        params = s2s_init(jax.random.PRNGKey(seed), cfg)
        params, log = train_model(
            lambda p, b: s2s_loss(p, cfg, b), params, dataset,
            TrainConfig(steps=steps, batch_size=DT_BATCH, seed=seed))
        return {"params": jax.device_get(params), "log": log}
    out = load_or(f"s2s_{tag}", build)
    return out["params"], cfg, out["log"]


def env_for(workload, batch, budget_mb, max_steps=64):
    return FusionEnv(workload, PAPER_ACCEL, batch=batch,
                     budget_bytes=budget_mb * MB, nmax=max_steps)


def fmt_speedup(speedup, valid):
    return f"{speedup:.2f}" if valid else "N/A"


def emit_csv(rows):
    """rows: list of (name, us_per_call, derived-string)."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
