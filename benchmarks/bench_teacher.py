"""Teacher-corpus generation benchmark (DESIGN.md §10).

Times the two corpus pipelines over the same (workload x budget) condition
grid and writes ``BENCH_teacher.json``:

 - ``host_s``: ``collect_teacher_data`` — one host GA per condition (each
   generation is a vmapped fitness call, but selection/mutation/repair
   round-trip through NumPy and conditions run serially);
 - ``grid_s``: ``generate_teacher_corpus`` — ONE jitted GA program over the
   whole grid plus ONE fused decoration program (``compile_s`` is reported
   separately: the program is condition-count-polymorphic only in data, so
   production sweeps amortize it).

    PYTHONPATH=src python benchmarks/bench_teacher.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.core import (GSamplerConfig, PAPER_ACCEL, collect_teacher_data,
                        generate_teacher_corpus)
from repro.workloads import resnet18, vgg16


def run(quick: bool = False, out: str = "BENCH_teacher.json") -> dict:
    workloads = [vgg16(), resnet18()]
    budgets = [12.0, 24.0] if quick else [8.0, 16.0, 24.0, 32.0, 48.0, 64.0]
    gens = 10 if quick else 50
    cfg = GSamplerConfig(generations=gens, seed=0)
    nmax = 20
    n_cond = len(workloads) * len(budgets)

    t0 = time.perf_counter()
    ds_grid = generate_teacher_corpus(
        workloads, PAPER_ACCEL, batch=64, budgets_mb=budgets, max_steps=nmax,
        ga_cfg=cfg, seed=0)
    t_grid_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    generate_teacher_corpus(
        workloads, PAPER_ACCEL, batch=64, budgets_mb=budgets, max_steps=nmax,
        ga_cfg=cfg, seed=0)
    t_grid = time.perf_counter() - t0

    t0 = time.perf_counter()
    ds_host = collect_teacher_data(
        workloads, PAPER_ACCEL, batch=64, budgets_mb=budgets, max_steps=nmax,
        ga_cfg=cfg, seed=0)
    t_host = time.perf_counter() - t0

    report = {
        "bench": "teacher",
        "quick": quick,
        "n_conditions": n_cond,
        "generations": gens,
        "host_s": t_host,
        "grid_s": t_grid,
        "grid_compile_s": t_grid_cold - t_grid,
        "grid_speedup_x": t_host / t_grid,
        "host_trajectories": len(ds_host),
        "grid_trajectories": len(ds_grid),
    }
    print(f"{n_cond} conditions x {gens} gens: host {t_host:6.1f} s | grid "
          f"{t_grid:6.1f} s ({report['grid_speedup_x']:.1f}x, "
          f"+{report['grid_compile_s']:.1f} s one-time compile) | "
          f"{len(ds_host)} vs {len(ds_grid)} trajectories")
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_teacher.json")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
