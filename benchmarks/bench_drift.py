"""Closed-loop drift benchmark (DESIGN.md §15).

A scripted, seeded drift stream hits TWO engines built from the SAME
narrowly-trained base mapper (trained only on the in-distribution
(workload x accel x budget) grid):

 - ``closed_loop``: a :class:`~repro.RefreshWorker` polls between ticks —
   the §15 pipeline (drift report -> G-Sampled teacher corpus for the
   drifted region -> ``fine_tune`` -> ``upgrade_pytree`` restore -> probe
   gate -> hot swap) runs exactly as in production;
 - ``frozen``: the same engine with no worker — the pre-§15 behaviour.

Three phases, identical for both engines:

 - phase A: in-distribution traffic (declared via
   ``ServingConfig.known_*``) — establishes the hit-rate baseline and
   seeds the replay buffer's retained conditions;
 - phase B: the shift — ~75% of requests move to NOVEL zoo accelerators
   (``laptop``/``datacenter``, never in the teacher corpus) at unseen
   budgets.  The monitor's unseen-accel window fires mid-phase and the
   closed-loop engine refreshes + swaps while serving;
 - phase C: post-swap traffic over the drifted mix.

EVAL: every distinct drifted condition is scored as DT speedup vs a
fresh per-condition G-Sampler search (the §11 ratio).  The committed
claim is RECOVERY: ``closed_ratio >= --min-ratio`` (default 0.98) while
``frozen_ratio`` stays at least ``--min-gap`` below it — the swap bought
back teacher-level quality the frozen mapper lost.  The harness also
enforces the swap mechanics: zero steady-state recompiles ACROSS the
hot swap (phases B+C on warmed programs), at least one ACCEPTED refresh,
and a bit-exact cached response for a non-drifted key after the swap.

``--check BENCH_drift.json`` turns all of that into the CI gate (plus a
machine-relative latency tolerance vs the committed baseline).

    PYTHONPATH=src python benchmarks/bench_drift.py [--quick]
        [--out BENCH_drift.json] [--check BASELINE.json] [--tol 2.5]
        [--min-ratio 0.98] [--min-gap 0.02]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro import (ACCEL_ZOO, DriftConfig, DTConfig, GSamplerConfig,
                   HW_FEATURE_DIM, MapperEngine, MapRequest, RefreshWorker,
                   ServingConfig, TrainConfig, dnnfuser_infer, dt_init,
                   dt_loss, generate_teacher_corpus, gsampler_search,
                   restore_params, train_model)
from repro.core import FusionEnv
from repro.workloads import resnet18, tiny_cnn, vgg16

try:                                   # as a module (benchmarks.run) ...
    from .common import fmt_speedup, load_or
except ImportError:                    # ... or as a script
    from common import fmt_speedup, load_or

MB = float(2 ** 20)
TICK = 8
BATCH = 64                             # matches RefreshWorker's corpus batch
TRAIN_ACCELS = ["edge", "mobile"]
DRIFT_ACCELS = ["laptop", "datacenter"]


def _setup(quick: bool) -> dict:
    """``ga`` is both the base-corpus teacher and the EVAL reference;
    ``refresh_ga`` is the refresh teacher — deliberately stronger, so the
    corpus the fine-tune imitates is at least as good as the reference
    the ratio is scored against (a refresh that imitates a weaker teacher
    cannot reach ratio 1.0 no matter how well it trains)."""
    if quick:
        return dict(workloads=[tiny_cnn()], budgets=[2.0, 6.0],
                    drift_budgets=[6.0, 16.0], max_steps=16, steps=240,
                    refresh_steps=400, n_phase=64, window=32,
                    ga=GSamplerConfig(population=32, generations=24, seed=0),
                    refresh_ga=GSamplerConfig(population=48, generations=40,
                                              seed=0))
    return dict(workloads=[vgg16(), resnet18()], budgets=[16.0, 32.0, 48.0],
                drift_budgets=[24.0, 40.0], max_steps=20, steps=600,
                refresh_steps=400, n_phase=128, window=32,
                ga=GSamplerConfig(seed=0),
                refresh_ga=GSamplerConfig(population=64, generations=72,
                                          seed=0))


def _train_base(su: dict, quick: bool):
    """The narrow base mapper: teacher corpus over the in-distribution
    grid ONLY (train accels, train budgets), served from its checkpoint;
    cached under artifacts/bench (delete to regenerate)."""
    cfg = DTConfig(max_steps=su["max_steps"], hw_dim=HW_FEATURE_DIM)
    accels = [ACCEL_ZOO[n] for n in TRAIN_ACCELS]
    mode = "quick" if quick else "full"
    ckpt_dir = pathlib.Path("artifacts/bench") / f"driftbase_ckpt_{mode}"

    def build():
        ds = generate_teacher_corpus(
            su["workloads"], accels, batch=BATCH, budgets_mb=su["budgets"],
            max_steps=su["max_steps"], ga_cfg=su["ga"], top_k=6, seed=0)
        params = dt_init(jax.random.PRNGKey(0), cfg)
        params, log = train_model(
            lambda p, b: dt_loss(p, cfg, b), params, ds,
            TrainConfig(steps=su["steps"], batch_size=16,
                        warmup=min(50, su["steps"] // 5), seed=0),
            ckpt_dir=ckpt_dir, resume=False)
        params = restore_params(ckpt_dir, params)
        return {"params": jax.device_get(params),
                "final_loss": log["final_loss"], "n_traj": len(ds)}

    art = load_or(f"driftbase_{mode}", build)
    return art, cfg


def make_stream(su: dict, n: int, seed: int, drift_frac: float) -> list:
    """Seeded request stream: each draw is drifted (novel accel at an
    unseen budget) with probability ``drift_frac``, else in-distribution.
    ``drift_frac=0`` is pure phase-A traffic."""
    rng = np.random.default_rng(seed)
    indist = [(w, ACCEL_ZOO[a], b) for w in su["workloads"]
              for a in TRAIN_ACCELS for b in su["budgets"]]
    drifted = [(w, ACCEL_ZOO[a], b) for w in su["workloads"]
               for a in DRIFT_ACCELS for b in su["drift_budgets"]]
    out = []
    for _ in range(n):
        pool = drifted if rng.random() < drift_frac else indist
        w, acc, b = pool[rng.integers(0, len(pool))]
        out.append(MapRequest(w, BATCH, b * MB, acc))
    return out


def serve_phase(engine, stream: list, worker=None) -> dict:
    """Serve one phase in fixed-width ticks; the closed-loop engine polls
    its worker between ticks (the §15 'off the request path' hook), so
    any refresh wall-time lands here, not on a request."""
    t0 = time.perf_counter()
    for i in range(0, len(stream), TICK):
        engine.serve(stream[i:i + TICK])
        if worker is not None:
            worker.poll()
    wall = time.perf_counter() - t0
    d = engine.stats()["drift"]
    return {"requests": len(stream), "wall_s": wall,
            "ms_per_request": wall * 1e3 / len(stream),
            "reports_fired": d["reports_fired"],
            "swaps_accepted": d["swaps_accepted"]}


def eval_ratios(params_by_name: dict, cfg, su: dict, max_conds: int = 6):
    """Score every distinct drifted condition: DT speedup (per candidate
    params) vs ONE fresh G-Sampler search per condition (shared across
    candidates, same GA budget the teachers used)."""
    conds = [(w, ACCEL_ZOO[a], b) for w in su["workloads"]
             for a in DRIFT_ACCELS for b in su["drift_budgets"]]
    if len(conds) > max_conds:
        idx = np.linspace(0, len(conds) - 1, max_conds).astype(int)
        conds = [conds[i] for i in idx]
    rows = []
    for w, acc, b in conds:
        env = FusionEnv(w, acc, batch=BATCH, budget_bytes=b * MB,
                        nmax=su["max_steps"])
        gs = gsampler_search(env, su["ga"], top_k=4)
        row = dict(workload=w.name, accel=acc.name, budget_mb=b,
                   teacher_speedup=gs.speedup, teacher_valid=gs.valid)
        for name, params in params_by_name.items():
            r = dnnfuser_infer(params, cfg, env)
            row[f"{name}_speedup"] = float(r.speedup)
            row[f"{name}_valid"] = bool(r.valid)
            row[f"{name}_ratio"] = (float(r.speedup) / gs.speedup
                                    if (r.valid and gs.valid) else 0.0)
        rows.append(row)
        print("  " + " vs ".join(
            f"{n} {fmt_speedup(row[f'{n}_speedup'], row[f'{n}_valid']):>5s}x"
            for n in params_by_name)
            + f" vs G-Sampler {fmt_speedup(gs.speedup, gs.valid):>5s}x  "
            f"[{w.name} @ {acc.name} {b:.0f}MB]")
    means = {name: float(np.mean([r[f"{name}_ratio"] for r in rows]))
             for name in params_by_name}
    return rows, means


def run(quick: bool = False, out: str = "BENCH_drift.json") -> list:
    su = _setup(quick)
    art, cfg = _train_base(su, quick)
    base_params = art["params"]
    print(f"base mapper: {art['n_traj']} teacher trajectories over "
          f"{TRAIN_ACCELS} x {su['budgets']}MB, imitation loss "
          f"{art['final_loss']:.4f}; drift -> {DRIFT_ACCELS} x "
          f"{su['drift_budgets']}MB")

    config = ServingConfig(
        known_accels=tuple(TRAIN_ACCELS),
        known_workloads=tuple(w.name for w in su["workloads"]),
        drift=DriftConfig(window=su["window"]))
    engines = {
        "closed_loop": MapperEngine.from_config(base_params, cfg, config),
        "frozen": MapperEngine.from_config(base_params, cfg, config),
    }
    worker = RefreshWorker(
        engines["closed_loop"],
        train=TrainConfig(steps=su["refresh_steps"], batch_size=16,
                          lr=3e-4, warmup=min(40, su["refresh_steps"] // 5)),
        ga=su["refresh_ga"], batch=BATCH, top_k=2, seed=1)
    workers = {"closed_loop": worker, "frozen": None}

    streams = {"A": make_stream(su, su["n_phase"], seed=0, drift_frac=0.0),
               "B": make_stream(su, su["n_phase"], seed=1, drift_frac=0.75),
               "C": make_stream(su, su["n_phase"], seed=2, drift_frac=0.75)}
    probe_req = streams["A"][0]          # a non-drifted key to pin bit-exact

    phases, compiles, bit_exact = {}, {}, {}
    for name, eng in engines.items():
        eng.warmup([w for w in su["workloads"]], ACCEL_ZOO["edge"],
                   max_tick=TICK)
        phases[name] = {"A": serve_phase(eng, streams["A"], workers[name])}
        pre = eng.serve([probe_req])[0]              # cached from phase A
        before = eng.compile_count
        phases[name]["B"] = serve_phase(eng, streams["B"], workers[name])
        phases[name]["C"] = serve_phase(eng, streams["C"], workers[name])
        compiles[name] = eng.compile_count - before  # across the hot swap
        post = eng.serve([probe_req])[0]
        bit_exact[name] = bool(post.cached and
                               np.array_equal(pre.strategy, post.strategy))
        d = eng.stats()["drift"]
        print(f"{name:11s}: {d['reports_fired']} drift reports, "
              f"{d['swaps_accepted']} swaps accepted, "
              f"{d['cache_invalidated']} cache entries invalidated, "
              f"{compiles[name]} steady compiles across B+C, "
              f"non-drifted bit-exact={bit_exact[name]}")

    print("eval: distinct drifted conditions vs fresh G-Sampler")
    rows, means = eval_ratios(
        {"closed_loop": engines["closed_loop"].params,
         "frozen": base_params}, cfg, su)
    closed_stats = engines["closed_loop"].stats()["drift"]
    report = {
        "bench": "drift",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "n_phase": su["n_phase"],
        "tick": TICK,
        "window": su["window"],
        "drift_frac": 0.75,
        "train_accels": TRAIN_ACCELS,
        "drift_accels": DRIFT_ACCELS,
        "train_budgets_mb": su["budgets"],
        "drift_budgets_mb": su["drift_budgets"],
        "imitation_loss": art["final_loss"],
        "phases": phases,
        "drift_stats": {k: closed_stats[k] for k in
                        ("windows_evaluated", "reports_fired",
                         "swaps_accepted", "swaps_rejected",
                         "cache_invalidated", "baseline_hit_rate")},
        "refresh": worker.last_result,
        "steady_new_compiles": compiles,
        "non_drifted_bit_exact": bit_exact,
        "results": rows,
        "closed_ratio": means["closed_loop"],
        "frozen_ratio": means["frozen"],
        "recovery_gap": means["closed_loop"] - means["frozen"],
    }
    print(f"drifted-region DT/G-Sampler ratio: closed-loop "
          f"{report['closed_ratio']:.3f} vs frozen "
          f"{report['frozen_ratio']:.3f} "
          f"(recovery gap {report['recovery_gap']:+.3f})")
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")
    if report["drift_stats"]["swaps_accepted"] < 1:
        # RuntimeError, not SystemExit: benchmarks/run.py isolates suite
        # failures with `except Exception` and must keep running
        raise RuntimeError(
            "the closed loop never accepted a swap — drift either did not "
            f"fire ({report['drift_stats']['reports_fired']} reports) or "
            f"every candidate was gated out ({worker.last_result})")
    mode = "quick" if quick else "full"
    return [(f"drift_closed_loop_{mode}",
             phases["closed_loop"]["C"]["ms_per_request"] * 1e3,
             f"ratio={report['closed_ratio']:.2f}"),
            (f"drift_frozen_{mode}",
             phases["frozen"]["C"]["ms_per_request"] * 1e3,
             f"ratio={report['frozen_ratio']:.2f}")]


def check_regression(report: dict, baseline_path: str, tol: float,
                     min_ratio: float, min_gap: float) -> list:
    """Gate rules (empty list = pass).  Quality gates are DT/G-Sampler
    ratios measured ON THIS machine; only the latency gate is relative to
    the committed baseline (with a generous tolerance)."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    failures = []
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline"]
    if report["drift_stats"]["swaps_accepted"] < 1:
        failures.append("no accepted hot swap: drift_stats="
                        f"{report['drift_stats']}")
    if report["closed_ratio"] < min_ratio:
        failures.append(
            f"closed-loop drifted-region ratio {report['closed_ratio']:.3f} "
            f"< {min_ratio:.2f} — the refresh did not recover "
            f"teacher-level quality")
    if report["frozen_ratio"] > report["closed_ratio"] - min_gap:
        failures.append(
            f"frozen ratio {report['frozen_ratio']:.3f} is within "
            f"{min_gap:.2f} of closed-loop {report['closed_ratio']:.3f} — "
            f"the drift stream is not actually out-of-distribution")
    for name, n in report["steady_new_compiles"].items():
        if n != 0:
            failures.append(f"{name}: {n} steady-state recompiles across "
                            f"the drift phases (hot swap must not recompile)")
    for name, ok in report["non_drifted_bit_exact"].items():
        if not ok:
            failures.append(f"{name}: non-drifted cached response changed "
                            f"across the swap (§15 bit-exactness contract)")
    new = report["phases"]["closed_loop"]["C"]["ms_per_request"]
    old = (base.get("phases", {}).get("closed_loop", {}).get("C", {})
           .get("ms_per_request"))
    if old is None:
        failures.append(f"baseline {baseline_path} has no closed_loop "
                        f"phase-C ms_per_request — regenerate it")
    elif new > old * tol:
        failures.append(f"closed_loop post-swap ms_per_request: {new:.2f} > "
                        f"{tol:.1f}x baseline {old:.2f}")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: tiny workload, small GA, short training")
    ap.add_argument("--out", default="BENCH_drift.json")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) on regression vs this baseline")
    ap.add_argument("--tol", type=float, default=2.5,
                    help="allowed post-swap latency ratio vs the baseline")
    ap.add_argument("--min-ratio", type=float, default=0.98,
                    help="required closed-loop drifted-region DT/G-Sampler "
                         "ratio")
    ap.add_argument("--min-gap", type=float, default=0.02,
                    help="required closed-loop margin over the frozen "
                         "baseline")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_drift_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    run(quick=args.quick, out=args.out)
    report = json.loads(pathlib.Path(args.out).read_text())
    if args.check:
        failures = check_regression(report, args.check, args.tol,
                                    args.min_ratio, args.min_gap)
        if failures:
            print("DRIFT REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"drift gate OK (closed >= {args.min_ratio}, gap >= "
              f"{args.min_gap}, zero swap recompiles, bit-exact non-drifted "
              f"vs {args.check})")


if __name__ == "__main__":
    main()
