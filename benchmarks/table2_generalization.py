"""Table 2: generalization to unseen memory conditions (paper §5.3).

DNNFuser/Seq2Seq trained at 16/32/48/64 MB on VGG16 and ResNet18; evaluated
one-shot at the unseen interpolated conditions 20..45 MB vs a full
G-Sampler search per condition.
"""
from __future__ import annotations

from repro.core import dnnfuser_infer, gsampler_search, s2s_infer
from repro.workloads import resnet18, vgg16

from . import common as C

UNSEEN = [20.0, 25.0, 30.0, 35.0, 40.0, 45.0]


def run(quick: bool = False):
    rows = []
    conds = UNSEEN[:3] if quick else UNSEEN
    print("\n=== Table 2: unseen memory conditions (batch 64)")
    print(f"{'cond':>6s} | {'VGG16':^24s} | {'ResNet18':^24s}")
    print(f"{'MB':>6s} | {'DF':>6s} {'S2S':>6s} {'GS':>8s} |"
          f" {'DF':>6s} {'S2S':>6s} {'GS':>8s}")
    per_wl = {}
    for wl_fn, name in [(vgg16, "vgg16"), (resnet18, "resnet18")]:
        wl = wl_fn()
        ds = C.teacher_dataset([wl], 64, C.TRAIN_BUDGETS, 20,
                               f"{name}_b64")
        dtp, dtc, _ = C.train_dt(ds, f"{name}_b64", max_steps=20)
        s2p, s2c, _ = C.train_s2s(ds, f"{name}_b64", max_steps=20)
        per_wl[name] = (wl, dtp, dtc, s2p, s2c)
    for cond in conds:
        cols = []
        for name in ("vgg16", "resnet18"):
            wl, dtp, dtc, s2p, s2c = per_wl[name]
            env = C.env_for(wl, 64, cond, max_steps=20)
            df = dnnfuser_infer(dtp, dtc, env)
            s2 = s2s_infer(s2p, s2c, env)
            gs = gsampler_search(env)
            cols.append((df, s2, gs))
            rows.append((f"table2/{name}/{int(cond)}MB",
                         df.wall_s * 1e6,
                         f"df={C.fmt_speedup(df.speedup, df.valid)};"
                         f"s2s={C.fmt_speedup(s2.speedup, s2.valid)};"
                         f"gs={gs.speedup:.2f}"))
        (df1, s21, gs1), (df2, s22, gs2) = cols
        print(f"{cond:6.0f} | {df1.speedup:6.2f} {s21.speedup:6.2f} "
              f"{gs1.speedup:8.2f} | {df2.speedup:6.2f} {s22.speedup:6.2f} "
              f"{gs2.speedup:8.2f}")
    return rows


if __name__ == "__main__":
    run()
