"""Kernel-layer benchmark: population cost-model evaluation throughput.

Three implementations of the paper's search hot loop:
  naive   — per-candidate Python loop (ref_model; the paper's regime),
  vmapped — one jitted vmap over the population (our G-Sampler's engine),
  pallas  — the fusion_eval kernel (interpret mode on CPU; on TPU this is
            the deployable path with the layer table VMEM-resident).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import PAPER_ACCEL, cost_model as cm
from repro.core import ref_model
from repro.kernels import fusion_eval_population
from repro.workloads import resnet18

from . import common as C


def run(quick: bool = False):
    hw = PAPER_ACCEL
    wl_obj = resnet18()
    wl = cm.pack_workload(wl_obj, hw, nmax=64)
    wl_np = {k: np.asarray(v) for k, v in wl.items()}
    rng = np.random.default_rng(0)
    pop_n = 512 if quick else 2048
    pop = np.stack([cm.random_strategy(rng, wl_obj.n, 64, 64)
                    for _ in range(pop_n)])
    budget = 20.0 * C.MB

    n_naive = min(pop_n, 64)
    t0 = time.perf_counter()
    for s in pop[:n_naive]:
        ref_model.evaluate_ref(wl_np, s, 64, budget, hw)
    t_naive = (time.perf_counter() - t0) / n_naive * pop_n

    out = cm.evaluate_population(wl, jnp.asarray(pop), 64.0, budget, hw)
    out.latency.block_until_ready()
    t0 = time.perf_counter()
    out = cm.evaluate_population(wl, jnp.asarray(pop), 64.0, budget, hw)
    out.latency.block_until_ready()
    t_vmap = time.perf_counter() - t0

    lat, _, _ = fusion_eval_population(pop, wl, batch=64.0, hw=hw)
    lat.block_until_ready()
    t0 = time.perf_counter()
    lat, _, _ = fusion_eval_population(pop, wl, batch=64.0, hw=hw)
    lat.block_until_ready()
    t_pl = time.perf_counter() - t0

    print("\n=== fusion_eval kernel: population evaluation "
          f"(pop={pop_n}, resnet18)")
    print(f"naive python : {t_naive*1e3:9.1f} ms  (1.0x)")
    print(f"vmapped jit  : {t_vmap*1e3:9.1f} ms  ({t_naive/t_vmap:7.0f}x)")
    print(f"pallas(intrp): {t_pl*1e3:9.1f} ms  (interpret-mode CPU; "
          "TPU path keeps the layer table in VMEM)")
    return [("fusion_eval/naive", t_naive / pop_n * 1e6, "per_candidate"),
            ("fusion_eval/vmapped", t_vmap / pop_n * 1e6,
             f"speedup={t_naive/t_vmap:.0f}x"),
            ("fusion_eval/pallas_interpret", t_pl / pop_n * 1e6,
             "cpu_interpret")]


if __name__ == "__main__":
    run()
