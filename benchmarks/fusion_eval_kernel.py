"""Kernel-layer benchmark: population cost-model evaluation throughput
under the traced-hardware contract (DESIGN §13).

Three implementations of the paper's search hot loop:
  naive   — per-candidate Python loop (ref_model; the paper's regime),
  vmapped — one jitted vmap over the population (the XLA evaluator),
  pallas  — the fusion_eval block kernel (interpret mode on CPU; on TPU
            this is the deployable path with the layer table VMEM-resident),
plus the production grid form: one ``evaluate_grid`` call over a
(workload x ACCEL_ZOO x budget) condition block on each backend.

Beyond wall clock, the run records the SEMANTIC gates of §13 and the
committed ``BENCH_kernel.json`` baseline pins them:
  - ``zoo_bitwise_match``: the pallas backend must be bit-identical to the
    XLA evaluator on every zoo accelerator, including the BPE-mismatched
    ones (pack-time int8 served on a 2-byte datacenter part) — the property
    the backend-switchable teacher pipeline rests on;
  - ``sweep_compiles``: sweeping all zoo accelerators at a fixed block
    shape must reuse ONE compiled program (the accelerator is traced
    kernel data, not a static argument).

``--check BASELINE.json`` turns the harness into a regression gate in the
style of ``bench_infer.py``: wall-clock metrics are ratio-gated (machines
differ; ``--tol``), the semantic gates are hard.

    PYTHONPATH=src python benchmarks/fusion_eval_kernel.py [--quick]
        [--out BENCH_kernel.json] [--check BASELINE.json] [--tol 4.0]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core import PAPER_ACCEL, cost_model as cm
from repro.core import ref_model
from repro.core.accel import ACCEL_ZOO
from repro.kernels import fusion_eval
from repro.workloads import resnet18

MB = float(2 ** 20)

GATED_METRICS = ("vmapped_us_per_cand", "pallas_us_per_cand",
                 "grid_pallas_us_per_cand")


def _timeit(fn, reps: int = 5) -> float:
    fn()                                   # warm the jit cache
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _costout_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def run(quick: bool = False, out: str | None = None) -> list:
    """Suite entry point for ``benchmarks.run`` (CSV rows only)."""
    rows, _ = run_report(quick=quick, out=out)
    return rows


def run_report(quick: bool = False, out: str | None = None):
    hw = PAPER_ACCEL
    wl_obj = resnet18()
    wl = cm.pack_workload(wl_obj, hw, nmax=64)
    wl_np = {k: np.asarray(v) for k, v in wl.items()}
    rng = np.random.default_rng(0)
    pop_n = 512 if quick else 2048
    pop = np.stack([cm.random_strategy(rng, wl_obj.n, 64, 64)
                    for _ in range(pop_n)])
    budget = 20.0 * MB
    popj = jnp.asarray(pop)

    # --- naive python reference (subset, extrapolated) ----------------------
    n_naive = min(pop_n, 64)
    t0 = time.perf_counter()
    for s in pop[:n_naive]:
        ref_model.evaluate_ref(wl_np, s, 64, budget, hw)
    t_naive = (time.perf_counter() - t0) / n_naive * pop_n

    # --- vmapped XLA evaluator ----------------------------------------------
    t_vmap = _timeit(lambda: cm.evaluate_population(
        wl, popj, 64.0, budget, hw).latency.block_until_ready())

    # --- pallas kernel (same CostOut contract) ------------------------------
    t_pl = _timeit(lambda: fusion_eval.fusion_eval_population(
        popj, wl, batch=64.0, budget_bytes=budget,
        hw=hw).latency.block_until_ready())

    # --- §13 semantic gates: zoo-wide bit parity + one-program hw sweep -----
    cache_size = getattr(fusion_eval._fusion_eval_grid_jit, "_cache_size",
                         lambda: -1)
    before = cache_size()
    zoo_match = True
    for acc in ACCEL_ZOO.values():                 # same block shape each time
        got = fusion_eval.fusion_eval_population(
            popj, wl, batch=64.0, budget_bytes=budget, hw=acc)
        want = cm.evaluate_population(wl, popj, 64.0, budget, acc)
        zoo_match &= _costout_equal(got, want)
    sweep_compiles = cache_size() - before if before >= 0 else -1

    # --- production grid form: one call over (workload x zoo x budget) ------
    accels = list(ACCEL_ZOO.values())
    Cn = len(accels)
    grid_pop = 128 if quick else 512
    wls = cm.stack_workloads([cm.pack_workload(wl_obj, a, 64)
                              for a in accels])
    strats = jnp.asarray(pop[:grid_pop])[None].repeat(Cn, axis=0)
    batches = jnp.full((Cn,), 64.0, jnp.float32)
    budgets = jnp.asarray(np.linspace(12, 48, Cn) * MB, np.float32)
    t_grid_x = _timeit(lambda: cm.evaluate_grid(
        wls, strats, batches, budgets, accels,
        evaluator="xla").latency.block_until_ready())
    t_grid_p = _timeit(lambda: cm.evaluate_grid(
        wls, strats, batches, budgets, accels,
        evaluator="pallas").latency.block_until_ready())
    n_grid = Cn * grid_pop

    print("\n=== fusion_eval kernel: population evaluation "
          f"(pop={pop_n}, resnet18, traced hw)")
    print(f"naive python : {t_naive*1e3:9.1f} ms  (1.0x)")
    print(f"vmapped jit  : {t_vmap*1e3:9.1f} ms  ({t_naive/t_vmap:7.0f}x)")
    print(f"pallas(intrp): {t_pl*1e3:9.1f} ms  (interpret-mode CPU; "
          "TPU path keeps the layer table in VMEM)")
    print(f"grid [{Cn}x{grid_pop}] xla {t_grid_x*1e3:7.1f} ms | pallas "
          f"{t_grid_p*1e3:7.1f} ms")
    print(f"zoo bit parity: {'OK' if zoo_match else 'BROKEN'} | hw-sweep "
          f"compiles: {sweep_compiles}")

    report = {
        "bench": "kernel",
        "device": __import__("jax").devices()[0].platform,
        "quick": quick,
        "results": {
            "workload": wl_obj.name,
            "pop": pop_n,
            "naive_us_per_cand": t_naive / pop_n * 1e6,
            "vmapped_us_per_cand": t_vmap / pop_n * 1e6,
            "pallas_us_per_cand": t_pl / pop_n * 1e6,
            "grid_conditions": Cn,
            "grid_pop": grid_pop,
            "grid_xla_us_per_cand": t_grid_x / n_grid * 1e6,
            "grid_pallas_us_per_cand": t_grid_p / n_grid * 1e6,
            "zoo_bitwise_match": bool(zoo_match),
            "sweep_compiles": int(sweep_compiles),
        },
    }
    path = pathlib.Path(out or "artifacts/bench/BENCH_kernel_last.json")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")

    r = report["results"]
    return [("fusion_eval/naive", r["naive_us_per_cand"], "per_candidate"),
            ("fusion_eval/vmapped", r["vmapped_us_per_cand"],
             f"speedup={t_naive/t_vmap:.0f}x"),
            ("fusion_eval/pallas_interpret", r["pallas_us_per_cand"],
             f"zoo_bitwise={zoo_match}"),
            ("fusion_eval/grid_pallas", r["grid_pallas_us_per_cand"],
             f"compiles={sweep_compiles}")], report


def check_regression(report: dict, baseline_path: str, tol: float) -> list:
    """bench_infer-style gate: wall metrics ratio-gated by ``tol``; the §13
    semantic fields (bit parity, one-program hw sweep) are hard gates."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    if base.get("quick") != report.get("quick"):
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline in "
                f"the same mode"]
    ref, new = base.get("results", {}), report["results"]
    failures, compared = [], 0
    for metric in GATED_METRICS:
        if metric not in ref:
            continue
        compared += 1
        if new[metric] > ref[metric] * tol:
            failures.append(f"{metric}: {new[metric]:.2f} us > {tol:.1f}x "
                            f"baseline {ref[metric]:.2f} us")
    if not new.get("zoo_bitwise_match", False):
        failures.append("zoo_bitwise_match is False — the pallas evaluator "
                        "diverged from the XLA cost model (DESIGN §13)")
    if "sweep_compiles" in ref and ref["sweep_compiles"] >= 0:
        if new["sweep_compiles"] < 0:
            # a hard gate that cannot measure must not go silently green
            failures.append("sweep_compiles could not be measured (jit "
                            "cache introspection unavailable) while the "
                            "baseline pins it — re-point the probe or "
                            "regenerate the baseline")
        elif new["sweep_compiles"] > max(ref["sweep_compiles"], 0):
            failures.append(f"hw sweep compiled {new['sweep_compiles']} "
                            f"programs (baseline {ref['sweep_compiles']}) — "
                            f"the accelerator went back to being a static "
                            f"argument")
    if compared == 0:
        failures.append(f"no comparable metrics between this run and "
                        f"{baseline_path} — regenerate the baseline")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller population (CI smoke)")
    ap.add_argument("--out", default="BENCH_kernel.json")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) on perf regression vs --tol x this "
                         "baseline, or on any §13 semantic-gate break")
    ap.add_argument("--tol", type=float, default=4.0,
                    help="allowed wall-clock ratio vs baseline (default 4)")
    args = ap.parse_args()
    out = args.out
    if args.check and pathlib.Path(out).resolve() == \
            pathlib.Path(args.check).resolve():
        out = "artifacts/bench/BENCH_kernel_check.json"
    _, report = run_report(quick=args.quick, out=out)
    if args.check:
        failures = check_regression(report, args.check, args.tol)
        if failures:
            print("KERNEL GATE FAILED vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"kernel gate OK (tol {args.tol}x vs {args.check})")


if __name__ == "__main__":
    main()
