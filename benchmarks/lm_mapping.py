"""Beyond-paper: the fusion mapper on the assigned LM architectures.

Each ArchConfig is lowered to a block-granularity fusion workload
(workloads/lm_workloads.py) and mapped by G-Sampler and by a DNNFuser
transferred from the CNN general model — demonstrating the paper's central
claim (generalizable mapping knowledge) on transformer/MoE/SSM graphs the
paper never saw.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import dnnfuser_infer, gsampler_search
from repro.workloads.lm_workloads import lm_workload

from . import common as C

ARCHS = ["gemma3_1b", "qwen3_8b", "qwen3_moe_235b", "rwkv6_3b", "hymba_15b"]


def run(quick: bool = False):
    rows = []
    archs = ARCHS[:3] if quick else ARCHS
    print("\n=== Beyond-paper: fusion mapping of assigned LM archs "
          "(prefill, seq 4096, batch 32, budget 48MB)")
    # transfer the CNN general mapper to LM graphs with a short fine-tune
    gen = C.cache("dt_general_T56")
    for arch in archs:
        cfg = get_config(arch)
        wl = lm_workload(cfg, seq_len=4096, batch=32, mode="prefill")
        env = C.env_for(wl, 32, 48.0, max_steps=128)
        gs = gsampler_search(env)
        line = (f"{arch:16s}: GS speedup {gs.speedup:5.2f} "
                f"(usage {gs.peak_mem/C.MB:5.1f}MB, groups from "
                f"{wl.n} blocks)")
        derived = f"gs={gs.speedup:.2f};usage_mb={gs.peak_mem/C.MB:.1f}"
        if gen.exists():
            ds = C.teacher_dataset([wl], 32, [24.0, 48.0], 128,
                                   f"lm_{arch}")
            gp, gc, _ = C.train_dt(ds, f"lm_{arch}", max_steps=128,
                                   steps=20 if quick else 60)
            df = dnnfuser_infer(gp, gc, env)
            line += f" | Transfer-DF {C.fmt_speedup(df.speedup, df.valid)}"
            derived += f";df={C.fmt_speedup(df.speedup, df.valid)}"
        print(line)
        rows.append((f"lm_mapping/{arch}", gs.wall_s * 1e6, derived))
    return rows


if __name__ == "__main__":
    run()
