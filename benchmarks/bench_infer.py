"""Perf-regression harness for one-shot inference (DESIGN.md §9).

Times the three serving paths on vgg16/resnet18 and writes
``BENCH_infer.json`` so later PRs have a wall-clock baseline to not
regress:

 - ``host_ms``:   the Python-loop reference rollout (N+1 jitted full-sequence
                  forwards + full cost-model prefix evaluations, NumPy
                  round-trips every step);
 - ``fused_ms``:  the device-resident ``lax.scan`` rollout (KV-cached decode
                  + O(1) ``prefix_step`` env transition + on-device budget
                  guard), one device call per episode;
 - ``batch``:     ``dnnfuser_infer_batch`` serving a stacked grid of
                  (batch, budget) conditions in ONE device call — reported
                  as conditions/sec.

Weights are random-init (timing does not depend on training); all numbers
are post-jit steady-state medians.

``--check BASELINE.json`` turns the harness into a regression GATE: after
timing, the fused-rollout and batched-serving latencies are compared
per-workload against the committed baseline and the process exits non-zero
if any exceeds ``--tol`` x baseline — the CI perf job runs
``--quick --check BENCH_infer.json`` so a fused-path regression fails the
build instead of hiding in a JSON artifact.

    PYTHONPATH=src python benchmarks/bench_infer.py [--quick] [--out PATH]
        [--check BASELINE.json] [--tol 2.5]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

import jax
import numpy as np

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, dt_init,
                        dnnfuser_infer, dnnfuser_infer_fused,
                        dnnfuser_infer_batch)
from repro.workloads import resnet18, vgg16

MB = float(2 ** 20)


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def bench_workload(wl, params, cfg, *, budget_mb: float, batch: int,
                   n_conditions: int, reps: int) -> dict:
    env = FusionEnv(wl, PAPER_ACCEL, batch=batch, budget_bytes=budget_mb * MB,
                    nmax=cfg.max_steps)
    # warm the jit caches
    host = dnnfuser_infer(params, cfg, env)
    fused = dnnfuser_infer_fused(params, cfg, env)
    assert (host.strategy == fused.strategy).all(), \
        "fused rollout diverged from host reference"

    t_host = _median_time(lambda: dnnfuser_infer(params, cfg, env),
                          max(2, reps // 3))
    t_fused = _median_time(lambda: dnnfuser_infer_fused(params, cfg, env),
                           reps)

    batches = np.full(n_conditions, float(batch), np.float32)
    budgets = (np.linspace(8.0, 64.0, n_conditions) * MB).astype(np.float32)
    dnnfuser_infer_batch(params, cfg, env, batches, budgets)   # warm
    t_batch = _median_time(
        lambda: dnnfuser_infer_batch(params, cfg, env, batches, budgets),
        max(2, reps // 2))

    return {
        "workload": wl.name,
        "n_layers": wl.n,
        "batch": batch,
        "budget_mb": budget_mb,
        "host_ms": t_host * 1e3,
        "fused_ms": t_fused * 1e3,
        "fused_speedup_x": t_host / t_fused,
        "batch_conditions": n_conditions,
        "batch_ms": t_batch * 1e3,
        "batch_conditions_per_s": n_conditions / t_batch,
        "batch_ms_per_condition": t_batch * 1e3 / n_conditions,
    }


def run(quick: bool = False, out: str = "BENCH_infer.json") -> dict:
    cfg = DTConfig(max_steps=20)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    reps = 3 if quick else 10
    n_conditions = 32 if quick else 64
    rows = []
    for wl_fn in (vgg16, resnet18):
        r = bench_workload(wl_fn(), params, cfg, budget_mb=20.0, batch=64,
                           n_conditions=n_conditions, reps=reps)
        rows.append(r)
        print(f"{r['workload']:9s}: host {r['host_ms']:7.1f} ms | fused "
              f"{r['fused_ms']:6.2f} ms ({r['fused_speedup_x']:5.1f}x) | "
              f"batch[{n_conditions}] {r['batch_ms']:7.1f} ms = "
              f"{r['batch_conditions_per_s']:7.1f} cond/s")
    report = {
        "bench": "infer",
        "device": jax.devices()[0].platform,
        "quick": quick,
        "results": rows,
    }
    path = pathlib.Path(out)
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {path}")
    return report


GATED_METRICS = ("fused_ms", "batch_ms_per_condition")


def check_regression(report: dict, baseline_path: str, tol: float) -> list:
    """Compare ``report`` to the committed baseline; returns a list of
    human-readable failures (empty = gate passes).

    Only the device-resident serving metrics are gated (``GATED_METRICS``);
    the host-reference path is informational.  ``tol`` is a ratio — CI
    machines differ from the machine that wrote the baseline, so the gate
    catches order-of-magnitude regressions (a lost jit cache, an accidental
    host sync in the scan), not single-percent noise."""
    base = json.loads(pathlib.Path(baseline_path).read_text())
    if base.get("quick") != report.get("quick"):
        # quick and full runs amortize dispatch overhead over different
        # condition counts — comparing across modes quietly skews the margin
        return [f"baseline {baseline_path} was written with "
                f"quick={base.get('quick')} but this run used "
                f"quick={report.get('quick')}; regenerate the baseline in "
                f"the same mode"]
    by_wl = {r["workload"]: r for r in base.get("results", [])}
    failures, compared = [], 0
    for row in report["results"]:
        ref = by_wl.get(row["workload"])
        if ref is None:
            continue
        for metric in GATED_METRICS:
            if metric not in ref:
                continue
            compared += 1
            new, old = row[metric], ref[metric]
            if new > old * tol:
                failures.append(
                    f"{row['workload']}.{metric}: {new:.2f} ms > "
                    f"{tol:.1f}x baseline {old:.2f} ms")
    if compared == 0:
        # a gate that compares nothing must not go green: a renamed
        # workload / truncated baseline would otherwise disable the gate
        failures.append(
            f"no comparable (workload, metric) pairs between this run and "
            f"{baseline_path} — regenerate the baseline")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps / conditions (CI smoke)")
    ap.add_argument("--out", default="BENCH_infer.json")
    ap.add_argument("--check", metavar="BASELINE",
                    help="fail (exit 1) if serving latency regresses more "
                         "than --tol x this baseline JSON")
    ap.add_argument("--tol", type=float, default=2.5,
                    help="allowed ratio vs the baseline (default 2.5)")
    args = ap.parse_args()
    if args.check and pathlib.Path(args.out).resolve() == \
            pathlib.Path(args.check).resolve():
        args.out = "artifacts/bench/BENCH_infer_check.json"
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    report = run(quick=args.quick, out=args.out)
    if args.check:
        failures = check_regression(report, args.check, args.tol)
        if failures:
            print("PERF REGRESSION vs", args.check)
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print(f"perf gate OK (tol {args.tol}x vs {args.check})")


if __name__ == "__main__":
    main()
