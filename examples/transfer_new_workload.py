"""Transfer learning (paper §5.4): adapt the general mapper to a NEW
workload with 10% of the training.

    PYTHONPATH=src python examples/transfer_new_workload.py
"""
import jax

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, TrainConfig,
                        collect_teacher_data, dnnfuser_infer, dt_init,
                        dt_loss, gsampler_search, train_model)
from repro.workloads import mnasnet_b1, resnet18, vgg16

MB = 2 ** 20
T = 56


def main():
    print("pre-training the general mapper on VGG16 + ResNet18 ...")
    ds_gen = collect_teacher_data([vgg16(), resnet18()], PAPER_ACCEL,
                                  batch=64, budgets_mb=[16, 32, 48, 64],
                                  max_steps=T)
    cfg = DTConfig(max_steps=T)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, _ = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds_gen,
                            TrainConfig(steps=300, batch_size=16))

    print("transfer: fine-tuning on MnasNet with 10% of the steps ...")
    wl = mnasnet_b1()
    ds_new = collect_teacher_data([wl], PAPER_ACCEL, batch=64,
                                  budgets_mb=[25, 45], max_steps=T)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params,
                              ds_new, TrainConfig(steps=30, batch_size=16,
                                                  lr=1e-4))
    print(f"fine-tune loss {log['final_loss']:.4f} in {log['wall_s']:.0f}s")

    for cond in (25.0, 35.0, 55.0):
        env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=cond * MB,
                        nmax=T)
        df = dnnfuser_infer(params, cfg, env)
        gs = gsampler_search(env)
        print(f"  {cond:4.0f}MB: Transfer-DF "
              f"{df.speedup:5.2f}x (valid={df.valid})  vs  GS full search "
              f"{gs.speedup:5.2f}x")


if __name__ == "__main__":
    main()
