"""Transfer learning (paper §5.4): adapt the general mapper to a NEW
workload with ~10% of the training, warm-started from a checkpoint.

    PYTHONPATH=src python examples/transfer_new_workload.py

Pre-training uses the device-grid teacher (one fused GA program over the
VGG16/ResNet18 x budget grid) and the sharded imitation trainer, and
checkpoints under ``artifacts/transfer_pretrain`` — re-runs skip straight
to fine-tuning.  ``fine_tune`` then warm-starts from that checkpoint on an
MnasNet corpus with unseen budget conditions.
"""
import jax

from repro.checkpoint import Checkpointer
from repro.core import (DTConfig, FusionEnv, GSamplerConfig, PAPER_ACCEL,
                        TrainConfig, dnnfuser_infer_fused, dt_init, dt_loss,
                        fine_tune, generate_teacher_corpus, gsampler_search,
                        train_model)
from repro.distributed.sharding import data_parallel_mesh
from repro.workloads import mnasnet_b1, resnet18, vgg16

MB = 2 ** 20
T = 56
CKPT = "artifacts/transfer_pretrain"


def main():
    cfg = DTConfig(max_steps=T)
    loss_fn = lambda p, b: dt_loss(p, cfg, b)
    mesh = data_parallel_mesh()

    print("pre-training the general mapper on VGG16 + ResNet18 "
          "(grid teacher, sharded trainer; resumes from checkpoint) ...")
    if (Checkpointer(CKPT).latest_step() or 0) >= 300:
        print(f"  checkpoint {CKPT} complete; skipping teacher + training")
    else:
        ds_gen = generate_teacher_corpus(
            [vgg16(), resnet18()], PAPER_ACCEL, batch=64,
            budgets_mb=[16, 32, 48, 64], max_steps=T, seed=0)
        _, log = train_model(
            loss_fn, dt_init(jax.random.PRNGKey(0), cfg), ds_gen,
            TrainConfig(steps=300, batch_size=16, ckpt_every=150),
            mesh=mesh, ckpt_dir=CKPT)
        print(f"  {len(ds_gen)} teacher trajectories; "
              f"start_step={log['start_step']}, "
              f"final loss {log['final_loss']}")

    print("transfer: fine-tuning on MnasNet with 10% of the steps ...")
    wl = mnasnet_b1()
    ds_new = generate_teacher_corpus([wl], PAPER_ACCEL, batch=64,
                                     budgets_mb=[25, 45], max_steps=T,
                                     seed=1)
    params, log = fine_tune(
        loss_fn, CKPT, ds_new,
        TrainConfig(steps=30, batch_size=16, lr=1e-4, warmup=5),
        template=dt_init(jax.random.PRNGKey(0), cfg), mesh=mesh)
    print(f"fine-tune loss {log['final_loss']:.4f} in {log['wall_s']:.0f}s")

    for cond in (25.0, 35.0, 55.0):
        env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=cond * MB,
                        nmax=T)
        df = dnnfuser_infer_fused(params, cfg, env)
        gs = gsampler_search(env)
        print(f"  {cond:4.0f}MB: Transfer-DF "
              f"{df.speedup:5.2f}x (valid={df.valid})  vs  GS full search "
              f"{gs.speedup:5.2f}x")


if __name__ == "__main__":
    main()
