"""Batched serving demo: prefill + greedy decode with donated KV caches.

    PYTHONPATH=src python examples/serve_llm.py [--arch qwen3_8b]

Runs the reduced config on CPU; the identical ``steps.build_prefill`` /
``build_decode_step`` pair is what the multi-pod dry-run lowers for the
production meshes (including seq-sharded caches for long contexts).
"""
import argparse

from repro.launch.serve import serve_greedy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    args = ap.parse_args()
    out = serve_greedy(args.arch, batch=4, prompt_len=32, gen_len=16)
    print(f"arch={args.arch}: prefill {out['t_prefill_s']*1e3:.0f} ms, "
          f"decode {out['tok_per_s']:.1f} tok/s")
    print("sampled tokens[0]:", out["tokens"][0].tolist())


if __name__ == "__main__":
    main()
