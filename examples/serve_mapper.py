"""Batched mapper serving: many (batch, budget, accel) conditions, ONE call.

    PYTHONPATH=src python examples/serve_mapper.py [--conditions 48]

A deployed mapper service answers streams of queries like "map VGG16 under
a 20 MB buffer at batch 32 on a mobile-class NPU" — each a full one-shot
rollout.  The device-resident serving primitive ``dnnfuser_infer_batch``
(DESIGN.md §9, §11) vmaps the fused scan rollout over a stacked grid of
conditions — batch size, memory budget AND the accelerator itself ride
per-row traced vectors — so the whole heterogeneous request batch costs a
single jitted call: this is the fan-out surface the generalization
benchmarks and any production front-end sit on.

1. train an hw-conditioned DNNFuser on a G-Sampler teacher corpus spanning
   two zoo accelerators (edge + mobile);
2. stack a grid of (batch, budget, accel) conditions — budgets never seen
   in training, plus rows on a THIRD accelerator (laptop) the mapper never
   trained on;
3. serve them all in one call and report throughput + per-accel validity.
"""
import argparse
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, DTConfig, FusionEnv, GSamplerConfig,
                        HW_FEATURE_DIM, TrainConfig, dnnfuser_infer_batch,
                        dt_init, dt_loss, generate_teacher_corpus,
                        train_model)
from repro.workloads import vgg16

MB = 2 ** 20


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--conditions", type=int, default=48)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    wl = vgg16()
    print(wl.summary())

    train_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"]]
    print("\n[1/2] training an hw-conditioned mapper "
          "(teacher @ 16-64 MB on edge + mobile) ...")
    ds = generate_teacher_corpus(
        [wl], train_accels, batch=64, budgets_mb=[16, 32, 48, 64],
        max_steps=20, ga_cfg=GSamplerConfig(population=24, generations=20))
    cfg = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=args.steps, batch_size=16))
    print(f"      {len(ds)} trajectories; final imitation loss "
          f"{log['final_loss']:.4f}")

    C = args.conditions
    rng = np.random.default_rng(0)
    serve_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"],
                    ACCEL_ZOO["laptop"]]          # laptop: never trained on
    rows = [serve_accels[i]
            for i in rng.integers(0, len(serve_accels), size=C)]
    batches = rng.choice([16, 32, 64], size=C).astype(np.float32)
    budgets = (rng.uniform(8.0, 72.0, size=C) * MB).astype(np.float32)
    env = FusionEnv(wl, ACCEL_ZOO["edge"], batch=64, budget_bytes=32 * MB,
                    nmax=20)   # supplies the packed workload

    print(f"[2/2] serving {C} (batch, budget, accel) conditions in one "
          f"call ...")
    dnnfuser_infer_batch(params, cfg, env, batches, budgets, rows)  # warm
    t0 = time.perf_counter()
    out = dnnfuser_infer_batch(params, cfg, env, batches, budgets, rows)
    wall = time.perf_counter() - t0

    valid = out["valid"]
    print(f"      {C} conditions in {wall*1e3:.1f} ms "
          f"= {C/wall:.0f} conditions/sec")
    if not valid.any():
        print(f"      0/{C} within budget — every requested budget is below "
              f"this workload's irreducible (all-SYNC) working set")
        return
    for acc in serve_accels:
        sel = np.array([r.name == acc.name for r in rows])
        if not sel.any():
            continue
        v = valid[sel]
        tag = " (UNSEEN)" if acc.name == "laptop" else ""
        print(f"      {acc.name:7s}{tag}: {int(v.sum())}/{int(sel.sum())} "
              f"within budget; speedups up to "
              f"{out['speedup'][sel][v].max() if v.any() else 0:.2f}x")
    worst = int(np.argmin(out["speedup"]))
    best = int(np.argmax(np.where(valid, out["speedup"], -np.inf)))
    for tag, i in (("best", best), ("worst", worst)):
        print(f"      {tag}: {rows[i].name}, batch {int(batches[i])}, "
              f"budget {budgets[i]/MB:5.1f} MB -> "
              f"speedup {out['speedup'][i]:.2f}x, "
              f"usage {out['peak_mem'][i]/MB:5.1f} MB, "
              f"strategy {[int(v) for v in out['strategy'][i][: wl.n + 1]]}")


if __name__ == "__main__":
    main()
