"""Batched mapper serving: many (batch, budget) conditions, ONE device call.

    PYTHONPATH=src python examples/serve_mapper.py [--conditions 48]

A deployed mapper service answers streams of queries like "map VGG16 under
a 20 MB buffer at batch 32" — each a full one-shot rollout.  The
device-resident serving primitive ``dnnfuser_infer_batch`` (DESIGN.md §9)
vmaps the fused scan rollout over a stacked grid of conditions, so the
whole request batch costs a single jitted call: this is the fan-out surface
the generalization benchmarks and any production front-end sit on.

1. train a small DNNFuser mapper on G-Sampler teacher data (as quickstart);
2. stack a grid of (batch, budget) conditions — including conditions never
   seen in training;
3. serve them all in one call and report throughput + per-condition
   validity/speedup.
"""
import argparse
import time

import jax
import numpy as np

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, TrainConfig,
                        collect_teacher_data, dnnfuser_infer_batch, dt_init,
                        dt_loss, train_model)
from repro.workloads import vgg16

MB = 2 ** 20


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--conditions", type=int, default=48)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    wl = vgg16()
    print(wl.summary())

    print("\n[1/2] training the mapper (G-Sampler teacher @ 16-64 MB) ...")
    ds = collect_teacher_data([wl], PAPER_ACCEL, batch=64,
                              budgets_mb=[16, 32, 48, 64], max_steps=20)
    cfg = DTConfig(max_steps=20)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=args.steps, batch_size=16))
    print(f"      final imitation loss {log['final_loss']:.4f}")

    C = args.conditions
    rng = np.random.default_rng(0)
    batches = rng.choice([16, 32, 64], size=C).astype(np.float32)
    budgets = (rng.uniform(8.0, 72.0, size=C) * MB).astype(np.float32)
    env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=32 * MB,
                    nmax=20)   # supplies the packed workload + HW config

    print(f"[2/2] serving {C} (batch, budget) conditions in one call ...")
    dnnfuser_infer_batch(params, cfg, env, batches, budgets)   # warm jit
    t0 = time.perf_counter()
    out = dnnfuser_infer_batch(params, cfg, env, batches, budgets)
    wall = time.perf_counter() - t0

    valid = out["valid"]
    print(f"      {C} conditions in {wall*1e3:.1f} ms "
          f"= {C/wall:.0f} conditions/sec")
    if not valid.any():
        print(f"      0/{C} within budget — every requested budget is below "
              f"this workload's irreducible (all-SYNC) working set")
        return
    print(f"      {int(valid.sum())}/{C} within budget; "
          f"speedups {out['speedup'][valid].min():.2f}x.."
          f"{out['speedup'][valid].max():.2f}x")
    worst = int(np.argmin(out["speedup"]))
    best = int(np.argmax(np.where(valid, out["speedup"], -np.inf)))
    for tag, i in (("best", best), ("worst", worst)):
        print(f"      {tag}: batch {int(batches[i])}, "
              f"budget {budgets[i]/MB:5.1f} MB -> "
              f"speedup {out['speedup'][i]:.2f}x, "
              f"usage {out['peak_mem'][i]/MB:5.1f} MB, "
              f"strategy {[int(v) for v in out['strategy'][i][: wl.n + 1]]}")


if __name__ == "__main__":
    main()
