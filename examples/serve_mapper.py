"""One mapper, one engine, one loop: production serving that repairs itself.

    PYTHONPATH=src python examples/serve_mapper.py [--requests 96]

A deployed mapper service fields a MIXED stream — "map vgg16 under 20 MB
at batch 32 on a mobile NPU" next to "map tiny_cnn under 3 MB on edge" —
arriving one request at a time, and must answer without recompiling or
re-searching.  Then the traffic CHANGES: a device class the mapper never
trained on starts dominating.  This is the §12–§15 stack end to end,
driven entirely through the supported public surface (``import repro``):

 - ``repro.serve`` builds the whole stack — engine + async front door —
   from ONE frozen :class:`repro.ServingConfig` (§15);
 - the engine buckets request shapes into a warmed, closed set of
   compiled programs, dedupes and caches solved strategies (§12), and
   the scheduler coalesces the live stream — cache hits resolve at
   submit, misses ride one fused device call (§14);
 - every served condition lands in a replay buffer; when the stream
   shifts to an UNSEEN accelerator, the ``DriftMonitor`` fires, a
   ``RefreshWorker`` G-Samples a fresh teacher corpus for exactly the
   drifted region, fine-tunes off the serving path, and — only after the
   candidate beats the live params on a held-out probe — hot-swaps them
   behind the running scheduler: zero recompiles, non-drifted cached
   responses bit-exact (§15);
 - the strategy cache persists, so a FRESH engine next process starts
   warm.
"""
import argparse
import pathlib
import tempfile
import time

import jax
import numpy as np

import repro
from repro import (ACCEL_ZOO, DriftConfig, DTConfig, GSamplerConfig,
                   HW_FEATURE_DIM, MapRequest, RefreshWorker, ServingConfig,
                   TrainConfig, dt_init, dt_loss, generate_teacher_corpus,
                   train_model)
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = 2 ** 20


def pump_stream(sched, stream, worker=None, gap_s=1e-3):
    """Submit one request per ``gap_s`` of simulated time; the closed-loop
    variant polls its refresh worker between pumps (§15: the refresh runs
    between ticks, never on a request)."""
    futures = []
    for i, req in enumerate(stream):
        futures.append(sched.submit(req, now=i * gap_s))
        sched.pump(now=i * gap_s)
        if worker is not None:
            worker.poll()
    sched.drain(now=len(stream) * gap_s)
    if worker is not None:
        worker.poll()
    return futures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--tick", type=int, default=16)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    train_nets = [vgg16(), tiny_cnn()]
    train_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"]]
    print("[1/5] training an hw-conditioned mapper "
          "(teacher @ 16-64 MB on edge + mobile) ...")
    ds = generate_teacher_corpus(
        train_nets, train_accels, batch=64, budgets_mb=[16, 32, 48, 64],
        max_steps=20, ga_cfg=GSamplerConfig(population=24, generations=20))
    cfg = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=args.steps, batch_size=16))
    print(f"      {len(ds)} trajectories; final imitation loss "
          f"{log['final_loss']:.4f}")

    # -- one config, one call: the whole serving stack -----------------------
    serve_nets = [vgg16(), tiny_cnn(), resnet18()]   # resnet18: UNSEEN net
    serve_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"],
                    ACCEL_ZOO["laptop"]]             # laptop: UNSEEN accel
    cache_file = pathlib.Path(tempfile.mkdtemp()) / "strategies.json"
    config = ServingConfig(
        cache_path=cache_file, flush_ms=25.0, max_wave=args.tick,
        known_accels=tuple(a.name for a in serve_accels),
        known_workloads=tuple(w.name for w in serve_nets),
        drift=DriftConfig(window=32))
    print(f"[2/5] repro.serve: engine + async scheduler from one "
          f"ServingConfig; warmup (ticks <= {args.tick}) ...")
    t0 = time.perf_counter()
    sched = repro.serve(params, cfg, config, warm=serve_nets,
                        accel=ACCEL_ZOO["edge"])
    engine = sched.engine
    print(f"      warmed in {time.perf_counter() - t0:.1f} s (nmax buckets "
          f"{engine.nmax_buckets}) — steady state reuses these programs")

    # -- act I: mixed open-loop stream over the declared conditions ----------
    rng = np.random.default_rng(0)
    budgets = np.linspace(7.0, 50.0, 12) * MB        # never trained on
    stream = [MapRequest(serve_nets[rng.integers(3)],
                         int(rng.choice([16, 32, 64])),
                         float(rng.choice(budgets)),
                         serve_accels[rng.integers(3)])
              for _ in range(args.requests)]
    print(f"[3/5] async front door: {args.requests} mixed requests, "
          f"one at a time, coalesced up to {args.tick}-wide (§14) ...")
    compiles_before = engine.compile_count
    t0 = time.perf_counter()
    futures = pump_stream(sched, stream)
    wall = time.perf_counter() - t0
    responses = [f.result() for f in futures]
    s = engine.stats()
    lat = sorted(f.latency_s for f in futures)
    print(f"      {len(stream)} requests in {wall*1e3:.0f} ms = "
          f"{len(stream)/wall:.0f} req/s; e2e p50 "
          f"{lat[len(lat)//2]*1e3:.0f} ms; "
          f"{s['scheduler']['resolved_at_submit']} resolved at submit; "
          f"cache hit rate {s['strategy_hit_rate']:.2f}; recompiles "
          f"{engine.compile_count - compiles_before} (must be 0)")

    # -- act II: the traffic drifts to an accelerator we never trained on ----
    dc = ACCEL_ZOO["datacenter"]
    drift_budgets = [10.0 * MB, 30.0 * MB]
    drifted = [MapRequest(train_nets[rng.integers(2)], 64,
                          float(drift_budgets[rng.integers(2)]), dc)
               if rng.random() < 0.75 else stream[rng.integers(len(stream))]
               for _ in range(2 * config.drift.window)]
    worker = RefreshWorker(
        engine, train=TrainConfig(steps=200, batch_size=16, lr=3e-4,
                                  warmup=20),
        ga=GSamplerConfig(population=32, generations=24), seed=1)
    probe = stream[0]                    # a non-drifted key to pin bit-exact
    pre = engine.serve([probe])[0]
    print(f"[4/5] drift: {len(drifted)} requests, 75% on '{dc.name}' "
          f"(never trained) — the monitor watches windows of "
          f"{config.drift.window} ...")
    compiles_before = engine.compile_count
    pump_stream(sched, drifted, worker=worker)
    d = engine.stats()["drift"]
    res = worker.last_result
    if res is None:
        print("      no drift report fired — stream stayed in distribution")
    else:
        print(f"      {d['reports_fired']} drift report(s); refresh: "
              f"corpus={res['corpus_size']} trajectories, probe "
              f"{res['live_score']:.2f} -> {res['candidate_score']:.2f}, "
              f"accepted={res['accepted']}")
    print(f"      {d['swaps_accepted']} hot swap(s), "
          f"{d['cache_invalidated']} drifted cache entries invalidated, "
          f"recompiles {engine.compile_count - compiles_before} (must be 0)")
    post = engine.serve([probe])[0]
    same = bool(post.cached and np.array_equal(pre.strategy, post.strategy))
    print(f"      non-drifted key still cached + bit-exact: {same}")
    dres = engine.serve([MapRequest(w, 64, b, dc)
                         for w in train_nets for b in drift_budgets])
    best = max(dres, key=lambda r: r.speedup)
    print(f"      post-swap '{dc.name}' grid: "
          f"{sum(r.valid for r in dres)}/{len(dres)} within budget, best "
          f"{best.workload} -> {best.speedup:.2f}x")

    # -- act III: warm restart — the next process starts from the file -------
    engine.save_cache()
    fresh = repro.MapperEngine.from_config(engine.params, cfg, config)
    replay = fresh.serve(drifted)        # no warmup, no device: cache hits
    ws = fresh.stats()
    print(f"[5/5] warm restart: fresh engine loaded "
          f"{ws['strategy_cache']['entries']} persisted strategies, replayed "
          f"the drifted stream with {ws['device_calls']} device calls and "
          f"{ws['compile_count']} compiles "
          f"(hit rate {ws['strategy_hit_rate']:.2f}, "
          f"{sum(r.valid for r in replay)}/{len(replay)} within budget)")


if __name__ == "__main__":
    main()
