"""One mapper, one engine: a production-shaped async serving front door.

    PYTHONPATH=src python examples/serve_mapper.py [--requests 96]

A deployed mapper service fields a MIXED stream — "map vgg16 under 20 MB
at batch 32 on a mobile NPU" next to "map tiny_cnn under 3 MB on edge" —
arriving one request at a time, and must answer without recompiling or
re-searching.  This is the §12/§14 stack end to end:

 - core: the fused episode rolls heterogeneous (workload, batch, budget,
   accel) rows in ONE device call — the workload itself is a traced
   per-row condition (DESIGN §12), the accelerator too (§11);
 - engine: ``serving.MapperEngine`` buckets request shapes (pow2 batches x
   nmax buckets -> a warmed, closed set of compiled programs), dedupes and
   caches solved strategies;
 - front door: ``serving.AsyncMapperScheduler`` — continuous batching
   over the live stream: cache hits resolve at submit, misses coalesce
   until a full device call forms or a flush deadline expires (§14);
 - restart: the strategy cache persists to disk, so a FRESH engine in the
   next process starts warm — repeat conditions never touch the device.

The stream mixes zoo networks x zoo accelerators (including one never
trained on) x budgets never seen in training.
"""
import argparse
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.core import (ACCEL_ZOO, DTConfig, GSamplerConfig, HW_FEATURE_DIM,
                        MapperEngine, MapRequest, TrainConfig, dt_init,
                        dt_loss, generate_teacher_corpus, train_model)
from repro.serving import AsyncMapperScheduler
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = 2 ** 20


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--tick", type=int, default=16)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    train_nets = [vgg16(), tiny_cnn()]
    train_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"]]
    print("[1/4] training an hw-conditioned mapper "
          "(teacher @ 16-64 MB on edge + mobile) ...")
    ds = generate_teacher_corpus(
        train_nets, train_accels, batch=64, budgets_mb=[16, 32, 48, 64],
        max_steps=20, ga_cfg=GSamplerConfig(population=24, generations=20))
    cfg = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=args.steps, batch_size=16))
    print(f"      {len(ds)} trajectories; final imitation loss "
          f"{log['final_loss']:.4f}")

    # -- the engine: one warmup, then a closed set of compiled programs ------
    serve_nets = [vgg16(), tiny_cnn(), resnet18()]   # resnet18: UNSEEN net
    serve_accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"],
                    ACCEL_ZOO["laptop"]]             # laptop: UNSEEN accel
    cache_file = pathlib.Path(tempfile.mkdtemp()) / "strategies.json"
    engine = MapperEngine(params, cfg, cache_path=cache_file)
    print(f"[2/4] engine warmup (nmax buckets {engine.nmax_buckets}, "
          f"ticks <= {args.tick}) ...")
    t0 = time.perf_counter()
    n_programs = engine.warmup(serve_nets, ACCEL_ZOO["edge"],
                               max_tick=args.tick)
    print(f"      {n_programs} programs compiled in "
          f"{time.perf_counter() - t0:.1f} s — steady state reuses these")

    # -- mixed open-loop stream: unseen budgets, unseen accel, unseen net ----
    rng = np.random.default_rng(0)
    budgets = np.linspace(7.0, 50.0, 12) * MB        # never trained on
    stream = [MapRequest(serve_nets[rng.integers(3)],
                         int(rng.choice([16, 32, 64])),
                         float(rng.choice(budgets)),
                         serve_accels[rng.integers(3)])
              for _ in range(args.requests)]
    print(f"[3/4] async front door: {args.requests} mixed requests, "
          f"one at a time, coalesced up to {args.tick}-wide (§14) ...")
    # Requests arrive ~1 ms apart; the scheduler resolves cache hits at
    # submit and flushes a lane once it fills or its deadline expires.
    sched = AsyncMapperScheduler(engine, flush_ms=25.0, max_wave=args.tick)
    compiles_before = engine.compile_count
    t0 = time.perf_counter()
    futures = []
    for i, req in enumerate(stream):
        futures.append(sched.submit(req, now=i * 1e-3))
        sched.pump(now=i * 1e-3)
    sched.drain(now=len(stream) * 1e-3)
    wall = time.perf_counter() - t0
    responses = [f.result() for f in futures]
    s = engine.stats()
    ss = s["scheduler"]
    lat = sorted(f.latency_s for f in futures)
    p50, p99 = lat[len(lat) // 2], lat[int(len(lat) * 0.99)]

    print(f"      {len(stream)} requests in {wall*1e3:.0f} ms = "
          f"{len(stream)/wall:.0f} req/s over {s['device_calls'] - n_programs}"
          f" device calls; e2e p50 {p50*1e3:.0f} ms / p99 {p99*1e3:.0f} ms")
    print(f"      {ss['resolved_at_submit']} resolved at submit; flushes: "
          f"{ss['flushes']}")
    print(f"      strategy cache: {s['strategy_hits']} hits / "
          f"{s['strategy_misses']} misses (rate {s['strategy_hit_rate']:.2f})"
          f", {s['tick_dedup']} in-tick dedups")
    print(f"      recompiles in steady state: "
          f"{engine.compile_count - compiles_before} (must be 0)")

    # -- warm restart: a FRESH engine loads the persisted strategies --------
    engine.save_cache()
    warm = MapperEngine(params, cfg, cache_path=cache_file)
    replay = warm.serve(stream)          # no warmup, no device: all cache hits
    ws = warm.stats()
    same = all(np.array_equal(a.strategy, b.strategy) and a.valid == b.valid
               for a, b in zip(replay, responses))
    print(f"[4/4] warm restart: fresh engine loaded "
          f"{ws['strategy_cache']['entries']} persisted strategies, replayed "
          f"the stream with {ws['device_calls']} device calls and "
          f"{ws['compile_count']} compiles; bit-identical: {same}")
    if not any(r.valid for r in responses):
        print(f"      0/{len(responses)} within budget — every requested "
              f"budget is below the workloads' irreducible (all-SYNC) "
              f"working set")
        return
    for acc in serve_accels:
        sel = [r for r, q in zip(responses, stream) if q.accel is acc]
        ok = sum(r.valid for r in sel)
        tag = " (UNSEEN)" if acc.name == "laptop" else ""
        best = max((r.speedup for r in sel if r.valid), default=0.0)
        print(f"      {acc.name:7s}{tag}: {ok}/{len(sel)} within budget; "
              f"speedups up to {best:.2f}x")
    best = max((r for r in responses if r.valid), key=lambda r: r.speedup)
    print(f"      best: {best.workload} -> {best.speedup:.2f}x, "
          f"usage {best.peak_mem/MB:.1f} MB, "
          f"strategy {[int(v) for v in best.strategy]}")


if __name__ == "__main__":
    main()
