"""End-to-end training driver with a LEARNED fusion mapper in the loop.

    PYTHONPATH=src python examples/train_with_mapper.py [--arch gemma3_1b]

Stage 1 trains the DNNFuser mapper itself for this arch: the arch is
lowered to an LM-block fusion workload, the device-grid G-Sampler teacher
sweeps a grid of activation budgets in one fused program
(``generate_teacher_corpus``), and the sharded imitation trainer fits the
decision transformer, checkpointing under ``artifacts/mapper_<arch>`` —
re-runs warm-start from the checkpoint instead of retraining.

Stage 2 is the original driver: the (now learned) mapper one-shot-infers
the input micro-batch under the activation budget, the trainer uses it as
the gradient-accumulation micro-batch, and the loop checkpoints
asynchronously and resumes if re-run (kill it mid-way and run again to
see).  On real TPU hardware drop ``--reduced`` and raise the sizes — this
is the same ``launch/train.py`` path the dry-run lowers for the 16x16 mesh.
"""
import argparse

import jax

from repro.checkpoint import Checkpointer
from repro.core import (DTConfig, GSamplerConfig, PAPER_ACCEL, TrainConfig,
                        dt_init, dt_loss, generate_teacher_corpus,
                        restore_params, train_model)
from repro.configs import get_config
from repro.distributed.sharding import data_parallel_mesh
from repro.launch.train import train
from repro.workloads.lm_workloads import lm_workload


def train_mapper(arch: str, *, seq_len: int, global_batch: int,
                 ckpt_dir: str, steps: int = 400):
    """Teacher-corpus -> sharded imitation training for one arch's LM
    workload; resumes from ``ckpt_dir`` when already trained."""
    cfg = get_config(arch, reduced=True)
    wl = lm_workload(cfg, seq_len=seq_len, batch=global_batch, mode="train")
    T = max(16, wl.n + 1)
    dt_cfg = DTConfig(max_steps=T)
    if (Checkpointer(ckpt_dir).latest_step() or 0) >= steps:
        # fully trained: skip the (expensive) teacher GA entirely
        params = restore_params(ckpt_dir,
                                dt_init(jax.random.PRNGKey(0), dt_cfg))
        print(f"[mapper-train] checkpoint {ckpt_dir} complete; reusing it")
        return params, dt_cfg
    corpus = generate_teacher_corpus(
        [wl], PAPER_ACCEL, batch=global_batch,
        budgets_mb=[4.0, 8.0, 16.0, 24.0, 48.0],
        max_steps=T, ga_cfg=GSamplerConfig(generations=25, seed=0), seed=0)
    params, log = train_model(
        lambda p, b: dt_loss(p, dt_cfg, b),
        dt_init(jax.random.PRNGKey(0), dt_cfg), corpus,
        TrainConfig(steps=steps, batch_size=32, log_every=100,
                    ckpt_every=steps // 2),
        mesh=data_parallel_mesh(), ckpt_dir=ckpt_dir)
    print(f"[mapper-train] {len(corpus)} teacher trajectories, "
          f"resumed from step {log['start_step']}, "
          f"final loss {log['final_loss'] if log['losses'] else 'cached'}")
    return params, dt_cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--gsampler", action="store_true",
                    help="skip mapper training; fall back to a fresh "
                         "G-Sampler search (the teacher)")
    args = ap.parse_args()

    dt_params = dt_cfg = None
    if not args.gsampler:
        dt_params, dt_cfg = train_mapper(
            args.arch, seq_len=128, global_batch=8,
            ckpt_dir=f"artifacts/mapper_{args.arch}")

    loop, info = train(args.arch, steps=args.steps, global_batch=8,
                       seq_len=128, reduced=True,
                       ckpt_dir=f"artifacts/example_train_{args.arch}",
                       use_mapper=True, act_budget_mb=8.0,
                       dt_params=dt_params, dt_cfg=dt_cfg)
    src = "G-Sampler search" if dt_params is None else "one-shot DNNFuser"
    print(f"\nmapper ({src}) chose micro_batch={info['micro_batch']} "
          f"(grad_accum={info['grad_accum']}), modeled fusion speedup "
          f"{info['speedup']:.2f}x")
    print("loss curve:", [(s, round(l, 3)) for s, l in loop.losses])
    print(f"median step {loop.monitor.median*1e3:.0f} ms; "
          f"straggler events: {len(loop.monitor.events)}")
    print("re-run this script to see checkpoint resume "
          f"(start_step was {loop.start_step})")


if __name__ == "__main__":
    main()
