"""End-to-end training driver with the fusion mapper in the loop.

    PYTHONPATH=src python examples/train_with_mapper.py [--arch gemma3_1b]

The arch is lowered to a fusion workload; the mapper picks the input
micro-batch under an activation budget; the trainer uses it as the
gradient-accumulation micro-batch; the loop checkpoints asynchronously and
resumes if re-run (kill it mid-way and run again to see).  On real TPU
hardware drop ``--reduced`` and raise the sizes — this is the same
``launch/train.py`` path the dry-run lowers for the 16x16 mesh.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    loop, info = train(args.arch, steps=args.steps, global_batch=8,
                       seq_len=128, reduced=True,
                       ckpt_dir=f"artifacts/example_train_{args.arch}",
                       use_mapper=True, act_budget_mb=8.0)
    print(f"\nmapper chose micro_batch={info['micro_batch']} "
          f"(grad_accum={info['grad_accum']}), modeled fusion speedup "
          f"{info['speedup']:.2f}x")
    print("loss curve:", [(s, round(l, 3)) for s, l in loop.losses])
    print(f"median step {loop.monitor.median*1e3:.0f} ms; "
          f"straggler events: {len(loop.monitor.events)}")
    print("re-run this script to see checkpoint resume "
          f"(start_step was {loop.start_step})")


if __name__ == "__main__":
    main()
