"""Quickstart: the full DNNFuser pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. G-Sampler (the search-based teacher) searches fusion strategies for
   VGG16 at a few on-chip-buffer conditions;
2. the trajectories are decorated into (reward, state, action) sequences
   and a decision transformer is imitation-trained on them;
3. the trained mapper infers a strategy ONE-SHOT at an unseen 28 MB
   condition — no search — and we compare against a fresh search.
"""
import time

import jax

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, TrainConfig,
                        collect_teacher_data, dnnfuser_infer, dt_init,
                        dt_loss, gsampler_search, train_model)
from repro.workloads import vgg16

MB = 2 ** 20


def main():
    wl = vgg16()
    print(wl.summary())

    print("\n[1/3] teacher: G-Sampler searching @ 16/32/48/64 MB ...")
    t0 = time.perf_counter()
    ds = collect_teacher_data([wl], PAPER_ACCEL, batch=64,
                              budgets_mb=[16, 32, 48, 64], max_steps=20)
    print(f"      {len(ds)} trajectories in {time.perf_counter()-t0:.1f}s; "
          f"teacher speedups up to "
          f"{max(m[2] for m in ds.meta):.2f}x")

    print("[2/3] student: imitation-training the decision transformer ...")
    cfg = DTConfig(max_steps=20)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: dt_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=300, batch_size=16))
    print(f"      final imitation loss {log['final_loss']:.4f} "
          f"({log['wall_s']:.0f}s)")

    print("[3/3] one-shot inference at UNSEEN condition 28 MB ...")
    env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=28 * MB,
                    nmax=20)
    df = dnnfuser_infer(params, cfg, env)
    gs = gsampler_search(env)
    n = wl.n
    print(f"      DNNFuser : speedup {df.speedup:.2f}x usage "
          f"{df.peak_mem/MB:5.1f}MB in {df.wall_s*1e3:6.0f} ms (one shot)")
    print(f"      G-Sampler: speedup {gs.speedup:.2f}x usage "
          f"{gs.peak_mem/MB:5.1f}MB in {gs.wall_s*1e3:6.0f} ms (2k samples)")
    print("      strategy:", [int(v) for v in df.strategy[: n + 1]])


if __name__ == "__main__":
    main()
