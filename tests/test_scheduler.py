"""The async serving front door (DESIGN.md §14).

Pins the scheduler contracts:

 - **determinism**: responses from async/coalesced serving are bit-
   identical to per-request serving, independent of arrival order, flush
   deadline and wave width — and so are the strategy-cache contents
   after a drain (same unique conditions, same solved entries);
 - **continuous batching mechanics**: width-triggered flushes under
   load, deadline-triggered flushes for stragglers, cache hits resolved
   at submit (never queued), bounded queue with admission rejection;
 - **oversized ticks** (the warmup escape hatch): a tick wider than the
   warmed set chunks into warmed pow2 programs — zero new compiles, and
   every response still bit-exact with solo serving.
"""
import jax
import numpy as np
import pytest

from repro.core import ACCEL_ZOO, DTConfig, dt_init
from repro.core import infer as infer_mod
from repro.serving import (AdmissionError, AsyncMapperScheduler, MapperEngine,
                           MapRequest, pow2_chunks)
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = 2 ** 20

CFG = DTConfig(max_steps=20)
PARAMS = dt_init(jax.random.PRNGKey(2), CFG)


def _stream():
    """A small mixed stream with duplicate conditions across nets/accels."""
    nets = [vgg16(), resnet18(), tiny_cnn()]
    accs = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"]]
    reqs = [MapRequest(nets[i % 3], 16 << (i % 2), (8 + (i % 5)) * MB,
                       accs[i % 2]) for i in range(10)]
    return reqs + reqs[:4]                       # 4 exact repeats


def _assert_same_response(a, b):
    assert (a.strategy == b.strategy).all()
    assert a.latency == b.latency and a.peak_mem == b.peak_mem
    assert a.valid == b.valid


def _snapshot_equal(s1: dict, s2: dict):
    assert s1.keys() == s2.keys()
    for k, (st1, *rest1) in s1.items():
        st2, *rest2 = s2[k]
        assert (np.asarray(st1) == np.asarray(st2)).all(), k
        assert rest1 == rest2, k


def test_pow2_chunks():
    assert pow2_chunks(23, 8) == (8, 8, 7)
    assert pow2_chunks(8, 8) == (8,)
    assert pow2_chunks(3, 8) == (3,)
    assert pow2_chunks(9, 7) == (8, 1)           # cap rounds up to pow2
    with pytest.raises(ValueError):
        pow2_chunks(0, 8)


def test_scheduler_bit_identical_to_solo_serving_under_permutation():
    """S3: permuted arrival orders, different flush deadlines and wave
    widths, all against one per-request baseline — every response and the
    drained cache contents must be bit-identical."""
    reqs = _stream()
    solo = MapperEngine(PARAMS, CFG)
    base = [solo.serve_one(r) for r in reqs]

    rng = np.random.default_rng(0)
    orders = [list(range(len(reqs))), list(rng.permutation(len(reqs))),
              list(rng.permutation(len(reqs)))]
    configs = [dict(flush_ms=0.0, max_wave=4), dict(flush_ms=5.0, max_wave=4),
               dict(flush_ms=1e3, max_wave=2), dict(flush_ms=1e3, max_wave=8)]
    snap = None
    for order, kw in zip(orders + orders[:1], configs):
        eng = MapperEngine(PARAMS, CFG)
        sched = AsyncMapperScheduler(eng, **kw)
        futs = {}
        for t, i in enumerate(order):
            futs[i] = sched.submit(reqs[i], now=t * 1e-3)
            sched.pump(now=t * 1e-3)
        sched.drain(now=len(order) * 1e-3)
        for i, b in enumerate(base):
            _assert_same_response(futs[i].result(), b)
        s = eng.strategies.snapshot()
        if snap is None:
            snap = s
        else:
            _snapshot_equal(snap, s)             # identical cache contents
    _snapshot_equal(snap, solo.strategies.snapshot())


def test_scheduler_width_and_deadline_flushes():
    eng = MapperEngine(PARAMS, CFG)
    sched = AsyncMapperScheduler(eng, flush_ms=10.0, max_wave=2)
    a = sched.submit(MapRequest(tiny_cnn(), 16, 8 * MB, ACCEL_ZOO["edge"]),
                     now=0.0)
    sched.pump(now=0.001)
    assert not a.done and sched.queue_depth == 1     # lone request waits
    b = sched.submit(MapRequest(tiny_cnn(), 32, 9 * MB, ACCEL_ZOO["edge"]),
                     now=0.002)
    sched.pump(now=0.002)                            # 2 unique = full wave
    assert a.done and b.done and sched.flushes["width"] == 1
    assert a.latency_s > 0 and a.t_done == b.t_done  # same tick
    # a straggler flushes on deadline, not width
    c = sched.submit(MapRequest(tiny_cnn(), 16, 11 * MB, ACCEL_ZOO["edge"]),
                     now=0.1)
    sched.pump(now=0.105)
    assert not c.done
    sched.pump(now=0.111)
    assert c.done and sched.flushes["deadline"] == 1
    # an exact duplicate of a solved condition resolves AT SUBMIT
    d = sched.submit(MapRequest(tiny_cnn(), 16, 8 * MB, ACCEL_ZOO["edge"]),
                     now=0.2)
    assert d.done and d.result().cached
    assert sched.resolved_at_submit == 1
    _assert_same_response(d.result(), a.result())


def test_scheduler_admission_control():
    eng = MapperEngine(PARAMS, CFG)
    sched = AsyncMapperScheduler(eng, max_queue=2, flush_ms=1e3, max_wave=8)
    r = [MapRequest(tiny_cnn(), 16, (8 + i) * MB, ACCEL_ZOO["edge"])
         for i in range(3)]
    sched.submit(r[0], now=0.0)
    sched.submit(r[1], now=0.0)
    with pytest.raises(AdmissionError):
        sched.submit(r[2], now=0.0)
    assert sched.rejected == 1 and sched.submitted == 2
    sched.drain(now=0.01)                        # frees the queue
    fut = sched.submit(r[2], now=0.02)           # admitted after backpressure
    sched.drain(now=0.03)
    assert fut.done and sched.queue_depth == 0


def test_oversized_tick_chunks_to_warmed_programs():
    """S1: warmup covers ticks up to 8 lanes; a 23-request tick must chunk
    into (8, 8, 7->pad 8) — ZERO new compiles (engine counter AND jax's
    own jit cache) and every response bit-exact with solo serving."""
    eng = MapperEngine(PARAMS, CFG, max_coalesce=16)
    eng.warmup([tiny_cnn()], ACCEL_ZOO["edge"], max_tick=8)
    assert eng.chunk_cap == 8
    jit_cache = getattr(infer_mod._fused_batch, "_cache_size", None)
    jit_before = jit_cache() if jit_cache else None
    before = eng.compile_count
    reqs = [MapRequest(tiny_cnn(), 1 + i % 4, (6 + i) * MB, ACCEL_ZOO["edge"])
            for i in range(23)]
    out = eng.serve(reqs)
    assert eng.compile_count == before, "oversized tick recompiled"
    if jit_cache is not None:
        assert jit_cache() == jit_before
    hist = eng.coalesce_hist
    assert hist.get(8, 0) >= 2 and hist.get(7, 0) == 1
    solo = MapperEngine(PARAMS, CFG)
    for req, resp in zip(reqs, out):
        _assert_same_response(resp, solo.serve_one(req))
