"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fusion_eval, ops, ref
from repro.core import cost_model as cm
from repro.core import ref_model
from repro.core.accel import ACCEL_ZOO, PAPER_ACCEL
from repro.nn.attention import attend
from repro.workloads import resnet18, tiny_cnn, vgg16

RNG = np.random.default_rng(0)
MB = 2.0 ** 20


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 256, 8, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, -1), (False, -1),
                                           (True, 96)])
def test_flash_attention_sweep(B, S, Hq, Hkv, hd, dtype, causal, window):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=128, bk=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,T,Hq,Hkv,hd,kv_len", [
    (1, 1024, 4, 4, 64, 800), (2, 2048, 8, 2, 64, 2048),
    (1, 1024, 8, 1, 128, 513),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, T, Hq, Hkv, hd, kv_len, dtype):
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), dtype)
    out = ops.flash_decode(q, k, v, kv_len, bk=256, interpret=True)
    want = ref.decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,T,H,n,chunk", [
    (1, 64, 2, 32, 32), (2, 130, 3, 64, 64), (1, 256, 1, 16, 64),
])
def test_wkv6_sweep(B, T, H, n, chunk):
    r, k, v = (jnp.asarray(RNG.normal(size=(B, T, H, n)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.75, 0.9995, size=(B, T, H, n)),
                    jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, n)), jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(B, H, n, n)), jnp.float32)
    y, sT = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr),
                               rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# fusion_eval: the production grid evaluator (DESIGN §13).
#
# The kernel is packed ONCE with the paper accelerator (1-byte tensors) and
# then served across the whole ACCEL_ZOO — including the 2-byte datacenter
# part, the pack-time/serve-time BPE mismatch that the pre-§13 kernel
# silently evaluated wrong.  Against the XLA evaluator the contract is
# BIT-exactness (what makes the gsampler evaluator switch corpus-neutral);
# against the independent f64 loop oracle (core.ref_model) it is the
# existing 1e-5 kernel tolerance.
# ---------------------------------------------------------------------------

_FE_WL = resnet18(batch=32)
_FE_PACKED = cm.pack_workload(_FE_WL, PAPER_ACCEL, nmax=64)
_FE_POP = np.stack([cm.random_strategy(RNG, _FE_WL.n, 64, 32)
                    for _ in range(64)])


def _assert_costout_equal(got, want):
    for field, a, b in zip(got._fields, got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=field)


@pytest.mark.parametrize("accel", sorted(ACCEL_ZOO))
def test_fusion_eval_zoo_sweep(accel):
    """Bit parity with the XLA evaluator on every zoo accelerator, incl.
    the serve-time BPE mismatch (pack bpe=1, datacenter bpe=2)."""
    hw = ACCEL_ZOO[accel]
    out = ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                     budget_bytes=20 * MB, hw=hw,
                                     interpret=True)
    want = ref.fusion_eval_ref(_FE_POP, _FE_PACKED, batch=32.0,
                               budget_bytes=20 * MB, hw=hw)
    _assert_costout_equal(out, want)


@pytest.mark.parametrize("accel", ["edge", "datacenter"])
def test_fusion_eval_matches_ref_model(accel):
    """Independent oracle: the f64 loop model, with the workload packed
    DIRECTLY at the serving accelerator's datatype — the ground truth the
    in-kernel BPE rescale must reproduce."""
    hw = ACCEL_ZOO[accel]
    wl_serve = {k: np.asarray(v)
                for k, v in cm.pack_workload(_FE_WL, hw, nmax=64).items()}
    out = ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                     budget_bytes=20 * MB, hw=hw,
                                     interpret=True)
    for i in range(0, len(_FE_POP), 7):
        want = ref_model.evaluate_ref(wl_serve, _FE_POP[i], 32, 20 * MB, hw)
        for k in ("latency", "peak_mem", "traffic"):
            a = float(np.asarray(getattr(out, k))[i])
            assert abs(a - want[k]) <= 1e-5 * max(abs(want[k]), 1.0), \
                (accel, i, k, a, want[k])
        assert bool(np.asarray(out.valid)[i]) == want["valid"]
        assert int(np.asarray(out.n_groups)[i]) == want["n_groups"]


def test_fusion_eval_grid_blocks():
    """[C, POP, P] grid contract vs evaluate_grid_stats: heterogeneous
    workloads x accels x budgets, non-pow2 population, bit parity incl.
    the repair-operator stats (masked gid + per-group footprints)."""
    wl_objs = [resnet18(), vgg16(), tiny_cnn()]
    pack_accs = [PAPER_ACCEL, ACCEL_ZOO["datacenter"], ACCEL_ZOO["nano"]]
    serve_accs = [ACCEL_ZOO["datacenter"], PAPER_ACCEL, ACCEL_ZOO["mobile"]]
    wls = cm.stack_workloads([cm.pack_workload(w, a, 64)
                              for w, a in zip(wl_objs, pack_accs)])
    strats = np.stack([
        np.stack([cm.random_strategy(RNG, w.n, 64, 16) for _ in range(9)])
        for w in wl_objs])
    batches = np.full(3, 16.0, np.float32)
    budgets = np.asarray([20 * MB, 48 * MB, 4 * MB], np.float32)
    out, gid, M_g = ops.fusion_eval_grid_stats(wls, strats, batches,
                                               budgets, serve_accs,
                                               interpret=True)
    want, wgid, wMg = ref.fusion_eval_grid_ref(wls, strats, batches,
                                               budgets, serve_accs)
    _assert_costout_equal(out, want)
    np.testing.assert_array_equal(np.asarray(M_g), np.asarray(wMg))
    mask = np.asarray(wls["mask"])
    for c in range(3):                      # gid is defined under the mask
        np.testing.assert_array_equal(np.asarray(gid)[c][:, mask[c]],
                                      np.asarray(wgid)[c][:, mask[c]])
    # the plain grid entry point rides the same program
    out2 = ops.fusion_eval_grid(wls, strats, batches, budgets, serve_accs,
                                interpret=True)
    _assert_costout_equal(out2, want)


@pytest.mark.parametrize("pop_n", [1, 5])
def test_fusion_eval_nonpow2_population(pop_n):
    """Odd population sizes pad to the block width and unpad exactly."""
    w = tiny_cnn()
    wl = cm.pack_workload(w, PAPER_ACCEL, nmax=32)
    pop = np.stack([cm.random_strategy(RNG, w.n, 32, 16)
                    for _ in range(pop_n)])
    out = ops.fusion_eval_population(pop, wl, batch=16.0,
                                     budget_bytes=4 * MB, hw=PAPER_ACCEL,
                                     interpret=True)
    want = cm.evaluate_population(wl, jnp.asarray(pop), 16.0, 4 * MB,
                                  PAPER_ACCEL)
    _assert_costout_equal(out, want)


def test_fusion_eval_zero_recompiles_across_accels():
    """The accelerator is traced kernel data: sweeping the zoo at a fixed
    block shape must not grow the jit cache (the §13 serving property)."""
    cache_size = getattr(fusion_eval._fusion_eval_grid_jit, "_cache_size",
                         None)
    if cache_size is None:
        pytest.skip("jax version exposes no jit cache introspection")
    ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                               budget_bytes=20 * MB, hw=PAPER_ACCEL,
                               interpret=True)
    before = cache_size()
    for hw in ACCEL_ZOO.values():
        ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                   budget_bytes=20 * MB, hw=hw,
                                   interpret=True)
    assert cache_size() == before, \
        "hw sweep recompiled — the accelerator became a static argument"


# ---------------------------------------------------------------------------
# attend() pallas dispatch over KV caches (the flash_decode audit): the
# cached paths carry q_offset/kv_len masking; dropping it (the pre-§13
# dispatch) read the UNWRITTEN cache tail.
# ---------------------------------------------------------------------------


def test_attend_pallas_cached_decode_masks_tail():
    """Single-token cached decode routes to flash_decode and must mask the
    garbage tail beyond kv_len (also exercises the bk > T clamp)."""
    B, T, Hq, Hkv, hd = 2, 60, 4, 2, 16
    kv_len = 37
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    k = k.at[:, kv_len:].set(1e6)            # poison the unwritten tail
    v = v.at[:, kv_len:].set(-1e6)
    for q_off in (kv_len - 1, 20):          # last-token and mid-cache query
        ox = attend(q, k, v, causal=True, q_offset=q_off, kv_len=kv_len,
                    impl="xla")
        op = attend(q, k, v, causal=True, q_offset=q_off, kv_len=kv_len,
                    impl="pallas")
        np.testing.assert_allclose(np.asarray(op), np.asarray(ox),
                                   rtol=2e-5, atol=2e-5)


def test_attend_pallas_cached_append_bitexact_xla():
    """Multi-token cache appends (the dt_decode_step shape: 2-3 tokens per
    step) have no pallas kernel — the dispatch must fall back to the exact
    XLA masking math, keeping cached decode == full forward bit-for-bit
    whether or not the pallas path is selected."""
    B, T, Hq, Hkv, hd = 2, 60, 4, 4, 16
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    for S, kv_len in ((2, 2), (3, 17), (3, 60)):
        q = jnp.asarray(RNG.normal(size=(B, S, Hq, hd)), jnp.float32)
        ox = attend(q, k, v, causal=True, q_offset=kv_len - S,
                    kv_len=kv_len, impl="xla")
        op = attend(q, k, v, causal=True, q_offset=kv_len - S,
                    kv_len=kv_len, impl="pallas")
        np.testing.assert_array_equal(np.asarray(op), np.asarray(ox))


def test_flash_decode_cache_not_multiple_of_block():
    """bk > T and T % bk != 0 must clamp/pad instead of dropping tail keys."""
    B, T, Hq, Hkv, hd = 1, 72, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), jnp.float32)
    for kv_len, bk in ((72, 512), (50, 32), (7, 16)):
        out = ops.flash_decode(q, k, v, kv_len, bk=bk, interpret=True)
        want = ref.decode_ref(q, k, v, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_model_pallas_path_matches_xla():
    """attn_impl='pallas' end-to-end equals the XLA path (reduced arch)."""
    from repro.configs import get_config
    from repro.models import get_model
    cfg = get_config("qwen3_8b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 128)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    lx, _ = model.forward(params, cfg, batch, impl="xla")
    lp, _ = model.forward(params, cfg, batch, impl="pallas")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


# --- compiled (non-interpret) lowering: probe / fallback / autotune ---------

def test_compiled_backend_probe_memoized_on_cpu():
    """The CPU container cannot lower Pallas compiled; the probe must say
    so (memoized — the second call is free)."""
    assert fusion_eval.compiled_backend_supported() is False
    assert fusion_eval.compiled_backend_supported() is False
    s = fusion_eval.backend_stats()
    assert s["backend"] == "cpu" and s["compiled_supported"] is False


def test_compiled_request_falls_back_bit_identically():
    """Explicitly asking for interpret=False on an unsupported backend
    must WARN and serve the interpret result — bit-identical, no crash
    (the DESIGN §14 graceful-fallback contract)."""
    import warnings
    want = ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                      budget_bytes=20 * MB, hw=PAPER_ACCEL,
                                      interpret=True)
    before = fusion_eval.backend_stats()["interpret_fallbacks"]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                         budget_bytes=20 * MB,
                                         hw=PAPER_ACCEL, interpret=False)
    _assert_costout_equal(got, want)
    stats = fusion_eval.backend_stats()
    assert stats["interpret_fallbacks"] == before + 1
    if before == 0:                              # warn once, count always
        assert any("interpret mode" in str(w.message) for w in rec)
    # the default (interpret=None) resolves to the probe verdict, so the
    # same call without flags is also bit-identical
    auto = ops.fusion_eval_population(_FE_POP, _FE_PACKED, batch=32.0,
                                      budget_bytes=20 * MB, hw=PAPER_ACCEL)
    _assert_costout_equal(auto, want)


def test_autotune_block_on_interpret_backend_returns_legacy_default():
    """Autotuning times compiled programs; under interpret it must return
    the legacy block width untimed (and memoize it), so bp=None keeps
    CPU-CI behavior identical to the old bp=128 default."""
    bp = fusion_eval.autotune_block(64, _FE_POP.shape[0])
    assert bp == fusion_eval._block_size(_FE_POP.shape[0], 128)
    key = (64, fusion_eval._block_size(_FE_POP.shape[0], 256))
    assert fusion_eval.backend_stats()["autotuned_bp"][key] == bp


# ---------------------------------------------------------------------------
# optimality oracle (DESIGN §16): every production evaluator — XLA
# evaluate_population, the Pallas kernel (interpret AND the compiled->
# interpret fallback entry), and the prefix-scan serving evaluator — is
# pinned against the exact f64 brute-force optimum on the shared
# adversarial workload set.
# ---------------------------------------------------------------------------

import _adversarial as adv
from repro.core import optimal as op


def _oracle_rows(wl_np, batch, nmax):
    """bf-optimal strategy + all-sync + a strided slice of the full space."""
    n = int(wl_np["n"])
    pop = op.enumerate_strategies(n, batch, nmax)
    idx = np.unique(np.linspace(0, len(pop) - 1, 14).astype(int))
    return pop[idx]


@pytest.mark.parametrize("case", adv.cases(), ids=lambda c: c[0])
def test_evaluators_agree_with_f64_oracle_adversarial(case):
    """On adversarial chains all four evaluator ports agree with the f64
    loop oracle within kernel tolerance, and their best valid row equals
    the certified brute-force optimum."""
    name, wl, batch, budget, pack_hw, serve_hw = case
    wl_np = adv.packed(wl, pack_hw)
    bf = op.brute_force_optimal(wl_np, batch, budget, serve_hw)
    pop = np.concatenate([bf.strategy[None], _oracle_rows(wl_np, batch,
                                                          adv.NMAX)])
    wl_serve = op.scaled_wl_np(wl_np, serve_hw)

    outs = {
        "xla": cm.evaluate_population(wl_np, jnp.asarray(pop), float(batch),
                                      float(budget), serve_hw),
        "pallas": ops.fusion_eval_population(pop, wl_np, batch=float(batch),
                                             budget_bytes=float(budget),
                                             hw=serve_hw, interpret=True),
        "pallas_auto": ops.fusion_eval_population(
            pop, wl_np, batch=float(batch), budget_bytes=float(budget),
            hw=serve_hw),                    # compiled-or-fallback resolve
    }
    scans = [cm.prefix_scan(wl_np, jnp.asarray(s), float(batch),
                            float(budget), serve_hw)[1] for s in pop]
    outs["prefix_scan"] = cm.CostOut(*(np.stack([np.asarray(getattr(f, k))
                                                 for f in scans])
                                       for k in cm.CostOut._fields))

    boundary = name.startswith("boundary")
    for port, out in outs.items():
        lat = np.asarray(out.latency, np.float64)
        pk = np.asarray(out.peak_mem, np.float64)
        va = np.asarray(out.valid, bool)
        best = np.inf
        for i, s in enumerate(pop):
            want = ref_model.evaluate_ref(wl_serve, s, batch, budget,
                                          serve_hw)
            assert abs(lat[i] - want["latency"]) <= \
                1e-5 * max(abs(want["latency"]), 1e-30), (port, name, i)
            assert abs(pk[i] - want["peak_mem"]) <= \
                1e-5 * max(abs(want["peak_mem"]), 1.0), (port, name, i)
            at_edge = abs(want["peak_mem"] - budget) <= 1e-4 * max(budget,
                                                                   1.0)
            if not (boundary and at_edge):
                assert bool(va[i]) == want["valid"], (port, name, i)
            if va[i] and want["valid"]:
                best = min(best, lat[i])
        if bf.valid and not boundary:
            assert abs(best - bf.latency) <= 1e-5 * abs(bf.latency), \
                (port, name, best, bf.latency)
