"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core import cost_model as cm
from repro.core.accel import PAPER_ACCEL
from repro.workloads import resnet18, vgg16

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 256, 8, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, -1), (False, -1),
                                           (True, 96)])
def test_flash_attention_sweep(B, S, Hq, Hkv, hd, dtype, causal, window):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=128, bk=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,T,Hq,Hkv,hd,kv_len", [
    (1, 1024, 4, 4, 64, 800), (2, 2048, 8, 2, 64, 2048),
    (1, 1024, 8, 1, 128, 513),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, T, Hq, Hkv, hd, kv_len, dtype):
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, Hkv, hd)), dtype)
    out = ops.flash_decode(q, k, v, kv_len, bk=256, interpret=True)
    want = ref.decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,T,H,n,chunk", [
    (1, 64, 2, 32, 32), (2, 130, 3, 64, 64), (1, 256, 1, 16, 64),
])
def test_wkv6_sweep(B, T, H, n, chunk):
    r, k, v = (jnp.asarray(RNG.normal(size=(B, T, H, n)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.75, 0.9995, size=(B, T, H, n)),
                    jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, n)), jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(B, H, n, n)), jnp.float32)
    y, sT = ops.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("wl_fn,batch", [(vgg16, 64), (resnet18, 32)])
def test_fusion_eval_sweep(wl_fn, batch):
    hw = PAPER_ACCEL
    w = wl_fn(batch=batch)
    wl = cm.pack_workload(w, hw, nmax=64)
    pop = np.stack([cm.random_strategy(RNG, w.n, 64, batch)
                    for _ in range(64)])
    lat, peak, traf = ops.fusion_eval_population(
        pop, wl, batch=float(batch), hw=hw, interpret=True)
    rl, rp, rt = ref.fusion_eval_ref(pop, wl, batch=batch,
                                     budget_bytes=20 * 2 ** 20, hw=hw)
    np.testing.assert_allclose(np.asarray(lat), np.asarray(rl), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(peak), np.asarray(rp), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(traf), np.asarray(rt), rtol=1e-5)


def test_model_pallas_path_matches_xla():
    """attn_impl='pallas' end-to-end equals the XLA path (reduced arch)."""
    from repro.configs import get_config
    from repro.models import get_model
    cfg = get_config("qwen3_8b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 128)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    lx, _ = model.forward(params, cfg, batch, impl="xla")
    lp, _ = model.forward(params, cfg, batch, impl="pallas")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)
