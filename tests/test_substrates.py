"""Data pipeline, checkpointing, fault tolerance, compression, pipeline
parallelism."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import Checkpointer, restore_pytree, save_pytree
from repro.data import SyntheticLM
from repro.optim.compression import compressed, quantize_int8, dequantize_int8


def test_data_deterministic_and_resumable():
    src = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=3)
    a = src.batch_at(7)
    b = src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards tile the global batch
    full = src.batch_at(5)["tokens"]
    parts = [src.shard_at(5, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # labels are next-token
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_data_prefetcher():
    from repro.data import Prefetcher
    src = SyntheticLM(vocab=64, seq_len=8, global_batch=4, seed=0)
    pf = Prefetcher(src.batch_at, start_step=3, depth=2)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.close()
    assert (s0, s1) == (3, 4)
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(3)["tokens"])


def test_checkpoint_roundtrip_and_digest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": np.int64(7)}}
    save_pytree(tree, tmp_path / "ck")
    back = restore_pytree(tmp_path / "ck", tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    # digest catches corruption
    meta = json.loads((tmp_path / "ck" / "meta.json").read_text())
    victim = tmp_path / "ck" / meta["leaves"]["a"]["file"]
    arr = np.load(victim); arr[0, 0] += 1; np.save(victim, arr)
    with pytest.raises(IOError):
        restore_pytree(tmp_path / "ck", tree)


def test_checkpointer_keep_last_k_and_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        ck.save_async(step, {"w": jnp.full((3,), float(step))})
    ck.wait()
    assert ck.steps() == [3, 4]
    step, tree = ck.restore({"w": jnp.zeros((3,))})
    assert step == 4 and float(tree["w"][0]) == 4.0


def test_trainloop_crash_resume_equivalence(tmp_path):
    """Kill training mid-run; the resumed run must produce the same final
    params as an uninterrupted run (deterministic pipeline + checkpoints)."""
    from repro.runtime import TrainLoop

    def make(ckpt_dir):
        tx = optim.sgd(lr=0.1, momentum=0.0)
        params = {"w": jnp.zeros((4,))}
        opt = tx.init(params)

        @jax.jit
        def step_fn(p, o, batch):
            loss, g = jax.value_and_grad(
                lambda q: jnp.mean((q["w"] - batch) ** 2))(p)
            up, o = tx.update(g, o, p)
            return optim.apply_updates(p, up), o, loss

        batch_fn = lambda s: jnp.full((4,), float(s % 5))
        return TrainLoop(step_fn, params, opt, batch_fn,
                         ckpt_dir=str(ckpt_dir), ckpt_every=5, log_every=10)

    loop_a = make(tmp_path / "a")
    pa, _ = loop_a.run(40)

    loop_b = make(tmp_path / "b")
    with pytest.raises(RuntimeError, match="simulated node failure"):
        loop_b.run(40, crash_at=17)
    loop_b2 = make(tmp_path / "b")          # restart: restores step 15
    assert loop_b2.start_step > 0
    pb, _ = loop_b2.run(40)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-6)


def test_elastic_restore_across_shardings(tmp_path):
    """Checkpoint saved from one 'mesh' restores under a different sharding
    (here: host arrays -> device arrays; the multi-device version runs in
    the subprocess dry-run test)."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_pytree(tree, tmp_path / "ck")
    back = restore_pytree(tmp_path / "ck", tree, shardings=jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree))
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_int8_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s, shape, n = quantize_int8(x)
    back = dequantize_int8(q, s, shape, n)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 100

    # EF-compressed SGD converges on a quadratic like plain SGD
    tx = compressed(optim.sgd(lr=0.05, momentum=0.0))
    params = {"w": jnp.full((8,), 5.0)}
    state = tx.init(params)
    target = jnp.arange(8, dtype=jnp.float32)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        up, state = tx.update(g, state, params)
        params = optim.apply_updates(params, up)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.05


@pytest.mark.slow
def test_pipeline_parallel_4_stages():
    """GPipe shard_map pipeline == sequential stage application (subprocess
    with 4 host devices)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward, make_stage_mesh
        S, n_micro, mb, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(S, d, d)) / np.sqrt(d), jnp.float32)
        xs = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
        stage_fn = lambda W, x: jnp.tanh(x @ W)
        mesh = make_stage_mesh(S)
        out = pipeline_forward(Ws, xs, stage_fn, mesh,
                               n_microbatches=n_micro)
        want = xs
        for i in range(S):
            want = jnp.tanh(want @ Ws[i])
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
    """)
    p = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "PIPELINE_OK" in p.stdout
