"""Teacher-corpus pipeline: grid-GA determinism, decoration parity with the
host environment, returns-to-go relabeling and trajectory windowing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DTConfig, FusionEnv, GSamplerConfig, PAPER_ACCEL,
                        TrajectoryDataset, dt_apply, dt_init,
                        generate_teacher_corpus, returns_to_go,
                        window_dataset)
from repro.core import cost_model as cm
from repro.core.dataset import _decorate_grid
from repro.workloads import tiny_cnn, vgg16

MB = 2 ** 20
GA = GSamplerConfig(generations=8, population=16, seed=0)


def _gen(seed):
    return generate_teacher_corpus(
        [tiny_cnn()], PAPER_ACCEL, batch=64, budgets_mb=[2, 6],
        max_steps=12, top_k=4, ga_cfg=GSamplerConfig(
            generations=8, population=16, seed=seed),
        seed=seed, augment_jitter=1)


def test_corpus_same_seed_is_bit_identical():
    a, b = _gen(0), _gen(0)
    for k in ("rtg", "states", "actions", "mask", "t0"):
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k), err_msg=k)
    assert a.meta == b.meta


def test_corpus_rows_are_valid_and_deduped():
    ds = _gen(1)
    assert len(ds) > 0
    # every trajectory respects its own budget at every step (rtg >= 0 by
    # construction; the final step's peak must be under budget => rtg > 0
    # OR exactly at budget)
    assert (ds.rtg * ds.mask >= 0.0).all()
    keys = set()
    for i, (name, budget, sp, accel) in enumerate(ds.meta):
        key = (name, budget, accel, ds.actions[i].tobytes())
        assert key not in keys, "duplicate trajectory survived dedup"
        keys.add(key)
        assert sp > 0
        assert accel == PAPER_ACCEL.name


def test_decorate_grid_matches_host_env():
    wl = vgg16()
    nmax = 20
    env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=24 * MB,
                    nmax=nmax)
    rng = np.random.default_rng(0)
    strategies = np.stack([cm.random_strategy(rng, env.n, nmax, 64)
                           for _ in range(4)])
    wls = cm.stack_workloads([env.wl])
    st, rtg, ac, mk, fin = _decorate_grid(
        wls, jnp.asarray(strategies)[None], jnp.asarray([64.0], jnp.float32),
        jnp.asarray([24.0 * MB], jnp.float32), PAPER_ACCEL)
    T = env.n + 1
    for i, s in enumerate(strategies):
        host = env.decorate(s)
        np.testing.assert_allclose(np.asarray(st)[0, i, :T], host["states"],
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(rtg)[0, i, :T], host["rtg"],
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(ac)[0, i, :T], host["actions"],
                                   atol=1e-6)
        # padding beyond the episode is zero (masked)
        assert (np.asarray(mk)[0, i, :T] == 1.0).all()
        assert (np.asarray(mk)[0, i, T:] == 0.0).all()
        assert (np.asarray(st)[0, i, T:] == 0.0).all()


def test_returns_to_go_relabel_rule():
    peaks = np.array([0.0, 5.0, 10.0, 20.0], np.float32) * MB
    rtg = returns_to_go(peaks, 10.0 * MB)
    np.testing.assert_allclose(rtg, [1.0, 0.5, 0.0, 0.0])
    # parity with the environment's decoration
    env = FusionEnv(tiny_cnn(), PAPER_ACCEL, batch=64, budget_bytes=4 * MB,
                    nmax=12)
    s = np.full(12, cm.SYNC, np.int32)
    s[: env.n + 1] = 4
    traj = env.decorate(s)
    tr = cm.prefix_trace(env.wl, jnp.asarray(s), 64.0, 4.0 * MB, env.hw)
    np.testing.assert_allclose(
        traj["rtg"], returns_to_go(np.asarray(tr.peak_mem)[: env.n + 1],
                                   4.0 * MB), atol=1e-6)


def _toy_dataset(N=3, T=16, L=None):
    rng = np.random.default_rng(0)
    L = L or [16, 11, 7]
    mask = np.zeros((N, T), np.float32)
    for i, l in enumerate(L):
        mask[i, :l] = 1.0
    return TrajectoryDataset(
        rtg=(rng.random((N, T)).astype(np.float32) * mask),
        states=rng.random((N, T, 8)).astype(np.float32) * mask[..., None],
        actions=rng.random((N, T)).astype(np.float32) * mask,
        mask=mask, meta=[("w", 1.0, 1.0)] * N)


def test_window_dataset_slices_and_offsets():
    ds = _toy_dataset()
    w = window_dataset(ds, 8, stride=4)
    assert w.max_steps == 8
    assert len(w) > len(ds)
    # every window is an exact slice of its parent at offset t0
    per_parent = {}
    cursor = 0
    for i in range(len(ds)):
        L = int(ds.mask[i].sum())
        starts = list(range(0, max(L - 8, 0) + 1, 4))
        if starts[-1] + 8 < L:
            starts.append(L - 8)
        per_parent[i] = starts
    k = 0
    for i, starts in per_parent.items():
        for s0 in starts:
            assert int(w.t0[k]) == s0
            np.testing.assert_array_equal(w.rtg[k], ds.rtg[i, s0:s0 + 8])
            np.testing.assert_array_equal(w.states[k],
                                          ds.states[i, s0:s0 + 8])
            np.testing.assert_array_equal(w.actions[k],
                                          ds.actions[i, s0:s0 + 8])
            np.testing.assert_array_equal(w.mask[k], ds.mask[i, s0:s0 + 8])
            k += 1
    assert k == len(w)
    # tail coverage: the last step of every trajectory lands in some window
    for i, starts in per_parent.items():
        L = int(ds.mask[i].sum())
        assert any(s0 + 8 >= L for s0 in starts)


def test_window_dataset_noop_when_wide_enough():
    ds = _toy_dataset()
    assert window_dataset(ds, 16) is ds
    assert window_dataset(ds, 32) is ds


def test_dt_apply_time_offsets():
    cfg = DTConfig(n_blocks=1, n_heads=1, d_model=32, d_ff=64, max_steps=24)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, T = 2, 8
    rtg = jnp.asarray(rng.random((B, T)), jnp.float32)
    st = jnp.asarray(rng.random((B, T, 8)), jnp.float32)
    ac = jnp.asarray(rng.random((B, T)), jnp.float32)
    base = dt_apply(params, cfg, rtg, st, ac)
    zero = dt_apply(params, cfg, rtg, st, ac, jnp.zeros((B,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(zero))
    off = dt_apply(params, cfg, rtg, st, ac, jnp.full((B,), 5, jnp.int32))
    assert not np.allclose(np.asarray(base), np.asarray(off)), \
        "time offsets must reach the timestep embedding"
    # offsets past the embedding table fail loudly (NaN), never silently
    # clamp to the last row
    over = dt_apply(params, cfg, rtg, st, ac,
                    jnp.full((B,), cfg.max_steps - 2, jnp.int32))
    assert np.isnan(np.asarray(over)).any(), \
        "out-of-table time offsets must poison the output"


# ---------------------------------------------------------------------------
# teacher="optimal" (DESIGN §16): provably-optimal labels, identical schema
# ---------------------------------------------------------------------------


def _gen_optimal(seed):
    return generate_teacher_corpus(
        [tiny_cnn()], PAPER_ACCEL, batch=8, budgets_mb=[2, 6],
        max_steps=12, top_k=4, seed=seed, augment_jitter=1,
        teacher="optimal")


def test_optimal_teacher_corpus_schema_and_determinism():
    """Same TrajectoryDataset schema as the GA teacher, bit-identical
    across reruns of the same seed."""
    a, b = _gen_optimal(3), _gen_optimal(3)
    ga = _gen(0)
    for k in ("rtg", "states", "actions", "mask", "t0", "hw"):
        np.testing.assert_array_equal(getattr(a, k), getattr(b, k),
                                      err_msg=k)
        assert getattr(a, k).dtype == getattr(ga, k).dtype, k
        assert getattr(a, k).shape[1:] == getattr(ga, k).shape[1:], k
    assert a.meta == b.meta and len(a) > 0


def test_optimal_teacher_labels_are_the_certified_optimum():
    """The highest-speedup trajectory per condition decodes back to the
    oracle's exact optimum latency."""
    from repro.core import optimal as op
    ds = _gen_optimal(0)
    for budget in (2.0, 6.0):
        env = FusionEnv(tiny_cnn(), PAPER_ACCEL, batch=8,
                        budget_bytes=budget * MB, nmax=12)
        res = op.optimal_mapping(env, certify=False)
        assert res.valid
        best = max((m[2] for m in ds.meta if m[1] == budget), default=0.0)
        want = env.baseline_latency / res.latency
        assert best == pytest.approx(want, rel=1e-4), (budget, best, want)


def test_optimal_teacher_rejects_unknown_name():
    with pytest.raises(ValueError, match="teacher"):
        generate_teacher_corpus([tiny_cnn()], PAPER_ACCEL, batch=8,
                                budgets_mb=[2], max_steps=12,
                                teacher="dp")
