"""Contract-linter tests (DESIGN §18).

Per-rule fixture triples — a violating snippet, a clean snippet, and a
suppressed snippet — for every rule family, plus the mechanics (noqa
justification policy, baseline fingerprints, stale-entry detection, CLI
exit codes) and the self-check: this repository with the committed
ANALYSIS_baseline.json yields zero new findings.
"""
import json
import pathlib
import textwrap

import pytest

from repro.analysis import (RULES, apply_baseline, load_baseline,
                            run_analysis, write_baseline)
from repro.analysis.__main__ import main as cli_main

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run(tmp_path, code, rel="src/repro/core/mod.py", extra=None):
    """Analyze one snippet placed at ``rel`` inside a scratch repo root."""
    root = tmp_path / "repo"
    f = root / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(code))
    for relp, content in (extra or {}).items():
        p = root / relp
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(content))
    return run_analysis(root, files=[rel])


def _rules_fired(result):
    return {f.rule for f in result.findings}


# --------------------------------------------------------------------------
# one (violating, clean, suppressed) triple per rule; suppressed=None for
# repo-level rules whose suppression path is the baseline (tested below)
FIXTURES = {
    "RNG001": (
        "import numpy as np\nx = np.random.rand(3)\n",
        "import numpy as np\nrng = np.random.default_rng(0)\n"
        "x = rng.random(3)\n"
        "def f(rng: np.random.Generator):\n    return rng\n",
        "import numpy as np\n"
        "x = np.random.rand(3)  # repro: noqa[RNG001] -- throwaway demo\n",
    ),
    "RNG002": (
        "import numpy as np\nrng = np.random.default_rng()\n",
        "import numpy as np\nrng = np.random.default_rng(1234)\n",
        "import numpy as np\n"
        "rng = np.random.default_rng()  # repro: noqa[RNG002] -- probe only\n",
    ),
    "RNG003": (
        "import time, numpy as np\n"
        "rng = np.random.default_rng(int(time.time()))\n",
        "import numpy as np\n"
        "def corpus(cfg):\n"
        "    return np.random.default_rng(cfg.seed)\n",
        "import time, numpy as np\n"
        "rng = np.random.default_rng(int(time.time()))"
        "  # repro: noqa[RNG003] -- demo harness\n",
    ),
    # the PR 5 bug pattern: hardware as a static jit kwarg
    "JIT001": (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('bp', 'hw'))\n"
        "def fusion_eval(strategies, bp, hw):\n    return strategies\n",
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('bp', 'interpret'))\n"
        "def fusion_eval(strategies, hw, bp, interpret):\n"
        "    return strategies\n",
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('hw',))"
        "  # repro: noqa[JIT001] -- hw is a compile-time probe here\n"
        "def probe(hw):\n    return hw\n",
    ),
    "JIT002": (
        "import jax\n"
        "def f(x):\n    return x\n"
        "g = jax.jit(f, static_argnames=())\n",
        "import jax\n"
        "def f(x):\n    return x\n"
        "g = jax.jit(f)\n",
        "import jax\n"
        "def f(x):\n    return x\n"
        "g = jax.jit(f, static_argnames=())"
        "  # repro: noqa[JIT002] -- kwarg kept for API symmetry\n",
    ),
    "SYNC001": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n    return x.sum().item()\n",
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n    return x.sum()\n"
        "def host(x):\n    return x.item()\n",
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x.sum().item()"
        "  # repro: noqa[SYNC001] -- fixture of the failure itself\n",
    ),
    "SYNC002": (
        "import jax, numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n    return np.asarray(x)\n",
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n    return jnp.asarray(x)\n",
        "import jax, numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.asarray(x)"
        "  # repro: noqa[SYNC002] -- fixture of the failure itself\n",
    ),
    "SYNC003": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x:\n        return 1\n    return 0\n",
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('flag',))\n"
        "def f(x, flag, opt=None):\n"
        "    if flag:\n        return x\n"
        "    if opt is None:\n        return -x\n    return x\n",
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x:  # repro: noqa[SYNC003] -- fixture of the failure itself\n"
        "        return 1\n    return 0\n",
    ),
    "SYNC004": (
        "import jax.numpy as jnp\n"
        "def hot(x):\n    return float(jnp.sum(x))\n",
        "import jax.numpy as jnp\n"
        "def hot(x):\n    return jnp.sum(x)\n"
        "def boundary(y):\n    return float(y)\n",
        "import jax.numpy as jnp\n"
        "def hot(x):\n"
        "    return float(jnp.sum(x))"
        "  # repro: noqa[SYNC004] -- one sync at episode boundary\n",
    ),
    "DET001": (
        "def f(xs):\n    return [x for x in set(xs)]\n",
        "def f(xs):\n    return [x for x in sorted(set(xs))]\n",
        "def f(xs):\n"
        "    return [x for x in set(xs)]"
        "  # repro: noqa[DET001] -- feeds a commutative sum\n",
    ),
    "DET002": (
        "def save(d):\n    return [[k, v] for k, v in d.items()]\n",
        "def save(d):\n    return [[k, v] for k, v in sorted(d.items())]\n",
        "def save(d):\n"
        "    return [[k, v] for k, v in d.items()]"
        "  # repro: noqa[DET002] -- order never reaches persisted bytes\n",
    ),
    "DET003": (
        "import numpy as np\nx = np.zeros(3, dtype=np.float64)\n",
        "import numpy as np\nx = np.zeros(3, dtype=np.float32)\n",
        "import numpy as np\n"
        "x = np.zeros(3, dtype=np.float64)"
        "  # repro: noqa[DET003] -- deliberate f64 oracle arithmetic\n",
    ),
}

_SERVING_REL = "src/repro/serving/mod.py"
_FIXTURE_REL = {           # rules scoped to particular paths
    "SYNC004": _SERVING_REL,
    "DET002": "src/repro/core/dataset.py",
    "DET003": "src/repro/core/mod.py",
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_fires_on_violation(tmp_path, rule):
    bad, _, _ = FIXTURES[rule]
    res = _run(tmp_path, bad, rel=_FIXTURE_REL.get(rule,
                                                   "src/repro/core/mod.py"))
    assert rule in _rules_fired(res), \
        f"{rule} must fire on its violating fixture; got {res.findings}"


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_silent_on_clean(tmp_path, rule):
    _, clean, _ = FIXTURES[rule]
    res = _run(tmp_path, clean, rel=_FIXTURE_REL.get(rule,
                                                     "src/repro/core/mod.py"))
    assert rule not in _rules_fired(res), \
        f"{rule} false-positives on its clean fixture: {res.findings}"


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_suppressed_with_justified_noqa(tmp_path, rule):
    _, _, suppressed = FIXTURES[rule]
    res = _run(tmp_path, suppressed,
               rel=_FIXTURE_REL.get(rule, "src/repro/core/mod.py"))
    assert rule not in _rules_fired(res)
    assert any(f.rule == rule for f in res.suppressed), \
        "the noqa must record a suppressed finding, not a silent miss"
    # a justified, used noqa triggers no ANA meta-findings
    assert not _rules_fired(res) & {"ANA001", "ANA002"}


# ------------------------------------------------------------ scoping edges

def test_det002_only_in_order_sensitive_modules(tmp_path):
    bad, _, _ = FIXTURES["DET002"]
    res = _run(tmp_path, bad, rel="src/repro/core/train.py")
    assert "DET002" not in _rules_fired(res)


def test_det003_only_in_core(tmp_path):
    bad, _, _ = FIXTURES["DET003"]
    res = _run(tmp_path, bad, rel=_SERVING_REL)
    assert "DET003" not in _rules_fired(res)


def test_sync001_item_in_serving_hot_path_even_outside_jit(tmp_path):
    res = _run(tmp_path, "def hot(x):\n    return x.item()\n",
               rel=_SERVING_REL)
    assert "SYNC001" in _rules_fired(res)
    res = _run(tmp_path, "def hot(x):\n    return x.item()\n",
               rel="src/repro/core/mod.py")
    assert "SYNC001" not in _rules_fired(res)


def test_jit001_static_argnums_resolves_param_names(tmp_path):
    code = ("import functools, jax\n"
            "@functools.partial(jax.jit, static_argnums=(1,))\n"
            "def f(x, hw):\n    return x\n")
    res = _run(tmp_path, code)
    assert "JIT001" in _rules_fired(res)


def test_noqa_example_inside_docstring_is_not_a_suppression(tmp_path):
    code = ('"""Docs show: x = f()  # repro: noqa[RNG001] -- example."""\n'
            "import numpy as np\nx = np.random.rand(3)\n")
    res = _run(tmp_path, code)
    assert "RNG001" in _rules_fired(res)       # docstring did not suppress
    assert "ANA001" not in _rules_fired(res)   # and is not a dead noqa


# --------------------------------------------------------------- DOC family

def _doc_repo(tmp_path, design, readme):
    root = tmp_path / "repo"
    (root / "src" / "repro").mkdir(parents=True, exist_ok=True)
    (root / "DESIGN.md").write_text(design)
    (root / "README.md").write_text(readme)
    return root

_CLAIM_SCRIPTS = ["table1_methods.py", "table2_generalization.py",
                  "table3_transfer.py", "fig4_solutions.py",
                  "speed_oneshot.py", "table_hw_generalization.py"]
_GOOD_README = ("run `python -m pytest` and `python -m benchmarks.run`\n"
                + "".join(f"- benchmarks/{s}\n" for s in _CLAIM_SCRIPTS))


def _mk_scripts(root):
    (root / "benchmarks").mkdir(exist_ok=True)
    for s in _CLAIM_SCRIPTS:
        (root / "benchmarks" / s).write_text("")


def test_doc001_gap_in_section_numbering(tmp_path):
    root = _doc_repo(tmp_path, "## §1 A\n## §3 C\n", _GOOD_README)
    _mk_scripts(root)
    fired = {f.rule for f in run_analysis(root, files=[]).findings}
    assert "DOC001" in fired
    root2 = _doc_repo(tmp_path / "b", "## §1 A\n## §2 B\n", _GOOD_README)
    _mk_scripts(root2)
    assert "DOC001" not in {f.rule
                            for f in run_analysis(root2, files=[]).findings}


def test_doc002_unresolved_citation(tmp_path):
    root = _doc_repo(tmp_path, "## §1 A\n", _GOOD_README)
    _mk_scripts(root)
    mod = root / "src" / "repro" / "mod.py"
    mod.write_text('"""Implements DESIGN §9."""\n')
    res = run_analysis(root, files=["src/repro/mod.py"])
    assert "DOC002" in _rules_fired(res)
    mod.write_text('"""Implements DESIGN §1."""\n')
    res = run_analysis(root, files=["src/repro/mod.py"])
    assert "DOC002" not in _rules_fired(res)


def test_doc003_missing_link_and_baseline(tmp_path):
    root = _doc_repo(tmp_path, "## §1 A\n",
                     _GOOD_README + "see [x](missing_dir/nope.md) and "
                                    "BENCH_ghost.json\n")
    _mk_scripts(root)
    msgs = [f.message for f in run_analysis(root, files=[]).findings
            if f.rule == "DOC003"]
    assert any("missing_dir/nope.md" in m for m in msgs)
    assert any("BENCH_ghost.json" in m for m in msgs)


def test_doc004_readme_completeness(tmp_path):
    root = _doc_repo(tmp_path, "## §1 A\n", "an empty readme\n")
    fired = {f.rule for f in run_analysis(root, files=[]).findings}
    assert "DOC004" in fired
    root2 = _doc_repo(tmp_path / "b", "## §1 A\n", _GOOD_README)
    _mk_scripts(root2)
    assert "DOC004" not in {f.rule
                            for f in run_analysis(root2, files=[]).findings}


# --------------------------------------------------------------- EXP family

def test_exp001_all_name_without_binding(tmp_path):
    code = "__all__ = ['ghost']\n"
    res = _run(tmp_path, code, rel="src/repro/core/__init__.py")
    assert "EXP001" in _rules_fired(res)


def test_exp001_lazy_table_satisfies_all(tmp_path):
    code = ("_API = ('Engine',)\n"
            "def __getattr__(name):\n"
            "    if name in _API:\n"
            "        from . import engine\n"
            "        return getattr(engine, name)\n"
            "    raise AttributeError(name)\n"
            "__all__ = ['Engine']\n")
    res = _run(tmp_path, code, rel="src/repro/serving/__init__.py")
    assert not _rules_fired(res) & {"EXP001", "EXP002"}


def test_exp002_lazy_name_not_advertised(tmp_path):
    code = ("_API = ('Engine', 'Hidden')\n"
            "def __getattr__(name):\n"
            "    if name in _API:\n"
            "        from . import engine\n"
            "        return getattr(engine, name)\n"
            "    raise AttributeError(name)\n"
            "__all__ = ['Engine']\n")
    res = _run(tmp_path, code, rel="src/repro/serving/__init__.py")
    assert "EXP002" in _rules_fired(res)


def test_exp_handles_computed_all_like_repro_init(tmp_path):
    code = ("__version__ = '1.0'\n"
            "_PUBLIC = {'A': 'core', 'B': 'serving'}\n"
            "__all__ = ['__version__', 'serve'] + sorted(_PUBLIC)\n"
            "def __getattr__(name):\n"
            "    if name in _PUBLIC:\n"
            "        return object()\n"
            "    raise AttributeError(name)\n"
            "def serve():\n    return None\n")
    res = _run(tmp_path, code, rel="src/repro/__init__.py")
    assert not _rules_fired(res) & {"EXP001", "EXP002"}


# ----------------------------------------------------- suppression mechanics

def test_ana002_bare_noqa_does_not_suppress(tmp_path):
    code = ("import numpy as np\n"
            "x = np.random.rand(3)  # repro: noqa[RNG001]\n")
    res = _run(tmp_path, code)
    fired = _rules_fired(res)
    assert "RNG001" in fired, "bare noqa must not suppress"
    assert "ANA002" in fired


def test_ana002_unknown_rule_id(tmp_path):
    code = "x = 1  # repro: noqa[ZZZ999] -- because\n"
    res = _run(tmp_path, code)
    assert "ANA002" in _rules_fired(res)


def test_ana001_unused_noqa(tmp_path):
    code = "x = 1  # repro: noqa[RNG001] -- nothing here violates\n"
    res = _run(tmp_path, code)
    assert "ANA001" in _rules_fired(res)


# ----------------------------------------------------------------- baseline

def test_baseline_absorbs_by_fingerprint_across_line_drift(tmp_path):
    bad, _, _ = FIXTURES["RNG001"]
    res = _run(tmp_path, bad)
    bl = tmp_path / "bl.json"
    write_baseline(bl, res.findings)
    entries = load_baseline(bl)
    new, stale = apply_baseline(res.findings, entries)
    assert not new and not stale
    # shift the violating line down two lines: fingerprint still matches
    res2 = _run(tmp_path / "shift", "# pad\n# pad\n" + bad)
    new2, stale2 = apply_baseline(res2.findings, entries)
    assert not new2 and not stale2
    # fix the violation: the entry goes stale (baseline must shrink)
    res3 = _run(tmp_path / "fix", FIXTURES["RNG001"][1])
    new3, stale3 = apply_baseline(res3.findings, entries)
    assert not new3 and stale3


def test_baseline_requires_justifications(tmp_path):
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"version": 1, "entries": [
        {"rule": "RNG001", "path": "a.py", "fingerprint": "x", }]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(bl)


def test_doc_finding_is_baselinable(tmp_path):
    root = _doc_repo(tmp_path, "## §1 A\n## §3 C\n", _GOOD_README)
    _mk_scripts(root)
    findings = run_analysis(root, files=[]).findings
    doc = [f for f in findings if f.rule == "DOC001"]
    assert doc
    bl = tmp_path / "bl.json"
    write_baseline(bl, doc)
    new, stale = apply_baseline(doc, load_baseline(bl))
    assert not new and not stale


# ---------------------------------------------------------------------- CLI

def test_cli_check_exit_codes(tmp_path, capsys):
    root = tmp_path / "repo"
    mod = root / "src" / "repro" / "mod.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("import numpy as np\nx = np.random.rand(3)\n")
    assert cli_main(["--root", str(root), "--check"]) == 1
    mod.write_text("x = 1\n")
    assert cli_main(["--root", str(root), "--check"]) == 0
    assert cli_main(["--root", str(tmp_path), "--check"]) == 2  # not a repo
    capsys.readouterr()


def test_cli_json_output(tmp_path, capsys):
    root = tmp_path / "repo"
    mod = root / "src" / "repro" / "mod.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("import numpy as np\nx = np.random.rand(3)\n")
    out = tmp_path / "out.json"
    assert cli_main(["--root", str(root), "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["findings"] and \
        payload["findings"][0]["rule"] == "RNG001"
    capsys.readouterr()


# ------------------------------------------------------------- registry/self

def test_registry_has_all_families():
    families = {rid[:3] for rid in RULES}
    assert {"RNG", "JIT", "SYN", "DET", "DOC", "EXP", "ANA"} <= families
    assert len(RULES) >= 18
    for rule in RULES.values():
        assert rule.description and rule.contract and \
            rule.severity in ("error", "warning", "info")


def test_analysis_package_is_jax_free():
    """The CI analysis job runs dependency-free: importing repro.analysis
    must not pull jax/numpy."""
    import subprocess
    import sys
    code = ("import sys; import repro.analysis; "
            "bad = [m for m in ('jax', 'numpy') if m in sys.modules]; "
            "assert not bad, bad")
    subprocess.run([sys.executable, "-c", code], check=True,
                   env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin"})


def test_self_check_repo_is_clean_with_committed_baseline():
    """The repo itself, under the committed baseline, has zero unbaselined
    findings and zero stale entries — the exact CI `analysis` gate."""
    res = run_analysis(ROOT)
    entries = load_baseline(ROOT / "ANALYSIS_baseline.json")
    new, stale = apply_baseline(res.findings, entries)
    assert not new, "new contract-linter findings:\n" + \
        "\n".join(f.format() for f in new)
    assert not stale, f"stale baseline entries (prune them): {stale}"
