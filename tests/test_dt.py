"""DNNFuser decision transformer + Seq2Seq: causality, learnability,
conditional one-shot inference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, S2SConfig,
                        TrainConfig, TrajectoryDataset, collect_teacher_data,
                        dnnfuser_infer, dt_apply, dt_init, dt_loss,
                        s2s_apply, s2s_init, s2s_loss, s2s_infer,
                        train_model, GSamplerConfig)
from repro.workloads import vgg16

MB = 2 ** 20
CFG = DTConfig(max_steps=20)


def _rand_batch(rng, B, T):
    return {"rtg": jnp.asarray(rng.random((B, T)), jnp.float32),
            "states": jnp.asarray(rng.random((B, T, 8)), jnp.float32),
            "actions": jnp.asarray(rng.random((B, T)), jnp.float32),
            "mask": jnp.ones((B, T), jnp.float32)}


def test_dt_causality():
    """Prediction at step t must not depend on actions/states at steps > t
    and not on the action at step t itself."""
    rng = np.random.default_rng(0)
    params = dt_init(jax.random.PRNGKey(0), CFG)
    b = _rand_batch(rng, 1, CFG.max_steps)
    base = dt_apply(params, CFG, b["rtg"], b["states"], b["actions"])
    t = 7
    # perturb future state + current/future actions
    s2 = b["states"].at[:, t + 1:].set(0.123)
    a2 = b["actions"].at[:, t:].set(-0.777)
    pert = dt_apply(params, CFG, b["rtg"], s2, a2)
    np.testing.assert_allclose(np.asarray(base)[:, : t + 1],
                               np.asarray(pert)[:, : t + 1], atol=1e-5)


def test_dt_overfits_tiny_dataset():
    rng = np.random.default_rng(1)
    N, T = 8, 20
    ds = TrajectoryDataset(
        rtg=rng.random((N, T)).astype(np.float32),
        states=rng.random((N, T, 8)).astype(np.float32),
        actions=rng.random((N, T)).astype(np.float32),
        mask=np.ones((N, T), np.float32))
    params = dt_init(jax.random.PRNGKey(0), CFG)
    loss0 = float(dt_loss(params, CFG, {k: jnp.asarray(v) for k, v in
                                        ds.sample(rng, 8).items()}))
    params, log = train_model(lambda p, b: dt_loss(p, CFG, b), params, ds,
                              TrainConfig(steps=150, batch_size=8, lr=1e-3,
                                          log_every=50))
    assert log["final_loss"] < loss0 * 0.2, (loss0, log["final_loss"])


@pytest.fixture(scope="module")
def trained_mapper():
    wl = vgg16()
    ds = collect_teacher_data(
        [wl], PAPER_ACCEL, batch=64, budgets_mb=[16, 48], max_steps=20,
        top_k=4, ga_cfg=GSamplerConfig(generations=20, seed=0),
        augment_jitter=1)
    params = dt_init(jax.random.PRNGKey(0), CFG)
    params, _ = train_model(lambda p, b: dt_loss(p, CFG, b), params, ds,
                            TrainConfig(steps=250, batch_size=16))
    return wl, params


def test_dt_inference_valid_on_unseen_condition(trained_mapper):
    wl, params = trained_mapper
    env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=24 * MB,
                    nmax=20)
    res = dnnfuser_infer(params, CFG, env)
    assert res.valid                       # conditioning respects budget
    assert res.speedup > 0.75              # never catastrophically bad
    assert res.n_model_calls == wl.n + 1   # one-shot: N+1 tiny forwards


def test_s2s_trains_and_infers():
    rng = np.random.default_rng(2)
    wl = vgg16()
    ds = collect_teacher_data(
        [wl], PAPER_ACCEL, batch=64, budgets_mb=[32], max_steps=20,
        top_k=3, ga_cfg=GSamplerConfig(generations=12, seed=0),
        augment_jitter=0)
    cfg = S2SConfig(max_steps=20)
    params = s2s_init(jax.random.PRNGKey(0), cfg)
    params, log = train_model(lambda p, b: s2s_loss(p, cfg, b), params, ds,
                              TrainConfig(steps=150, batch_size=8))
    env = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=32 * MB,
                    nmax=20)
    res = s2s_infer(params, cfg, env)
    assert res.valid and np.isfinite(res.latency)
