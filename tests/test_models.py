"""Per-architecture smoke tests: reduced configs, one train step on CPU,
finite outputs, and prefill/decode consistency with the teacher-forced
forward (the serving path's correctness anchor)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, SHAPES, cells
from repro.models import get_model, input_specs, decode_state_specs

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=32):
    if cfg.family == "encdec":
        sd = max(S // 8, 8)
        return {"embeds": jnp.asarray(
                    RNG.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, sd)),
                                      jnp.int32),
                "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, sd)),
                                      jnp.int32)}
    if cfg.embed_inputs:
        return {"embeds": jnp.asarray(
                    RNG.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)}
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: forward+backward+update, shapes + no
    NaNs (assignment: per-arch smoke test)."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = _batch(cfg)
    logits, _ = model.forward(params, cfg, batch)
    S_expect = batch["labels"].shape[1]
    assert logits.shape == (2, S_expect, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen3_8b", "gemma3_1b", "rwkv6_3b",
                                  "hymba_15b", "whisper_base"])
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + decode_step(token) logits == teacher-forced
    forward at the same position (KV-cache correctness)."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    full_logits, _ = model.forward(params, cfg, batch)

    if cfg.family == "encdec":
        sd = batch["tokens"].shape[1]
        pf = {"embeds": batch["embeds"], "tokens": batch["tokens"][:, :-1]}
        logits_last, state = model.prefill(params, cfg, pf, max_len=sd + 4,
                                           cache_dtype=jnp.float32)
        step = {"tokens": batch["tokens"][:, -1:]}
    elif cfg.embed_inputs:
        pf = {"embeds": batch["embeds"][:, :-1]}
        logits_last, state = model.prefill(params, cfg, pf, max_len=S + 4,
                                           cache_dtype=jnp.float32)
        step = {"embeds": batch["embeds"][:, -1:]}
    else:
        pf = {"tokens": batch["tokens"][:, :-1]}
        logits_last, state = model.prefill(params, cfg, pf, max_len=S + 4,
                                           cache_dtype=jnp.float32)
        step = {"tokens": batch["tokens"][:, -1:]}
    # prefill's last logits == forward at position -2
    np.testing.assert_allclose(np.asarray(logits_last[:, -1]),
                               np.asarray(full_logits[:, -2]),
                               rtol=2e-3, atol=2e-3)
    dec_logits, _ = model.decode_step(params, cfg, state, step)
    np.testing.assert_allclose(np.asarray(dec_logits[:, -1]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_cell_policy_covers_40():
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(runnable) == 33
    assert all(s == "long_500k" for _, s, ok, _ in skipped for s in [s])
    # every skip has a reason recorded
    assert all(why for _, _, _, why in skipped)


def test_input_specs_cover_all_cells():
    for arch, shape_name, ok, _ in cells(include_skipped=False):
        cfg = get_config(arch)
        specs = input_specs(cfg, SHAPES[shape_name])
        assert specs, (arch, shape_name)
        if SHAPES[shape_name].kind == "decode":
            st = decode_state_specs(cfg, SHAPES[shape_name])
            assert jax.tree_util.tree_leaves(st)
