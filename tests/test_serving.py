"""The layered serving engine (DESIGN.md §12).

Pins the three §12 contracts:

 - **cross-workload batching**: a mixed [resnet18, mobilenet_v2, tiny_cnn]
   request batch through ``dnnfuser_infer_batch`` — heterogeneous true
   layer counts under one padded ``nmax`` — is per-row bit-exact with each
   workload served alone on BOTH the fused and the host reference paths;
 - **bucketing**: engine results (pow2-padded request batches, nmax-bucket
   padding, masked positions) are bit-exact with unbucketed single calls,
   and after warmup, traffic across all bucket shapes triggers ZERO new
   compilations (the recompile-churn guard);
 - **backend protocol**: DT and seq2seq ride the same rollout/serving code
   via ``backend_for``; the strategy LRU counts hits/misses and evicts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACCEL_ZOO, DTConfig, DTBackend, FusionEnv,
                        MapperEngine, MapRequest, PAPER_ACCEL, S2SConfig,
                        S2SBackend, StrategyCache, backend_for,
                        dnnfuser_infer, dnnfuser_infer_batch,
                        dnnfuser_infer_fused, dt_init, s2s_init)
from repro.core import cost_model as cm
from repro.core import infer as infer_mod
from repro.serving import (batch_bucket, budget_bucket,
                           default_nmax_buckets, nmax_bucket, pow2_buckets)
from repro.workloads import mobilenet_v2, resnet18, tiny_cnn, vgg16

MB = 2 ** 20


# --- bucketing primitives ---------------------------------------------------

def test_bucketing_primitives():
    assert [batch_bucket(c) for c in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pow2_buckets(8) == (1, 2, 4, 8)
    assert default_nmax_buckets(20) == (8, 16, 20)
    assert default_nmax_buckets(64) == (8, 16, 32, 64)
    assert nmax_bucket(7, (8, 16, 20)) == 8
    assert nmax_bucket(17, (8, 16, 20)) == 20
    with pytest.raises(ValueError):
        nmax_bucket(21, (8, 16, 20))
    assert budget_bucket(20 * MB) == budget_bucket(20 * MB + 1000)
    assert budget_bucket(20 * MB) != budget_bucket(21 * MB)


def test_strategy_cache_lru_counters_and_eviction():
    c = StrategyCache(capacity=2)
    assert c.get("a") is None and c.misses == 1
    c.put("a", 1); c.put("b", 2)
    assert c.get("a") == 1 and c.hits == 1
    c.put("c", 3)                      # evicts "b" (least recent)
    assert "b" not in c and "a" in c and len(c) == 2
    assert c.get("b") is None
    assert 0.0 < c.hit_rate < 1.0


def test_stack_workloads_rejects_mixed_nmax():
    with pytest.raises(ValueError, match="different nmax"):
        cm.stack_workloads([cm.pack_workload(tiny_cnn(), PAPER_ACCEL, 8),
                            cm.pack_workload(tiny_cnn(), PAPER_ACCEL, 16)])


# --- cross-workload batching (the §12 core contract) ------------------------

def test_mixed_network_batch_matches_each_served_alone():
    """[resnet18, mobilenet_v2, tiny_cnn] — three true layer counts (18,
    53, 6) under one nmax=64 — served in ONE device call must be per-row
    bit-exact with every workload served alone, on the fused AND the host
    reference paths."""
    cfg = DTConfig(max_steps=64)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    conds = [(resnet18(), ACCEL_ZOO["edge"], 64, 20 * MB),
             (mobilenet_v2(), ACCEL_ZOO["mobile"], 32, 12 * MB),
             (tiny_cnn(), ACCEL_ZOO["edge"], 16, 2 * MB)]
    envs = [FusionEnv(w, acc, batch=b, budget_bytes=m, nmax=64)
            for w, acc, b, m in conds]
    out = dnnfuser_infer_batch(params, cfg, envs,
                               np.asarray([c[2] for c in conds], np.float32),
                               np.asarray([c[3] for c in conds], np.float32))
    assert out["strategy"].shape == (3, 64)
    for i, env in enumerate(envs):
        fused = dnnfuser_infer_fused(params, cfg, env)
        host = dnnfuser_infer(params, cfg, env)
        assert (out["strategy"][i] == fused.strategy).all(), i
        assert (out["strategy"][i] == host.strategy).all(), i
        np.testing.assert_allclose(out["latency"][i], fused.latency,
                                   rtol=1e-5)
        assert bool(out["valid"][i]) == fused.valid
        # padding positions past the true n stay SYNC
        assert (out["strategy"][i][env.n + 1:] == cm.SYNC).all()


def test_stacked_workload_dict_and_hw_validation():
    cfg = DTConfig(max_steps=20)
    params = dt_init(jax.random.PRNGKey(1), cfg)
    wls = [cm.pack_workload(vgg16(), PAPER_ACCEL, 20),
           cm.pack_workload(tiny_cnn(), PAPER_ACCEL, 20)]
    stacked = cm.stack_workloads(wls)
    with pytest.raises(ValueError, match="hw is required"):
        dnnfuser_infer_batch(params, cfg, stacked, [64.0, 64.0],
                             [20 * MB, 20 * MB])
    with pytest.raises(ValueError, match="rows"):
        dnnfuser_infer_batch(params, cfg, stacked, [64.0], [20 * MB],
                             PAPER_ACCEL)
    out = dnnfuser_infer_batch(params, cfg, stacked, [64.0, 64.0],
                               [20 * MB, 20 * MB], PAPER_ACCEL)
    for i, w in enumerate((vgg16(), tiny_cnn())):
        env = FusionEnv(w, PAPER_ACCEL, batch=64, budget_bytes=20 * MB,
                        nmax=20)
        one = dnnfuser_infer_fused(params, cfg, env)
        assert (out["strategy"][i] == one.strategy).all(), i


# --- the engine -------------------------------------------------------------

CFG = DTConfig(max_steps=20)
PARAMS = dt_init(jax.random.PRNGKey(2), CFG)


def _mixed_requests(rng, n):
    nets = [vgg16(), resnet18(), tiny_cnn()]
    accs = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"], ACCEL_ZOO["laptop"]]
    return [MapRequest(nets[rng.integers(len(nets))],
                       int(rng.choice([16, 32, 64])),
                       float(rng.integers(6, 48)) * MB,
                       accs[rng.integers(len(accs))]) for _ in range(n)]


def test_engine_bucketed_results_bit_exact_with_unbucketed():
    """A 3-request group pads to a 4-lane bucket; every real row must equal
    its own unbucketed fused rollout (and the padded lanes must not leak
    into the responses)."""
    eng = MapperEngine(PARAMS, CFG)
    reqs = [MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"]),
            MapRequest(resnet18(), 32, 14 * MB, ACCEL_ZOO["mobile"]),
            MapRequest(vgg16(), 16, 9 * MB, ACCEL_ZOO["laptop"]),
            MapRequest(tiny_cnn(), 64, 3 * MB, ACCEL_ZOO["edge"])]
    out = eng.serve(reqs)                        # groups: nmax20 x3, nmax8 x1
    assert eng.rows_padded == 1                  # 3 -> pow2 bucket of 4
    for req, resp in zip(reqs, out):
        env = FusionEnv(req.workload, req.accel, batch=req.batch,
                        budget_bytes=req.budget_bytes,
                        nmax=nmax_bucket(req.workload.n + 1,
                                         eng.nmax_buckets))
        one = dnnfuser_infer_fused(PARAMS, CFG, env)
        assert resp.strategy.shape == (req.workload.n + 1,)
        assert (resp.strategy == one.strategy[: req.workload.n + 1]).all()
        np.testing.assert_allclose(resp.latency, one.latency, rtol=1e-5)
        assert resp.valid == one.valid


def test_engine_zero_recompiles_after_warmup():
    """The churn guard: warmup covers the (nmax x pow2-batch) bucket grid;
    serving mixed traffic across ALL those shapes afterwards must not
    materialize a single new program."""
    eng = MapperEngine(PARAMS, CFG)
    compiled = eng.warmup([vgg16(), resnet18(), tiny_cnn()],
                          ACCEL_ZOO["edge"], max_tick=8)
    assert compiled == eng.compile_count > 0
    jit_cache = getattr(infer_mod._fused_batch, "_cache_size", None)
    jit_before = jit_cache() if jit_cache else None
    before = eng.compile_count
    rng = np.random.default_rng(1)
    for tick in (1, 2, 3, 5, 7, 8):              # every bucket shape
        eng.serve(_mixed_requests(rng, tick))
    assert eng.compile_count == before, "recompile churn in steady state"
    if jit_cache is not None:                    # cross-check jax's cache
        assert jit_cache() == jit_before, \
            "engine counter says 0 but jax compiled new programs"
    assert eng.stats()["strategy_misses"] > 0    # it did real device work


def test_engine_exact_budget_identity_is_default():
    """DESIGN §14: by default the strategy identity is the EXACT condition
    — a nearby (same-quantum) budget is a different condition and must
    NOT reuse the cached strategy, which is what makes coalesced serving
    bit-identical to per-request serving regardless of arrival order."""
    eng = MapperEngine(PARAMS, CFG)
    req = MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"])
    r1 = eng.serve_one(req)
    assert not r1.cached
    r2 = eng.serve_one(req)                      # identical condition: hit
    assert r2.cached and (r2.strategy == r1.strategy).all()
    near = eng.serve_one(MapRequest(vgg16(), 64, 20 * MB + 1000,
                                    ACCEL_ZOO["edge"]))
    assert not near.cached                       # nearby budget: solved fresh
    # in-tick dedup follows the same identity: only EXACT duplicates share
    # a lane
    eng2 = MapperEngine(PARAMS, CFG)
    eng2.serve([req, MapRequest(vgg16(), 64, 20 * MB + 1000,
                                ACCEL_ZOO["edge"])])
    assert eng2.tick_dedup == 0
    eng2.serve([MapRequest(resnet18(), 32, 14 * MB, ACCEL_ZOO["mobile"]),
                MapRequest(resnet18(), 32, 14 * MB, ACCEL_ZOO["mobile"])])
    assert eng2.tick_dedup == 1


def test_engine_strategy_cache_hits_and_budget_quantization():
    """The opt-in ``approx_budget_sharing=True`` mode restores quantized
    budget identities (same-quantum conditions share one solved strategy)
    while validity stays per-request."""
    eng = MapperEngine(PARAMS, CFG, budget_quantum=MB,
                       approx_budget_sharing=True)
    req = MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"])
    r1 = eng.serve_one(req)
    assert not r1.cached
    # same condition -> hit; nearby budget in the same 1 MB quantum -> hit
    r2 = eng.serve_one(req)
    r3 = eng.serve_one(MapRequest(vgg16(), 64, 20 * MB + 1000,
                                  ACCEL_ZOO["edge"]))
    assert r2.cached and r3.cached
    assert (r2.strategy == r1.strategy).all()
    # validity is re-derived against the EXACT requested budget: a reused
    # strategy must never be called valid for a budget it overflows
    tight = eng.serve_one(MapRequest(vgg16(), 64,
                                     max(r1.peak_mem - 1.0, 1.0),
                                     ACCEL_ZOO["edge"]))
    if tight.cached:
        assert not tight.valid
    # in-tick duplicates share one device lane but keep PER-REQUEST
    # validity: a huge budget_quantum collapses a generous and an
    # impossible budget into one bucket — the impossible one must still
    # come back invalid
    wide = MapperEngine(PARAMS, CFG, budget_quantum=64 * MB,
                        approx_budget_sharing=True)
    roomy, tiny = wide.serve([
        MapRequest(vgg16(), 64, 40 * MB, ACCEL_ZOO["edge"]),
        MapRequest(vgg16(), 64, 1024.0, ACCEL_ZOO["edge"])])
    assert wide.tick_dedup == 1 and tiny.cached
    assert roomy.valid and not tiny.valid
    # different batch / budget bucket / accel are distinct conditions
    assert not eng.serve_one(MapRequest(vgg16(), 32, 20 * MB,
                                        ACCEL_ZOO["edge"])).cached
    assert not eng.serve_one(MapRequest(vgg16(), 64, 26 * MB,
                                        ACCEL_ZOO["edge"])).cached
    assert not eng.serve_one(MapRequest(vgg16(), 64, 20 * MB,
                                        ACCEL_ZOO["mobile"])).cached
    assert eng.stats()["strategy_hit_rate"] > 0


def test_engine_rejects_oversized_bucket_config():
    with pytest.raises(ValueError, match="max_steps"):
        MapperEngine(PARAMS, CFG, nmax_buckets=(8, 64))
    eng = MapperEngine(PARAMS, CFG)              # mobilenet (n=53) > 20
    with pytest.raises(ValueError, match="nmax bucket"):
        eng.serve_one(MapRequest(mobilenet_v2(), 64, 20 * MB, PAPER_ACCEL))


# --- persistent strategy cache (DESIGN §14) ---------------------------------

def test_strategy_cache_persists_across_engines(tmp_path):
    """Cross-process amortization: strategies solved by one engine, saved,
    then loaded read-through by a FRESH engine must serve as hits — no
    device calls, no compiles — and bit-identically."""
    path = tmp_path / "strategies.json"
    eng = MapperEngine(PARAMS, CFG)
    reqs = [MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"]),
            MapRequest(tiny_cnn(), 16, 3 * MB, ACCEL_ZOO["mobile"])]
    first = eng.serve(reqs)
    assert eng.save_cache(path) == len(reqs)
    fresh = MapperEngine(PARAMS, CFG, cache_path=path)
    again = fresh.serve(reqs)
    assert fresh.device_calls == 0 and fresh.compile_count == 0
    for a, b in zip(first, again):
        assert b.cached and (a.strategy == b.strategy).all()
        assert a.latency == b.latency and a.valid == b.valid
    assert fresh.strategies.shared_hits == len(reqs)
    # merge-write: a second engine's strategies union into the same file
    eng2 = MapperEngine(PARAMS, CFG)
    extra = MapRequest(resnet18(), 32, 14 * MB, ACCEL_ZOO["laptop"])
    eng2.serve_one(extra)
    assert eng2.save_cache(path) == 1 + len(reqs)
    both = MapperEngine(PARAMS, CFG, cache_path=path)
    assert both.serve_cached(extra) is not None
    assert both.serve_cached(reqs[0]) is not None


def test_strategy_cache_rejects_stale_checkpoint(tmp_path):
    """A persisted cache is keyed to its checkpoint fingerprint: a file
    written under different params must load ZERO entries (and raise
    under strict=True) — never serve another checkpoint's strategies."""
    path = tmp_path / "strategies.json"
    eng = MapperEngine(PARAMS, CFG)
    eng.serve_one(MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"]))
    eng.save_cache(path)
    other_params = dt_init(jax.random.PRNGKey(7), CFG)
    other = MapperEngine(other_params, CFG)
    assert other.load_cache(path) == 0
    assert other.strategies.stale_skipped == 1
    with pytest.raises(ValueError, match="incompatible"):
        other.load_cache(path, strict=True)
    # budget-identity modes don't share files either: exact keys must not
    # resolve against quantized ones
    approx = MapperEngine(PARAMS, CFG, approx_budget_sharing=True)
    assert approx.load_cache(path) == 0


def test_engine_stats_schema():
    """S2: one observability dict across every layer — queueing, admission,
    coalescing, per-replica and cache persistence counters all in one
    ``stats()`` call."""
    from repro.serving import AsyncMapperScheduler
    eng = MapperEngine(PARAMS, CFG)
    sched = AsyncMapperScheduler(eng, flush_ms=0.0, max_wave=4)
    sched.submit(MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"]), now=0.0)
    sched.drain(0.01)
    s = eng.stats()
    for key in ("requests_served", "device_calls", "compile_count",
                "compiled_shapes", "chunk_cap", "rows_padded", "tick_dedup",
                "coalesce_width_hist", "strategy_hit_rate", "strategy_cache",
                "replicas", "scheduler", "drift",
                "escalations", "polish_invocations", "polish_improved"):
        assert key in s, key
    # §17 refinement is off by default: counters exist but never move
    assert (s["escalations"], s["polish_invocations"],
            s["polish_improved"]) == (0, 0, 0)
    assert s["coalesce_width_hist"] == {1: 1}
    for key in ("entries", "capacity", "shared_hits", "loads", "saves",
                "stale_skipped"):
        assert key in s["strategy_cache"], key
    for key in ("queue_depth", "max_queue_depth", "submitted", "rejected",
                "resolved_at_submit", "flushes"):
        assert key in s["scheduler"], key
    assert s["scheduler"]["submitted"] == 1
    assert s["replicas"] is None                 # unreplicated engine
    # §15 closed-loop counters: replay/telemetry, drift windows, swaps
    for key in ("replay_depth", "replay_capacity", "replay_total",
                "windows_evaluated", "reports_fired", "pending_reports",
                "swaps_accepted", "swaps_rejected", "cache_invalidated",
                "last_report"):
        assert key in s["drift"], key
    assert s["drift"]["replay_depth"] == 1       # the one served request
    assert s["drift"]["swaps_accepted"] == 0


# --- backend protocol -------------------------------------------------------

def test_backend_registry_resolves_and_rejects():
    assert backend_for(DTConfig()) is DTBackend
    assert backend_for(S2SConfig()) is S2SBackend
    with pytest.raises(TypeError, match="no MapperBackend"):
        backend_for(object())


def test_s2s_rides_the_same_batched_serving_path():
    """The seq2seq baseline serves through the SAME fused/batched rollout
    (and the engine) via backend dispatch — no model-specific plumbing."""
    cfg = S2SConfig(max_steps=20)
    params = s2s_init(jax.random.PRNGKey(3), cfg)
    env = FusionEnv(vgg16(), PAPER_ACCEL, batch=64, budget_bytes=16 * MB,
                    nmax=20)
    one = dnnfuser_infer_fused(params, cfg, env)
    out = dnnfuser_infer_batch(params, cfg, env, [64.0], [16 * MB])
    assert (out["strategy"][0] == one.strategy).all()
    eng = MapperEngine(params, cfg)
    resp = eng.serve_one(MapRequest(vgg16(), 64, 16 * MB, PAPER_ACCEL))
    assert (resp.strategy == one.strategy[: vgg16().n + 1]).all()
