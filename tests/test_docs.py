"""Docs consistency (the §-numbering is load-bearing; DESIGN.md header).

Docstrings across ``src/``, ``benchmarks/`` and ``examples/`` cite DESIGN
sections as ``DESIGN §N`` / ``DESIGN.md §N``; DESIGN.md promises those
anchors are append-only.  README.md names benchmark scripts and committed
baselines.  This test makes both promises CI-enforced:

 - every cited §N resolves to a real ``## §N`` heading in DESIGN.md;
 - every ``benchmarks/*.py`` named in README.md exists (and so does every
   other local file README links to);
 - the tier-1 verify command and the benchmark driver are documented.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DESIGN = (ROOT / "DESIGN.md").read_text()
README_PATH = ROOT / "README.md"

SECTION_RE = re.compile(r"^##\s*§(\d+)\b", re.M)
CITE_RE = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")


def _sections() -> set[int]:
    return {int(m) for m in SECTION_RE.findall(DESIGN)}


def _py_files():
    for sub in ("src", "benchmarks", "examples"):
        yield from sorted((ROOT / sub).rglob("*.py"))


def test_design_sections_are_contiguous_from_1():
    secs = _sections()
    assert secs, "DESIGN.md has no '## §N' headings"
    assert secs == set(range(1, max(secs) + 1)), \
        f"§-numbering must be append-only/contiguous, got {sorted(secs)}"


@pytest.mark.parametrize("path", list(_py_files()),
                         ids=lambda p: str(p.relative_to(ROOT)))
def test_design_citations_resolve(path):
    secs = _sections()
    text = path.read_text()
    cited = {int(m) for m in CITE_RE.findall(text)}
    missing = cited - secs
    assert not missing, (
        f"{path.relative_to(ROOT)} cites DESIGN §{sorted(missing)} "
        f"but DESIGN.md only has §{sorted(secs)}")


def test_readme_exists_and_names_the_verify_command():
    assert README_PATH.exists(), "top-level README.md is required"
    text = README_PATH.read_text()
    assert "python -m pytest" in text, "README must give the tier-1 command"
    assert "benchmarks.run" in text, "README must name the benchmark driver"


def test_readme_benchmark_scripts_exist():
    text = README_PATH.read_text()
    scripts = set(re.findall(r"benchmarks/([\w.]+\.py)", text))
    assert scripts, "README must link the paper-claims benchmark scripts"
    for required in ("table1_methods.py", "table2_generalization.py",
                     "table3_transfer.py", "fig4_solutions.py",
                     "speed_oneshot.py", "table_hw_generalization.py"):
        assert required in scripts, f"README must reference {required}"
    for s in scripts:
        assert (ROOT / "benchmarks" / s).exists(), \
            f"README names benchmarks/{s} which does not exist"


def test_readme_local_links_resolve():
    text = README_PATH.read_text()
    for target in re.findall(r"\]\(([^)#\s]+)\)", text):
        if target.startswith(("http://", "https://")):
            continue
        assert (ROOT / target).exists(), f"README links missing {target}"


def test_readme_bench_baselines_exist():
    text = README_PATH.read_text()
    baselines = set(re.findall(r"\bBENCH_\w+\.json\b", text))
    assert baselines, "README must cite the committed BENCH_*.json numbers"
    for b in baselines:
        assert (ROOT / b).exists(), f"README cites {b} which is not committed"


def test_readme_public_symbols_import_from_repro():
    """S2 (DESIGN §15): the README's quickstarts are written against the
    supported ``repro`` public surface — every symbol a README code block
    imports from ``repro``/``repro.core``/``repro.serving`` must be in
    ``repro.__all__`` and actually resolve, and user-facing code blocks
    must not deep-import serving internals."""
    import repro
    text = README_PATH.read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert blocks, "README must keep runnable python quickstarts"
    code = "\n".join(blocks)
    assert "repro.serving.engine" not in code and \
        "repro.serving.scheduler" not in code, \
        "README quickstarts must not deep-import serving internals"
    imported = set()
    for m in re.finditer(
            r"^from\s+repro(?:\.\w+)?\s+import\s+(\([^)]*\)|[^\n]+)",
            code, re.M):
        names = m.group(1).strip("()").replace("\n", " ")
        imported.update(s.strip() for s in names.split(",") if s.strip())
    assert imported, "README quickstarts must import from repro"
    for name in sorted(imported):
        assert name in repro.__all__, \
            f"README imports {name} which is not in repro.__all__"
        assert getattr(repro, name) is not None    # lazy re-export resolves
    # the full advertised surface resolves, not just what README shows
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name


# Unconditional skip/xfail markers that are ALLOWED to exist, with their
# tracked reasons.  §16 removed the last two (the hypothesis-gated
# property tests now run a seeded fallback); anything new must be added
# here deliberately or the guard below fails.
TRACKED_SKIP_DEBT: dict[str, str] = {}

_SKIP_MARK_RE = re.compile(
    r"@pytest\.mark\.(?:skip|xfail)\(([^)]*)\)\s*\n\s*def\s+(\w+)")


def test_no_untracked_skip_debt():
    """Silent skip-debt cannot accumulate: every unconditional
    @pytest.mark.skip/xfail decorator in tests/ must carry a reason that
    is tracked in TRACKED_SKIP_DEBT (conditional runtime pytest.skip()
    calls — e.g. environment probes — are exempt by construction)."""
    found = {}
    for p in sorted((ROOT / "tests").glob("test_*.py")):
        for args, fn in _SKIP_MARK_RE.findall(p.read_text()):
            found[f"{p.name}::{fn}"] = args.strip()
    assert set(found) == set(TRACKED_SKIP_DEBT), (
        "skip/xfail markers drifted from TRACKED_SKIP_DEBT: "
        f"found={found!r} tracked={TRACKED_SKIP_DEBT!r}")
