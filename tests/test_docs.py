"""Docs consistency (the §-numbering is load-bearing; DESIGN.md header).

Since §18 the doc contracts — contiguous append-only ``## §N`` anchors,
``DESIGN §N`` citations resolving, README naming only committed scripts /
links / BENCH baselines, README completeness — are implemented ONCE as
the contract linter's DOC rule family (``repro.analysis.rules.docs``).
This module delegates: the analyzer runs over the repo exactly once
(cached) and each test asserts its slice of the DOC findings is empty,
keeping per-file failure locality without a second regex implementation.
The checks the analyzer cannot express statically (importing the public
surface, skip-debt tracking) stay here.
"""
import functools
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
README_PATH = ROOT / "README.md"


@functools.lru_cache(maxsize=1)
def _doc_findings():
    """One analyzer pass over the repo; DOC findings only."""
    from repro.analysis import run_analysis
    return tuple(f for f in run_analysis(ROOT).findings
                 if f.rule.startswith("DOC"))


def _fmt(findings):
    return "\n".join(f.format() for f in findings)


def _py_files():
    for sub in ("src", "benchmarks", "examples"):
        yield from sorted((ROOT / sub).rglob("*.py"))


def test_doc_rules_are_registered():
    """The delegation below is only meaningful while the DOC family
    exists; pin the rule ids so a registry regression fails loudly here
    rather than silently passing an empty check."""
    from repro.analysis import RULES
    assert {"DOC001", "DOC002", "DOC003", "DOC004"} <= set(RULES)


def test_design_sections_are_contiguous_from_1():
    bad = [f for f in _doc_findings() if f.rule == "DOC001"]
    assert not bad, _fmt(bad)


@pytest.mark.parametrize("path", list(_py_files()),
                         ids=lambda p: str(p.relative_to(ROOT)))
def test_design_citations_resolve(path):
    rel = path.relative_to(ROOT).as_posix()
    bad = [f for f in _doc_findings()
           if f.rule == "DOC002" and f.path == rel]
    assert not bad, _fmt(bad)


def test_readme_integrity():
    """Every local link, benchmarks/*.py script and BENCH_*.json baseline
    README.md names exists (DOC003)."""
    bad = [f for f in _doc_findings() if f.rule == "DOC003"]
    assert not bad, _fmt(bad)


def test_readme_completeness():
    """README keeps the paper-claims scripts, the tier-1 pytest command
    and the benchmarks.run driver visible (DOC004)."""
    bad = [f for f in _doc_findings() if f.rule == "DOC004"]
    assert not bad, _fmt(bad)


def test_readme_exists_and_names_the_verify_command():
    assert README_PATH.exists(), "top-level README.md is required"
    text = README_PATH.read_text()
    assert "python -m pytest" in text, "README must give the tier-1 command"
    assert "benchmarks.run" in text, "README must name the benchmark driver"


def test_readme_public_symbols_import_from_repro():
    """S2 (DESIGN §15): the README's quickstarts are written against the
    supported ``repro`` public surface — every symbol a README code block
    imports from ``repro``/``repro.core``/``repro.serving`` must be in
    ``repro.__all__`` and actually resolve, and user-facing code blocks
    must not deep-import serving internals."""
    import repro
    text = README_PATH.read_text()
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert blocks, "README must keep runnable python quickstarts"
    code = "\n".join(blocks)
    assert "repro.serving.engine" not in code and \
        "repro.serving.scheduler" not in code, \
        "README quickstarts must not deep-import serving internals"
    imported = set()
    for m in re.finditer(
            r"^from\s+repro(?:\.\w+)?\s+import\s+(\([^)]*\)|[^\n]+)",
            code, re.M):
        names = m.group(1).strip("()").replace("\n", " ")
        imported.update(s.strip() for s in names.split(",") if s.strip())
    assert imported, "README quickstarts must import from repro"
    for name in sorted(imported):
        assert name in repro.__all__, \
            f"README imports {name} which is not in repro.__all__"
        assert getattr(repro, name) is not None    # lazy re-export resolves
    # the full advertised surface resolves, not just what README shows
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name


# Unconditional skip/xfail markers that are ALLOWED to exist, with their
# tracked reasons.  §16 removed the last two (the hypothesis-gated
# property tests now run a seeded fallback); anything new must be added
# here deliberately or the guard below fails.
TRACKED_SKIP_DEBT: dict[str, str] = {}

_SKIP_MARK_RE = re.compile(
    r"@pytest\.mark\.(?:skip|xfail)\(([^)]*)\)\s*\n\s*def\s+(\w+)")


def test_no_untracked_skip_debt():
    """Silent skip-debt cannot accumulate: every unconditional
    @pytest.mark.skip/xfail decorator in tests/ must carry a reason that
    is tracked in TRACKED_SKIP_DEBT (conditional runtime pytest.skip()
    calls — e.g. environment probes — are exempt by construction)."""
    found = {}
    for p in sorted((ROOT / "tests").glob("test_*.py")):
        for args, fn in _SKIP_MARK_RE.findall(p.read_text()):
            found[f"{p.name}::{fn}"] = args.strip()
    assert set(found) == set(TRACKED_SKIP_DEBT), (
        "skip/xfail markers drifted from TRACKED_SKIP_DEBT: "
        f"found={found!r} tracked={TRACKED_SKIP_DEBT!r}")
