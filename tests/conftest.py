import os
import sys
import pathlib

# src layout without install
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))
