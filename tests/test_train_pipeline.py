"""End-to-end training subsystem smoke (DESIGN §10, CI training-smoke job):
generated teacher corpus -> >=300-step sharded imitation train with
microbatch accumulation -> monotonically improving smoothed loss ->
bit-exact checkpoint resume -> transfer fine-tuning warm start."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (DTConfig, FusionEnv, GSamplerConfig, PAPER_ACCEL,
                        TrainConfig, dnnfuser_infer_fused, dt_init, dt_loss,
                        fine_tune, generate_teacher_corpus, restore_params,
                        train_model)
from repro.distributed.sharding import data_parallel_mesh
from repro.workloads import tiny_cnn

MB = 2 ** 20
T = 12
CFG = DTConfig(n_blocks=1, n_heads=1, d_model=32, d_ff=64, max_steps=T)
TC = TrainConfig(steps=320, batch_size=16, lr=1e-3, warmup=20,
                 log_every=5, grad_accum=2, ckpt_every=80, seed=0)


@pytest.fixture(scope="module")
def corpus():
    return generate_teacher_corpus(
        [tiny_cnn()], PAPER_ACCEL, batch=64, budgets_mb=[2.0, 6.0],
        max_steps=T, top_k=4,
        ga_cfg=GSamplerConfig(generations=8, population=16, seed=0),
        seed=0, augment_jitter=1)


def _loss_fn(p, b):
    return dt_loss(p, CFG, b)


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture(scope="module")
def trained(corpus, tmp_path_factory):
    ckpt = tmp_path_factory.mktemp("ckpt_full")
    params, log = train_model(_loss_fn, dt_init(jax.random.PRNGKey(0), CFG),
                              corpus, TC, mesh=data_parallel_mesh(),
                              ckpt_dir=str(ckpt))
    return params, log, str(ckpt)


def test_smoothed_loss_improves_monotonically(trained):
    _, log, _ = trained
    losses = np.asarray([l for _, l in log["losses"]])
    assert len(losses) >= 32
    # smooth over quarters of the (regularly sampled) loss curve; the
    # smoothed curve must be monotonically non-increasing (5% jitter slack)
    # and show a real overall improvement.
    q = np.array_split(losses, 4)
    means = np.asarray([c.mean() for c in q])
    assert (means[1:] <= means[:-1] * 1.05 + 1e-4).all(), means
    assert means[-1] < 0.3 * means[0], means
    assert np.isfinite(losses).all()


def test_checkpoint_resume_is_bit_exact(corpus, trained, tmp_path):
    params_full, _, _ = trained
    # crash after step 160 (a ckpt_every multiple), then resume to the end
    p1, log1 = train_model(_loss_fn, dt_init(jax.random.PRNGKey(0), CFG),
                           corpus, TC, mesh=data_parallel_mesh(),
                           ckpt_dir=str(tmp_path), crash_at=160)
    p2, log2 = train_model(_loss_fn, dt_init(jax.random.PRNGKey(0), CFG),
                           corpus, TC, mesh=data_parallel_mesh(),
                           ckpt_dir=str(tmp_path))
    assert log2["start_step"] == 160, "resume must pick up the checkpoint"
    assert _params_equal(params_full, p2), \
        "resumed params must be bit-identical to the uninterrupted run"


def test_restore_params_roundtrip(trained):
    params, _, ckpt_dir = trained
    restored = restore_params(ckpt_dir, dt_init(jax.random.PRNGKey(1), CFG))
    assert _params_equal(params, restored)


def test_fine_tune_warm_starts_from_checkpoint(corpus, trained):
    _, log_pre, ckpt_dir = trained
    ft_cfg = TrainConfig(steps=32, batch_size=16, lr=1e-4, warmup=4,
                         log_every=4, seed=1)
    params, log = fine_tune(_loss_fn, ckpt_dir, corpus, ft_cfg,
                            template=dt_init(jax.random.PRNGKey(1), CFG),
                            mesh=data_parallel_mesh())
    # warm start: the very first fine-tune loss is already near the
    # pre-trained floor, far below a cold start's first loss
    first_ft = log["losses"][0][1]
    first_cold = log_pre["losses"][0][1]
    assert first_ft < 0.25 * first_cold, (first_ft, first_cold)
    assert np.isfinite(log["final_loss"])


def test_trained_mapper_infers_valid_strategy(corpus, trained):
    params, _, _ = trained
    env = FusionEnv(tiny_cnn(), PAPER_ACCEL, batch=64,
                    budget_bytes=4.0 * MB, nmax=T)
    res = dnnfuser_infer_fused(params, CFG, env)
    assert res.valid
    assert res.speedup > 0.5
