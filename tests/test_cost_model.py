"""Cost-model unit + property tests: the vectorized jnp model must agree
with the independent loop-based reference, and satisfy the fusion-physics
invariants the paper's results rest on."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade gracefully without the 'test' extra
    HAVE_HYPOTHESIS = False

from repro.workloads import vgg16, resnet18, mobilenet_v2, get_workload
from repro.core import cost_model as cm
from repro.core import ref_model
from repro.core.accel import PAPER_ACCEL, AccelConfig

HW = PAPER_ACCEL
MB = 2 ** 20
WL = {w.name: w for w in (vgg16(), resnet18(), mobilenet_v2())}
PACKED = {n: cm.pack_workload(w, HW, 64) for n, w in WL.items()}
PACKED_NP = {n: {k: np.asarray(v) for k, v in p.items()}
             for n, p in PACKED.items()}


# The property checks run under hypothesis when the 'test' extra is
# installed (CI), and against a fixed seeded-numpy sweep otherwise — the
# bare install no longer silently skips them (the pre-§16 skip-debt).


def _check_matches_reference(s, wname):
    out = cm.evaluate(PACKED[wname], jnp.asarray(s), 64.0, 20 * MB, HW)
    ref = ref_model.evaluate_ref(PACKED_NP[wname], s, 64, 20 * MB, HW)
    for k in ("latency", "peak_mem", "traffic"):
        a, b = float(getattr(out, k)), ref[k]
        assert abs(a - b) <= 1e-5 * max(abs(b), 1.0), (k, a, b)
    assert bool(out.valid) == ref["valid"]
    assert int(out.n_groups) == ref["n_groups"]


def _check_invariants(s, wname):
    """Physics: latency/peak positive; fusing never increases off-chip
    traffic at fixed micro-batches vs all-sync; peak >= the largest
    staged activation term."""
    w = WL[wname]
    out = cm.evaluate(PACKED[wname], jnp.asarray(s), 64.0, 20 * MB, HW)
    assert float(out.latency) > 0 and float(out.peak_mem) >= 0
    # full fusion at full-batch micro-batches (weights fetched once, all
    # intermediates staged) is the traffic lower bound vs all-sync
    s_fused = np.full(64, cm.SYNC, np.int32)
    s_fused[: w.n + 1] = 64
    out_f = cm.evaluate(PACKED[wname], jnp.asarray(s_fused), 64.0,
                        20 * MB, HW)
    s_allsync = np.full(64, cm.SYNC, np.int32); s_allsync[0] = 1
    out_s = cm.evaluate(PACKED[wname], jnp.asarray(s_allsync), 64.0,
                        20 * MB, HW)
    assert float(out_f.traffic) <= float(out_s.traffic) * (1 + 1e-6)


def _seeded_strategy(rng, n, batch=64):
    vals = np.where(rng.random(n + 1) < 0.4, cm.SYNC,
                    rng.integers(1, batch + 1, size=n + 1))
    s = np.full(64, cm.SYNC, np.int32)
    s[: n + 1] = vals
    if s[0] < 1:
        s[0] = 1
    return s


if HAVE_HYPOTHESIS:
    def _rand_strategy(data, n, batch=64):
        vals = data.draw(st.lists(
            st.one_of(st.just(-1), st.integers(1, batch)),
            min_size=n + 1, max_size=n + 1))
        s = np.full(64, cm.SYNC, np.int32)
        s[: n + 1] = vals
        if s[0] < 1:
            s[0] = 1
        return s

    @settings(max_examples=60, deadline=None)
    @given(data=st.data(), wname=st.sampled_from(sorted(WL)))
    def test_jnp_matches_reference(data, wname):
        _check_matches_reference(_rand_strategy(data, WL[wname].n), wname)

    @settings(max_examples=40, deadline=None)
    @given(data=st.data(), wname=st.sampled_from(sorted(WL)))
    def test_invariants(data, wname):
        _check_invariants(_rand_strategy(data, WL[wname].n), wname)
else:
    @pytest.mark.parametrize("wname", sorted(WL))
    def test_jnp_matches_reference(wname):
        rng = np.random.default_rng(7)
        for _ in range(20):
            _check_matches_reference(_seeded_strategy(rng, WL[wname].n),
                                     wname)

    @pytest.mark.parametrize("wname", sorted(WL))
    def test_invariants(wname):
        rng = np.random.default_rng(11)
        for _ in range(13):
            _check_invariants(_seeded_strategy(rng, WL[wname].n), wname)


def test_baseline_matches_ref():
    for n, w in WL.items():
        b = cm.baseline_no_fusion(PACKED[n], 64.0, HW)
        rb = ref_model.baseline_ref(PACKED_NP[n], 64, HW)
        assert abs(float(b.latency) - rb) < 1e-6 * rb


def test_prefix_trace_full_equals_evaluate():
    w = WL["resnet18"]
    rng = np.random.default_rng(0)
    s = cm.random_strategy(rng, w.n, 64, 64)
    tr = cm.prefix_trace(PACKED["resnet18"], jnp.asarray(s), 64.0,
                         20 * MB, HW)
    full = cm.evaluate(PACKED["resnet18"], jnp.asarray(s), 64.0, 20 * MB, HW)
    # entry n+1 applies positions < n+1 == the whole strategy
    assert np.isclose(float(tr.latency[w.n + 1]), float(full.latency),
                      rtol=1e-6)


def test_memory_monotone_in_microbatch():
    """Raising one staged micro-batch can only raise group peak memory."""
    w = WL["vgg16"]
    s = np.full(64, cm.SYNC, np.int32)
    s[: w.n + 1] = 4
    lo = cm.evaluate(PACKED["vgg16"], jnp.asarray(s), 64.0, 64 * MB, HW)
    s2 = s.copy(); s2[3] = 32
    hi = cm.evaluate(PACKED["vgg16"], jnp.asarray(s2), 64.0, 64 * MB, HW)
    assert float(hi.peak_mem) >= float(lo.peak_mem)


def test_speedup_band_matches_paper_case1():
    """Faithfulness anchor: G-Sampler-quality strategies on VGG16 case-1
    land near the paper's 1.19x (band check, not exact-match)."""
    from repro.core import FusionEnv, gsampler_search, GSamplerConfig
    env = FusionEnv(WL["vgg16"], HW, batch=64, budget_bytes=20 * MB)
    res = gsampler_search(env, GSamplerConfig(generations=25, seed=0))
    assert res.valid
    assert 1.05 <= res.speedup <= 1.6, res.speedup
