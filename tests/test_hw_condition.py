"""Hardware-conditioned mapper (DESIGN.md §11): model, serving, upgrade.

 - the hw embedding conditions the DT (different accel vectors -> different
   logits) and the KV-cached decode matches the full forward with hw;
 - fused rollouts stay bit-identical to the host reference on every zoo
   accelerator, and ``dnnfuser_infer_batch`` with HETEROGENEOUS per-row hw
   vectors matches per-condition runs in one device call;
 - the teacher corpus labels trajectories with their accelerator and the
   loss consumes them;
 - a pre-§11 checkpoint upgrades into the hw-conditioned architecture
   function-preserved (zero-filled ``emb_h``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACCEL_ZOO, DTConfig, FusionEnv, HW_FEATURE_DIM,
                        PAPER_ACCEL, S2SConfig, accel_features,
                        dnnfuser_infer, dnnfuser_infer_batch,
                        dnnfuser_infer_fused, dt_apply, dt_cache_init,
                        dt_decode_step, dt_init, dt_loss, dt_prefill,
                        s2s_apply, s2s_init, s2s_loss)
from repro.checkpoint import save_pytree, upgrade_pytree
from repro.workloads import tiny_cnn, vgg16

MB = 2 ** 20
CFG = DTConfig(max_steps=20, hw_dim=HW_FEATURE_DIM)


def _feat(name):
    return jnp.asarray(np.asarray(accel_features(ACCEL_ZOO[name]),
                                  np.float32))


# --- model-level conditioning ----------------------------------------------

def test_hw_embedding_conditions_the_model():
    params = dt_init(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    T = CFG.max_steps
    rtg = jnp.asarray(rng.random((1, T)), jnp.float32)
    st = jnp.asarray(rng.random((1, T, 8)), jnp.float32)
    ac = jnp.asarray(rng.random((1, T)), jnp.float32)
    a = dt_apply(params, CFG, rtg, st, ac, hw=_feat("edge")[None])
    b = dt_apply(params, CFG, rtg, st, ac, hw=_feat("datacenter")[None])
    assert not np.allclose(np.asarray(a), np.asarray(b)), \
        "hw condition must reach the logits"
    # None == zeros (the 'unspecified hardware' condition)
    z = dt_apply(params, CFG, rtg, st, ac,
                 hw=jnp.zeros((1, HW_FEATURE_DIM)))
    n = dt_apply(params, CFG, rtg, st, ac)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(n))


def test_dt_decode_step_matches_dt_apply_with_hw():
    params = dt_init(jax.random.PRNGKey(1), CFG)
    rng = np.random.default_rng(1)
    T = CFG.max_steps
    rtg = jnp.asarray(rng.random((1, T)), jnp.float32)
    states = jnp.asarray(rng.random((1, T, 8)), jnp.float32)
    actions = jnp.asarray(rng.random((1, T)), jnp.float32)
    hw = _feat("mobile")[None]
    full = np.asarray(dt_apply(params, CFG, rtg, states, actions,
                               hw=hw))[0]
    cache = dt_cache_init(CFG)
    pred, cache = dt_prefill(params, CFG, cache, rtg[:, 0], states[:, 0], hw)
    preds = [float(pred[0])]
    for t in range(1, T):
        pred, cache = dt_decode_step(params, CFG, cache, rtg[:, t],
                                     states[:, t], actions[:, t - 1], hw)
        preds.append(float(pred[0]))
    np.testing.assert_allclose(np.array(preds), full, atol=1e-5)


def test_s2s_hw_conditioning_and_loss():
    cfg = S2SConfig(max_steps=12, hw_dim=HW_FEATURE_DIM)
    params = s2s_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    rtg = jnp.asarray(rng.random((2, 12)), jnp.float32)
    st = jnp.asarray(rng.random((2, 12, 8)), jnp.float32)
    ac = jnp.asarray(rng.random((2, 12)), jnp.float32)
    a = s2s_apply(params, cfg, rtg, st, ac, hw=jnp.stack([_feat("edge")] * 2))
    b = s2s_apply(params, cfg, rtg, st, ac,
                  hw=jnp.stack([_feat("datacenter")] * 2))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    batch = dict(rtg=rtg, states=st, actions=ac,
                 mask=jnp.ones((2, 12), jnp.float32),
                 hw=jnp.stack([_feat("nano")] * 2))
    assert np.isfinite(float(s2s_loss(params, cfg, batch)))


def test_hw_batch_key_flows_through_dt_loss():
    params = dt_init(jax.random.PRNGKey(3), CFG)
    rng = np.random.default_rng(3)
    T = CFG.max_steps
    batch = dict(rtg=jnp.asarray(rng.random((2, T)), jnp.float32),
                 states=jnp.asarray(rng.random((2, T, 8)), jnp.float32),
                 actions=jnp.asarray(rng.random((2, T)), jnp.float32),
                 mask=jnp.ones((2, T), jnp.float32),
                 hw=jnp.stack([_feat("edge"), _feat("laptop")]))
    l_hw = float(dt_loss(params, CFG, batch))
    l_no = float(dt_loss(params, CFG, {k: v for k, v in batch.items()
                                       if k != "hw"}))
    assert np.isfinite(l_hw) and l_hw != l_no


# --- serving: fused == host, heterogeneous batch == singles ----------------

@pytest.mark.parametrize("accel", ["nano", "mobile", "datacenter"])
def test_fused_rollout_matches_host_on_zoo_accels(accel):
    params = dt_init(jax.random.PRNGKey(0), CFG)
    env = FusionEnv(vgg16(), ACCEL_ZOO[accel], batch=64,
                    budget_bytes=20 * MB, nmax=CFG.max_steps)
    h = dnnfuser_infer(params, CFG, env)
    f = dnnfuser_infer_fused(params, CFG, env)
    assert (h.strategy == f.strategy).all()
    np.testing.assert_allclose(f.latency, h.latency, rtol=1e-5)


def test_infer_batch_heterogeneous_hw_matches_singles():
    """The §11 acceptance shape: per-row hw vectors (4 different zoo
    accelerators, incl. one with a different datatype) serve in ONE device
    call, each row bit-identical to its per-condition fused AND host run."""
    params = dt_init(jax.random.PRNGKey(4), CFG)
    wl = vgg16()
    rows = [ACCEL_ZOO[n] for n in ("edge", "nano", "laptop", "datacenter")]
    batches = np.array([64.0, 32.0, 64.0, 16.0], np.float32)
    budgets = np.array([20.0, 12.0, 32.0, 24.0], np.float32) * MB
    env0 = FusionEnv(wl, PAPER_ACCEL, batch=64, budget_bytes=32 * MB,
                     nmax=CFG.max_steps)
    out = dnnfuser_infer_batch(params, CFG, env0, batches, budgets, rows)
    assert out["strategy"].shape == (4, CFG.max_steps)
    for i, acc in enumerate(rows):
        env = FusionEnv(wl, acc, batch=int(batches[i]),
                        budget_bytes=float(budgets[i]), nmax=CFG.max_steps)
        one = dnnfuser_infer_fused(params, CFG, env)
        host = dnnfuser_infer(params, CFG, env)
        assert (out["strategy"][i] == one.strategy).all(), acc.name
        assert (out["strategy"][i] == host.strategy).all(), acc.name
        np.testing.assert_allclose(out["latency"][i], one.latency,
                                   rtol=1e-5)


# --- checkpoint upgrade path -----------------------------------------------

def test_pre_s11_checkpoint_upgrades_function_preserved(tmp_path):
    cfg0 = DTConfig(max_steps=16)
    p0 = dt_init(jax.random.PRNGKey(5), cfg0)
    save_pytree(p0, tmp_path / "ck")
    cfg1 = DTConfig(max_steps=16, hw_dim=HW_FEATURE_DIM)
    p1, missing = upgrade_pytree(tmp_path / "ck",
                                 dt_init(jax.random.PRNGKey(5), cfg1))
    assert sorted(missing) == ["emb_h/b", "emb_h/w"]
    rng = np.random.default_rng(5)
    rtg = jnp.asarray(rng.random((2, 16)), jnp.float32)
    st = jnp.asarray(rng.random((2, 16, 8)), jnp.float32)
    ac = jnp.asarray(rng.random((2, 16)), jnp.float32)
    old = dt_apply(p0, cfg0, rtg, st, ac)
    for hw in (None, jnp.stack([_feat("edge"), _feat("datacenter")])):
        new = dt_apply(p1, cfg1, rtg, st, ac, hw=hw)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    # ... and the upgraded tree trains: the condition reaches the loss
    batch = dict(rtg=rtg, states=st, actions=ac,
                 mask=jnp.ones((2, 16), jnp.float32),
                 hw=jnp.stack([_feat("edge"), _feat("nano")]))
    assert np.isfinite(float(dt_loss(p1, cfg1, batch)))


def test_upgrade_pytree_with_params_prefix(tmp_path):
    cfg0 = DTConfig(max_steps=12)
    p0 = dt_init(jax.random.PRNGKey(6), cfg0)
    save_pytree({"params": p0, "opt_state": {"count": np.zeros(())}},
                tmp_path / "ck")
    cfg1 = DTConfig(max_steps=12, hw_dim=HW_FEATURE_DIM)
    p1, missing = upgrade_pytree(tmp_path / "ck",
                                 dt_init(jax.random.PRNGKey(6), cfg1),
                                 prefix="params")
    assert sorted(missing) == ["emb_h/b", "emb_h/w"]
    assert float(np.abs(np.asarray(p1["emb_h"]["w"])).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(p1["head"]["w"]),
                                  np.asarray(p0["head"]["w"]))
