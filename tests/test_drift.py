"""The §15 closed loop: ServingConfig, drift detection, hot swap, refresh.

Pins the four §15 contracts:

 - **one construction surface**: ``ServingConfig`` construction is
   bit-identical to the pre-§15 scattered kwargs, which keep working
   through a once-per-process ``DeprecationWarning`` shim;
 - **drift detection**: the bounded replay buffer and the window monitor
   — fires on unseen accels/networks, hit-rate decay and budget
   violations; stays quiet on stable traffic; self-calibrates when no
   training mix was declared;
 - **hot swap**: ``swap_params`` is zero-recompile (engine counter AND
   the jax jit cache), bit-exact for non-drifted keys (their cached
   strategies survive the scoped invalidation), and atomic between ticks
   under the async scheduler — resolved futures keep old-params answers,
   queued requests solve on the new params;
 - **the refresh pipeline**: drift report -> G-Sampled corpus ->
   fine-tune -> ``upgrade_pytree`` restore -> quality gate -> swap; the
   gate REJECTS a candidate that probes worse than the live params.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, upgrade_pytree
from repro.core import (ACCEL_ZOO, DTConfig, GSamplerConfig, TrainConfig,
                        dnnfuser_infer_fused, dt_init, dt_loss,
                        generate_teacher_corpus, train_model, FusionEnv)
from repro.core import infer as infer_mod
from repro.serving import (AsyncMapperScheduler, DriftConfig, DriftMonitor,
                           DriftReport, MapperEngine, MapRequest,
                           RefreshWorker, ReplayBuffer, ReplayRecord,
                           ServingConfig, StrategyCache,
                           region_key_predicate)
from repro.serving.config import _reset_deprecation_warnings
from repro.serving.engine import _accel_key
from repro.serving.refresh import probe_score
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = 2 ** 20
CFG = DTConfig(max_steps=20)
PARAMS = dt_init(jax.random.PRNGKey(2), CFG)
PARAMS2 = dt_init(jax.random.PRNGKey(9), CFG)
EDGE, MOBILE, DC = (ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"],
                    ACCEL_ZOO["datacenter"])


def _rec(wl, accel, *, budget_mb=8.0, valid=True, cached=False,
         speedup=1.5, batch=32):
    return ReplayRecord(wl, batch, budget_mb * MB, accel, valid, cached,
                        speedup)


# --- ServingConfig + deprecation shims (S1) ---------------------------------

def test_deprecated_kwargs_warn_once_and_match_config():
    """Old-kwarg construction == ServingConfig construction, field for
    field and response for response; the warning fires once per kwarg per
    process."""
    _reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="max_coalesce"):
        legacy = MapperEngine(PARAMS, CFG, max_coalesce=8,
                              approx_budget_sharing=True)
    via_cfg = MapperEngine.from_config(
        PARAMS, CFG, ServingConfig(max_coalesce=8,
                                   approx_budget_sharing=True))
    assert legacy.serving_config == via_cfg.serving_config
    # the shim built the exact same frozen record -> identical behavior
    req = MapRequest(vgg16(), 64, 20 * MB, EDGE)
    a, b = legacy.serve_one(req), via_cfg.serve_one(req)
    assert np.array_equal(a.strategy, b.strategy)
    assert (a.latency, a.valid, a.cached) == (b.latency, b.valid, b.cached)
    # once per process: the same kwarg again is silent
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        MapperEngine(PARAMS, CFG, max_coalesce=8)
    assert not [r for r in rec if issubclass(r.category, DeprecationWarning)]


def test_config_construction_rejects_bad_mixes():
    with pytest.raises(TypeError, match="bogus"):
        MapperEngine(PARAMS, CFG, bogus=1)
    with pytest.raises(TypeError, match="flush_ms"):
        MapperEngine(PARAMS, CFG, flush_ms=2.0)   # a scheduler-only field
    with pytest.raises(TypeError, match="not both"):
        MapperEngine(PARAMS, CFG, config=ServingConfig(), max_coalesce=8)
    eng = MapperEngine.from_config(PARAMS, CFG)
    with pytest.raises(TypeError, match="not both"):
        AsyncMapperScheduler(eng, config=ServingConfig(), flush_ms=2.0)


def test_scheduler_reads_config_and_inherits_engines():
    """The scheduler consumes the SAME deployment record: explicitly, via
    its own deprecated kwargs, or inherited from the engine."""
    _reset_deprecation_warnings()
    eng = MapperEngine.from_config(PARAMS, CFG, ServingConfig(flush_ms=3.0,
                                                              max_queue=7))
    inherited = AsyncMapperScheduler(eng)
    assert inherited.flush_s == 0.003 and inherited.max_queue == 7
    with pytest.warns(DeprecationWarning, match="flush_ms"):
        legacy = AsyncMapperScheduler(eng, flush_ms=5.0)
    explicit = AsyncMapperScheduler(eng, config=ServingConfig(flush_ms=5.0))
    assert legacy.flush_s == explicit.flush_s == 0.005


def test_repro_serve_factory():
    """repro.serve: the one-call front door builds the warmed engine +
    scheduler from one config."""
    import repro
    sched = repro.serve(PARAMS, CFG,
                        ServingConfig(max_coalesce=4, flush_ms=0.0),
                        warm=[tiny_cnn()], accel=EDGE)
    assert isinstance(sched, AsyncMapperScheduler)
    eng = sched.engine
    assert eng.compile_count > 0                  # warmed
    assert "tiny_cnn" in eng.monitor.known_workloads
    before = eng.compile_count
    fut = sched.submit(MapRequest(tiny_cnn(), 32, 5 * MB, EDGE), now=0.0)
    sched.drain(0.0)
    assert fut.result().workload == "tiny_cnn"
    assert eng.compile_count == before            # steady state


# --- replay + drift monitor --------------------------------------------------

def test_replay_buffer_bounded():
    buf = ReplayBuffer(capacity=4)
    for i in range(6):
        buf.append(_rec(tiny_cnn(), EDGE, budget_mb=float(i)))
    assert len(buf) == 4 and buf.total == 6
    kept = [r.budget_bytes / MB for r in buf]
    assert kept == [2.0, 3.0, 4.0, 5.0]           # oldest dropped first
    assert [r.budget_bytes / MB for r in buf.recent(2)] == [4.0, 5.0]


def test_monitor_quiet_on_stable_traffic_and_fires_on_unseen():
    mon = DriftMonitor(DriftConfig(window=4), known_accels=("edge",),
                       known_workloads=("tiny_cnn",))
    for _ in range(8):                            # two clean windows
        assert mon.observe(_rec(tiny_cnn(), EDGE, cached=True)) is None
    assert mon.windows_evaluated == 2 and mon.reports_fired == 0
    # a window dominated by an unseen accel fires, with the region named
    for _ in range(3):
        assert mon.observe(_rec(tiny_cnn(), DC, cached=False)) is None
    rep = mon.observe(_rec(vgg16(), DC, cached=False, budget_mb=40.0))
    assert isinstance(rep, DriftReport) and rep.drifted
    assert "unseen_accel" in rep.triggers and "unseen_workload" in rep.triggers
    assert [a.name for a in rep.accels] == ["datacenter"]
    assert {w.name for w in rep.workloads} == {"tiny_cnn", "vgg16"}
    assert 40.0 in rep.budgets_mb
    assert mon.pending and mon.pop_reports() == [rep] and not mon.pending


def test_monitor_hit_rate_decay_and_violations():
    mon = DriftMonitor(DriftConfig(window=4, hit_rate_drop=0.3,
                                   violation_rate=0.5),
                       known_accels=("edge",), known_workloads=("tiny_cnn",))
    for _ in range(4):                            # baseline: all hits
        mon.observe(_rec(tiny_cnn(), EDGE, cached=True))
    assert mon.baseline_hit_rate == 1.0
    for _ in range(3):
        mon.observe(_rec(tiny_cnn(), EDGE, cached=False))
    rep = mon.observe(_rec(tiny_cnn(), EDGE, cached=False))
    assert rep is not None and rep.triggers == ("hit_rate_decay",)
    for _ in range(3):
        mon.observe(_rec(tiny_cnn(), EDGE, cached=True, valid=False))
    rep = mon.observe(_rec(tiny_cnn(), EDGE, cached=True, valid=False))
    assert rep is not None and "budget_violations" in rep.triggers


def test_monitor_self_calibrates_without_declared_mix():
    mon = DriftMonitor(DriftConfig(window=4))     # no known sets
    for _ in range(4):
        assert mon.observe(_rec(vgg16(), MOBILE)) is None
    assert mon.known_accels == {"mobile"}         # adopted, didn't fire
    assert mon.known_workloads == {"vgg16"}
    for _ in range(4):
        rep = mon.observe(_rec(vgg16(), DC))
    assert rep is not None and "unseen_accel" in rep.triggers


def test_engine_feeds_monitor_and_warmup_bypasses():
    eng = MapperEngine.from_config(
        PARAMS, CFG, ServingConfig(drift=DriftConfig(window=4,
                                                     replay_capacity=8)))
    eng.warmup([tiny_cnn()], EDGE, max_tick=2)
    assert len(eng.monitor.replay) == 0           # warmup is not demand
    assert eng.monitor.known_accels == {"edge"}
    eng.serve([MapRequest(tiny_cnn(), 32, 5 * MB, EDGE)])
    eng.serve_one(MapRequest(tiny_cnn(), 32, 5 * MB, EDGE))
    assert len(eng.monitor.replay) == 2
    assert [r.cached for r in eng.monitor.replay] == [False, True]


# --- scoped cache invalidation ----------------------------------------------

def test_cache_invalidate_and_region_predicate():
    c = StrategyCache(capacity=8)
    k_edge = ("vgg16", 64, 1.0, _accel_key(EDGE))
    k_dc = ("vgg16", 64, 1.0, _accel_key(DC))
    k_net = ("resnet18", 32, 2.0, _accel_key(EDGE))
    for k in (k_edge, k_dc, k_net):
        c.put(k, "v")
    pred = region_key_predicate([resnet18()], [DC], _accel_key)
    assert pred(k_dc) and pred(k_net) and not pred(k_edge)
    assert c.invalidate(pred) == 2
    assert k_edge in c and k_dc not in c and k_net not in c
    # shared-layer entries are invalidated too
    c._shared[k_dc] = "stale"
    assert c.invalidate(pred) == 1 and k_dc not in c


# --- hot swap (the tentpole contract) ---------------------------------------

def test_hot_swap_zero_recompile_and_bit_exact_non_drifted():
    """Across a swap: zero new programs (engine counter AND the jax-level
    jit cache), non-drifted keys keep answering bit-identically from
    cache, invalidated keys re-solve on the NEW params."""
    eng = MapperEngine.from_config(PARAMS, CFG, ServingConfig(max_coalesce=4))
    eng.warmup([vgg16(), tiny_cnn()], EDGE, max_tick=2)
    keep = MapRequest(vgg16(), 64, 20 * MB, EDGE)
    drop = MapRequest(tiny_cnn(), 32, 5 * MB, EDGE)
    before_keep, before_drop = eng.serve([keep])[0], eng.serve([drop])[0]
    compiles = eng.compile_count
    jit_cache = getattr(infer_mod._fused_batch, "_cache_size", None)
    jit_before = jit_cache() if jit_cache else None
    old_id = eng.checkpoint_id

    # warmup's synthetic tiny_cnn probes are in the cache too: all of the
    # region's keys go, the vgg16 ones all stay
    pred = region_key_predicate([tiny_cnn()], [], _accel_key)
    invalidated = eng.swap_params(PARAMS2, invalidate=pred)
    assert invalidated >= 1
    assert eng.swaps_accepted == 1 and eng.cache_invalidated == invalidated
    assert all(k[0] != "tiny_cnn" for k in eng.strategies.snapshot())
    assert eng.checkpoint_id != old_id
    assert eng.strategies.context["checkpoint"] == eng.checkpoint_id

    after_keep = eng.serve([keep])[0]
    after_drop = eng.serve([drop])[0]
    assert eng.compile_count == compiles, "swap must not recompile"
    if jit_cache is not None:
        assert jit_cache() == jit_before, \
            "engine counter says 0 but jax compiled new programs"
    # non-drifted key: cached, bit-exact with the pre-swap answer
    assert after_keep.cached
    assert np.array_equal(after_keep.strategy, before_keep.strategy)
    assert after_keep.latency == before_keep.latency
    # drifted key: re-solved fresh, identical to the new params' rollout
    assert not after_drop.cached
    env = FusionEnv(tiny_cnn(), EDGE, batch=32, budget_bytes=5 * MB, nmax=8)
    fresh = dnnfuser_infer_fused(PARAMS2, CFG, env)
    assert np.array_equal(after_drop.strategy,
                          fresh.strategy[: tiny_cnn().n + 1])


def test_swap_rejects_architecture_changes():
    eng = MapperEngine.from_config(PARAMS, CFG)
    with pytest.raises(ValueError, match="structure"):
        eng.swap_params({"not": np.zeros(3)})
    bigger = dt_init(jax.random.PRNGKey(1), DTConfig(max_steps=64))
    with pytest.raises(ValueError, match="signature"):
        eng.swap_params(bigger)
    assert eng.swaps_accepted == 0 and eng.params is PARAMS


def test_swap_under_load_parity():
    """S3: through the async front door, already-resolved futures keep
    their old-params answers; requests queued across the swap solve on
    the NEW params in their next tick."""
    eng = MapperEngine.from_config(
        PARAMS, CFG, ServingConfig(max_coalesce=4, flush_ms=1e6))
    eng.warmup([vgg16(), tiny_cnn()], EDGE, max_tick=4)
    sched = AsyncMapperScheduler(eng)
    keep = MapRequest(vgg16(), 64, 20 * MB, EDGE)
    drop = MapRequest(tiny_cnn(), 32, 5 * MB, EDGE)
    f_old = sched.submit(keep, now=0.0)
    sched.drain(0.0)                              # tick 1: old params
    assert f_old.done
    # queued BEFORE the swap, but its tick forms AFTER: new params solve it
    f_inflight = sched.submit(drop, now=1.0)
    assert not f_inflight.done
    eng.swap_params(PARAMS2,
                    invalidate=region_key_predicate([tiny_cnn()], [],
                                                    _accel_key))
    f_hit = sched.submit(keep, now=2.0)           # survives: resolves at submit
    assert f_hit.done and f_hit.result().cached
    assert np.array_equal(f_hit.result().strategy, f_old.result().strategy)
    sched.drain(2.0)                              # tick 2: new params
    env = FusionEnv(tiny_cnn(), EDGE, batch=32, budget_bytes=5 * MB, nmax=8)
    fresh = dnnfuser_infer_fused(PARAMS2, CFG, env)
    assert np.array_equal(f_inflight.result().strategy,
                          fresh.strategy[: tiny_cnn().n + 1])
    assert eng.compile_count > 0 and eng.swaps_accepted == 1


def test_upgrade_pytree_function_preservation_on_swap(tmp_path):
    """S3: the swap candidate restored through ``upgrade_pytree`` (the
    documented checkpoint path) is leaf-exact with what was trained, and
    the engine serves exactly that function after the swap."""
    Checkpointer(tmp_path).save(1, {"params": PARAMS2, "opt": {"t": 0}})
    cand, missing = upgrade_pytree(Checkpointer(tmp_path).path(), PARAMS,
                                   prefix="params")
    assert missing == []                          # same arch: nothing zero-filled
    for a, b in zip(jax.tree.leaves(cand), jax.tree.leaves(PARAMS2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    eng = MapperEngine.from_config(PARAMS, CFG)
    eng.swap_params(cand)
    resp = eng.serve_one(MapRequest(vgg16(), 64, 20 * MB, EDGE))
    env = FusionEnv(vgg16(), EDGE, batch=64, budget_bytes=20 * MB, nmax=20)
    fresh = dnnfuser_infer_fused(PARAMS2, CFG, env)
    assert np.array_equal(resp.strategy, fresh.strategy[: vgg16().n + 1])


# --- the refresh pipeline ----------------------------------------------------

@pytest.fixture(scope="module")
def live_params():
    """A mapper briefly imitation-trained on tiny_cnn@edge (4-8 MB), so
    the probe gate has a meaningful live score to defend."""
    ds = generate_teacher_corpus(
        [tiny_cnn()], [EDGE], batch=64, budgets_mb=[4, 8], max_steps=20,
        top_k=4, ga_cfg=GSamplerConfig(population=16, generations=8))
    p, _ = train_model(lambda p, b: dt_loss(p, CFG, b),
                       dt_init(jax.random.PRNGKey(0), CFG), ds,
                       TrainConfig(steps=60, batch_size=16))
    return p


def test_refresh_closed_loop_accepts_and_swaps(live_params, tmp_path):
    """Drifted traffic -> report -> corpus -> fine-tune -> gate -> swap:
    the full loop, on a datacenter-shift stream."""
    eng = MapperEngine.from_config(
        live_params, CFG,
        ServingConfig(max_coalesce=8,
                      drift=DriftConfig(window=8, replay_capacity=64)))
    eng.warmup([tiny_cnn()], EDGE, max_tick=4)
    for i in range(8):                            # in-distribution window
        eng.serve([MapRequest(tiny_cnn(), 32, (4 + i % 4) * MB, EDGE)])
    for i in range(8):                            # drifted window
        eng.serve([MapRequest(tiny_cnn(), 64, (40 + i) * MB, DC)])
    assert eng.monitor.reports_fired == 1
    worker = RefreshWorker(
        eng, train=TrainConfig(steps=40, batch_size=16, lr=1e-4, warmup=5),
        ga=GSamplerConfig(population=16, generations=8), batch=64,
        top_k=4, max_probe=4, ckpt_dir=tmp_path)
    res = worker.poll()
    assert res is not None and res["accepted"]
    assert res["candidate_score"] >= res["live_score"]
    assert eng.swaps_accepted == 1 and eng.params is not live_params
    assert "datacenter" in eng.monitor.known_accels   # stops re-firing
    assert worker.poll() is None                  # reports were drained
    s = eng.stats()["drift"]
    assert s["swaps_accepted"] == 1 and s["reports_fired"] == 1


def test_refresh_gate_rejects_bad_candidate(live_params, tmp_path,
                                            monkeypatch):
    """The quality gate: a candidate that probes worse than the live
    params is REJECTED — the serving checkpoint and the strategy cache
    stay untouched.  The probe scorer is stubbed to force the worse-
    candidate branch deterministically (its real ordering is pinned by
    ``test_probe_score_orders_params``)."""
    import repro.serving.refresh as refresh_mod
    eng = MapperEngine.from_config(live_params, CFG)
    eng.serve_one(MapRequest(tiny_cnn(), 64, 6 * MB, EDGE))
    entries = len(eng.strategies)
    monkeypatch.setattr(
        refresh_mod, "probe_score",
        lambda params, cfg, conds, repair=True:
            1.0 if params is eng.params else 0.5)
    worker = RefreshWorker(
        eng, train=TrainConfig(steps=5, batch_size=16, lr=1e-4, warmup=1),
        ga=GSamplerConfig(population=16, generations=8), batch=64,
        top_k=4, max_probe=4, ckpt_dir=tmp_path)
    res = worker.refresh([tiny_cnn()], [EDGE], [4.0, 8.0])
    assert not res["accepted"]
    assert eng.params is live_params              # swap never happened
    assert eng.swaps_rejected == 1 and eng.swaps_accepted == 0
    assert len(eng.strategies) == entries         # cache untouched


def test_probe_score_orders_params(live_params):
    """probe_score: trained params must beat random init on the trained
    region (the quantity the gate compares)."""
    conds = [(tiny_cnn(), 64, 6 * MB, EDGE), (tiny_cnn(), 64, 7 * MB, EDGE)]
    assert probe_score(live_params, CFG, conds) >= \
        probe_score(PARAMS, CFG, conds)
    assert probe_score(live_params, CFG, []) == 0.0
