"""Data-parallel engine replicas (DESIGN.md §14).

The replica contract: sharding a formed tick's independent vmap lanes
across a ("data",) mesh is a PLACEMENT decision, not a numeric one —
per-row results are bit-identical to the single-device program, and the
engine's compile accounting/warmed-set closure still hold.

The container exposes one physical CPU device, so the multi-device path
runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
(exactly how the CI scaling-smoke job runs it); the single-device
``ReplicaGroup(1)`` path runs in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import ACCEL_ZOO, DTConfig, dt_init
from repro.serving import MapperEngine, MapRequest, ReplicaGroup
from repro.workloads import tiny_cnn

MB = 2 ** 20

CFG = DTConfig(max_steps=20)
PARAMS = dt_init(jax.random.PRNGKey(2), CFG)


def test_replica_group_validates_count():
    avail = len(jax.devices())
    with pytest.raises(ValueError, match="visible"):
        ReplicaGroup(avail + 1)
    with pytest.raises(ValueError, match="visible"):
        ReplicaGroup(0)
    g = ReplicaGroup(1)
    assert g.n == 1 and g.pad_width(1) == 1
    s = g.stats()
    assert s["n_replicas"] == 1 and s["sharded_calls"] == 0


def test_single_replica_engine_bit_identical_inprocess():
    """replicas=1 exercises the full placement path (replicated params,
    sharded ticks) on one device — results must match the plain engine."""
    plain = MapperEngine(PARAMS, CFG)
    rep = MapperEngine(PARAMS, CFG, replicas=1)
    reqs = [MapRequest(tiny_cnn(), 1 + i % 3, (6 + i) * MB,
                       ACCEL_ZOO["edge"]) for i in range(5)]
    base = [plain.serve_one(r) for r in reqs]
    out = rep.serve(reqs)
    for a, b in zip(out, base):
        assert (a.strategy == b.strategy).all()
        assert a.latency == b.latency and a.valid == b.valid
    rs = rep.stats()["replicas"]
    assert rs["n_replicas"] == 1 and rs["sharded_calls"] >= 1
    assert sum(rs["rows_per_replica"]) >= len(reqs)


_SUBPROC = textwrap.dedent("""
    import jax, numpy as np
    assert len(jax.devices()) == 2, jax.devices()
    from repro.core import ACCEL_ZOO, DTConfig, dt_init
    from repro.serving import MapperEngine, MapRequest
    from repro.workloads import tiny_cnn

    MB = 2 ** 20
    cfg = DTConfig(max_steps=8, n_blocks=1, d_model=32, d_ff=64)
    params = dt_init(jax.random.PRNGKey(2), cfg)
    reqs = [MapRequest(tiny_cnn(), 1 + i % 3, (6 + i) * MB,
                       ACCEL_ZOO["edge"]) for i in range(5)]
    single = MapperEngine(params, cfg)
    base = [single.serve_one(r) for r in reqs]
    rep = MapperEngine(params, cfg, replicas=2)
    out = rep.serve(reqs)
    for a, b in zip(out, base):
        assert (a.strategy == b.strategy).all()
        assert a.latency == b.latency and a.peak_mem == b.peak_mem
        assert a.valid == b.valid
    rs = rep.stats()["replicas"]
    assert rs["n_replicas"] == 2 and len(rs["devices"]) == 2
    assert rs["sharded_calls"] >= 1
    assert rs["rows_per_replica"][0] == rs["rows_per_replica"][1] > 0
    # replica padding: even a 1-request tick pads to one lane per replica
    calls = rep.device_calls
    rep.serve([MapRequest(tiny_cnn(), 4, 32 * MB, ACCEL_ZOO["edge"])])
    assert rep.device_calls == calls + 1
    assert rep.rows_padded >= 1                  # 1 request -> 2 lanes
    print("REPLICA_PARITY_OK")
""")


def test_two_replica_parity_subprocess():
    """Shard a tick across 2 (virtual) devices; per-row results must be
    bit-identical to the single-device engine in the same process."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "REPLICA_PARITY_OK" in proc.stdout
