"""Shared adversarial workload set for the optimality-oracle tests.

Each case is a (name, workload, batch, budget_bytes, pack_accel,
serve_accel) tuple chosen to sit on an edge the evaluators historically
get wrong (DESIGN §16): degenerate single-layer chains, budgets exactly
at the feasibility boundary, pack/serve BPE mismatch, mixed-magnitude
layer sizes, and depthwise utilization caps.  Not collected by pytest
(no ``test_`` prefix); imported by test_optimal / test_kernels /
test_search via the tests-dir sys.path entry.
"""
import numpy as np

from repro.core import cost_model as cm
from repro.core import ref_model
from repro.core.accel import ACCEL_ZOO, PAPER_ACCEL
from repro.workloads.layer import Layer, Workload

MB = 2.0 ** 20
NMAX = 8


def _wl(name, layers, input_elems):
    return Workload(name=name, layers=tuple(layers),
                    input_elems=float(input_elems),
                    input_shape6=(4, 4, 4, 4, 1, 1))


def single_layer():
    """n=1: the only fusion decision is the trailing position's tiling."""
    return _wl("adv_single", [
        Layer.op("conv", macs=2.0e6, out_elems=4096.0, w_elems=1024.0,
                 shape6=(8, 8, 8, 8, 1, 1)),
    ], input_elems=4096.0)


def mixed_magnitude():
    """Layer sizes spanning 4 orders of magnitude: rounding in f32
    accumulations shows up here first."""
    return _wl("adv_mixed", [
        Layer.op("big", macs=5.0e8, out_elems=2.0e6, w_elems=256.0,
                 shape6=(64, 64, 32, 32, 1, 1)),
        Layer.op("tiny", macs=3.0e4, out_elems=64.0, w_elems=1.0e5,
                 shape6=(2, 2, 2, 2, 1, 1)),
        Layer.op("mid", macs=1.0e6, out_elems=9000.0, w_elems=4096.0,
                 shape6=(16, 16, 8, 8, 1, 1)),
    ], input_elems=1.0e6)


def depthwise_capped():
    """Depthwise layer (util_cap=0.08) between two convs: the utilization
    clamp must survive every evaluator port."""
    return _wl("adv_dw", [
        Layer.conv("c0", k=32, c=16, y=14, x=14, r=3, s=3),
        Layer.depthwise("dw", c=32, y=14, x=14, r=3, s=3),
        Layer.conv("c1", k=64, c=32, y=7, x=7, r=1, s=1),
    ], input_elems=16.0 * 14 * 14)


def skip_chain():
    """Residual skips, including a skip to the network input (src=0) and a
    skip that crosses a likely group boundary."""
    return _wl("adv_skip", [
        Layer.conv("c0", k=16, c=8, y=8, x=8, r=3, s=3),
        Layer.conv("c1", k=16, c=16, y=8, x=8, r=3, s=3, skip_src=0),
        Layer.conv("c2", k=16, c=16, y=8, x=8, r=3, s=3),
        Layer.conv("c3", k=16, c=16, y=8, x=8, r=3, s=3, skip_src=1),
    ], input_elems=8.0 * 8 * 8)


def _boundary_budget(wl, batch, hw, frac=0.6):
    """A budget EXACTLY equal to some strategy's f64 peak: feasibility at
    this budget flips on the comparison's tie-handling (peak <= budget)."""
    from repro.core import optimal as op
    wl_np = {k: np.asarray(v)
             for k, v in cm.pack_workload(wl, hw, NMAX).items()}
    # peak of the all-sync (no-fusion) strategy is always achievable
    s = np.full(NMAX, cm.SYNC, np.int32)
    s[0] = batch
    ref = ref_model.evaluate_ref(op.scaled_wl_np(wl_np, hw), s, batch,
                                 1e30, hw)
    return float(ref["peak_mem"])


def cases():
    """The adversarial (name, wl, batch, budget_bytes, pack_hw, serve_hw)
    grid.  pack_hw != serve_hw rows exercise the BPE-rescale path."""
    edge, dc = ACCEL_ZOO["edge"], ACCEL_ZOO["datacenter"]
    out = [
        ("single_tight", single_layer(), 8, 0.05 * MB, edge, edge),
        ("single_loose", single_layer(), 8, 64 * MB, edge, edge),
        ("mixed_mag", mixed_magnitude(), 16, 24 * MB, edge, edge),
        ("mixed_mag_bpe", mixed_magnitude(), 16, 24 * MB, PAPER_ACCEL, dc),
        ("depthwise", depthwise_capped(), 8, 2 * MB, edge, edge),
        ("skips", skip_chain(), 8, 1 * MB, edge, edge),
        ("skips_bpe", skip_chain(), 8, 1 * MB, PAPER_ACCEL, dc),
    ]
    # budget exactly AT the all-sync peak (feasible by <=), and one ulp
    # below it (the all-sync fallback must report invalid or find another)
    wl = depthwise_capped()
    at = _boundary_budget(wl, 8, edge)
    out.append(("boundary_at", wl, 8, at, edge, edge))
    out.append(("boundary_below", wl, 8, np.nextafter(at, 0.0), edge, edge))
    return out


def packed(wl, pack_hw):
    """Packed numpy workload dict at NMAX for ``pack_hw``'s datatype."""
    return {k: np.asarray(v)
            for k, v in cm.pack_workload(wl, pack_hw, NMAX).items()}
